from .synthetic import hki_series, osm_points, tweet_latitudes, make_queries_1d, make_queries_2d

__all__ = ["hki_series", "osm_points", "tweet_latitudes",
           "make_queries_1d", "make_queries_2d"]
