"""Synthetic datasets statistically matched to the paper's three benchmarks.

The container is offline, so the real HKI / TWEET / OSM files are not
available; these generators reproduce their relevant statistics (sizes,
smooth random-walk measure for HKI, skewed clustered point distributions for
TWEET/OSM) with fixed seeds, at any requested scale up to the paper's 100M.

    HKI   [3]  0.9M (timestamp, index value)      -> MAX queries
    TWEET [15] 1M   (latitude,)                   -> COUNT queries (1 key)
    OSM   [5]  100M (latitude, longitude)         -> COUNT queries (2 keys)
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

__all__ = ["hki_series", "tweet_latitudes", "osm_points",
           "make_queries_1d", "make_queries_2d"]


def hki_series(n: int = 900_000, seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """(timestamps, index values): minute-bar random walk around ~30_000
    (the Hang-Seng-like level of the paper's HK-40 2018 dataset)."""
    rng = np.random.default_rng(seed)
    # trading-minute timestamps with gaps (sessions), strictly increasing
    t = np.cumsum(rng.uniform(0.5, 1.5, n))
    # GBM-ish walk with intraday noise and occasional jumps
    steps = rng.normal(0, 12.0, n) + rng.normal(0, 80.0, n) * (rng.uniform(size=n) < 0.002)
    level = 30_000 + np.cumsum(steps)
    level = np.maximum(level, 1000.0)
    return t, level


def tweet_latitudes(n: int = 1_000_000, seed: int = 1) -> np.ndarray:
    """1-D latitudes: mixture of city clusters + sparse background, in
    [-60, 70] — the skew profile of geotagged tweet latitudes."""
    rng = np.random.default_rng(seed)
    centers = np.array([40.7, 34.0, 51.5, 48.8, 35.7, 19.4, -23.5, 1.3, 28.6, -33.9])
    weights = np.array([.2, .14, .12, .08, .1, .08, .08, .06, .08, .06])
    comp = rng.choice(len(centers), size=n, p=weights)
    lat = centers[comp] + rng.normal(0, 1.5, n)
    bg = rng.uniform(-60, 70, n)
    take_bg = rng.uniform(size=n) < 0.05
    lat = np.where(take_bg, bg, lat)
    return np.clip(lat, -60, 70)


def osm_points(n: int = 1_000_000, seed: int = 2) -> Tuple[np.ndarray, np.ndarray]:
    """2-D (latitude, longitude) mixture: dense metro clusters, road-like
    filaments, uniform background — OSM-node-like skew."""
    rng = np.random.default_rng(seed)
    centers = np.array([
        [40.7, -74.0], [34.0, -118.2], [51.5, -0.1], [48.8, 2.3],
        [35.7, 139.7], [19.4, -99.1], [-23.5, -46.6], [1.3, 103.8],
        [28.6, 77.2], [-33.9, 151.2], [55.7, 37.6], [30.0, 31.2],
    ])
    weights = np.full(len(centers), 1 / len(centers))
    comp = rng.choice(len(centers), size=n, p=weights)
    pts = centers[comp] + rng.normal(0, 1.2, (n, 2))
    # filaments: move a third of points along random "roads"
    fil = rng.uniform(size=n) < 0.3
    tpar = rng.uniform(-8, 8, n)
    ang = rng.uniform(0, np.pi, len(centers))[comp]
    pts[fil, 0] += tpar[fil] * np.cos(ang[fil])
    pts[fil, 1] += tpar[fil] * np.sin(ang[fil])
    bg = np.stack([rng.uniform(-60, 70, n), rng.uniform(-180, 180, n)], axis=1)
    take_bg = rng.uniform(size=n) < 0.08
    pts = np.where(take_bg[:, None], bg, pts)
    lat = np.clip(pts[:, 0], -60, 70)
    lon = np.clip(pts[:, 1], -180, 180)
    return lat, lon


def make_queries_1d(keys: np.ndarray, n_queries: int = 1000, seed: int = 7,
                    selectivity: float | None = None):
    """Paper §7.1: endpoints drawn from the dataset's keys.  With
    ``selectivity`` set, ranges cover ~that fraction of sorted keys."""
    rng = np.random.default_rng(seed)
    k = np.sort(np.asarray(keys, np.float64))
    n = len(k)
    if selectivity is None:
        a = k[rng.integers(0, n, n_queries)]
        b = k[rng.integers(0, n, n_queries)]
        return np.minimum(a, b), np.maximum(a, b)
    span = max(1, int(selectivity * n))
    i0 = rng.integers(0, max(1, n - span), n_queries)
    return k[i0], k[np.minimum(i0 + span, n - 1)]


def make_queries_2d(px: np.ndarray, py: np.ndarray, n_queries: int = 1000,
                    seed: int = 7, frac: float = 0.05):
    """Rectangles sampled from the dataset (paper §7.1): centers at data
    points, extents ~frac of the data bounding box."""
    rng = np.random.default_rng(seed)
    n = len(px)
    ci = rng.integers(0, n, n_queries)
    wx = (px.max() - px.min()) * frac * rng.uniform(0.3, 1.5, n_queries)
    wy = (py.max() - py.min()) * frac * rng.uniform(0.3, 1.5, n_queries)
    x0 = px[ci] - wx / 2
    x1 = px[ci] + wx / 2
    y0 = py[ci] - wy / 2
    y1 = py[ci] + wy / 2
    return x0, x1, y0, y1
