"""Deterministic synthetic LM data pipeline.

Step-indexed PRNG: batch(step) is a pure function of (seed, step, shape), so
a restart from checkpoint step N reproduces exactly the batches the failed
run would have seen — the data-side half of fault tolerance.  Batches are
produced host-side as numpy and device_put with the cell's input sharding.

PolyFit integration (DESIGN.md §5): the pipeline keeps a PolyFit COUNT index
over the corpus' sequence-length distribution; bucketing/mixing decisions
query it instead of scanning histograms (``length_stats``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import numpy as np

__all__ = ["SyntheticTokens", "length_stats"]


@dataclasses.dataclass
class SyntheticTokens:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    frontend: str = "none"
    frontend_dim: int = 0
    n_img_tokens: int = 0
    enc_len: int = 0

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed << 32) ^ step)
        out = {"tokens": rng.integers(
            0, self.vocab, (self.global_batch, self.seq_len), dtype=np.int32)}
        if self.frontend == "audio_stub":
            out["frames"] = rng.normal(
                0, 1, (self.global_batch, self.enc_len or self.seq_len,
                       self.frontend_dim)).astype(np.float32)
        elif self.frontend == "vision_stub":
            out["images"] = rng.normal(
                0, 1, (self.global_batch, self.n_img_tokens,
                       self.frontend_dim)).astype(np.float32)
        return out

    def sharded_batch(self, step: int, shardings) -> Dict:
        host = self.batch(step)
        return {k: jax.device_put(v, shardings[k]) if k in shardings
                else jax.device_put(v) for k, v in host.items()}


def length_stats(doc_lengths: np.ndarray, buckets, delta: float = 64.0):
    """Approximate per-bucket document counts via a PolyFit COUNT index over
    the length distribution (the paper's technique inside the pipeline)."""
    from ..core import build_index_1d, query_sum
    import jax.numpy as jnp

    idx = build_index_1d(np.asarray(doc_lengths, np.float64), None, "count",
                         deg=2, delta=delta)
    lqs = np.asarray([b[0] for b in buckets], np.float64)
    uqs = np.asarray([b[1] for b in buckets], np.float64)
    res = query_sum(idx, jnp.asarray(lqs), jnp.asarray(uqs))
    return np.asarray(res.answer), idx
