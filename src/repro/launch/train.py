"""Training launcher: --arch x --shape on a (data, model) mesh with
checkpoint/restart, heartbeat/straggler monitoring, and injected-failure
recovery (elastic re-mesh + restore).

CPU-runnable end to end with --smoke (reduced config); the production mesh
path is exercised shape-only by launch/dryrun.py.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --smoke \
        --steps 20 --fail-at 7 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse

import jax
from jax.sharding import NamedSharding

from ..checkpoint import CheckpointManager
from ..configs import ARCHS, SHAPES
from ..data.pipeline import SyntheticTokens
from ..dist.fault_tolerance import (FailureInjector, HeartbeatMonitor,
                                    SimulatedPodFailure, elastic_remesh)
from ..dist.sharding import (batch_specs, mesh_context, param_specs,
                             state_specs)
from ..models import init_model
from ..optim import adamw_init
from ..train import make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--shape", default="train_4k", choices=sorted(SHAPES))
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config + tiny batch (CPU)")
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=5)
    ap.add_argument("--fail-at", type=int, nargs="*", default=[],
                    help="inject a simulated pod failure at these steps")
    ap.add_argument("--data-axis", type=int, default=1)
    ap.add_argument("--model-axis", type=int, default=1)
    args = ap.parse_args(argv)

    cfg = ARCHS[args.arch]
    shape = SHAPES[args.shape]
    if args.smoke:
        cfg = cfg.smoke()
        import dataclasses as dc
        shape = dc.replace(shape, seq_len=32, global_batch=4)

    def build_mesh():
        return jax.make_mesh((args.data_axis, args.model_axis),
                             ("data", "model"))

    mesh = build_mesh()
    rng = jax.random.PRNGKey(0)
    params = init_model(rng, cfg)
    pspecs = param_specs(params, mesh)
    params = jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), params, pspecs)
    state = adamw_init(params)
    sspecs = state_specs(params, mesh)

    pipe = SyntheticTokens(cfg.vocab, shape.seq_len, shape.global_batch,
                           frontend=cfg.frontend,
                           frontend_dim=cfg.frontend_dim,
                           n_img_tokens=cfg.n_img_tokens,
                           enc_len=shape.seq_len)
    bspecs = batch_specs(cfg, shape, mesh)
    bshard = {k: NamedSharding(mesh, s) for k, s in bspecs.items()}

    train_step = jax.jit(make_train_step(cfg, microbatches=args.microbatches,
                                         total_steps=args.steps),
                         donate_argnums=(0,))

    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    monitor = HeartbeatMonitor()
    injector = FailureInjector(tuple(args.fail_at))
    start_step = 0
    if ckpt and ckpt.latest_step() is not None:
        state = ckpt.restore(state, mesh=mesh, specs=sspecs)
        start_step = ckpt.latest_step() + 1
        print(f"[train] restored checkpoint step {start_step - 1}")

    step = start_step
    while step < args.steps:
        try:
            injector.check(step)
            with mesh_context(mesh):
                batch = pipe.sharded_batch(step, bshard)
                state, metrics = train_step(state, batch)
            msg = monitor.beat()
            if msg:
                print(f"[train][warn] {msg}")
            if step % 1 == 0:
                print(f"[train] step {step} loss={float(metrics['loss']):.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f}")
            if ckpt and step % args.ckpt_every == 0:
                ckpt.save_async(step, tuple(state))
            step += 1
        except SimulatedPodFailure as e:
            print(f"[train][FAILURE] {e}; re-meshing + restoring")
            injector = FailureInjector(tuple(s for s in args.fail_at
                                             if s != step))
            if ckpt:
                ckpt.wait()
                state = ckpt.restore(state)
                state, mesh = elastic_remesh(state, sspecs, build_mesh)
                step = ckpt.latest_step() + 1
            else:
                state, mesh = elastic_remesh(state, sspecs, build_mesh)
            # input shardings are mesh-bound; rebind to the rebuilt mesh
            bshard = {k: NamedSharding(mesh, s) for k, s in bspecs.items()}
    if ckpt:
        ckpt.wait()
    print(f"[train] done at step {step}")
    return state


if __name__ == "__main__":
    main()
