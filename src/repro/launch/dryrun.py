import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
cell on the production meshes, record memory/cost/collective analysis.

    PYTHONPATH=src python -m repro.launch.dryrun --mesh single --arch qwen3-1.7b
    PYTHONPATH=src python -m repro.launch.dryrun --mesh multi          # all

Per cell this produces benchmarks/results/dryrun/<mesh>_<arch>_<shape>.json
holding: per-device memory stats, per-device HLO flops/bytes,
collective-bytes by op type (parsed from the optimized HLO), and the
roofline terms of EXPERIMENTS.md §Roofline.
"""
import argparse     # noqa: E402
import json         # noqa: E402
import re           # noqa: E402
import time         # noqa: E402
import traceback    # noqa: E402

import jax          # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from ..configs import ARCHS, SHAPES  # noqa: E402
from ..dist.sharding import (batch_specs, cache_specs, mesh_context, named,  # noqa: E402
                             param_specs, state_specs)
from ..launch.mesh import dp_axes, make_production_mesh  # noqa: E402
from ..models import init_cache, init_model  # noqa: E402
from ..optim import adamw_init  # noqa: E402
from ..serve.step import make_serve_step, make_prefill  # noqa: E402
from ..train import make_train_step  # noqa: E402

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "benchmarks", "results", "dryrun")

# TPU v5e constants (roofline)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # B/s per chip
ICI_BW = 50e9                # B/s per link
HBM_PER_CHIP = 16e9          # v5e HBM

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# Per-arch gradient-accumulation depth for train cells: the smallest M whose
# activations fit 16 GB/chip (probed; EXPERIMENTS.md §Perf P7).  Lower M
# means fewer FSDP weight re-gathers per step — the train cells' dominant
# collective cost scales ~linearly with M.
TRAIN_MICROBATCHES = {
    "phi3-medium-14b": 8, "zamba2-2.7b": 8,
    "phi3.5-moe-42b-a6.6b": 8, "qwen3-moe-30b-a3b": 8,
}
DEFAULT_MICROBATCHES = 4


def _shape_bytes(shape_str: str) -> int:
    """'f32[2,4096]' -> byte count (0 for token/opaque)."""
    m = re.match(r"([a-z0-9]+)\[([0-9,]*)\]", shape_str)
    if not m:
        return 0
    dt, dims = m.groups()
    if dt not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def parse_collectives(hlo_text: str):
    """Sum result-shape bytes of every collective op in optimized HLO.

    Bytes are per-device (HLO shapes after SPMD partitioning are local).
    Returns {op_type: {'count': n, 'bytes': b}}.
    """
    out = {c: {"count": 0, "bytes": 0} for c in _COLLECTIVES}
    # '%x = TYPE[dims]{layout} all-reduce(' or tuple results
    pat = re.compile(
        r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\][^ ]*))\s+"
        r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
        r"(?:-start|-done)?\(")
    for m in pat.finditer(hlo_text):
        shapes, op = m.groups()
        total = 0
        for sm in re.finditer(r"[a-z0-9]+\[[0-9,]*\]", shapes):
            total += _shape_bytes(sm.group(0))
        # -start/-done pairs would double count; only count starts and plain
        before = hlo_text[m.start():m.end()]
        if "-done(" in before:
            continue
        out[op]["count"] += 1
        out[op]["bytes"] += total
    return out


def input_specs(cfg, shape, mesh):
    """ShapeDtypeStruct stand-ins for a cell's inputs (no allocation)."""
    B, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        n_txt = S - cfg.n_img_tokens if cfg.family == "vlm" else S
        batch = {"tokens": sds((B, n_txt), jnp.int32)}
        if cfg.family == "encdec":
            batch = {"tokens": sds((B, cfg.dec_seq), jnp.int32),
                     "frames": sds((B, S, cfg.frontend_dim), jnp.float32)}
        elif cfg.family == "vlm":
            batch["images"] = sds((B, cfg.n_img_tokens, cfg.frontend_dim),
                                  jnp.float32)
        return batch
    if shape.kind == "prefill":
        n_txt = S - cfg.n_img_tokens if cfg.family == "vlm" else S
        batch = {"tokens": sds((B, n_txt), jnp.int32)}
        if cfg.family == "encdec":
            batch = {"tokens": sds((B, cfg.dec_seq), jnp.int32),
                     "frames": sds((B, S, cfg.frontend_dim), jnp.float32)}
        elif cfg.family == "vlm":
            batch["images"] = sds((B, cfg.n_img_tokens, cfg.frontend_dim),
                                  jnp.float32)
        return batch
    if shape.kind == "decode":
        cache = jax.eval_shape(lambda: init_cache(cfg, B, S))
        return {"cache": cache, "token": sds((B,), jnp.int32),
                "pos": sds((), jnp.int32)}
    raise ValueError(shape.kind)


def runnable(cfg, shape) -> str:
    """'' if the cell runs; otherwise the documented skip reason."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return ("skip: pure full-attention arch (no windowing/SSM); 500k "
                "context needs sub-quadratic attention (DESIGN.md §5)")
    return ""


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             microbatches: int = 0):
    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    mesh_name = "multi" if multi_pod else "single"
    tag = f"{mesh_name}_{arch}_{shape_name}"
    os.makedirs(RESULTS_DIR, exist_ok=True)
    out_path = os.path.join(RESULTS_DIR, tag + ".json")

    reason = runnable(cfg, shape)
    if reason:
        json.dump({"cell": tag, "status": "skipped", "reason": reason},
                  open(out_path, "w"), indent=1)
        print(f"[dryrun] {tag}: SKIP ({reason})")
        return

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()
    try:
      # the PolyFit core turns global x64 on, which leaks s64 *index*
      # dtypes into the model stack's scans (the layer k/v stacking);
      # the SPMD partitioner rejects the resulting s64/s32 index compares
      # on 512-way meshes.  The model stack is dtype-explicit, so lowering
      # with x64 off is value-identical.
      with jax.experimental.disable_x64():
        params_abs = jax.eval_shape(
            lambda: init_model(jax.random.PRNGKey(0), cfg))
        pspecs = param_specs(params_abs, mesh)
        bspecs = batch_specs(cfg, shape, mesh)

        with mesh_context(mesh):
            if shape.kind == "train":
                state_abs = jax.eval_shape(adamw_init, params_abs)
                sspecs = state_specs(params_abs, mesh)
                batch_abs = input_specs(cfg, shape, mesh)
                mb = microbatches or TRAIN_MICROBATCHES.get(
                    arch, DEFAULT_MICROBATCHES)
                if shape.global_batch % mb:
                    mb = 1
                step = make_train_step(cfg, microbatches=mb)
                lowered = jax.jit(
                    step,
                    in_shardings=(named(mesh, sspecs),
                                  {k: NamedSharding(mesh, s)
                                   for k, s in bspecs.items()}),
                    donate_argnums=(0,),
                ).lower(state_abs, batch_abs)
            elif shape.kind == "prefill":
                batch_abs = input_specs(cfg, shape, mesh)
                cspecs = cache_specs(cfg, shape, mesh)
                pre = make_prefill(cfg)
                lowered = jax.jit(
                    pre,
                    in_shardings=(named(mesh, pspecs),
                                  {k: NamedSharding(mesh, s)
                                   for k, s in bspecs.items()}),
                    out_shardings=(named(mesh, cspecs), None),
                ).lower(params_abs, batch_abs)
            else:  # decode
                ins = input_specs(cfg, shape, mesh)
                cspecs = cache_specs(cfg, shape, mesh)
                dp = dp_axes(mesh)
                dpsz = int(np.prod([mesh.shape[a] for a in dp]))
                tok_spec = P(dp if len(dp) > 1 else dp[0]) \
                    if shape.global_batch % dpsz == 0 else P(None)
                serve = make_serve_step(cfg)
                lowered = jax.jit(
                    serve,
                    in_shardings=(named(mesh, pspecs), named(mesh, cspecs),
                                  NamedSharding(mesh, tok_spec),
                                  NamedSharding(mesh, P())),
                    out_shardings=(None, named(mesh, cspecs)),
                    donate_argnums=(1,),
                ).lower(params_abs, ins["cache"], ins["token"], ins["pos"])

            compiled = lowered.compile()
        mem = compiled.memory_analysis()
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        hlo = compiled.as_text()
        coll = parse_collectives(hlo)

        flops = float(ca.get("flops", 0.0))
        bytes_accessed = float(ca.get("bytes accessed", 0.0))
        coll_bytes = sum(v["bytes"] for v in coll.values())
        terms = {
            "compute_s": flops / PEAK_FLOPS,
            "memory_s": bytes_accessed / HBM_BW,
            "collective_s": coll_bytes / ICI_BW,
        }
        dominant = max(terms, key=terms.get)
        # live bytes: outputs aliased onto donated inputs don't re-count
        dev_bytes = (mem.argument_size_in_bytes + mem.output_size_in_bytes
                     + mem.temp_size_in_bytes - mem.alias_size_in_bytes)
        result = {
            "cell": tag, "status": "ok", "arch": arch, "shape": shape_name,
            "mesh": list(mesh.shape.items()), "chips": n_chips,
            "kind": shape.kind,
            "microbatches": (microbatches or TRAIN_MICROBATCHES.get(
                arch, DEFAULT_MICROBATCHES)) if shape.kind == "train" else 1,
            "compile_s": round(time.time() - t0, 1),
            "memory": {
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                "per_device_total": dev_bytes,
                "fits_hbm": bool(dev_bytes < HBM_PER_CHIP),
            },
            "cost": {"flops_per_device": flops,
                     "bytes_per_device": bytes_accessed},
            "collectives": coll,
            "collective_bytes_per_device": coll_bytes,
            "roofline_terms_s": terms,
            "dominant_term": dominant,
        }
        json.dump(result, open(out_path, "w"), indent=1)
        print(f"[dryrun] {tag}: OK compile={result['compile_s']}s "
              f"mem/dev={dev_bytes/1e9:.2f}GB flops/dev={flops:.3e} "
              f"coll={coll_bytes/1e6:.1f}MB dominant={dominant}")
    except Exception as e:  # noqa: BLE001
        json.dump({"cell": tag, "status": "error",
                   "error": f"{type(e).__name__}: {e}",
                   "trace": traceback.format_exc()[-4000:]},
                  open(out_path, "w"), indent=1)
        print(f"[dryrun] {tag}: ERROR {type(e).__name__}: {e}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--arch", default=None, choices=sorted(ARCHS))
    ap.add_argument("--shape", default=None, choices=sorted(SHAPES))
    ap.add_argument("--microbatches", type=int, default=0)
    args = ap.parse_args(argv)

    archs = [args.arch] if args.arch else sorted(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                run_cell(arch, shape, mp, args.microbatches)


if __name__ == "__main__":
    main()
