"""Production meshes (multi-pod dry-run contract).

Functions, not module-level constants: importing this module never touches
jax device state.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh", "dp_axes"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips with multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (CPU tests)."""
    return jax.make_mesh((data, model), ("data", "model"))


def dp_axes(mesh) -> tuple:
    """The data-parallel axes of a mesh: ('pod','data') when 'pod' exists."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)
