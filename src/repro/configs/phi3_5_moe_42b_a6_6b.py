"""Config for --arch phi3.5-moe-42b-a6.6b (exact assigned spec; see registry.py)."""
from .registry import ARCHS

CONFIG = ARCHS["phi3.5-moe-42b-a6.6b"]
SMOKE = CONFIG.smoke()
