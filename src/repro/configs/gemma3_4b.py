"""Config for --arch gemma3-4b (exact assigned spec; see registry.py)."""
from .registry import ARCHS

CONFIG = ARCHS["gemma3-4b"]
SMOKE = CONFIG.smoke()
