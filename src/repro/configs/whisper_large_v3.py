"""Config for --arch whisper-large-v3 (exact assigned spec; see registry.py)."""
from .registry import ARCHS

CONFIG = ARCHS["whisper-large-v3"]
SMOKE = CONFIG.smoke()
