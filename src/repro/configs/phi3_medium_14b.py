"""Config for --arch phi3-medium-14b (exact assigned spec; see registry.py)."""
from .registry import ARCHS

CONFIG = ARCHS["phi3-medium-14b"]
SMOKE = CONFIG.smoke()
