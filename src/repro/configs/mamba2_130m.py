"""Config for --arch mamba2-130m (exact assigned spec; see registry.py)."""
from .registry import ARCHS

CONFIG = ARCHS["mamba2-130m"]
SMOKE = CONFIG.smoke()
