from .base import ArchConfig, SHAPES, ShapeSpec
from .registry import ARCHS, get_arch

__all__ = ["ArchConfig", "SHAPES", "ShapeSpec", "ARCHS", "get_arch"]
