"""Config for --arch qwen3-1.7b (exact assigned spec; see registry.py)."""
from .registry import ARCHS

CONFIG = ARCHS["qwen3-1.7b"]
SMOKE = CONFIG.smoke()
