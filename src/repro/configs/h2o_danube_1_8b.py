"""Config for --arch h2o-danube-1.8b (exact assigned spec; see registry.py)."""
from .registry import ARCHS

CONFIG = ARCHS["h2o-danube-1.8b"]
SMOKE = CONFIG.smoke()
