"""The 10 assigned architectures, exactly as specified in the assignment
(``[source; verified-tier]`` recorded in ``source``)."""
from __future__ import annotations

from .base import ArchConfig

__all__ = ["ARCHS", "get_arch"]


ARCHS = {
    "mamba2-130m": ArchConfig(
        name="mamba2-130m", family="ssm", n_layers=24, d_model=768,
        n_heads=0, n_kv_heads=0, d_ff=0, vocab=50280, ssm_state=128,
        sub_quadratic=True, source="SSD [arXiv:2405.21060; unverified]"),
    "h2o-danube-1.8b": ArchConfig(
        name="h2o-danube-1.8b", family="dense", n_layers=24, d_model=2560,
        n_heads=32, n_kv_heads=8, d_ff=6912, vocab=32000,
        window=4096, swa_period=0, sub_quadratic=True,
        source="llama+mistral mix, SWA [arXiv:2401.16818; hf]"),
    "gemma3-4b": ArchConfig(
        name="gemma3-4b", family="dense", n_layers=34, d_model=2560,
        n_heads=8, n_kv_heads=4, d_ff=10240, vocab=262144,
        window=1024, swa_period=6, rope_theta=1_000_000.0,
        sub_quadratic=True,
        source="5:1 local:global, 128k [hf:google/gemma-3-1b-pt; unverified]"),
    "phi3-medium-14b": ArchConfig(
        name="phi3-medium-14b", family="dense", n_layers=40, d_model=5120,
        n_heads=40, n_kv_heads=10, d_ff=17920, vocab=100352,
        source="RoPE SwiGLU GQA [arXiv:2404.14219; unverified]"),
    "qwen3-1.7b": ArchConfig(
        name="qwen3-1.7b", family="dense", n_layers=28, d_model=2048,
        n_heads=16, n_kv_heads=8, d_ff=6144, vocab=151936, qk_norm=True,
        source="qk_norm, GQA [hf:Qwen/Qwen3-8B; hf]"),
    "zamba2-2.7b": ArchConfig(
        name="zamba2-2.7b", family="hybrid", n_layers=54, d_model=2560,
        n_heads=32, n_kv_heads=32, d_ff=10240, vocab=32000, ssm_state=64,
        shared_attn_period=6, sub_quadratic=True,
        source="Mamba2 + shared attn blocks [arXiv:2411.15242; hf]"),
    "whisper-large-v3": ArchConfig(
        name="whisper-large-v3", family="encdec", n_layers=32, d_model=1280,
        n_heads=20, n_kv_heads=20, d_ff=5120, vocab=51866,
        n_dec_layers=32, dec_seq=448, frontend="audio_stub", frontend_dim=128,
        source="enc-dec, conv frontend (stub) [arXiv:2212.04356; unverified]"),
    "qwen3-moe-30b-a3b": ArchConfig(
        name="qwen3-moe-30b-a3b", family="moe", n_layers=48, d_model=2048,
        n_heads=32, n_kv_heads=4, d_ff=768, vocab=151936,
        n_experts=128, top_k=8, qk_norm=True,
        source="128 experts top-8 [hf:Qwen/Qwen3-30B-A3B; hf]"),
    "phi3.5-moe-42b-a6.6b": ArchConfig(
        name="phi3.5-moe-42b-a6.6b", family="moe", n_layers=32, d_model=4096,
        n_heads=32, n_kv_heads=8, d_ff=6400, vocab=32064,
        n_experts=16, top_k=2,
        source="16 experts top-2 [hf:microsoft/Phi-3.5-MoE-instruct; hf]"),
    "phi-3-vision-4.2b": ArchConfig(
        name="phi-3-vision-4.2b", family="vlm", n_layers=32, d_model=3072,
        n_heads=32, n_kv_heads=32, d_ff=8192, vocab=32064,
        frontend="vision_stub", frontend_dim=1024, n_img_tokens=576,
        source="phi3-mini + CLIP [hf:microsoft/Phi-3-vision-128k-instruct; hf]"),
}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]
