"""Config for --arch phi-3-vision-4.2b (exact assigned spec; see registry.py)."""
from .registry import ARCHS

CONFIG = ARCHS["phi-3-vision-4.2b"]
SMOKE = CONFIG.smoke()
