"""Config for --arch qwen3-moe-30b-a3b (exact assigned spec; see registry.py)."""
from .registry import ARCHS

CONFIG = ARCHS["qwen3-moe-30b-a3b"]
SMOKE = CONFIG.smoke()
