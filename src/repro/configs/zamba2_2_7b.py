"""Config for --arch zamba2-2.7b (exact assigned spec; see registry.py)."""
from .registry import ARCHS

CONFIG = ARCHS["zamba2-2.7b"]
SMOKE = CONFIG.smoke()
