"""Architecture config schema for the assigned arch pool (+ smoke variants).

Every assigned architecture is a frozen ``ArchConfig``; ``smoke()`` derives a
reduced same-family config for CPU tests.  ``d_head`` defaults to
d_model // n_heads (the assignment fixes shapes via d_model and head counts).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["ArchConfig", "SHAPES", "ShapeSpec"]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | ssm | hybrid | moe | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: Optional[int] = None
    # attention flavor
    rope_theta: float = 10_000.0
    qk_norm: bool = False
    # sliding window: per-layer pattern; None = global.  ``swa_period``:
    # every swa_period-th layer (1-indexed) is global, the rest local with
    # ``window``.  swa_period=0 -> all layers global unless window set for all
    window: Optional[int] = None
    swa_period: int = 0
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # SSM (mamba2)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 128
    # hybrid (zamba2-style): one shared attention block applied every
    # ``shared_attn_period`` mamba blocks
    shared_attn_period: int = 0
    # encoder-decoder (whisper)
    n_dec_layers: int = 0
    dec_seq: int = 448
    # modality frontend stub
    frontend: str = "none"       # none | audio_stub | vision_stub
    frontend_dim: int = 0        # mel bins / CLIP patch dim
    n_img_tokens: int = 0
    # capabilities
    sub_quadratic: bool = False  # long_500k eligibility
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    source: str = ""

    @property
    def head_dim(self) -> int:
        if self.d_head:
            return self.d_head
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def layer_windows(self, seq_len: int) -> Tuple[int, ...]:
        """Effective attention window per layer (seq_len == global)."""
        out = []
        for i in range(self.n_layers):
            if self.window is None:
                out.append(seq_len)
            elif self.swa_period and (i + 1) % self.swa_period == 0:
                out.append(seq_len)      # periodic global layer
            else:
                out.append(min(self.window, seq_len))
        return tuple(out)

    def smoke(self) -> "ArchConfig":
        """Reduced same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            n_layers=min(self.n_layers, 4 if self.shared_attn_period == 0
                         else 2 * self.shared_attn_period),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            d_head=32,
            d_ff=256 if self.d_ff else 0,
            vocab=512,
            window=None if self.window is None else 16,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_headdim=16 if self.ssm_state else 64,
            ssm_chunk=16,
            n_dec_layers=min(self.n_dec_layers, 2),
            dec_seq=16 if self.n_dec_layers else 448,
            frontend_dim=min(self.frontend_dim, 24) if self.frontend_dim else 0,
            n_img_tokens=min(self.n_img_tokens, 8) if self.n_img_tokens else 0,
        )
