"""PolyFit 1-D index: a sequence of minimax polynomial segments + aggregates.

Construction follows the paper (§4): build F(k) (CF_sum for SUM/COUNT,
DF_max for MAX/MIN; Eq. 7), segment it with GS subject to E(I) <= delta, and
index the segments.  The TPU-side layout replaces the STX B-tree / aggregate
R-tree with flat device arrays + a sparse table (DESIGN.md §3):

    seg_lo     (h,)        first key of each segment (sorted; search bounds)
    seg_hi     (h,)        last key of each segment (the fit's own scale hi)
    coeffs     (h, deg+1)  polynomial coefficients in the scaled variable u
    seg_start  (h,)        index of the first dataset key in the segment
    seg_agg    (h,)        exact MAX (or -MIN) of measures inside the segment
    st         (L, h)      sparse table over seg_agg (MAX/MIN only)

Query semantics: ranges are (lq, uq] for SUM/COUNT (the paper's Eq. 5 computes
CF(uq) - CF(lq) with an inclusive CF, which selects keys in (lq, uq]) and
[lq, uq] for MAX/MIN.  The deterministic guarantees (Lemmas 5.1-5.4) hold for
query endpoints drawn from the key domain, matching the paper's workload
("we randomly choose two keys in the datasets as the start and end points").

``staircase=True`` additionally constrains each fit at both ends of every
flat piece of the step function, extending the certified bound from the key
set toward the continuum (DESIGN.md §3); the paper-faithful default is False.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .exact import ExactMax, ExactSum, build_sparse_table
from .fitting import PolyModel, continuum_error, fit_minimax_lp
from .poly import eval_segments, locate as locate_segments
from .segmentation import (FastAcceptFitter, Fitter, greedy_segmentation,
                           parallel_segmentation)

__all__ = ["PolyFitIndex1D", "build_index_1d", "assemble_index_1d"]

_SUPPORTED = ("sum", "count", "max", "min")


@dataclasses.dataclass(frozen=True)
class PolyFitIndex1D:
    agg: str                 # 'sum' | 'count' | 'max' | 'min'
    deg: int
    delta: float
    # device arrays ----------------------------------------------------
    seg_lo: jnp.ndarray      # (h,)
    seg_hi: jnp.ndarray      # (h,)
    coeffs: jnp.ndarray      # (h, deg+1)
    seg_start: jnp.ndarray   # (h,) int32
    seg_agg: Optional[jnp.ndarray]   # (h,)  (max/min only)
    st: Optional[jnp.ndarray]        # (L, h) sparse table (max/min only)
    # refinement backend (exact structures over the raw data) -----------
    exact_sum: Optional[ExactSum]
    exact_max: Optional[ExactMax]
    n: int                   # dataset size
    # per-segment certified E(I) — the dynamic layer's drift budget is the
    # headroom delta - seg_err[i] (engine/dynamic.py); None on old pickles
    seg_err: Optional[np.ndarray] = None

    @property
    def h(self) -> int:
        return int(self.seg_lo.shape[0])

    def size_bytes(self) -> int:
        """Index size (paper's metric): segments + coefficients + aggregates.

        Excludes the raw-data refinement backend, mirroring the paper, which
        reports the learned structure's size (the dataset itself is needed by
        every method's refinement phase alike).
        """
        total = self.seg_lo.nbytes + self.seg_hi.nbytes + self.coeffs.nbytes
        total += self.seg_start.nbytes
        if self.seg_agg is not None:
            total += self.seg_agg.nbytes + self.st.nbytes
        return int(total)

    def locate(self, q: jnp.ndarray) -> jnp.ndarray:
        """Segment id containing each query key (clamped to the domain)."""
        return locate_segments(q, self.seg_lo)

    def eval_at(self, q: jnp.ndarray) -> jnp.ndarray:
        """P_{I(q)}(q): evaluate the covering polynomial (vectorized).

        u is clamped to [-1, 1]: the polynomial is certified on the segment's
        key span, and F is constant on the gap between the segment's last key
        and the next segment's first key, so clamping is exact for CF-type
        functions and prevents extrapolation outside the certified region
        (see core.poly for the shared primitives).
        """
        return eval_segments(q, self.seg_lo, self.seg_hi, self.coeffs)


def _exact_function(keys: np.ndarray, measures: np.ndarray, agg: str):
    """(sorted_keys, F(k_i) values at keys, sorted_measures)."""
    order = np.argsort(keys, kind="stable")
    k = np.asarray(keys, np.float64)[order]
    m = np.asarray(measures, np.float64)[order]
    if agg in ("sum", "count"):
        F = np.cumsum(m)                      # CF_sum (inclusive)
    elif agg == "max":
        F = m                                 # DF_max at the keys
    elif agg == "min":
        F = -m                                # reuse MAX machinery
        m = -m
    else:
        raise ValueError(f"agg must be one of {_SUPPORTED}, got {agg}")
    return k, F, m


def _continuum_post(m: PolyModel, keys, values) -> PolyModel:
    """Certificate post-processor: err := max(key error, continuum sup-error
    vs the step function F).

    Required for sound MAX/MIN evaluation: Eq. 17 maximizes P over continuous
    regions, and near-interpolating fits can bulge between keys (DESIGN.md §3,
    beyond-paper soundness fix).
    """
    ce = continuum_error(m, keys, values)
    if ce > m.err:
        m = PolyModel(m.lo, m.hi, m.coeffs, ce)
    return m


def _enforce_continuum(segs, k, F, deg, delta, fitter):
    """Re-segment (greedily) any parallel-built segment whose continuum
    certificate exceeds delta."""
    out: List[PolyModel] = []
    for s in segs:
        i = int(np.searchsorted(k, s.lo, side="left"))
        j = int(np.searchsorted(k, s.hi, side="right"))
        m = fitter(k[i:j], F[i:j], deg)
        if m.err <= delta:
            out.append(m)
        else:
            out.extend(greedy_segmentation(k[i:j], F[i:j], deg, delta, fitter=fitter))
    return out


def _staircase_points(k: np.ndarray, F: np.ndarray):
    """Add (k_{i+1}, F(k_i)) constraint pairs: both ends of each flat piece."""
    if len(k) < 2:
        return k, F
    ks = np.concatenate([k, k[1:]])
    Fs = np.concatenate([F, F[:-1]])
    order = np.argsort(ks, kind="stable")
    return ks[order], Fs[order]


def build_index_1d(
    keys: np.ndarray,
    measures: Optional[np.ndarray],
    agg: str,
    deg: int = 2,
    delta: float = 100.0,
    fitter: Fitter = fit_minimax_lp,
    method: str = "greedy",          # 'greedy' | 'parallel'
    staircase: bool = False,
    continuum: Optional[bool] = None,
    fast_accept: bool = True,
    keep_exact: bool = True,
) -> PolyFitIndex1D:
    """Construct a PolyFit index (paper §4).

    measures=None with agg='count' counts records (measure := 1).
    ``method='parallel'`` uses the batched-Lawson TPU construction.
    ``continuum`` (default: True for max/min, False for sum/count) makes the
    per-segment certificate cover the whole key span, not just the keys —
    required for sound MAX evaluation (see ``fitting.continuum_error``).
    """
    keys = np.asarray(keys, np.float64)
    if measures is None:
        if agg != "count":
            raise ValueError("measures required unless agg='count'")
        measures = np.ones_like(keys)
    measures = np.asarray(measures, np.float64)
    if agg == "count":
        measures = np.ones_like(keys)
    k, F, m_sorted = _exact_function(keys, measures, agg)

    is_extremal = agg in ("max", "min")
    if continuum is None:
        continuum = is_extremal
    eff_fitter = FastAcceptFitter(
        exact=fitter, delta=delta,
        post=_continuum_post if continuum else None, screen=fast_accept)

    fit_k, fit_F = (_staircase_points(k, F) if staircase else (k, F))
    if method == "parallel":
        segs = parallel_segmentation(fit_k, fit_F, deg, delta, fitter=eff_fitter)
        if continuum:
            segs = _enforce_continuum(segs, fit_k, fit_F, deg, delta, eff_fitter)
    else:
        segs = greedy_segmentation(fit_k, fit_F, deg, delta, fitter=eff_fitter)

    return assemble_index_1d(segs, k, m_sorted, agg, deg, delta,
                             keep_exact=keep_exact)


def assemble_index_1d(
    segs: Sequence[PolyModel],
    k: np.ndarray,
    m_sorted: np.ndarray,
    agg: str,
    deg: int,
    delta: float,
    keep_exact: bool = True,
) -> PolyFitIndex1D:
    """Assemble a PolyFitIndex1D from fitted segments + sorted data.

    ``k`` must be sorted ascending and ``m_sorted`` in internal space
    (negated for agg='min'); ``segs`` must tile the key range in order.
    Shared by ``build_index_1d`` and the dynamic merge path
    (``engine.dynamic``), which re-emits an index after selective refits.
    """
    is_extremal = agg in ("max", "min")
    h = len(segs)
    seg_lo = np.array([s.lo for s in segs])
    seg_hi = np.array([s.hi for s in segs])   # the fit's own scale hi
    coeffs = np.zeros((h, deg + 1))
    for i, s in enumerate(segs):
        coeffs[i, : len(s.coeffs)] = s.coeffs
    seg_err = np.array([s.err for s in segs])
    seg_start = np.searchsorted(k, seg_lo, side="left").astype(np.int32)

    seg_agg = st = None
    exact_sum = exact_max = None
    if is_extremal:
        seg_end = np.concatenate([seg_start[1:], [len(k)]]).astype(np.int32)
        seg_agg = np.array([
            m_sorted[s:e].max() if e > s else -np.inf
            for s, e in zip(seg_start, seg_end)
        ])
        st = build_sparse_table(seg_agg)
    if keep_exact:
        if is_extremal:
            exact_max = ExactMax(jnp.asarray(k), jnp.asarray(m_sorted),
                                 jnp.asarray(build_sparse_table(m_sorted)))
        else:
            exact_sum = ExactSum(jnp.asarray(k), jnp.asarray(np.cumsum(m_sorted)))

    return PolyFitIndex1D(
        agg=agg, deg=deg, delta=float(delta),
        seg_lo=jnp.asarray(seg_lo), seg_hi=jnp.asarray(seg_hi),
        coeffs=jnp.asarray(coeffs), seg_start=jnp.asarray(seg_start),
        seg_agg=None if seg_agg is None else jnp.asarray(seg_agg),
        st=None if st is None else jnp.asarray(st),
        exact_sum=exact_sum, exact_max=exact_max, n=len(k),
        seg_err=seg_err,
    )
