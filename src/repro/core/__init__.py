"""PolyFit core — the paper's contribution as a composable JAX module.

Index construction (fitting + segmentation) runs in float64 (the minimax
certificates are meaningless at float32 for cumulative functions reaching
1e8); we therefore enable jax x64 here.  Model/serving code elsewhere in the
package uses explicitly-dtyped float32/bfloat16 arrays and is unaffected.
"""
import jax

jax.config.update("jax_enable_x64", True)

from .exact import ExactMax, ExactSum, build_sparse_table, sparse_table_range_max  # noqa: E402
from .fitting import (  # noqa: E402
    PolyModel, continuum_error, eval_poly, eval_poly_batch, fit_lstsq,
    fit_minimax_lawson, fit_minimax_lp, lawson_batched, max_error, rescale,
)
from .poly import (clipped_poly_max, eval_segments, horner, locate,  # noqa: E402
                   scale_unit)
from .segmentation import (FastAcceptFitter, dp_segmentation,  # noqa: E402
                           greedy_segmentation, parallel_segmentation)
from .index import PolyFitIndex1D, assemble_index_1d, build_index_1d  # noqa: E402
from .index2d import (AGGS_2D, MergeSortTree, PolyFitIndex2D,  # noqa: E402
                      build_index_2d, count_dominated, dominance_rank,
                      query_count_2d, query_dommax_2d, query_sum_2d,
                      selective_refit_2d)
from .queries import (QueryResult, max_eval_segments,  # noqa: E402
                      poly_max_on_interval, query_max, query_sum)
from .baselines import FitingTree, PGMIndex, RMIIndex, cone_segments  # noqa: E402

__all__ = [
    "PolyModel", "continuum_error", "eval_poly", "eval_poly_batch", "fit_lstsq",
    "fit_minimax_lawson", "fit_minimax_lp", "lawson_batched", "max_error",
    "rescale", "FastAcceptFitter", "dp_segmentation", "greedy_segmentation",
    "parallel_segmentation", "PolyFitIndex1D", "build_index_1d",
    "assemble_index_1d",
    "AGGS_2D", "MergeSortTree", "PolyFitIndex2D", "build_index_2d",
    "count_dominated", "dominance_rank", "query_count_2d", "query_sum_2d",
    "query_dommax_2d", "selective_refit_2d",
    "ExactMax", "ExactSum", "build_sparse_table", "sparse_table_range_max",
    "QueryResult", "max_eval_segments", "poly_max_on_interval", "query_max",
    "query_sum", "clipped_poly_max", "eval_segments", "horner", "locate",
    "scale_unit",
    "FitingTree", "PGMIndex", "RMIIndex", "cone_segments",
]
