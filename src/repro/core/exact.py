"""Exact range-aggregate baselines (paper §3.2), TPU-adapted.

* ``ExactSum``  — the key-cumulative array of §3.2.1: presorted keys +
  CF_sum prefix array; a range SUM is two ``searchsorted`` lookups
  (Eq. 5).  Unlike the classical prefix-sum array it supports floating-point
  search keys, exactly as the paper notes.
* ``ExactMax``  — the aggregate max-tree of §3.2.2, adapted to TPU as a
  **sparse table** (binary lifting): ``st[j, i] = max(m[i : i+2^j])``.
  A range max over any [i, j) is the max of two overlapping power-of-two
  windows — O(1), branch-free, fully vectorized over query batches.  This
  replaces the pointer-based O(log n) tree descent (DESIGN.md §3).

Both are pure JAX on the query path (vectorized over batches of queries) and
double as the refinement backend for the relative-error guarantee
(Algorithms 2 & 3, line "perform refinement on D").
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["ExactSum", "ExactMax", "build_sparse_table", "sparse_table_range_max"]


def build_sparse_table(m: np.ndarray) -> np.ndarray:
    """st[j, i] = max(m[i : i + 2^j]) (clipped at the end).  (L, n)."""
    m = np.asarray(m)
    n = len(m)
    levels = max(1, int(np.floor(np.log2(max(n, 1)))) + 1)
    st = np.full((levels, n), -np.inf, dtype=np.float64)
    st[0] = m
    for j in range(1, levels):
        half = 1 << (j - 1)
        right = np.concatenate([st[j - 1, half:], np.full(half, -np.inf)])
        st[j] = np.maximum(st[j - 1], right)
    return st


def sparse_table_range_max(st: jnp.ndarray, i: jnp.ndarray, j: jnp.ndarray):
    """Vectorized max over [i, j) per query; empty ranges give -inf.

    i, j: int arrays of equal shape.  O(1) per query: two gathers + max.
    """
    length = jnp.maximum(j - i, 0)
    # floor(log2(length)); length==0 handled via -inf mask
    lvl = jnp.where(length > 0,
                    jnp.floor(jnp.log2(jnp.maximum(length, 1).astype(jnp.float64))).astype(jnp.int32),
                    0)
    pow2 = (1 << lvl).astype(i.dtype)
    left = st[lvl, i]
    right = st[lvl, jnp.maximum(j - pow2, 0)]
    out = jnp.maximum(left, right)
    return jnp.where(length > 0, out, -jnp.inf)


@dataclasses.dataclass(frozen=True)
class ExactSum:
    """Sorted keys + cumulative measure array; exact SUM/COUNT in O(log n)."""

    keys: jnp.ndarray      # (n,) sorted
    cf: jnp.ndarray        # (n,) CF_sum at each key (inclusive prefix sum)

    @staticmethod
    def build(keys: np.ndarray, measures: np.ndarray) -> "ExactSum":
        order = np.argsort(keys, kind="stable")
        k = np.asarray(keys, np.float64)[order]
        m = np.asarray(measures, np.float64)[order]
        return ExactSum(jnp.asarray(k), jnp.asarray(np.cumsum(m)))

    def cf_at(self, q: jnp.ndarray) -> jnp.ndarray:
        """CF_sum(q) = sum of measures with key <= q (vectorized)."""
        idx = jnp.searchsorted(self.keys, q, side="right")
        padded = jnp.concatenate([jnp.zeros((1,), self.cf.dtype), self.cf])
        return padded[idx]

    def query(self, lq: jnp.ndarray, uq: jnp.ndarray) -> jnp.ndarray:
        """Exact R_sum(D, [lq, uq]) for batches of ranges (Eq. 5).

        Inclusive endpoints: sum over keys in [lq, uq].
        """
        hi = self.cf_at(uq)
        lo_idx = jnp.searchsorted(self.keys, lq, side="left")
        padded = jnp.concatenate([jnp.zeros((1,), self.cf.dtype), self.cf])
        lo = padded[lo_idx]
        return hi - lo


@dataclasses.dataclass(frozen=True)
class ExactMax:
    """Sorted keys + sparse table over measures; exact MAX in O(1)/query."""

    keys: jnp.ndarray      # (n,) sorted
    measures: jnp.ndarray  # (n,)
    st: jnp.ndarray        # (L, n) sparse table

    @staticmethod
    def build(keys: np.ndarray, measures: np.ndarray) -> "ExactMax":
        order = np.argsort(keys, kind="stable")
        k = np.asarray(keys, np.float64)[order]
        m = np.asarray(measures, np.float64)[order]
        return ExactMax(jnp.asarray(k), jnp.asarray(m), jnp.asarray(build_sparse_table(m)))

    def query(self, lq: jnp.ndarray, uq: jnp.ndarray) -> jnp.ndarray:
        """Exact R_max(D, [lq, uq]), inclusive; empty ranges -> -inf."""
        i = jnp.searchsorted(self.keys, lq, side="left")
        j = jnp.searchsorted(self.keys, uq, side="right")
        return sparse_table_range_max(self.st, i, j)
