"""Learned-index competitors the paper benchmarks against (Table 4/5).

Per the paper's Appendix A, RMI [39], FITing-tree [23] and PGM [22] are
adapted to range aggregates by fitting CF_sum(k) instead of positions and
reusing the same guarantee machinery (Lemmas 5.1-5.4).  None of them supports
MAX or two keys (Table 4) — matching the paper, we only implement the
CF path.

* ``FitingTree`` — greedy piecewise-linear segments via the shrinking-cone
  (swing filter) algorithm from the FITing-tree paper: one pass, each segment
  anchored at its first point, error |CF - pred| <= delta certified.
* ``PGMIndex``  — piecewise-linear with recursive levels (PLA over the
  segment keys until one root segment remains), the PGM query structure.
  Simplification vs. the original: segments come from the same one-pass cone
  rather than the O'Rourke optimal hull — counts are within a small factor
  of optimal and certificates are identical in kind (documented in
  DESIGN.md §6).
* ``RMIIndex``  — 2-stage RMI with linear models (the configuration the
  paper selects after tuning, Appendix A.2: LR beats NN on response time);
  stage-2 assignment by the stage-1 model, per-leaf error bounds measured
  post-hoc (RMI gives no a-priori bound).

All query paths are vectorized JAX (searchsorted / gather / fma), so the
response-time benchmark compares like against like.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .exact import ExactSum

__all__ = ["FitingTree", "PGMIndex", "RMIIndex", "cone_segments"]


def cone_segments(keys: np.ndarray, values: np.ndarray, delta: float):
    """One-pass shrinking-cone piecewise-linear segmentation.

    Returns (starts, slopes, intercepts): per segment, pred(k) = slope *
    (k - start_key) + intercept with |values - pred| <= delta certified on
    the segment's keys.
    """
    keys = np.asarray(keys, np.float64)
    values = np.asarray(values, np.float64)
    n = len(keys)
    starts, slopes, inters = [], [], []
    i = 0
    while i < n:
        x0, y0 = keys[i], values[i]
        lo, hi = -np.inf, np.inf
        j = i + 1
        while j < n:
            dx = keys[j] - x0
            if dx <= 0:
                j += 1
                continue
            s_hi = (values[j] + delta - y0) / dx
            s_lo = (values[j] - delta - y0) / dx
            nlo, nhi = max(lo, s_lo), min(hi, s_hi)
            if nlo > nhi:
                break
            lo, hi = nlo, nhi
            j += 1
        if j == i + 1:
            slope = 0.0
        else:
            slope = 0.5 * (max(lo, -1e300) + min(hi, 1e300))
            if not np.isfinite(slope):
                slope = lo if np.isfinite(lo) else (hi if np.isfinite(hi) else 0.0)
        starts.append(x0)
        slopes.append(slope)
        inters.append(y0)
        i = j
    return (np.asarray(starts), np.asarray(slopes), np.asarray(inters))


@dataclasses.dataclass(frozen=True)
class FitingTree:
    delta: float
    starts: jnp.ndarray
    slopes: jnp.ndarray
    inters: jnp.ndarray
    exact: Optional[ExactSum]

    @staticmethod
    def build(keys, measures, delta: float, keep_exact: bool = True) -> "FitingTree":
        order = np.argsort(keys, kind="stable")
        k = np.asarray(keys, np.float64)[order]
        m = np.asarray(measures, np.float64)[order]
        cf = np.cumsum(m)
        s, sl, it = cone_segments(k, cf, delta)
        return FitingTree(float(delta), jnp.asarray(s), jnp.asarray(sl),
                          jnp.asarray(it),
                          ExactSum(jnp.asarray(k), jnp.asarray(cf)) if keep_exact else None)

    @property
    def h(self) -> int:
        return int(self.starts.shape[0])

    def size_bytes(self) -> int:
        return int(self.starts.nbytes + self.slopes.nbytes + self.inters.nbytes)

    def cf_at(self, q):
        i = jnp.clip(jnp.searchsorted(self.starts, q, side="right") - 1, 0, self.h - 1)
        return self.inters[i] + self.slopes[i] * (q - self.starts[i])

    def query(self, lq, uq, eps_rel: float | None = None):
        from .queries import QueryResult
        approx = self.cf_at(uq) - self.cf_at(lq)
        if eps_rel is None:
            return QueryResult(approx, approx, jnp.zeros_like(approx, bool))
        two_d = 2.0 * self.delta
        ok = (approx - two_d > 0) & (two_d / jnp.maximum(approx - two_d, 1e-300) <= eps_rel)
        truth = self.exact.cf_at(uq) - self.exact.cf_at(lq)
        return QueryResult(jnp.where(ok, approx, truth), approx, ~ok)


@dataclasses.dataclass(frozen=True)
class PGMIndex:
    """Recursive PLA levels: level 0 fits CF over keys; level l+1 fits the
    *rank of segment starts* over level-l start keys, giving a constant-work
    root->leaf descent (each level's prediction is off by <= eps_l ranks)."""

    delta: float
    levels: Tuple[Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray], ...]  # top->leaf
    eps_rank: int
    exact: Optional[ExactSum]

    @staticmethod
    def build(keys, measures, delta: float, eps_rank: int = 8,
              keep_exact: bool = True) -> "PGMIndex":
        order = np.argsort(keys, kind="stable")
        k = np.asarray(keys, np.float64)[order]
        m = np.asarray(measures, np.float64)[order]
        cf = np.cumsum(m)
        s, sl, it = cone_segments(k, cf, delta)
        levels = [(s, sl, it)]
        cur = s
        while len(cur) > 2 * eps_rank + 2:
            ranks = np.arange(len(cur), dtype=np.float64)
            s2, sl2, it2 = cone_segments(cur, ranks, float(eps_rank))
            levels.append((s2, sl2, it2))
            cur = s2
        levels.reverse()  # root first
        jl = tuple((jnp.asarray(a), jnp.asarray(b), jnp.asarray(c)) for a, b, c in levels)
        return PGMIndex(float(delta), jl, eps_rank,
                        ExactSum(jnp.asarray(k), jnp.asarray(cf)) if keep_exact else None)

    @property
    def h(self) -> int:
        return int(self.levels[-1][0].shape[0])

    def size_bytes(self) -> int:
        return int(sum(a.nbytes + b.nbytes + c.nbytes for a, b, c in self.levels))

    def cf_at(self, q):
        # root: binary search over the (small) top level; lower levels:
        # predicted rank +- eps_rank window searched branch-free
        s0, sl0, it0 = self.levels[0]
        i = jnp.clip(jnp.searchsorted(s0, q, side="right") - 1, 0, s0.shape[0] - 1)
        for lvl in range(1, len(self.levels)):
            s, sl, it = self.levels[lvl]
            n = s.shape[0]
            ps, psl, pit = self.levels[lvl - 1]
            pred = pit[i] + psl[i] * (q - ps[i])
            j = jnp.clip(pred.astype(jnp.int32), 0, n - 1)
            # correct within [j-eps, j+eps]: largest idx with s[idx] <= q
            lo = jnp.clip(j - self.eps_rank, 0, n - 1)
            best = lo
            for d in range(2 * self.eps_rank + 1):
                idx = jnp.clip(lo + d, 0, n - 1)
                best = jnp.where(s[idx] <= q, idx, best)
            i = best
        s, sl, it = self.levels[-1]
        return it[i] + sl[i] * (q - s[i])

    def query(self, lq, uq, eps_rel: float | None = None):
        from .queries import QueryResult
        approx = self.cf_at(uq) - self.cf_at(lq)
        if eps_rel is None:
            return QueryResult(approx, approx, jnp.zeros_like(approx, bool))
        two_d = 2.0 * self.delta
        ok = (approx - two_d > 0) & (two_d / jnp.maximum(approx - two_d, 1e-300) <= eps_rel)
        truth = self.exact.cf_at(uq) - self.exact.cf_at(lq)
        return QueryResult(jnp.where(ok, approx, truth), approx, ~ok)


@dataclasses.dataclass(frozen=True)
class RMIIndex:
    """2-stage RMI (LR root -> LR leaves), Appendix A.2 configuration."""

    n_leaf: int
    root: Tuple[float, float]            # slope, intercept -> leaf id
    slopes: jnp.ndarray                  # (n_leaf,)
    inters: jnp.ndarray
    errs: jnp.ndarray                    # (n_leaf,) measured |CF - pred| bound
    kmin: float
    exact: Optional[ExactSum]

    @staticmethod
    def build(keys, measures, n_leaf: int = 1024, keep_exact: bool = True) -> "RMIIndex":
        order = np.argsort(keys, kind="stable")
        k = np.asarray(keys, np.float64)[order]
        m = np.asarray(measures, np.float64)[order]
        cf = np.cumsum(m)
        n = len(k)
        # root LR: key -> leaf id (fit to uniform rank spread)
        ranks = np.arange(n) / max(n - 1, 1) * (n_leaf - 1)
        A = np.stack([k, np.ones_like(k)], axis=1)
        root, *_ = np.linalg.lstsq(A, ranks, rcond=None)
        leaf = np.clip((root[0] * k + root[1]).astype(np.int64), 0, n_leaf - 1)
        slopes = np.zeros(n_leaf)
        inters = np.zeros(n_leaf)
        errs = np.zeros(n_leaf)
        # leaves must be monotone in key for contiguous assignment; root LR is
        # monotone (slope>0 for sorted CF), so each leaf gets a key range
        for b in range(n_leaf):
            sel = leaf == b
            if not sel.any():
                # inherit the previous model so coverage is total
                slopes[b] = slopes[b - 1] if b else 0.0
                inters[b] = inters[b - 1] if b else 0.0
                errs[b] = errs[b - 1] if b else 0.0
                continue
            kk, vv = k[sel], cf[sel]
            if len(kk) == 1:
                slopes[b], inters[b] = 0.0, vv[0]
            else:
                Ab = np.stack([kk, np.ones_like(kk)], axis=1)
                sol, *_ = np.linalg.lstsq(Ab, vv, rcond=None)
                slopes[b], inters[b] = sol[0], sol[1]
            errs[b] = np.max(np.abs(vv - (slopes[b] * kk + inters[b])))
        return RMIIndex(n_leaf, (float(root[0]), float(root[1])),
                        jnp.asarray(slopes), jnp.asarray(inters), jnp.asarray(errs),
                        float(k[0]),
                        ExactSum(jnp.asarray(k), jnp.asarray(cf)) if keep_exact else None)

    def size_bytes(self) -> int:
        return int(self.slopes.nbytes + self.inters.nbytes + self.errs.nbytes + 16)

    def cf_at(self, q):
        b = jnp.clip((self.root[0] * q + self.root[1]).astype(jnp.int32), 0, self.n_leaf - 1)
        return self.slopes[b] * q + self.inters[b], self.errs[b]

    def query(self, lq, uq, eps_rel: float | None = None):
        from .queries import QueryResult
        pu, eu = self.cf_at(uq)
        pl, el = self.cf_at(lq)
        approx = pu - pl
        bound = eu + el
        if eps_rel is None:
            return QueryResult(approx, approx, jnp.zeros_like(approx, bool))
        ok = (approx - bound > 0) & (bound / jnp.maximum(approx - bound, 1e-300) <= eps_rel)
        truth = self.exact.cf_at(uq) - self.exact.cf_at(lq)
        return QueryResult(jnp.where(ok, approx, truth), approx, ~ok)
