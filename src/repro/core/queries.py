"""Approximate range-aggregate query evaluation (paper §5), batched in JAX.

SUM/COUNT (Alg. 2):   A = P_Iu(uq) - P_Il(lq)                       (Eq. 14)
MAX/MIN   (Alg. 3):   A = max(boundary polynomial extrema,
                              interior per-segment exact aggregates)  (Eq. 17)

Guarantees:
* Q_abs — build with delta = eps_abs/2 (SUM, Lemma 5.1) or delta = eps_abs
  (MAX, Lemma 5.3); the raw approximate answer already satisfies the bound.
* Q_rel — test Lemma 5.2 (SUM: 2*delta/(A-2*delta) <= eps_rel) or Lemma 5.4
  (MAX: A >= delta*(1+1/eps_rel)); failing queries are *vectorially* refined
  against the exact structures and merged with ``jnp.where`` — no host round
  trip (DESIGN.md §3).

Boundary extrema use closed-form zero-derivative points (Table 2 of the
paper): P' is degree deg-1; we solve linear/quadratic/cubic derivatives in
closed form (deg <= 4).  For deg >= 5 a Chebyshev-grid + Newton refinement
fallback is used (the paper likewise recommends deg <= 3 for MAX).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .exact import sparse_table_range_max
from .index import PolyFitIndex1D
from .poly import horner as _horner, locate, scale_unit

__all__ = [
    "query_sum", "query_max", "QueryResult",
    "poly_max_on_interval", "solve_derivative_roots", "max_eval_segments",
]

_NAN = jnp.nan


class QueryResult(NamedTuple):
    answer: jnp.ndarray      # final (possibly refined) answers
    approx: jnp.ndarray      # raw index-only answers
    refined: jnp.ndarray     # bool: True where refinement was triggered


# ---------------------------------------------------------------------------
# closed-form real roots of low-degree polynomials (branch-free, nan-padded)
# ---------------------------------------------------------------------------

def _roots_linear(b, a):
    """a*u + b = 0 -> 1 root (nan if degenerate)."""
    return jnp.where(jnp.abs(a) > 0, -b / jnp.where(a == 0, 1.0, a), _NAN)


def _roots_quadratic(c, b, a):
    """a u^2 + b u + c = 0 -> 2 roots (nan-padded)."""
    lin = _roots_linear(c, b)
    disc = b * b - 4 * a * c
    sq = jnp.sqrt(jnp.maximum(disc, 0.0))
    denom = jnp.where(a == 0, 1.0, 2 * a)
    r1 = (-b - sq) / denom
    r2 = (-b + sq) / denom
    quad_ok = (jnp.abs(a) > 0) & (disc >= 0)
    r1 = jnp.where(quad_ok, r1, jnp.where(jnp.abs(a) > 0, _NAN, lin))
    r2 = jnp.where(quad_ok, r2, _NAN)
    return r1, r2


def _roots_cubic(d, c, b, a):
    """a u^3 + b u^2 + c u + d = 0 -> 3 real roots (nan-padded).

    Trigonometric/Cardano method, branch-free.  Falls back to the quadratic
    solver when a == 0.
    """
    q1, q2 = _roots_quadratic(d, c, b)
    safe_a = jnp.where(jnp.abs(a) > 0, a, 1.0)
    # depressed cubic t^3 + p t + q, u = t - b/(3a)
    shift = b / (3 * safe_a)
    p = (3 * safe_a * c - b * b) / (3 * safe_a * safe_a)
    q = (2 * b**3 - 9 * safe_a * b * c + 27 * safe_a * safe_a * d) / (27 * safe_a**3)
    disc = (q * q) / 4 + (p**3) / 27
    # three-real-root branch (disc <= 0): trigonometric
    pm = jnp.minimum(p, -1e-300)
    m = 2 * jnp.sqrt(-pm / 3)
    arg = jnp.clip(3 * q / (pm * m), -1.0, 1.0)
    theta = jnp.arccos(arg) / 3
    t0 = m * jnp.cos(theta)
    t1 = m * jnp.cos(theta - 2 * jnp.pi / 3)
    t2 = m * jnp.cos(theta - 4 * jnp.pi / 3)
    # one-real-root branch (disc > 0): Cardano
    sq = jnp.sqrt(jnp.maximum(disc, 0.0))
    cbrt = lambda x: jnp.sign(x) * jnp.abs(x) ** (1.0 / 3.0)
    t_single = cbrt(-q / 2 + sq) + cbrt(-q / 2 - sq)
    three = disc <= 0
    r0 = jnp.where(three, t0, t_single) - shift
    r1_ = jnp.where(three, t1, _NAN) - shift
    r2_ = jnp.where(three, t2, _NAN) - shift
    is_cubic = jnp.abs(a) > 0
    return (jnp.where(is_cubic, r0, q1),
            jnp.where(is_cubic, r1_, q2),
            jnp.where(is_cubic, r2_, _NAN))


def solve_derivative_roots(coeffs: jnp.ndarray):
    """Real roots of P'(u) for batched coeffs (..., deg+1) -> (..., R).

    deg<=4 is closed-form (paper Table 2); deg>=5 raises (use the grid path).
    """
    deg = coeffs.shape[-1] - 1
    c = [coeffs[..., j] for j in range(deg + 1)]
    if deg <= 1:
        return jnp.full(coeffs.shape[:-1] + (1,), _NAN, coeffs.dtype)
    if deg == 2:
        r = _roots_linear(c[1], 2 * c[2])
        return r[..., None]
    if deg == 3:
        r1, r2 = _roots_quadratic(c[1], 2 * c[2], 3 * c[3])
        return jnp.stack([r1, r2], axis=-1)
    if deg == 4:
        r0, r1, r2 = _roots_cubic(c[1], 2 * c[2], 3 * c[3], 4 * c[4])
        return jnp.stack([r0, r1, r2], axis=-1)
    raise NotImplementedError("closed-form extrema only for deg<=4; "
                              "use grid_extrema for higher degrees")


def poly_max_on_interval(coeffs, ua, ub, grid_pts: int = 0):
    """max_{u in [ua, ub]} P(u), batched; empty intervals (ua>ub) -> -inf.

    Candidates: both endpoints + real zero-derivative points inside the
    interval (closed form for deg<=4) [+ optional Chebyshev grid for deg>=5].
    """
    deg = coeffs.shape[-1] - 1
    cands = [ua, ub]
    if deg >= 2:
        if deg <= 4:
            roots = solve_derivative_roots(coeffs)
        else:
            # Chebyshev grid + one Newton step toward P'=0
            t = jnp.cos(jnp.pi * (jnp.arange(grid_pts or 32) + 0.5) / (grid_pts or 32))
            grid = ua[..., None] + (ub - ua)[..., None] * (t + 1) / 2
            dcoef = coeffs[..., 1:] * jnp.arange(1, deg + 1)
            d2coef = dcoef[..., 1:] * jnp.arange(1, deg)
            d1 = _horner(dcoef, grid)
            d2 = _horner(d2coef, grid)
            roots = grid - d1 / jnp.where(jnp.abs(d2) > 1e-12, d2, 1.0)
        roots = jnp.clip(roots, ua[..., None], ub[..., None])
        roots = jnp.where(jnp.isnan(roots), ua[..., None], roots)
        cands.append(roots)
    vals = [_horner(coeffs, ua), _horner(coeffs, ub)]
    if deg >= 2:
        vals.append(_horner(coeffs[..., None, :], cands[2]).max(axis=-1))
    out = jnp.stack(vals[:2] + ([vals[2]] if deg >= 2 else []), axis=-1).max(axis=-1)
    return jnp.where(ua <= ub, out, -jnp.inf)


# ---------------------------------------------------------------------------
# SUM / COUNT (Alg. 2)
# ---------------------------------------------------------------------------

def query_sum(index: PolyFitIndex1D, lq, uq,
              eps_rel: float | None = None) -> QueryResult:
    """Approximate R_sum(D, (lq, uq]) (Eq. 14) with optional Q_rel refinement.

    With eps_rel=None this is the Q_abs path: the answer satisfies
    |A - R| <= 2*delta (= eps_abs when the index was built with
    delta = eps_abs/2, Lemma 5.1).
    """
    assert index.agg in ("sum", "count"), index.agg
    lq = jnp.asarray(lq, jnp.float64)
    uq = jnp.asarray(uq, jnp.float64)
    approx = index.eval_at(uq) - index.eval_at(lq)
    if eps_rel is None:
        return QueryResult(approx, approx, jnp.zeros_like(approx, bool))
    # Lemma 5.2 test: 2d / (A - 2d) <= eps_rel  (requires A > 2d)
    two_d = 2.0 * index.delta
    ok = (approx - two_d > 0) & (two_d / jnp.maximum(approx - two_d, 1e-300) <= eps_rel)
    exact = index.exact_sum
    if exact is None:
        raise ValueError("Q_rel refinement requires keep_exact=True")
    # vectorized refinement (Alg. 2 line 6) for the failing subset
    hi = exact.cf_at(uq)
    lo = exact.cf_at(lq)
    truth = hi - lo
    ans = jnp.where(ok, approx, truth)
    return QueryResult(ans, approx, ~ok)


# ---------------------------------------------------------------------------
# MAX / MIN (Alg. 3)
# ---------------------------------------------------------------------------

def max_eval_segments(seg_lo, seg_hi, coeffs, st, lq, uq):
    """Raw approximate MAX (Eq. 17) over flat segment arrays.

    Array-level so both ``query_max`` (index objects) and the engine's XLA
    backend (tile-padded ``IndexPlan`` arrays) share one implementation:
    padded segments carry a huge seg_lo sentinel, which in-domain queries
    never locate, and ``st`` stays unpadded at the true segment count.
    """
    il = locate(lq, seg_lo)
    iu = locate(uq, seg_lo)
    lo_l, hi_l = seg_lo[il], seg_hi[il]
    lo_u, hi_u = seg_lo[iu], seg_hi[iu]

    same = il == iu
    # left boundary segment: [lq, min(hi_l, uq)]
    ua_l = scale_unit(lq, lo_l, hi_l)
    ub_l = scale_unit(jnp.minimum(hi_l, uq), lo_l, hi_l)
    m_left = poly_max_on_interval(coeffs[il], ua_l, ub_l)
    # lq may fall in the key-free gap past the segment's last key: no data of
    # segment il is inside the query range then — suppress its contribution
    m_left = jnp.where(lq <= hi_l, m_left, -jnp.inf)
    # right boundary segment: [max(lo_u, lq), uq] — suppressed when same seg
    ua_u = scale_unit(jnp.maximum(lo_u, lq), lo_u, hi_u)
    ub_u = scale_unit(uq, lo_u, hi_u)
    m_right = jnp.where(same, -jnp.inf,
                        poly_max_on_interval(coeffs[iu], ua_u, ub_u))
    # interior fully-covered segments: exact per-segment aggregates via the
    # sparse table (replaces the aR-tree internal-node traversal)
    m_mid = sparse_table_range_max(st, il + 1, iu)
    return jnp.maximum(jnp.maximum(m_left, m_right), m_mid)


def _max_eval(index: PolyFitIndex1D, lq, uq):
    return max_eval_segments(index.seg_lo, index.seg_hi, index.coeffs,
                             index.st, lq, uq)


def query_max(index: PolyFitIndex1D, lq, uq,
              eps_rel: float | None = None) -> QueryResult:
    """Approximate R_max(D, [lq, uq]) (Eq. 17) with optional Q_rel refinement.

    Q_abs: build with delta = eps_abs (Lemma 5.3).  MIN queries reuse the MAX
    machinery on negated measures; answers are negated back here.
    """
    assert index.agg in ("max", "min"), index.agg
    neg = index.agg == "min"
    lq = jnp.asarray(lq, jnp.float64)
    uq = jnp.asarray(uq, jnp.float64)
    approx = _max_eval(index, lq, uq)
    if eps_rel is None:
        out = -approx if neg else approx
        return QueryResult(out, out, jnp.zeros_like(out, bool))
    # Lemma 5.4 test: A >= delta * (1 + 1/eps_rel)
    ok = approx >= index.delta * (1.0 + 1.0 / eps_rel)
    exact = index.exact_max
    if exact is None:
        raise ValueError("Q_rel refinement requires keep_exact=True")
    truth = exact.query(lq, uq)
    ans = jnp.where(ok, approx, truth)
    if neg:
        ans = -ans
    return QueryResult(ans, -approx if neg else approx, ~ok)
