"""PolyFit with two keys (paper §6): quadtree-segmented bivariate surfaces.

Pipeline (COUNT is the aggregate the paper evaluates; SUM/MAX/MIN over
(x, y) are the measure-carrying extension, DESIGN.md §12):

1. The fitted function per aggregate family:
   * ``count2d`` — ``CF_count(u, v)`` = #points with x<=u and y<=v (Def. 6.2);
   * ``sum2d``   — ``CF_sum(u, v)`` = sum of measures over the dominated set
     (so rectangle SUM decomposes by the same 4-corner inclusion-exclusion);
   * ``max2d``/``min2d`` — the *dominance max* staircase
     ``DMAX(u, v) = max{w_i : x_i <= u, y_i <= v}`` (MIN negates measures),
     floored at the dataset minimum so the function is total and monotone.
     MAX does not telescope over rectangle corners, so 2-D MAX/MIN queries
     are dominance (corner) queries — see DESIGN.md §12 for what a
     full-rectangle decomposition would need.
   Exact values are produced offline by a *weighted* merge-sort tree
   (numpy block sorts + searchsorted; O(n log^2 n), no per-point loops).
2. Quadtree segmentation (Fig. 10): a region whose best bivariate fit
   P(u,v) = sum a_ij u^i v^j (i,j <= deg) violates E(I) <= delta is split
   into 4 children at the midpoint.  Constraints are the data points inside
   the region plus a fixed evaluation grid and the region corners (all with
   exact F values), which controls the fit away from data — query corners
   mix x and y from *different* records, so data points alone do not cover
   the evaluation locations (documented deviation, DESIGN.md §6).  Each
   leaf carries its certified fit error (``leaf_err`` — the selective
   refit's per-leaf certificate and the source of ``certified_delta``)
   and its exact measure aggregate (``leaf_agg`` — a tested partition
   invariant today, and the interior-leaf table a future full-rectangle
   MAX decomposition would reduce over; see ROADMAP).
3. Query: 4-corner inclusion-exclusion for COUNT/SUM (Eq. 19), a single
   corner evaluation for dominance MAX/MIN.  Leaves are found with a
   fixed-depth, branch-free quadtree descent (vectorized over batches).
4. Guarantees: delta = eps_abs/4 (Lemma 6.3) for COUNT/SUM, eps_abs for
   dominance MAX/MIN (the Lemma 5.3 shape); the Q_rel acceptance tests
   (Lemma 6.4 / 5.4) route failing queries to the exact merge-sort-tree
   backend, which answers rectangle sums and dominance maxima in O(log^2 n)
   fully vectorized gathers.
5. ``selective_refit_2d`` absorbs a merged batch of inserts/deletes without
   rebuilding the tree: a changed point (x0, y0) alters a CF-type function
   only inside its dominance region {u >= x0, v >= y0}, and *constantly* on
   any leaf wholly inside it — so clean dominated leaves take an exact
   constant-coefficient bump (E(I) unchanged), leaves crossed by the
   region's boundary rays are re-fitted (and re-split while the certificate
   fails), and every other leaf is untouched, bit for bit.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "AGGS_2D",
    "dominance_rank",
    "count_dominated",
    "MergeSortTree",
    "PolyFitIndex2D",
    "build_index_2d",
    "selective_refit_2d",
    "query_count_2d",
    "query_sum_2d",
    "query_dommax_2d",
    "mst_count_prefix",
    "mst_weighted_prefix",
    "mst_cf",
    "mst_cf_sum",
    "mst_dommax",
    "quadtree_locate",
    "quadtree_eval_cf",
]

AGGS_2D = ("count2d", "sum2d", "max2d", "min2d")


# ---------------------------------------------------------------------------
# offline exact CF_count evaluation
# ---------------------------------------------------------------------------

def count_dominated(px: np.ndarray, py: np.ndarray,
                    qx: np.ndarray, qy: np.ndarray) -> np.ndarray:
    """For each query point (qx_j, qy_j): #data points with x<=qx and y<=qy."""
    tree = MergeSortTree.build(px, py)
    return np.asarray(tree.cf(jnp.asarray(np.asarray(qx, np.float64)),
                              jnp.asarray(np.asarray(qy, np.float64))))


def dominance_rank(px: np.ndarray, py: np.ndarray) -> np.ndarray:
    """CF_count at every data point (inclusive of the point itself)."""
    return count_dominated(px, py, px, py)


# ---------------------------------------------------------------------------
# exact online backend: merge sort tree (refinement + exact baseline)
# ---------------------------------------------------------------------------

def mst_count_prefix(xs: jnp.ndarray, ys_levels: jnp.ndarray, i: jnp.ndarray,
                     v: jnp.ndarray, strict: bool = False) -> jnp.ndarray:
    """#points among x-rank [0, i) with y <= v (or y < v if strict).

    Array-level (no MergeSortTree object) so the engine can jit it over
    ``IndexPlan2D`` refinement arrays; the static per-level binary searches
    unroll at trace time.
    """
    n = int(xs.shape[0])
    levels = int(ys_levels.shape[0])
    total = jnp.zeros_like(i)
    pos = jnp.zeros_like(i)
    for l in range(levels - 1, -1, -1):
        b = 1 << l
        take = pos + b <= i
        # binary search for v in ys_levels[l][pos : pos+b] (sorted run)
        lo = jnp.zeros_like(i)
        hi = jnp.full_like(i, b)
        for _ in range(l + 1):
            active = lo < hi
            mid = (lo + hi) // 2
            idx = jnp.clip(pos + jnp.minimum(mid, b - 1), 0, n - 1)
            y = ys_levels[l][idx]
            go_right = active & ((y < v) if strict else (y <= v))
            lo = jnp.where(go_right, mid + 1, lo)
            hi = jnp.where(active & ~go_right, mid, hi)
        total = total + jnp.where(take, lo, 0)
        pos = jnp.where(take, pos + b, pos)
    return total


def mst_weighted_prefix(xs: jnp.ndarray, ys_levels: jnp.ndarray,
                        wacc_levels: jnp.ndarray, i: jnp.ndarray,
                        v: jnp.ndarray, *, mode: str) -> jnp.ndarray:
    """Weighted dominance reduction over x-rank [0, i) with y <= v.

    ``wacc_levels`` are per-level, per-block *inclusive* prefix arrays over
    the block-y-sorted weights: prefix sums for mode='sum', prefix maxima
    for mode='max' (identities 0 / -inf).  Same block decomposition — and
    the same in-block binary search, so the same op sequence — as
    ``mst_count_prefix``; one extra clamped gather per level turns the
    in-block count into the block's weighted contribution.  Plain jnp on
    values, so it runs inside Pallas kernel bodies as well as jitted XLA.
    """
    if mode not in ("sum", "max"):
        raise ValueError(f"mode must be 'sum' or 'max', got {mode!r}")
    is_sum = mode == "sum"
    n = int(xs.shape[0])
    levels = int(ys_levels.shape[0])
    ident = 0.0 if is_sum else -jnp.inf
    total = jnp.full(jnp.shape(i), ident, wacc_levels.dtype)
    pos = jnp.zeros_like(i)
    for l in range(levels - 1, -1, -1):
        b = 1 << l
        take = pos + b <= i
        lo = jnp.zeros_like(i)
        hi = jnp.full_like(i, b)
        for _ in range(l + 1):
            active = lo < hi
            mid = (lo + hi) // 2
            idx = jnp.clip(pos + jnp.minimum(mid, b - 1), 0, n - 1)
            go_right = active & (ys_levels[l][idx] <= v)
            lo = jnp.where(go_right, mid + 1, lo)
            hi = jnp.where(active & ~go_right, mid, hi)
        val = wacc_levels[l][jnp.clip(pos + lo - 1, 0, n - 1)]
        val = jnp.where(take & (lo > 0), val, ident)
        total = total + val if is_sum else jnp.maximum(total, val)
        pos = jnp.where(take, pos + b, pos)
    return total


def mst_cf(xs: jnp.ndarray, ys_levels: jnp.ndarray, u, v) -> jnp.ndarray:
    """CF_count(u, v) = #points with x <= u and y <= v, vectorized."""
    i = jnp.searchsorted(xs, u, side="right")
    return mst_count_prefix(xs, ys_levels, i, v)


def mst_cf_sum(xs: jnp.ndarray, ys_levels: jnp.ndarray,
               wcum_levels: jnp.ndarray, u, v) -> jnp.ndarray:
    """CF_sum(u, v) = sum of measures with x <= u and y <= v, vectorized."""
    i = jnp.searchsorted(xs, u, side="right")
    return mst_weighted_prefix(xs, ys_levels, wcum_levels, i, v, mode="sum")


def mst_dommax(xs: jnp.ndarray, ys_levels: jnp.ndarray,
               wpmax_levels: jnp.ndarray, u, v) -> jnp.ndarray:
    """DMAX(u, v) = max measure with x <= u and y <= v (-inf if none)."""
    i = jnp.searchsorted(xs, u, side="right")
    return mst_weighted_prefix(xs, ys_levels, wpmax_levels, i, v, mode="max")


@dataclasses.dataclass(frozen=True)
class MergeSortTree:
    """Static BIT-style decomposition for exact rectangle counts — and,
    when built with weights, exact dominance sums/maxima — in JAX.

    xs           (n,)   x-sorted keys
    ys_levels    (L, n) y values sorted within blocks of size 2^l at level l
    wcum_levels  (L, n) per-block inclusive prefix sums of the weights,
                        carried through the same block sorts (weighted only)
    wpmax_levels (L, n) per-block inclusive prefix maxima (weighted only)
    ws           (n,)   weights in x-sorted order (weighted only)
    """

    xs: jnp.ndarray
    ys_levels: jnp.ndarray
    wcum_levels: Optional[jnp.ndarray] = None
    wpmax_levels: Optional[jnp.ndarray] = None
    ws: Optional[jnp.ndarray] = None

    @staticmethod
    def build(px: np.ndarray, py: np.ndarray,
              ws: Optional[np.ndarray] = None) -> "MergeSortTree":
        order = np.argsort(px, kind="stable")
        xs = np.asarray(px, np.float64)[order]
        ys = np.asarray(py, np.float64)[order]
        n = len(xs)
        levels = max(1, int(np.ceil(np.log2(max(n, 2)))) + 1)
        npad = 1 << (levels - 1)
        arrs = np.empty((levels, n), np.float64)
        arrs[0] = ys  # level 0: blocks of size 1 (already "sorted")
        padded = np.full(npad, np.inf)
        padded[:n] = ys
        if ws is None:
            for l in range(1, levels):
                b = 1 << l
                # vectorized per-block sort: reshape to (npad/b, b), sort rows
                padded = np.sort(padded.reshape(-1, b), axis=1).reshape(-1)
                arrs[l] = padded[:n]
            return MergeSortTree(jnp.asarray(xs), jnp.asarray(arrs))
        w = np.asarray(ws, np.float64)[order]
        wcum = np.empty((levels, n), np.float64)
        wpmax = np.empty((levels, n), np.float64)
        wcum[0] = w
        wpmax[0] = w
        wpad = np.zeros(npad)
        wpad[:n] = w
        for l in range(1, levels):
            b = 1 << l
            yb = padded.reshape(-1, b)
            # stable per-block argsort: same sorted y values as np.sort,
            # plus the permutation to carry the weights along
            perm = np.argsort(yb, axis=1, kind="stable")
            yb = np.take_along_axis(yb, perm, axis=1)
            wb = np.take_along_axis(wpad.reshape(-1, b), perm, axis=1)
            padded = yb.reshape(-1)
            wpad = wb.reshape(-1)
            arrs[l] = padded[:n]
            wcum[l] = np.cumsum(wb, axis=1).reshape(-1)[:n]
            wpmax[l] = np.maximum.accumulate(wb, axis=1).reshape(-1)[:n]
        return MergeSortTree(jnp.asarray(xs), jnp.asarray(arrs),
                             jnp.asarray(wcum), jnp.asarray(wpmax),
                             jnp.asarray(w))

    @property
    def n(self) -> int:
        return int(self.xs.shape[0])

    def _count_prefix(self, i: jnp.ndarray, v: jnp.ndarray,
                      strict: bool = False) -> jnp.ndarray:
        """#points among x-rank [0, i) with y <= v (or y < v if strict)."""
        return mst_count_prefix(self.xs, self.ys_levels, i, v, strict)

    def query(self, x0, x1, y0, y1) -> jnp.ndarray:
        """Exact #points in [x0,x1] x [y0,y1] (inclusive), vectorized."""
        i0 = jnp.searchsorted(self.xs, x0, side="left")
        i1 = jnp.searchsorted(self.xs, x1, side="right")
        hi = self._count_prefix(i1, y1) - self._count_prefix(i0, y1)
        lom = (self._count_prefix(i1, y0, strict=True)
               - self._count_prefix(i0, y0, strict=True))
        return hi - lom

    def cf(self, u, v) -> jnp.ndarray:
        """CF_count(u, v), vectorized."""
        return mst_cf(self.xs, self.ys_levels, u, v)

    def cf_sum(self, u, v) -> jnp.ndarray:
        """CF_sum(u, v), vectorized (weighted trees only)."""
        return mst_cf_sum(self.xs, self.ys_levels, self.wcum_levels, u, v)

    def dommax(self, u, v) -> jnp.ndarray:
        """Dominance max of measures (-inf if the dominated set is empty)."""
        return mst_dommax(self.xs, self.ys_levels, self.wpmax_levels, u, v)

    def cf_np(self, u, v) -> np.ndarray:
        """CF_count on the host (numpy) — used during construction where
        region shapes vary per call and JAX would recompile every time."""
        xs = np.asarray(self.xs)
        ysl = np.asarray(self.ys_levels)
        n = len(xs)
        i = np.searchsorted(xs, np.asarray(u, np.float64), side="right")
        v = np.asarray(v, np.float64)
        total = np.zeros_like(i)
        pos = np.zeros_like(i)
        for l in range(ysl.shape[0] - 1, -1, -1):
            b = 1 << l
            take = pos + b <= i
            lo = np.zeros_like(i)
            hi = np.full_like(i, b)
            for _ in range(l + 1):
                active = lo < hi
                mid = (lo + hi) // 2
                idx = np.clip(pos + np.minimum(mid, b - 1), 0, n - 1)
                go_right = active & (ysl[l][idx] <= v)
                lo = np.where(go_right, mid + 1, lo)
                hi = np.where(active & ~go_right, mid, hi)
            total = total + np.where(take, lo, 0)
            pos = np.where(take, pos + b, pos)
        return total

    def _weighted_prefix_np(self, i: np.ndarray, v: np.ndarray,
                            mode: str) -> np.ndarray:
        """Host twin of ``mst_weighted_prefix`` (construction-time oracle)."""
        is_sum = mode == "sum"
        xs = np.asarray(self.xs)
        ysl = np.asarray(self.ys_levels)
        wacc = np.asarray(self.wcum_levels if is_sum else self.wpmax_levels)
        n = len(xs)
        ident = 0.0 if is_sum else -np.inf
        total = np.full(np.shape(i), ident)
        pos = np.zeros_like(i)
        for l in range(ysl.shape[0] - 1, -1, -1):
            b = 1 << l
            take = pos + b <= i
            lo = np.zeros_like(i)
            hi = np.full_like(i, b)
            for _ in range(l + 1):
                active = lo < hi
                mid = (lo + hi) // 2
                idx = np.clip(pos + np.minimum(mid, b - 1), 0, n - 1)
                go_right = active & (ysl[l][idx] <= v)
                lo = np.where(go_right, mid + 1, lo)
                hi = np.where(active & ~go_right, mid, hi)
            val = wacc[l][np.clip(pos + lo - 1, 0, n - 1)]
            val = np.where(take & (lo > 0), val, ident)
            total = total + val if is_sum else np.maximum(total, val)
            pos = np.where(take, pos + b, pos)
        return total

    def cf_sum_np(self, u, v) -> np.ndarray:
        i = np.searchsorted(np.asarray(self.xs), np.asarray(u, np.float64),
                            side="right")
        return self._weighted_prefix_np(i, np.asarray(v, np.float64), "sum")

    def dommax_np(self, u, v) -> np.ndarray:
        i = np.searchsorted(np.asarray(self.xs), np.asarray(u, np.float64),
                            side="right")
        return self._weighted_prefix_np(i, np.asarray(v, np.float64), "max")


# ---------------------------------------------------------------------------
# bivariate minimax fitting
# ---------------------------------------------------------------------------

def _vander2d(u, v, deg):
    cols = []
    for i in range(deg + 1):
        for j in range(deg + 1):
            cols.append((u**i) * (v**j))
    return np.stack(cols, axis=-1)


def _fit2d_lp(u, v, F, deg):
    """Minimax bivariate fit (Eq. 10 with P(u_i, v_i)); returns (coef, err)."""
    from scipy.optimize import linprog

    A = _vander2d(u, v, deg)
    n, k = A.shape
    if n <= k:
        coef, *_ = np.linalg.lstsq(A, F, rcond=None)
        return coef, float(np.max(np.abs(F - A @ coef))) if n else 0.0
    ones = np.ones((n, 1))
    A_ub = np.block([[-A, -ones], [A, -ones]])
    b_ub = np.concatenate([-F, F])
    c = np.zeros(k + 1)
    c[-1] = 1.0
    res = linprog(c, A_ub=A_ub, b_ub=b_ub,
                  bounds=[(None, None)] * k + [(0, None)], method="highs")
    if not res.success:
        coef, *_ = np.linalg.lstsq(A, F, rcond=None)
        return coef, float(np.max(np.abs(F - A @ coef)))
    coef = res.x[:k]
    return coef, float(np.max(np.abs(F - A @ coef)))


def _fit2d_lstsq(u, v, F, deg):
    A = _vander2d(u, v, deg)
    coef, *_ = np.linalg.lstsq(A, F, rcond=None)
    err = float(np.max(np.abs(F - A @ coef))) if len(F) else 0.0
    return coef, err


# ---------------------------------------------------------------------------
# quadtree index
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PolyFitIndex2D:
    deg: int
    delta: float
    # tree topology: children[node, q] = child id or -1 (leaf); quadrant q =
    # (v >= ymid)*2 + (u >= xmid)
    children: jnp.ndarray       # (N, 4) int32
    leaf_of: jnp.ndarray        # (N,) int32: leaf slot or -1 for internal
    bounds: jnp.ndarray         # (N, 4): x0, x1, y0, y1
    coeffs: jnp.ndarray         # (n_leaves, (deg+1)^2)
    leaf_nodes: jnp.ndarray     # (n_leaves,) int32: leaf slot -> node id
    max_depth: int
    root_bounds: Tuple[float, float, float, float]
    exact: Optional[MergeSortTree]
    n: int
    # -- measure-carrying extension (DESIGN.md §12) ----------------------
    agg: str = "count2d"
    leaf_err: Optional[np.ndarray] = None   # (n_leaves,) certified E(I)
    leaf_agg: Optional[jnp.ndarray] = None  # (n_leaves,) exact per-leaf agg
    measures_sorted: Optional[np.ndarray] = None  # host, x-sorted internal
    extremal_floor: Optional[float] = None  # frozen DMAX floor (max2d/min2d)

    @property
    def n_leaves(self) -> int:
        return int(self.coeffs.shape[0])

    @property
    def certified_delta(self) -> float:
        """The per-leaf certificate actually achieved: delta unless a leaf
        hit max_depth with residual error (then that error governs)."""
        if self.leaf_err is None:
            return float(self.delta)
        return float(max(self.delta, float(np.max(self.leaf_err))))

    def size_bytes(self) -> int:
        return int(self.children.nbytes + self.bounds.nbytes + self.coeffs.nbytes)

    def locate(self, u, v):
        """Leaf slot for each (u, v); fixed-depth branch-free descent."""
        return quadtree_locate(self.children, self.leaf_of, self.bounds,
                               self.max_depth, u, v)

    def eval_cf(self, u, v):
        """P_{leaf(u,v)}(u, v): approximate fitted function (vectorized)."""
        return quadtree_eval_cf(self.children, self.leaf_of, self.bounds,
                                self.coeffs, self.leaf_nodes, self.max_depth,
                                self.deg, u, v)


def quadtree_locate(children, leaf_of, bounds, max_depth: int, u, v):
    """Leaf slot for each (u, v); fixed-depth branch-free descent.

    Array-level (shared with the engine's XLA backend over IndexPlan2D):
    quadrant = (v >= ymid)*2 + (u >= xmid), so midpoint ties descend toward
    the higher-coordinate child — the rule the flat-leaf one-hot membership
    in kernels/leaf_eval2d.py reproduces exactly.
    """
    node = jnp.zeros(jnp.shape(u), jnp.int32)
    for _ in range(max_depth):
        b = bounds[node]
        xmid = 0.5 * (b[..., 0] + b[..., 1])
        ymid = 0.5 * (b[..., 2] + b[..., 3])
        q = (v >= ymid).astype(jnp.int32) * 2 + (u >= xmid).astype(jnp.int32)
        child = children[node, q]
        node = jnp.where(child >= 0, child, node)
    return leaf_of[node]


def _quadtree_locate_np(children, leaf_of, bounds, max_depth: int, u, v):
    """Host twin of ``quadtree_locate`` (same descent rule in numpy).

    Used at construction/assembly time where the point and topology shapes
    differ on every call and the eager JAX descent would pay a fresh
    per-shape compile for each of its primitives — a constant ~350 ms that
    dominated small (LSM-compaction-sized) builds.
    """
    node = np.zeros(np.shape(u), np.int32)
    for _ in range(max_depth):
        b = bounds[node]
        xmid = 0.5 * (b[..., 0] + b[..., 1])
        ymid = 0.5 * (b[..., 2] + b[..., 3])
        q = (v >= ymid).astype(np.int32) * 2 + (u >= xmid).astype(np.int32)
        child = children[node, q]
        node = np.where(child >= 0, child, node)
    return leaf_of[node]


def quadtree_eval_cf(children, leaf_of, bounds, coeffs, leaf_nodes,
                     max_depth: int, deg: int, u, v):
    """P_{leaf(u,v)}(u, v): the fitted surface over flat quadtree arrays."""
    leaf = quadtree_locate(children, leaf_of, bounds, max_depth, u, v)
    # leaf coeffs are stored for *scaled* coordinates of the leaf region
    node_ids = leaf_nodes[leaf]
    b = bounds[node_ids]
    us = _scale01(u, b[..., 0], b[..., 1])
    vs = _scale01(v, b[..., 2], b[..., 3])
    c = coeffs[leaf].reshape(leaf.shape + (deg + 1, deg + 1))
    # Horner in v inside Horner in u
    acc = jnp.zeros_like(us)
    for i in range(deg, -1, -1):
        inner = jnp.zeros_like(vs)
        for j in range(deg, -1, -1):
            inner = inner * vs + c[..., i, j]
        acc = acc * us + inner
    return acc


def _scale01(x, lo, hi):
    span = jnp.where(hi > lo, hi - lo, 1.0)
    return jnp.clip((2.0 * x - lo - hi) / span, -1.0, 1.0)


class _QuadtreeBuilder:
    """Shared quadtree fitting machinery.

    Used by ``build_index_2d`` for full construction and by
    ``selective_refit_2d`` to re-fit (and, when the certificate fails,
    re-split) only the dirty leaves against a fresh exact oracle.
    """

    def __init__(self, sx, sy, cf_exact, *, deg, delta, grid, max_depth,
                 max_fit_points, fast_accept):
        self.sx, self.sy = sx, sy          # x-sorted data coordinates
        self.cf_exact = cf_exact           # vectorized host oracle for F
        self.deg = deg
        self.delta = delta
        self.max_depth = max_depth
        self.max_fit_points = max_fit_points
        self.fast_accept = fast_accept
        gg = np.linspace(0.0, 1.0, grid)
        gu, gv = np.meshgrid(gg, gg)
        self.gu, self.gv = gu.ravel(), gv.ravel()
        self.rng = np.random.default_rng(0xF17)

    def region_points(self, x0, x1, y0, y1):
        i0 = np.searchsorted(self.sx, x0, side="left")
        i1 = np.searchsorted(self.sx, x1, side="right")
        xs = self.sx[i0:i1]
        ys = self.sy[i0:i1]
        m = (ys >= y0) & (ys <= y1)
        return xs[m], ys[m]

    def fit_region(self, x0, x1, y0, y1):
        rx, ry = self.region_points(x0, x1, y0, y1)
        # constraint set: data points in region + grid + corners
        cu = np.concatenate([rx, x0 + (x1 - x0) * self.gu])
        cv = np.concatenate([ry, y0 + (y1 - y0) * self.gv])
        F = np.asarray(self.cf_exact(cu, cv), np.float64)
        usc = np.clip((2 * cu - x0 - x1) / max(x1 - x0, 1e-300), -1, 1)
        vsc = np.clip((2 * cv - y0 - y1) / max(y1 - y0, 1e-300), -1, 1)
        deg, delta = self.deg, self.delta

        if self.fast_accept:
            coef, err = _fit2d_lstsq(usc, vsc, F, deg)
            if err <= delta:
                return coef, err
        # LP on a bounded constraint subsample, validated (and repaired with
        # the worst violators, Remez-style) against the full set
        m = len(F)
        if m <= self.max_fit_points:
            return _fit2d_lp(usc, vsc, F, deg)
        sub = self.rng.choice(m, self.max_fit_points, replace=False)
        for _ in range(3):
            coef, _ = _fit2d_lp(usc[sub], vsc[sub], F[sub], deg)
            resid = np.abs(F - _vander2d(usc, vsc, deg) @ coef)
            err = float(resid.max())
            if err <= delta:
                return coef, err
            worst = np.argsort(resid)[-256:]
            sub = np.unique(np.concatenate([sub, worst]))
        return coef, err

    def build(self, x0, x1, y0, y1, depth, children, bounds, depths,
              node_coef) -> int:
        """DFS-construct the (sub)tree over [x0,x1]x[y0,y1], appending to
        the host topology lists; ``node_coef[node] = (coef, err)`` marks
        leaves.  Returns the subtree's root node id."""
        node = len(children)
        children.append([-1, -1, -1, -1])
        bounds.append((x0, x1, y0, y1))
        depths.append(depth)
        coef, err = self.fit_region(x0, x1, y0, y1)
        if err <= self.delta or depth >= self.max_depth:
            node_coef[node] = (coef, err)
            return node
        xm, ym = 0.5 * (x0 + x1), 0.5 * (y0 + y1)
        args = (children, bounds, depths, node_coef)
        children[node][0] = self.build(x0, xm, y0, ym, depth + 1, *args)
        children[node][1] = self.build(xm, x1, y0, ym, depth + 1, *args)
        children[node][2] = self.build(x0, xm, ym, y1, depth + 1, *args)
        children[node][3] = self.build(xm, x1, ym, y1, depth + 1, *args)
        return node


def _internal_measures(px, measures, agg: str) -> np.ndarray:
    """Measures in internal space (MIN negated; COUNT is unit measures)."""
    if agg == "count2d":
        return np.ones_like(px)
    if measures is None:
        raise ValueError("measures required unless agg='count2d'")
    w = np.asarray(measures, np.float64)
    if w.shape != px.shape:
        raise ValueError(f"measures shape {w.shape} != points {px.shape}")
    return -w if agg == "min2d" else w


def _oracle_2d(tree: MergeSortTree, agg: str, floor: Optional[float]):
    """Host-side exact-F oracle the quadtree fits against."""
    if agg == "count2d":
        return lambda us, vs: tree.cf_np(us, vs)
    if agg == "sum2d":
        return lambda us, vs: tree.cf_sum_np(us, vs)
    return lambda us, vs: np.maximum(tree.dommax_np(us, vs), floor)


def _assemble_index_2d(children, bounds, depths, node_coef, *, agg, deg,
                       delta, max_depth, root_bounds, tree, keep_exact,
                       sx, sy, sw, floor) -> PolyFitIndex2D:
    """Assemble the device index from host topology + per-node leaf fits.

    Leaf slots are assigned in ascending node-id order (preorder for a
    fresh build; refit-split leaves append after the surviving ones).
    ``leaf_agg`` is recomputed exactly from the data through the descent's
    own membership rule, so it is a true partition aggregate.
    """
    children = np.asarray(children, np.int32)
    bounds_a = np.asarray(bounds, np.float64)
    nnodes = len(children)
    leaf_of = np.full(nnodes, -1, np.int32)
    leaf_nodes: List[int] = []
    coeffs: List[np.ndarray] = []
    leaf_err: List[float] = []
    for node in range(nnodes):
        got = node_coef.get(node)
        if got is None:
            continue
        leaf_of[node] = len(leaf_nodes)
        leaf_nodes.append(node)
        coeffs.append(got[0])
        leaf_err.append(got[1])
    leaf_nodes_a = np.asarray(leaf_nodes, np.int32)

    children_j = jnp.asarray(children)
    leaf_of_j = jnp.asarray(leaf_of)
    bounds_j = jnp.asarray(bounds_a)

    # exact per-leaf measure aggregate over the descent's own partition
    # (host descent: shapes vary per build, see _quadtree_locate_np)
    leaf = _quadtree_locate_np(children, leaf_of, bounds_a, max_depth,
                               sx, sy)
    nl = len(leaf_nodes)
    if agg in ("max2d", "min2d"):
        la = np.full(nl, -np.inf)
        np.maximum.at(la, leaf, sw)
    else:
        la = np.zeros(nl)
        np.add.at(la, leaf, sw)

    return PolyFitIndex2D(
        deg=deg, delta=float(delta),
        children=children_j, leaf_of=leaf_of_j, bounds=bounds_j,
        coeffs=jnp.asarray(np.stack(coeffs)),
        leaf_nodes=jnp.asarray(leaf_nodes_a),
        max_depth=max_depth, root_bounds=root_bounds,
        exact=tree if keep_exact else None, n=len(sx),
        agg=agg, leaf_err=np.asarray(leaf_err, np.float64),
        leaf_agg=jnp.asarray(la),
        measures_sorted=None if agg == "count2d" else sw,
        extremal_floor=floor,
    )


def build_index_2d(
    px: np.ndarray,
    py: np.ndarray,
    measures: Optional[np.ndarray] = None,
    agg: str = "count2d",
    deg: int = 3,
    delta: float = 100.0,
    grid: int = 8,
    max_depth: int = 12,
    max_fit_points: int = 2048,
    fast_accept: bool = True,
    keep_exact: bool = True,
) -> PolyFitIndex2D:
    """Quadtree segmentation of the aggregate's F (paper §6, Fig. 10).

    ``agg='count2d'`` fits CF_count (measures ignored); ``'sum2d'`` fits
    CF_sum over ``measures``; ``'max2d'``/``'min2d'`` fit the dominance-max
    staircase (MIN on negated measures end to end), floored at the dataset
    minimum so F is total — dominance answers are certified wherever the
    true dominance max reaches that frozen floor (every query that
    dominates at least one point of the build-time dataset).
    """
    if agg not in AGGS_2D:
        raise ValueError(f"agg must be one of {AGGS_2D}, got {agg!r}")
    px = np.asarray(px, np.float64)
    py = np.asarray(py, np.float64)
    w = _internal_measures(px, measures, agg)
    tree = MergeSortTree.build(px, py, ws=None if agg == "count2d" else w)

    # order data by x for fast in-region slicing
    xo = np.argsort(px, kind="stable")
    sx, sy, sw = px[xo], py[xo], w[xo]
    floor = float(sw.min()) if agg in ("max2d", "min2d") else None
    cf_exact = _oracle_2d(tree, agg, floor)

    x0r, x1r = float(px.min()), float(px.max())
    y0r, y1r = float(py.min()), float(py.max())

    builder = _QuadtreeBuilder(sx, sy, cf_exact, deg=deg, delta=delta,
                               grid=grid, max_depth=max_depth,
                               max_fit_points=max_fit_points,
                               fast_accept=fast_accept)
    children: List[List[int]] = []
    bounds: List[Tuple[float, float, float, float]] = []
    depths: List[int] = []
    node_coef: Dict[int, Tuple[np.ndarray, float]] = {}

    import sys
    old_limit = sys.getrecursionlimit()
    sys.setrecursionlimit(max(old_limit, 10000))
    try:
        builder.build(x0r, x1r, y0r, y1r, 0, children, bounds, depths,
                      node_coef)
    finally:
        sys.setrecursionlimit(old_limit)

    return _assemble_index_2d(
        children, bounds, depths, node_coef, agg=agg, deg=deg, delta=delta,
        max_depth=max_depth, root_bounds=(x0r, x1r, y0r, y1r), tree=tree,
        keep_exact=keep_exact, sx=sx, sy=sy, sw=sw, floor=floor)


def selective_refit_2d(
    index: PolyFitIndex2D,
    px: np.ndarray,
    py: np.ndarray,
    w: np.ndarray,
    changed_x: np.ndarray,
    changed_y: np.ndarray,
    changed_w: np.ndarray,
    *,
    grid: int = 8,
    max_fit_points: int = 2048,
    fast_accept: bool = True,
    keep_exact: bool = True,
) -> Tuple[PolyFitIndex2D, dict]:
    """Absorb a merged update batch by refitting *only* the dirty leaves.

    ``px, py, w`` is the merged dataset (w in *internal* space — negated
    for min2d, unit for count2d); ``changed_*`` lists every inserted or
    deleted point with its signed internal measure (+w insert, -w delete).

    A changed point (x0, y0) alters a CF-type F only on its dominance
    region {u >= x0, v >= y0}:

    * leaves wholly inside it see an exact *constant* shift (every point of
      the leaf dominates (x0, y0)), absorbed as a constant-coefficient bump
      that leaves the certified E(I) untouched;
    * leaves crossed by the region's boundary rays ({x0} x [y0, inf) and
      [x0, inf) x {y0}) see a non-constant change and are re-fitted against
      the fresh exact oracle — re-split on the spot while the certificate
      fails and depth remains;
    * every other leaf keeps its coefficient row bit for bit.

    For dominance-MAX trees the change is max-composition, not additive, so
    every leaf intersecting the dominance region is re-fitted (the rest are
    untouched).  The extremal floor is re-frozen at the merged dataset's
    minimum; when it moves (a below-floor insert, or a delete of the old
    minimum), every leaf whose raw dominance-max dips below the higher of
    the two floors is additionally re-fitted — those leaves' polynomials
    were certified against the stale clamp.  Points outside the frozen
    root rectangle cannot be covered by the existing topology: the
    function falls back to a full rebuild and reports it in the stats.

    Returns ``(new_index, stats)`` with stats keys ``n_leaves`` (before),
    ``refit``, ``split`` (leaves that re-split), ``shifted``, ``rebuild``,
    ``floor_refit`` (clean leaves re-fitted only because the floor moved).
    """
    agg, deg, delta = index.agg, index.deg, index.delta
    max_depth = index.max_depth
    px = np.asarray(px, np.float64)
    py = np.asarray(py, np.float64)
    w = np.asarray(w, np.float64)
    x0r, x1r, y0r, y1r = index.root_bounds
    out_of_root = (px.min() < x0r or px.max() > x1r
                   or py.min() < y0r or py.max() > y1r)
    if out_of_root:
        meas = None
        if agg != "count2d":
            meas = -w if agg == "min2d" else w
        idx = build_index_2d(px, py, measures=meas, agg=agg, deg=deg,
                             delta=delta, grid=grid, max_depth=max_depth,
                             max_fit_points=max_fit_points,
                             fast_accept=fast_accept, keep_exact=keep_exact)
        return idx, {"n_leaves": index.n_leaves, "refit": idx.n_leaves,
                     "split": 0, "shifted": 0, "rebuild": True}

    extremal = agg in ("max2d", "min2d")
    tree = MergeSortTree.build(px, py, ws=None if agg == "count2d" else w)
    xo = np.argsort(px, kind="stable")
    sx, sy, sw = px[xo], py[xo], w[xo]
    # re-freeze the floor at the *merged* dataset's minimum: reusing the
    # build-time floor after a below-floor insert (or a delete of the old
    # minimum) would leave refit leaves certified against a stale clamp
    floor = float(sw.min()) if extremal else None
    cf_exact = _oracle_2d(tree, agg, floor)

    builder = _QuadtreeBuilder(sx, sy, cf_exact, deg=deg, delta=delta,
                               grid=grid, max_depth=max_depth,
                               max_fit_points=max_fit_points,
                               fast_accept=fast_accept)

    # host topology (mutable for splits)
    children = [list(r) for r in np.asarray(index.children)]
    bounds = [tuple(float(x) for x in b) for b in np.asarray(index.bounds)]
    depths = list(_node_depths(np.asarray(index.children)))
    leaf_nodes = np.asarray(index.leaf_nodes)
    old_coeffs = np.asarray(index.coeffs)
    old_err = (np.asarray(index.leaf_err) if index.leaf_err is not None
               else np.full(len(leaf_nodes), float(delta)))
    lb = np.asarray(index.bounds)[leaf_nodes]   # (L, 4): x0, x1, y0, y1

    cx = np.asarray(changed_x, np.float64)[None, :]
    cy = np.asarray(changed_y, np.float64)[None, :]
    cw = np.asarray(changed_w, np.float64)
    # (L, C) classification against each changed point's dominance region
    untouched = (lb[:, 1:2] < cx) | (lb[:, 3:4] < cy)
    n_floor = 0
    if extremal:
        dirty = (~untouched).any(axis=1)
        old_floor = index.extremal_floor
        if old_floor is not None and floor != old_floor:
            # the frozen clamp moved: any leaf whose raw dominance-max
            # dips below the higher of the two floors was answering with
            # the old clamp value somewhere in its region (by bimonotone
            # F, the region minimum sits at the lower-left corner) —
            # force a targeted refit of exactly those leaves
            raw = tree.dommax_np(lb[:, 0], lb[:, 2])
            floor_dirty = raw < max(old_floor, floor)
            n_floor = int((floor_dirty & ~dirty).sum())
            dirty |= floor_dirty
        shift = np.zeros(len(lb))
    else:
        dominated = (lb[:, 0:1] >= cx) & (lb[:, 2:3] >= cy)
        dirty = (~(untouched | dominated)).any(axis=1)
        shift = np.where(dirty, 0.0,
                         np.where(dominated, cw[None, :], 0.0).sum(axis=1))

    node_coef: Dict[int, Tuple[np.ndarray, float]] = {}
    n_refit = n_split = n_shift = 0
    for s, node in enumerate(leaf_nodes):
        node = int(node)
        if not dirty[s]:
            c = old_coeffs[s]
            if shift[s] != 0.0:
                c = c.copy()
                c[0] += shift[s]   # the (u^0 v^0) term: an exact CF bump
                n_shift += 1
            node_coef[node] = (c, float(old_err[s]))
            continue
        x0, x1, y0, y1 = lb[s]
        coef, err = builder.fit_region(x0, x1, y0, y1)
        n_refit += 1
        if err <= delta or depths[node] >= max_depth:
            node_coef[node] = (coef, err)
            continue
        # certificate fails with depth to spare: re-split this leaf in place
        n_split += 1
        xm, ym = 0.5 * (x0 + x1), 0.5 * (y0 + y1)
        args = (children, bounds, depths, node_coef)
        d = depths[node] + 1
        children[node][0] = builder.build(x0, xm, y0, ym, d, *args)
        children[node][1] = builder.build(xm, x1, y0, ym, d, *args)
        children[node][2] = builder.build(x0, xm, ym, y1, d, *args)
        children[node][3] = builder.build(xm, x1, ym, y1, d, *args)

    new_index = _assemble_index_2d(
        children, bounds, depths, node_coef, agg=agg, deg=deg, delta=delta,
        max_depth=max_depth, root_bounds=index.root_bounds, tree=tree,
        keep_exact=keep_exact, sx=sx, sy=sy, sw=sw, floor=floor)
    stats = {"n_leaves": int(len(leaf_nodes)), "refit": n_refit,
             "split": n_split, "shifted": n_shift, "rebuild": False,
             "floor_refit": n_floor}
    return new_index, stats


def _node_depths(children: np.ndarray) -> np.ndarray:
    """Per-node depth from the topology (root = node 0 at depth 0)."""
    depth = np.zeros(len(children), np.int64)
    stack = [0]
    while stack:
        node = stack.pop()
        for c in children[node]:
            if c >= 0:
                depth[c] = depth[node] + 1
                stack.append(int(c))
    return depth


# ---------------------------------------------------------------------------
# core-level query helpers (the engine's fused executors mirror these)
# ---------------------------------------------------------------------------

def query_count_2d(index: PolyFitIndex2D, lx, ux, ly, uy,
                   eps_rel: float | None = None):
    """Approximate 2-key range COUNT (Eq. 19) with optional Q_rel refinement.

    Semantics follow Eq. 19 literally: A = CF(ux,uy) - CF(lx,uy) - CF(ux,ly)
    + CF(lx,ly), i.e. the half-open rectangle (lx, ux] x (ly, uy].
    """
    from .queries import QueryResult

    lx = jnp.asarray(lx, jnp.float64)
    ux = jnp.asarray(ux, jnp.float64)
    ly = jnp.asarray(ly, jnp.float64)
    uy = jnp.asarray(uy, jnp.float64)
    approx = (index.eval_cf(ux, uy) - index.eval_cf(lx, uy)
              - index.eval_cf(ux, ly) + index.eval_cf(lx, ly))
    if eps_rel is None:
        return QueryResult(approx, approx, jnp.zeros_like(approx, bool))
    four_d = 4.0 * index.delta
    ok = approx >= four_d * (1.0 + 1.0 / eps_rel)   # Lemma 6.4
    if index.exact is None:
        raise ValueError("Q_rel refinement requires keep_exact=True")
    truth = (index.exact.cf(ux, uy) - index.exact.cf(lx, uy)
             - index.exact.cf(ux, ly) + index.exact.cf(lx, ly)).astype(approx.dtype)
    ans = jnp.where(ok, approx, truth)
    return QueryResult(ans, approx, ~ok)


def query_sum_2d(index: PolyFitIndex2D, lx, ux, ly, uy,
                 eps_rel: float | None = None):
    """Approximate 2-key range SUM over (lx, ux] x (ly, uy]: the 4-corner
    inclusion-exclusion of CF_sum, |A - R| <= 4*delta (the Lemma 6.3
    argument applied to the weighted CF)."""
    from .queries import QueryResult

    assert index.agg == "sum2d", index.agg
    lx = jnp.asarray(lx, jnp.float64)
    ux = jnp.asarray(ux, jnp.float64)
    ly = jnp.asarray(ly, jnp.float64)
    uy = jnp.asarray(uy, jnp.float64)
    approx = (index.eval_cf(ux, uy) - index.eval_cf(lx, uy)
              - index.eval_cf(ux, ly) + index.eval_cf(lx, ly))
    if eps_rel is None:
        return QueryResult(approx, approx, jnp.zeros_like(approx, bool))
    ok = approx >= 4.0 * index.delta * (1.0 + 1.0 / eps_rel)   # Lemma 6.4
    if index.exact is None:
        raise ValueError("Q_rel refinement requires keep_exact=True")
    ex = index.exact
    truth = (ex.cf_sum(ux, uy) - ex.cf_sum(lx, uy)
             - ex.cf_sum(ux, ly) + ex.cf_sum(lx, ly)).astype(approx.dtype)
    ans = jnp.where(ok, approx, truth)
    return QueryResult(ans, approx, ~ok)


def query_dommax_2d(index: PolyFitIndex2D, u, v,
                    eps_rel: float | None = None):
    """Approximate dominance MAX/MIN: the extremal measure over
    {x <= u, y <= v}, |A - R| <= delta wherever the true dominance max
    reaches the frozen floor (every corner dominating a build-time point).
    MIN trees run on negated measures end to end."""
    from .queries import QueryResult

    assert index.agg in ("max2d", "min2d"), index.agg
    u = jnp.asarray(u, jnp.float64)
    v = jnp.asarray(v, jnp.float64)
    approx = index.eval_cf(u, v)
    neg = index.agg == "min2d"
    if eps_rel is None:
        out = -approx if neg else approx
        return QueryResult(out, out, jnp.zeros_like(out, bool))
    # Lemma 5.4 shape, in MAX space
    ok = approx >= index.delta * (1.0 + 1.0 / eps_rel)
    if index.exact is None:
        raise ValueError("Q_rel refinement requires keep_exact=True")
    truth = index.exact.dommax(u, v).astype(approx.dtype)
    ans = jnp.where(ok, approx, truth)
    if neg:
        ans, approx = -ans, -approx
    return QueryResult(ans, approx, ~ok)
