"""PolyFit with two keys (paper §6): quadtree-segmented bivariate surfaces.

Pipeline (COUNT, the aggregate the paper evaluates):

1. ``CF_count(u, v)`` = #points with x<=u and y<=v (Def. 6.2).  Exact values
   are produced offline by a vectorized divide-and-conquer dominance counter
   (numpy mergesort + searchsorted; O(n log^2 n), no Python-level per-point
   loops).
2. Quadtree segmentation (Fig. 10): a region whose best bivariate fit
   P(u,v) = sum a_ij u^i v^j (i,j <= deg) violates E(I) <= delta is split
   into 4 children at the midpoint.  Constraints are the data points inside
   the region plus a fixed evaluation grid and the region corners (all with
   exact CF values), which controls the fit away from data — query corners
   mix x and y from *different* records, so data points alone do not cover
   the evaluation locations (documented deviation, DESIGN.md §6).
3. Query (Eq. 19): 4-corner inclusion-exclusion, each corner evaluated in
   its own leaf region.  Leaves are found with a fixed-depth, branch-free
   quadtree descent (vectorized over query batches).
4. Guarantees: delta = eps_abs/4 (Lemma 6.3); the Q_rel test
   A >= 4*delta*(1+1/eps_rel) (Lemma 6.4) routes failing queries to the
   exact backend — a merge-sort tree (static BIT decomposition over x-rank
   with per-level sorted y arrays), which answers exact rectangle counts in
   O(log^2 n) fully vectorized gathers.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "dominance_rank",
    "count_dominated",
    "MergeSortTree",
    "PolyFitIndex2D",
    "build_index_2d",
    "query_count_2d",
    "mst_count_prefix",
    "mst_cf",
    "quadtree_locate",
    "quadtree_eval_cf",
]


# ---------------------------------------------------------------------------
# offline exact CF_count evaluation
# ---------------------------------------------------------------------------

def count_dominated(px: np.ndarray, py: np.ndarray,
                    qx: np.ndarray, qy: np.ndarray) -> np.ndarray:
    """For each query point (qx_j, qy_j): #data points with x<=qx and y<=qy."""
    tree = MergeSortTree.build(px, py)
    return np.asarray(tree.cf(jnp.asarray(np.asarray(qx, np.float64)),
                              jnp.asarray(np.asarray(qy, np.float64))))


def dominance_rank(px: np.ndarray, py: np.ndarray) -> np.ndarray:
    """CF_count at every data point (inclusive of the point itself)."""
    return count_dominated(px, py, px, py)


# ---------------------------------------------------------------------------
# exact online backend: merge sort tree (refinement + exact baseline)
# ---------------------------------------------------------------------------

def mst_count_prefix(xs: jnp.ndarray, ys_levels: jnp.ndarray, i: jnp.ndarray,
                     v: jnp.ndarray, strict: bool = False) -> jnp.ndarray:
    """#points among x-rank [0, i) with y <= v (or y < v if strict).

    Array-level (no MergeSortTree object) so the engine can jit it over
    ``IndexPlan2D`` refinement arrays; the static per-level binary searches
    unroll at trace time.
    """
    n = int(xs.shape[0])
    levels = int(ys_levels.shape[0])
    total = jnp.zeros_like(i)
    pos = jnp.zeros_like(i)
    for l in range(levels - 1, -1, -1):
        b = 1 << l
        take = pos + b <= i
        # binary search for v in ys_levels[l][pos : pos+b] (sorted run)
        lo = jnp.zeros_like(i)
        hi = jnp.full_like(i, b)
        for _ in range(l + 1):
            active = lo < hi
            mid = (lo + hi) // 2
            idx = jnp.clip(pos + jnp.minimum(mid, b - 1), 0, n - 1)
            y = ys_levels[l][idx]
            go_right = active & ((y < v) if strict else (y <= v))
            lo = jnp.where(go_right, mid + 1, lo)
            hi = jnp.where(active & ~go_right, mid, hi)
        total = total + jnp.where(take, lo, 0)
        pos = jnp.where(take, pos + b, pos)
    return total


def mst_cf(xs: jnp.ndarray, ys_levels: jnp.ndarray, u, v) -> jnp.ndarray:
    """CF_count(u, v) = #points with x <= u and y <= v, vectorized."""
    i = jnp.searchsorted(xs, u, side="right")
    return mst_count_prefix(xs, ys_levels, i, v)


@dataclasses.dataclass(frozen=True)
class MergeSortTree:
    """Static BIT-style decomposition for exact rectangle counts in JAX.

    xs        (n,)   x-sorted keys
    ys_levels (L, n) y values sorted within blocks of size 2^l at level l
    """

    xs: jnp.ndarray
    ys_levels: jnp.ndarray

    @staticmethod
    def build(px: np.ndarray, py: np.ndarray) -> "MergeSortTree":
        order = np.argsort(px, kind="stable")
        xs = np.asarray(px, np.float64)[order]
        ys = np.asarray(py, np.float64)[order]
        n = len(xs)
        levels = max(1, int(np.ceil(np.log2(max(n, 2)))) + 1)
        npad = 1 << (levels - 1)
        arrs = np.empty((levels, n), np.float64)
        arrs[0] = ys  # level 0: blocks of size 1 (already "sorted")
        padded = np.full(npad, np.inf)
        padded[:n] = ys
        for l in range(1, levels):
            b = 1 << l
            # vectorized per-block sort: reshape to (npad/b, b), sort rows
            padded = np.sort(padded.reshape(-1, b), axis=1).reshape(-1)
            arrs[l] = padded[:n]
        return MergeSortTree(jnp.asarray(xs), jnp.asarray(arrs))

    @property
    def n(self) -> int:
        return int(self.xs.shape[0])

    def _count_prefix(self, i: jnp.ndarray, v: jnp.ndarray,
                      strict: bool = False) -> jnp.ndarray:
        """#points among x-rank [0, i) with y <= v (or y < v if strict)."""
        return mst_count_prefix(self.xs, self.ys_levels, i, v, strict)

    def query(self, x0, x1, y0, y1) -> jnp.ndarray:
        """Exact #points in [x0,x1] x [y0,y1] (inclusive), vectorized."""
        i0 = jnp.searchsorted(self.xs, x0, side="left")
        i1 = jnp.searchsorted(self.xs, x1, side="right")
        hi = self._count_prefix(i1, y1) - self._count_prefix(i0, y1)
        lom = (self._count_prefix(i1, y0, strict=True)
               - self._count_prefix(i0, y0, strict=True))
        return hi - lom

    def cf(self, u, v) -> jnp.ndarray:
        """CF_count(u, v), vectorized."""
        return mst_cf(self.xs, self.ys_levels, u, v)

    def cf_np(self, u, v) -> np.ndarray:
        """CF_count on the host (numpy) — used during construction where
        region shapes vary per call and JAX would recompile every time."""
        xs = np.asarray(self.xs)
        ysl = np.asarray(self.ys_levels)
        n = len(xs)
        i = np.searchsorted(xs, np.asarray(u, np.float64), side="right")
        v = np.asarray(v, np.float64)
        total = np.zeros_like(i)
        pos = np.zeros_like(i)
        for l in range(ysl.shape[0] - 1, -1, -1):
            b = 1 << l
            take = pos + b <= i
            lo = np.zeros_like(i)
            hi = np.full_like(i, b)
            for _ in range(l + 1):
                active = lo < hi
                mid = (lo + hi) // 2
                idx = np.clip(pos + np.minimum(mid, b - 1), 0, n - 1)
                go_right = active & (ysl[l][idx] <= v)
                lo = np.where(go_right, mid + 1, lo)
                hi = np.where(active & ~go_right, mid, hi)
            total = total + np.where(take, lo, 0)
            pos = np.where(take, pos + b, pos)
        return total


# ---------------------------------------------------------------------------
# bivariate minimax fitting
# ---------------------------------------------------------------------------

def _vander2d(u, v, deg):
    cols = []
    for i in range(deg + 1):
        for j in range(deg + 1):
            cols.append((u**i) * (v**j))
    return np.stack(cols, axis=-1)


def _fit2d_lp(u, v, F, deg):
    """Minimax bivariate fit (Eq. 10 with P(u_i, v_i)); returns (coef, err)."""
    from scipy.optimize import linprog

    A = _vander2d(u, v, deg)
    n, k = A.shape
    if n <= k:
        coef, *_ = np.linalg.lstsq(A, F, rcond=None)
        return coef, float(np.max(np.abs(F - A @ coef))) if n else 0.0
    ones = np.ones((n, 1))
    A_ub = np.block([[-A, -ones], [A, -ones]])
    b_ub = np.concatenate([-F, F])
    c = np.zeros(k + 1)
    c[-1] = 1.0
    res = linprog(c, A_ub=A_ub, b_ub=b_ub,
                  bounds=[(None, None)] * k + [(0, None)], method="highs")
    if not res.success:
        coef, *_ = np.linalg.lstsq(A, F, rcond=None)
        return coef, float(np.max(np.abs(F - A @ coef)))
    coef = res.x[:k]
    return coef, float(np.max(np.abs(F - A @ coef)))


def _fit2d_lstsq(u, v, F, deg):
    A = _vander2d(u, v, deg)
    coef, *_ = np.linalg.lstsq(A, F, rcond=None)
    err = float(np.max(np.abs(F - A @ coef))) if len(F) else 0.0
    return coef, err


# ---------------------------------------------------------------------------
# quadtree index
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PolyFitIndex2D:
    deg: int
    delta: float
    # tree topology: children[node, q] = child id or -1 (leaf); quadrant q =
    # (v >= ymid)*2 + (u >= xmid)
    children: jnp.ndarray       # (N, 4) int32
    leaf_of: jnp.ndarray        # (N,) int32: leaf slot or -1 for internal
    bounds: jnp.ndarray         # (N, 4): x0, x1, y0, y1
    coeffs: jnp.ndarray         # (n_leaves, (deg+1)^2)
    leaf_nodes: jnp.ndarray     # (n_leaves,) int32: leaf slot -> node id
    max_depth: int
    root_bounds: Tuple[float, float, float, float]
    exact: Optional[MergeSortTree]
    n: int

    @property
    def n_leaves(self) -> int:
        return int(self.coeffs.shape[0])

    def size_bytes(self) -> int:
        return int(self.children.nbytes + self.bounds.nbytes + self.coeffs.nbytes)

    def locate(self, u, v):
        """Leaf slot for each (u, v); fixed-depth branch-free descent."""
        return quadtree_locate(self.children, self.leaf_of, self.bounds,
                               self.max_depth, u, v)

    def eval_cf(self, u, v):
        """P_{leaf(u,v)}(u, v): approximate CF_count (vectorized)."""
        return quadtree_eval_cf(self.children, self.leaf_of, self.bounds,
                                self.coeffs, self.leaf_nodes, self.max_depth,
                                self.deg, u, v)


def quadtree_locate(children, leaf_of, bounds, max_depth: int, u, v):
    """Leaf slot for each (u, v); fixed-depth branch-free descent.

    Array-level (shared with the engine's XLA backend over IndexPlan2D):
    quadrant = (v >= ymid)*2 + (u >= xmid), so midpoint ties descend toward
    the higher-coordinate child — the rule the flat-leaf one-hot membership
    in kernels/leaf_eval2d.py reproduces exactly.
    """
    node = jnp.zeros(jnp.shape(u), jnp.int32)
    for _ in range(max_depth):
        b = bounds[node]
        xmid = 0.5 * (b[..., 0] + b[..., 1])
        ymid = 0.5 * (b[..., 2] + b[..., 3])
        q = (v >= ymid).astype(jnp.int32) * 2 + (u >= xmid).astype(jnp.int32)
        child = children[node, q]
        node = jnp.where(child >= 0, child, node)
    return leaf_of[node]


def quadtree_eval_cf(children, leaf_of, bounds, coeffs, leaf_nodes,
                     max_depth: int, deg: int, u, v):
    """P_{leaf(u,v)}(u, v): approximate CF_count over flat quadtree arrays."""
    leaf = quadtree_locate(children, leaf_of, bounds, max_depth, u, v)
    # leaf coeffs are stored for *scaled* coordinates of the leaf region
    node_ids = leaf_nodes[leaf]
    b = bounds[node_ids]
    us = _scale01(u, b[..., 0], b[..., 1])
    vs = _scale01(v, b[..., 2], b[..., 3])
    c = coeffs[leaf].reshape(leaf.shape + (deg + 1, deg + 1))
    # Horner in v inside Horner in u
    acc = jnp.zeros_like(us)
    for i in range(deg, -1, -1):
        inner = jnp.zeros_like(vs)
        for j in range(deg, -1, -1):
            inner = inner * vs + c[..., i, j]
        acc = acc * us + inner
    return acc


def _scale01(x, lo, hi):
    span = jnp.where(hi > lo, hi - lo, 1.0)
    return jnp.clip((2.0 * x - lo - hi) / span, -1.0, 1.0)


def build_index_2d(
    px: np.ndarray,
    py: np.ndarray,
    deg: int = 3,
    delta: float = 100.0,
    grid: int = 8,
    max_depth: int = 12,
    max_fit_points: int = 2048,
    fast_accept: bool = True,
    keep_exact: bool = True,
) -> PolyFitIndex2D:
    """Quadtree segmentation of CF_count (paper §6, Fig. 10)."""
    px = np.asarray(px, np.float64)
    py = np.asarray(py, np.float64)
    n = len(px)
    tree = MergeSortTree.build(px, py)

    # order data by x for fast in-region slicing
    xo = np.argsort(px, kind="stable")
    sx, sy = px[xo], py[xo]

    def cf_exact(us, vs):
        return tree.cf_np(us, vs)

    x0r, x1r = float(px.min()), float(px.max())
    y0r, y1r = float(py.min()), float(py.max())

    children: List[List[int]] = []
    bounds: List[Tuple[float, float, float, float]] = []
    leaf_of: List[int] = []
    leaf_nodes: List[int] = []
    leaf_coeffs: List[np.ndarray] = []

    gg = np.linspace(0.0, 1.0, grid)
    gu, gv = np.meshgrid(gg, gg)
    gu, gv = gu.ravel(), gv.ravel()

    def region_points(x0, x1, y0, y1):
        i0 = np.searchsorted(sx, x0, side="left")
        i1 = np.searchsorted(sx, x1, side="right")
        xs = sx[i0:i1]
        ys = sy[i0:i1]
        m = (ys >= y0) & (ys <= y1)
        return xs[m], ys[m]

    fit_rng = np.random.default_rng(0xF17)

    def fit_region(x0, x1, y0, y1, depth):
        rx, ry = region_points(x0, x1, y0, y1)
        # constraint set: data points in region + grid + corners
        cu = np.concatenate([rx, x0 + (x1 - x0) * gu])
        cv = np.concatenate([ry, y0 + (y1 - y0) * gv])
        F = cf_exact(cu, cv).astype(np.float64)
        usc = np.clip((2 * cu - x0 - x1) / max(x1 - x0, 1e-300), -1, 1)
        vsc = np.clip((2 * cv - y0 - y1) / max(y1 - y0, 1e-300), -1, 1)

        def full_err(coef):
            return float(np.max(np.abs(F - _vander2d(usc, vsc, deg) @ coef)))

        if fast_accept:
            coef, err = _fit2d_lstsq(usc, vsc, F, deg)
            if err <= delta:
                return coef, err
        # LP on a bounded constraint subsample, validated (and repaired with
        # the worst violators, Remez-style) against the full set
        m = len(F)
        if m <= max_fit_points:
            return _fit2d_lp(usc, vsc, F, deg)
        sub = fit_rng.choice(m, max_fit_points, replace=False)
        for _ in range(3):
            coef, _ = _fit2d_lp(usc[sub], vsc[sub], F[sub], deg)
            resid = np.abs(F - _vander2d(usc, vsc, deg) @ coef)
            err = float(resid.max())
            if err <= delta:
                return coef, err
            worst = np.argsort(resid)[-256:]
            sub = np.unique(np.concatenate([sub, worst]))
        return coef, err

    def build(x0, x1, y0, y1, depth) -> int:
        node = len(children)
        children.append([-1, -1, -1, -1])
        bounds.append((x0, x1, y0, y1))
        leaf_of.append(-1)
        coef, err = fit_region(x0, x1, y0, y1, depth)
        if err <= delta or depth >= max_depth:
            leaf_of[node] = len(leaf_coeffs)
            leaf_nodes.append(node)
            leaf_coeffs.append(coef)
            return node
        xm, ym = 0.5 * (x0 + x1), 0.5 * (y0 + y1)
        children[node][0] = build(x0, xm, y0, ym, depth + 1)
        children[node][1] = build(xm, x1, y0, ym, depth + 1)
        children[node][2] = build(x0, xm, ym, y1, depth + 1)
        children[node][3] = build(xm, x1, ym, y1, depth + 1)
        return node

    import sys
    old_limit = sys.getrecursionlimit()
    sys.setrecursionlimit(max(old_limit, 10000))
    try:
        build(x0r, x1r, y0r, y1r, 0)
    finally:
        sys.setrecursionlimit(old_limit)

    return PolyFitIndex2D(
        deg=deg, delta=float(delta),
        children=jnp.asarray(np.asarray(children, np.int32)),
        leaf_of=jnp.asarray(np.asarray(leaf_of, np.int32)),
        bounds=jnp.asarray(np.asarray(bounds, np.float64)),
        coeffs=jnp.asarray(np.stack(leaf_coeffs)),
        leaf_nodes=jnp.asarray(np.asarray(leaf_nodes, np.int32)),
        max_depth=max_depth,
        root_bounds=(x0r, x1r, y0r, y1r),
        exact=tree if keep_exact else None,
        n=n,
    )


def query_count_2d(index: PolyFitIndex2D, lx, ux, ly, uy,
                   eps_rel: float | None = None):
    """Approximate 2-key range COUNT (Eq. 19) with optional Q_rel refinement.

    Semantics follow Eq. 19 literally: A = CF(ux,uy) - CF(lx,uy) - CF(ux,ly)
    + CF(lx,ly), i.e. the half-open rectangle (lx, ux] x (ly, uy].
    """
    from .queries import QueryResult

    lx = jnp.asarray(lx, jnp.float64)
    ux = jnp.asarray(ux, jnp.float64)
    ly = jnp.asarray(ly, jnp.float64)
    uy = jnp.asarray(uy, jnp.float64)
    approx = (index.eval_cf(ux, uy) - index.eval_cf(lx, uy)
              - index.eval_cf(ux, ly) + index.eval_cf(lx, ly))
    if eps_rel is None:
        return QueryResult(approx, approx, jnp.zeros_like(approx, bool))
    four_d = 4.0 * index.delta
    ok = approx >= four_d * (1.0 + 1.0 / eps_rel)   # Lemma 6.4
    if index.exact is None:
        raise ValueError("Q_rel refinement requires keep_exact=True")
    truth = (index.exact.cf(ux, uy) - index.exact.cf(lx, uy)
             - index.exact.cf(ux, ly) + index.exact.cf(lx, ly)).astype(approx.dtype)
    ans = jnp.where(ok, approx, truth)
    return QueryResult(ans, approx, ~ok)
