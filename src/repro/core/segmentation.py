"""Segmentation of the exact function F(k) into minimax-fitted intervals.

* ``greedy_segmentation`` — the paper's GS (Alg. 1) accelerated with
  exponential (doubling + binary) search, exactly as §4.2.1 describes.  GS is
  optimal (Thm 4.3) because E(I) is monotone under interval growth
  (Lemma 4.2); we exploit the same monotonicity for the doubling search.
* ``dp_segmentation``     — the O(n² · fit) dynamic program the paper cites
  [42]; used in tests to verify GS optimality on small inputs.
* ``parallel_segmentation`` — beyond-paper: computes the maximal feasible
  segment length for *every* left endpoint with batched Lawson fits on the
  device (log-many rounds of doubling over all endpoints at once), then walks
  the O(h) greedy jumps on the host.  Produces the identical segmentation to
  GS when verified with the LP fitter at the chosen boundaries.

All fitters receive (keys, values) = (k_i, F(k_i)) for the keys inside the
candidate interval and return a PolyModel whose ``err`` field certifies
max_i |F(k_i) - P(k_i)| — the quantity the δ-guarantees are built on.
"""
from __future__ import annotations

from typing import Callable, List

import jax.numpy as jnp
import numpy as np

from .fitting import (PolyModel, fit_lstsq, fit_minimax_lp, fit_minimax_lawson,
                      lawson_batched)

__all__ = [
    "greedy_segmentation",
    "dp_segmentation",
    "parallel_segmentation",
    "FastAcceptFitter",
]

Fitter = Callable[[np.ndarray, np.ndarray, int], PolyModel]


def _feasible(fitter: Fitter, keys, values, deg, delta):
    m = fitter(keys, values, deg)
    return m, m.err <= delta


class FastAcceptFitter:
    """Least-squares fast-accept wrapper (construction speedup, exact-safe).

    The L2 fit's max residual upper-bounds E(I): if it already satisfies
    ``delta`` the LP is skipped entirely (feasible probes — the common case
    during doubling — cost one lstsq).  Rejections fall through to the exact
    fitter, so feasibility *decisions* match pure-LP GS wherever the lstsq
    bound is loose enough to matter; committed certificates are always the
    achieved max-residual of the stored fit.  ``post`` optionally augments a
    fit's certificate (e.g. continuum_error for MAX indexes).
    """

    def __init__(self, exact: Fitter = fit_minimax_lp, delta: float | None = None,
                 post=None, screen: bool = True):
        self.exact = exact
        self.delta = delta
        self.post = post
        self.screen = screen

    def _finish(self, m, keys, values):
        return self.post(m, keys, values) if self.post else m

    def __call__(self, keys, values, deg) -> PolyModel:
        if self.screen and self.delta is not None:
            m = self._finish(fit_lstsq(keys, values, deg), keys, values)
            if m.err <= self.delta:
                return m
        return self._finish(self.exact(keys, values, deg), keys, values)


def greedy_segmentation(
    keys: np.ndarray,
    values: np.ndarray,
    deg: int,
    delta: float,
    fitter: Fitter = fit_minimax_lp,
    use_exponential_search: bool = True,
) -> List[PolyModel]:
    """Paper Alg. 1 (GS) + exponential-search acceleration (§4.2.1).

    Scans left→right; for each left endpoint finds the maximal u with
    E([k_l, k_u]) <= delta.  Monotonicity of E (Lemma 4.2) makes doubling +
    binary search sound: if a prefix is infeasible, every extension is too.
    """
    keys = np.asarray(keys, np.float64)
    values = np.asarray(values, np.float64)
    n = len(keys)
    if n == 0:
        return []
    segs: List[PolyModel] = []
    l = 0
    while l < n:
        if l == n - 1:
            m = fitter(keys[l : l + 1], values[l : l + 1], deg)
            segs.append(m)
            break
        if not use_exponential_search:
            # literal Alg. 1: extend one key at a time
            prev = fitter(keys[l : l + 1], values[l : l + 1], deg)
            u = l + 1
            while u < n:
                m, ok = _feasible(fitter, keys[l : u + 1], values[l : u + 1], deg, delta)
                if not ok:
                    break
                prev = m
                u += 1
            segs.append(prev)
            l = u
            continue
        # exponential search: find smallest infeasible length by doubling
        step = max(deg + 2, 2)
        lo_len = 1                      # last known-feasible length
        best = None
        while True:
            length = min(lo_len + step, n - l)
            m, ok = _feasible(fitter, keys[l : l + length], values[l : l + length], deg, delta)
            if ok:
                best, lo_len = m, length
                if length == n - l:
                    break
                step *= 2
            else:
                break
        if best is None:
            # even the minimal extension fails -> single-key interpolation
            best = fitter(keys[l : l + 1], values[l : l + 1], deg)
            lo_len = 1
        if lo_len < n - l:
            # binary search in (lo_len, lo_len + step]
            hi_len = min(lo_len + step, n - l)
            while lo_len + 1 < hi_len:
                mid = (lo_len + hi_len) // 2
                m, ok = _feasible(fitter, keys[l : l + mid], values[l : l + mid], deg, delta)
                if ok:
                    best, lo_len = m, mid
                else:
                    hi_len = mid
        segs.append(best)
        l += lo_len
    return segs


def dp_segmentation(
    keys: np.ndarray,
    values: np.ndarray,
    deg: int,
    delta: float,
    fitter: Fitter = fit_minimax_lp,
) -> List[PolyModel]:
    """O(n^2) optimal DP (reference implementation for tests).

    dp[i] = min #segments covering keys[:i]; transition over all j<i with
    feasible fit on keys[j:i].  Uses Lemma 4.2 to prune: for fixed i, as j
    decreases the interval grows, so once infeasible we can stop.
    """
    keys = np.asarray(keys, np.float64)
    values = np.asarray(values, np.float64)
    n = len(keys)
    INF = 10**9
    dp = [0] + [INF] * n
    choice = [None] * (n + 1)
    for i in range(1, n + 1):
        for j in range(i - 1, -1, -1):
            m, ok = _feasible(fitter, keys[j:i], values[j:i], deg, delta)
            if not ok:
                break  # Lemma 4.2: larger intervals only get worse
            if dp[j] + 1 < dp[i]:
                dp[i] = dp[j] + 1
                choice[i] = (j, m)
    segs: List[PolyModel] = []
    i = n
    while i > 0:
        j, m = choice[i]
        segs.append(m)
        i = j
    segs.reverse()
    return segs


class _ChunkState:
    """Exponential-search state machine for one chunk's greedy cursor."""

    __slots__ = ("base", "end", "cursor", "phase", "lo_len", "step", "hi_len", "done")

    def __init__(self, base: int, end: int):
        self.base = base        # chunk's first key (global index)
        self.end = end          # chunk's one-past-last key
        self.cursor = base      # current segment's left endpoint
        self.phase = "grow"     # 'grow' | 'binary'
        self.lo_len = 1         # last known-feasible length
        self.step = 0
        self.hi_len = 0
        self.done = base >= end


def parallel_segmentation(
    keys: np.ndarray,
    values: np.ndarray,
    deg: int,
    delta: float,
    chunks: int = 64,
    iters: int = 40,
    verify_lp: bool = True,
    fitter: Fitter = fit_minimax_lp,
) -> List[PolyModel]:
    """Beyond-paper TPU-parallel construction: lockstep-chunked GS.

    The key domain is split into ``chunks`` equal pieces whose greedy scans
    run *in lockstep*: each round gathers every active chunk's next
    exponential/binary-search probe interval and evaluates all of them in a
    single ``lawson_batched`` device call (padded to the round's max length).
    Probe count per chunk is O(h_c log l_max), so wall-clock shrinks by ~C
    versus sequential GS while segment count grows by at most C-1 (forced
    breaks at chunk boundaries).  Final segments are re-certified with the
    exact LP (``verify_lp``) so stored certificates equal the paper's E(I).
    """
    keys64 = np.asarray(keys, np.float64)
    values64 = np.asarray(values, np.float64)
    n = len(keys64)
    if n == 0:
        return []
    # each forced chunk boundary can add one segment vs sequential GS: cap
    # chunk count so the overhead stays small relative to the data size
    chunks = max(1, min(chunks, n // 4096, n))
    bounds = np.linspace(0, n, chunks + 1).astype(np.int64)
    states = [_ChunkState(int(bounds[i]), int(bounds[i + 1])) for i in range(chunks)]
    # per-chunk list of (start, length) accepted segments
    accepted: List[List[tuple]] = [[] for _ in range(chunks)]

    def _probe_interval(st: _ChunkState):
        """Next probe (start, length) for a chunk, or None if settled."""
        avail = st.end - st.cursor
        if st.phase == "grow":
            length = min(st.lo_len + st.step, avail)
            return (st.cursor, length)
        else:  # binary
            if st.lo_len + 1 >= st.hi_len:
                return None
            mid = (st.lo_len + st.hi_len) // 2
            return (st.cursor, mid)

    def _advance(st: _ChunkState, length: int, feasible: bool):
        avail = st.end - st.cursor
        if st.phase == "grow":
            if feasible:
                st.lo_len = length
                if length == avail:
                    _commit(st)
                    return
                st.step *= 2
            else:
                st.hi_len = length
                st.phase = "binary"
                if st.lo_len + 1 >= st.hi_len:
                    _commit(st)
        else:
            if feasible:
                st.lo_len = length
            else:
                st.hi_len = length
            if st.lo_len + 1 >= st.hi_len:
                _commit(st)

    def _commit(st: _ChunkState):
        accepted[states.index(st)].append((st.cursor, st.lo_len))
        st.cursor += st.lo_len
        if st.cursor >= st.end:
            st.done = True
        else:
            st.phase = "grow"
            st.lo_len = 1
            st.step = max(deg + 2, 2)
            st.hi_len = 0

    for st in states:
        if not st.done:
            st.step = max(deg + 2, 2)

    while any(not st.done for st in states):
        probes = []
        probe_states = []
        for st in states:
            if st.done:
                continue
            p = _probe_interval(st)
            while p is None:  # binary settled without a probe
                _commit(st)
                if st.done:
                    break
                p = _probe_interval(st)
            if st.done or p is None:
                continue
            probes.append(p)
            probe_states.append(st)
        if not probes:
            break
        # pad shapes to powers of two so lawson_batched compiles O(log) times
        Lmax = 1 << int(np.ceil(np.log2(max(p[1] for p in probes))))
        B = 1 << int(np.ceil(np.log2(len(probes))))
        u = np.zeros((B, Lmax))
        F = np.zeros((B, Lmax))
        valid = np.zeros((B, Lmax))
        for b, (s, L) in enumerate(probes):
            kw = keys64[s : s + L]
            vw = values64[s : s + L]
            lo, hi = kw[0], kw[-1]
            span = hi - lo if hi > lo else 1.0
            u[b, :L] = (2.0 * kw - lo - hi) / span
            F[b, :L] = vw
            valid[b, :L] = 1.0
        _, errs = lawson_batched(jnp.asarray(u), jnp.asarray(F),
                                 jnp.asarray(valid), deg, iters)
        errs = np.asarray(errs)
        for b, st in enumerate(probe_states):
            _advance(st, probes[b][1], bool(errs[b] <= delta))

    # certify + emit (LP restores the paper's exact E(I); shrink on the rare
    # Lawson under-certification)
    segs: List[PolyModel] = []
    refit = fitter if verify_lp else (
        lambda k, v, d: fit_minimax_lawson(k, v, d, iters=iters))
    for clist in accepted:
        for (s, L) in clist:
            while L >= 1:
                m = refit(keys64[s : s + L], values64[s : s + L], deg)
                if m.err <= delta or L == 1:
                    segs.append(m)
                    break
                L = max(1, L - max(1, L // 8))
    # ensure coverage: accepted segments tile each chunk by construction;
    # shrinking above can leave a tail -> re-run greedy on any gap
    segs.sort(key=lambda m: m.lo)
    out: List[PolyModel] = []
    covered_to = 0
    for m in segs:
        i = int(np.searchsorted(keys64, m.lo, side="left"))
        if i > covered_to:
            out.extend(greedy_segmentation(keys64[covered_to:i], values64[covered_to:i],
                                           deg, delta, fitter=fitter))
        out.append(m)
        covered_to = max(covered_to, int(np.searchsorted(keys64, m.hi, side="right")))
    if covered_to < n:
        out.extend(greedy_segmentation(keys64[covered_to:], values64[covered_to:],
                                       deg, delta, fitter=fitter))
    return out
