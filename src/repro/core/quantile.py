"""Certified quantile inversion of the fitted cumulative function.

PolyFit's CF index stores, per segment I, a polynomial P_I whose minimax
residual ``err(I) = max_{k in I} |P_I(k) - F(k)|`` is certified **at the
data keys** (the paper's Eq. 10 constraint set; DESIGN.md §16).  F is
monotone non-decreasing (COUNT, or SUM of non-negative measures), so a rank
target t inverts to a key interval using only key-certified facts — the
fitted polynomial is *not* assumed monotone, and nothing is asserted about
P between keys:

* **upper end** — segment endpoints are data keys, so the first segment s
  whose endpoint value satisfies ``P_s(+1) >= t + slack + delta`` has
  ``F(seg_hi[s]) >= t + slack``: every rank-t crossing sits at or below
  ``seg_hi[s]``.  Within s, the suffix ``[u*, 1]`` on which P stays >=
  ``t + slack + err(s)`` (u* = the *largest* root of P = target, a set on
  which no monotonicity is needed) certifies every key it contains, so the
  upper end tightens to the first data key >= u* — a snap through the
  plan's exact key array when present, the segment endpoint otherwise.
* **lower end** — segments 0..s-1 with running-max endpoint value <=
  ``t - slack - delta`` are cleared wholesale (their keys' F values are
  certified below the target); within segment s the prefix ``[-1, u*)`` on
  which P stays <= ``t - slack - err(s)`` (u* = the *smallest* root) clears
  every key it contains.  Any real in the cleared region lower-bounds the
  crossing — no key snap required.

The interval [lower, upper] therefore brackets the exact quantile with the
rank error pushed through the inverse, the same certificate machinery as
Lemmas 5.1-5.4.  Location uses the running max of the per-segment endpoint
values P_i(+1) (``boundary_array``): a cummax is sorted, so the branch-free
``bsearch_count`` applies, and its first crossing of a threshold coincides
with the raw array's.  Root finding inside the located segment is closed
form for deg <= 3 (the degrees the paper recommends) via the shared solvers
in ``core.queries``, and a fixed-iteration safeguarded Newton/bisection
otherwise.

Everything here is plain ``jnp`` on values — it runs inside jitted XLA
paths, inside Pallas kernel bodies (``kernels/quantile_invert.py``), and in
host-side oracles, identically.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .poly import horner
from .queries import _roots_cubic, _roots_linear, _roots_quadratic

__all__ = [
    "boundary_array", "certified_quantile", "certified_quantile_shifted",
    "invert_cf", "rank_slack",
]

#: rank-unit slack for COUNT tables: absorbs every numpy.quantile
#: interpolation convention (linear/lower/higher all live within one rank
#: unit of q*N; the extra unit covers the inclusive-CF off-by-one).
COUNT_RANK_SLACK = 2.0

_NEWTON_ITERS = 40


def rank_slack(agg: str, total) -> jnp.ndarray:
    """Soundness margin added to rank targets before certification.

    COUNT ranks are integers — 2 rank units dominate every interpolation
    convention.  SUM ranks are continuous — a relative margin well above
    the float64 validity tolerance (1e-9 per lane) suffices.
    """
    if agg == "count":
        return jnp.asarray(COUNT_RANK_SLACK)
    return 1e-7 * (jnp.abs(jnp.asarray(total)) + 1.0)


def boundary_array(coeffs: jnp.ndarray) -> jnp.ndarray:
    """``B[i] = max_{j<=i} P_j(+1)`` — running max of segment endpoint CF
    values.  Sorted by construction; zero-coefficient padding rows evaluate
    to 0 and sit at the tail, where the running max has already saturated.
    """
    return jax.lax.cummax(horner(coeffs, jnp.ones(coeffs.shape[0],
                                                  coeffs.dtype)))


def _newton_root(c: jnp.ndarray, t: jnp.ndarray) -> jnp.ndarray:
    """One root of P(u) = t on [-1, 1], safeguarded Newton + bisection.

    Fixed iteration count (branch-free, kernel-safe); when no sign change
    exists on the interval the result is rejected downstream by the root
    validity mask.
    """
    # derivative weights via scalar multiplies (no materialized constant
    # array — Pallas kernel bodies cannot capture traced-time constants)
    dc = jnp.stack([c[..., j] * float(j) for j in range(1, c.shape[-1])],
                   axis=-1)
    a = jnp.full_like(t, -1.0)
    b = jnp.ones_like(t)
    fa = horner(c, a) - t
    u = 0.5 * (a + b)
    for _ in range(_NEWTON_ITERS):
        fu = horner(c, u) - t
        same = (fu > 0) == (fa > 0)
        a = jnp.where(same, u, a)
        fa = jnp.where(same, fu, fa)
        b = jnp.where(same, b, u)
        du = horner(dc, u)
        step = u - fu / jnp.where(du == 0, 1.0, du)
        lo = jnp.minimum(a, b)
        hi = jnp.maximum(a, b)
        bad = (du == 0) | ~jnp.isfinite(step) | (step <= lo) | (step >= hi)
        u = jnp.where(bad, 0.5 * (a + b), step)
    return u


def _unit_roots(c: jnp.ndarray, t: jnp.ndarray):
    """Real roots of P(u) = t, nan-padded; closed form through deg 3."""
    deg = c.shape[-1] - 1
    if deg <= 1:
        return (_roots_linear(c[..., 0] - t, c[..., 1]),)
    if deg == 2:
        return _roots_quadratic(c[..., 0] - t, c[..., 1], c[..., 2])
    if deg == 3:
        return _roots_cubic(c[..., 0] - t, c[..., 1], c[..., 2], c[..., 3])
    return (_newton_root(c, t),)


def _extreme_root(c: jnp.ndarray, T: jnp.ndarray, which: str):
    """(root, found): largest/smallest real root of P(u) = T inside [-1, 1].

    No root inside the interval means P - T holds one sign throughout —
    the caller resolves which via an endpoint evaluation.
    """
    sign = 1.0 if which == "max" else -1.0
    best = jnp.full_like(T, -jnp.inf)
    for r in _unit_roots(c, T):
        valid = jnp.isfinite(r) & (jnp.abs(r) <= 1.0 + 1e-9)
        best = jnp.where(valid, jnp.maximum(best, sign * jnp.clip(r, -1.0, 1.0)),
                         best)
    found = jnp.isfinite(best)
    return jnp.where(found, sign * best, 0.0), found


def _unscale(u: jnp.ndarray, lo: jnp.ndarray, hi: jnp.ndarray) -> jnp.ndarray:
    """Inverse of ``core.poly.scale_unit`` (degenerate span -> lo)."""
    return jnp.where(hi > lo, 0.5 * (u * (hi - lo) + lo + hi), lo)


def _count(keys: jnp.ndarray, q: jnp.ndarray, side: str,
           scan: bool) -> jnp.ndarray:
    """searchsorted(keys, q, side): O(log n) branch-free binary search, or
    the O(Q*n) one-hot comparison sum (``pallas_scan`` A/B twin — the
    summed predicate is exactly the bsearch predicate, so indices match
    bit-for-bit)."""
    if scan:
        cmp = (keys[None, :] <= q[:, None]) if side == "right" else (
            keys[None, :] < q[:, None])
        return jnp.sum(cmp, axis=1, dtype=jnp.int32)
    from ..kernels.locate import bsearch_count  # lazy: kernels import core
    return bsearch_count(keys, q, side=side)


def invert_cf(t: jnp.ndarray, side: str, *, B: jnp.ndarray,
              seg_lo: jnp.ndarray, seg_hi: jnp.ndarray, coeffs: jnp.ndarray,
              seg_err: jnp.ndarray, h: int, delta: float, slack,
              ref_keys: Optional[jnp.ndarray] = None, n: int = 0,
              raw: bool = False, scan: bool = False):
    """Certified one-sided inverse of the fitted CF at rank targets ``t``.

    Locates with the *global* delta (sound: the located segment's endpoint
    key provably clears the global target, hence also the tighter
    per-segment one), then resolves the crossing inside the segment against
    the gathered ``seg_err``.  Returns (x, ok).  side='hi' lanes with
    ok=False have targets above the fitted range and must fall back to the
    domain top.  side='lo' is unconditionally sound against the *static*
    data (its worst case is already the domain floor); there, ok reports
    whether the stronger contract "every data key <= x has F(key) <= t"
    holds — the fact the dynamic executor needs to push the exact buffer
    correction through the inverse (ok=False only on the vacuous
    domain-floor fallback, which dynamic lanes must replace with a
    below-all-live-keys floor).
    """
    pad = slack + delta
    # complete real root sets exist closed-form through deg 3 (the degrees
    # the paper recommends); without them the prefix/suffix sign conditions
    # cannot be certified, so deg > 3 keeps segment-endpoint granularity.
    tight = coeffs.shape[-1] - 1 <= 3
    if side == "hi":
        s = jnp.minimum(_count(B, t + pad, "left", scan), h - 1)
    else:
        s = jnp.clip(_count(B, t - pad, "right", scan), 0, h - 1)
    lo = jnp.take(seg_lo, s)
    hi = jnp.take(seg_hi, s)
    c = jnp.take(coeffs, s, axis=0)
    e = jnp.take(seg_err, s)

    if side == "hi":
        # suffix [u*, 1] on which P >= T: every data key it holds (seg_hi[s]
        # is one) has F >= t + slack, so the first key >= u* caps the rank-t
        # crossing.  u* = largest root, or -1 when P >= T on all of [-1, 1]
        # (no root in the interval means P - T holds the sign it has at +1).
        T = t + (slack + e)
        ok = t + pad <= B[h - 1]
        if raw:                 # uncertified point estimate, no snap
            root, found = _extreme_root(c, T, "max")
            return _unscale(jnp.where(found, root, -1.0), lo, hi), ok
        if tight:
            root, found = _extreme_root(c, T, "max")
            x = _unscale(jnp.where(found, root, -1.0), lo, hi)
        else:
            x = hi
        if ref_keys is not None:
            k = jnp.minimum(_count(ref_keys, x, "left", scan), n - 1)
            x = jnp.take(ref_keys, k)
        else:
            x = hi   # segment endpoint key: coarser, still certified
        return x, ok

    # side == 'lo': prefix [-1, u*) on which P <= T clears every key it
    # holds; segments below s were cleared wholesale by the locate.  When
    # the segment-start value already exceeds T nothing inside s clears,
    # and the certified floor is the previous segment's endpoint key.
    prev = jnp.take(seg_hi, jnp.maximum(s - 1, 0))
    below = jnp.where(s > 0, prev, seg_lo[0])
    if not tight:
        return below, s > 0
    T = t - (slack + e)
    tiny = 1e-9 * (jnp.abs(T) + 1.0)
    root, found = _extreme_root(c, T, "min")
    start_ok = horner(c, jnp.full_like(t, -1.0)) <= T + tiny
    u = jnp.where(found, root, 1.0)
    x = jnp.where(start_ok, _unscale(u, lo, hi), below)
    return x, start_ok | (s > 0)


def certified_quantile_shifted(t_mid: jnp.ndarray, t_lo: jnp.ndarray,
                               t_hi: jnp.ndarray, *, seg_lo: jnp.ndarray,
                               seg_hi: jnp.ndarray, coeffs: jnp.ndarray,
                               seg_err: jnp.ndarray, h: int, delta: float,
                               B: jnp.ndarray,
                               ref_keys: Optional[jnp.ndarray] = None,
                               n: int = 0, scan: bool = False):
    """(answer, lower, upper) for slack-pre-shifted rank targets.

    ``t_lo``/``t_hi`` already carry the soundness slack (``rank_slack``) —
    this is the form the Pallas kernels consume, since the slack is a
    traced value folded into the target arrays before the kernel launch.
    """
    args = dict(seg_lo=seg_lo, seg_hi=seg_hi, coeffs=coeffs, h=h, scan=scan)
    x_hi, ok_hi = invert_cf(t_hi, "hi", B=B, seg_err=seg_err, delta=delta,
                            slack=0.0, ref_keys=ref_keys, n=n, **args)
    x_lo, _ = invert_cf(t_lo, "lo", B=B, seg_err=seg_err, delta=delta,
                        slack=0.0, **args)
    dom_hi = seg_hi[h - 1]
    x_hi = jnp.where(ok_hi, x_hi, dom_hi)
    zeros = jnp.zeros_like(seg_err)
    x_mid, ok_mid = invert_cf(t_mid, "hi", B=B, seg_err=zeros, delta=0.0,
                              slack=0.0, raw=True, **args)
    x_mid = jnp.clip(jnp.where(ok_mid, x_mid, dom_hi), x_lo, x_hi)
    return x_mid, x_lo, x_hi


def certified_quantile(t: jnp.ndarray, *, slack, **kw):
    """(answer, lower, upper) for rank targets ``t`` (already in CF units).

    [lower, upper] brackets every rank-t crossing of the monotone CF; the
    answer is the raw fitted crossing clipped into the certificate.
    Targets above the fitted range fall back to the fitted domain top,
    which brackets unconditionally (the data lives inside the domain).
    """
    return certified_quantile_shifted(t, t - slack, t + slack, **kw)
