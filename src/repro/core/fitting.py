"""Minimax (Chebyshev / L-infinity) polynomial fitting — the heart of PolyFit.

The paper (Def. 4.1 / Eq. 10) fits, inside a key interval I holding keys
k_1..k_l with exact-function values F(k_i), the polynomial P minimizing

    E(I) = min_{a} max_i |F(k_i) - P(k_i)|

via a linear program solved with CPLEX.  We provide three fitters:

* ``fit_minimax_lp``     — the paper-faithful LP (scipy/HiGHS, exact).
* ``fit_minimax_lawson`` — Lawson's iteratively-reweighted-least-squares
  algorithm in pure JAX.  It converges to the same minimax solution and, being
  a fixed sequence of small weighted lstsq solves, is *vmappable*: thousands
  of candidate intervals are fitted in one batched device call.  This is the
  beyond-paper construction engine (see DESIGN.md §3).
* ``fit_lstsq``          — plain least squares; used as a cheap lower-bound
  screen (max-residual of the L2 fit upper-bounds E(I)).

Numerical conditioning: the paper observes CPLEX condition numbers of 1E+10
at degree 4 on raw keys.  We always rescale keys to u = (2k - lo - hi) /
(hi - lo) ∈ [-1, 1] per interval before building the Vandermonde system; the
stored model is (lo, hi, coeffs-in-u).  Evaluation is Horner in u.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "PolyModel",
    "rescale",
    "eval_poly",
    "eval_poly_batch",
    "fit_lstsq",
    "fit_minimax_lp",
    "fit_minimax_lawson",
    "lawson_batched",
    "max_error",
]


@dataclasses.dataclass(frozen=True)
class PolyModel:
    """One fitted segment: P(k) = Horner(coeffs, u(k)) on [lo, hi]."""

    lo: float
    hi: float
    coeffs: np.ndarray  # (deg+1,), ascending powers of u
    err: float          # E(I): certified max |F - P| over the fitted keys

    @property
    def deg(self) -> int:
        return len(self.coeffs) - 1

    def __call__(self, k):
        u = rescale(k, self.lo, self.hi)
        return eval_poly(self.coeffs, u)


def rescale(k, lo, hi):
    """Map keys in [lo, hi] to u in [-1, 1] (degenerate interval -> 0)."""
    span = hi - lo
    span = np.where(span <= 0, 1.0, span) if isinstance(span, np.ndarray) else (
        span if span > 0 else 1.0)
    return (2.0 * k - lo - hi) / span


def eval_poly(coeffs, u):
    """Horner evaluation, ascending-power coeffs. Works for np and jnp."""
    xp = jnp if isinstance(u, jnp.ndarray) or isinstance(coeffs, jnp.ndarray) else np
    acc = xp.zeros_like(u) + coeffs[-1]
    for j in range(len(coeffs) - 2, -1, -1):
        acc = acc * u + coeffs[j]
    return acc


def eval_poly_batch(coeffs, u):
    """Horner over batched coeffs: coeffs (..., deg+1), u (...,) -> (...,)."""
    acc = coeffs[..., -1]
    for j in range(coeffs.shape[-1] - 2, -1, -1):
        acc = acc * u + coeffs[..., j]
    return acc


def _vander(u, deg):
    xp = jnp if isinstance(u, jnp.ndarray) else np
    return xp.stack([u**j for j in range(deg + 1)], axis=-1)


def max_error(model: PolyModel, keys: np.ndarray, values: np.ndarray) -> float:
    return float(np.max(np.abs(values - model(keys)))) if len(keys) else 0.0


def continuum_error(model: PolyModel, keys: np.ndarray, values: np.ndarray,
                    strict: bool = False) -> float:
    """Certificate extension for MAX soundness (DESIGN.md §3).

    The paper's LP (Eq. 10) bounds |F - P| at the keys only, but the MAX
    query (Eq. 17) maximizes P over a *continuous* region: a fit that
    interpolates the keys but bulges between them silently breaks Lemma 5.3
    (observed: 200x overestimates on white-noise measures).

    For the paper's workload (query endpoints drawn from the key set), the
    region-max candidates are piece endpoints (covered by the key
    constraints) plus P's interior critical points.  We therefore certify
    err = max(key errors, |P(c) - m_i| for each critical point c inside
    piece i).  Critical points come from np.roots on P' (host-side, any
    degree).  ``strict=True`` additionally certifies the right-limit of each
    flat piece (|P(k_{i+1}) - m_i|), extending the bound to arbitrary real
    query endpoints at the cost of far shorter segments on jumpy data.
    """
    keys = np.asarray(keys, np.float64)
    values = np.asarray(values, np.float64)
    ell = len(keys)
    if ell == 0:
        return 0.0
    u = rescale(keys, model.lo, model.hi)
    Pu = eval_poly(model.coeffs, u)
    err = float(np.max(np.abs(values - Pu)))
    deg = model.deg
    if strict and ell >= 2:
        err = max(err, float(np.max(np.abs(Pu[1:] - values[:-1]))))
    if deg < 2 or ell < 2:
        return err
    dcoef = model.coeffs[1:] * np.arange(1, deg + 1)
    r = np.roots(dcoef[::-1]) if len(dcoef) > 1 else np.array([])
    crit = np.real(r[np.abs(np.imag(r)) < 1e-12]) if len(r) else np.array([])
    crit = crit[(crit > -1.0) & (crit < 1.0)]
    ua, ub = u[:-1], u[1:]
    for c in crit:
        inside = (ua < c) & (c < ub)
        if inside.any():
            pc = float(eval_poly(model.coeffs, np.float64(c)))
            err = max(err, float(np.max(np.abs(pc - values[:-1][inside]))))
    return err


# ---------------------------------------------------------------------------
# Least squares (screening / Lawson initialization)
# ---------------------------------------------------------------------------

def fit_lstsq(keys: np.ndarray, values: np.ndarray, deg: int) -> PolyModel:
    keys = np.asarray(keys, np.float64)
    values = np.asarray(values, np.float64)
    lo, hi = float(keys[0]), float(keys[-1])
    u = rescale(keys, lo, hi)
    A = _vander(u, deg)
    coef, *_ = np.linalg.lstsq(A, values, rcond=None)
    err = float(np.max(np.abs(values - A @ coef))) if len(keys) else 0.0
    return PolyModel(lo, hi, coef, err)


# ---------------------------------------------------------------------------
# Exact LP minimax (paper Eq. 10) — scipy/HiGHS
# ---------------------------------------------------------------------------

def fit_minimax_lp(keys: np.ndarray, values: np.ndarray, deg: int) -> PolyModel:
    """Solve Eq. 10 exactly: minimize t s.t. |F(k_i) - P(k_i)| <= t."""
    from scipy.optimize import linprog

    keys = np.asarray(keys, np.float64)
    values = np.asarray(values, np.float64)
    n = len(keys)
    lo, hi = float(keys[0]), float(keys[-1])
    if n <= deg + 1:
        # interpolation: error 0 (solve square/underdetermined system)
        u = rescale(keys, lo, hi)
        A = _vander(u, deg)
        coef, *_ = np.linalg.lstsq(A, values, rcond=None)
        return PolyModel(lo, hi, coef, max(0.0, float(np.max(np.abs(values - A @ coef))) if n else 0.0))
    u = rescale(keys, lo, hi)
    A = _vander(u, deg)
    ones = np.ones((n, 1))
    #  F - A a <= t   ->  -A a - t <= -F
    #  A a - F <= t   ->   A a - t <=  F
    A_ub = np.block([[-A, -ones], [A, -ones]])
    b_ub = np.concatenate([-values, values])
    c = np.zeros(deg + 2)
    c[-1] = 1.0
    res = linprog(c, A_ub=A_ub, b_ub=b_ub,
                  bounds=[(None, None)] * (deg + 1) + [(0, None)],
                  method="highs")
    if not res.success:  # pragma: no cover - HiGHS is robust on these
        m = fit_lstsq(keys, values, deg)
        return m
    coef = res.x[: deg + 1]
    err = float(np.max(np.abs(values - A @ coef)))
    return PolyModel(lo, hi, coef, err)


# ---------------------------------------------------------------------------
# Lawson IRLS minimax — pure JAX, vmappable
# ---------------------------------------------------------------------------

def _lawson_body(A, F, w, ridge):
    """One Lawson step: weighted lstsq, then reweight by |residual|."""
    Aw = A * w[:, None]
    G = Aw.T @ A + ridge * jnp.eye(A.shape[1], dtype=A.dtype)
    b = Aw.T @ F
    coef = jnp.linalg.solve(G, b)
    r = jnp.abs(F - A @ coef)
    w_new = w * r
    s = jnp.sum(w_new)
    w_new = jnp.where(s > 0, w_new / s, w)
    return coef, w_new, r


@partial(jax.jit, static_argnames=("deg", "iters"))
def _lawson_fixed(u, F, valid, deg: int, iters: int):
    """Lawson on padded arrays. ``valid`` masks padding.

    Returns (coeffs (deg+1,), max_abs_residual over valid points).
    """
    A = _vander(u, deg)
    # zero out padded rows so they contribute nothing
    A = A * valid[:, None]
    Fv = F * valid
    nval = jnp.maximum(jnp.sum(valid), 1.0)
    w = valid / nval
    ridge = jnp.asarray(1e-9, A.dtype)

    def body(carry, _):
        w, _ = carry
        coef, w_new, r = _lawson_body(A, Fv, w, ridge)
        return (w_new, coef), None

    coef0 = jnp.zeros((deg + 1,), A.dtype)
    (w, coef), _ = jax.lax.scan(body, (w, coef0), None, length=iters)
    resid = jnp.abs(Fv - A @ coef) * valid
    return coef, jnp.max(resid)


def fit_minimax_lawson(keys, values, deg: int, iters: int = 60) -> PolyModel:
    keys = np.asarray(keys, np.float64)
    values = np.asarray(values, np.float64)
    lo, hi = float(keys[0]), float(keys[-1])
    u = jnp.asarray(rescale(keys, lo, hi))
    F = jnp.asarray(values)
    valid = jnp.ones_like(F)
    coef, err = _lawson_fixed(u, F, valid, deg, iters)
    return PolyModel(lo, hi, np.asarray(coef), float(err))


@partial(jax.jit, static_argnames=("deg", "iters"))
def lawson_batched(u, F, valid, deg: int, iters: int = 60):
    """Batched Lawson: u/F/valid are (B, L) padded windows in the scaled
    variable; returns coeffs (B, deg+1) and errs (B,).

    This is the TPU-parallel construction engine: one call fits B candidate
    intervals simultaneously (DESIGN.md §3, parallel GS).
    """
    fn = partial(_lawson_fixed, deg=deg, iters=iters)
    return jax.vmap(fn)(u, F, valid)
