"""Shared polynomial/segment primitives for every execution layer.

Horner evaluation, segment location, Chebyshev scaling and the closed-form
clipped polynomial maximum were historically re-implemented in three places
(``core/index.py``, ``kernels/ref.py``, ``kernels/poly_eval.py``); they now
live here once.  Everything in this module is plain ``jnp`` on values — no
tracing tricks — so the same functions run

* inside jitted XLA query paths (``core.queries``, ``engine``),
* inside Pallas kernel bodies (the finalize steps of ``kernels/*.py``), and
* in the pure-jnp oracles (``kernels/ref.py``).

Conventions (DESIGN.md §3): coefficients are ascending-power along the last
axis; keys are mapped to u in [-1, 1] over the segment's key span with a
clamp (the fit is certified on the span; F is constant on inter-segment
gaps, so clamping is exact for CF-type functions and prevents
extrapolation).
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = [
    "horner", "locate", "scale_unit", "eval_segments", "clipped_poly_max",
]


def horner(c: jnp.ndarray, u: jnp.ndarray) -> jnp.ndarray:
    """P(u) by Horner's rule; c (..., deg+1) ascending powers, u (...,)."""
    acc = c[..., -1]
    for j in range(c.shape[-1] - 2, -1, -1):
        acc = acc * u + c[..., j]
    return acc


def locate(q: jnp.ndarray, seg_lo: jnp.ndarray) -> jnp.ndarray:
    """Segment id containing each query key (clamped to the table).

    ``seg_lo`` may be tile-padded with a huge sentinel: in-domain queries
    never resolve to padding because the sentinel exceeds every key.
    """
    idx = jnp.searchsorted(seg_lo, q, side="right") - 1
    return jnp.clip(idx, 0, seg_lo.shape[0] - 1)


def scale_unit(q: jnp.ndarray, lo: jnp.ndarray, hi: jnp.ndarray) -> jnp.ndarray:
    """Map keys to u in [-1, 1] over [lo, hi], clamped (degenerate span -> lo)."""
    span = jnp.where(hi > lo, hi - lo, 1.0)
    return jnp.clip((2.0 * q - lo - hi) / span, -1.0, 1.0)


def eval_segments(q: jnp.ndarray, seg_lo: jnp.ndarray, seg_hi: jnp.ndarray,
                  coeffs: jnp.ndarray) -> jnp.ndarray:
    """P_{I(q)}(q): locate each key's segment and evaluate its polynomial."""
    idx = locate(q, seg_lo)
    u = scale_unit(q, seg_lo[idx], seg_hi[idx])
    return horner(coeffs[idx], u)


def clipped_poly_max(c: jnp.ndarray, slo: jnp.ndarray, shi: jnp.ndarray,
                     a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """max_{k in [a, b]} P(u(k)) per row, closed form for deg <= 3.

    Candidates are both (clamped) endpoints plus the real zero-derivative
    points inside the interval (paper Table 2: P' is linear/quadratic for
    deg 2/3, the recommended MAX degrees).  Empty intervals (a > b) give
    -inf.  c is (..., deg+1); slo/shi the segment's scaling span.

    deg >= 4 needs the cubic-root solver in ``core.queries`` — this helper
    is shared by the Pallas range-MAX kernel, whose in-register closed forms
    stop at deg 3.
    """
    deg = c.shape[-1] - 1
    ua = scale_unit(a, slo, shi)
    ub = scale_unit(b, slo, shi)
    best = jnp.maximum(horner(c, ua), horner(c, ub))
    if deg >= 2:
        c1 = c[..., 1]
        c2 = 2.0 * c[..., 2]
        lin = jnp.where(jnp.abs(c2) > 0, -c1 / jnp.where(c2 == 0, 1.0, c2), ua)
        if deg == 2:
            roots = [lin]
        else:  # deg == 3: P' = c1 + 2 c2 u + 3 c3 u^2
            c3 = 3.0 * c[..., 3]
            disc = c2 * c2 - 4.0 * c3 * c1
            sq = jnp.sqrt(jnp.maximum(disc, 0.0))
            den = jnp.where(jnp.abs(c3) > 0, 2.0 * c3, 1.0)
            quad_ok = (jnp.abs(c3) > 0) & (disc >= 0)
            roots = [jnp.where(quad_ok, (-c2 - sq) / den, lin),
                     jnp.where(quad_ok, (-c2 + sq) / den, lin)]
        for r in roots:
            best = jnp.maximum(best, horner(c, jnp.clip(r, ua, ub)))
    return jnp.where(a <= b, best, -jnp.inf)
