"""Mamba2 (state-space duality / SSD) block — mamba2-130m and the zamba2
hybrid's backbone [arXiv:2405.21060].

Training path: the chunked SSD algorithm — within-chunk quadratic
("attention-like") term plus inter-chunk state recurrence carried by a
lax.scan over chunks.  Decode path: O(1) recurrent state update.  Layout and
parameterization follow the reference mamba2 block:

    in_proj -> [z | x | B | C | dt];  causal depthwise conv over [x|B|C];
    y = SSD(x, dt, A, B, C) + D * x;  out = out_proj(rms(y * silu(z)))

Shapes: d_inner = expand * d_model, H = d_inner / headdim heads, state N,
single B/C group (G=1) as in the released configs.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import BATCH, init_linear, init_rms, linear, rms_norm, shard_hint

__all__ = ["init_mamba2", "mamba2_train", "mamba2_decode", "init_ssm_cache"]


def init_mamba2(rng, cfg, dtype=jnp.float32):
    d = cfg.d_model
    di = cfg.d_inner
    H = cfg.ssm_heads
    N = cfg.ssm_state
    conv_dim = di + 2 * N                      # x | B | C share the conv
    r = jax.random.split(rng, 5)
    d_in_proj = 2 * di + 2 * N + H             # z | x | B | C | dt
    return {
        "in_proj": init_linear(r[0], d, d_in_proj, dtype),
        "conv_w": jax.random.normal(r[1], (cfg.ssm_conv, conv_dim), dtype) * 0.2,
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H).astype(dtype)),
        "D": jnp.ones((H,), dtype),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((H,), 0.01, jnp.float32))).astype(dtype),
        "norm": init_rms(di, dtype),
        "out_proj": init_linear(r[4], di, d, dtype),
    }


def _split_proj(cfg, zxbcdt):
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di:di + di + 2 * N]
    dt = zxbcdt[..., di + di + 2 * N:]
    return z, xBC, dt


def _causal_conv(xBC, w, b):
    """Depthwise causal conv along seq; xBC (B,S,C), w (K,C)."""
    K = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    # unrolled taps: K is 4 — cheaper than conv_general for tiny K
    out = jnp.zeros_like(xBC)
    for k in range(K):
        out = out + pad[:, k:k + xBC.shape[1], :] * w[k][None, None, :]
    return out + b[None, None, :]


def _segsum(x):
    """segsum(x)[..., i, j] = sum x[..., j+1..i] (lower-triangular), -inf above."""
    Q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    ii = jnp.arange(Q)
    mask = ii[:, None] >= ii[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int, return_state: bool = False):
    """Chunked SSD scan.

    x: (B,S,H,P) dt: (B,S,H) A: (H,) Bm/Cm: (B,S,N)  [single group]
    Returns y: (B,S,H,P) [, final state (B,H,P,N)].  f32 state math.
    """
    # pin intermediates to batch sharding — without these GSPMD invents
    # conflicting shardings for the einsum chain and replicates global-batch
    # tensors ("involuntary full rematerialization", ~50GB/dev at train_4k)
    x = shard_hint(x, BATCH, None, None, None)
    dt = shard_hint(dt, BATCH, None, None)
    Bm = shard_hint(Bm, BATCH, None, None)
    Cm = shard_hint(Cm, BATCH, None, None)
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    S0 = S
    pad = (-S) % chunk
    if pad:
        # dt=0 padding is exact: decay exp(0*A)=1 and zero input leave the
        # carried state untouched; padded outputs are sliced off below
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        S = S + pad
    nc = S // chunk
    xc = x.reshape(Bsz, nc, chunk, H, P).astype(jnp.float32)
    dtc = dt.reshape(Bsz, nc, chunk, H).astype(jnp.float32)
    Bc = Bm.reshape(Bsz, nc, chunk, N).astype(jnp.float32)
    Cc = Cm.reshape(Bsz, nc, chunk, N).astype(jnp.float32)
    # A_log parameterization gives A = -exp(A_log) < 0; dA = dt * A <= 0
    dA = dtc * A.astype(jnp.float32)[None, None, None, :]   # (B,nc,Q,H)
    # within-chunk decay L = exp(segsum(dA)) per head: (B,nc,H,Q,Q)
    dAh = jnp.moveaxis(dA, -1, 2)                      # (B,nc,H,Q)
    L = shard_hint(jnp.exp(_segsum(dAh)), BATCH, None, None, None, None)
    xdt = shard_hint(xc * dtc[..., None], BATCH, None, None, None, None)
    # diagonal (within-chunk) term
    scores = shard_hint(jnp.einsum("bcin,bcjn->bcij", Cc, Bc),
                        BATCH, None, None, None)       # (B,nc,Q,Q)
    y_diag = shard_hint(
        jnp.einsum("bcij,bchij,bcjhp->bcihp", scores, L, xdt),
        BATCH, None, None, None, None)
    # chunk-final states: decay from j to end of chunk
    dA_cum = jnp.cumsum(dAh, axis=-1)                  # (B,nc,H,Q)
    decay_to_end = jnp.exp(dA_cum[..., -1:] - dA_cum)  # (B,nc,H,Q)
    states = shard_hint(
        jnp.einsum("bcjn,bchj,bcjhp->bchpn", Bc, decay_to_end, xdt),
        BATCH, None, None, None, None)
    # inter-chunk recurrence: S_{c+1} = exp(sum dA_c) * S_c + states_c
    chunk_decay = jnp.exp(dA_cum[..., -1])             # (B,nc,H)

    def scan_fn(carry, inp):
        s_prev = carry
        st, dec = inp
        s_new = s_prev * dec[..., None, None] + st
        return s_new, s_prev

    init = jnp.zeros((Bsz, H, P, N), jnp.float32)
    s_final, s_prev_all = jax.lax.scan(
        scan_fn, init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    s_prev_all = shard_hint(jnp.moveaxis(s_prev_all, 0, 1),
                            BATCH, None, None, None, None)  # (B,nc,H,P,N)
    # off-diagonal term: contribution of carried state to each position
    decay_in = jnp.exp(dA_cum)                         # (B,nc,H,Q)
    y_off = shard_hint(
        jnp.einsum("bcin,bchi,bchpn->bcihp", Cc, decay_in, s_prev_all),
        BATCH, None, None, None, None)
    y = (y_diag + y_off).reshape(Bsz, S, H, P)[:, :S0]
    if return_state:
        return y.astype(x.dtype), s_final
    return y.astype(x.dtype)


def mamba2_train(p, x, cfg, compute_dtype=jnp.bfloat16,
                 return_cache: bool = False):
    """Full-sequence mamba2 block. x: (B, S, d_model).

    With return_cache=True also returns (final_state (B,H,P,N),
    conv_tail (B, K-1, conv_dim)) for serving prefill.
    """
    B, S, _ = x.shape
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    zxbcdt = shard_hint(linear(p["in_proj"], x, compute_dtype),
                        BATCH, None, None)
    z, xBC_pre, dt = _split_proj(cfg, zxbcdt)
    xBC = jax.nn.silu(_causal_conv(xBC_pre, p["conv_w"].astype(compute_dtype),
                                   p["conv_b"].astype(compute_dtype)))
    xs = xBC[..., :di].reshape(B, S, H, P)
    Bm = xBC[..., di:di + N]
    Cm = xBC[..., di + N:]
    dt_s = jax.nn.softplus(dt.astype(jnp.float32)
                           + p["dt_bias"].astype(jnp.float32))   # (B,S,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                 # (H,) negative
    if return_cache:
        y, s_final = ssd_chunked(xs, dt_s, A, Bm, Cm, cfg.ssm_chunk,
                                 return_state=True)
    else:
        y = ssd_chunked(xs, dt_s, A, Bm, Cm, cfg.ssm_chunk)
    y = y + xs * p["D"].astype(compute_dtype)[None, None, :, None]
    y = y.reshape(B, S, di)
    y = rms_norm(p["norm"], y * jax.nn.silu(z))
    out = linear(p["out_proj"], y, compute_dtype)
    if return_cache:
        conv_tail = xBC_pre[:, -(cfg.ssm_conv - 1):, :].astype(jnp.float32)
        return out, s_final, conv_tail
    return out


def init_ssm_cache(batch, cfg, n_layers, dtype=jnp.float32):
    return {
        "state": jnp.zeros((n_layers, batch, cfg.ssm_heads, cfg.ssm_headdim,
                            cfg.ssm_state), dtype),
        "conv": jnp.zeros((n_layers, batch, cfg.ssm_conv - 1,
                           cfg.d_inner + 2 * cfg.ssm_state), dtype),
    }


def mamba2_decode(p, x, state, conv_cache, cfg, compute_dtype=jnp.bfloat16):
    """One-token recurrent step.  x: (B, 1, d_model); state (B,H,P,N);
    conv_cache (B, K-1, conv_dim).  Returns (y, state, conv_cache)."""
    B = x.shape[0]
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    zxbcdt = linear(p["in_proj"], x, compute_dtype)[:, 0]        # (B, *)
    z, xBC, dt = _split_proj(cfg, zxbcdt)
    # conv over [cache | current]
    win = jnp.concatenate([conv_cache, xBC[:, None, :]], axis=1)  # (B,K,C)
    w = p["conv_w"].astype(compute_dtype)
    conv_out = jnp.einsum("bkc,kc->bc", win.astype(compute_dtype), w) \
        + p["conv_b"].astype(compute_dtype)
    xBC_c = jax.nn.silu(conv_out)
    new_conv = win[:, 1:, :]
    xs = xBC_c[..., :di].reshape(B, H, P).astype(jnp.float32)
    Bm = xBC_c[..., di:di + N].astype(jnp.float32)
    Cm = xBC_c[..., di + N:].astype(jnp.float32)
    dt_s = jax.nn.softplus(dt.astype(jnp.float32)
                           + p["dt_bias"].astype(jnp.float32))   # (B,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dA = jnp.exp(dt_s * A[None, :])                              # (B,H)
    # state update: s = dA * s + dt * x ⊗ B
    upd = jnp.einsum("bhp,bn->bhpn", xs * dt_s[..., None], Bm)
    state = state * dA[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", state, Cm)
    y = y + xs * p["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(B, 1, di).astype(compute_dtype)
    y = rms_norm(p["norm"], y * jax.nn.silu(z[:, None, :]))
    return linear(p["out_proj"], y, compute_dtype), state, new_conv
