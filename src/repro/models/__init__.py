from .transformer import (decode_step, forward_train, init_cache, init_model,
                          loss_fn, prefill)

__all__ = ["decode_step", "forward_train", "init_cache", "init_model",
           "loss_fn", "prefill"]
