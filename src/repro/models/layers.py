"""Shared transformer layers: norms, RoPE, SwiGLU, embeddings.

Pure-function style: params are nested dicts of jnp arrays; every apply
function takes (params, x, ...).  Compute dtype is bf16 by default with f32
master params (cast at use); all dtypes explicit so the core package's x64
flag cannot leak in.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

__all__ = ["rms_norm", "rope", "swiglu", "init_linear", "linear",
           "init_rms", "init_embed", "embed", "logits", "causal_window_mask",
           "shard_hint", "BATCH"]

Dtype = jnp.dtype

# logical batch axes; shard_hint drops names absent from the active mesh
BATCH = ("pod", "data")


def shard_hint(x, *entries):
    """with_sharding_constraint against the ambient mesh (no-op without one).

    Entries are axis names / tuples / None; names missing from the mesh are
    dropped, so model code can say shard_hint(h, BATCH, None, None) and run
    unchanged on 1-device CPU, the 16x16 pod, or the 2x16x16 multi-pod mesh.
    Pinning activations this way stops GSPMD from picking pathological
    intermediate shardings ("involuntary full rematerialization") inside
    scans (see EXPERIMENTS.md §Perf).
    """
    try:
        from jax.sharding import PartitionSpec as P
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or not mesh.axis_names:
            return x
        names = set(mesh.axis_names)

        def fix(e):
            if e is None:
                return None
            t = tuple(a for a in ((e,) if isinstance(e, str) else e)
                      if a in names)
            return t if len(t) > 1 else (t[0] if t else None)

        spec = P(*[fix(e) for e in entries])
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:   # pragma: no cover - conservative fallback
        return x


def init_rms(d, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rms_norm(p, x, eps=1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dt)


def init_linear(rng, d_in, d_out, dtype=jnp.float32):
    std = 1.0 / math.sqrt(d_in)
    return {"w": jax.random.normal(rng, (d_in, d_out), dtype) * std}


def linear(p, x, compute_dtype=jnp.bfloat16):
    return jnp.einsum("...d,df->...f", x.astype(compute_dtype),
                      p["w"].astype(compute_dtype))


def init_embed(rng, vocab, d, dtype=jnp.float32):
    return {"emb": jax.random.normal(rng, (vocab, d), dtype) * 0.02}


def embed(p, tokens, compute_dtype=jnp.bfloat16):
    return p["emb"].astype(compute_dtype)[tokens]


def logits(p, x, compute_dtype=jnp.bfloat16):
    """Tied output head: x @ emb^T (f32 accumulation for the softmax)."""
    return jnp.einsum("...d,vd->...v", x.astype(compute_dtype),
                      p["emb"].astype(compute_dtype),
                      preferred_element_type=jnp.float32)


def rope(x, positions, theta: float):
    """Rotary embedding. x: (..., S, H, D); positions: broadcastable (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32) *
                    (math.log(theta) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


def init_swiglu(rng, d, f, dtype=jnp.float32):
    r1, r2, r3 = jax.random.split(rng, 3)
    return {"wi": init_linear(r1, d, f, dtype),
            "wg": init_linear(r2, d, f, dtype),
            "wo": init_linear(r3, f, d, dtype)}


def swiglu(p, x, compute_dtype=jnp.bfloat16):
    h = linear(p["wi"], x, compute_dtype)
    g = linear(p["wg"], x, compute_dtype)
    return linear(p["wo"], jax.nn.silu(g) * h, compute_dtype)


def causal_window_mask(q_pos, k_pos, window):
    """mask[i, j] = (k_pos_j <= q_pos_i) & (q_pos_i - k_pos_j < window)."""
    diff = q_pos[..., :, None] - k_pos[..., None, :]
    return (diff >= 0) & (diff < window)
