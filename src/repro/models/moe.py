"""Top-k MoE layer (qwen3-moe 128e/top-8, phi3.5-moe 16e/top-2).

Sort-based dispatch with static capacity (no (T, E, C) one-hot blowup):
tokens' (token, k)-assignments are ranked within their expert via an argsort;
assignments past the capacity C = T*top_k/E * capacity_factor are dropped
(GShard-style).  The (E, C, d) dispatch buffer is the unit of expert
parallelism — under pjit it carries a sharding constraint putting E on the
'model' mesh axis, which is what makes the expert GEMM local to each
expert-shard (EXPERIMENTS.md §Perf iterates on the collectives this choice
induces).

Router: softmax gates, top-k, renormalized combine weights; auxiliary
load-balancing loss (Switch-style) returned alongside.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import init_linear, linear

__all__ = ["init_moe", "moe_layer"]


def init_moe(rng, d_model, d_ff, n_experts, dtype=jnp.float32):
    r = jax.random.split(rng, 4)
    std_in = 1.0 / math.sqrt(d_model)
    std_out = 1.0 / math.sqrt(d_ff)
    return {
        "router": init_linear(r[0], d_model, n_experts, dtype),
        "wi": jax.random.normal(r[1], (n_experts, d_model, d_ff), dtype) * std_in,
        "wg": jax.random.normal(r[2], (n_experts, d_model, d_ff), dtype) * std_in,
        "wo": jax.random.normal(r[3], (n_experts, d_ff, d_model), dtype) * std_out,
    }


MAX_TOKENS_PER_DISPATCH = 32_768


def moe_layer(p, x, *, n_experts: int, top_k: int, capacity_factor: float,
              compute_dtype=jnp.bfloat16, ep_axis: Optional[str] = None
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d).  Returns (y, aux_loss).

    Token counts past MAX_TOKENS_PER_DISPATCH are processed in chunks via a
    lax.scan so the (E, C, d) dispatch buffer stays bounded (~the 32k-token
    capacity) regardless of sequence length — required for the prefill_32k
    cells where a single dispatch would be tens of GB.
    """
    B, S, d = x.shape
    T = B * S
    if T > MAX_TOKENS_PER_DISPATCH and T % MAX_TOKENS_PER_DISPATCH == 0:
        nc = T // MAX_TOKENS_PER_DISPATCH
        xc = x.reshape(T, d).reshape(nc, MAX_TOKENS_PER_DISPATCH, d)

        def step(aux, chunk):
            y, a = _moe_tokens(p, chunk, n_experts=n_experts, top_k=top_k,
                               capacity_factor=capacity_factor,
                               compute_dtype=compute_dtype)
            return aux + a, y

        aux, ys = jax.lax.scan(step, jnp.zeros((), jnp.float32), xc)
        return ys.reshape(B, S, d), aux / nc
    y, aux = _moe_tokens(p, x.reshape(T, d), n_experts=n_experts,
                         top_k=top_k, capacity_factor=capacity_factor,
                         compute_dtype=compute_dtype)
    return y.reshape(B, S, d), aux


def _moe_tokens(p, xt, *, n_experts: int, top_k: int, capacity_factor: float,
                compute_dtype=jnp.bfloat16):
    """Dispatch/compute/combine for a flat (T, d) token chunk."""
    T, d = xt.shape
    E, K = n_experts, top_k
    C = max(1, int(capacity_factor * T * K / E))

    gate_logits = linear(p["router"], xt, compute_dtype).astype(jnp.float32)
    gates = jax.nn.softmax(gate_logits, axis=-1)                  # (T, E)
    top_w, top_e = jax.lax.top_k(gates, K)                        # (T, K)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # aux load-balance loss (Switch): E * sum_e f_e * p_e
    me = jnp.mean(gates, axis=0)
    ce = jnp.zeros((E,), jnp.float32).at[top_e.reshape(-1)].add(1.0) / (T * K)
    aux = E * jnp.sum(me * ce)

    # ---- dispatch: rank each (t, k) assignment within its expert ----------
    flat_e = top_e.reshape(-1)                                    # (T*K,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    # start offset of each expert in the sorted list
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(E), side="left")
    pos_sorted = jnp.arange(T * K) - seg_start[sorted_e]
    pos = jnp.zeros((T * K,), jnp.int32).at[order].set(pos_sorted.astype(jnp.int32))
    keep = pos < C
    tok_ids = jnp.repeat(jnp.arange(T), K)                        # (T*K,)

    buf = jnp.zeros((E, C, d), compute_dtype)
    buf = buf.at[flat_e, jnp.where(keep, pos, 0)].add(
        jnp.where(keep[:, None], xt[tok_ids].astype(compute_dtype), 0))

    # ---- expert GEMMs (E sharded over the model axis under pjit) ----------
    h = jnp.einsum("ecd,edf->ecf", buf, p["wi"].astype(compute_dtype))
    g = jnp.einsum("ecd,edf->ecf", buf, p["wg"].astype(compute_dtype))
    o = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * h,
                   p["wo"].astype(compute_dtype))                 # (E, C, d)

    # ---- combine ----------------------------------------------------------
    w_flat = top_w.reshape(-1).astype(compute_dtype)
    gathered = o[flat_e, jnp.where(keep, pos, 0)]                 # (T*K, d)
    contrib = jnp.where(keep[:, None], gathered * w_flat[:, None], 0)
    y = jnp.zeros((T, d), compute_dtype).at[tok_ids].add(contrib)
    return y, aux
