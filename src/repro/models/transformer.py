"""Model assembly for the assigned pool: dense / MoE / SSM / hybrid /
encoder-decoder / VLM, as pure functions over scan-stacked parameters.

Layer stacks use ``jax.lax.scan`` over parameters stacked on a leading L axis
(compile time stays flat in depth — essential for 40 dry-run cells), with
per-layer attention windows carried as a scanned array so heterogeneous
patterns (gemma3 5:1 local:global) need no control flow.  Each block is
wrapped in ``jax.checkpoint`` (remat) during training.

Entry points:
    init_model(rng, cfg)                   -> params
    forward_train(params, cfg, batch)      -> logits (f32)
    init_cache(cfg, batch, max_seq)        -> decode cache
    prefill(params, cfg, batch)            -> (cache, last_logits)
    decode_step(params, cfg, cache, token, pos) -> (logits, cache)
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from . import attention as attn
from . import moe as moe_mod
from . import ssm as ssm_mod
from .layers import (BATCH, embed, init_embed, init_linear, init_rms, linear,
                     logits, rms_norm, shard_hint)
from .layers import init_swiglu, swiglu

__all__ = ["init_model", "forward_train", "init_cache", "prefill",
           "decode_step", "loss_fn"]

CD = jnp.bfloat16  # compute dtype


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_dense_block(rng, cfg, dtype=jnp.float32):
    r1, r2 = jax.random.split(rng)
    return {
        "ln1": init_rms(cfg.d_model, dtype),
        "attn": attn.init_attn(r1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                               cfg.head_dim, cfg.qk_norm, dtype),
        "ln2": init_rms(cfg.d_model, dtype),
        "mlp": init_swiglu(r2, cfg.d_model, cfg.d_ff, dtype),
    }


def _init_moe_block(rng, cfg, dtype=jnp.float32):
    r1, r2 = jax.random.split(rng)
    return {
        "ln1": init_rms(cfg.d_model, dtype),
        "attn": attn.init_attn(r1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                               cfg.head_dim, cfg.qk_norm, dtype),
        "ln2": init_rms(cfg.d_model, dtype),
        "moe": moe_mod.init_moe(r2, cfg.d_model, cfg.d_ff, cfg.n_experts, dtype),
    }


def _init_mamba_block(rng, cfg, dtype=jnp.float32):
    return {
        "ln": init_rms(cfg.d_model, dtype),
        "mixer": ssm_mod.init_mamba2(rng, cfg, dtype),
    }


def _init_encdec_block(rng, cfg, dtype=jnp.float32, *, cross: bool = False):
    r1, r2, r3 = jax.random.split(rng, 3)
    p = {
        "ln1": init_rms(cfg.d_model, dtype),
        "attn": attn.init_attn(r1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                               cfg.head_dim, False, dtype),
        "ln2": init_rms(cfg.d_model, dtype),
        "mlp": init_swiglu(r2, cfg.d_model, cfg.d_ff, dtype),
    }
    if cross:
        p["lnx"] = init_rms(cfg.d_model, dtype)
        p["xattn"] = attn.init_attn(r3, cfg.d_model, cfg.n_heads,
                                    cfg.n_kv_heads, cfg.head_dim, False, dtype)
    return p


def _stack(init_fn, rng, n, *args):
    rngs = jax.random.split(rng, n)
    return jax.vmap(lambda r: init_fn(r, *args))(rngs)


def init_model(rng, cfg, dtype=jnp.float32) -> Dict[str, Any]:
    r = jax.random.split(rng, 8)
    fam = cfg.family
    if fam in ("dense", "vlm"):
        p = {"embed": init_embed(r[0], cfg.vocab, cfg.d_model, dtype),
             "blocks": _stack(_init_dense_block, r[1], cfg.n_layers, cfg, dtype),
             "final_norm": init_rms(cfg.d_model, dtype)}
        if fam == "vlm":
            p["img_proj"] = init_linear(r[2], cfg.frontend_dim, cfg.d_model, dtype)
        return p
    if fam == "moe":
        return {"embed": init_embed(r[0], cfg.vocab, cfg.d_model, dtype),
                "blocks": _stack(_init_moe_block, r[1], cfg.n_layers, cfg, dtype),
                "final_norm": init_rms(cfg.d_model, dtype)}
    if fam == "ssm":
        return {"embed": init_embed(r[0], cfg.vocab, cfg.d_model, dtype),
                "blocks": _stack(_init_mamba_block, r[1], cfg.n_layers, cfg, dtype),
                "final_norm": init_rms(cfg.d_model, dtype)}
    if fam == "hybrid":
        shared = {
            "ln1": init_rms(cfg.d_model, dtype),
            "attn": attn.init_attn(r[2], cfg.d_model, cfg.n_heads,
                                   cfg.n_kv_heads, cfg.head_dim, False, dtype),
            "ln2": init_rms(cfg.d_model, dtype),
            "mlp": init_swiglu(r[3], cfg.d_model, cfg.d_ff, dtype),
        }
        return {"embed": init_embed(r[0], cfg.vocab, cfg.d_model, dtype),
                "blocks": _stack(_init_mamba_block, r[1], cfg.n_layers, cfg, dtype),
                "shared": shared,
                "final_norm": init_rms(cfg.d_model, dtype)}
    if fam == "encdec":
        return {"enc_proj": init_linear(r[0], cfg.frontend_dim, cfg.d_model, dtype),
                "enc_blocks": _stack(partial(_init_encdec_block, cross=False),
                                     r[1], cfg.n_layers, cfg, dtype),
                "enc_norm": init_rms(cfg.d_model, dtype),
                "embed": init_embed(r[2], cfg.vocab, cfg.d_model, dtype),
                "dec_blocks": _stack(partial(_init_encdec_block, cross=True),
                                     r[3], cfg.n_dec_layers, cfg, dtype),
                "final_norm": init_rms(cfg.d_model, dtype)}
    raise ValueError(fam)


# ---------------------------------------------------------------------------
# training-time forwards
# ---------------------------------------------------------------------------

def _dense_stack(blocks, x, cfg, windows, remat=True, causal=True):
    akw = dict(n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, d_head=cfg.head_dim,
               theta=cfg.rope_theta, qk_norm=cfg.qk_norm, causal=causal,
               compute_dtype=CD)

    def body(h, xs):
        blk, w = xs
        h = h + attn.attn_train(blk["attn"], rms_norm(blk["ln1"], h),
                                window=w, **akw)
        h = h + swiglu(blk["mlp"], rms_norm(blk["ln2"], h), CD)
        return h, None

    body_fn = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(body_fn, x, (blocks, windows))
    return x


def _moe_stack(blocks, x, cfg, windows, remat=True):
    akw = dict(n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, d_head=cfg.head_dim,
               theta=cfg.rope_theta, qk_norm=cfg.qk_norm, causal=True,
               compute_dtype=CD)

    def body(carry, xs):
        h, aux = carry
        blk, w = xs
        h = h + attn.attn_train(blk["attn"], rms_norm(blk["ln1"], h),
                                window=w, **akw)
        y, a = moe_mod.moe_layer(blk["moe"], rms_norm(blk["ln2"], h),
                                 n_experts=cfg.n_experts, top_k=cfg.top_k,
                                 capacity_factor=cfg.capacity_factor,
                                 compute_dtype=CD)
        return (h + y, aux + a), None

    body_fn = jax.checkpoint(body) if remat else body
    (x, aux), _ = jax.lax.scan(body_fn, (x, jnp.zeros((), jnp.float32)),
                               (blocks, windows))
    return x, aux


def _mamba_stack(blocks, x, cfg, remat=True):
    def body(h, blk):
        h = h + ssm_mod.mamba2_train(blk["mixer"], rms_norm(blk["ln"], h),
                                     cfg, CD)
        return h, None

    body_fn = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(body_fn, x, blocks)
    return x


def _hybrid_stack(params, x, cfg, seq_len, remat=True):
    period = cfg.shared_attn_period
    n_super = cfg.n_layers // period
    blocks = jax.tree.map(
        lambda a: a.reshape((n_super, period) + a.shape[1:]), params["blocks"])
    shared = params["shared"]
    akw = dict(n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, d_head=cfg.head_dim,
               theta=cfg.rope_theta, qk_norm=False, causal=True,
               compute_dtype=CD)

    def inner(hh, blk):
        hh = hh + ssm_mod.mamba2_train(blk["mixer"],
                                       rms_norm(blk["ln"], hh), cfg, CD)
        return hh, None

    def shared_block(h):
        h = h + attn.attn_train(shared["attn"], rms_norm(shared["ln1"], h),
                                window=seq_len, **akw)
        return h + swiglu(shared["mlp"], rms_norm(shared["ln2"], h), CD)

    # checkpoint at the *individual layer* granularity: super-block remat
    # would keep 6 mamba layers' SSD residuals (the (B,nc,H,Q,Q) decay
    # tensors) live at once during the recomputed backward
    inner_fn = jax.checkpoint(inner) if remat else inner
    shared_fn = jax.checkpoint(shared_block) if remat else shared_block

    def super_body(h, sb):
        h, _ = jax.lax.scan(inner_fn, h, sb)
        return shared_fn(h), None

    x, _ = jax.lax.scan(super_body, x, blocks)
    return x


def _encdec_encode(params, frames, cfg, remat=True):
    x = linear(params["enc_proj"], frames.astype(CD), CD)
    S = x.shape[1]
    x = shard_hint(x + _sinusoid(S, cfg.d_model, CD)[None], BATCH, None, None)
    akw = dict(n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, d_head=cfg.head_dim,
               theta=cfg.rope_theta, qk_norm=False, causal=False,
               compute_dtype=CD)

    def body(h, blk):
        h = h + attn.attn_train(blk["attn"], rms_norm(blk["ln1"], h),
                                window=S, **akw)
        h = h + swiglu(blk["mlp"], rms_norm(blk["ln2"], h), CD)
        return h, None

    body_fn = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(body_fn, x, params["enc_blocks"])
    return rms_norm(params["enc_norm"], x)


def _sinusoid(S, d, dtype):
    pos = jnp.arange(S)[:, None].astype(jnp.float32)
    i = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    ang = pos / jnp.power(10000.0, 2 * i / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


def _encdec_decode_train(params, enc_out, tokens, cfg, remat=True):
    x = embed(params["embed"], tokens, CD)
    S = x.shape[1]
    x = shard_hint(x + _sinusoid(S, cfg.d_model, CD)[None], BATCH, None, None)
    enc_out = shard_hint(enc_out, BATCH, None, None)
    akw = dict(n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, d_head=cfg.head_dim,
               theta=cfg.rope_theta, qk_norm=False, causal=True,
               compute_dtype=CD)
    xkw = dict(n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, d_head=cfg.head_dim,
               compute_dtype=CD)

    def body(h, blk):
        h = h + attn.attn_train(blk["attn"], rms_norm(blk["ln1"], h),
                                window=S, **akw)
        ek, ev = attn.project_cross_kv(blk["xattn"], enc_out,
                                       n_kv=cfg.n_kv_heads,
                                       d_head=cfg.head_dim, compute_dtype=CD)
        h = h + attn.attn_cross(blk["xattn"], rms_norm(blk["lnx"], h), ek, ev,
                                **xkw)
        h = h + swiglu(blk["mlp"], rms_norm(blk["ln2"], h), CD)
        return h, None

    body_fn = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(body_fn, x, params["dec_blocks"])
    return x


def forward_train(params, cfg, batch, remat: bool = True):
    """batch: dict with 'tokens' (B,S) [+ 'frames' | 'images'].  Returns
    (logits_f32 (B,S,V), aux_loss)."""
    fam = cfg.family
    aux = jnp.zeros((), jnp.float32)
    if fam == "encdec":
        enc = _encdec_encode(params, batch["frames"], cfg, remat)
        x = _encdec_decode_train(params, enc, batch["tokens"], cfg, remat)
        x = rms_norm(params["final_norm"], x)
        return logits(params["embed"], x, CD), aux

    tokens = batch["tokens"]
    x = embed(params["embed"], tokens, CD)
    if fam == "vlm":
        img = linear(params["img_proj"], batch["images"].astype(CD), CD)
        x = jnp.concatenate([img, x], axis=1)
    x = shard_hint(x, BATCH, None, None)
    S = x.shape[1]
    windows = jnp.asarray(cfg.layer_windows(S)) if fam in ("dense", "vlm", "moe") else None
    if fam in ("dense", "vlm"):
        x = _dense_stack(params["blocks"], x, cfg, windows, remat)
    elif fam == "moe":
        x, aux = _moe_stack(params["blocks"], x, cfg, windows, remat)
    elif fam == "ssm":
        x = _mamba_stack(params["blocks"], x, cfg, remat)
    elif fam == "hybrid":
        x = _hybrid_stack(params, x, cfg, S, remat)
    else:
        raise ValueError(fam)
    if fam == "vlm":
        x = x[:, batch["images"].shape[1]:]   # text positions only
    x = rms_norm(params["final_norm"], x)
    return logits(params["embed"], x, CD), aux


def loss_fn(params, cfg, batch, remat: bool = True):
    """Next-token cross entropy (f32 log-softmax, vocab-shardable)."""
    lg, aux = forward_train(params, cfg, batch, remat)
    labels = batch["tokens"][:, 1:]
    lg = lg[:, :-1]
    lse = jax.nn.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(lg, labels[..., None], axis=-1)[..., 0]
    mask = batch.get("loss_mask")
    mask = mask[:, 1:] if mask is not None else jnp.ones_like(gold)
    nll = jnp.sum((lse - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return nll + 0.01 * aux, {"nll": nll, "aux": aux}


# ---------------------------------------------------------------------------
# serving: prefill + single-token decode
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, max_seq: int, cache_dtype=jnp.bfloat16):
    fam = cfg.family
    if fam in ("dense", "vlm", "moe"):
        return attn.init_kv_cache(batch, max_seq, cfg.n_kv_heads, cfg.head_dim,
                                  cfg.n_layers, cache_dtype)
    if fam == "ssm":
        return ssm_mod.init_ssm_cache(batch, cfg, cfg.n_layers)
    if fam == "hybrid":
        n_super = cfg.n_layers // cfg.shared_attn_period
        return {"ssm": ssm_mod.init_ssm_cache(batch, cfg, cfg.n_layers),
                "kv": attn.init_kv_cache(batch, max_seq, cfg.n_kv_heads,
                                         cfg.head_dim, n_super, cache_dtype)}
    if fam == "encdec":
        return {"self": attn.init_kv_cache(batch, cfg.dec_seq, cfg.n_kv_heads,
                                           cfg.head_dim, cfg.n_dec_layers,
                                           cache_dtype),
                "cross_k": jnp.zeros((cfg.n_dec_layers, batch, max_seq,
                                      cfg.n_kv_heads, cfg.head_dim), cache_dtype),
                "cross_v": jnp.zeros((cfg.n_dec_layers, batch, max_seq,
                                      cfg.n_kv_heads, cfg.head_dim), cache_dtype)}
    raise ValueError(fam)


def decode_step(params, cfg, cache, token, pos):
    """One decode step.  token: (B,) int32; pos: scalar int32.
    Returns (logits (B, V) f32, new cache)."""
    fam = cfg.family
    B = token.shape[0]
    x = embed(params["embed"], token[:, None], CD)          # (B,1,D)
    akw = dict(n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, d_head=cfg.head_dim,
               theta=cfg.rope_theta, qk_norm=cfg.qk_norm, compute_dtype=CD)

    if fam in ("dense", "vlm", "moe"):
        S = cache["k"].shape[2]
        windows = jnp.asarray(cfg.layer_windows(S))

        def body(h, xs):
            blk, ck, cv, w = xs
            y, ck, cv = attn.attn_decode(blk["attn"], rms_norm(blk["ln1"], h),
                                         ck, cv, pos, window=w, **akw)
            h = h + y
            if fam == "moe":
                y2, _ = moe_mod.moe_layer(blk["moe"], rms_norm(blk["ln2"], h),
                                          n_experts=cfg.n_experts,
                                          top_k=cfg.top_k,
                                          capacity_factor=cfg.capacity_factor,
                                          compute_dtype=CD)
            else:
                y2 = swiglu(blk["mlp"], rms_norm(blk["ln2"], h), CD)
            return h + y2, (ck, cv)

        x, (nk, nv) = jax.lax.scan(body, x,
                                   (params["blocks"], cache["k"], cache["v"],
                                    windows))
        cache = {"k": nk, "v": nv}
    elif fam == "ssm":
        def body(h, xs):
            blk, st, cv = xs
            y, st, cv = ssm_mod.mamba2_decode(blk["mixer"],
                                              rms_norm(blk["ln"], h), st, cv,
                                              cfg, CD)
            return h + y, (st, cv)
        x, (ns, ncv) = jax.lax.scan(body, x, (params["blocks"],
                                              cache["state"], cache["conv"]))
        cache = {"state": ns, "conv": ncv}
    elif fam == "hybrid":
        period = cfg.shared_attn_period
        n_super = cfg.n_layers // period
        blocks = jax.tree.map(
            lambda a: a.reshape((n_super, period) + a.shape[1:]),
            params["blocks"])
        ssm_c = jax.tree.map(
            lambda a: a.reshape((n_super, period) + a.shape[1:]), cache["ssm"])
        shared = params["shared"]
        Skv = cache["kv"]["k"].shape[2]

        def super_body(h, xs):
            sb, st, cv, ck, cvv = xs

            def inner(hh, ys):
                blk, s1, c1 = ys
                y, s1, c1 = ssm_mod.mamba2_decode(blk["mixer"],
                                                  rms_norm(blk["ln"], hh),
                                                  s1, c1, cfg, CD)
                return hh + y, (s1, c1)
            h, (st, cv) = jax.lax.scan(inner, h, (sb, st, cv))
            y, ck, cvv = attn.attn_decode(shared["attn"],
                                          rms_norm(shared["ln1"], h), ck, cvv,
                                          pos, window=Skv,
                                          **{**akw, "qk_norm": False})
            h = h + y
            h = h + swiglu(shared["mlp"], rms_norm(shared["ln2"], h), CD)
            return h, (st, cv, ck, cvv)

        x, (ns, ncv, nk, nv) = jax.lax.scan(
            super_body, x,
            (blocks, ssm_c["state"], ssm_c["conv"],
             cache["kv"]["k"], cache["kv"]["v"]))
        cache = {"ssm": {"state": jax.tree.map(lambda a: a.reshape((cfg.n_layers,) + a.shape[2:]), ns),
                         "conv": jax.tree.map(lambda a: a.reshape((cfg.n_layers,) + a.shape[2:]), ncv)},
                 "kv": {"k": nk, "v": nv}}
    elif fam == "encdec":
        x = x + _sinusoid_at(pos, cfg.d_model, CD)[None, None]

        def body(h, xs):
            blk, ck, cv, xk, xv = xs
            y, ck, cv = attn.attn_decode(blk["attn"], rms_norm(blk["ln1"], h),
                                         ck, cv, pos, window=cfg.dec_seq,
                                         **{**akw, "qk_norm": False})
            h = h + y
            h = h + attn.attn_cross(blk["xattn"], rms_norm(blk["lnx"], h),
                                    xk.astype(CD), xv.astype(CD),
                                    n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                                    d_head=cfg.head_dim, compute_dtype=CD)
            h = h + swiglu(blk["mlp"], rms_norm(blk["ln2"], h), CD)
            return h, (ck, cv)

        x, (nk, nv) = jax.lax.scan(body, x,
                                   (params["dec_blocks"], cache["self"]["k"],
                                    cache["self"]["v"], cache["cross_k"],
                                    cache["cross_v"]))
        cache = {"self": {"k": nk, "v": nv}, "cross_k": cache["cross_k"],
                 "cross_v": cache["cross_v"]}
    else:
        raise ValueError(fam)

    x = rms_norm(params["final_norm"], x)
    return logits(params["embed"], x, CD)[:, 0], cache


def _sinusoid_at(pos, d, dtype):
    i = jnp.arange(d // 2).astype(jnp.float32)
    ang = pos.astype(jnp.float32) / jnp.power(10000.0, 2 * i / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)]).astype(dtype)


def prefill(params, cfg, batch, max_seq: Optional[int] = None,
            cache_dtype=jnp.bfloat16):
    """Process the full prompt in one pass; return (cache, last-token logits).

    Attention families capture K/V per layer during the forward scan;
    SSM/hybrid capture the final SSD state + conv tail (mamba2_train
    return_cache); encdec precomputes the per-layer cross K/V.
    """
    fam = cfg.family
    tokens = batch["tokens"]
    B, S = tokens.shape
    if fam == "encdec":
        enc = _encdec_encode(params, batch["frames"], cfg, remat=False)
        cache = init_cache(cfg, B, enc.shape[1], cache_dtype)

        def proj(blk):
            return attn.project_cross_kv(blk["xattn"], enc,
                                         n_kv=cfg.n_kv_heads,
                                         d_head=cfg.head_dim, compute_dtype=CD)
        ck, cv = jax.vmap(proj)(params["dec_blocks"])
        cache["cross_k"] = ck.astype(cache_dtype)
        cache["cross_v"] = cv.astype(cache_dtype)
        lg, _ = forward_train(params, cfg,
                              {"tokens": tokens, "frames": batch["frames"]},
                              remat=False)
        return cache, lg[:, -1]

    x = embed(params["embed"], tokens, CD)
    if fam == "vlm":
        img = linear(params["img_proj"], batch["images"].astype(CD), CD)
        x = jnp.concatenate([img, x], axis=1)
    Sx = x.shape[1]
    max_seq = max_seq or Sx   # VLM caches cover image prefix + text
    cache = init_cache(cfg, B, max_seq, cache_dtype)

    if fam in ("dense", "vlm", "moe"):
        windows = jnp.asarray(cfg.layer_windows(Sx))

        def body(h, xs):
            blk, w = xs
            hn = rms_norm(blk["ln1"], h)
            y, k, v = attn.attn_train_kv(blk["attn"], hn, n_heads=cfg.n_heads,
                                         n_kv=cfg.n_kv_heads,
                                         d_head=cfg.head_dim, window=w,
                                         theta=cfg.rope_theta,
                                         qk_norm=cfg.qk_norm, causal=True,
                                         compute_dtype=CD)
            h = h + y
            if fam == "moe":
                y2, _ = moe_mod.moe_layer(blk["moe"], rms_norm(blk["ln2"], h),
                                          n_experts=cfg.n_experts,
                                          top_k=cfg.top_k,
                                          capacity_factor=cfg.capacity_factor,
                                          compute_dtype=CD)
            else:
                y2 = swiglu(blk["mlp"], rms_norm(blk["ln2"], h), CD)
            return h + y2, (k.astype(cache_dtype), v.astype(cache_dtype))

        x, (ks, vs) = jax.lax.scan(body, x, (params["blocks"], windows))
        # static prefix write (start is always 0): a dynamic-update-slice
        # here lowers to an s64-indexed in-place update under x64, which
        # the SPMD partitioner rejects (mixed s64/s32 compare) on sharded
        # caches — concat of the written prefix with the untouched tail is
        # the same value and partitions cleanly
        sx = ks.shape[2]
        if sx == cache["k"].shape[2]:
            cache["k"], cache["v"] = ks, vs
        else:
            cache["k"] = jnp.concatenate([ks, cache["k"][:, :, sx:]], axis=2)
            cache["v"] = jnp.concatenate([vs, cache["v"][:, :, sx:]], axis=2)
    elif fam == "ssm":
        def body(h, blk):
            y, st, tail = ssm_mod.mamba2_train(blk["mixer"],
                                               rms_norm(blk["ln"], h), cfg,
                                               CD, return_cache=True)
            return h + y, (st, tail)
        x, (sts, tails) = jax.lax.scan(body, x, params["blocks"])
        cache = {"state": sts, "conv": tails}
    elif fam == "hybrid":
        period = cfg.shared_attn_period
        n_super = cfg.n_layers // period
        blocks = jax.tree.map(
            lambda a: a.reshape((n_super, period) + a.shape[1:]),
            params["blocks"])
        shared = params["shared"]

        def super_body(h, sb):
            def inner(hh, blk):
                y, st, tail = ssm_mod.mamba2_train(blk["mixer"],
                                                   rms_norm(blk["ln"], hh),
                                                   cfg, CD, return_cache=True)
                return hh + y, (st, tail)
            h, (sts, tails) = jax.lax.scan(inner, h, sb)
            y, k, v = attn.attn_train_kv(shared["attn"],
                                         rms_norm(shared["ln1"], h),
                                         n_heads=cfg.n_heads,
                                         n_kv=cfg.n_kv_heads,
                                         d_head=cfg.head_dim, window=Sx,
                                         theta=cfg.rope_theta, qk_norm=False,
                                         causal=True, compute_dtype=CD)
            h = h + y
            h = h + swiglu(shared["mlp"], rms_norm(shared["ln2"], h), CD)
            return h, (sts, tails, k.astype(cache_dtype), v.astype(cache_dtype))

        x, (sts, tails, ks, vs) = jax.lax.scan(super_body, x, blocks)
        flat = lambda a: a.reshape((cfg.n_layers,) + a.shape[2:])
        cache = {"ssm": {"state": flat(sts), "conv": flat(tails)},
                 "kv": {"k": jax.lax.dynamic_update_slice_in_dim(
                            cache["kv"]["k"], ks, 0, axis=2),
                        "v": jax.lax.dynamic_update_slice_in_dim(
                            cache["kv"]["v"], vs, 0, axis=2)}}
    else:
        raise ValueError(fam)

    if fam == "vlm":
        x = x[:, batch["images"].shape[1]:]
    x = rms_norm(params["final_norm"], x)
    lg = logits(params["embed"], x[:, -1:], CD)[:, 0]
    return cache, lg
