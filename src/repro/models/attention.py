"""GQA attention: training (full/windowed causal or bidirectional), prefill
and single-token decode against a KV cache.  Supports qk-norm (qwen3) and
per-layer window sizes (gemma3 5:1 local:global, h2o-danube SWA).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import (BATCH, causal_window_mask, init_linear, init_rms,
                     linear, rms_norm, rope, shard_hint)

__all__ = ["init_attn", "attn_train", "attn_decode", "attn_cross",
           "init_kv_cache"]


def init_attn(rng, d_model, n_heads, n_kv, d_head, qk_norm=False,
              dtype=jnp.float32):
    r = jax.random.split(rng, 4)
    p = {
        "wq": init_linear(r[0], d_model, n_heads * d_head, dtype),
        "wk": init_linear(r[1], d_model, n_kv * d_head, dtype),
        "wv": init_linear(r[2], d_model, n_kv * d_head, dtype),
        "wo": init_linear(r[3], n_heads * d_head, d_model, dtype),
    }
    if qk_norm:
        p["qn"] = init_rms(d_head, dtype)
        p["kn"] = init_rms(d_head, dtype)
    return p


def _qkv(p, x, n_heads, n_kv, d_head, positions, theta, qk_norm,
         compute_dtype, use_rope=True):
    B, S = x.shape[:2]
    q = linear(p["wq"], x, compute_dtype).reshape(B, S, n_heads, d_head)
    k = linear(p["wk"], x, compute_dtype).reshape(B, S, n_kv, d_head)
    v = linear(p["wv"], x, compute_dtype).reshape(B, S, n_kv, d_head)
    if qk_norm:
        q = rms_norm(p["qn"], q)
        k = rms_norm(p["kn"], k)
    if use_rope:
        q = rope(q, positions, theta)
        k = rope(k, positions, theta)
    return q, k, v


def _sdpa(q, k, v, mask, n_kv):
    """q: (B,S,Hq,D); k,v: (B,T,Hkv,D); mask: (B?,S,T) or (S,T) or None."""
    B, S, Hq, D = q.shape
    G = Hq // n_kv
    qg = q.reshape(B, S, n_kv, G, D)
    scores = jnp.einsum("bsngd,btnd->bnsgt", qg, k,
                        preferred_element_type=jnp.float32)
    scores = scores * (1.0 / math.sqrt(D))
    if mask is not None:
        if mask.ndim == 3:        # (B, S, T)
            m = mask[:, None, :, None, :]
        else:                     # (S, T) or (1, T)
            m = mask[None, None, :, None, :]
        scores = jnp.where(m, scores, -1e30)
    w = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(q.dtype)
    out = jnp.einsum("bnsgt,btnd->bsngd", w, v)
    return out.reshape(B, S, Hq, D)


CHUNKED_THRESHOLD = 2048   # materialized S^2 scores above this would OOM
Q_CHUNK = 1024
KV_CHUNK = 1024


def _sdpa_chunked(q, k, v, n_kv, window, causal,
                  q_chunk=Q_CHUNK, kv_chunk=KV_CHUNK):
    """Online-softmax (flash-style) attention: scores materialize one
    (q_chunk x kv_chunk) tile at a time inside nested lax.scans, so long
    sequences (the 32k/500k cells) never allocate S^2.  Window/causal masks
    are computed per tile from block offsets."""
    B, Sq, Hq, D = q.shape
    S = k.shape[1]
    G = Hq // n_kv
    q_chunk = min(q_chunk, Sq)
    nq, nk = Sq // q_chunk, S // kv_chunk
    # pin batch sharding: with head counts that don't divide the model axis
    # GSPMD otherwise replicates these reshapes at global batch size
    qb = shard_hint(q.reshape(B, nq, q_chunk, n_kv, G, D),
                    BATCH, None, None, None, None, None)
    kb = shard_hint(k.reshape(B, nk, kv_chunk, n_kv, D),
                    BATCH, None, None, None, None)
    vb = shard_hint(v.reshape(B, nk, kv_chunk, n_kv, D),
                    BATCH, None, None, None, None)
    scale = 1.0 / math.sqrt(D)

    def q_step(_, qi):
        qblk, qidx = qi                         # (B,qc,n,G,D), scalar
        qpos = qidx * q_chunk + jnp.arange(q_chunk)

        @jax.checkpoint
        def kv_step(carry, ki):
            m, l, acc = carry
            kblk, vblk, kidx = ki
            kpos = kidx * kv_chunk + jnp.arange(kv_chunk)
            s = jnp.einsum("bqngd,bknd->bnqgk", qblk, kblk,
                           preferred_element_type=jnp.float32) * scale
            diff = qpos[:, None] - kpos[None, :]
            ok = (diff < window)
            if causal:
                ok &= diff >= 0
            s = jnp.where(ok[None, None, :, None, :], s, -1e30)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p_ = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p_.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bnqgk,bknd->bnqgd", p_.astype(vblk.dtype), vblk)
            acc_new = shard_hint(acc_new, BATCH, None, None, None, None)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, n_kv, q_chunk, G), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, n_kv, q_chunk, G), jnp.float32)
        a0 = jnp.zeros((B, n_kv, q_chunk, G, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0), jnp.arange(nk)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        # (B,n,qc,G,D) -> (B,qc,n,G,D)
        return None, jnp.moveaxis(out, 2, 1)

    _, blocks = jax.lax.scan(q_step, None,
                             (jnp.moveaxis(qb, 1, 0), jnp.arange(nq)))
    # blocks: (nq, B, qc, n, G, D)
    out = jnp.moveaxis(blocks, 0, 1).reshape(B, Sq, Hq, D)
    return out.astype(q.dtype)


def _attend_full_seq(q, k, v, n_kv, window, causal):
    """Pick materialized vs online-softmax attention by sequence length."""
    S = q.shape[1]
    if S > CHUNKED_THRESHOLD and S % Q_CHUNK == 0 and S % KV_CHUNK == 0:
        return _sdpa_chunked(q, k, v, n_kv, window if causal else S, causal)
    if causal:
        mask = causal_window_mask(jnp.arange(S), jnp.arange(S), window)
    else:
        mask = None
    return _sdpa(q, k, v, mask, n_kv)


def attn_train(p, x, *, n_heads, n_kv, d_head, window, theta, qk_norm=False,
               causal=True, compute_dtype=jnp.bfloat16):
    """Full-sequence attention (training / prefill).  window==S -> global.
    Sequences past CHUNKED_THRESHOLD take the online-softmax tiled path."""
    out, _, _ = attn_train_kv(p, x, n_heads=n_heads, n_kv=n_kv, d_head=d_head,
                              window=window, theta=theta, qk_norm=qk_norm,
                              causal=causal, compute_dtype=compute_dtype)
    return out


def attn_train_kv(p, x, *, n_heads, n_kv, d_head, window, theta,
                  qk_norm=False, causal=True, compute_dtype=jnp.bfloat16):
    """attn_train that also returns (k, v) for serving-prefill cache capture."""
    B, S, _ = x.shape
    positions = jnp.arange(S)[None, :]
    q, k, v = _qkv(p, x, n_heads, n_kv, d_head, positions, theta, qk_norm,
                   compute_dtype)
    out = _attend_full_seq(q, k, v, n_kv, window, causal)
    y = linear(p["wo"], out.reshape(B, S, n_heads * d_head), compute_dtype)
    return y, k, v


def init_kv_cache(batch, max_seq, n_kv, d_head, n_layers, dtype=jnp.bfloat16):
    shape = (n_layers, batch, max_seq, n_kv, d_head)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def attn_decode(p, x, cache_k, cache_v, pos, *, n_heads, n_kv, d_head,
                window, theta, qk_norm=False, compute_dtype=jnp.bfloat16):
    """One-token decode: x (B, 1, D), cache (B, T, n_kv, D), pos scalar.

    Returns (out (B, 1, D), new_cache_k, new_cache_v).  The KV write is an
    in-place dynamic update at ``pos``; attention sees keys [0, pos] clipped
    to the layer's window.
    """
    B = x.shape[0]
    positions = jnp.full((B, 1), pos)
    q, k, v = _qkv(p, x, n_heads, n_kv, d_head, positions, theta, qk_norm,
                   compute_dtype)
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k.astype(cache_k.dtype), pos, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v.astype(cache_v.dtype), pos, axis=1)
    T = cache_k.shape[1]
    kpos = jnp.arange(T)
    valid = (kpos <= pos) & (pos - kpos < window)
    mask = valid[None, :]                    # (1, T) -> broadcast (S=1, T)
    out = _sdpa(q, cache_k.astype(q.dtype), cache_v.astype(q.dtype),
                mask, n_kv)
    y = linear(p["wo"], out.reshape(B, 1, n_heads * d_head), compute_dtype)
    return y, cache_k, cache_v


def attn_cross(p, x, enc_k, enc_v, *, n_heads, n_kv, d_head,
               compute_dtype=jnp.bfloat16):
    """Cross attention (whisper decoder): query from x, fixed encoder K/V
    (already projected, no RoPE — whisper uses learned positions).  Long
    encoder contexts take the online-softmax tiled path."""
    B, S, _ = x.shape
    T = enc_k.shape[1]
    q = linear(p["wq"], x, compute_dtype).reshape(B, S, n_heads, d_head)
    if T > CHUNKED_THRESHOLD and T % KV_CHUNK == 0 and S % min(Q_CHUNK, S) == 0:
        out = _sdpa_chunked(q, enc_k, enc_v, n_kv, window=T + S, causal=False)
    else:
        out = _sdpa(q, enc_k, enc_v, None, n_kv)
    return linear(p["wo"], out.reshape(B, S, n_heads * d_head), compute_dtype)


def project_cross_kv(p, enc_out, *, n_kv, d_head, compute_dtype=jnp.bfloat16):
    B, T, _ = enc_out.shape
    k = linear(p["wk"], enc_out, compute_dtype).reshape(B, T, n_kv, d_head)
    v = linear(p["wv"], enc_out, compute_dtype).reshape(B, T, n_kv, d_head)
    return k, v
