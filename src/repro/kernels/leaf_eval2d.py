"""Pallas TPU kernel: fused 2-key range-COUNT query evaluation (Eq. 19).

The quadtree descent of ``core.index2d`` is pointer chasing — unvectorizable
on the VPU — so the engine flattens the quadtree's *leaves* into a tile-
padded table and resolves each query corner with the same one-hot membership
trick as the 1-D kernels (DESIGN.md §7): leaves partition the root rectangle,
and membership

    one_hot[q, j] = (mx0[j] <= qx < mx1[j]) & (my0[j] <= qy < my1[j])

is locally decidable per tile.  ``mx1``/``my1`` are the leaf's upper bounds
with right/top root-edge leaves widened to a huge sentinel, reproducing the
descent's tie rule (coordinates exactly on an interior split line belong to
the higher-coordinate leaf; the root's own upper edge stays inside).

All four inclusion-exclusion corners of a COUNT query — (ux,uy), (lx,uy),
(ux,ly), (lx,ly) — are resolved against the same resident leaf tile, so the
leaf table is read once per query block instead of four times.  Finalization
evaluates each corner's bivariate polynomial (Horner in v inside Horner in
u, on the leaf's scaled coordinates) and combines with signs (+,-,-,+).

Grid: (num_query_blocks, num_leaf_tiles), leaf tiles innermost; the
(BQ, 4*(K+4)) gather accumulator lives in VMEM scratch across the inner
loop (K = (deg+1)^2 coefficients + 4 scaling bounds per corner slot).

``corner_count2d_gather_pallas`` is the O(Q*log L) locate->gather rewrite
(the engine's ``pallas`` backend; the one-hot scan above stays available as
``pallas_scan``): leaves are disjoint intervals in Morton (Z-order) space,
so a corner resolves with three branch-free binary searches instead of a
membership scan — see kernels/locate.py and DESIGN.md §10.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .locate import locate_leaf2d
from .poly_eval import DEFAULT_BH, DEFAULT_BQ

__all__ = ["corner_count2d_pallas", "corner_count2d_gather_pallas",
           "corner_eval2d_pallas", "corner_eval2d_gather_pallas"]


def _bivariate_horner(qx, qy, c, b, deg: int):
    """P(u(qx), v(qy)) per row from gathered coeff rows c (BQ, (deg+1)^2)
    and scaling bounds b (BQ, 4) — the exact op sequence of the one-hot
    kernel's finalize step, so results are bit-identical."""
    span_x = jnp.where(b[:, 1] > b[:, 0], b[:, 1] - b[:, 0], 1.0)
    span_y = jnp.where(b[:, 3] > b[:, 2], b[:, 3] - b[:, 2], 1.0)
    us = jnp.clip((2.0 * qx - b[:, 0] - b[:, 1]) / span_x, -1.0, 1.0)
    vs = jnp.clip((2.0 * qy - b[:, 2] - b[:, 3]) / span_y, -1.0, 1.0)
    v = jnp.zeros_like(us)
    for i in range(deg, -1, -1):
        inner = jnp.zeros_like(vs)
        for j in range(deg, -1, -1):
            inner = inner * vs + c[:, i * (deg + 1) + j]
        v = v * us + inner
    return v


def _corner_count2d_gather_kernel(lx_ref, ux_ref, ly_ref, uy_ref,
                                  xcuts_ref, ycuts_ref, z_ref,
                                  bounds_ref, coef_ref, out_ref,
                                  *, deg: int, depth: int):
    xcuts = xcuts_ref[...]
    ycuts = ycuts_ref[...]
    z = z_ref[...]
    bounds = bounds_ref[...]
    coef = coef_ref[...]
    corners = ((ux_ref[...], uy_ref[...]), (lx_ref[...], uy_ref[...]),
               (ux_ref[...], ly_ref[...]), (lx_ref[...], ly_ref[...]))
    vals = []
    for qx, qy in corners:
        leaf = locate_leaf2d(qx, qy, xcuts, ycuts, z, depth)   # O(log L)
        c = jnp.take(coef, leaf, axis=0)
        b = jnp.take(bounds, leaf, axis=0)
        vals.append(_bivariate_horner(qx, qy, c, b, deg))
    out_ref[...] = vals[0] - vals[1] - vals[2] + vals[3]


def corner_count2d_gather_pallas(lx, ux, ly, uy, xcuts, ycuts, leaf_z,
                                 bounds, coeffs, deg: int, depth: int,
                                 bq: int = DEFAULT_BQ, interpret: bool = True):
    """Locate->gather 4-corner COUNT (DESIGN.md §10): the quadtree leaves
    are disjoint Morton intervals, so each corner resolves with three
    binary searches (cell x, cell y, leaf z) and one gathered bivariate
    Horner — no scan over the leaf table.  ``leaf_z`` must be sorted
    ascending (the plan stores the whole leaf table in z order) and
    sentinel-padded; corners must be pre-clamped into the root region.
    """
    Q, L = lx.shape[0], leaf_z.shape[0]
    assert Q % bq == 0, (Q, bq)
    k = (deg + 1) * (deg + 1)
    assert coeffs.shape[1] == k, coeffs.shape
    nx, ny = xcuts.shape[0], ycuts.shape[0]
    kernel = functools.partial(_corner_count2d_gather_kernel, deg=deg,
                               depth=depth)
    return pl.pallas_call(
        kernel,
        grid=(Q // bq,),
        in_specs=[
            pl.BlockSpec((bq,), lambda i: (i,)),
            pl.BlockSpec((bq,), lambda i: (i,)),
            pl.BlockSpec((bq,), lambda i: (i,)),
            pl.BlockSpec((bq,), lambda i: (i,)),
            pl.BlockSpec((nx,), lambda i: (0,)),
            pl.BlockSpec((ny,), lambda i: (0,)),
            pl.BlockSpec((L,), lambda i: (0,)),
            pl.BlockSpec((L, 4), lambda i: (0, 0)),
            pl.BlockSpec((L, k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bq,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((Q,), coeffs.dtype),
        interpret=interpret,
    )(lx, ux, ly, uy, xcuts, ycuts, leaf_z, bounds, coeffs)


def _corner_eval2d_gather_kernel(u_ref, v_ref, xcuts_ref, ycuts_ref, z_ref,
                                 bounds_ref, coef_ref, out_ref,
                                 *, deg: int, depth: int):
    u = u_ref[...]
    v = v_ref[...]
    leaf = locate_leaf2d(u, v, xcuts_ref[...], ycuts_ref[...], z_ref[...],
                         depth)
    c = jnp.take(coef_ref[...], leaf, axis=0)
    b = jnp.take(bounds_ref[...], leaf, axis=0)
    out_ref[...] = _bivariate_horner(u, v, c, b, deg)


def corner_eval2d_gather_pallas(u, v, xcuts, ycuts, leaf_z, bounds, coeffs,
                                deg: int, depth: int, bq: int = DEFAULT_BQ,
                                interpret: bool = True):
    """Single-corner leaf evaluation P_{leaf(u,v)}(u, v) via locate->gather
    (DESIGN.md §12): three binary searches resolve the corner's leaf in the
    z-sorted table, one gathered bivariate Horner evaluates it.  This is
    the dominance MAX/MIN query kernel — dominance queries touch exactly
    one leaf, so there is no inclusion-exclusion combination step.
    Corners must be pre-clamped into the root region."""
    Q, L = u.shape[0], leaf_z.shape[0]
    assert Q % bq == 0, (Q, bq)
    k = (deg + 1) * (deg + 1)
    assert coeffs.shape[1] == k, coeffs.shape
    nx, ny = xcuts.shape[0], ycuts.shape[0]
    kernel = functools.partial(_corner_eval2d_gather_kernel, deg=deg,
                               depth=depth)
    return pl.pallas_call(
        kernel,
        grid=(Q // bq,),
        in_specs=[
            pl.BlockSpec((bq,), lambda i: (i,)),
            pl.BlockSpec((bq,), lambda i: (i,)),
            pl.BlockSpec((nx,), lambda i: (0,)),
            pl.BlockSpec((ny,), lambda i: (0,)),
            pl.BlockSpec((L,), lambda i: (0,)),
            pl.BlockSpec((L, 4), lambda i: (0, 0)),
            pl.BlockSpec((L, k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bq,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((Q,), coeffs.dtype),
        interpret=interpret,
    )(u, v, xcuts, ycuts, leaf_z, bounds, coeffs)


def _corner_eval2d_kernel(u_ref, v_ref, mx0_ref, mx1_ref, my0_ref, my1_ref,
                          bounds_ref, coef_ref, out_ref, acc,
                          *, n_tiles: int, deg: int):
    h = pl.program_id(1)
    k = (deg + 1) * (deg + 1)

    @pl.when(h == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    qx = u_ref[...]
    qy = v_ref[...]
    coef = coef_ref[...]                                   # (BH, K)
    table = jnp.concatenate([coef, bounds_ref[...]], axis=1)  # (BH, K+4)
    one_hot = ((mx0_ref[...][None, :] <= qx[:, None]) &
               (qx[:, None] < mx1_ref[...][None, :]) &
               (my0_ref[...][None, :] <= qy[:, None]) &
               (qy[:, None] < my1_ref[...][None, :])).astype(coef.dtype)
    acc[...] += jnp.dot(one_hot, table, preferred_element_type=coef.dtype)

    @pl.when(h == n_tiles - 1)
    def _finalize():
        out_ref[...] = _bivariate_horner(qx, qy, acc[:, :k], acc[:, k:], deg)


def corner_eval2d_pallas(u, v, mx0, mx1, my0, my1, bounds, coeffs,
                         deg: int, bq: int = DEFAULT_BQ,
                         bh: int = DEFAULT_BH, interpret: bool = True):
    """Single-corner leaf evaluation over the flat leaf table — the one-hot
    membership twin of ``corner_eval2d_gather_pallas`` (the engine's
    ``pallas_scan`` backend and the deep-tree fallback).  Shapes pre-padded
    and corners pre-clamped by the caller."""
    Q, L = u.shape[0], mx0.shape[0]
    assert Q % bq == 0 and L % bh == 0, (Q, L, bq, bh)
    k = (deg + 1) * (deg + 1)
    assert coeffs.shape[1] == k, coeffs.shape
    n_tiles = L // bh
    kernel = functools.partial(_corner_eval2d_kernel, n_tiles=n_tiles,
                               deg=deg)
    return pl.pallas_call(
        kernel,
        grid=(Q // bq, n_tiles),
        in_specs=[
            pl.BlockSpec((bq,), lambda i, j: (i,)),
            pl.BlockSpec((bq,), lambda i, j: (i,)),
            pl.BlockSpec((bh,), lambda i, j: (j,)),
            pl.BlockSpec((bh,), lambda i, j: (j,)),
            pl.BlockSpec((bh,), lambda i, j: (j,)),
            pl.BlockSpec((bh,), lambda i, j: (j,)),
            pl.BlockSpec((bh, 4), lambda i, j: (j, 0)),
            pl.BlockSpec((bh, k), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bq,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((Q,), coeffs.dtype),
        scratch_shapes=[pltpu.VMEM((bq, k + 4), coeffs.dtype)],
        interpret=interpret,
    )(u, v, mx0, mx1, my0, my1, bounds, coeffs)


def _corner_count2d_kernel(lx_ref, ux_ref, ly_ref, uy_ref,
                           mx0_ref, mx1_ref, my0_ref, my1_ref,
                           bounds_ref, coef_ref, out_ref, acc,
                           *, n_tiles: int, deg: int):
    h = pl.program_id(1)
    k = (deg + 1) * (deg + 1)
    ncol = k + 4

    @pl.when(h == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    mx0 = mx0_ref[...]
    mx1 = mx1_ref[...]
    my0 = my0_ref[...]
    my1 = my1_ref[...]
    coef = coef_ref[...]                                   # (BH, K)
    table = jnp.concatenate([coef, bounds_ref[...]], axis=1)  # (BH, K+4)

    corners = ((0, ux_ref[...], uy_ref[...]), (1, lx_ref[...], uy_ref[...]),
               (2, ux_ref[...], ly_ref[...]), (3, lx_ref[...], ly_ref[...]))
    for slot, qx, qy in corners:
        one_hot = ((mx0[None, :] <= qx[:, None]) & (qx[:, None] < mx1[None, :]) &
                   (my0[None, :] <= qy[:, None]) & (qy[:, None] < my1[None, :])
                   ).astype(coef.dtype)                    # (BQ, BH)
        acc[:, slot * ncol:(slot + 1) * ncol] += jnp.dot(
            one_hot, table, preferred_element_type=coef.dtype)

    @pl.when(h == n_tiles - 1)
    def _finalize():
        vals = []
        for slot, qx, qy in corners:
            c = acc[:, slot * ncol:slot * ncol + k]
            b0 = acc[:, slot * ncol + k + 0]
            b1 = acc[:, slot * ncol + k + 1]
            b2 = acc[:, slot * ncol + k + 2]
            b3 = acc[:, slot * ncol + k + 3]
            span_x = jnp.where(b1 > b0, b1 - b0, 1.0)
            span_y = jnp.where(b3 > b2, b3 - b2, 1.0)
            us = jnp.clip((2.0 * qx - b0 - b1) / span_x, -1.0, 1.0)
            vs = jnp.clip((2.0 * qy - b2 - b3) / span_y, -1.0, 1.0)
            v = jnp.zeros_like(us)
            for i in range(deg, -1, -1):
                inner = jnp.zeros_like(vs)
                for j in range(deg, -1, -1):
                    inner = inner * vs + c[:, i * (deg + 1) + j]
                v = v * us + inner
            vals.append(v)
        out_ref[...] = vals[0] - vals[1] - vals[2] + vals[3]


def corner_count2d_pallas(lx, ux, ly, uy, mx0, mx1, my0, my1, bounds, coeffs,
                          deg: int, bq: int = DEFAULT_BQ,
                          bh: int = DEFAULT_BH, interpret: bool = True):
    """4-corner COUNT over a flat leaf table; shapes pre-padded to block
    multiples and corners pre-clamped into the root region by the caller
    (the engine's count2d executor does both)."""
    Q, L = lx.shape[0], mx0.shape[0]
    assert Q % bq == 0 and L % bh == 0, (Q, L, bq, bh)
    assert coeffs.shape[1] == (deg + 1) * (deg + 1), coeffs.shape
    n_tiles = L // bh
    k = (deg + 1) * (deg + 1)
    kernel = functools.partial(_corner_count2d_kernel, n_tiles=n_tiles,
                               deg=deg)
    return pl.pallas_call(
        kernel,
        grid=(Q // bq, n_tiles),
        in_specs=[
            pl.BlockSpec((bq,), lambda i, j: (i,)),
            pl.BlockSpec((bq,), lambda i, j: (i,)),
            pl.BlockSpec((bq,), lambda i, j: (i,)),
            pl.BlockSpec((bq,), lambda i, j: (i,)),
            pl.BlockSpec((bh,), lambda i, j: (j,)),
            pl.BlockSpec((bh,), lambda i, j: (j,)),
            pl.BlockSpec((bh,), lambda i, j: (j,)),
            pl.BlockSpec((bh,), lambda i, j: (j,)),
            pl.BlockSpec((bh, 4), lambda i, j: (j, 0)),
            pl.BlockSpec((bh, k), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bq,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((Q,), coeffs.dtype),
        scratch_shapes=[pltpu.VMEM((bq, 4 * (k + 4)), coeffs.dtype)],
        interpret=interpret,
    )(lx, ux, ly, uy, mx0, mx1, my0, my1, bounds, coeffs)
