"""Jit'd public wrappers around the Pallas query kernels.

The segment-table layout these kernels consume is now the canonical
``repro.engine.plan.IndexPlan`` (``SegTable`` remains as an alias, and
``from_index`` as the adapter constructor, for callers that want the raw
kernels without the engine's fused refinement path).  The wrappers handle
the kernel ABI only: query clamping to the index domain and padding queries
to block multiples (with domain-minimum sentinels, sliced off afterwards).

``backend`` selects: 'pallas' (the locate->gather kernels, interpret-mode
on CPU — the TPU-shaped code path), 'pallas_scan' (the original one-hot
membership kernels, kept for A/B benchmarking) or 'ref' (plain XLA, faster
on CPU hosts; identical semantics, see ref.py).  Benchmarks run all of
them.  For the full engine — backend dispatch plus in-path Q_rel
refinement — use ``repro.engine.Engine``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..engine.plan import IndexPlan, build_plan
from . import ref as _ref
from .poly_eval import DEFAULT_BH, DEFAULT_BQ, poly_eval_pallas
from .range_sum import range_sum_gather_pallas, range_sum_pallas
from .range_max import range_max_gather_pallas, range_max_pallas

__all__ = ["SegTable", "from_index", "poly_eval", "range_sum", "range_max"]

# The flat tile-padded segment table was promoted into the engine's
# canonical plan; the historical name stays importable.
SegTable = IndexPlan


def from_index(index, dtype=jnp.float32, bh: int = DEFAULT_BH) -> IndexPlan:
    """Build a kernel-ready IndexPlan from a core.index.PolyFitIndex1D.

    Skips the exact-refinement arrays (raw-kernel callers measure the pure
    approximation path); ``engine.build_plan`` includes them.
    """
    return build_plan(index, dtype=dtype, bh=bh, with_exact=False)


def _pad_queries(q, bq, fill):
    n = q.shape[0]
    p = (-n) % bq
    if p:
        q = jnp.concatenate([q, jnp.full((p,), fill, q.dtype)])
    return q, n


@functools.partial(jax.jit, static_argnames=("backend", "bq", "bh", "interpret"))
def poly_eval(table: IndexPlan, q, backend: str = "pallas",
              bq: int = DEFAULT_BQ, bh: int = DEFAULT_BH,
              interpret: bool = True):
    q = jnp.asarray(q, table.coeffs.dtype)
    dom_lo = table.seg_lo[0]
    q = jnp.maximum(q, dom_lo)
    if backend == "ref":
        # padded segments (sentinel lo) are never matched by locate/one-hot,
        # so ref can consume the padded table directly
        return _ref.poly_eval_ref(q, table.seg_lo, table.seg_next,
                                  table.seg_hi, table.coeffs)
    qp, n = _pad_queries(q, bq, dom_lo)
    out = poly_eval_pallas(qp, table.seg_lo, table.seg_next, table.seg_hi,
                           table.coeffs, bq=bq, bh=bh, interpret=interpret)
    return out[:n]


@functools.partial(jax.jit, static_argnames=("backend", "bq", "bh", "interpret"))
def range_sum(table: IndexPlan, lq, uq, backend: str = "pallas",
              bq: int = DEFAULT_BQ, bh: int = DEFAULT_BH,
              interpret: bool = True):
    dt = table.coeffs.dtype
    lq = jnp.maximum(jnp.asarray(lq, dt), table.seg_lo[0])
    uq = jnp.maximum(jnp.asarray(uq, dt), table.seg_lo[0])
    if backend == "ref":
        return _ref.range_sum_ref(lq, uq, table.seg_lo, table.seg_next,
                                  table.seg_hi, table.coeffs)
    lp, n = _pad_queries(lq, bq, table.seg_lo[0])
    up, _ = _pad_queries(uq, bq, table.seg_lo[0])
    if backend == "pallas_scan":
        out = range_sum_pallas(lp, up, table.seg_lo, table.seg_next,
                               table.seg_hi, table.coeffs,
                               bq=bq, bh=bh, interpret=interpret)
    else:
        out = range_sum_gather_pallas(lp, up, table.seg_lo, table.seg_hi,
                                      table.coeffs, bq=bq,
                                      interpret=interpret)
    return out[:n]


@functools.partial(jax.jit, static_argnames=("backend", "bq", "bh", "interpret"))
def range_max(table: IndexPlan, lq, uq, backend: str = "pallas",
              bq: int = DEFAULT_BQ, bh: int = DEFAULT_BH,
              interpret: bool = True):
    dt = table.coeffs.dtype
    lq = jnp.maximum(jnp.asarray(lq, dt), table.seg_lo[0])
    uq = jnp.maximum(jnp.asarray(uq, dt), table.seg_lo[0])
    if backend == "ref":
        return _ref.range_max_ref(lq, uq, table.seg_lo, table.seg_next,
                                  table.seg_hi, table.coeffs, table.seg_agg)
    lp, n = _pad_queries(lq, bq, table.seg_lo[0])
    up, _ = _pad_queries(uq, bq, table.seg_lo[0])
    if backend == "pallas_scan":
        out = range_max_pallas(lp, up, table.seg_lo, table.seg_next,
                               table.seg_hi, table.coeffs, table.seg_agg,
                               bq=bq, bh=bh, interpret=interpret)
    else:
        out = range_max_gather_pallas(lp, up, table.seg_lo, table.seg_hi,
                                      table.coeffs, table.st, bq=bq,
                                      interpret=interpret)
    return out[:n]
