"""Jit'd public wrappers around the Pallas query kernels.

Handles the kernel ABI: query clamping to the index domain, padding queries
to block multiples (with domain-minimum sentinels, sliced off afterwards) and
padding the segment table to tile multiples (+inf seg_lo so padded segments
match nothing).  ``from_index`` adapts a core.PolyFitIndex1D.

``backend`` selects: 'pallas' (interpret-mode on CPU — the TPU-shaped code
path) or 'ref' (plain XLA, faster on CPU hosts; identical semantics, see
ref.py).  Benchmarks run both.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from . import ref as _ref
from .poly_eval import DEFAULT_BH, DEFAULT_BQ, poly_eval_pallas
from .range_sum import range_sum_pallas
from .range_max import range_max_pallas

__all__ = ["SegTable", "from_index", "poly_eval", "range_sum", "range_max"]


class SegTable(NamedTuple):
    """Flat, tile-padded segment table (device arrays, query dtype)."""

    seg_lo: jnp.ndarray     # (Hp,) +inf padded
    seg_next: jnp.ndarray   # (Hp,) next segment's lo; +inf for last/padding
    seg_hi: jnp.ndarray     # (Hp,)
    coeffs: jnp.ndarray     # (Hp, deg+1) zero padded
    seg_agg: jnp.ndarray    # (Hp,) -inf padded (max/min only; zeros for sum)
    h: int                  # true segment count


def _pad_to(x, mult, fill):
    n = x.shape[0]
    p = (-n) % mult
    if p == 0:
        return x
    pad_shape = (p,) + x.shape[1:]
    return jnp.concatenate([x, jnp.full(pad_shape, fill, x.dtype)])


def _big(dtype):
    """Huge-but-finite sentinel: +-inf would produce 0*inf = NaN inside the
    one-hot matmuls, so padding and the open last boundary use finfo.max/4."""
    return float(np.finfo(np.dtype(dtype)).max) / 4


def from_index(index, dtype=jnp.float32, bh: int = DEFAULT_BH) -> SegTable:
    """Build a SegTable from a core.index.PolyFitIndex1D."""
    big = _big(dtype)
    seg_lo = jnp.asarray(index.seg_lo, dtype)
    seg_hi = jnp.asarray(index.seg_hi, dtype)
    nxt = jnp.concatenate([seg_lo[1:], jnp.full((1,), big, dtype)])
    coeffs = jnp.asarray(index.coeffs, dtype)
    agg = (jnp.asarray(index.seg_agg, dtype) if index.seg_agg is not None
           else jnp.zeros_like(seg_lo))
    h = int(seg_lo.shape[0])
    return SegTable(
        _pad_to(seg_lo, bh, big), _pad_to(nxt, bh, big),
        _pad_to(seg_hi, bh, big), _pad_to(coeffs, bh, 0.0),
        _pad_to(agg, bh, -jnp.inf), h)


def _pad_queries(q, bq, fill):
    n = q.shape[0]
    p = (-n) % bq
    if p:
        q = jnp.concatenate([q, jnp.full((p,), fill, q.dtype)])
    return q, n


@functools.partial(jax.jit, static_argnames=("backend", "bq", "bh", "interpret"))
def poly_eval(table: SegTable, q, backend: str = "pallas",
              bq: int = DEFAULT_BQ, bh: int = DEFAULT_BH,
              interpret: bool = True):
    q = jnp.asarray(q, table.coeffs.dtype)
    dom_lo = table.seg_lo[0]
    q = jnp.maximum(q, dom_lo)
    if backend == "ref":
        # padded segments (+inf lo) are never matched by locate/one-hot, so
        # ref can consume the padded table directly (keeps h un-traced)
        return _ref.poly_eval_ref(q, table.seg_lo, table.seg_next,
                                  table.seg_hi, table.coeffs)
    qp, n = _pad_queries(q, bq, dom_lo)
    out = poly_eval_pallas(qp, table.seg_lo, table.seg_next, table.seg_hi,
                           table.coeffs, bq=bq, bh=bh, interpret=interpret)
    return out[:n]


@functools.partial(jax.jit, static_argnames=("backend", "bq", "bh", "interpret"))
def range_sum(table: SegTable, lq, uq, backend: str = "pallas",
              bq: int = DEFAULT_BQ, bh: int = DEFAULT_BH,
              interpret: bool = True):
    dt = table.coeffs.dtype
    lq = jnp.maximum(jnp.asarray(lq, dt), table.seg_lo[0])
    uq = jnp.maximum(jnp.asarray(uq, dt), table.seg_lo[0])
    if backend == "ref":
        return _ref.range_sum_ref(lq, uq, table.seg_lo, table.seg_next,
                                  table.seg_hi, table.coeffs)
    lp, n = _pad_queries(lq, bq, table.seg_lo[0])
    up, _ = _pad_queries(uq, bq, table.seg_lo[0])
    out = range_sum_pallas(lp, up, table.seg_lo, table.seg_next, table.seg_hi,
                           table.coeffs, bq=bq, bh=bh, interpret=interpret)
    return out[:n]


@functools.partial(jax.jit, static_argnames=("backend", "bq", "bh", "interpret"))
def range_max(table: SegTable, lq, uq, backend: str = "pallas",
              bq: int = DEFAULT_BQ, bh: int = DEFAULT_BH,
              interpret: bool = True):
    dt = table.coeffs.dtype
    lq = jnp.maximum(jnp.asarray(lq, dt), table.seg_lo[0])
    uq = jnp.maximum(jnp.asarray(uq, dt), table.seg_lo[0])
    if backend == "ref":
        return _ref.range_max_ref(lq, uq, table.seg_lo, table.seg_next,
                                  table.seg_hi, table.coeffs, table.seg_agg)
    lp, n = _pad_queries(lq, bq, table.seg_lo[0])
    up, _ = _pad_queries(uq, bq, table.seg_lo[0])
    out = range_max_pallas(lp, up, table.seg_lo, table.seg_next, table.seg_hi,
                           table.coeffs, table.seg_agg,
                           bq=bq, bh=bh, interpret=interpret)
    return out[:n]
