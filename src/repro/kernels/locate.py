"""Branch-free vectorized segment/leaf location (the locate half of the
locate->gather kernel architecture, DESIGN.md §10).

Every one-hot membership kernel in this package does O(Q*H) work per batch:
the whole tile-padded table is compared against every query.  PolyFit's
complexity claim needs the lookup to be O(log H), so this module provides
the shared locate primitives the gather kernels are built on:

* ``bsearch_count`` — a branch-free binary search over a sorted array,
  returning per-lane ``searchsorted`` counts in ceil(log2 n) probe rounds.
  Each round is one clamped gather + compare + select, so the whole search
  vectorizes across the query batch with no per-lane control flow (the VPU
  analogue of Skarupke's branchless lower bound).  It is plain ``jnp`` on
  values, so the same function runs inside Pallas kernel bodies, inside the
  jnp oracles (``ref.py``), and in host-side tests.
* ``locate_segments`` — the kernel-side twin of ``core.poly.locate``:
  clip(searchsorted(seg_lo, q, right) - 1, 0, H-1).
* ``rmq_gather`` — O(1) sparse-table range max via two flattened gathers,
  mirroring ``core.exact.sparse_table_range_max`` (used for interior
  MAX spans and delta-buffer MAX corrections).
* ``interleave2`` / ``dyadic_cuts`` / ``leaf_morton_codes`` — the 2-D
  story: quadtree leaves are intervals in Morton (Z-order) space, so corner
  location becomes *three* binary searches (cell x, cell y, leaf z).  The
  cut grids are rebuilt with the exact midpoint recursion the quadtree
  build uses, so locating against them is bit-identical to the one-hot
  membership rule (ties on a split line go to the higher-coordinate leaf).
* ``locate_pallas`` — a standalone Pallas kernel exposing the 1-D segment
  locate (grid over query blocks, the whole boundary array resident in
  VMEM; compiled mode lowers the probe gathers to Mosaic dynamic gathers,
  interpret mode runs them as plain XLA gathers on CPU).

Sentinel-padded tails need no special casing anywhere: the padding value
exceeds every real key, so the counts never reach it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .poly_eval import DEFAULT_BQ

__all__ = [
    "bsearch_count", "locate_segments", "floor_log2", "rmq_gather",
    "interleave2", "locate_leaf2d", "dyadic_cuts", "leaf_morton_codes",
    "locate_pallas", "MAX_MORTON_DEPTH", "INT_SENTINEL",
]

# 2 bits per level must fit an int32 Morton code (sign bit reserved)
MAX_MORTON_DEPTH = 15
INT_SENTINEL = np.iinfo(np.int32).max


def bsearch_count(keys: jnp.ndarray, q: jnp.ndarray,
                  side: str = "right") -> jnp.ndarray:
    """Per-lane ``searchsorted(keys, q, side)`` in ceil(log2 n) rounds.

    Returns the number of ``keys`` entries <= q (side='right') or < q
    (side='left') as int32.  ``keys`` must be sorted ascending; each round
    probes index ``c + step - 1`` (clamped) and advances the count when the
    probe satisfies the predicate — branch-free, one gather per round.
    """
    n = keys.shape[0]
    c = jnp.zeros(q.shape, jnp.int32)
    step = 1 << max(0, (n - 1).bit_length())   # bit_ceil(n)
    while step >= 1:
        probe = c + (step - 1)
        pv = jnp.take(keys, jnp.minimum(probe, n - 1))
        ok = (pv <= q) if side == "right" else (pv < q)
        c = jnp.where((probe <= n - 1) & ok, c + step, c)
        step >>= 1
    return c


def locate_segments(seg_lo: jnp.ndarray, q: jnp.ndarray) -> jnp.ndarray:
    """Segment id containing q — the gather-path twin of ``core.poly.locate``
    (clip(searchsorted(seg_lo, q, 'right') - 1, 0, H-1))."""
    return jnp.maximum(bsearch_count(seg_lo, q, side="right") - 1, 0)


def floor_log2(length: jnp.ndarray, max_levels: int) -> jnp.ndarray:
    """floor(log2(length)) for int vectors with 1 <= length < 2^max_levels
    (0 for length < 1) — a static sum of compares, no float log."""
    k = jnp.zeros(length.shape, jnp.int32)
    for i in range(1, max_levels):
        k = k + (length >= (1 << i)).astype(jnp.int32)
    return k


def rmq_gather(st: jnp.ndarray, i0: jnp.ndarray, i1: jnp.ndarray):
    """Max over [i0, i1) against a (L, n) sparse table; empty -> -inf.

    Two flattened gathers per lane — the in-kernel twin of
    ``core.exact.sparse_table_range_max`` (same two-window decomposition,
    so results are bit-identical).
    """
    levels, n = st.shape
    flat = st.reshape(-1)
    length = jnp.maximum(i1 - i0, 0)
    lvl = floor_log2(jnp.maximum(length, 1), levels)
    pow2 = jnp.left_shift(jnp.int32(1), lvl)
    left = jnp.take(flat, lvl * n + jnp.minimum(i0, n - 1))
    right = jnp.take(flat, lvl * n + jnp.clip(i1 - pow2, 0, n - 1))
    return jnp.where(length > 0, jnp.maximum(left, right), -jnp.inf)


# ---------------------------------------------------------------------------
# 2-D: quadtree leaves as Morton-interval table
# ---------------------------------------------------------------------------

def interleave2(ix: jnp.ndarray, iy: jnp.ndarray, depth: int) -> jnp.ndarray:
    """Morton (Z-order) code of cell (ix, iy) at ``depth`` bits per axis."""
    z = jnp.zeros(jnp.shape(ix), jnp.int32)
    for b in range(depth):
        z = z | (((ix >> b) & 1) << (2 * b)) | (((iy >> b) & 1) << (2 * b + 1))
    return z


def locate_leaf2d(qx, qy, xcuts, ycuts, leaf_z, depth: int) -> jnp.ndarray:
    """Leaf-table row containing each (pre-clamped) query corner.

    Three binary searches: cell x = #xcuts <= qx, cell y = #ycuts <= qy
    (so a corner exactly on a split line lands in the higher cell — the
    quadtree descent's tie rule), then the Morton code's containing leaf
    interval in the z-sorted table.  O(log H) total.
    """
    ix = bsearch_count(xcuts, qx, side="right")
    iy = bsearch_count(ycuts, qy, side="right")
    z = interleave2(ix, iy, depth)
    return jnp.maximum(bsearch_count(leaf_z, z, side="right") - 1, 0)


def dyadic_cuts(lo: float, hi: float, depth: int) -> np.ndarray:
    """The 2^depth - 1 interior split lines of a midpoint-recursive quadtree
    axis, computed with the *same* float recursion as the tree build
    (``mid = 0.5*(lo + hi)`` of each node's own bounds), so every leaf
    boundary equals a cut value exactly."""
    m = 1 << depth
    g = np.empty(m + 1, np.float64)
    g[0], g[m] = lo, hi
    stack = [(0, m)]
    while stack:
        i0, i1 = stack.pop()
        if i1 - i0 < 2:
            continue
        im = (i0 + i1) // 2
        g[im] = 0.5 * (g[i0] + g[i1])
        stack.append((i0, im))
        stack.append((im, i1))
    return g[1:m]


def leaf_morton_codes(leaf_bounds: np.ndarray, xcuts: np.ndarray,
                      ycuts: np.ndarray, depth: int) -> np.ndarray:
    """Morton code of each leaf's lower-left cell (its z-interval start).

    A quadtree leaf at depth d covers a contiguous Z-order run of
    4^(depth-d) cells, so the starts sort the leaves into disjoint
    intervals covering [0, 4^depth).
    """
    ix0 = np.searchsorted(xcuts, leaf_bounds[:, 0], side="right")
    iy0 = np.searchsorted(ycuts, leaf_bounds[:, 2], side="right")
    z = np.zeros(len(leaf_bounds), np.int64)
    for b in range(depth):
        z |= ((ix0 >> b) & 1) << (2 * b)
        z |= ((iy0 >> b) & 1) << (2 * b + 1)
    return z.astype(np.int32)


# ---------------------------------------------------------------------------
# standalone locate kernel
# ---------------------------------------------------------------------------

def _locate_kernel(q_ref, lo_ref, out_ref):
    out_ref[...] = locate_segments(lo_ref[...], q_ref[...])


def locate_pallas(q, seg_lo, bq: int = DEFAULT_BQ, interpret: bool = True):
    """Segment id per query key: (Q,) int32 against sorted (Hp,) seg_lo.

    Grid over query blocks only — the boundary array is fully resident, and
    each block does ceil(log2 Hp) gather rounds, independent of Hp's size.
    """
    Q, H = q.shape[0], seg_lo.shape[0]
    assert Q % bq == 0, (Q, bq)
    return pl.pallas_call(
        _locate_kernel,
        grid=(Q // bq,),
        in_specs=[
            pl.BlockSpec((bq,), lambda i: (i,)),
            pl.BlockSpec((H,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bq,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((Q,), jnp.int32),
        interpret=interpret,
    )(q, seg_lo)
