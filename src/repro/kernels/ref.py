"""Pure-jnp oracles for the Pallas kernels (same array-level semantics).

These mirror the kernels' contracts exactly — including the query-clamp and
the one-hot membership rule one_hot[q, j] = (seg_lo[j] <= q) & (q <
seg_next[j]) — so tests can assert elementwise equality at matching dtypes.
They are also the XLA fallback path used by ops.py when interpret-mode
Pallas would be slower than plain XLA (CPU hosts).
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["poly_eval_ref", "range_sum_ref", "range_max_ref"]


def _locate(q, seg_lo):
    idx = jnp.searchsorted(seg_lo, q, side="right") - 1
    return jnp.clip(idx, 0, seg_lo.shape[0] - 1)


def _eval_at(q, seg_lo, seg_hi, coeffs):
    idx = _locate(q, seg_lo)
    lo = seg_lo[idx]
    hi = seg_hi[idx]
    span = jnp.where(hi > lo, hi - lo, 1.0)
    u = jnp.clip((2.0 * q - lo - hi) / span, -1.0, 1.0)
    c = coeffs[idx]
    deg = coeffs.shape[1] - 1
    acc = c[..., deg]
    for j in range(deg - 1, -1, -1):
        acc = acc * u + c[..., j]
    return acc


def poly_eval_ref(q, seg_lo, seg_next, seg_hi, coeffs):
    q = jnp.maximum(q, seg_lo[0])
    return _eval_at(q, seg_lo, seg_hi, coeffs)


def range_sum_ref(lq, uq, seg_lo, seg_next, seg_hi, coeffs):
    lq = jnp.maximum(lq, seg_lo[0])
    uq = jnp.maximum(uq, seg_lo[0])
    return (_eval_at(uq, seg_lo, seg_hi, coeffs)
            - _eval_at(lq, seg_lo, seg_hi, coeffs))


def _clipped_poly_max(c, slo, shi, a, b):
    deg = c.shape[-1] - 1
    span = jnp.where(shi > slo, shi - slo, 1.0)
    ua = jnp.clip((2.0 * a - slo - shi) / span, -1.0, 1.0)
    ub = jnp.clip((2.0 * b - slo - shi) / span, -1.0, 1.0)

    def horner(u):
        acc = c[..., deg]
        for j in range(deg - 1, -1, -1):
            acc = acc * u + c[..., j]
        return acc

    best = jnp.maximum(horner(ua), horner(ub))
    if deg >= 2:
        c1 = c[..., 1]
        c2 = 2.0 * c[..., 2]
        if deg == 2:
            roots = [jnp.where(jnp.abs(c2) > 0,
                               -c1 / jnp.where(c2 == 0, 1.0, c2), ua)]
        else:
            c3 = 3.0 * c[..., 3]
            disc = c2 * c2 - 4.0 * c3 * c1
            sq = jnp.sqrt(jnp.maximum(disc, 0.0))
            den = jnp.where(jnp.abs(c3) > 0, 2.0 * c3, 1.0)
            quad_ok = (jnp.abs(c3) > 0) & (disc >= 0)
            lin = jnp.where(jnp.abs(c2) > 0, -c1 / jnp.where(c2 == 0, 1.0, c2), ua)
            roots = [jnp.where(quad_ok, (-c2 - sq) / den, lin),
                     jnp.where(quad_ok, (-c2 + sq) / den, lin)]
        for r in roots:
            best = jnp.maximum(best, horner(jnp.clip(r, ua, ub)))
    return jnp.where(a <= b, best, -jnp.inf)


def range_max_ref(lq, uq, seg_lo, seg_next, seg_hi, coeffs, seg_agg):
    lq = jnp.maximum(lq, seg_lo[0])
    uq = jnp.maximum(uq, seg_lo[0])
    il = _locate(lq, seg_lo)
    iu = _locate(uq, seg_lo)
    same = il == iu
    m_left = _clipped_poly_max(coeffs[il], seg_lo[il], seg_hi[il],
                               lq, jnp.minimum(seg_hi[il], uq))
    m_left = jnp.where(lq <= seg_hi[il], m_left, -jnp.inf)
    m_right = _clipped_poly_max(coeffs[iu], seg_lo[iu], seg_hi[iu],
                                jnp.maximum(seg_lo[iu], lq), uq)
    m_right = jnp.where(same, -jnp.inf, m_right)
    interior = ((seg_lo[None, :] > lq[:, None]) &
                (seg_next[None, :] <= uq[:, None]))
    m_mid = jnp.max(jnp.where(interior, seg_agg[None, :], -jnp.inf), axis=1)
    return jnp.maximum(jnp.maximum(m_left, m_right), m_mid)
