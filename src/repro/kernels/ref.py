"""Pure-jnp oracles for the Pallas kernels (same array-level semantics).

These mirror the kernels' contracts exactly — including the query-clamp and
the one-hot membership rule one_hot[q, j] = (seg_lo[j] <= q) & (q <
seg_next[j]) — so tests can assert elementwise equality at matching dtypes.
They are also the XLA fallback path used by ops.py / the engine when
interpret-mode Pallas would be slower than plain XLA (CPU hosts).

Shared Horner/locate/clamp logic lives in ``core.poly`` (DESIGN.md §3); this
module only adds the kernel-contract glue (clamping rules, dense interior
reductions, 2-D leaf membership).
"""
from __future__ import annotations

import jax.numpy as jnp

from ..core.poly import clipped_poly_max, eval_segments, locate

__all__ = ["poly_eval_ref", "range_sum_ref", "range_max_ref",
           "corner_count2d_ref", "leaf_eval2d_ref", "delta_sum_ref",
           "delta_max_ref", "delta_count2d_ref", "delta_sum2d_ref",
           "delta_dommax2d_ref"]


def poly_eval_ref(q, seg_lo, seg_next, seg_hi, coeffs):
    q = jnp.maximum(q, seg_lo[0])
    return eval_segments(q, seg_lo, seg_hi, coeffs)


def range_sum_ref(lq, uq, seg_lo, seg_next, seg_hi, coeffs):
    lq = jnp.maximum(lq, seg_lo[0])
    uq = jnp.maximum(uq, seg_lo[0])
    return (eval_segments(uq, seg_lo, seg_hi, coeffs)
            - eval_segments(lq, seg_lo, seg_hi, coeffs))


def range_max_ref(lq, uq, seg_lo, seg_next, seg_hi, coeffs, seg_agg):
    lq = jnp.maximum(lq, seg_lo[0])
    uq = jnp.maximum(uq, seg_lo[0])
    il = locate(lq, seg_lo)
    iu = locate(uq, seg_lo)
    same = il == iu
    m_left = clipped_poly_max(coeffs[il], seg_lo[il], seg_hi[il],
                              lq, jnp.minimum(seg_hi[il], uq))
    m_left = jnp.where(lq <= seg_hi[il], m_left, -jnp.inf)
    m_right = clipped_poly_max(coeffs[iu], seg_lo[iu], seg_hi[iu],
                               jnp.maximum(seg_lo[iu], lq), uq)
    m_right = jnp.where(same, -jnp.inf, m_right)
    interior = ((seg_lo[None, :] > lq[:, None]) &
                (seg_next[None, :] <= uq[:, None]))
    m_mid = jnp.max(jnp.where(interior, seg_agg[None, :], -jnp.inf), axis=1)
    return jnp.maximum(jnp.maximum(m_left, m_right), m_mid)


def delta_sum_ref(lq, uq, keys, vals):
    """Exact sum of buffered measures with key in (lq, uq] (delta_scan
    oracle); sentinel-padded slots never satisfy membership."""
    member = ((lq[:, None] < keys[None, :]) &
              (keys[None, :] <= uq[:, None])).astype(vals.dtype)
    return member @ vals


def delta_max_ref(lq, uq, keys, vals):
    """Exact max of buffered measures with key in [lq, uq]; -inf if none."""
    member = (lq[:, None] <= keys[None, :]) & (keys[None, :] <= uq[:, None])
    return jnp.max(jnp.where(member, vals[None, :], -jnp.inf), axis=1)


def delta_count2d_ref(lx, ux, ly, uy, keys_x, keys_y, dtype=None):
    """Exact count of buffered points in (lx, ux] x (ly, uy]."""
    dtype = dtype or keys_x.dtype
    member = ((lx[:, None] < keys_x[None, :]) & (keys_x[None, :] <= ux[:, None]) &
              (ly[:, None] < keys_y[None, :]) & (keys_y[None, :] <= uy[:, None]))
    return jnp.sum(member.astype(dtype), axis=1)


def delta_sum2d_ref(lx, ux, ly, uy, keys_x, keys_y, wv):
    """Exact sum of buffered measures over points in (lx, ux] x (ly, uy];
    sentinel-padded slots carry weight 0 and never satisfy membership."""
    member = ((lx[:, None] < keys_x[None, :]) & (keys_x[None, :] <= ux[:, None]) &
              (ly[:, None] < keys_y[None, :]) & (keys_y[None, :] <= uy[:, None])
              ).astype(wv.dtype)
    return member @ wv


def delta_dommax2d_ref(u, v, keys_x, keys_y, wv):
    """Exact dominance max of buffered measures over {x <= u, y <= v};
    -inf if no buffered point is dominated."""
    member = ((keys_x[None, :] <= u[:, None]) &
              (keys_y[None, :] <= v[:, None]))
    return jnp.max(jnp.where(member, wv[None, :], -jnp.inf), axis=1)


def leaf_eval2d_ref(qx, qy, mx0, mx1, my0, my1, bounds, coeffs, deg):
    """CF at (qx, qy) via the flat-leaf one-hot membership rule.

    one_hot[q, j] = (mx0[j] <= qx < mx1[j]) & (my0[j] <= qy < my1[j]) —
    identical to the quadtree descent's quadrant rule (ties go to the
    higher-coordinate leaf) provided queries are pre-clamped into the root
    region; right/top root-edge leaves carry a huge mx1/my1 sentinel.
    """
    one_hot = ((mx0[None, :] <= qx[:, None]) & (qx[:, None] < mx1[None, :]) &
               (my0[None, :] <= qy[:, None]) & (qy[:, None] < my1[None, :])
               ).astype(coeffs.dtype)
    gath = one_hot @ jnp.concatenate([coeffs, bounds], axis=1)
    k = coeffs.shape[1]
    c, b = gath[:, :k], gath[:, k:]
    span_x = jnp.where(b[:, 1] > b[:, 0], b[:, 1] - b[:, 0], 1.0)
    span_y = jnp.where(b[:, 3] > b[:, 2], b[:, 3] - b[:, 2], 1.0)
    us = jnp.clip((2.0 * qx - b[:, 0] - b[:, 1]) / span_x, -1.0, 1.0)
    vs = jnp.clip((2.0 * qy - b[:, 2] - b[:, 3]) / span_y, -1.0, 1.0)
    acc = jnp.zeros_like(us)
    for i in range(deg, -1, -1):
        inner = jnp.zeros_like(vs)
        for j in range(deg, -1, -1):
            inner = inner * vs + c[:, i * (deg + 1) + j]
        acc = acc * us + inner
    return acc


def corner_count2d_ref(lx, ux, ly, uy, mx0, mx1, my0, my1, bounds, coeffs,
                       deg):
    """4-corner inclusion-exclusion COUNT (Eq. 19) over the flat leaf table.

    Caller must pre-clamp the corner coordinates into the root region (the
    engine's count2d executor does this).
    """
    ev = lambda qx, qy: leaf_eval2d_ref(qx, qy, mx0, mx1, my0, my1, bounds,
                                        coeffs, deg)
    return ev(ux, uy) - ev(lx, uy) - ev(ux, ly) + ev(lx, ly)
