"""Pallas TPU kernels for the PolyFit query hot path.

Each kernel ships as <name>.py (pl.pallas_call + BlockSpec), with ops.py as
the jit'd public wrapper and ref.py as the pure-jnp oracle the tests sweep
against (DESIGN.md §3 for the TPU adaptation rationale).
"""
from .delta_scan import (delta_count2d_gather_pallas, delta_count2d_pallas,
                         delta_max_gather_pallas, delta_max_pallas,
                         delta_sum_gather_pallas, delta_sum_pallas)
from .leaf_eval2d import corner_count2d_gather_pallas, corner_count2d_pallas
from .locate import bsearch_count, locate_pallas
from .ops import SegTable, from_index, poly_eval, range_max, range_sum
from .quantile_invert import quantile_invert_pallas

__all__ = ["SegTable", "from_index", "poly_eval", "range_max", "range_sum",
           "corner_count2d_pallas", "corner_count2d_gather_pallas",
           "delta_sum_pallas", "delta_max_pallas", "delta_count2d_pallas",
           "delta_sum_gather_pallas", "delta_max_gather_pallas",
           "delta_count2d_gather_pallas", "bsearch_count", "locate_pallas",
           "quantile_invert_pallas"]
