"""Pallas TPU kernels: fused range-SUM/COUNT query evaluation (Eq. 14).

Two implementations of A = P_{I(u)}(u) - P_{I(l)}(l) per (l, u) range:

* ``range_sum_gather_pallas`` — the locate->gather path (DESIGN.md §10,
  the engine's ``pallas`` backend): both endpoints are resolved with the
  branch-free binary search of ``locate.py`` in O(log H) probe rounds,
  then exactly one (deg+1)-coefficient row per endpoint is gathered and
  Horner-evaluated.  Per-query work is independent of the table size.
* ``range_sum_pallas`` — the original one-hot membership scan (the
  ``pallas_scan`` backend, kept for A/B benchmarking): both endpoints'
  one-hot rows are resolved against each resident segment tile with an MXU
  matmul — O(Q*H) work, memory-bound on the table when H is large.

Both paths gather the same rows and share ``core.poly.horner``/
``scale_unit``, so their answers are bit-identical.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.poly import horner, scale_unit
from .locate import locate_segments
from .poly_eval import DEFAULT_BH, DEFAULT_BQ

__all__ = ["range_sum_pallas", "range_sum_gather_pallas"]


def _range_sum_gather_kernel(lq_ref, uq_ref, lo_ref, hi_ref, coef_ref,
                             out_ref):
    lo = lo_ref[...]
    hi = hi_ref[...]
    coef = coef_ref[...]
    vals = []
    for q_ref in (lq_ref, uq_ref):
        q = q_ref[...]
        idx = locate_segments(lo, q)                       # O(log H)
        c = jnp.take(coef, idx, axis=0)                    # (BQ, deg+1)
        u = scale_unit(q, jnp.take(lo, idx), jnp.take(hi, idx))
        vals.append(horner(c, u))
    out_ref[...] = vals[1] - vals[0]


def range_sum_gather_pallas(lq, uq, seg_lo, seg_hi, coeffs,
                            bq: int = DEFAULT_BQ, interpret: bool = True):
    """Locate->gather range SUM: grid over query blocks only, the whole
    (sentinel-padded) segment table resident per block."""
    Q, H = lq.shape[0], seg_lo.shape[0]
    assert Q % bq == 0, (Q, bq)
    deg = coeffs.shape[1] - 1
    return pl.pallas_call(
        _range_sum_gather_kernel,
        grid=(Q // bq,),
        in_specs=[
            pl.BlockSpec((bq,), lambda i: (i,)),
            pl.BlockSpec((bq,), lambda i: (i,)),
            pl.BlockSpec((H,), lambda i: (0,)),
            pl.BlockSpec((H,), lambda i: (0,)),
            pl.BlockSpec((H, deg + 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bq,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((Q,), coeffs.dtype),
        interpret=interpret,
    )(lq, uq, seg_lo, seg_hi, coeffs)


def _range_sum_kernel(lq_ref, uq_ref, lo_ref, nxt_ref, hi_ref, coef_ref,
                      out_ref, acc, *, n_tiles: int, deg: int):
    """acc layout: (BQ, 2*(deg+3)): per endpoint [coef x (deg+1), lo, hi]."""
    h = pl.program_id(1)
    ncol = deg + 3

    @pl.when(h == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    lo = lo_ref[...]
    nxt = nxt_ref[...]
    hi = hi_ref[...]
    coef = coef_ref[...]
    # (BH, deg+3): coeffs | scale-lo | scale-hi — one matmul gathers all
    table = jnp.concatenate([coef, lo[:, None], hi[:, None]], axis=1)

    for slot, q_ref in ((0, lq_ref), (1, uq_ref)):
        q = q_ref[...]
        one_hot = ((lo[None, :] <= q[:, None]) &
                   (q[:, None] < nxt[None, :])).astype(coef.dtype)
        acc[:, slot * ncol:(slot + 1) * ncol] += jnp.dot(
            one_hot, table, preferred_element_type=coef.dtype)

    @pl.when(h == n_tiles - 1)
    def _finalize():
        vals = []
        for slot, q_ref in ((0, lq_ref), (1, uq_ref)):
            q = q_ref[...]
            c = acc[:, slot * ncol:slot * ncol + deg + 1]
            slo = acc[:, slot * ncol + deg + 1]
            shi = acc[:, slot * ncol + deg + 2]
            vals.append(horner(c, scale_unit(q, slo, shi)))
        out_ref[...] = vals[1] - vals[0]


def range_sum_pallas(lq, uq, seg_lo, seg_next, seg_hi, coeffs,
                     bq: int = DEFAULT_BQ, bh: int = DEFAULT_BH,
                     interpret: bool = True):
    Q, H = lq.shape[0], seg_lo.shape[0]
    assert Q % bq == 0 and H % bh == 0, (Q, H, bq, bh)
    deg = coeffs.shape[1] - 1
    n_tiles = H // bh
    kernel = functools.partial(_range_sum_kernel, n_tiles=n_tiles, deg=deg)
    return pl.pallas_call(
        kernel,
        grid=(Q // bq, n_tiles),
        in_specs=[
            pl.BlockSpec((bq,), lambda i, j: (i,)),
            pl.BlockSpec((bq,), lambda i, j: (i,)),
            pl.BlockSpec((bh,), lambda i, j: (j,)),
            pl.BlockSpec((bh,), lambda i, j: (j,)),
            pl.BlockSpec((bh,), lambda i, j: (j,)),
            pl.BlockSpec((bh, deg + 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bq,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((Q,), coeffs.dtype),
        scratch_shapes=[pltpu.VMEM((bq, 2 * (deg + 3)), coeffs.dtype)],
        interpret=interpret,
    )(lq, uq, seg_lo, seg_next, seg_hi, coeffs)
