"""Pallas TPU kernel: certified CF inversion for QUANTILE queries.

Per rank target the kernel runs the branch-free locate -> closed-form /
Newton solve -> key-grid snap pipeline of ``core.quantile`` entirely
on-chip and emits the (answer, lower, upper) triple in one launch:

* ``quantile_invert_pallas`` — the locate->gather path (the engine's
  ``pallas`` backend): the cummax'd segment-boundary array ``B`` is
  binary-searched with the same probe loop as ``kernels.locate``
  (O(log H) rounds), one coefficient row is gathered per target, and the
  per-segment root solve plus the exact-key snap run vectorised over the
  query block.  ``scan=True`` switches every searchsorted to the one-hot
  comparison sum — O(Q*(H+n)) work — which is the ``pallas_scan`` A/B
  twin; the summed predicate equals the bsearch predicate, so both
  variants return bit-identical keys.

The boundary array ``B`` and the exact key grid ``ref_keys`` are
computed *outside* the kernel and passed as inputs: ``lax.cummax`` is a
host-side prefix pass over the (H,) table, not per-query work, and
keeping the kernel body pure gather/arithmetic avoids relying on
associative-scan lowering inside Mosaic.  Rank-slack is folded into the
target arrays before launch (``certified_quantile_shifted`` form)
because the slack is a traced scalar.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core.quantile import certified_quantile_shifted
from .poly_eval import DEFAULT_BQ

__all__ = ["quantile_invert_pallas"]


def _quantile_invert_kernel(tm_ref, tl_ref, th_ref, B_ref, lo_ref, hi_ref,
                            coef_ref, err_ref, keys_ref, mid_ref, out_lo_ref,
                            out_hi_ref, *, h, n, delta, scan):
    mid, x_lo, x_hi = certified_quantile_shifted(
        tm_ref[...], tl_ref[...], th_ref[...],
        seg_lo=lo_ref[...], seg_hi=hi_ref[...], coeffs=coef_ref[...],
        seg_err=err_ref[...], h=h, delta=delta, B=B_ref[...],
        ref_keys=keys_ref[...], n=n, scan=scan)
    mid_ref[...] = mid
    out_lo_ref[...] = x_lo
    out_hi_ref[...] = x_hi


def quantile_invert_pallas(t_mid: jnp.ndarray, t_lo: jnp.ndarray,
                           t_hi: jnp.ndarray, B: jnp.ndarray,
                           seg_lo: jnp.ndarray, seg_hi: jnp.ndarray,
                           coeffs: jnp.ndarray, seg_err: jnp.ndarray,
                           ref_keys: jnp.ndarray, *, h: int, n: int,
                           delta: float, bq: int = DEFAULT_BQ,
                           interpret: bool = True, scan: bool = False):
    """(answer, lower, upper) for slack-pre-shifted rank-target blocks.

    ``ref_keys`` is the (padded) sorted exact key grid; ``n`` the live
    key count.  All (H,)/(H, deg+1)/(nk,) tables are resident per block;
    only the three target arrays and outputs are bq-blocked.
    """
    Q = t_mid.shape[0]
    H = seg_lo.shape[0]
    nk = ref_keys.shape[0]
    deg = coeffs.shape[1] - 1
    assert Q % bq == 0, f"Q={Q} not a multiple of bq={bq}"
    kernel = functools.partial(_quantile_invert_kernel, h=h, n=n,
                               delta=delta, scan=scan)
    qspec = pl.BlockSpec((bq,), lambda i: (i,))
    tspec = pl.BlockSpec((H,), lambda i: (0,))
    out = jax.ShapeDtypeStruct((Q,), coeffs.dtype)
    return pl.pallas_call(
        kernel,
        grid=(Q // bq,),
        in_specs=[qspec, qspec, qspec, tspec, tspec, tspec,
                  pl.BlockSpec((H, deg + 1), lambda i: (0, 0)), tspec,
                  pl.BlockSpec((nk,), lambda i: (0,))],
        out_specs=(qspec, qspec, qspec),
        out_shape=(out, out, out),
        interpret=interpret,
    )(t_mid, t_lo, t_hi, B, seg_lo, seg_hi, coeffs, seg_err, ref_keys)
