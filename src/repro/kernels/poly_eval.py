"""Pallas TPU kernel: fused segment-resolve + Horner polynomial evaluation.

Evaluates P_{I(q)}(q) for a batch of query keys against a PolyFit segment
table — the hot inner loop of every SUM/COUNT query (Eq. 14 does two of
these per query; see range_sum.py for the fused two-endpoint version).

TPU adaptation (DESIGN.md §3): instead of a per-lane binary search (pointer
chasing — unvectorizable on the VPU), each (query-block x segment-tile) step
computes the *one-hot membership matrix*

    one_hot[q, j] = (seg_lo[j] <= q) & (q < seg_next[j])

which is locally decidable per tile because ``seg_next`` (the next segment's
start, +inf for the last) ships alongside ``seg_lo``.  Membership is then
turned into gathered coefficients with an MXU matmul ``one_hot @ coeffs``,
accumulated across segment tiles in VMEM scratch.  The wrapper clamps
queries to >= seg_lo[0], so the one-hots partition [seg_lo[0], +inf) and
out-of-domain queries resolve to the edge polynomials — identical to the XLA
path's clip semantics.

Grid: (num_query_blocks, num_segment_tiles), segment tiles innermost so the
scratch accumulators live across the inner loop and the output block is
written once at the last tile.

Block sizes: BQ=256 queries x BH=512 segments gives a (256, 512) f32
compare/matmul tile (512 KiB in VMEM) plus (512, deg+1) coefficients —
comfortably inside the ~16 MiB VMEM budget with MXU-aligned dims.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.poly import horner, scale_unit

__all__ = ["poly_eval_pallas", "DEFAULT_BQ", "DEFAULT_BH"]

DEFAULT_BQ = 256
DEFAULT_BH = 512


def _poly_eval_kernel(q_ref, lo_ref, nxt_ref, hi_ref, coef_ref, out_ref,
                      acc_coef, acc_lo, acc_hi, *, n_tiles: int, deg: int):
    h = pl.program_id(1)

    @pl.when(h == 0)
    def _init():
        acc_coef[...] = jnp.zeros_like(acc_coef)
        acc_lo[...] = jnp.zeros_like(acc_lo)
        acc_hi[...] = jnp.zeros_like(acc_hi)

    q = q_ref[...]                      # (BQ,)
    lo = lo_ref[...]                    # (BH,)
    nxt = nxt_ref[...]                  # (BH,)
    hi = hi_ref[...]                    # (BH,)
    coef = coef_ref[...]                # (BH, deg+1)

    one_hot = ((lo[None, :] <= q[:, None]) &
               (q[:, None] < nxt[None, :])).astype(coef.dtype)   # (BQ, BH)
    # membership -> gathered coefficients / bounds, on the MXU
    acc_coef[...] += jnp.dot(one_hot, coef, preferred_element_type=coef.dtype)
    acc_lo[...] += one_hot @ lo
    acc_hi[...] += one_hot @ hi

    @pl.when(h == n_tiles - 1)
    def _finalize():
        u = scale_unit(q, acc_lo[...], acc_hi[...])
        out_ref[...] = horner(acc_coef[...], u)


def poly_eval_pallas(q, seg_lo, seg_next, seg_hi, coeffs,
                     bq: int = DEFAULT_BQ, bh: int = DEFAULT_BH,
                     interpret: bool = True):
    """P_{I(q)}(q) for q (Q,) against H segments.  Shapes must be padded to
    block multiples by the caller (see ops.pad_index / ops.poly_eval)."""
    Q, H = q.shape[0], seg_lo.shape[0]
    assert Q % bq == 0 and H % bh == 0, (Q, H, bq, bh)
    deg = coeffs.shape[1] - 1
    n_tiles = H // bh
    kernel = functools.partial(_poly_eval_kernel, n_tiles=n_tiles, deg=deg)
    return pl.pallas_call(
        kernel,
        grid=(Q // bq, n_tiles),
        in_specs=[
            pl.BlockSpec((bq,), lambda i, j: (i,)),
            pl.BlockSpec((bh,), lambda i, j: (j,)),
            pl.BlockSpec((bh,), lambda i, j: (j,)),
            pl.BlockSpec((bh,), lambda i, j: (j,)),
            pl.BlockSpec((bh, deg + 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bq,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((Q,), coeffs.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, deg + 1), coeffs.dtype),
            pltpu.VMEM((bq,), coeffs.dtype),
            pltpu.VMEM((bq,), coeffs.dtype),
        ],
        interpret=interpret,
    )(q, seg_lo, seg_next, seg_hi, coeffs)
