"""Pallas TPU kernels: exact delta-buffer scans for dynamic plans.

A ``DynamicEngine`` (engine/dynamic.py) buffers inserts/deletes in fixed-
capacity, sentinel-padded device arrays between merges.  Queries fuse the
static plan's approximation with an *exact* correction over the buffer, so
the certified error bounds survive updates: the only approximation error
left is the static plan's own E(I) <= delta.

All three kernels reuse the one-hot membership matmul pattern of
``poly_eval.py``/``range_sum.py`` — membership of each buffered key in each
query range is a (BQ, BD) compare tile, turned into a gathered reduction on
the MXU (SUM/COUNT) or a masked VPU max (MAX/MIN), accumulated across
buffer tiles in VMEM scratch:

* ``delta_sum_pallas``     — sum of buffered measures with key in (lq, uq]
                             (the CF-difference range of Eq. 5);
* ``delta_max_pallas``     — max of buffered measures with key in [lq, uq]
                             (MAX range semantics; -inf on empty);
* ``delta_count2d_pallas`` — count of buffered points in the half-open
                             rectangle (lx, ux] x (ly, uy] (Eq. 19).

Empty buffer slots hold a huge-but-finite sentinel key (``plan.big_sentinel``)
so they fail every membership test without needing a separate count input —
the kernels are oblivious to the fill level.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .poly_eval import DEFAULT_BH, DEFAULT_BQ

__all__ = ["delta_sum_pallas", "delta_max_pallas", "delta_count2d_pallas"]


def _delta_sum_kernel(lq_ref, uq_ref, k_ref, v_ref, out_ref, acc,
                      *, n_tiles: int):
    d = pl.program_id(1)

    @pl.when(d == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    lq = lq_ref[...]
    uq = uq_ref[...]
    k = k_ref[...]
    v = v_ref[...]
    # (BQ, BD) membership in (lq, uq]; sentinel-padded slots never match
    member = ((lq[:, None] < k[None, :]) &
              (k[None, :] <= uq[:, None])).astype(v.dtype)
    acc[...] += jnp.dot(member, v, preferred_element_type=v.dtype)

    @pl.when(d == n_tiles - 1)
    def _finalize():
        out_ref[...] = acc[...]


def delta_sum_pallas(lq, uq, keys, vals, bq: int = DEFAULT_BQ,
                     bd: int = DEFAULT_BH, interpret: bool = True):
    """Exact sum of buffered measures with key in (lq, uq] per query."""
    Q, D = lq.shape[0], keys.shape[0]
    bd = min(bd, D)
    assert Q % bq == 0 and D % bd == 0, (Q, D, bq, bd)
    n_tiles = D // bd
    kernel = functools.partial(_delta_sum_kernel, n_tiles=n_tiles)
    return pl.pallas_call(
        kernel,
        grid=(Q // bq, n_tiles),
        in_specs=[
            pl.BlockSpec((bq,), lambda i, j: (i,)),
            pl.BlockSpec((bq,), lambda i, j: (i,)),
            pl.BlockSpec((bd,), lambda i, j: (j,)),
            pl.BlockSpec((bd,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bq,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((Q,), vals.dtype),
        scratch_shapes=[pltpu.VMEM((bq,), vals.dtype)],
        interpret=interpret,
    )(lq, uq, keys, vals)


def _delta_max_kernel(lq_ref, uq_ref, k_ref, v_ref, out_ref, acc,
                      *, n_tiles: int):
    d = pl.program_id(1)

    @pl.when(d == 0)
    def _init():
        acc[...] = jnp.full_like(acc, -jnp.inf)

    lq = lq_ref[...]
    uq = uq_ref[...]
    k = k_ref[...]
    v = v_ref[...]
    member = (lq[:, None] <= k[None, :]) & (k[None, :] <= uq[:, None])
    tile_max = jnp.max(jnp.where(member, v[None, :], -jnp.inf), axis=1)
    acc[...] = jnp.maximum(acc[...], tile_max)

    @pl.when(d == n_tiles - 1)
    def _finalize():
        out_ref[...] = acc[...]


def delta_max_pallas(lq, uq, keys, vals, bq: int = DEFAULT_BQ,
                     bd: int = DEFAULT_BH, interpret: bool = True):
    """Exact max of buffered measures with key in [lq, uq] (-inf if none)."""
    Q, D = lq.shape[0], keys.shape[0]
    bd = min(bd, D)
    assert Q % bq == 0 and D % bd == 0, (Q, D, bq, bd)
    n_tiles = D // bd
    kernel = functools.partial(_delta_max_kernel, n_tiles=n_tiles)
    return pl.pallas_call(
        kernel,
        grid=(Q // bq, n_tiles),
        in_specs=[
            pl.BlockSpec((bq,), lambda i, j: (i,)),
            pl.BlockSpec((bq,), lambda i, j: (i,)),
            pl.BlockSpec((bd,), lambda i, j: (j,)),
            pl.BlockSpec((bd,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bq,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((Q,), vals.dtype),
        scratch_shapes=[pltpu.VMEM((bq,), vals.dtype)],
        interpret=interpret,
    )(lq, uq, keys, vals)


def _delta_count2d_kernel(lx_ref, ux_ref, ly_ref, uy_ref, kx_ref, ky_ref,
                          out_ref, acc, *, n_tiles: int):
    d = pl.program_id(1)

    @pl.when(d == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    lx = lx_ref[...]
    ux = ux_ref[...]
    ly = ly_ref[...]
    uy = uy_ref[...]
    kx = kx_ref[...]
    ky = ky_ref[...]
    member = ((lx[:, None] < kx[None, :]) & (kx[None, :] <= ux[:, None]) &
              (ly[:, None] < ky[None, :]) & (ky[None, :] <= uy[:, None])
              ).astype(acc.dtype)
    ones = jnp.ones((member.shape[1],), acc.dtype)
    acc[...] += jnp.dot(member, ones, preferred_element_type=acc.dtype)

    @pl.when(d == n_tiles - 1)
    def _finalize():
        out_ref[...] = acc[...]


def delta_count2d_pallas(lx, ux, ly, uy, keys_x, keys_y,
                         bq: int = DEFAULT_BQ, bd: int = DEFAULT_BH,
                         interpret: bool = True, dtype=None):
    """Exact count of buffered points in (lx, ux] x (ly, uy] per query."""
    Q, D = lx.shape[0], keys_x.shape[0]
    bd = min(bd, D)
    assert Q % bq == 0 and D % bd == 0, (Q, D, bq, bd)
    dtype = dtype or keys_x.dtype
    n_tiles = D // bd
    kernel = functools.partial(_delta_count2d_kernel, n_tiles=n_tiles)
    return pl.pallas_call(
        kernel,
        grid=(Q // bq, n_tiles),
        in_specs=[
            pl.BlockSpec((bq,), lambda i, j: (i,)),
            pl.BlockSpec((bq,), lambda i, j: (i,)),
            pl.BlockSpec((bq,), lambda i, j: (i,)),
            pl.BlockSpec((bq,), lambda i, j: (i,)),
            pl.BlockSpec((bd,), lambda i, j: (j,)),
            pl.BlockSpec((bd,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bq,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((Q,), dtype),
        scratch_shapes=[pltpu.VMEM((bq,), dtype)],
        interpret=interpret,
    )(lx, ux, ly, uy, keys_x, keys_y)
