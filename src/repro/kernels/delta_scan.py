"""Pallas TPU kernels: exact delta-buffer scans for dynamic plans.

A ``DynamicEngine`` (engine/dynamic.py) buffers inserts/deletes in fixed-
capacity, sentinel-padded device arrays between merges.  Queries fuse the
static plan's approximation with an *exact* correction over the buffer, so
the certified error bounds survive updates: the only approximation error
left is the static plan's own E(I) <= delta.

All three kernels reuse the one-hot membership matmul pattern of
``poly_eval.py``/``range_sum.py`` — membership of each buffered key in each
query range is a (BQ, BD) compare tile, turned into a gathered reduction on
the MXU (SUM/COUNT) or a masked VPU max (MAX/MIN), accumulated across
buffer tiles in VMEM scratch:

* ``delta_sum_pallas``     — sum of buffered measures with key in (lq, uq]
                             (the CF-difference range of Eq. 5);
* ``delta_max_pallas``     — max of buffered measures with key in [lq, uq]
                             (MAX range semantics; -inf on empty);
* ``delta_count2d_pallas`` — count of buffered points in the half-open
                             rectangle (lx, ux] x (ly, uy] (Eq. 19).

Empty buffer slots hold a huge-but-finite sentinel key (``plan.big_sentinel``)
so they fail every membership test without needing a separate count input —
the kernels are oblivious to the fill level.

The ``*_gather_pallas`` variants are the O(Q*log D) locate->gather rewrites
(DESIGN.md §10) the engine's ``pallas`` backend uses (the scans above stay
available as ``pallas_scan``).  They exploit structure the buffers already
maintain on append (engine/dynamic.py):

* SUM — the log is sorted, so an exclusive prefix-sum array turns the
  correction into two binary searches and a subtraction;
* MAX — a sparse table over the sorted log answers the located span in
  O(1) (two gathers), exactly like interior segments in range_max;
* 2-D COUNT — per-level block-sorted y arrays (the merge-sort-tree layout
  of ``core.index2d``) answer each corner's dominance count in O(log^2 D).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.index2d import mst_count_prefix, mst_weighted_prefix
from .locate import bsearch_count, rmq_gather
from .poly_eval import DEFAULT_BH, DEFAULT_BQ

__all__ = ["delta_sum_pallas", "delta_max_pallas", "delta_count2d_pallas",
           "delta_sum_gather_pallas", "delta_max_gather_pallas",
           "delta_count2d_gather_pallas", "delta_sum2d_pallas",
           "delta_sum2d_gather_pallas", "delta_dommax2d_pallas",
           "delta_dommax2d_gather_pallas"]


def _delta_sum_kernel(lq_ref, uq_ref, k_ref, v_ref, out_ref, acc,
                      *, n_tiles: int):
    d = pl.program_id(1)

    @pl.when(d == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    lq = lq_ref[...]
    uq = uq_ref[...]
    k = k_ref[...]
    v = v_ref[...]
    # (BQ, BD) membership in (lq, uq]; sentinel-padded slots never match
    member = ((lq[:, None] < k[None, :]) &
              (k[None, :] <= uq[:, None])).astype(v.dtype)
    acc[...] += jnp.dot(member, v, preferred_element_type=v.dtype)

    @pl.when(d == n_tiles - 1)
    def _finalize():
        out_ref[...] = acc[...]


def delta_sum_pallas(lq, uq, keys, vals, bq: int = DEFAULT_BQ,
                     bd: int = DEFAULT_BH, interpret: bool = True):
    """Exact sum of buffered measures with key in (lq, uq] per query."""
    Q, D = lq.shape[0], keys.shape[0]
    bd = min(bd, D)
    assert Q % bq == 0 and D % bd == 0, (Q, D, bq, bd)
    n_tiles = D // bd
    kernel = functools.partial(_delta_sum_kernel, n_tiles=n_tiles)
    return pl.pallas_call(
        kernel,
        grid=(Q // bq, n_tiles),
        in_specs=[
            pl.BlockSpec((bq,), lambda i, j: (i,)),
            pl.BlockSpec((bq,), lambda i, j: (i,)),
            pl.BlockSpec((bd,), lambda i, j: (j,)),
            pl.BlockSpec((bd,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bq,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((Q,), vals.dtype),
        scratch_shapes=[pltpu.VMEM((bq,), vals.dtype)],
        interpret=interpret,
    )(lq, uq, keys, vals)


def _delta_sum_gather_kernel(lq_ref, uq_ref, k_ref, cf_ref, out_ref):
    k = k_ref[...]
    cf = cf_ref[...]
    # membership (lq, uq]: prefix sums at the "# keys <= q" counts
    cu = bsearch_count(k, uq_ref[...], side="right")
    cl = bsearch_count(k, lq_ref[...], side="right")
    out_ref[...] = jnp.take(cf, cu) - jnp.take(cf, cl)


def delta_sum_gather_pallas(lq, uq, keys, cf, bq: int = DEFAULT_BQ,
                            interpret: bool = True):
    """Exact sum of buffered measures with key in (lq, uq] via the buffer's
    exclusive prefix-sum array ``cf`` ((D+1,), cf[i] = sum(vals[:i]),
    maintained on append): two O(log D) binary searches + a subtraction."""
    Q, D = lq.shape[0], keys.shape[0]
    assert Q % bq == 0 and cf.shape[0] == D + 1, (Q, bq, cf.shape, D)
    return pl.pallas_call(
        _delta_sum_gather_kernel,
        grid=(Q // bq,),
        in_specs=[
            pl.BlockSpec((bq,), lambda i: (i,)),
            pl.BlockSpec((bq,), lambda i: (i,)),
            pl.BlockSpec((D,), lambda i: (0,)),
            pl.BlockSpec((D + 1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bq,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((Q,), cf.dtype),
        interpret=interpret,
    )(lq, uq, keys, cf)


def _delta_max_kernel(lq_ref, uq_ref, k_ref, v_ref, out_ref, acc,
                      *, n_tiles: int):
    d = pl.program_id(1)

    @pl.when(d == 0)
    def _init():
        acc[...] = jnp.full_like(acc, -jnp.inf)

    lq = lq_ref[...]
    uq = uq_ref[...]
    k = k_ref[...]
    v = v_ref[...]
    member = (lq[:, None] <= k[None, :]) & (k[None, :] <= uq[:, None])
    tile_max = jnp.max(jnp.where(member, v[None, :], -jnp.inf), axis=1)
    acc[...] = jnp.maximum(acc[...], tile_max)

    @pl.when(d == n_tiles - 1)
    def _finalize():
        out_ref[...] = acc[...]


def delta_max_pallas(lq, uq, keys, vals, bq: int = DEFAULT_BQ,
                     bd: int = DEFAULT_BH, interpret: bool = True):
    """Exact max of buffered measures with key in [lq, uq] (-inf if none)."""
    Q, D = lq.shape[0], keys.shape[0]
    bd = min(bd, D)
    assert Q % bq == 0 and D % bd == 0, (Q, D, bq, bd)
    n_tiles = D // bd
    kernel = functools.partial(_delta_max_kernel, n_tiles=n_tiles)
    return pl.pallas_call(
        kernel,
        grid=(Q // bq, n_tiles),
        in_specs=[
            pl.BlockSpec((bq,), lambda i, j: (i,)),
            pl.BlockSpec((bq,), lambda i, j: (i,)),
            pl.BlockSpec((bd,), lambda i, j: (j,)),
            pl.BlockSpec((bd,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bq,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((Q,), vals.dtype),
        scratch_shapes=[pltpu.VMEM((bq,), vals.dtype)],
        interpret=interpret,
    )(lq, uq, keys, vals)


def _delta_max_gather_kernel(lq_ref, uq_ref, k_ref, st_ref, out_ref):
    k = k_ref[...]
    # membership [lq, uq]: the sorted log's covered span is [i0, i1)
    i0 = bsearch_count(k, lq_ref[...], side="left")
    i1 = bsearch_count(k, uq_ref[...], side="right")
    out_ref[...] = rmq_gather(st_ref[...], i0, i1)


def delta_max_gather_pallas(lq, uq, keys, st, bq: int = DEFAULT_BQ,
                            interpret: bool = True):
    """Exact max of buffered measures with key in [lq, uq] (-inf if none):
    locate the sorted log's covered span, then an O(1) two-gather RMQ
    against the buffer's sparse table (rebuilt on append)."""
    Q, D = lq.shape[0], keys.shape[0]
    assert Q % bq == 0 and st.shape[1] == D, (Q, bq, st.shape, D)
    levels = st.shape[0]
    return pl.pallas_call(
        _delta_max_gather_kernel,
        grid=(Q // bq,),
        in_specs=[
            pl.BlockSpec((bq,), lambda i: (i,)),
            pl.BlockSpec((bq,), lambda i: (i,)),
            pl.BlockSpec((D,), lambda i: (0,)),
            pl.BlockSpec((levels, D), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bq,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((Q,), st.dtype),
        interpret=interpret,
    )(lq, uq, keys, st)


def _delta_count2d_kernel(lx_ref, ux_ref, ly_ref, uy_ref, kx_ref, ky_ref,
                          out_ref, acc, *, n_tiles: int):
    d = pl.program_id(1)

    @pl.when(d == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    lx = lx_ref[...]
    ux = ux_ref[...]
    ly = ly_ref[...]
    uy = uy_ref[...]
    kx = kx_ref[...]
    ky = ky_ref[...]
    member = ((lx[:, None] < kx[None, :]) & (kx[None, :] <= ux[:, None]) &
              (ly[:, None] < ky[None, :]) & (ky[None, :] <= uy[:, None])
              ).astype(acc.dtype)
    ones = jnp.ones((member.shape[1],), acc.dtype)
    acc[...] += jnp.dot(member, ones, preferred_element_type=acc.dtype)

    @pl.when(d == n_tiles - 1)
    def _finalize():
        out_ref[...] = acc[...]


def delta_count2d_pallas(lx, ux, ly, uy, keys_x, keys_y,
                         bq: int = DEFAULT_BQ, bd: int = DEFAULT_BH,
                         interpret: bool = True, dtype=None):
    """Exact count of buffered points in (lx, ux] x (ly, uy] per query."""
    Q, D = lx.shape[0], keys_x.shape[0]
    bd = min(bd, D)
    assert Q % bq == 0 and D % bd == 0, (Q, D, bq, bd)
    dtype = dtype or keys_x.dtype
    n_tiles = D // bd
    kernel = functools.partial(_delta_count2d_kernel, n_tiles=n_tiles)
    return pl.pallas_call(
        kernel,
        grid=(Q // bq, n_tiles),
        in_specs=[
            pl.BlockSpec((bq,), lambda i, j: (i,)),
            pl.BlockSpec((bq,), lambda i, j: (i,)),
            pl.BlockSpec((bq,), lambda i, j: (i,)),
            pl.BlockSpec((bq,), lambda i, j: (i,)),
            pl.BlockSpec((bd,), lambda i, j: (j,)),
            pl.BlockSpec((bd,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bq,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((Q,), dtype),
        scratch_shapes=[pltpu.VMEM((bq,), dtype)],
        interpret=interpret,
    )(lx, ux, ly, uy, keys_x, keys_y)


def _delta_count2d_gather_kernel(lx_ref, ux_ref, ly_ref, uy_ref,
                                 kx_ref, ylv_ref, out_ref, *, dtype):
    kx = kx_ref[...]
    ylv = ylv_ref[...]

    def cf(x, y):
        # dominance count #(px <= x & py <= y): x-prefix by binary search,
        # then the merge-sort-tree prefix count (same op sequence as the
        # exact-refinement path in core.index2d)
        i = bsearch_count(kx, x, side="right")
        return mst_count_prefix(kx, ylv, i, y).astype(dtype)

    lx, ux, ly, uy = lx_ref[...], ux_ref[...], ly_ref[...], uy_ref[...]
    out_ref[...] = cf(ux, uy) - cf(lx, uy) - cf(ux, ly) + cf(lx, ly)


def delta_count2d_gather_pallas(lx, ux, ly, uy, keys_x, ys_levels,
                                bq: int = DEFAULT_BQ, interpret: bool = True,
                                dtype=None):
    """Exact count of buffered points in (lx, ux] x (ly, uy] per query in
    O(log^2 D): the buffer is x-sorted and ``ys_levels`` ((L, D), level l =
    y values sorted within blocks of 2^l, rebuilt on append) decomposes any
    x-prefix into <= L sorted blocks, each answered by a binary search —
    the merge-sort-tree scheme of core.index2d applied to the delta log."""
    Q, D = lx.shape[0], keys_x.shape[0]
    assert Q % bq == 0 and ys_levels.shape[1] == D, (Q, bq, ys_levels.shape)
    dtype = dtype or keys_x.dtype
    levels = ys_levels.shape[0]
    kernel = functools.partial(_delta_count2d_gather_kernel, dtype=dtype)
    return pl.pallas_call(
        kernel,
        grid=(Q // bq,),
        in_specs=[
            pl.BlockSpec((bq,), lambda i: (i,)),
            pl.BlockSpec((bq,), lambda i: (i,)),
            pl.BlockSpec((bq,), lambda i: (i,)),
            pl.BlockSpec((bq,), lambda i: (i,)),
            pl.BlockSpec((D,), lambda i: (0,)),
            pl.BlockSpec((levels, D), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bq,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((Q,), dtype),
        interpret=interpret,
    )(lx, ux, ly, uy, keys_x, ys_levels)


def _delta_sum2d_kernel(lx_ref, ux_ref, ly_ref, uy_ref, kx_ref, ky_ref,
                        w_ref, out_ref, acc, *, n_tiles: int):
    d = pl.program_id(1)

    @pl.when(d == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    lx = lx_ref[...]
    ux = ux_ref[...]
    ly = ly_ref[...]
    uy = uy_ref[...]
    kx = kx_ref[...]
    ky = ky_ref[...]
    w = w_ref[...]
    member = ((lx[:, None] < kx[None, :]) & (kx[None, :] <= ux[:, None]) &
              (ly[:, None] < ky[None, :]) & (ky[None, :] <= uy[:, None])
              ).astype(w.dtype)
    acc[...] += jnp.dot(member, w, preferred_element_type=w.dtype)

    @pl.when(d == n_tiles - 1)
    def _finalize():
        out_ref[...] = acc[...]


def delta_sum2d_pallas(lx, ux, ly, uy, keys_x, keys_y, wv,
                       bq: int = DEFAULT_BQ, bd: int = DEFAULT_BH,
                       interpret: bool = True):
    """Exact sum of buffered measures over points in (lx, ux] x (ly, uy]
    per query (the weighted twin of ``delta_count2d_pallas``)."""
    Q, D = lx.shape[0], keys_x.shape[0]
    bd = min(bd, D)
    assert Q % bq == 0 and D % bd == 0, (Q, D, bq, bd)
    n_tiles = D // bd
    kernel = functools.partial(_delta_sum2d_kernel, n_tiles=n_tiles)
    return pl.pallas_call(
        kernel,
        grid=(Q // bq, n_tiles),
        in_specs=[
            pl.BlockSpec((bq,), lambda i, j: (i,)),
            pl.BlockSpec((bq,), lambda i, j: (i,)),
            pl.BlockSpec((bq,), lambda i, j: (i,)),
            pl.BlockSpec((bq,), lambda i, j: (i,)),
            pl.BlockSpec((bd,), lambda i, j: (j,)),
            pl.BlockSpec((bd,), lambda i, j: (j,)),
            pl.BlockSpec((bd,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bq,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((Q,), wv.dtype),
        scratch_shapes=[pltpu.VMEM((bq,), wv.dtype)],
        interpret=interpret,
    )(lx, ux, ly, uy, keys_x, keys_y, wv)


def _delta_sum2d_gather_kernel(lx_ref, ux_ref, ly_ref, uy_ref,
                               kx_ref, ylv_ref, wcum_ref, out_ref):
    kx = kx_ref[...]
    ylv = ylv_ref[...]
    wcum = wcum_ref[...]

    def cf(x, y):
        i = bsearch_count(kx, x, side="right")
        return mst_weighted_prefix(kx, ylv, wcum, i, y, mode="sum")

    lx, ux, ly, uy = lx_ref[...], ux_ref[...], ly_ref[...], uy_ref[...]
    out_ref[...] = cf(ux, uy) - cf(lx, uy) - cf(ux, ly) + cf(lx, ly)


def delta_sum2d_gather_pallas(lx, ux, ly, uy, keys_x, ys_levels, wcum_levels,
                              bq: int = DEFAULT_BQ, interpret: bool = True):
    """Exact sum of buffered measures over (lx, ux] x (ly, uy] in
    O(log^2 D): the weighted merge-sort-tree correction — per-level
    block-sorted y arrays plus per-block inclusive weight prefix sums,
    both rebuilt on append (engine/dynamic.py)."""
    Q, D = lx.shape[0], keys_x.shape[0]
    assert Q % bq == 0 and ys_levels.shape[1] == D, (Q, bq, ys_levels.shape)
    levels = ys_levels.shape[0]
    return pl.pallas_call(
        _delta_sum2d_gather_kernel,
        grid=(Q // bq,),
        in_specs=[
            pl.BlockSpec((bq,), lambda i: (i,)),
            pl.BlockSpec((bq,), lambda i: (i,)),
            pl.BlockSpec((bq,), lambda i: (i,)),
            pl.BlockSpec((bq,), lambda i: (i,)),
            pl.BlockSpec((D,), lambda i: (0,)),
            pl.BlockSpec((levels, D), lambda i: (0, 0)),
            pl.BlockSpec((levels, D), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bq,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((Q,), wcum_levels.dtype),
        interpret=interpret,
    )(lx, ux, ly, uy, keys_x, ys_levels, wcum_levels)


def _delta_dommax2d_kernel(u_ref, v_ref, kx_ref, ky_ref, w_ref, out_ref,
                           acc, *, n_tiles: int):
    d = pl.program_id(1)

    @pl.when(d == 0)
    def _init():
        acc[...] = jnp.full_like(acc, -jnp.inf)

    u = u_ref[...]
    v = v_ref[...]
    kx = kx_ref[...]
    ky = ky_ref[...]
    w = w_ref[...]
    member = (kx[None, :] <= u[:, None]) & (ky[None, :] <= v[:, None])
    tile_max = jnp.max(jnp.where(member, w[None, :], -jnp.inf), axis=1)
    acc[...] = jnp.maximum(acc[...], tile_max)

    @pl.when(d == n_tiles - 1)
    def _finalize():
        out_ref[...] = acc[...]


def delta_dommax2d_pallas(u, v, keys_x, keys_y, wv, bq: int = DEFAULT_BQ,
                          bd: int = DEFAULT_BH, interpret: bool = True):
    """Exact dominance max of buffered measures over {x <= u, y <= v} per
    query corner (-inf if none dominated)."""
    Q, D = u.shape[0], keys_x.shape[0]
    bd = min(bd, D)
    assert Q % bq == 0 and D % bd == 0, (Q, D, bq, bd)
    n_tiles = D // bd
    kernel = functools.partial(_delta_dommax2d_kernel, n_tiles=n_tiles)
    return pl.pallas_call(
        kernel,
        grid=(Q // bq, n_tiles),
        in_specs=[
            pl.BlockSpec((bq,), lambda i, j: (i,)),
            pl.BlockSpec((bq,), lambda i, j: (i,)),
            pl.BlockSpec((bd,), lambda i, j: (j,)),
            pl.BlockSpec((bd,), lambda i, j: (j,)),
            pl.BlockSpec((bd,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bq,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((Q,), wv.dtype),
        scratch_shapes=[pltpu.VMEM((bq,), wv.dtype)],
        interpret=interpret,
    )(u, v, keys_x, keys_y, wv)


def _delta_dommax2d_gather_kernel(u_ref, v_ref, kx_ref, ylv_ref, wpmax_ref,
                                  out_ref):
    kx = kx_ref[...]
    i = bsearch_count(kx, u_ref[...], side="right")
    out_ref[...] = mst_weighted_prefix(kx, ylv_ref[...], wpmax_ref[...], i,
                                       v_ref[...], mode="max")


def delta_dommax2d_gather_pallas(u, v, keys_x, ys_levels, wpmax_levels,
                                 bq: int = DEFAULT_BQ,
                                 interpret: bool = True):
    """Exact dominance max over {x <= u, y <= v} in O(log^2 D): the
    merge-sort-tree decomposition with per-block inclusive prefix *maxima*
    instead of prefix sums."""
    Q, D = u.shape[0], keys_x.shape[0]
    assert Q % bq == 0 and ys_levels.shape[1] == D, (Q, bq, ys_levels.shape)
    levels = ys_levels.shape[0]
    return pl.pallas_call(
        _delta_dommax2d_gather_kernel,
        grid=(Q // bq,),
        in_specs=[
            pl.BlockSpec((bq,), lambda i: (i,)),
            pl.BlockSpec((bq,), lambda i: (i,)),
            pl.BlockSpec((D,), lambda i: (0,)),
            pl.BlockSpec((levels, D), lambda i: (0, 0)),
            pl.BlockSpec((levels, D), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bq,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((Q,), wpmax_levels.dtype),
        interpret=interpret,
    )(u, v, keys_x, ys_levels, wpmax_levels)
