"""Pallas TPU kernel: fused range-MAX query evaluation (Eq. 17).

Per (query-block x segment-tile) step, three contributions accumulate:

* left/right boundary segments — resolved with the same one-hot matmul as
  range_sum (coefficients + scale bounds gathered on the MXU);
* interior segments — the aR-tree traversal is replaced by a dense masked
  reduction: a segment j is strictly interior iff seg_lo[j] > lq and
  seg_next[j] <= uq, both locally decidable, so the tile contributes
  rowmax(where(mask, seg_agg, -inf)) — branch-free VPU work (DESIGN.md §3).

Finalization computes each boundary polynomial's max over its clipped
interval via closed-form zero-derivative points (P' quadratic for deg <= 3,
the paper's recommended MAX degree; higher degrees use the XLA path in
core.queries).  MIN is served by the same kernel on negated aggregates.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.poly import clipped_poly_max
from .poly_eval import DEFAULT_BH, DEFAULT_BQ

__all__ = ["range_max_pallas"]

_NEG = -jnp.inf


def _range_max_kernel(lq_ref, uq_ref, lo_ref, nxt_ref, hi_ref, coef_ref,
                      agg_ref, out_ref, acc, acc_int, *, n_tiles: int, deg: int):
    """acc: (BQ, 2*(deg+3)) boundary gather; acc_int: (BQ,) interior max."""
    h = pl.program_id(1)
    ncol = deg + 3

    @pl.when(h == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        acc_int[...] = jnp.full_like(acc_int, _NEG)

    lq = lq_ref[...]
    uq = uq_ref[...]
    lo = lo_ref[...]
    nxt = nxt_ref[...]
    hi = hi_ref[...]
    coef = coef_ref[...]
    agg = agg_ref[...]
    table = jnp.concatenate([coef, lo[:, None], hi[:, None]], axis=1)

    for slot, q in ((0, lq), (1, uq)):
        one_hot = ((lo[None, :] <= q[:, None]) &
                   (q[:, None] < nxt[None, :])).astype(coef.dtype)
        acc[:, slot * ncol:(slot + 1) * ncol] += jnp.dot(
            one_hot, table, preferred_element_type=coef.dtype)

    # interior: strictly between the two boundary segments
    interior = ((lo[None, :] > lq[:, None]) &
                (nxt[None, :] <= uq[:, None]))                    # (BQ, BH)
    tile_max = jnp.max(jnp.where(interior, agg[None, :], _NEG), axis=1)
    acc_int[...] = jnp.maximum(acc_int[...], tile_max)

    @pl.when(h == n_tiles - 1)
    def _finalize():
        cl = acc[:, 0:deg + 1]
        slo_l = acc[:, deg + 1]
        shi_l = acc[:, deg + 2]
        cu = acc[:, ncol:ncol + deg + 1]
        slo_u = acc[:, ncol + deg + 1]
        shi_u = acc[:, ncol + deg + 2]
        same = (slo_l == slo_u) & (shi_l == shi_u)
        # left boundary: [lq, min(hi_l, uq)], suppressed when lq past hi_l
        m_left = clipped_poly_max(cl, slo_l, shi_l, lq, jnp.minimum(shi_l, uq))
        m_left = jnp.where(lq <= shi_l, m_left, _NEG)
        # right boundary: [max(lo_u, lq), uq], suppressed when same segment
        m_right = clipped_poly_max(cu, slo_u, shi_u, jnp.maximum(slo_u, lq), uq)
        m_right = jnp.where(same, _NEG, m_right)
        out_ref[...] = jnp.maximum(jnp.maximum(m_left, m_right), acc_int[...])


def range_max_pallas(lq, uq, seg_lo, seg_next, seg_hi, coeffs, seg_agg,
                     bq: int = DEFAULT_BQ, bh: int = DEFAULT_BH,
                     interpret: bool = True):
    Q, H = lq.shape[0], seg_lo.shape[0]
    assert Q % bq == 0 and H % bh == 0, (Q, H, bq, bh)
    deg = coeffs.shape[1] - 1
    assert deg <= 3, "in-kernel closed forms cover deg<=3 (paper's MAX range)"
    n_tiles = H // bh
    kernel = functools.partial(_range_max_kernel, n_tiles=n_tiles, deg=deg)
    return pl.pallas_call(
        kernel,
        grid=(Q // bq, n_tiles),
        in_specs=[
            pl.BlockSpec((bq,), lambda i, j: (i,)),
            pl.BlockSpec((bq,), lambda i, j: (i,)),
            pl.BlockSpec((bh,), lambda i, j: (j,)),
            pl.BlockSpec((bh,), lambda i, j: (j,)),
            pl.BlockSpec((bh,), lambda i, j: (j,)),
            pl.BlockSpec((bh, deg + 1), lambda i, j: (j, 0)),
            pl.BlockSpec((bh,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bq,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((Q,), coeffs.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 2 * (deg + 3)), coeffs.dtype),
            pltpu.VMEM((bq,), coeffs.dtype),
        ],
        interpret=interpret,
    )(lq, uq, seg_lo, seg_next, seg_hi, coeffs, seg_agg)
