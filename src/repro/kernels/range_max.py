"""Pallas TPU kernels: fused range-MAX query evaluation (Eq. 17).

* ``range_max_gather_pallas`` — locate->gather (DESIGN.md §10, the engine's
  ``pallas`` backend): both boundary segments are located with the
  branch-free binary search of ``locate.py`` (O(log H)), their coefficient
  rows gathered, and the strictly-interior span (il, iu) answered in O(1)
  with two gathers against the plan's per-segment sparse table — the same
  two-window RMQ the XLA backend uses, so no scan over seg_agg remains.
* ``range_max_pallas`` — the original one-hot membership scan (the
  ``pallas_scan`` backend): boundary rows via MXU matmul, interior via a
  dense masked reduction over every resident tile — O(Q*H).

Both compute boundary extrema with ``core.poly.clipped_poly_max``
(closed-form zero-derivative points, deg <= 3 — the paper's recommended
MAX degree; higher degrees use the XLA path in core.queries), and MIN is
served on negated aggregates, so answers are bit-identical across paths.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.poly import clipped_poly_max
from .locate import locate_segments, rmq_gather
from .poly_eval import DEFAULT_BH, DEFAULT_BQ

__all__ = ["range_max_pallas", "range_max_gather_pallas"]

_NEG = -jnp.inf


def _range_max_gather_kernel(lq_ref, uq_ref, lo_ref, hi_ref, coef_ref,
                             st_ref, out_ref):
    lq = lq_ref[...]
    uq = uq_ref[...]
    lo = lo_ref[...]
    hi = hi_ref[...]
    coef = coef_ref[...]
    il = locate_segments(lo, lq)
    iu = locate_segments(lo, uq)
    lo_l, hi_l = jnp.take(lo, il), jnp.take(hi, il)
    lo_u, hi_u = jnp.take(lo, iu), jnp.take(hi, iu)
    cl = jnp.take(coef, il, axis=0)
    cu = jnp.take(coef, iu, axis=0)
    same = il == iu
    # left boundary: [lq, min(hi_l, uq)], suppressed when lq past hi_l
    m_left = clipped_poly_max(cl, lo_l, hi_l, lq, jnp.minimum(hi_l, uq))
    m_left = jnp.where(lq <= hi_l, m_left, _NEG)
    # right boundary: [max(lo_u, lq), uq], suppressed when same segment
    m_right = clipped_poly_max(cu, lo_u, hi_u, jnp.maximum(lo_u, lq), uq)
    m_right = jnp.where(same, _NEG, m_right)
    # interior segments are exactly (il, iu): seg_lo[j] > lq <=> j > il and
    # seg_next[j] <= uq <=> j < iu — an O(1) sparse-table range max
    m_int = rmq_gather(st_ref[...], il + 1, iu)
    out_ref[...] = jnp.maximum(jnp.maximum(m_left, m_right), m_int)


def range_max_gather_pallas(lq, uq, seg_lo, seg_hi, coeffs, st,
                            bq: int = DEFAULT_BQ, interpret: bool = True):
    """Locate->gather range MAX; ``st`` is the plan's (L, h) sparse table
    over per-segment aggregates (unpadded — in-domain queries never locate
    the sentinel tail)."""
    Q, H = lq.shape[0], seg_lo.shape[0]
    assert Q % bq == 0, (Q, bq)
    deg = coeffs.shape[1] - 1
    assert deg <= 3, "in-kernel closed forms cover deg<=3 (paper's MAX range)"
    # monotone cast: per-entry rounding commutes with max, so an f32 table
    # sees exactly the f32 per-segment aggregates the one-hot path scans
    st = st.astype(coeffs.dtype)
    levels, h = st.shape
    return pl.pallas_call(
        _range_max_gather_kernel,
        grid=(Q // bq,),
        in_specs=[
            pl.BlockSpec((bq,), lambda i: (i,)),
            pl.BlockSpec((bq,), lambda i: (i,)),
            pl.BlockSpec((H,), lambda i: (0,)),
            pl.BlockSpec((H,), lambda i: (0,)),
            pl.BlockSpec((H, deg + 1), lambda i: (0, 0)),
            pl.BlockSpec((levels, h), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bq,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((Q,), coeffs.dtype),
        interpret=interpret,
    )(lq, uq, seg_lo, seg_hi, coeffs, st)


def _range_max_kernel(lq_ref, uq_ref, lo_ref, nxt_ref, hi_ref, coef_ref,
                      agg_ref, out_ref, acc, acc_int, *, n_tiles: int, deg: int):
    """acc: (BQ, 2*(deg+3)) boundary gather; acc_int: (BQ,) interior max."""
    h = pl.program_id(1)
    ncol = deg + 3

    @pl.when(h == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        acc_int[...] = jnp.full_like(acc_int, _NEG)

    lq = lq_ref[...]
    uq = uq_ref[...]
    lo = lo_ref[...]
    nxt = nxt_ref[...]
    hi = hi_ref[...]
    coef = coef_ref[...]
    agg = agg_ref[...]
    table = jnp.concatenate([coef, lo[:, None], hi[:, None]], axis=1)

    for slot, q in ((0, lq), (1, uq)):
        one_hot = ((lo[None, :] <= q[:, None]) &
                   (q[:, None] < nxt[None, :])).astype(coef.dtype)
        acc[:, slot * ncol:(slot + 1) * ncol] += jnp.dot(
            one_hot, table, preferred_element_type=coef.dtype)

    # interior: strictly between the two boundary segments
    interior = ((lo[None, :] > lq[:, None]) &
                (nxt[None, :] <= uq[:, None]))                    # (BQ, BH)
    tile_max = jnp.max(jnp.where(interior, agg[None, :], _NEG), axis=1)
    acc_int[...] = jnp.maximum(acc_int[...], tile_max)

    @pl.when(h == n_tiles - 1)
    def _finalize():
        cl = acc[:, 0:deg + 1]
        slo_l = acc[:, deg + 1]
        shi_l = acc[:, deg + 2]
        cu = acc[:, ncol:ncol + deg + 1]
        slo_u = acc[:, ncol + deg + 1]
        shi_u = acc[:, ncol + deg + 2]
        same = (slo_l == slo_u) & (shi_l == shi_u)
        # left boundary: [lq, min(hi_l, uq)], suppressed when lq past hi_l
        m_left = clipped_poly_max(cl, slo_l, shi_l, lq, jnp.minimum(shi_l, uq))
        m_left = jnp.where(lq <= shi_l, m_left, _NEG)
        # right boundary: [max(lo_u, lq), uq], suppressed when same segment
        m_right = clipped_poly_max(cu, slo_u, shi_u, jnp.maximum(slo_u, lq), uq)
        m_right = jnp.where(same, _NEG, m_right)
        out_ref[...] = jnp.maximum(jnp.maximum(m_left, m_right), acc_int[...])


def range_max_pallas(lq, uq, seg_lo, seg_next, seg_hi, coeffs, seg_agg,
                     bq: int = DEFAULT_BQ, bh: int = DEFAULT_BH,
                     interpret: bool = True):
    Q, H = lq.shape[0], seg_lo.shape[0]
    assert Q % bq == 0 and H % bh == 0, (Q, H, bq, bh)
    deg = coeffs.shape[1] - 1
    assert deg <= 3, "in-kernel closed forms cover deg<=3 (paper's MAX range)"
    n_tiles = H // bh
    kernel = functools.partial(_range_max_kernel, n_tiles=n_tiles, deg=deg)
    return pl.pallas_call(
        kernel,
        grid=(Q // bq, n_tiles),
        in_specs=[
            pl.BlockSpec((bq,), lambda i, j: (i,)),
            pl.BlockSpec((bq,), lambda i, j: (i,)),
            pl.BlockSpec((bh,), lambda i, j: (j,)),
            pl.BlockSpec((bh,), lambda i, j: (j,)),
            pl.BlockSpec((bh,), lambda i, j: (j,)),
            pl.BlockSpec((bh, deg + 1), lambda i, j: (j, 0)),
            pl.BlockSpec((bh,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bq,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((Q,), coeffs.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 2 * (deg + 3)), coeffs.dtype),
            pltpu.VMEM((bq,), coeffs.dtype),
        ],
        interpret=interpret,
    )(lq, uq, seg_lo, seg_next, seg_hi, coeffs, seg_agg)
