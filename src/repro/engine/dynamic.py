"""Dynamic PolyFit: delta-buffered inserts/deletes with selective refit
(DESIGN.md §9).

A static ``IndexPlan`` freezes the fitted key array; absorbing one new point
used to mean rebuilding the whole plan.  ``DynamicEngine`` makes plans
updatable while keeping every certified bound:

* **Delta buffers** — fixed-capacity, device-resident, sentinel-padded
  arrays (a sorted insert log and delete tombstones), registered as pytree
  leaves so the fused query paths stay jittable with one compilation per
  (aggregate, backend, batch-bucket, capacity).
* **Fused exact correction** — every query executes the static plan's
  backend-dispatched approximation *and* an exact delta scan
  (``kernels/delta_scan.py``; one-hot membership matmul, like the segment
  kernels) in a single jitted executor.  The only approximation error left
  is the static plan's own E(I) <= delta, so Lemmas 5.1-5.4/6.3-6.4 hold
  verbatim over the updated dataset (the buffered contribution is exact).
* **Selective refit** — when the buffer fills, or a segment's accumulated
  |measure| drift exceeds its error headroom (delta - E(I)), a merge pass
  re-fits *only* the segments whose spans contain changed keys
  (``core.segmentation.greedy_segmentation`` on the affected windows);
  clean SUM/COUNT segments absorb the CF shift of upstream edits as a
  constant-coefficient bump (adding c to F adds c to the fitted P exactly,
  leaving E(I) unchanged), and clean MAX/MIN segments are untouched.  The
  merged index is assembled (``core.index.assemble_index_1d``) and the new
  plan is installed atomically — plans are immutable pytrees, so queries
  already in flight keep the old plan and are never blocked; with
  ``background=True`` the merge itself runs on a worker thread and only the
  final pointer swap takes the lock.

MAX/MIN deletes cannot be folded into a monotone max correction (the
deleted point may *be* the maximum), so they shadow their victim instead:
the buffer carries the victim keys plus a victim-masked exact sparse
table (``vic_keys``/``live_st``), queries whose range covers a victim
refine to the exact live answer, and the actual removal waits for the
next capacity-triggered merge — no delete ever forces an eager refit
(``engine.lsm`` applies the same scheme per level).  SUM/COUNT deletes
ride the tombstone buffer like inserts.

``DynamicEngine2D`` applies the same buffering + fused-correction scheme
to 2-key COUNT/SUM/dominance-MAX/MIN plans; its merge runs
``core.index2d.selective_refit_2d`` over the touched leaves only.
"""
from __future__ import annotations

import dataclasses
import threading
from functools import partial
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.exact import build_sparse_table, sparse_table_range_max
from ..core.fitting import PolyModel, fit_minimax_lp
from ..core.index import PolyFitIndex1D, _continuum_post, assemble_index_1d
from ..core.index2d import (MergeSortTree, PolyFitIndex2D, mst_dommax,
                            selective_refit_2d)
from ..core.queries import QueryResult
from ..core.segmentation import FastAcceptFitter, greedy_segmentation
from ..kernels import ref as _ref
from ..kernels.delta_scan import (delta_count2d_gather_pallas,
                                  delta_count2d_pallas,
                                  delta_dommax2d_gather_pallas,
                                  delta_dommax2d_pallas,
                                  delta_max_gather_pallas, delta_max_pallas,
                                  delta_sum2d_gather_pallas,
                                  delta_sum2d_pallas,
                                  delta_sum_gather_pallas, delta_sum_pallas)
from ..core.poly import horner
from ..core.quantile import boundary_array, invert_cf, rank_slack
from ..kernels.poly_eval import DEFAULT_BQ
from .engine import (QuantileResult, _bucket_size, _pad_bucket, check_pow2,
                     raw_count2d, raw_eval2d, raw_extremum, raw_sum,
                     truth_count2d, truth_dommax2d, truth_extremum,
                     truth_sum, truth_sum2d)
from .plan import (IndexPlan, IndexPlan2D, big_sentinel, build_plan,
                   build_plan_2d, pad_to_multiple)

__all__ = ["DeltaBuffer", "DeltaBuffer2D", "DynamicEngine",
           "DynamicEngine2D", "fused_executor", "fused_quantile_executor"]


# ---------------------------------------------------------------------------
# device-resident delta buffers (pytree-registered, fixed capacity)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DeltaBuffer:
    """Sorted insert log + delete tombstones for a 1-D plan.

    Empty slots hold a huge-but-finite sentinel key (``big_sentinel``) so
    they fail every membership test inside the delta-scan kernels; the
    kernels never need the fill level.  Values live in *internal* space
    (negated for MIN plans, mirroring the static index).

    Appends also maintain the locate->gather correction structures
    (DESIGN.md §10): exclusive prefix sums over both logs (a buffered
    SUM/COUNT correction is then two binary searches + a subtraction) and,
    for MAX/MIN plans, a sparse table over the insert log (the located span
    answers in O(1)).  Sentinel slots carry value 0, so the prefix sums are
    flat across the tail and the structures are fill-level oblivious too.

    Extremal deletes shadow their victim instead of merging eagerly:
    ``vic_keys`` holds the (sentinel-padded, sorted) keys of deleted base
    rows and ``live_st`` a victim-masked exact sparse table over the base
    measures.  A query whose range covers a victim cannot trust the fitted
    approximation (the victim may *be* the maximum) and refines against
    ``live_st`` instead — exact, and no merge on the write path.  Both are
    ``None`` until the first extremal delete, keeping the no-victim trace
    bit-identical to the victim-free executor.
    """

    ins_keys: jnp.ndarray   # (cap,) sorted, sentinel-padded
    ins_vals: jnp.ndarray   # (cap,) measures; 0 on padding
    ins_cf: jnp.ndarray     # (cap+1,) exclusive prefix sum of ins_vals
    del_keys: jnp.ndarray   # (cap,) sorted, sentinel-padded
    del_vals: jnp.ndarray   # (cap,) tombstoned measures; 0 on padding
    del_cf: jnp.ndarray     # (cap+1,) exclusive prefix sum of del_vals
    ins_st: Optional[jnp.ndarray]   # (L, cap) sparse table (max/min only)
    cap: int
    vic_keys: Optional[jnp.ndarray] = None   # (vcap,) deleted base keys
    live_st: Optional[jnp.ndarray] = None    # (L, n) victim-masked exact ST

    @staticmethod
    def empty(cap: int, dtype=jnp.float64,
              with_st: bool = False) -> "DeltaBuffer":
        big = big_sentinel(dtype)
        s = jnp.full((cap,), big, dtype)
        z = jnp.zeros((cap,), dtype)
        cf = jnp.zeros((cap + 1,), dtype)
        st = (jnp.full((max(1, cap.bit_length()), cap), -jnp.inf, dtype)
              if with_st else None)
        return DeltaBuffer(s, z, cf, s, z, cf, st, cap)


jax.tree_util.register_dataclass(
    DeltaBuffer,
    data_fields=["ins_keys", "ins_vals", "ins_cf", "del_keys", "del_vals",
                 "del_cf", "ins_st", "vic_keys", "live_st"],
    meta_fields=["cap"],
)


@dataclasses.dataclass(frozen=True)
class DeltaBuffer2D:
    """Insert/delete point logs for a 2-key plan (x-sorted).

    ``*_ylv`` are merge-sort-tree level arrays (level l = y values sorted
    within blocks of 2^l of the x-order), rebuilt on append, so the
    locate->gather correction answers each corner's dominance count in
    O(log^2 cap) instead of scanning the log.

    Measure-carrying plans (sum2d/max2d/min2d) additionally log each
    point's measure (``*_w``, internal space — negated for min2d, 0 on
    sentinel padding) and, for the locate->gather backend, the weighted
    merge-sort-tree companions: per-block inclusive prefix sums
    (``*_wcum``) for the SUM correction and prefix maxima (``ins_wpmax``)
    for the dominance-MAX correction.  Extremal deletes never populate the
    delete log: they shadow base victims via ``vic_x``/``vic_y`` and the
    victim-masked exact tree ``live_wpmax`` (see ``DeltaBuffer``), so the
    delete log needs no max structure.
    """

    ins_x: jnp.ndarray
    ins_y: jnp.ndarray
    ins_ylv: jnp.ndarray    # (L, cap) per-level block-sorted y arrays
    del_x: jnp.ndarray
    del_y: jnp.ndarray
    del_ylv: jnp.ndarray    # (L, cap)
    cap: int
    # -- measure-carrying extension (sum2d/max2d/min2d plans) ------------
    ins_w: Optional[jnp.ndarray] = None      # (cap,) measures; 0 on padding
    del_w: Optional[jnp.ndarray] = None
    ins_wcum: Optional[jnp.ndarray] = None   # (L, cap) block prefix sums
    del_wcum: Optional[jnp.ndarray] = None
    ins_wpmax: Optional[jnp.ndarray] = None  # (L, cap) block prefix maxima
    vic_x: Optional[jnp.ndarray] = None      # (vcap,) deleted base points
    vic_y: Optional[jnp.ndarray] = None
    live_wpmax: Optional[jnp.ndarray] = None  # (L, n) victim-masked tree

    @staticmethod
    def empty(cap: int, dtype=jnp.float64,
              weighted: bool = False) -> "DeltaBuffer2D":
        big = big_sentinel(dtype)
        s = jnp.full((cap,), big, dtype)
        lv = jnp.full((max(1, cap.bit_length()), cap), big, dtype)
        if not weighted:
            return DeltaBuffer2D(s, s, lv, s, s, lv, cap)
        z = jnp.zeros((cap,), dtype)
        zlv = jnp.zeros((max(1, cap.bit_length()), cap), dtype)
        return DeltaBuffer2D(s, s, lv, s, s, lv, cap,
                             ins_w=z, del_w=z, ins_wcum=zlv, del_wcum=zlv,
                             ins_wpmax=zlv)


jax.tree_util.register_dataclass(
    DeltaBuffer2D,
    data_fields=["ins_x", "ins_y", "ins_ylv", "del_x", "del_y", "del_ylv",
                 "ins_w", "del_w", "ins_wcum", "del_wcum", "ins_wpmax",
                 "vic_x", "vic_y", "live_wpmax"],
    meta_fields=["cap"],
)


def _merge_sorted(cap: int, keys, vals, new_k, new_v):
    """Merge a (sentinel-padded) batch into the sorted log, keeping shape.

    Valid entries sort before the sentinels, so slicing back to ``cap``
    drops padding only (caller guarantees fill + batch <= cap).
    """
    k = jnp.concatenate([keys, new_k])
    v = jnp.concatenate([vals, new_v])
    order = jnp.argsort(k)   # stable: existing entries first on ties
    return k[order][:cap], v[order][:cap]


def _prefix_sum_jnp(vals):
    """Exclusive prefix-sum array ((cap+1,)) over the sorted log's values."""
    return jnp.concatenate([jnp.zeros((1,), vals.dtype), jnp.cumsum(vals)])


def _sparse_table_jnp(vals, *, cap: int):
    """(L, cap) sparse table over the sorted log (``build_sparse_table``
    semantics: st[j, i] = max(vals[i : i+2^j]), -inf past the end)."""
    rows = [vals]
    for j in range(1, max(1, cap.bit_length())):
        half = 1 << (j - 1)
        prev = rows[-1]
        shifted = jnp.concatenate(
            [prev[half:], jnp.full((half,), -jnp.inf, prev.dtype)])
        rows.append(jnp.maximum(prev, shifted))
    return jnp.stack(rows)


def _mst_levels_jnp(ys, *, cap: int):
    """(L, cap) merge-sort-tree levels of the x-sorted log's y values
    (level l = per-block sort with block size 2^l; level 0 = x order)."""
    rows = [ys]
    for l in range(1, max(1, cap.bit_length())):
        b = 1 << l
        rows.append(jnp.sort(ys.reshape(cap // b, b), axis=1).reshape(-1))
    return jnp.stack(rows)


def _mst_levels_w_jnp(ys, ws, *, cap: int):
    """Weighted merge-sort-tree levels of the x-sorted log: per-level
    block-sorted y arrays plus per-block inclusive weight prefix sums and
    prefix maxima (the structures ``mst_weighted_prefix`` consumes).
    Returns (ylv, wcum, wpmax), each (L, cap)."""
    ylv, wcum, wpmax = [ys], [ws], [ws]
    y, w = ys, ws
    for l in range(1, max(1, cap.bit_length())):
        b = 1 << l
        y2 = y.reshape(cap // b, b)
        perm = jnp.argsort(y2, axis=1)   # jax sorts are stable
        y2 = jnp.take_along_axis(y2, perm, axis=1)
        w2 = jnp.take_along_axis(w.reshape(cap // b, b), perm, axis=1)
        y, w = y2.reshape(-1), w2.reshape(-1)
        ylv.append(y)
        wcum.append(jnp.cumsum(w2, axis=1).reshape(-1))
        wpmax.append(jax.lax.cummax(w2, axis=1).reshape(-1))
    return jnp.stack(ylv), jnp.stack(wcum), jnp.stack(wpmax)


# The fused append executors: ONE jitted device dispatch per insert/delete
# chunk, rebuilding the sorted log and every derived correction structure
# (prefix sums, sparse table, merge-sort-tree levels) inside a single
# compilation.  The previous shape — one jitted call per structure, per
# batch — dispatched (and, on first use per backend, *compiled*) each helper
# separately; the measured ~480x `updates2d.insert.pallas` gap in
# BENCH_updates.json was exactly those un-warmed per-structure compilations
# landing on the timed path.  One fused executable per (cap, structure
# flags) also means chunked inserts amortize: appending a 1024-record chunk
# costs one dispatch, not eight 128-record ones.

@partial(jax.jit, static_argnames=("cap", "with_st"))
def _append_1d(keys, vals, new_k, new_v, *, cap: int, with_st: bool):
    """Fused 1-D append: merged sorted log + exclusive prefix sums and,
    for the locate->gather MAX/MIN correction, the insert-log sparse
    table.  Returns (keys, vals, cf, st-or-None)."""
    k, v = _merge_sorted(cap, keys, vals, new_k, new_v)
    cf = _prefix_sum_jnp(v)
    st = _sparse_table_jnp(v, cap=cap) if with_st else None
    return k, v, cf, st


@partial(jax.jit, static_argnames=("cap", "levels", "weighted"))
def _append_2d(bx, by, bw, nx, ny, nw, *, cap: int, levels: bool,
               weighted: bool):
    """Fused 2-D append: x-sorted point log plus (when the locate->gather
    correction reads them) the merge-sort-tree level arrays — weighted
    variants also rebuild the per-block prefix sums/maxima.  Returns
    (x, y, w, ylv, wcum, wpmax) with None for structures not requested
    (``bw``/``nw`` are ignored when ``weighted`` is False)."""
    x = jnp.concatenate([bx, nx])
    y = jnp.concatenate([by, ny])
    order = jnp.argsort(x)   # stable: existing entries first on ties
    x, y = x[order][:cap], y[order][:cap]
    w = ylv = wcum = wpmax = None
    if weighted:
        w = jnp.concatenate([bw, nw])[order][:cap]
        if levels:
            ylv, wcum, wpmax = _mst_levels_w_jnp(y, w, cap=cap)
    elif levels:
        ylv = _mst_levels_jnp(y, cap=cap)
    return x, y, w, ylv, wcum, wpmax


def _pad_batch(arr: np.ndarray, fill, dtype) -> jnp.ndarray:
    """Pad a host batch to the next power of two (bounds compilations)."""
    m = len(arr)
    size = max(1, 1 << (m - 1).bit_length()) if m else 1
    out = np.full((size,), fill, np.float64)
    out[:m] = arr
    return jnp.asarray(out, dtype)


# ---------------------------------------------------------------------------
# fused delta corrections (traced inside the dynamic executors)
# ---------------------------------------------------------------------------

def _delta_sum(lq, uq, keys, vals, cf, *, backend, interpret, bq):
    if backend == "pallas":
        # locate->gather: two binary searches into the append-maintained
        # prefix-sum array (O(log D) instead of the O(D) one-hot sweep)
        return delta_sum_gather_pallas(lq, uq, keys, cf, bq=bq,
                                       interpret=interpret)
    if backend == "pallas_scan":
        return delta_sum_pallas(lq, uq, keys, vals, bq=bq, interpret=interpret)
    if backend == "ref":
        return _ref.delta_sum_ref(lq, uq, keys, vals)
    # xla: the log is sorted and cf precomputed -> two searchsorted lookups
    return (cf[jnp.searchsorted(keys, uq, side="right")]
            - cf[jnp.searchsorted(keys, lq, side="right")])


def _delta_max(lq, uq, keys, vals, st, *, backend, interpret, bq):
    if backend == "pallas":
        # locate the covered span of the sorted log, O(1) sparse-table RMQ
        return delta_max_gather_pallas(lq, uq, keys, st, bq=bq,
                                       interpret=interpret)
    if backend == "pallas_scan":
        return delta_max_pallas(lq, uq, keys, vals, bq=bq, interpret=interpret)
    # xla + ref: dense masked max over the (small) buffer
    return _ref.delta_max_ref(lq, uq, keys, vals)


def _delta_count2d(lx, ux, ly, uy, kx, ky, ylv, *, backend, interpret, bq,
                   dtype):
    if backend == "pallas":
        # locate->gather: merge-sort-tree dominance counts, O(log^2 D)
        return delta_count2d_gather_pallas(lx, ux, ly, uy, kx, ylv, bq=bq,
                                           interpret=interpret, dtype=dtype)
    if backend == "pallas_scan":
        return delta_count2d_pallas(lx, ux, ly, uy, kx, ky, bq=bq,
                                    interpret=interpret, dtype=dtype)
    return _ref.delta_count2d_ref(lx, ux, ly, uy, kx, ky, dtype=dtype)


def _delta_sum2d(lx, ux, ly, uy, kx, ky, wv, ylv, wcum, *, backend,
                 interpret, bq):
    if backend == "pallas":
        # locate->gather: weighted merge-sort-tree sums, O(log^2 D)
        return delta_sum2d_gather_pallas(lx, ux, ly, uy, kx, ylv, wcum,
                                         bq=bq, interpret=interpret)
    if backend == "pallas_scan":
        return delta_sum2d_pallas(lx, ux, ly, uy, kx, ky, wv, bq=bq,
                                  interpret=interpret)
    return _ref.delta_sum2d_ref(lx, ux, ly, uy, kx, ky, wv)


def _delta_dommax2d(u, v, kx, ky, wv, ylv, wpmax, *, backend, interpret, bq):
    if backend == "pallas":
        # locate->gather: weighted merge-sort-tree maxima, O(log^2 D)
        return delta_dommax2d_gather_pallas(u, v, kx, ylv, wpmax, bq=bq,
                                            interpret=interpret)
    if backend == "pallas_scan":
        return delta_dommax2d_pallas(u, v, kx, ky, wv, bq=bq,
                                     interpret=interpret)
    return _ref.delta_dommax2d_ref(u, v, kx, ky, wv)


# ---------------------------------------------------------------------------
# fused dynamic executors: static approximation + exact delta correction +
# Q_rel acceptance + vectorized refinement, one jitted path per signature
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("backend", "eps_rel", "interpret", "bq"))
def _exec_dyn_sum(plan: IndexPlan, buf: DeltaBuffer, lq, uq, *, backend: str,
                  eps_rel: Optional[float], interpret: bool, bq: int):
    dt = plan.dtype
    lqr, uqr = lq.astype(dt), uq.astype(dt)
    lqc = jnp.maximum(lqr, plan.domain_lo)
    uqc = jnp.maximum(uqr, plan.domain_lo)
    static = raw_sum(plan, lqc, uqc, backend=backend, interpret=interpret,
                     bq=bq)
    # exact correction over (lq, uq] — unclamped: buffered keys may lie
    # outside the static domain
    corr = (_delta_sum(lqr, uqr, buf.ins_keys, buf.ins_vals, buf.ins_cf,
                       backend=backend, interpret=interpret, bq=bq)
            - _delta_sum(lqr, uqr, buf.del_keys, buf.del_vals, buf.del_cf,
                         backend=backend, interpret=interpret, bq=bq))
    approx = static + corr
    if eps_rel is None:
        return approx, approx, jnp.zeros(approx.shape, bool)
    # Lemma 5.2 holds over the updated dataset: |approx - truth| <= 2*delta
    # because the delta contribution is exact
    two_d = 2.0 * plan.delta
    ok = ((approx - two_d > 0) &
          (two_d / jnp.maximum(approx - two_d, 1e-300) <= eps_rel))
    truth = truth_sum(plan, lqr, uqr) + corr
    return jnp.where(ok, approx, truth), approx, ~ok


@partial(jax.jit, static_argnames=("backend", "interpret", "bq"))
def _exec_dyn_quantile(plan: IndexPlan, buf: DeltaBuffer, q, *, backend: str,
                       interpret: bool, bq: int):
    """Certified quantile over the *updated* CF G = F + (ins - del).

    G is the CF of the live multiset (deletes remove existing rows), hence
    monotone; only F is fitted.  The loop inverts F against the
    delta-corrected rank target and re-certifies with the exact buffer
    correction evaluated at the candidate key: at convergence the
    key-certified facts about F plus the exact B(x) give
    G(x_hi) >= rank + slack and G(x_lo) <= rank - slack (DESIGN.md §16).
    Inversion is O(Q log H) scalar work with no kernel variant, so
    ``backend`` is ignored and every backend shares this path
    bit-identically.
    """
    del backend, interpret, bq
    dt = plan.dtype
    qc = jnp.clip(q.astype(dt), 0.0, 1.0)
    err = (plan.seg_err if plan.seg_err is not None
           else jnp.full_like(plan.seg_lo, plan.delta))
    Bnd = boundary_array(plan.coeffs)
    kw = dict(B=Bnd, seg_lo=plan.seg_lo, seg_hi=plan.seg_hi,
              coeffs=plan.coeffs, h=plan.h)
    if plan.ref_keys is not None:
        keys = pad_to_multiple(plan.ref_keys, 128, big_sentinel(dt))
        nk = plan.n
    else:
        keys, nk = None, 0

    # total live mass and rank slack over the updated multiset
    dM = buf.ins_cf[-1] - buf.del_cf[-1]
    if plan.agg == "count":
        M = jnp.asarray(float(plan.n), dt) + dM
        slack = rank_slack("count", M)
    else:
        if plan.ref_cf is not None:
            M0, extra = plan.ref_cf[-1], 0.0
        else:
            M0 = horner(plan.coeffs[plan.h - 1], jnp.asarray(1.0, dt))
            extra = plan.delta
        M = M0 + dM
        slack = rank_slack("sum", M) + extra
    r = qc * M
    tiny = 1e-9 * (jnp.abs(r) + 1.0)

    def corr(x):
        # exact buffered mass at or below x (exclusive prefix sums; the
        # sentinel-padded tails contribute zero)
        return (buf.ins_cf[jnp.searchsorted(buf.ins_keys, x, side="right")]
                - buf.del_cf[jnp.searchsorted(buf.del_keys, x, side="right")])

    live = buf.ins_keys < big_sentinel(dt) / 2
    dom_hi = plan.seg_hi[plan.h - 1]
    dom_lo = plan.seg_lo[0]
    # unconditional fallbacks: >=/<= every live key of the updated set
    fb_top = jnp.maximum(dom_hi,
                         jnp.max(jnp.where(live, buf.ins_keys, -jnp.inf)))
    fb_lo = jnp.minimum(dom_lo,
                        jnp.min(jnp.where(live, buf.ins_keys, jnp.inf)))

    # raw fitted estimate: fixed-point on the delta-corrected rank
    zeros = jnp.zeros_like(err)
    xm, okm = invert_cf(r, "hi", seg_err=zeros, delta=0.0, slack=0.0,
                        raw=True, **kw)
    xm = jnp.where(okm, xm, dom_hi)
    for _ in range(2):
        xm2, okm = invert_cf(r - corr(xm), "hi", seg_err=zeros, delta=0.0,
                             slack=0.0, raw=True, **kw)
        xm = jnp.where(okm, xm2, dom_hi)

    # upper: find x_hi with F(x_hi) >= tF and tF + B(x_hi) >= r + slack
    r_hi = r + slack
    tF = r_hi - corr(xm)
    x_hi, ok_hi = xm, jnp.zeros(r.shape, bool)
    for _ in range(4):
        x_hi, ok_v = invert_cf(tF, "hi", seg_err=err,
                               delta=float(plan.delta), slack=0.0,
                               ref_keys=keys, n=nk, **kw)
        need = r_hi - corr(x_hi)
        ok_hi = (need <= tF + tiny) & ok_v
        tF = jnp.maximum(tF, need)
    x_hi = jnp.where(ok_hi, x_hi, fb_top)

    # lower: every base key <= x_lo has F <= tL (the invert_cf 'lo'
    # contract, flagged by ok_v), so F(x_lo) <= max(tL, 0) and
    # G(x_lo) <= max(tL, 0) + B(x_lo) <= r - slack at convergence; G
    # monotone => x_lo precedes every rank-r crossing
    r_lo = r - slack
    tL = r_lo - corr(xm)
    x_lo, ok_lo = xm, jnp.zeros(r.shape, bool)
    for _ in range(4):
        x_lo, ok_v = invert_cf(tL, "lo", seg_err=err,
                               delta=float(plan.delta), slack=0.0, **kw)
        need = r_lo - corr(x_lo)
        ok_lo = (need >= jnp.maximum(tL, 0.0) - tiny) & ok_v
        tL = jnp.minimum(tL, need)
    x_lo = jnp.where(ok_lo, x_lo, fb_lo)

    ans = jnp.clip(xm, x_lo, x_hi)
    return ans, x_lo, x_hi


@partial(jax.jit, static_argnames=("backend", "eps_rel", "interpret", "bq"))
def _exec_dyn_extremum(plan: IndexPlan, buf: DeltaBuffer, lq, uq, *,
                       backend: str, eps_rel: Optional[float],
                       interpret: bool, bq: int):
    """MAX space throughout; the delete log is empty by construction
    (extremal deletes shadow a victim — ``buf.vic_keys``/``buf.live_st`` —
    instead of populating the device delete log; see DeltaBuffer)."""
    dt = plan.dtype
    lqr, uqr = lq.astype(dt), uq.astype(dt)
    lqc = jnp.maximum(lqr, plan.domain_lo)
    uqc = jnp.maximum(uqr, plan.domain_lo)
    static = raw_extremum(plan, lqc, uqc, backend=backend,
                          interpret=interpret, bq=bq)
    ins = _delta_max(lqr, uqr, buf.ins_keys, buf.ins_vals, buf.ins_st,
                     backend=backend, interpret=interpret, bq=bq)
    approx = jnp.maximum(static, ins)
    neg = plan.agg == "min"
    if buf.vic_keys is not None:
        # victim-shadowed path: a range covering a deleted base row cannot
        # trust the fitted approximation (the victim may be the maximum) —
        # refine against the victim-masked exact sparse table instead
        i0 = jnp.searchsorted(plan.ref_keys, lqr, side="left")
        i1 = jnp.searchsorted(plan.ref_keys, uqr, side="right")
        base_exact = sparse_table_range_max(buf.live_st, i0, i1)
        exact = jnp.maximum(base_exact, ins)
        vk = buf.vic_keys
        threat = jnp.any((lqr[:, None] <= vk[None, :]) &
                         (vk[None, :] <= uqr[:, None]), axis=1)
        if eps_rel is None:
            ans = jnp.where(threat, exact, approx)
            if neg:
                ans = -ans
            return ans, ans, threat
        ok = (~threat) & (approx >= plan.delta * (1.0 + 1.0 / eps_rel))
        ans = jnp.where(ok, approx, exact)
        if neg:
            ans, approx = -ans, -approx
        return ans, approx, ~ok
    if eps_rel is None:
        out = -approx if neg else approx
        return out, out, jnp.zeros(out.shape, bool)
    # Lemma 5.4: max(static +- delta, exact) stays within delta of the truth
    ok = approx >= plan.delta * (1.0 + 1.0 / eps_rel)
    truth = jnp.maximum(truth_extremum(plan, lqr, uqr), ins)
    ans = jnp.where(ok, approx, truth)
    if neg:
        ans, approx = -ans, -approx
    return ans, approx, ~ok


@partial(jax.jit, static_argnames=("backend", "eps_rel", "interpret", "bq"))
def _exec_dyn_count2d(plan: IndexPlan2D, buf: DeltaBuffer2D, lx, ux, ly, uy,
                      *, backend: str, eps_rel: Optional[float],
                      interpret: bool, bq: int):
    dt = plan.dtype
    x0, x1, y0, y1 = plan.root
    lxr, uxr, lyr, uyr = (q.astype(dt) for q in (lx, ux, ly, uy))
    lxc, uxc = (jnp.clip(q, x0, x1) for q in (lxr, uxr))
    lyc, uyc = (jnp.clip(q, y0, y1) for q in (lyr, uyr))
    static = raw_count2d(plan, lxc, uxc, lyc, uyc, backend=backend,
                         interpret=interpret, bq=bq)
    corr = (_delta_count2d(lxr, uxr, lyr, uyr, buf.ins_x, buf.ins_y,
                           buf.ins_ylv, backend=backend, interpret=interpret,
                           bq=bq, dtype=dt)
            - _delta_count2d(lxr, uxr, lyr, uyr, buf.del_x, buf.del_y,
                             buf.del_ylv, backend=backend,
                             interpret=interpret, bq=bq, dtype=dt))
    approx = static + corr
    if eps_rel is None:
        return approx, approx, jnp.zeros(approx.shape, bool)
    ok = approx >= 4.0 * plan.delta * (1.0 + 1.0 / eps_rel)   # Lemma 6.4
    truth = truth_count2d(plan, lxr, uxr, lyr, uyr) + corr
    return jnp.where(ok, approx, truth), approx, ~ok


@partial(jax.jit, static_argnames=("backend", "eps_rel", "interpret", "bq"))
def _exec_dyn_sum2d(plan: IndexPlan2D, buf: DeltaBuffer2D, lx, ux, ly, uy,
                    *, backend: str, eps_rel: Optional[float],
                    interpret: bool, bq: int):
    dt = plan.dtype
    x0, x1, y0, y1 = plan.root
    lxr, uxr, lyr, uyr = (q.astype(dt) for q in (lx, ux, ly, uy))
    lxc, uxc = (jnp.clip(q, x0, x1) for q in (lxr, uxr))
    lyc, uyc = (jnp.clip(q, y0, y1) for q in (lyr, uyr))
    static = raw_count2d(plan, lxc, uxc, lyc, uyc, backend=backend,
                         interpret=interpret, bq=bq)
    # exact weighted correction — unclamped: buffered points may lie
    # outside the static root rectangle
    corr = (_delta_sum2d(lxr, uxr, lyr, uyr, buf.ins_x, buf.ins_y,
                         buf.ins_w, buf.ins_ylv, buf.ins_wcum,
                         backend=backend, interpret=interpret, bq=bq)
            - _delta_sum2d(lxr, uxr, lyr, uyr, buf.del_x, buf.del_y,
                           buf.del_w, buf.del_ylv, buf.del_wcum,
                           backend=backend, interpret=interpret, bq=bq))
    approx = static + corr
    if eps_rel is None:
        return approx, approx, jnp.zeros(approx.shape, bool)
    ok = approx >= 4.0 * plan.delta * (1.0 + 1.0 / eps_rel)   # Lemma 6.4
    truth = truth_sum2d(plan, lxr, uxr, lyr, uyr) + corr
    return jnp.where(ok, approx, truth), approx, ~ok


@partial(jax.jit, static_argnames=("backend", "eps_rel", "interpret", "bq"))
def _exec_dyn_dommax2d(plan: IndexPlan2D, buf: DeltaBuffer2D, u, v, *,
                       backend: str, eps_rel: Optional[float],
                       interpret: bool, bq: int):
    """MAX space throughout; the delete log is empty by construction
    (extremal deletes shadow a victim — ``buf.vic_x``/``buf.vic_y``/
    ``buf.live_wpmax`` — instead of populating the device delete log)."""
    dt = plan.dtype
    x0, x1, y0, y1 = plan.root
    ur, vr = u.astype(dt), v.astype(dt)
    uc = jnp.clip(ur, x0, x1)
    vc = jnp.clip(vr, y0, y1)
    static = raw_eval2d(plan, uc, vc, backend=backend, interpret=interpret,
                        bq=bq)
    ins = _delta_dommax2d(ur, vr, buf.ins_x, buf.ins_y, buf.ins_w,
                          buf.ins_ylv, buf.ins_wpmax, backend=backend,
                          interpret=interpret, bq=bq)
    approx = jnp.maximum(static, ins)
    neg = plan.agg == "min2d"
    if buf.vic_x is not None:
        # victim-shadowed path: refine dominance corners that cover a
        # deleted base point against the victim-masked merge-sort tree
        base_exact = mst_dommax(plan.ref_xs, plan.ref_ys_levels,
                                buf.live_wpmax, ur, vr)
        exact = jnp.maximum(base_exact.astype(dt), ins)
        threat = jnp.any((buf.vic_x[None, :] <= ur[:, None]) &
                         (buf.vic_y[None, :] <= vr[:, None]), axis=1)
        if eps_rel is None:
            ans = jnp.where(threat, exact, approx)
            if neg:
                ans = -ans
            return ans, ans, threat
        ok = (~threat) & (approx >= plan.delta * (1.0 + 1.0 / eps_rel))
        ans = jnp.where(ok, approx, exact)
        if neg:
            ans, approx = -ans, -approx
        return ans, approx, ~ok
    if eps_rel is None:
        out = -approx if neg else approx
        return out, out, jnp.zeros(out.shape, bool)
    ok = approx >= plan.delta * (1.0 + 1.0 / eps_rel)
    truth = jnp.maximum(truth_dommax2d(plan, ur, vr), ins)
    ans = jnp.where(ok, approx, truth)
    if neg:
        ans, approx = -ans, -approx
    return ans, approx, ~ok


# ---------------------------------------------------------------------------
# serving-executor factory: the AOT-lowerable unit behind serve/engine.py
# ---------------------------------------------------------------------------

def fused_executor(agg: str, dynamic: bool, *, backend: str,
                   eps_rel: Optional[float], interpret: bool, bq: int,
                   deg: int):
    """A plain callable ``fn(plan, buf, *padded_ranges)`` with every static
    argument closed over — the unit the serving engine AOT-lowers
    (``jax.jit(fn).lower(...).compile()``) and caches per (table, bucket).

    ``buf`` is the table's ``DeltaBuffer``/``DeltaBuffer2D`` for dynamic
    tables and an empty tuple for static ones (the argument slot is kept so
    one executable-cache shape serves both).  The function returns the raw
    executor triple ``(ans, approx, refined)`` over the padded bucket; the
    caller slices real rows back out.  Dispatch mirrors ``execute_*``
    exactly — including the deg>3 extremum backend downgrade — so answers
    are bit-identical to the session path.
    """
    from .engine import (_exec_extremum, _exec_extremum2d, _exec_rect2d,
                         _exec_sum)
    if agg in ("max", "min") and deg > 3 and backend in (
            "pallas", "pallas_scan", "ref"):
        backend = "xla"   # no in-kernel closed form past deg 3
    statics = dict(backend=backend, eps_rel=eps_rel, interpret=interpret,
                   bq=bq)
    if dynamic:
        ex = {"sum": _exec_dyn_sum, "count": _exec_dyn_sum,
              "max": _exec_dyn_extremum, "min": _exec_dyn_extremum,
              "count2d": _exec_dyn_count2d, "sum2d": _exec_dyn_sum2d,
              "max2d": _exec_dyn_dommax2d,
              "min2d": _exec_dyn_dommax2d}[agg]

        def fn(plan, buf, *qs):
            return ex(plan, buf, *qs, **statics)
    else:
        ex = {"sum": _exec_sum, "count": _exec_sum,
              "max": _exec_extremum, "min": _exec_extremum,
              "count2d": _exec_rect2d, "sum2d": _exec_rect2d,
              "max2d": _exec_extremum2d, "min2d": _exec_extremum2d}[agg]

        def fn(plan, buf, *qs):
            del buf
            return ex(plan, *qs, **statics)
    return fn


def fused_quantile_executor(dynamic: bool, *, backend: str, interpret: bool,
                            bq: int, deg: int):
    """The QUANTILE counterpart of ``fused_executor``: a plain callable
    ``fn(plan, buf, q)`` returning the certified (answer, lo, hi) triple
    over the padded fraction bucket.  Q_abs-only — there is no Q_rel
    refinement path (the certificate *is* the guarantee)."""
    del deg   # quantile inversion has no degree-gated backend downgrade
    from .engine import _exec_quantile
    if dynamic:
        def fn(plan, buf, q):
            return _exec_dyn_quantile(plan, buf, q, backend=backend,
                                      interpret=interpret, bq=bq)
    else:
        def fn(plan, buf, q):
            del buf
            return _exec_quantile(plan, q, backend=backend,
                                  interpret=interpret, bq=bq)
    return fn


# ---------------------------------------------------------------------------
# merge pass: apply the buffered ops, refit only the dirty segments
# ---------------------------------------------------------------------------

def _merge_1d(index: PolyFitIndex1D, keys: np.ndarray, meas: np.ndarray,
              ins_k: np.ndarray, ins_v: np.ndarray,
              del_k: np.ndarray, del_v: np.ndarray
              ) -> Tuple[PolyFitIndex1D, np.ndarray, np.ndarray]:
    """Merge buffered ops into (keys, meas) and selectively refit.

    Returns (new_index, new_keys, new_meas) with measures in internal
    space.  Only segments whose ``locate`` span contains a changed key are
    re-segmented (greedy GS on the affected windows); clean SUM/COUNT
    segments get their constant coefficient shifted by the exact upstream
    CF delta, which preserves their certified E(I).
    """
    agg, deg, delta = index.agg, index.deg, index.delta
    extremal = agg in ("max", "min")
    n_old = len(keys)

    # -- resolve tombstones against pending inserts, then the base data ----
    removed = np.zeros(n_old, bool)
    ins_removed = np.zeros(len(ins_k), bool)
    for key, val in zip(del_k, del_v):
        cand = np.where(~ins_removed & (ins_k == key) & (ins_v == val))[0]
        if len(cand):
            ins_removed[cand[0]] = True
            continue
        i0 = np.searchsorted(keys, key, side="left")
        i1 = np.searchsorted(keys, key, side="right")
        live = np.where(~removed[i0:i1] & (meas[i0:i1] == val))[0]
        if not len(live):
            live = np.where(~removed[i0:i1])[0]
        if not len(live):
            raise KeyError(f"delete of key {key!r}: no live occurrence")
        removed[i0 + live[0]] = True

    keep = ~removed
    kept_old = np.where(keep)[0]
    ik = ins_k[~ins_removed]
    iv = ins_v[~ins_removed]
    all_k = np.concatenate([keys[keep], ik])
    all_v = np.concatenate([meas[keep], iv])
    order = np.argsort(all_k, kind="stable")   # base entries first on ties
    new_k, new_m = all_k[order], all_v[order]
    if len(new_k) == 0:
        raise ValueError("merge would empty the dataset")

    # old position -> new position, for the CF shift of clean segments
    inv = np.empty(len(order), np.int64)
    inv[order] = np.arange(len(order))
    old_to_new = np.full(n_old, -1, np.int64)
    old_to_new[kept_old] = inv[: len(kept_old)]

    # -- mark dirty segments (locate() rule: searchsorted right - 1) -------
    seg_lo = np.asarray(index.seg_lo)
    seg_hi = np.asarray(index.seg_hi)
    coeffs = np.asarray(index.coeffs)
    seg_start = np.asarray(index.seg_start)
    seg_err = (np.asarray(index.seg_err) if index.seg_err is not None
               else np.full(len(seg_lo), delta))
    h = len(seg_lo)
    changed = np.concatenate([ins_k, del_k])
    dirty = np.zeros(h, bool)
    dirty[np.clip(np.searchsorted(seg_lo, changed, side="right") - 1,
                  0, h - 1)] = True
    # duplicate keys straddling a boundary can leave a "clean" segment whose
    # anchor position was removed — refit it rather than shift blindly
    for s in range(h):
        if not dirty[s] and old_to_new[seg_start[s]] < 0:
            dirty[s] = True

    old_F = np.cumsum(meas) if not extremal else meas
    new_F = np.cumsum(new_m) if not extremal else new_m
    ins_sorted = np.sort(ik)
    keep_cum = np.concatenate([[0], np.cumsum(keep)])

    def new_boundary(p: int) -> int:
        """New-array position of old boundary position p (start of seg)."""
        if p >= n_old:
            return len(new_k)
        # kept base keys before p + inserted keys sorting strictly before
        # keys[p] (stable merge puts equal inserted keys after the base run)
        return int(keep_cum[p]) + int(np.searchsorted(ins_sorted, keys[p],
                                                      side="left"))

    fitter = FastAcceptFitter(exact=fit_minimax_lp, delta=delta,
                              post=_continuum_post if extremal else None)
    segs: List[PolyModel] = []
    i = 0
    while i < h:
        if not dirty[i]:
            c = coeffs[i].copy()
            if not extremal:
                np_pos = old_to_new[seg_start[i]]
                c[0] += new_F[np_pos] - old_F[seg_start[i]]
            segs.append(PolyModel(float(seg_lo[i]), float(seg_hi[i]), c,
                                  float(seg_err[i])))
            i += 1
            continue
        j = i
        while j < h and dirty[j]:
            j += 1
        start = 0 if i == 0 else new_boundary(int(seg_start[i]))
        end = len(new_k) if j >= h else new_boundary(int(seg_start[j]))
        if end > start:
            segs.extend(greedy_segmentation(new_k[start:end],
                                            new_F[start:end], deg, delta,
                                            fitter=fitter))
        i = j

    new_index = assemble_index_1d(segs, new_k, new_m, agg, deg, delta,
                                  keep_exact=True)
    return new_index, new_k, new_m


# ---------------------------------------------------------------------------
# the dynamic engines
# ---------------------------------------------------------------------------

class _DeltaBufferedEngine:
    """Shared delta-buffer bookkeeping + (background) refit machinery.

    Subclasses implement ``_snapshot()`` (immutable view of the data + op
    logs for the merge thread) and ``_merge(snap, mark)`` (the merge pass,
    ending in a locked ``_install``); everything about thread lifecycle,
    drain-until-empty waiting, residual-op marks, and error surfacing
    lives here once.
    """

    _refit_error: Optional[BaseException] = None

    def _init_dynamic(self, *, backend: str, capacity: int, interpret: bool,
                      bq: int, min_bucket: int, auto_refit: bool,
                      background: bool) -> None:
        check_pow2("capacity", capacity)
        check_pow2("bq", bq)
        check_pow2("min_bucket", min_bucket)
        self.backend = backend
        self.capacity = capacity
        self.interpret = interpret
        self.bq = bq
        self.min_bucket = min_bucket
        self.auto_refit = auto_refit
        self.background = background
        self.refit_count = 0
        self._lock = threading.RLock()
        self._thread: Optional[threading.Thread] = None
        self._install_listeners: List = []
        # (ins, del) log lengths captured by the in-flight merge snapshot;
        # None when no merge is running.  Extremal deletes that NaN-cancel
        # a pending insert the snapshot already copied must be replayed at
        # install (the merge bakes the un-cancelled copy into the new base).
        self._merge_mark: Optional[Tuple[int, int]] = None

    def add_install_listener(self, fn) -> None:
        """Register ``fn(preview)`` to run on the merge thread with the
        about-to-be-installed state *before* the atomic install.  The
        serving engine uses this to pre-lower the incoming plan's bucket
        ladder so post-swap dispatches never pay a relower; listener
        errors propagate as refit errors (the install does not happen)."""
        self._install_listeners.append(fn)

    def _notify_install_listeners(self, preview) -> None:
        for fn in list(self._install_listeners):
            fn(preview)

    @property
    def n_pending(self) -> int:
        return self._n_pending

    def snapshot(self):
        """The current immutable (plan, delta-buffer) pair, as one atomic
        read — the state queries execute against.  External executors
        (e.g. ``engine.sharded``) must take both from one snapshot so the
        buffer matches the installed plan."""
        return self._state

    def _ensure_room(self, m: int) -> None:
        if m > self.capacity:
            raise ValueError(f"batch of {m} exceeds buffer capacity "
                             f"{self.capacity}; split the batch")
        if self._n_pending + m > self.capacity:
            self.refit(wait=True)   # drains every pending op (see refit)

    def flush(self) -> None:
        """Synchronously merge all buffered ops into a fresh plan."""
        self.refit(wait=True)

    def refit(self, wait: Optional[bool] = None) -> None:
        """Run (or join) a merge pass.  ``wait=False`` returns immediately
        with the merge running on a daemon thread; queries keep executing
        against the old (plan, buffer) snapshot until the atomic install.

        ``wait=True`` drains *every* pending op before returning: a joined
        thread may be a stale background merge whose snapshot predates ops
        logged since (they are replayed into the fresh buffer as
        residuals), so keep merging until nothing is pending.  MAX/MIN
        delete correctness relies on this — a residual tombstone would sit
        in a buffer the extremum executor never reads."""
        wait = (not self.background) if wait is None else wait
        t = self._start_refit()
        if wait:
            while t is not None:
                t.join()
                self._raise_refit_error()
                t = self._start_refit()
        self._raise_refit_error()

    def _raise_refit_error(self) -> None:
        if self._refit_error is not None:
            err, self._refit_error = self._refit_error, None
            raise err

    def _has_forced_work(self) -> bool:
        """Subclass hook: True when a merge must run even with zero pending
        buffered ops (e.g. the LSM shadow-fraction fold, which compacts
        tombstone-heavy levels that carry no new inserts)."""
        return False

    def _start_refit(self) -> Optional[threading.Thread]:
        with self._lock:
            if self._thread is not None and self._thread.is_alive():
                return self._thread
            if self._n_pending == 0 and not self._has_forced_work():
                return None
            snap = self._snapshot()
            mark = (len(self._ins_log), len(self._del_log))
            t = threading.Thread(target=self._merge_and_install,
                                 args=(snap, mark), daemon=True)
            self._thread = t
        t.start()
        return t

    def _merge_and_install(self, snap, mark) -> None:
        try:
            self._merge(snap, mark)
        except BaseException as e:   # surface on the caller's next refit()
            self._refit_error = e
        finally:
            self._thread = None

    @staticmethod
    def _flatten(log: List[Tuple[np.ndarray, np.ndarray]]):
        if not log:
            z = np.zeros((0,))
            return z, z
        return (np.concatenate([k for k, _ in log]),
                np.concatenate([v for _, v in log]))


class DynamicEngine(_DeltaBufferedEngine):
    """Updatable 1-D plan: buffered inserts/deletes, fused exact
    correction, selective (optionally background) refit.

    Single-writer: ``insert``/``delete``/``refit`` are serialized by an
    internal lock; queries are lock-free against an immutable
    (plan, buffer) snapshot, so a refit never blocks them.
    """

    def __init__(self, index: PolyFitIndex1D, *, backend: str = "xla",
                 capacity: int = 1024, interpret: bool = True,
                 bq: int = DEFAULT_BQ, min_bucket: int = 64,
                 auto_refit: bool = True, background: bool = False,
                 drift_floor: float = 0.05):
        if index.exact_sum is None and index.exact_max is None:
            raise ValueError("DynamicEngine requires an index built with "
                             "keep_exact=True (merge needs the raw data)")
        self._init_dynamic(backend=backend, capacity=capacity,
                           interpret=interpret, bq=bq,
                           min_bucket=min_bucket, auto_refit=auto_refit,
                           background=background)
        self.drift_floor = drift_floor
        self._agg = index.agg
        if index.exact_sum is not None:
            keys = np.asarray(index.exact_sum.keys)
            cf = np.asarray(index.exact_sum.cf)
            meas = np.diff(np.concatenate([[0.0], cf]))
        else:
            keys = np.asarray(index.exact_max.keys)
            meas = np.asarray(index.exact_max.measures)   # internal space
        self._install(index, keys, meas)

    # -- state ----------------------------------------------------------

    def _install(self, index: PolyFitIndex1D, keys: np.ndarray,
                 meas: np.ndarray, residual_ins: Optional[list] = None,
                 residual_del: Optional[list] = None,
                 residual_vic: Optional[list] = None,
                 plan: Optional[IndexPlan] = None) -> None:
        """Swap in a fresh (index, plan, empty-or-replayed buffer).

        ``plan`` lets the merge thread pass the plan it already built (and
        pre-lowered via the install listeners) so the installed object is
        the *same* identity the serving AOT cache was warmed against."""
        with self._lock:
            self._index = index
            self._keys = keys
            self._meas = meas
            self._seg_lo_host = np.asarray(index.seg_lo)
            err = (np.asarray(index.seg_err) if index.seg_err is not None
                   else np.zeros(index.h))
            self._budget = np.maximum(index.delta - err,
                                      self.drift_floor * index.delta)
            self._drift = np.zeros(index.h)
            self._ins_log: List[Tuple[np.ndarray, np.ndarray]] = []
            self._del_log: List[Tuple[np.ndarray, np.ndarray]] = []
            self._n_pending = 0
            self._vic: List[int] = []
            self._residual_vic: List[Tuple[float, float]] = []
            self._merge_mark = None
            if plan is None:
                plan = build_plan(index)
            # the insert-log sparse table is only read by the locate->gather
            # MAX correction, so only that backend pays its upkeep
            buf = DeltaBuffer.empty(
                self.capacity, plan.dtype,
                with_st=(self._agg in ("max", "min")
                         and self.backend == "pallas"))
            self._state = (plan, buf)
            for k, v in (residual_ins or []):
                if len(k):
                    self._log_ops(k, v, delete=False)
            if self._agg in ("max", "min"):
                # extremal residuals re-resolve through the victim path so
                # the fresh buffer's shadow mask covers them immediately
                nan_dirty = False
                for karr, varr in (residual_del or []):
                    for k, v in zip(karr, varr):
                        nan_dirty |= self._delete_extremal_resolved(
                            float(k), float(v))
                for k, v in (residual_vic or []):
                    nan_dirty |= self._delete_extremal_resolved(k, v)
                if nan_dirty:
                    self._rebuild_ins_buf()
                if self._vic:
                    self._refresh_vic_buf()
            else:
                for k, v in (residual_del or []):
                    if len(k):
                        self._log_ops(k, v, delete=True)

    @property
    def plan(self) -> IndexPlan:
        return self._state[0]

    @property
    def index(self) -> PolyFitIndex1D:
        return self._index

    @property
    def agg(self) -> str:
        return self._agg

    # -- updates --------------------------------------------------------

    def _log_ops(self, keys: np.ndarray, vals: np.ndarray,
                 delete: bool) -> None:
        """Append a batch to the device buffer + host log + drift (locked)."""
        if self._n_pending + len(keys) > self.capacity:
            # _append_sorted would silently drop the largest keys past cap;
            # overflowing here means the single-writer contract was broken
            raise RuntimeError("delta buffer overflow: concurrent writers "
                               "bypassed _ensure_room")
        plan, buf = self._state
        dt = plan.dtype
        big = big_sentinel(dt)
        pk = _pad_batch(keys, big, dt)
        pv = _pad_batch(vals, 0.0, dt)
        # one fused jitted dispatch per chunk (log + every derived structure)
        if delete:
            dk, dv, dcf, _ = _append_1d(buf.del_keys, buf.del_vals, pk, pv,
                                        cap=buf.cap, with_st=False)
            buf = dataclasses.replace(buf, del_keys=dk, del_vals=dv,
                                      del_cf=dcf)
            self._del_log.append((keys, vals))
        else:
            ik, iv, icf, st = _append_1d(buf.ins_keys, buf.ins_vals, pk, pv,
                                         cap=buf.cap,
                                         with_st=buf.ins_st is not None)
            buf = dataclasses.replace(buf, ins_keys=ik, ins_vals=iv,
                                      ins_cf=icf, ins_st=st)
            self._ins_log.append((keys, vals))
        self._state = (plan, buf)
        self._n_pending += len(keys)
        if delete and self._agg in ("max", "min"):
            # extremal tombstones leave the fitted function and its
            # certificate untouched (the victim shadow answers exactly),
            # so they ride the capacity trigger only, never drift
            return
        seg = np.clip(np.searchsorted(self._seg_lo_host, keys, side="right")
                      - 1, 0, len(self._seg_lo_host) - 1)
        np.add.at(self._drift, seg, np.abs(vals))

    def insert(self, keys, measures=None) -> None:
        """Buffer a batch of new (key, measure) records."""
        # always copy: the host log owns these arrays (extremal deletes
        # NaN-cancel pending inserts in place)
        keys = np.atleast_1d(np.array(keys, np.float64))
        if measures is None:
            if self._agg != "count":
                raise ValueError("measures required unless agg='count'")
            measures = np.ones_like(keys)
        measures = np.broadcast_to(
            np.asarray(measures, np.float64), keys.shape).copy()
        if self._agg == "count":
            measures = np.ones_like(keys)
        if self._agg == "min":
            measures = -measures
        self._ensure_room(len(keys))
        with self._lock:
            self._log_ops(keys, measures, delete=False)
            trigger = self._should_refit()
        if trigger:
            self.refit(wait=not self.background)

    def delete(self, keys) -> None:
        """Buffer delete tombstones for existing records (KeyError if a key
        has no live occurrence).  MAX/MIN deletes shadow their victim (the
        buffer's ``vic_keys``/``live_st`` mask) instead of merging eagerly:
        queries covering the victim refine against the victim-masked exact
        sparse table, and the physical removal rides the next ordinary
        merge — no delete pays a refit on the write path."""
        keys = np.atleast_1d(np.asarray(keys, np.float64))
        self._ensure_room(len(keys))
        if self._agg in ("max", "min"):
            with self._lock:
                nan_dirty = False
                for k in keys:
                    nan_dirty |= self._delete_extremal_one(float(k))
                if nan_dirty:
                    self._rebuild_ins_buf()
                self._refresh_vic_buf()
                trigger = self._should_refit()
            if trigger:
                self.refit(wait=not self.background)
            return
        with self._lock:
            vals = []
            batch_tomb: dict = {}   # duplicates within this batch advance
            for k in keys:          # the victim cursor too
                off = batch_tomb.get(float(k), 0)
                vals.append(self._find_victim(float(k), extra_tomb=off))
                batch_tomb[float(k)] = off + 1
            self._log_ops(keys, np.array(vals), delete=True)
            trigger = self._should_refit()
        if trigger:
            self.refit(wait=not self.background)

    def _delete_extremal_one(self, key: float) -> bool:
        """Resolve one extremal delete: shadow the leftmost unshadowed base
        occurrence (victim mask + ordinary tombstone for the next merge),
        else NaN-cancel a pending insert.  Returns True when a pending
        insert was cancelled (the device insert arrays need a rebuild)."""
        i0 = np.searchsorted(self._keys, key, side="left")
        i1 = np.searchsorted(self._keys, key, side="right")
        vic_set = set(self._vic)
        for pos in range(i0, i1):
            if pos not in vic_set:
                self._vic.append(pos)
                self._log_ops(np.array([key]),
                              np.array([float(self._meas[pos])]),
                              delete=True)
                return False
        for e, (karr, varr) in enumerate(self._ins_log):
            hit = np.where((karr == key) & ~np.isnan(karr))[0]
            if len(hit):
                j = int(hit[0])
                val = float(varr[j])
                karr[j] = varr[j] = np.nan
                self._n_pending -= 1
                if (self._merge_mark is not None
                        and e < self._merge_mark[0]):
                    # the in-flight merge copied this entry before the mark
                    # and will bake it into the new base — replay there
                    self._residual_vic.append((key, val))
                return True
        raise KeyError(f"delete of key {key!r}: no live occurrence")

    def _delete_extremal_resolved(self, key: float, val: float) -> bool:
        """Replay a residual extremal delete against the freshly installed
        base (value-matched victim preferred, then a pending insert, then
        any live occurrence).  Locked; returns True on a NaN-cancel."""
        i0 = np.searchsorted(self._keys, key, side="left")
        i1 = np.searchsorted(self._keys, key, side="right")
        vic_set = set(self._vic)
        cand = [p for p in range(i0, i1) if p not in vic_set]
        pos = next((p for p in cand if self._meas[p] == val),
                   cand[0] if cand else None)
        if pos is not None:
            self._vic.append(pos)
            self._log_ops(np.array([key]),
                          np.array([float(self._meas[pos])]), delete=True)
            return False
        for karr, varr in self._ins_log:
            hit = np.where((karr == key) & (varr == val)
                           & ~np.isnan(karr))[0]
            if len(hit):
                j = int(hit[0])
                karr[j] = varr[j] = np.nan
                self._n_pending -= 1
                return True
        raise KeyError(f"delete of key {key!r}: no live occurrence")

    def _refresh_vic_buf(self) -> None:
        """Rebuild the buffer's victim mask (sorted shadow keys + the
        victim-masked exact sparse table) and swap it in atomically."""
        plan, buf = self._state
        dt = plan.dtype
        if not self._vic:
            if buf.vic_keys is not None:
                buf = dataclasses.replace(buf, vic_keys=None, live_st=None)
                self._state = (plan, buf)
            return
        nv = len(self._vic)
        vcap = self.capacity
        while vcap < nv:
            vcap *= 2
        vk = np.full((vcap,), big_sentinel(np.float64))
        vk[:nv] = np.sort(self._keys[np.asarray(self._vic)])
        m = np.array(self._meas, np.float64, copy=True)
        m[np.asarray(self._vic)] = -np.inf
        buf = dataclasses.replace(
            buf, vic_keys=jnp.asarray(vk, dt),
            live_st=jnp.asarray(build_sparse_table(m), dt))
        self._state = (plan, buf)

    def _rebuild_ins_buf(self) -> None:
        """Rebuild the device insert log from the non-NaN host entries
        (one fused append), after a pending insert was cancelled."""
        plan, buf = self._state
        dt = plan.dtype
        with_st = buf.ins_st is not None
        fresh = DeltaBuffer.empty(self.capacity, dt, with_st=with_st)
        ik, iv = self._flatten(self._ins_log)
        if len(ik):
            alive = ~np.isnan(ik)
            ik, iv = ik[alive], iv[alive]
        if len(ik):
            big = big_sentinel(dt)
            nk, nv_, ncf, nst = _append_1d(
                fresh.ins_keys, fresh.ins_vals, _pad_batch(ik, big, dt),
                _pad_batch(iv, 0.0, dt), cap=self.capacity, with_st=with_st)
        else:
            nk, nv_, ncf, nst = (fresh.ins_keys, fresh.ins_vals,
                                 fresh.ins_cf, fresh.ins_st)
        buf = dataclasses.replace(buf, ins_keys=nk, ins_vals=nv_,
                                  ins_cf=ncf, ins_st=nst)
        self._state = (plan, buf)

    def _find_victim(self, key: float, extra_tomb: int = 0) -> float:
        """Measure (internal space) of the occurrence a tombstone removes:
        base occurrences first (left to right), then pending inserts."""
        tomb = extra_tomb + sum(int(np.sum(k == key))
                                for k, _ in self._del_log)
        i0 = np.searchsorted(self._keys, key, side="left")
        i1 = np.searchsorted(self._keys, key, side="right")
        pool = list(self._meas[i0:i1])
        for k, v in self._ins_log:
            pool.extend(v[k == key])
        if tomb >= len(pool):
            raise KeyError(f"delete of key {key!r}: no live occurrence")
        return float(pool[tomb])

    def _should_refit(self) -> bool:
        if not self.auto_refit:
            return False
        return (self._n_pending >= self.capacity
                or bool((self._drift > self._budget).any()))

    # -- merge / refit (lifecycle in _DeltaBufferedEngine) ----------------

    def _snapshot(self):
        # deep-copy the log arrays: extremal deletes NaN-cancel pending
        # inserts *in place* on the host log, which must not race the merge
        # thread's reads of this snapshot
        self._merge_mark = (len(self._ins_log), len(self._del_log))
        self._residual_vic = []
        return (self._index, self._keys, self._meas,
                [(k.copy(), v.copy()) for k, v in self._ins_log],
                [(k.copy(), v.copy()) for k, v in self._del_log])

    def _merge(self, snap, mark) -> None:
        index, keys, meas, ins_log, del_log = snap
        ik, iv = self._flatten(ins_log)
        if len(ik):
            alive = ~np.isnan(ik)   # NaN-cancelled pending inserts
            ik, iv = ik[alive], iv[alive]
        dk, dv = self._flatten(del_log)
        new_index, new_k, new_m = _merge_1d(index, keys, meas, ik, iv, dk, dv)
        # build the plan OFF the lock and hand the pre-lowered identity to
        # _install: the install listeners (serving AOT pre-compilation) see
        # the exact object queries will dispatch against after the swap
        new_plan = build_plan(new_index)
        self._notify_install_listeners(new_plan)
        with self._lock:
            residual_ins = [(k[~np.isnan(k)], v[~np.isnan(k)])
                            for k, v in self._ins_log[mark[0]:]]
            residual_del = self._del_log[mark[1]:]
            residual_vic = list(self._residual_vic)
            self._install(new_index, new_k, new_m, residual_ins,
                          residual_del, residual_vic, plan=new_plan)
            self.refit_count += 1

    # -- queries ---------------------------------------------------------

    def _prepare(self, lq, uq):
        lq, uq = jnp.asarray(lq), jnp.asarray(uq)
        n = lq.shape[0]
        size = _bucket_size(n, self.min_bucket)
        return lq, uq, n, size, min(self.bq, size)

    def sum(self, lq, uq, eps_rel: Optional[float] = None) -> QueryResult:
        assert self._agg in ("sum", "count"), self._agg
        plan, buf = self._state
        if eps_rel is not None and plan.ref_cf is None:
            raise ValueError("Q_rel refinement requires exact arrays")
        lq, uq, n, size, bq = self._prepare(lq, uq)
        fill = plan.domain_lo.astype(lq.dtype)
        ans, approx, refined = _exec_dyn_sum(
            plan, buf, _pad_bucket(lq, size, fill),
            _pad_bucket(uq, size, fill), backend=self.backend,
            eps_rel=eps_rel, interpret=self.interpret, bq=bq)
        return QueryResult(ans[:n], approx[:n], refined[:n])

    count = sum

    def quantile(self, q) -> QuantileResult:
        """Certified quantile fractions against the live plan-plus-buffer
        state: the delta buffer enters through its exact prefix-sum
        correction, so no flush is needed (DESIGN.md §16)."""
        assert self._agg in ("sum", "count"), self._agg
        plan, buf = self._state
        if plan.deg < 1:
            raise ValueError("quantile inversion needs a plan with "
                             "deg >= 1")
        q = jnp.asarray(q)
        n = q.shape[0]
        size = _bucket_size(n, self.min_bucket)
        ans, lo, hi = _exec_dyn_quantile(
            plan, buf, _pad_bucket(q, size, 0.5), backend=self.backend,
            interpret=self.interpret, bq=min(self.bq, size))
        return QuantileResult(ans[:n], lo[:n], hi[:n])

    def extremum(self, lq, uq, eps_rel: Optional[float] = None) -> QueryResult:
        assert self._agg in ("max", "min"), self._agg
        plan, buf = self._state
        if eps_rel is not None and plan.ref_st is None:
            raise ValueError("Q_rel refinement requires exact arrays")
        backend = self.backend
        if backend in ("pallas", "pallas_scan", "ref") and plan.deg > 3:
            backend = "xla"   # no in-kernel closed form past deg 3
        lq, uq, n, size, bq = self._prepare(lq, uq)
        fill = plan.domain_lo.astype(lq.dtype)
        ans, approx, refined = _exec_dyn_extremum(
            plan, buf, _pad_bucket(lq, size, fill),
            _pad_bucket(uq, size, fill), backend=backend,
            eps_rel=eps_rel, interpret=self.interpret, bq=bq)
        return QueryResult(ans[:n], approx[:n], refined[:n])

    def query(self, lq, uq, eps_rel: Optional[float] = None) -> QueryResult:
        if self._agg in ("sum", "count"):
            return self.sum(lq, uq, eps_rel=eps_rel)
        return self.extremum(lq, uq, eps_rel=eps_rel)


class DynamicEngine2D(_DeltaBufferedEngine):
    """Updatable 2-key plan (COUNT/SUM/dominance MAX/MIN): buffered point
    inserts/deletes with the fused exact correction; the merge pass runs
    ``core.index2d.selective_refit_2d``, touching only the leaves whose
    regions the changed points' dominance boundaries cross (stats of the
    last merge in ``last_refit_stats``)."""

    def __init__(self, index: PolyFitIndex2D, *, backend: str = "xla",
                 capacity: int = 1024, interpret: bool = True,
                 bq: int = DEFAULT_BQ, min_bucket: int = 64,
                 auto_refit: bool = True, background: bool = False):
        if index.exact is None:
            raise ValueError("DynamicEngine2D requires keep_exact=True")
        self._init_dynamic(backend=backend, capacity=capacity,
                           interpret=interpret, bq=bq,
                           min_bucket=min_bucket, auto_refit=auto_refit,
                           background=background)
        self._agg = index.agg
        self.last_refit_stats: Optional[dict] = None
        px = np.asarray(index.exact.xs)
        py = np.asarray(index.exact.ys_levels[0])
        if self._weighted:
            if index.measures_sorted is None:
                raise ValueError(f"a {self._agg} DynamicEngine2D needs an "
                                 "index built with measures")
            pw = np.asarray(index.measures_sorted)
        else:
            pw = np.ones_like(px)
        self._install(index, px, py, pw)

    @property
    def _weighted(self) -> bool:
        return self._agg != "count2d"

    @property
    def agg(self) -> str:
        return self._agg

    def _install(self, index: PolyFitIndex2D, px: np.ndarray, py: np.ndarray,
                 pw: np.ndarray, residual_ins: Optional[list] = None,
                 residual_del: Optional[list] = None,
                 residual_vic: Optional[list] = None,
                 plan: Optional[IndexPlan2D] = None) -> None:
        with self._lock:
            self._index = index
            self._px = px
            self._py = py
            self._pw = pw
            self._ins_log: List[Tuple[np.ndarray, np.ndarray, np.ndarray]] = []
            self._del_log: List[Tuple[np.ndarray, np.ndarray, np.ndarray]] = []
            self._n_pending = 0
            self._vic: List[int] = []
            self._residual_vic: List[Tuple[float, float, float]] = []
            self._merge_mark = None
            if plan is None:
                plan = build_plan_2d(index)
            buf = DeltaBuffer2D.empty(self.capacity, plan.dtype,
                                      weighted=self._weighted)
            self._state = (plan, buf)
            for x, y, w in (residual_ins or []):
                if len(x):
                    self._log_ops(x, y, w, delete=False)
            if self._agg in ("max2d", "min2d"):
                nan_dirty = False
                for xa, ya, wa in (residual_del or []):
                    for x, y, w in zip(xa, ya, wa):
                        nan_dirty |= self._delete_extremal_resolved(
                            float(x), float(y), float(w))
                for x, y, w in (residual_vic or []):
                    nan_dirty |= self._delete_extremal_resolved(x, y, w)
                if nan_dirty:
                    self._rebuild_ins_buf()
                if self._vic:
                    self._refresh_vic_buf()
            else:
                for x, y, w in (residual_del or []):
                    if len(x):
                        self._log_ops(x, y, w, delete=True)

    @property
    def plan(self) -> IndexPlan2D:
        return self._state[0]

    @property
    def index(self) -> PolyFitIndex2D:
        return self._index

    def _log_ops(self, xs: np.ndarray, ys: np.ndarray, ws: np.ndarray,
                 delete: bool) -> None:
        if self._n_pending + len(xs) > self.capacity:
            raise RuntimeError("delta buffer overflow: concurrent writers "
                               "bypassed _ensure_room")
        plan, buf = self._state
        dt = plan.dtype
        big = big_sentinel(dt)
        pkx = _pad_batch(xs, big, dt)
        pky = _pad_batch(ys, big, dt)
        pkw = _pad_batch(ws, 0.0, dt)
        # merge-sort-tree levels are only read by the locate->gather
        # correction, so only that backend pays the per-append block sorts;
        # either way the whole append is ONE fused jitted dispatch
        lv = self.backend == "pallas"
        if delete:
            bx, by, bw = buf.del_x, buf.del_y, buf.del_w
        else:
            bx, by, bw = buf.ins_x, buf.ins_y, buf.ins_w
        x, y, w, ylv, wcum, wpmax = _append_2d(
            bx, by, bw if self._weighted else bx, pkx, pky, pkw,
            cap=buf.cap, levels=lv, weighted=self._weighted)
        if delete:
            buf = dataclasses.replace(
                buf, del_x=x, del_y=y,
                del_w=w if self._weighted else None,
                del_ylv=ylv if lv else buf.del_ylv,
                del_wcum=wcum if (lv and self._weighted) else buf.del_wcum)
        else:
            buf = dataclasses.replace(
                buf, ins_x=x, ins_y=y,
                ins_w=w if self._weighted else None,
                ins_ylv=ylv if lv else buf.ins_ylv,
                ins_wcum=wcum if (lv and self._weighted) else buf.ins_wcum,
                ins_wpmax=(wpmax if (lv and self._weighted)
                           else buf.ins_wpmax))
        (self._del_log if delete else self._ins_log).append((xs, ys, ws))
        self._state = (plan, buf)
        self._n_pending += len(xs)

    def insert(self, xs, ys, ws=None) -> None:
        """Buffer new points; ``ws`` are the measures for sum2d/max2d/min2d
        tables (count2d counts records, measures must be omitted).

        A dominance MAX/MIN insert *below the frozen extremal floor*
        merges eagerly: the plan's clamp over-reports every query that
        dominates only the new point, and no monotone correction covers
        it — ``selective_refit_2d`` re-freezes the floor and refits
        exactly the leaves the old clamp touched."""
        # always copy: the host log owns these arrays (extremal deletes
        # NaN-cancel pending inserts in place)
        xs = np.atleast_1d(np.array(xs, np.float64))
        ys = np.atleast_1d(np.array(ys, np.float64))
        if not self._weighted:
            if ws is not None:
                raise ValueError("measures only apply to sum2d/max2d/min2d")
            ws = np.ones_like(xs)
        else:
            if ws is None:
                raise ValueError(f"measures required for agg={self._agg!r}")
            ws = np.broadcast_to(
                np.asarray(ws, np.float64), xs.shape).copy()
            if self._agg == "min2d":
                ws = -ws
        self._ensure_room(len(xs))
        with self._lock:
            self._log_ops(xs, ys, ws, delete=False)
            trigger = self.auto_refit and self._n_pending >= self.capacity
            floor = (self._index.extremal_floor
                     if self._agg in ("max2d", "min2d") else None)
            below_floor = floor is not None and bool((ws < floor).any())
        if below_floor:
            self.refit(wait=True)
        elif trigger:
            self.refit(wait=not self.background)

    def delete(self, xs, ys) -> None:
        """Buffer delete tombstones for existing points (KeyError if a
        point has no live occurrence).  Dominance MAX/MIN deletes shadow
        their victim (``vic_x``/``vic_y``/``live_wpmax`` in the buffer)
        instead of merging eagerly: corners dominating the victim refine
        against the victim-masked merge-sort tree, and the physical
        removal rides the next ordinary merge (the 1-D rule, DESIGN.md
        §9/§15)."""
        xs = np.atleast_1d(np.asarray(xs, np.float64))
        ys = np.atleast_1d(np.asarray(ys, np.float64))
        self._ensure_room(len(xs))
        if self._agg in ("max2d", "min2d"):
            with self._lock:
                nan_dirty = False
                for x, y in zip(xs, ys):
                    nan_dirty |= self._delete_extremal_one(float(x),
                                                           float(y))
                if nan_dirty:
                    self._rebuild_ins_buf()
                self._refresh_vic_buf()
                trigger = self.auto_refit and self._n_pending >= self.capacity
            if trigger:
                self.refit(wait=not self.background)
            return
        with self._lock:
            ws = []
            batch_tomb: dict = {}   # duplicates within this batch count too
            for x, y in zip(xs, ys):
                pt = (float(x), float(y))
                ws.append(self._find_victim(*pt,
                                            extra_tomb=batch_tomb.get(pt, 0)))
                batch_tomb[pt] = batch_tomb.get(pt, 0) + 1
            self._log_ops(xs, ys, np.asarray(ws), delete=True)
            trigger = self.auto_refit and self._n_pending >= self.capacity
        if trigger:
            self.refit(wait=not self.background)

    def _delete_extremal_one(self, x: float, y: float) -> bool:
        """Resolve one dominance MAX/MIN delete: shadow the leftmost
        unshadowed base occurrence of (x, y), else NaN-cancel a pending
        insert.  Returns True on a NaN-cancel (device rebuild needed)."""
        i0 = np.searchsorted(self._px, x, side="left")
        i1 = np.searchsorted(self._px, x, side="right")
        vic_set = set(self._vic)
        for pos in range(i0, i1):
            if self._py[pos] == y and pos not in vic_set:
                self._vic.append(pos)
                self._log_ops(np.array([x]), np.array([y]),
                              np.array([float(self._pw[pos])]), delete=True)
                return False
        for e, (xa, ya, wa) in enumerate(self._ins_log):
            hit = np.where((xa == x) & (ya == y) & ~np.isnan(xa))[0]
            if len(hit):
                j = int(hit[0])
                w = float(wa[j])
                xa[j] = ya[j] = wa[j] = np.nan
                self._n_pending -= 1
                if (self._merge_mark is not None
                        and e < self._merge_mark[0]):
                    self._residual_vic.append((x, y, w))
                return True
        raise KeyError(f"delete of point ({x!r}, {y!r}): not present")

    def _delete_extremal_resolved(self, x: float, y: float,
                                  w: float) -> bool:
        """Replay a residual dominance delete against the fresh base
        (measure-matched victim preferred, then a pending insert, then any
        live occurrence).  Locked; returns True on a NaN-cancel."""
        i0 = np.searchsorted(self._px, x, side="left")
        i1 = np.searchsorted(self._px, x, side="right")
        vic_set = set(self._vic)
        cand = [p for p in range(i0, i1)
                if self._py[p] == y and p not in vic_set]
        pos = next((p for p in cand if self._pw[p] == w),
                   cand[0] if cand else None)
        if pos is not None:
            self._vic.append(pos)
            self._log_ops(np.array([x]), np.array([y]),
                          np.array([float(self._pw[pos])]), delete=True)
            return False
        for xa, ya, wa in self._ins_log:
            hit = np.where((xa == x) & (ya == y) & (wa == w)
                           & ~np.isnan(xa))[0]
            if len(hit):
                j = int(hit[0])
                xa[j] = ya[j] = wa[j] = np.nan
                self._n_pending -= 1
                return True
        raise KeyError(f"delete of point ({x!r}, {y!r}): not present")

    def _refresh_vic_buf(self) -> None:
        """Rebuild the buffer's victim mask (shadow points + the
        victim-masked weighted merge-sort tree) and swap it in."""
        plan, buf = self._state
        dt = plan.dtype
        if not self._vic:
            if buf.vic_x is not None:
                buf = dataclasses.replace(buf, vic_x=None, vic_y=None,
                                          live_wpmax=None)
                self._state = (plan, buf)
            return
        nv = len(self._vic)
        vcap = self.capacity
        while vcap < nv:
            vcap *= 2
        vic = np.asarray(self._vic)
        big = big_sentinel(np.float64)
        vx = np.full((vcap,), big)
        vy = np.full((vcap,), big)
        vx[:nv] = self._px[vic]
        vy[:nv] = self._py[vic]
        ws = np.array(self._pw, np.float64, copy=True)
        ws[vic] = -np.inf
        # self._px is x-sorted, so MergeSortTree.build's stable argsort is
        # the identity and the tree's positions align with plan.ref_*
        t = MergeSortTree.build(self._px, self._py, ws=ws)
        buf = dataclasses.replace(
            buf, vic_x=jnp.asarray(vx, dt), vic_y=jnp.asarray(vy, dt),
            live_wpmax=jnp.asarray(t.wpmax_levels, dt))
        self._state = (plan, buf)

    def _rebuild_ins_buf(self) -> None:
        """Rebuild the device insert log from the non-NaN host entries
        (one fused append), after a pending insert was cancelled."""
        plan, buf = self._state
        dt = plan.dtype
        fresh = DeltaBuffer2D.empty(self.capacity, dt,
                                    weighted=self._weighted)
        ix, iy, iw = self._flatten3(self._ins_log)
        if len(ix):
            alive = ~np.isnan(ix)
            ix, iy, iw = ix[alive], iy[alive], iw[alive]
        if len(ix):
            big = big_sentinel(dt)
            lv = self.backend == "pallas"
            x, y, w, ylv, wcum, wpmax = _append_2d(
                fresh.ins_x, fresh.ins_y,
                fresh.ins_w if self._weighted else fresh.ins_x,
                _pad_batch(ix, big, dt), _pad_batch(iy, big, dt),
                _pad_batch(iw, 0.0, dt), cap=self.capacity, levels=lv,
                weighted=self._weighted)
            buf = dataclasses.replace(
                buf, ins_x=x, ins_y=y,
                ins_w=w if self._weighted else None,
                ins_ylv=ylv if lv else fresh.ins_ylv,
                ins_wcum=(wcum if (lv and self._weighted)
                          else fresh.ins_wcum),
                ins_wpmax=(wpmax if (lv and self._weighted)
                           else fresh.ins_wpmax))
        else:
            buf = dataclasses.replace(
                buf, ins_x=fresh.ins_x, ins_y=fresh.ins_y,
                ins_w=fresh.ins_w, ins_ylv=fresh.ins_ylv,
                ins_wcum=fresh.ins_wcum, ins_wpmax=fresh.ins_wpmax)
        self._state = (plan, buf)

    def _point_pool(self, x: float, y: float) -> list:
        """Measures (internal space) of the live-or-tombstoned occurrences
        of (x, y): base occurrences first (x-order), then pending inserts."""
        i0 = np.searchsorted(self._px, x, side="left")
        i1 = np.searchsorted(self._px, x, side="right")
        pool = list(self._pw[i0:i1][self._py[i0:i1] == y])
        for lx, ly, lw in self._ins_log:
            pool.extend(lw[(lx == x) & (ly == y)])
        return pool

    def _find_victim(self, x: float, y: float, extra_tomb: int = 0) -> float:
        """Measure of the occurrence this tombstone removes (KeyError when
        every occurrence is already tombstoned)."""
        tomb = extra_tomb + sum(int(np.sum((lx == x) & (ly == y)))
                                for lx, ly, _ in self._del_log)
        pool = self._point_pool(x, y)
        if tomb >= len(pool):
            raise KeyError(f"delete of point ({x!r}, {y!r}): not present")
        return float(pool[tomb])

    # -- merge / refit (lifecycle in _DeltaBufferedEngine) ----------------

    def _snapshot(self):
        # deep-copy the log arrays: extremal deletes NaN-cancel pending
        # inserts in place on the host log (see DynamicEngine._snapshot)
        self._merge_mark = (len(self._ins_log), len(self._del_log))
        self._residual_vic = []
        return (self._index, self._px, self._py, self._pw,
                [tuple(a.copy() for a in e) for e in self._ins_log],
                [tuple(a.copy() for a in e) for e in self._del_log])

    @staticmethod
    def _flatten3(log):
        if not log:
            z = np.zeros((0,))
            return z, z, z
        return tuple(np.concatenate([e[i] for e in log]) for i in range(3))

    def _merge(self, snap, mark) -> None:
        index, px, py, pw, ins_log, del_log = snap
        ix, iy, iw = (np.array(a) for a in self._flatten3(ins_log))
        dx, dy, dw = self._flatten3(del_log)
        keep = np.ones(len(px), bool)
        for x, y, w in zip(dx, dy, dw):
            # a tombstone cancels a matching pending insert first, then the
            # base occurrence carrying the victim's measure
            m = np.where((ix == x) & (iy == y) & (iw == w)
                         & ~np.isnan(ix))[0]
            if len(m):
                ix[m[0]] = iy[m[0]] = iw[m[0]] = np.nan
                continue
            cand = np.where(keep & (px == x) & (py == y) & (pw == w))[0]
            if not len(cand):
                cand = np.where(keep & (px == x) & (py == y))[0]
            if not len(cand):
                raise KeyError(f"delete of point ({x!r}, {y!r})")
            keep[cand[0]] = False
        alive = ~np.isnan(ix) if len(ix) else np.zeros(0, bool)
        new_px = np.concatenate([px[keep], ix[alive]])
        new_py = np.concatenate([py[keep], iy[alive]])
        new_pw = np.concatenate([pw[keep], iw[alive]])
        if len(new_px) == 0:
            raise ValueError("merge would empty the dataset")
        # net changes only: an insert+delete pair that cancelled inside the
        # buffer never touched the fitted function
        removed = ~keep
        cx = np.concatenate([ix[alive], px[removed]])
        cy = np.concatenate([iy[alive], py[removed]])
        cw = np.concatenate([iw[alive], -pw[removed]])
        new_index, stats = selective_refit_2d(index, new_px, new_py, new_pw,
                                              cx, cy, cw)
        order = np.argsort(new_px, kind="stable")
        # plan built off-lock; listeners (serving AOT pre-compilation) warm
        # against the exact object that will be installed
        new_plan = build_plan_2d(new_index)
        self._notify_install_listeners(new_plan)
        with self._lock:
            residual_ins = [tuple(a[~np.isnan(e[0])] for a in e)
                            for e in self._ins_log[mark[0]:]]
            residual_del = self._del_log[mark[1]:]
            residual_vic = list(self._residual_vic)
            self._install(new_index, new_px[order], new_py[order],
                          new_pw[order], residual_ins, residual_del,
                          residual_vic, plan=new_plan)
            self.last_refit_stats = stats
            self.refit_count += 1

    # -- queries ---------------------------------------------------------

    def _run_rect(self, executor, lx, ux, ly, uy, eps_rel):
        plan, buf = self._state
        if eps_rel is not None and plan.ref_xs is None:
            raise ValueError("Q_rel refinement requires exact arrays")
        qs = [jnp.asarray(q) for q in (lx, ux, ly, uy)]
        n = qs[0].shape[0]
        size = _bucket_size(n, self.min_bucket)
        bq = min(self.bq, size)
        x0, _, y0, _ = plan.root
        fills = (x0, x0, y0, y0)
        padded = [_pad_bucket(q, size, f) for q, f in zip(qs, fills)]
        ans, approx, refined = executor(
            plan, buf, *padded, backend=self.backend, eps_rel=eps_rel,
            interpret=self.interpret, bq=bq)
        return QueryResult(ans[:n], approx[:n], refined[:n])

    def count2d(self, lx, ux, ly, uy,
                eps_rel: Optional[float] = None) -> QueryResult:
        assert self._agg == "count2d", self._agg
        return self._run_rect(_exec_dyn_count2d, lx, ux, ly, uy, eps_rel)

    def sum2d(self, lx, ux, ly, uy,
              eps_rel: Optional[float] = None) -> QueryResult:
        assert self._agg == "sum2d", self._agg
        return self._run_rect(_exec_dyn_sum2d, lx, ux, ly, uy, eps_rel)

    def extremum2d(self, u, v,
                   eps_rel: Optional[float] = None) -> QueryResult:
        assert self._agg in ("max2d", "min2d"), self._agg
        plan, buf = self._state
        if eps_rel is not None and plan.ref_wpmax is None:
            raise ValueError("Q_rel refinement requires exact arrays")
        u, v = jnp.asarray(u), jnp.asarray(v)
        n = u.shape[0]
        size = _bucket_size(n, self.min_bucket)
        bq = min(self.bq, size)
        x0, _, y0, _ = plan.root
        ans, approx, refined = _exec_dyn_dommax2d(
            plan, buf, _pad_bucket(u, size, x0), _pad_bucket(v, size, y0),
            backend=self.backend, eps_rel=eps_rel, interpret=self.interpret,
            bq=bq)
        return QueryResult(ans[:n], approx[:n], refined[:n])

    def query(self, *ranges, eps_rel: Optional[float] = None) -> QueryResult:
        if self._agg == "count2d":
            return self.count2d(*ranges, eps_rel=eps_rel)
        if self._agg == "sum2d":
            return self.sum2d(*ranges, eps_rel=eps_rel)
        return self.extremum2d(*ranges, eps_rel=eps_rel)
