"""LSM-tiered PolyFit: a geometric ladder of immutable plans (DESIGN.md §15).

The single delta buffer of ``DynamicEngine`` has two measured cliffs: a
full merge stalls seconds (``updates2d.merge.*``), and an extremal delete
forces that merge *synchronously* on the write path.  The logarithmic
method converts the index into a hierarchy of geometrically-sized
immutable levels: slot ``s`` holds at most ``capacity * growth**s`` rows,
each level is one ordinary ``IndexPlan``/``IndexPlan2D`` fitted once with
the existing ``build_index_*`` machinery and never touched again, and a
query fuses the O(log n) per-level evaluations exactly —

* SUM/COUNT partials **add** across levels; per-level tombstones are
  exact side arrays (sorted keys + prefix sums, or a merge-sort tree over
  the deleted points), so their subtraction contributes **zero** error and
  the certified bound composes additively over the *data* plans only:
  ``B = sum_k FACTOR * delta_k`` (Lemma 5.2/6.4 shape per level).
* MAX/MIN take a **max** across levels; a deleted extremum is shadowed by
  a per-level victim mask (``vic_keys`` + a victim-masked exact sparse
  table / merge-sort tree) — queries whose range covers a victim fall
  back to the level's exact structure, every other query is answered by
  the untouched fitted plan, and **no delete ever merges eagerly**.

Compactions are the only writes that touch fitted structures: when the
policy fires, levels ``0..s`` (buffer included) merge into one fresh plan
for slot ``s`` on the background merge thread — bounded work proportional
to the compacted rows, never a full-ladder refit — and install atomically.
The trigger is cost-based (``CompactionPolicy``): measured merge latency
per row (from BENCH_updates.json) against the accumulated buffered-query
overhead, with capacity as the hard backstop.

Per-level answers are bit-identical to the flat ``execute_*`` executors
for in-domain queries: every multi-level correction (the below-domain
first-key addend, the out-of-root corner corrections, the validity masks)
is exactly ``+0.0`` / identity when the query lies inside the level's
domain, so a one-level ladder reproduces the flat engine bit for bit.
"""
from __future__ import annotations

import dataclasses
import json
from functools import partial
from pathlib import Path
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.exact import build_sparse_table, sparse_table_range_max
from ..core.index import build_index_1d
from ..core.index2d import (MergeSortTree, build_index_2d, mst_cf_sum,
                            mst_dommax)
from ..core.queries import QueryResult
from ..kernels.poly_eval import DEFAULT_BQ
from .dynamic import (DeltaBuffer, DeltaBuffer2D, _append_1d, _append_2d,
                      _DeltaBufferedEngine, _delta_dommax2d, _delta_max,
                      _delta_sum, _delta_sum2d, _pad_batch)
from .engine import (_bucket_size, _cf_at, _check_backend, _pad_bucket,
                     check_pow2, raw_count2d, raw_eval2d, raw_extremum,
                     raw_sum, truth_count2d, truth_sum, truth_sum2d)
from .plan import (IndexPlan, IndexPlan2D, big_sentinel, build_plan,
                   build_plan_2d)

__all__ = ["LsmLevel", "LsmLevel2D", "LsmPlan", "LsmPlan2D", "LsmEngine",
           "LsmEngine2D", "CompactionPolicy", "composed_bound",
           "execute_lsm", "level_executor", "combine_levels"]


# ---------------------------------------------------------------------------
# pytrees: one immutable level = one fitted plan + exact delete side arrays
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LsmLevel:
    """One immutable 1-D level: the fitted plan plus delete shadows.

    ``tomb_keys``/``tomb_cf`` (SUM/COUNT) are the level's tombstoned
    records — sorted keys + inclusive prefix sums of the deleted
    measures; their range sum is subtracted exactly, adding no error.
    ``vic_keys``/``live_st`` (MAX/MIN) mask deleted extrema: ``vic_keys``
    is the sentinel-padded sorted victim-key array the threat test scans,
    ``live_st`` the exact sparse table with victim slots at -inf (it
    aliases ``plan.ref_st`` until the first victim).  The fitted plan
    itself is never modified — level identity is plan identity.
    """

    plan: IndexPlan
    tomb_keys: Optional[jnp.ndarray]   # (t,) sorted; None when no tombs
    tomb_cf: Optional[jnp.ndarray]     # (t,) inclusive prefix sums
    vic_keys: Optional[jnp.ndarray]    # (vcap,) sorted, sentinel-padded
    live_st: Optional[jnp.ndarray]     # (L, n) victim-masked sparse table
    slot: int

    @property
    def dtype(self):
        return self.plan.dtype


jax.tree_util.register_dataclass(
    LsmLevel,
    data_fields=["plan", "tomb_keys", "tomb_cf", "vic_keys", "live_st"],
    meta_fields=["slot"],
)


@dataclasses.dataclass(frozen=True)
class LsmLevel2D:
    """One immutable 2-D level (rect COUNT/SUM or dominance MAX/MIN).

    Tombstones are a merge-sort tree over the deleted points (weights 1
    for count2d), subtracted via the exact 4-corner ``mst_cf_sum`` path;
    victims mirror the 1-D scheme with a dominance threat test and a
    victim-masked ``live_wpmax`` (aliases ``plan.ref_wpmax`` until the
    first victim).
    """

    plan: IndexPlan2D
    tomb_xs: Optional[jnp.ndarray]          # (t,)
    tomb_ys_levels: Optional[jnp.ndarray]   # (L, t)
    tomb_wcum: Optional[jnp.ndarray]        # (L, t)
    vic_x: Optional[jnp.ndarray]            # (vcap,) sentinel-padded
    vic_y: Optional[jnp.ndarray]            # (vcap,)
    live_wpmax: Optional[jnp.ndarray]       # (L, n) victim-masked
    slot: int

    @property
    def dtype(self):
        return self.plan.dtype


jax.tree_util.register_dataclass(
    LsmLevel2D,
    data_fields=["plan", "tomb_xs", "tomb_ys_levels", "tomb_wcum", "vic_x",
                 "vic_y", "live_wpmax"],
    meta_fields=["slot"],
)


@dataclasses.dataclass(frozen=True)
class LsmPlan:
    """The immutable level ladder, ascending slot order (newest first)."""

    levels: Tuple[LsmLevel, ...]
    agg: str

    @property
    def dtype(self):
        return self.levels[0].plan.dtype

    @property
    def deltas(self) -> Tuple[float, ...]:
        return tuple(lvl.plan.delta for lvl in self.levels)

    @property
    def n(self) -> int:
        return sum(lvl.plan.n for lvl in self.levels)


jax.tree_util.register_dataclass(
    LsmPlan, data_fields=["levels"], meta_fields=["agg"])


@dataclasses.dataclass(frozen=True)
class LsmPlan2D:
    levels: Tuple[LsmLevel2D, ...]
    agg: str

    @property
    def dtype(self):
        return self.levels[0].plan.dtype

    @property
    def deltas(self) -> Tuple[float, ...]:
        return tuple(lvl.plan.delta for lvl in self.levels)

    @property
    def n(self) -> int:
        return sum(lvl.plan.n for lvl in self.levels)


jax.tree_util.register_dataclass(
    LsmPlan2D, data_fields=["levels"], meta_fields=["agg"])


def composed_bound(agg: str, deltas) -> float:
    """Certified |A - R| bound of the fused multi-level answer.

    Tombstone/victim corrections are exact, so only the data plans
    contribute: additive aggregates sum the per-level Lemma bounds,
    extremal ones take the worst level (the max across levels of values
    each within delta_k of its level truth is within max(delta_k))."""
    from ..api.budget import BOUND_FACTOR   # lazy: api imports engine
    f = BOUND_FACTOR[agg]
    if agg in ("max", "min", "max2d", "min2d"):
        return f * max(deltas)
    return f * sum(deltas)


# ---------------------------------------------------------------------------
# per-level cores: flat raw evaluation + exact boundary corrections.
# Every correction is exactly +0.0 / identity for in-domain queries, so a
# single-level ladder is bit-identical to the flat executors per backend.
# ---------------------------------------------------------------------------

def _tomb_sum_1d(lvl: LsmLevel, lq, uq):
    return (_cf_at(lvl.tomb_keys, lvl.tomb_cf, uq)
            - _cf_at(lvl.tomb_keys, lvl.tomb_cf, lq))


def _level_sum(lvl: LsmLevel, lq, uq, *, backend, interpret, bq, with_truth):
    """(partial, truth?) for SUM/COUNT over (lq, uq] against one level."""
    p = lvl.plan
    lo = p.seg_lo[0]
    lqc = jnp.maximum(lq, lo)
    uqc = jnp.maximum(uq, lo)
    part = raw_sum(p, lqc, uqc, backend=backend, interpret=interpret, bq=bq)
    # the fitted CF is inclusive: clamping lq up to the level's first key
    # subtracts ~P(lo) ~= m0, excluding that key's measure from queries
    # that start below this level's domain — add it back (exactly +0.0
    # when the query is in-domain, preserving flat bit-identity)
    m0 = p.ref_cf[0]
    part = part + jnp.where((lq < lo) & (uq >= lo), m0,
                            jnp.zeros((), p.dtype))
    if lvl.tomb_keys is not None:
        part = part - _tomb_sum_1d(lvl, lq, uq)
    if not with_truth:
        return (part,)
    truth = truth_sum(p, lq, uq)
    if lvl.tomb_keys is not None:
        truth = truth - _tomb_sum_1d(lvl, lq, uq)
    return part, truth


def _level_extremum(lvl: LsmLevel, lq, uq, *, backend, interpret, bq,
                    with_truth):
    """(partial, exact, threat) for MAX over [lq, uq] (MAX space).

    The exact live maximum is always computed (two gathers): it both
    refines Q_rel rejections and answers threatened queries (range covers
    a victim) where the fitted plan may over-report a deleted extremum."""
    del with_truth   # extremal levels always carry their exact answer
    p = lvl.plan
    lo = p.seg_lo[0]
    hi = p.seg_hi[p.h - 1]
    lqc = jnp.clip(lq, lo, hi)
    uqc = jnp.clip(uq, lo, hi)
    raw = raw_extremum(p, lqc, uqc, backend=backend, interpret=interpret,
                       bq=bq)
    st = lvl.live_st if lvl.live_st is not None else p.ref_st
    i = jnp.searchsorted(p.ref_keys, lq, side="left")
    j = jnp.searchsorted(p.ref_keys, uq, side="right")
    exact = sparse_table_range_max(st, i, j)
    # a level contributes -inf when it has no live key in range: the fitted
    # staircase is only certified where the level holds data, and letting a
    # key-free level report a segment value would out-shout a smaller true
    # maximum living in another level.  The mask is exact (sparse-table
    # emptiness) and the identity branch is taken for every query that
    # covers a live key, preserving single-level flat bit-identity.
    valid = (uq >= lo) & (lq <= hi) & (exact > -jnp.inf)
    part = jnp.where(valid, raw, -jnp.inf)
    if lvl.vic_keys is not None:
        vk = lvl.vic_keys[None, :]
        threat = jnp.any((lq[:, None] <= vk) & (vk <= uq[:, None]), axis=1)
    else:
        threat = jnp.zeros(lq.shape, bool)
    return part, exact, threat


def _tomb_rect_2d(lvl: LsmLevel2D, lx, ux, ly, uy, dtype):
    cf = lambda u, v: mst_cf_sum(lvl.tomb_xs, lvl.tomb_ys_levels,
                                 lvl.tomb_wcum, u, v)
    return (cf(ux, uy) - cf(lx, uy) - cf(ux, ly) + cf(lx, ly)).astype(dtype)


def _level_rect(lvl: LsmLevel2D, lx, ux, ly, uy, *, backend, interpret, bq,
                with_truth):
    """(partial, truth?) for rect COUNT/SUM against one 2-D level.

    Hybrid clamped-corner scheme: the flat 4-corner evaluation runs on
    root-clamped corners (bit-identical in-domain), then each corner whose
    raw coordinate lies *below* the level's root gets its clamped
    evaluation subtracted back out — CF at such a corner is exactly 0,
    while the clamp left ~CF(root-edge) in the sum (the root edge of a
    level's bounding box always carries mass)."""
    p = lvl.plan
    x0, x1, y0, y1 = p.root
    lxc, uxc = (jnp.clip(q, x0, x1) for q in (lx, ux))
    lyc, uyc = (jnp.clip(q, y0, y1) for q in (ly, uy))
    part = raw_count2d(p, lxc, uxc, lyc, uyc, backend=backend,
                       interpret=interpret, bq=bq)
    zero = jnp.zeros((), p.dtype)
    for u, v, uc, vc, s in ((ux, uy, uxc, uyc, 1.0), (lx, uy, lxc, uyc, -1.0),
                            (ux, ly, uxc, lyc, -1.0), (lx, ly, lxc, lyc, 1.0)):
        e = raw_eval2d(p, uc, vc, backend=backend, interpret=interpret, bq=bq)
        part = part + jnp.where((u < x0) | (v < y0), -s * e, zero)
    if lvl.tomb_xs is not None:
        part = part - _tomb_rect_2d(lvl, lx, ux, ly, uy, p.dtype)
    if not with_truth:
        return (part,)
    truth = (truth_sum2d(p, lx, ux, ly, uy) if p.agg == "sum2d"
             else truth_count2d(p, lx, ux, ly, uy))
    if lvl.tomb_xs is not None:
        truth = truth - _tomb_rect_2d(lvl, lx, ux, ly, uy, p.dtype)
    return part, truth


def _level_dommax(lvl: LsmLevel2D, u, v, *, backend, interpret, bq,
                  with_truth):
    """(partial, exact, threat) for dominance MAX at (u, v) (MAX space)."""
    del with_truth
    p = lvl.plan
    x0, x1, y0, y1 = p.root
    uc = jnp.clip(u, x0, x1)
    vc = jnp.clip(v, y0, y1)
    raw = raw_eval2d(p, uc, vc, backend=backend, interpret=interpret, bq=bq)
    wp = lvl.live_wpmax if lvl.live_wpmax is not None else p.ref_wpmax
    exact = mst_dommax(p.ref_xs, p.ref_ys_levels, wp, u, v).astype(p.dtype)
    # as in 1-D: a level whose dominated set is empty contributes -inf
    # (the fitted staircase's extremal-floor clamp would otherwise report
    # ~level-min for queries dominating nothing in this level — including
    # a fresh buffered point below every level floor, which the exact
    # level-0 correction now answers alone, retiring the flat engine's
    # below-floor eager merge for the LSM path)
    valid = (u >= x0) & (v >= y0) & (exact > -jnp.inf)
    part = jnp.where(valid, raw, -jnp.inf)
    if lvl.vic_x is not None:
        threat = jnp.any((lvl.vic_x[None, :] <= u[:, None])
                         & (lvl.vic_y[None, :] <= v[:, None]), axis=1)
    else:
        threat = jnp.zeros(u.shape, bool)
    return part, exact, threat


_LEVEL_CORES = {
    "sum": _level_sum, "count": _level_sum,
    "max": _level_extremum, "min": _level_extremum,
    "count2d": _level_rect, "sum2d": _level_rect,
    "max2d": _level_dommax, "min2d": _level_dommax,
}


def level_executor(agg: str, *, backend: str, interpret: bool, bq: int,
                   with_truth: bool):
    """A plain callable ``fn(level, *padded_queries)`` with all statics
    closed over — the per-level unit the serving engine AOT-lowers and
    caches by (table, guarantee, bucket, slot), so a compaction evicts
    only the rebuilt levels' executables."""
    core = _LEVEL_CORES[agg]

    def fn(lvl, *qs):
        return core(lvl, *qs, backend=backend, interpret=interpret, bq=bq,
                    with_truth=with_truth)
    return fn


@partial(jax.jit,
         static_argnames=("agg", "backend", "interpret", "bq", "with_truth"))
def _run_level(lvl, *qs, agg: str, backend: str, interpret: bool, bq: int,
               with_truth: bool):
    return _LEVEL_CORES[agg](lvl, *qs, backend=backend, interpret=interpret,
                             bq=bq, with_truth=with_truth)


# ---------------------------------------------------------------------------
# cross-level combiners (jitted once per static signature; the level loop is
# unrolled over the tuple structure, so one compilation per ladder shape)
# ---------------------------------------------------------------------------

def _buf_corr_additive(buf, qs, *, agg, backend, interpret, bq, dtype):
    """Exact level-0 (delta buffer) contribution, answer space.  Only the
    insert side exists: deletes of buffered inserts cancel in place, and
    deletes of level rows become per-level tombstones/victims."""
    if agg in ("sum", "count"):
        lq, uq = qs
        return _delta_sum(lq, uq, buf.ins_keys, buf.ins_vals, buf.ins_cf,
                          backend=backend, interpret=interpret, bq=bq)
    lx, ux, ly, uy = qs
    if agg == "count2d":
        from .dynamic import _delta_count2d
        return _delta_count2d(lx, ux, ly, uy, buf.ins_x, buf.ins_y,
                              buf.ins_ylv, backend=backend,
                              interpret=interpret, bq=bq, dtype=dtype)
    return _delta_sum2d(lx, ux, ly, uy, buf.ins_x, buf.ins_y, buf.ins_w,
                        buf.ins_ylv, buf.ins_wcum, backend=backend,
                        interpret=interpret, bq=bq)


def _buf_corr_extremal(buf, qs, *, agg, backend, interpret, bq):
    """Exact level-0 insert maximum, MAX space."""
    if agg in ("max", "min"):
        lq, uq = qs
        return _delta_max(lq, uq, buf.ins_keys, buf.ins_vals, buf.ins_st,
                          backend=backend, interpret=interpret, bq=bq)
    u, v = qs
    return _delta_dommax2d(u, v, buf.ins_x, buf.ins_y, buf.ins_w,
                           buf.ins_ylv, buf.ins_wpmax, backend=backend,
                           interpret=interpret, bq=bq)


@partial(jax.jit, static_argnames=("agg", "backend", "eps_rel", "interpret",
                                   "bq", "bound", "has_buf"))
def _combine_additive(parts, truths, buf, qs, *, agg: str, backend: str,
                      eps_rel, interpret: bool, bq: int, bound: float,
                      has_buf: bool):
    """SUM/COUNT/rect2d fusion: per-level partials add; the composed bound
    drives the same acceptance shape the flat executors use (identical
    floats for a one-level ladder)."""
    total = parts[0]
    for p in parts[1:]:
        total = total + p
    corr = None
    if has_buf:
        corr = _buf_corr_additive(buf, qs, agg=agg, backend=backend,
                                  interpret=interpret, bq=bq,
                                  dtype=total.dtype)
        total = total + corr
    if eps_rel is None:
        return total, total, jnp.zeros(total.shape, bool)
    if agg in ("sum", "count"):
        # Lemma 5.2 shape with the composed bound B = sum_k 2*delta_k
        ok = ((total - bound > 0)
              & (bound / jnp.maximum(total - bound, 1e-300) <= eps_rel))
    else:
        # Lemma 6.4 shape with B = sum_k 4*delta_k
        ok = total >= bound * (1.0 + 1.0 / eps_rel)
    truth = truths[0]
    for t in truths[1:]:
        truth = truth + t
    if corr is not None:
        truth = truth + corr
    return jnp.where(ok, total, truth), total, ~ok


@partial(jax.jit, static_argnames=("agg", "backend", "eps_rel", "interpret",
                                   "bq", "bound", "has_buf"))
def _combine_extremal(parts, exacts, threats, buf, qs, *, agg: str,
                      backend: str, eps_rel, interpret: bool, bq: int,
                      bound: float, has_buf: bool):
    """MAX/MIN fusion (MAX space in, answer space out): partials max
    across levels; any threatened level (range covers a victim) forces
    the exact answer — which is free, because every level already carries
    its exact live maximum."""
    approx = parts[0]
    exact = exacts[0]
    threat = threats[0]
    for p, e, t in zip(parts[1:], exacts[1:], threats[1:]):
        approx = jnp.maximum(approx, p)
        exact = jnp.maximum(exact, e)
        threat = threat | t
    if has_buf:
        ins = _buf_corr_extremal(buf, qs, agg=agg, backend=backend,
                                 interpret=interpret, bq=bq)
        approx = jnp.maximum(approx, ins)
        exact = jnp.maximum(exact, ins)
    neg = agg in ("min", "min2d")
    if eps_rel is None:
        ans = jnp.where(threat, exact, approx)
        if neg:
            ans, approx = -ans, -approx
        return ans, approx, threat
    # Lemma 5.4 shape with B = max_k delta_k; threats always refine
    ok = (~threat) & (approx >= bound * (1.0 + 1.0 / eps_rel))
    ans = jnp.where(ok, approx, exact)
    if neg:
        ans, approx = -ans, -approx
    return ans, approx, ~ok


def combine_levels(agg: str, level_outs, buf, qs, *, backend: str,
                   eps_rel, interpret: bool, bq: int, bound: float):
    """Fuse per-level core outputs (+ optional delta buffer) into the
    final (ans, approx, refined) triple."""
    has_buf = buf is not None
    if buf is None:
        buf = ()
    if agg in ("max", "min", "max2d", "min2d"):
        parts, exacts, threats = zip(*level_outs)
        return _combine_extremal(parts, exacts, threats, buf, tuple(qs),
                                 agg=agg, backend=backend, eps_rel=eps_rel,
                                 interpret=interpret, bq=bq, bound=bound,
                                 has_buf=has_buf)
    parts = tuple(o[0] for o in level_outs)
    truths = (tuple(o[1] for o in level_outs)
              if eps_rel is not None else ())
    return _combine_additive(parts, truths, buf, tuple(qs), agg=agg,
                             backend=backend, eps_rel=eps_rel,
                             interpret=interpret, bq=bq, bound=bound,
                             has_buf=has_buf)


# ---------------------------------------------------------------------------
# the unified multi-level driver (session + serving + engine all route here)
# ---------------------------------------------------------------------------

def execute_lsm(lsm, buf, ranges, *, backend: str = "xla", eps_rel=None,
                interpret: bool = True, bq: int = DEFAULT_BQ,
                min_bucket: int = 64, level_runner=None) -> QueryResult:
    """Execute a query batch against an ``LsmPlan``/``LsmPlan2D`` ladder
    plus an optional level-0 delta buffer.

    ``level_runner(i, level, *padded_queries)`` overrides the per-level
    evaluation — the serving engine passes its AOT-compiled per-level
    executables here, so served answers are the session path's by
    construction.  The default is the module-level jitted core."""
    _check_backend(backend)
    agg = lsm.agg
    if agg in ("max", "min") and backend in ("pallas", "pallas_scan", "ref") \
            and any(l.plan.deg > 3 for l in lsm.levels):
        backend = "xla"   # no in-kernel closed form past deg 3 (flat rule)
    check_pow2("bq", bq)
    check_pow2("min_bucket", min_bucket)
    dt = lsm.dtype
    qs = [jnp.asarray(q).astype(dt) for q in ranges]
    n = qs[0].shape[0]
    size = _bucket_size(n, min_bucket)
    bq = min(bq, size)
    from .engine import pad_fills
    fills = pad_fills(lsm.levels[0].plan)
    qs = [_pad_bucket(q, size, jnp.asarray(f, dt))
          for q, f in zip(qs, fills)]
    with_truth = eps_rel is not None
    if level_runner is None:
        def level_runner(i, lvl, *padded):
            return _run_level(lvl, *padded, agg=agg, backend=backend,
                              interpret=interpret, bq=bq,
                              with_truth=with_truth)
    outs = [level_runner(i, lvl, *qs) for i, lvl in enumerate(lsm.levels)]
    bound = composed_bound(agg, lsm.deltas)
    ans, approx, refined = combine_levels(
        agg, outs, buf, qs, backend=backend, eps_rel=eps_rel,
        interpret=interpret, bq=bq, bound=bound)
    return QueryResult(ans[:n], approx[:n], refined[:n])


# ---------------------------------------------------------------------------
# cost-based compaction policy (retires the capacity/drift trigger)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CompactionPolicy:
    """Compact when the accumulated buffered-query overhead has paid for
    the merge, with capacity (and a watermark fraction of it) as hard
    backstops.  Coefficients come from the measured records in
    BENCH_updates.json (``from_bench``): merge cost scales per compacted
    row, buffered-query overhead per (query x buffered row)."""

    watermark: float = 0.5
    merge_us_per_row: float = 75.0
    query_overhead_us_per_row: float = 1e-3
    shadow_fraction: float = 0.25
    source: str = "defaults"

    @classmethod
    def from_bench(cls, path: Optional[str] = None, *,
                   dim: int = 1) -> "CompactionPolicy":
        cands = ([Path(path)] if path else []) + [
            Path.cwd() / "BENCH_updates.json",
            Path(__file__).resolve().parents[3] / "BENCH_updates.json",
        ]
        for p in cands:
            try:
                records = json.loads(p.read_text())
            except (OSError, ValueError):
                continue
            merge_us = overhead = None
            for rec in records:
                meta = rec.get("meta", {})
                if int(meta.get("dim", 1)) != dim:
                    continue
                n = meta.get("n") or meta.get("n2")
                cap = meta.get("capacity")
                full = post = None
                for r in rec.get("results", []):
                    us = r.get("us_per_query")
                    if us is None:
                        continue
                    name = r.get("name", "")
                    if ".merge." in name and n:
                        merge_us = max(merge_us or 0.0, us / float(n))
                    if ".query_full." in name:
                        full = max(full or 0.0, us)
                    if ".query_postmerge." in name:
                        post = max(post or 0.0, us)
                if full is not None and post is not None and cap:
                    overhead = max(overhead or 0.0,
                                   max(0.0, full - post) / float(cap))
            if merge_us is not None:
                return cls(merge_us_per_row=merge_us,
                           query_overhead_us_per_row=overhead or 1e-3,
                           source=str(p))
        return cls()

    def should_compact(self, *, n_pending: int, capacity: int,
                       queries_since: int, rows_to_compact: int) -> bool:
        if n_pending <= 0:
            return False
        if n_pending >= capacity or n_pending >= self.watermark * capacity:
            return True
        debt = queries_since * self.query_overhead_us_per_row * n_pending
        return debt >= self.merge_us_per_row * max(rows_to_compact, 1)

    def should_fold(self, *, shadow_rows: int, live_rows: int) -> bool:
        """Fold a level whose tombstone/victim mass dominates its live
        mass: shadow rows are carried (and subtracted/masked) by every
        query over the level yet answer nothing, so past the fraction
        the one-time merge pays for itself — and without it the mass is
        carried *forever* (deletes never merge on their own)."""
        if shadow_rows <= 0:
            return False
        return shadow_rows >= self.shadow_fraction * max(live_rows, 1)


# ---------------------------------------------------------------------------
# host-side level bookkeeping
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _HostLevel:
    """Mutable host mirror of one immutable level: the raw sorted columns
    (internal measure space, positions aligned with ``plan.ref_*``), the
    fitted index, the cached device level, and the delete shadows as
    ``(pos, *record)`` tuples.  The device *plan* object is reused across
    shadow refreshes — level identity (what the serving AOT cache keys
    on) is plan identity, and deletes never change it."""

    slot: int
    cols: Tuple[np.ndarray, ...]
    index: object
    level: object = None
    tomb: List[tuple] = dataclasses.field(default_factory=list)
    vic: List[tuple] = dataclasses.field(default_factory=list)

    def live_rows(self) -> int:
        return len(self.cols[0]) - len(self.tomb) - len(self.vic)

    def shadowed(self) -> set:
        return {r[0] for r in self.tomb} | {r[0] for r in self.vic}


def _pow2_at_least(n: int) -> int:
    return max(1, 1 << (max(n, 1) - 1).bit_length())


class _LsmBase(_DeltaBufferedEngine):
    """Shared LSM lifecycle: the geometric slot ladder, delete shadowing,
    NaN-cancel of buffered inserts, level compaction with residual replay,
    and the cost-based trigger.  Subclasses supply the dim-specific hooks
    (column arity, index/plan builders, level refresh, buffer appends).

    Writes are serialized by the inherited lock; queries are lock-free
    against the immutable ``(LsmPlan, DeltaBuffer)`` snapshot in
    ``self._state``.  Deletes NEVER merge: they shadow a row of the oldest
    level holding it (tombstone for additive aggregates, victim mask for
    extremal ones) or cancel a pending buffered insert in place — the
    worst-case delete cost is one shadow-structure rebuild, not a refit.
    """

    def _init_lsm(self, *, agg: str, backend: str, capacity: int,
                  growth: int, interpret: bool, bq: int, min_bucket: int,
                  auto_refit: bool, background: bool, policy, dim: int) -> None:
        if growth < 2:
            raise ValueError(f"growth must be >= 2, got {growth}")
        self._init_dynamic(backend=backend, capacity=capacity,
                           interpret=interpret, bq=bq,
                           min_bucket=min_bucket, auto_refit=auto_refit,
                           background=background)
        self._agg = agg
        self.growth = int(growth)
        self.policy = policy or CompactionPolicy.from_bench(dim=dim)
        self.compaction_count = 0
        self._levels: dict = {}
        self._ins_log: List[Tuple[np.ndarray, ...]] = []
        self._del_log: List[tuple] = []   # always empty (refit-mark compat)
        self._n_pending = 0
        self._queries_since = 0
        self._merging_slots: set = set()
        self._merge_mark_ins = 0
        self._residual_shadow: List[tuple] = []

    # -- basic accessors --------------------------------------------------

    @property
    def agg(self) -> str:
        return self._agg

    @property
    def _extremal(self) -> bool:
        return self._agg in ("max", "min", "max2d", "min2d")

    @property
    def plan(self):
        """The installed multi-level plan (``LsmPlan``/``LsmPlan2D``)."""
        return self._state[0]

    lsm_plan = plan

    @property
    def n_levels(self) -> int:
        return len(self._levels)

    @property
    def n(self) -> int:
        """Live rows across the ladder + buffered inserts."""
        return (sum(h.live_rows() for h in self._levels.values())
                + self._n_pending)

    @property
    def _dtype(self):
        return next(iter(self._levels.values())).level.plan.dtype

    def _ladder(self):
        return self._make_plan(tuple(self._levels[s].level
                                     for s in sorted(self._levels)))

    # -- construction -----------------------------------------------------

    def _initial_install(self, cols: Tuple[np.ndarray, ...]) -> None:
        if len(cols[0]) == 0:
            raise ValueError("an LSM engine needs at least one record")
        s = 1
        while len(cols[0]) > self.capacity * self.growth ** s:
            s += 1
        host = self._build_host(s, cols)
        with self._lock:
            self._levels = {s: host}
            self._state = (self._ladder(), self._empty_buf())

    # -- geometric slot ladder --------------------------------------------

    def _pick_slot(self) -> int:
        """Smallest slot whose geometric budget holds the buffer plus every
        level at or below it (the logarithmic-method invariant: slot s
        carries at most capacity * growth**s rows)."""
        s = 1
        while True:
            rows = self._n_pending + sum(
                h.live_rows() for k, h in self._levels.items() if k <= s)
            if rows <= self.capacity * self.growth ** s:
                return s
            s += 1

    def _shadow_slots(self) -> set:
        """Slots whose delete-shadow mass crossed the fold fraction."""
        return {s for s, h in self._levels.items()
                if self.policy.should_fold(
                    shadow_rows=len(h.tomb) + len(h.vic),
                    live_rows=h.live_rows())}

    def _has_forced_work(self) -> bool:
        # a shadow-heavy level must fold even with zero pending inserts
        # (the base _start_refit guard would otherwise no-op the merge)
        return bool(self._shadow_slots())

    def _should_compact(self) -> bool:
        if self._shadow_slots():
            return True
        s = self._pick_slot()
        rows = self._n_pending + sum(
            h.live_rows() for k, h in self._levels.items() if k <= s)
        return self.policy.should_compact(
            n_pending=self._n_pending, capacity=self.capacity,
            queries_since=self._queries_since, rows_to_compact=rows)

    # -- inserts ----------------------------------------------------------

    def _log_ins(self, *cols) -> None:
        if self._n_pending + len(cols[0]) > self.capacity:
            raise RuntimeError("delta buffer overflow: concurrent writers "
                               "bypassed _ensure_room")
        ladder, buf = self._state
        buf = self._buf_append(buf, *cols)
        self._ins_log.append(tuple(cols))
        self._state = (ladder, buf)
        self._n_pending += len(cols[0])

    def _insert_batch(self, cols: Tuple[np.ndarray, ...]) -> None:
        self._raise_refit_error()
        self._ensure_room(len(cols[0]))
        with self._lock:
            self._log_ins(*cols)
            trigger = self.auto_refit and self._should_compact()
        if trigger:
            self.refit(wait=not self.background)

    # -- deletes (never merge) --------------------------------------------

    def _delete_batch(self, recs: List) -> None:
        """Shadow each record: oldest level holding it first (largest
        slot), then the pending-insert log (cancelled in place by
        NaN-marking).  Raises KeyError on a record with no live
        occurrence; records earlier in the batch stay applied."""
        self._raise_refit_error()
        with self._lock:
            dirty: set = set()
            nan_dirty = False
            try:
                for r in recs:
                    nan_dirty |= self._delete_one(r, dirty)
            finally:
                for slot in dirty:
                    h = self._levels[slot]
                    h.level = self._refresh_level(h)
                buf = self._state[1]
                if nan_dirty:
                    buf = self._rebuild_buf()
                self._state = (self._ladder(), buf)
            trigger = self.auto_refit and bool(self._shadow_slots())
        if trigger:
            self.refit(wait=not self.background)

    def _delete_one(self, rec, dirty: set) -> bool:
        for slot in sorted(self._levels, reverse=True):   # oldest first
            h = self._levels[slot]
            pos = self._find_in_level(h, rec)
            if pos is None:
                continue
            record = self._level_record(h, pos)
            (h.vic if self._extremal else h.tomb).append((pos,) + record)
            dirty.add(slot)
            if slot in self._merging_slots:
                # this row was copied into the in-flight compaction before
                # we shadowed it; re-apply the shadow on the fresh level
                self._residual_shadow.append(record)
            return False
        hit = self._find_in_ins(rec)
        if hit is not None:
            e, j = hit
            record = self._nan_mark(e, j)
            self._n_pending -= 1
            if self._merging_slots and e < self._merge_mark_ins:
                # the merge snapshot copied this entry un-cancelled
                self._residual_shadow.append(record)
            return True
        raise KeyError(f"delete of {rec!r}: no live occurrence")

    def _rebuild_buf(self):
        """Fresh device buffer from the surviving (non-NaN) insert log —
        one fused append, so a cancel costs one dispatch like an insert."""
        buf = self._empty_buf()
        cols = [[] for _ in range(self._ncols)]
        for e in self._ins_log:
            alive = ~np.isnan(np.asarray(e[0]))
            if alive.any():
                for i, c in enumerate(e):
                    cols[i].append(np.asarray(c)[alive])
        if cols[0]:
            buf = self._buf_append(buf, *(np.concatenate(c) for c in cols))
        return buf

    # -- compaction (merge lifecycle in _DeltaBufferedEngine) -------------

    def _snapshot(self):
        # under self._lock (called from _start_refit)
        s = self._pick_slot()
        # shadow-heavy levels fold regardless of their slot; growing the
        # target slot until the geometric budget holds everything included
        # preserves the ladder invariant (each bump may pull in more
        # slots <= s, so recompute until it settles)
        forced = self._shadow_slots()
        while True:
            include = sorted({k for k in self._levels if k <= s} | forced)
            rows = self._n_pending + sum(
                self._levels[k].live_rows() for k in include)
            if rows <= self.capacity * self.growth ** s:
                break
            s += 1
        ins = [tuple(np.array(a, copy=True) for a in e)
               for e in self._ins_log]
        hosts = []
        for slot in include:
            h = self._levels[slot]
            cols = tuple(np.array(c, copy=True) for c in h.cols)
            hosts.append((slot, cols, sorted(h.shadowed())))
        self._merging_slots = {slot for slot, _, _ in hosts}
        self._merge_mark_ins = len(self._ins_log)
        self._residual_shadow = []
        return (s, ins, hosts)

    def _merge_rows(self, ins_log, hosts) -> Tuple[np.ndarray, ...]:
        parts: List[List[np.ndarray]] = [[] for _ in range(self._ncols)]
        for _, cols, dead in hosts:
            keep = np.ones(len(cols[0]), bool)
            if dead:
                keep[np.asarray(dead, int)] = False
            for i, c in enumerate(cols):
                parts[i].append(c[keep])
        for e in ins_log:
            alive = ~np.isnan(np.asarray(e[0]))
            for i, c in enumerate(e):
                parts[i].append(np.asarray(c)[alive])
        cols = tuple(np.concatenate(p) if p else np.zeros(0)
                     for p in parts)
        order = np.argsort(cols[0], kind="stable")
        return tuple(c[order] for c in cols)

    def _merge(self, snap, mark) -> None:
        s, ins_log, hosts = snap
        cols = self._merge_rows(ins_log, hosts)
        # the fit runs OFF-lock on the merge thread: bounded work
        # proportional to the compacted rows, never a full-ladder refit
        new_host = self._build_host(s, cols) if len(cols[0]) else None
        with self._lock:
            preview_levels = {slot: h.level
                              for slot, h in self._levels.items()
                              if slot not in self._merging_slots}
            listeners = list(self._install_listeners)
        if new_host is not None:
            preview_levels[s] = new_host.level
        if preview_levels and listeners:
            # plan-swap pre-compilation hook: the serving engine lowers the
            # incoming ladder here, still on the merge thread, so the
            # post-install dispatch path never pays a relower
            preview = self._make_plan(tuple(
                preview_levels[k] for k in sorted(preview_levels)))
            self._notify_install_listeners(preview)
        with self._lock:
            if new_host is not None:
                for record in self._residual_shadow:
                    self._apply_shadow(new_host, record)
                if self._residual_shadow:
                    new_host.level = self._refresh_level(new_host)
            elif self._residual_shadow:
                raise RuntimeError("internal: residual delete shadows with "
                                   "an empty compaction output")
            levels = {slot: h for slot, h in self._levels.items()
                      if slot not in self._merging_slots}
            if new_host is not None:
                levels[s] = new_host
            if not levels:
                raise ValueError("compaction would empty the dataset")
            residual_ins = self._ins_log[mark[0]:]
            self._levels = levels
            self._ins_log = []
            self._del_log = []
            self._n_pending = 0
            self._merging_slots = set()
            self._merge_mark_ins = 0
            self._residual_shadow = []
            self._queries_since = 0
            self._state = (self._ladder(), self._empty_buf())
            for e in residual_ins:
                alive = ~np.isnan(np.asarray(e[0]))
                if alive.any():
                    self._log_ins(*(np.asarray(c)[alive] for c in e))
            self.refit_count += 1
            self.compaction_count += 1

    # -- queries ----------------------------------------------------------

    def _query(self, ranges, eps_rel):
        self._queries_since += 1
        lsm, buf = self._state   # one atomic snapshot
        return execute_lsm(lsm, buf, ranges, backend=self.backend,
                           eps_rel=eps_rel, interpret=self.interpret,
                           bq=self.bq, min_bucket=self.min_bucket)


class LsmEngine(_LsmBase):
    """LSM-tiered 1-D PolyFit (COUNT/SUM/MAX/MIN): a mutable delta buffer
    plus a geometric ladder of immutable fitted plans.  Worst-case update
    cost is bounded by the compacted size (never a full refit); extremal
    deletes shadow their victim and never merge."""

    _ncols = 2

    def __init__(self, keys, measures=None, *, agg: str = "sum",
                 deg: int = 2, delta: float = 100.0, backend: str = "xla",
                 capacity: int = 1024, growth: int = 4,
                 interpret: bool = True, bq: int = DEFAULT_BQ,
                 min_bucket: int = 64, auto_refit: bool = True,
                 background: bool = False, policy=None):
        if agg not in ("sum", "count", "max", "min"):
            raise ValueError(f"unknown 1-D aggregate {agg!r}")
        _check_backend(backend)
        self.deg = deg
        self.delta = delta
        self._init_lsm(agg=agg, backend=backend, capacity=capacity,
                       growth=growth, interpret=interpret, bq=bq,
                       min_bucket=min_bucket, auto_refit=auto_refit,
                       background=background, policy=policy, dim=1)
        keys = np.array(np.atleast_1d(np.asarray(keys, np.float64)))
        meas = self._norm_measures(keys, measures)
        order = np.argsort(keys, kind="stable")
        self._initial_install((keys[order], meas[order]))

    # -- dim hooks --------------------------------------------------------

    def _norm_measures(self, keys, measures) -> np.ndarray:
        if measures is None:
            if self._agg != "count":
                raise ValueError("measures required unless agg='count'")
            return np.ones_like(keys)
        m = np.broadcast_to(np.asarray(measures, np.float64),
                            keys.shape).copy()
        if self._agg == "count":
            m = np.ones_like(keys)
        if self._agg == "min":
            m = -m   # internal MAX space, mirroring the static index
        return m

    def _build_host(self, slot: int, cols) -> _HostLevel:
        keys, meas = cols
        if self._agg == "count":
            raw = None
        elif self._agg == "min":
            raw = -meas   # build negates again into internal space
        else:
            raw = meas
        index = build_index_1d(keys, raw, self._agg, deg=self.deg,
                               delta=self.delta, keep_exact=True)
        h = _HostLevel(slot=slot, cols=(keys, meas), index=index)
        h.level = self._refresh_level(h)
        return h

    def _refresh_level(self, h: _HostLevel) -> LsmLevel:
        plan = h.level.plan if h.level is not None else build_plan(h.index)
        dt = plan.dtype
        big = big_sentinel(dt)
        if self._extremal:
            vic_keys = live_st = None
            if h.vic:
                nv = len(h.vic)
                vcap = max(self.capacity, _pow2_at_least(nv))
                vk = np.full(vcap, big)
                vk[:nv] = np.sort(np.float64([r[1] for r in h.vic]))
                vic_keys = jnp.asarray(vk, dt)
                meas = np.array(h.cols[1], np.float64, copy=True)
                meas[[r[0] for r in h.vic]] = -np.inf
                live_st = jnp.asarray(build_sparse_table(meas), dt)
            return LsmLevel(plan=plan, tomb_keys=None, tomb_cf=None,
                            vic_keys=vic_keys, live_st=live_st, slot=h.slot)
        tomb_keys = tomb_cf = None
        if h.tomb:
            nt = len(h.tomb)
            tcap = _pow2_at_least(nt)
            order = np.argsort(np.float64([r[1] for r in h.tomb]),
                               kind="stable")
            tk = np.full(tcap, big)
            tv = np.zeros(tcap)
            tk[:nt] = np.float64([r[1] for r in h.tomb])[order]
            tv[:nt] = np.float64([r[2] for r in h.tomb])[order]
            tomb_keys = jnp.asarray(tk, dt)
            tomb_cf = jnp.asarray(np.cumsum(tv), dt)
        return LsmLevel(plan=plan, tomb_keys=tomb_keys, tomb_cf=tomb_cf,
                        vic_keys=None, live_st=None, slot=h.slot)

    def _find_in_level(self, h: _HostLevel, key) -> Optional[int]:
        i0 = np.searchsorted(h.cols[0], key, side="left")
        i1 = np.searchsorted(h.cols[0], key, side="right")
        dead = h.shadowed()
        for pos in range(i0, i1):
            if pos not in dead:
                return pos
        return None

    def _level_record(self, h: _HostLevel, pos: int) -> tuple:
        return (float(h.cols[0][pos]), float(h.cols[1][pos]))

    def _find_in_ins(self, key) -> Optional[Tuple[int, int]]:
        for e, (k, _) in enumerate(self._ins_log):
            j = np.where((k == key) & ~np.isnan(k))[0]
            if len(j):
                return e, int(j[0])
        return None

    def _nan_mark(self, e: int, j: int) -> tuple:
        k, v = self._ins_log[e]
        record = (float(k[j]), float(v[j]))
        k[j] = np.nan
        v[j] = np.nan
        return record

    def _apply_shadow(self, h: _HostLevel, record: tuple) -> None:
        key, val = record
        dead = h.shadowed()
        i0 = np.searchsorted(h.cols[0], key, side="left")
        i1 = np.searchsorted(h.cols[0], key, side="right")
        cand = [p for p in range(i0, i1) if p not in dead]
        if not cand:
            raise KeyError(f"residual delete of key {key!r}: not present "
                           "in the compacted level")
        match = [p for p in cand if float(h.cols[1][p]) == val]
        pos = (match or cand)[0]
        (h.vic if self._extremal else h.tomb).append(
            (pos, key, float(h.cols[1][pos])))

    def _make_plan(self, levels) -> LsmPlan:
        return LsmPlan(levels=levels, agg=self._agg)

    def _empty_buf(self) -> DeltaBuffer:
        return DeltaBuffer.empty(
            self.capacity, self._dtype,
            with_st=(self._extremal and self.backend == "pallas"))

    def _buf_append(self, buf: DeltaBuffer, keys, vals) -> DeltaBuffer:
        dt = self._dtype
        pk = _pad_batch(keys, big_sentinel(dt), dt)
        pv = _pad_batch(vals, 0.0, dt)
        ik, iv, icf, st = _append_1d(buf.ins_keys, buf.ins_vals, pk, pv,
                                     cap=buf.cap,
                                     with_st=buf.ins_st is not None)
        return dataclasses.replace(buf, ins_keys=ik, ins_vals=iv,
                                   ins_cf=icf, ins_st=st)

    # -- public API -------------------------------------------------------

    def insert(self, keys, measures=None) -> None:
        """Buffer a batch of new (key, measure) records."""
        keys = np.array(np.atleast_1d(np.asarray(keys, np.float64)))
        meas = self._norm_measures(keys, measures)
        self._insert_batch((keys, meas))

    def delete(self, keys) -> None:
        """Delete one live occurrence per key — tombstone/victim shadowing
        only, NEVER a merge (KeyError if a key has no live occurrence)."""
        keys = np.atleast_1d(np.asarray(keys, np.float64))
        self._delete_batch([float(k) for k in keys])

    def sum(self, lq, uq, eps_rel: Optional[float] = None) -> QueryResult:
        assert self._agg in ("sum", "count"), self._agg
        return self._query((lq, uq), eps_rel)

    count = sum

    def extremum(self, lq, uq,
                 eps_rel: Optional[float] = None) -> QueryResult:
        assert self._agg in ("max", "min"), self._agg
        return self._query((lq, uq), eps_rel)

    def query(self, lq, uq, eps_rel: Optional[float] = None) -> QueryResult:
        return self._query((lq, uq), eps_rel)


class LsmEngine2D(_LsmBase):
    """LSM-tiered 2-D PolyFit (rect COUNT/SUM, dominance MAX/MIN).

    Identical lifecycle to ``LsmEngine`` over (x, y[, w]) point columns.
    Note dominance MAX/MIN inserts below the extremal floor need NO eager
    refit here (unlike ``DynamicEngine2D``): the level cores mask
    empty-dominated-set levels to -inf and the buffered point's exact
    correction answers alone."""

    _ncols = 3

    def __init__(self, px, py, measures=None, *, agg: str = "count2d",
                 deg: int = 3, delta: float = 100.0, grid: int = 8,
                 max_depth: int = 12, backend: str = "xla",
                 capacity: int = 1024, growth: int = 4,
                 interpret: bool = True, bq: int = DEFAULT_BQ,
                 min_bucket: int = 64, auto_refit: bool = True,
                 background: bool = False, policy=None):
        if agg not in ("count2d", "sum2d", "max2d", "min2d"):
            raise ValueError(f"unknown 2-D aggregate {agg!r}")
        _check_backend(backend)
        self.deg = deg
        self.delta = delta
        self.grid = grid
        self.max_depth = max_depth
        self._init_lsm(agg=agg, backend=backend, capacity=capacity,
                       growth=growth, interpret=interpret, bq=bq,
                       min_bucket=min_bucket, auto_refit=auto_refit,
                       background=background, policy=policy, dim=2)
        px = np.array(np.atleast_1d(np.asarray(px, np.float64)))
        py = np.array(np.atleast_1d(np.asarray(py, np.float64)))
        pw = self._norm_measures(px, measures)
        order = np.argsort(px, kind="stable")
        self._initial_install((px[order], py[order], pw[order]))

    @property
    def _weighted(self) -> bool:
        return self._agg != "count2d"

    # -- dim hooks --------------------------------------------------------

    def _norm_measures(self, px, ws) -> np.ndarray:
        if not self._weighted:
            if ws is not None:
                raise ValueError("measures only apply to sum2d/max2d/min2d")
            return np.ones_like(px)
        if ws is None:
            raise ValueError(f"measures required for agg={self._agg!r}")
        w = np.broadcast_to(np.asarray(ws, np.float64), px.shape).copy()
        if self._agg == "min2d":
            w = -w
        return w

    def _build_host(self, slot: int, cols) -> _HostLevel:
        px, py, pw = cols
        if self._agg == "count2d":
            raw = None
        elif self._agg == "min2d":
            raw = -pw   # build negates again into internal space
        else:
            raw = pw
        index = build_index_2d(px, py, raw, self._agg, deg=self.deg,
                               delta=self.delta, grid=self.grid,
                               max_depth=self.max_depth, keep_exact=True)
        h = _HostLevel(slot=slot, cols=(px, py, pw), index=index)
        h.level = self._refresh_level(h)
        return h

    def _refresh_level(self, h: _HostLevel) -> LsmLevel2D:
        plan = (h.level.plan if h.level is not None
                else build_plan_2d(h.index))
        dt = plan.dtype
        big = big_sentinel(dt)
        if self._extremal:
            vic_x = vic_y = live_wpmax = None
            if h.vic:
                nv = len(h.vic)
                vcap = max(self.capacity, _pow2_at_least(nv))
                vx = np.full(vcap, big)
                vy = np.full(vcap, big)
                vx[:nv] = np.float64([r[1] for r in h.vic])
                vy[:nv] = np.float64([r[2] for r in h.vic])
                vic_x = jnp.asarray(vx, dt)
                vic_y = jnp.asarray(vy, dt)
                ws = np.array(h.cols[2], np.float64, copy=True)
                ws[[r[0] for r in h.vic]] = -np.inf
                t = MergeSortTree.build(h.cols[0], h.cols[1], ws=ws)
                live_wpmax = jnp.asarray(t.wpmax_levels, dt)
            return LsmLevel2D(plan=plan, tomb_xs=None, tomb_ys_levels=None,
                              tomb_wcum=None, vic_x=vic_x, vic_y=vic_y,
                              live_wpmax=live_wpmax, slot=h.slot)
        tomb_xs = tomb_ys_levels = tomb_wcum = None
        if h.tomb:
            nt = len(h.tomb)
            tcap = _pow2_at_least(nt)
            tx = np.full(tcap, big)
            ty = np.full(tcap, big)
            tw = np.zeros(tcap)
            tx[:nt] = np.float64([r[1] for r in h.tomb])
            ty[:nt] = np.float64([r[2] for r in h.tomb])
            tw[:nt] = np.float64([r[3] for r in h.tomb])
            t = MergeSortTree.build(tx, ty, ws=tw)
            tomb_xs = jnp.asarray(t.xs, dt)
            tomb_ys_levels = jnp.asarray(t.ys_levels, dt)
            tomb_wcum = jnp.asarray(t.wcum_levels, dt)
        return LsmLevel2D(plan=plan, tomb_xs=tomb_xs,
                          tomb_ys_levels=tomb_ys_levels,
                          tomb_wcum=tomb_wcum, vic_x=None, vic_y=None,
                          live_wpmax=None, slot=h.slot)

    def _find_in_level(self, h: _HostLevel, rec) -> Optional[int]:
        x, y = rec
        i0 = np.searchsorted(h.cols[0], x, side="left")
        i1 = np.searchsorted(h.cols[0], x, side="right")
        dead = h.shadowed()
        for pos in range(i0, i1):
            if pos not in dead and h.cols[1][pos] == y:
                return pos
        return None

    def _level_record(self, h: _HostLevel, pos: int) -> tuple:
        return (float(h.cols[0][pos]), float(h.cols[1][pos]),
                float(h.cols[2][pos]))

    def _find_in_ins(self, rec) -> Optional[Tuple[int, int]]:
        x, y = rec
        for e, (lx, ly, _) in enumerate(self._ins_log):
            j = np.where((lx == x) & (ly == y) & ~np.isnan(lx))[0]
            if len(j):
                return e, int(j[0])
        return None

    def _nan_mark(self, e: int, j: int) -> tuple:
        lx, ly, lw = self._ins_log[e]
        record = (float(lx[j]), float(ly[j]), float(lw[j]))
        lx[j] = np.nan
        ly[j] = np.nan
        lw[j] = np.nan
        return record

    def _apply_shadow(self, h: _HostLevel, record: tuple) -> None:
        x, y, w = record
        dead = h.shadowed()
        i0 = np.searchsorted(h.cols[0], x, side="left")
        i1 = np.searchsorted(h.cols[0], x, side="right")
        cand = [p for p in range(i0, i1)
                if p not in dead and h.cols[1][p] == y]
        if not cand:
            raise KeyError(f"residual delete of point ({x!r}, {y!r}): not "
                           "present in the compacted level")
        match = [p for p in cand if float(h.cols[2][p]) == w]
        pos = (match or cand)[0]
        (h.vic if self._extremal else h.tomb).append(
            (pos, x, float(h.cols[1][pos]), float(h.cols[2][pos])))

    def _make_plan(self, levels) -> LsmPlan2D:
        return LsmPlan2D(levels=levels, agg=self._agg)

    def _empty_buf(self) -> DeltaBuffer2D:
        return DeltaBuffer2D.empty(self.capacity, self._dtype,
                                   weighted=self._weighted)

    def _buf_append(self, buf: DeltaBuffer2D, xs, ys, ws) -> DeltaBuffer2D:
        dt = self._dtype
        big = big_sentinel(dt)
        pkx = _pad_batch(xs, big, dt)
        pky = _pad_batch(ys, big, dt)
        pkw = _pad_batch(ws, 0.0, dt)
        lv = self.backend == "pallas"
        x, y, w, ylv, wcum, wpmax = _append_2d(
            buf.ins_x, buf.ins_y,
            buf.ins_w if self._weighted else buf.ins_x, pkx, pky, pkw,
            cap=buf.cap, levels=lv, weighted=self._weighted)
        return dataclasses.replace(
            buf, ins_x=x, ins_y=y,
            ins_w=w if self._weighted else None,
            ins_ylv=ylv if lv else buf.ins_ylv,
            ins_wcum=wcum if (lv and self._weighted) else buf.ins_wcum,
            ins_wpmax=(wpmax if (lv and self._weighted)
                       else buf.ins_wpmax))

    # -- public API -------------------------------------------------------

    def insert(self, xs, ys, ws=None) -> None:
        """Buffer new points (``ws`` = measures for sum2d/max2d/min2d)."""
        xs = np.array(np.atleast_1d(np.asarray(xs, np.float64)))
        ys = np.array(np.atleast_1d(np.asarray(ys, np.float64)))
        ws = self._norm_measures(xs, ws)
        self._insert_batch((xs, ys, ws))

    def delete(self, xs, ys) -> None:
        """Delete one live occurrence per point — shadowing only, NEVER a
        merge (KeyError if a point has no live occurrence)."""
        xs = np.atleast_1d(np.asarray(xs, np.float64))
        ys = np.atleast_1d(np.asarray(ys, np.float64))
        self._delete_batch([(float(x), float(y)) for x, y in zip(xs, ys)])

    def count2d(self, lx, ux, ly, uy,
                eps_rel: Optional[float] = None) -> QueryResult:
        assert self._agg == "count2d", self._agg
        return self._query((lx, ux, ly, uy), eps_rel)

    def sum2d(self, lx, ux, ly, uy,
              eps_rel: Optional[float] = None) -> QueryResult:
        assert self._agg == "sum2d", self._agg
        return self._query((lx, ux, ly, uy), eps_rel)

    def extremum2d(self, u, v,
                   eps_rel: Optional[float] = None) -> QueryResult:
        assert self._agg in ("max2d", "min2d"), self._agg
        return self._query((u, v), eps_rel)

    def query(self, *ranges, eps_rel: Optional[float] = None) -> QueryResult:
        return self._query(ranges, eps_rel)
