"""Canonical device-resident query plans (DESIGN.md §7).

An ``IndexPlan`` is the single layout every backend executes against — the
promotion of the old ``kernels.ops.SegTable`` adapter into a first-class
engine structure.  It bundles, per 1-D index:

* the tile-padded flat segment table (``seg_lo``/``seg_next``/``seg_hi``/
  ``coeffs``/``seg_agg``) the Pallas kernels and their jnp oracles consume
  (padding uses a huge-but-finite sentinel: +-inf would produce 0*inf = NaN
  inside the one-hot matmuls);
* the unpadded sparse table ``st`` over per-segment aggregates the XLA
  backend's O(1) interior-MAX reduction uses (MAX/MIN only);
* the exact-refinement arrays (sorted keys + prefix CF, or keys + measure
  sparse table) so the Lemma 5.2/5.4 Q_rel test and vectorized refinement
  run *inside* the fused jitted query path — no host round trip.

``IndexPlan2D`` is the 2-key analogue: quadtree descent arrays for the XLA
backend, the flattened tile-padded leaf table for the one-hot Pallas/ref
backends, and the merge-sort-tree arrays for exact refinement.  The leaf
table is stored in Morton (Z-order) so the locate->gather backend can
binary-search it (DESIGN.md §10): ``xcuts``/``ycuts`` are the exact dyadic
split grids (rebuilt with the tree's own midpoint recursion, so cell
resolution is bit-identical to the descent's tie rule) and ``leaf_z`` the
sorted per-leaf Morton interval starts.  The one-hot membership path is
order-independent, so both Pallas backends share one table.

Both are registered dataclass pytrees: array fields are jit-traced children,
everything shape-like (``agg``, ``deg``, ``h``, ``bh``, ...) is static
metadata, so one compilation serves every plan with the same layout.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.index import PolyFitIndex1D
from ..core.index2d import PolyFitIndex2D
from ..kernels.locate import (INT_SENTINEL, MAX_MORTON_DEPTH, dyadic_cuts,
                              leaf_morton_codes)
from ..kernels.poly_eval import DEFAULT_BH

__all__ = ["IndexPlan", "IndexPlan2D", "build_plan", "build_plan_2d",
           "big_sentinel", "pad_to_multiple"]


def big_sentinel(dtype) -> float:
    """Huge-but-finite padding value: +-inf would produce 0*inf = NaN inside
    the one-hot matmuls, so padding and open upper boundaries use
    finfo.max/4."""
    return float(np.finfo(np.dtype(dtype)).max) / 4


def pad_to_multiple(x: jnp.ndarray, mult: int, fill) -> jnp.ndarray:
    n = x.shape[0]
    p = (-n) % mult
    if p == 0:
        return x
    pad_shape = (p,) + x.shape[1:]
    return jnp.concatenate([x, jnp.full(pad_shape, fill, x.dtype)])


@dataclasses.dataclass(frozen=True)
class IndexPlan:
    """Device-resident 1-D query plan (all backends execute against this)."""

    # -- static metadata ------------------------------------------------
    agg: str                 # 'sum' | 'count' | 'max' | 'min'
    deg: int
    delta: float
    h: int                   # true segment count (<= padded length)
    n: int                   # dataset size
    bh: int                  # segment tile size the padding respects
    # -- tile-padded flat segment table (kernel ABI) --------------------
    seg_lo: jnp.ndarray      # (Hp,) sentinel-padded
    seg_next: jnp.ndarray    # (Hp,) next segment's lo; sentinel for last/pad
    seg_hi: jnp.ndarray      # (Hp,)
    coeffs: jnp.ndarray      # (Hp, deg+1) zero-padded
    seg_agg: jnp.ndarray     # (Hp,) -inf padded (max/min; zeros for sum)
    # -- XLA-backend extras ---------------------------------------------
    st: Optional[jnp.ndarray]        # (L, h) sparse table (max/min only)
    # -- exact refinement arrays (fused Q_rel path) ----------------------
    ref_keys: Optional[jnp.ndarray]  # (n,) sorted keys
    ref_cf: Optional[jnp.ndarray]    # (n,) inclusive prefix CF (sum/count)
    ref_st: Optional[jnp.ndarray]    # (L2, n) measure sparse table (max/min)
    # -- per-segment certified fit error E(I) (quantile certificates) ----
    seg_err: Optional[jnp.ndarray] = None   # (Hp,) delta-padded

    @property
    def dtype(self):
        return self.coeffs.dtype

    @property
    def domain_lo(self) -> jnp.ndarray:
        return self.seg_lo[0]

    def size_bytes(self) -> int:
        """Learned-structure size (paper's metric; excludes refinement).

        Counts the ``h`` real segments only — tile padding is an execution
        artifact, not index content.
        """
        it = self.seg_lo.dtype.itemsize
        # seg_lo + seg_next + seg_hi + seg_agg + coefficient rows
        total = self.h * (4 * it + (self.deg + 1) * self.coeffs.dtype.itemsize)
        if self.st is not None:
            total += self.st.nbytes
        return int(total)


jax.tree_util.register_dataclass(
    IndexPlan,
    data_fields=["seg_lo", "seg_next", "seg_hi", "coeffs", "seg_agg", "st",
                 "ref_keys", "ref_cf", "ref_st", "seg_err"],
    meta_fields=["agg", "deg", "delta", "h", "n", "bh"],
)


def build_plan(index: PolyFitIndex1D, dtype=jnp.float64,
               bh: int = DEFAULT_BH, with_exact: bool = True) -> IndexPlan:
    """Lower a constructed PolyFitIndex1D into the canonical device plan."""
    big = big_sentinel(dtype)
    seg_lo = jnp.asarray(index.seg_lo, dtype)
    seg_hi = jnp.asarray(index.seg_hi, dtype)
    nxt = jnp.concatenate([seg_lo[1:], jnp.full((1,), big, dtype)])
    coeffs = jnp.asarray(index.coeffs, dtype)
    agg = (jnp.asarray(index.seg_agg, dtype) if index.seg_agg is not None
           else jnp.zeros_like(seg_lo))

    st = None if index.st is None else jnp.asarray(index.st)
    ref_keys = ref_cf = ref_st = None
    if with_exact:
        if index.exact_sum is not None:
            ref_keys = index.exact_sum.keys
            ref_cf = index.exact_sum.cf
        elif index.exact_max is not None:
            ref_keys = index.exact_max.keys
            ref_st = index.exact_max.st

    return IndexPlan(
        agg=index.agg, deg=index.deg, delta=float(index.delta),
        h=int(seg_lo.shape[0]), n=int(index.n), bh=int(bh),
        seg_lo=pad_to_multiple(seg_lo, bh, big),
        seg_next=pad_to_multiple(nxt, bh, big),
        seg_hi=pad_to_multiple(seg_hi, bh, big),
        coeffs=pad_to_multiple(coeffs, bh, 0.0),
        seg_agg=pad_to_multiple(agg, bh, -jnp.inf),
        st=st, ref_keys=ref_keys, ref_cf=ref_cf, ref_st=ref_st,
        seg_err=(None if index.seg_err is None else pad_to_multiple(
            jnp.asarray(index.seg_err, dtype), bh, float(index.delta))),
    )


@dataclasses.dataclass(frozen=True)
class IndexPlan2D:
    """Device-resident 2-key COUNT plan (quadtree + flat leaf table)."""

    # -- static metadata ------------------------------------------------
    deg: int
    delta: float
    n: int
    n_leaves: int
    max_depth: int
    bh: int
    root: Tuple[float, float, float, float]   # x0, x1, y0, y1
    # -- quadtree descent arrays (XLA backend) ---------------------------
    children: jnp.ndarray    # (N, 4) int32
    leaf_of: jnp.ndarray     # (N,) int32
    bounds: jnp.ndarray      # (N, 4)
    leaf_nodes: jnp.ndarray  # (n_leaves,) int32
    qt_coeffs: jnp.ndarray   # (n_leaves, (deg+1)^2) — descent-path coeffs
    # -- flat tile-padded leaf table (Pallas/ref backends), Morton order --
    leaf_mx0: jnp.ndarray    # (Lp,) membership lower x (sentinel-padded)
    leaf_mx1: jnp.ndarray    # (Lp,) membership upper x (sentinel on root edge)
    leaf_my0: jnp.ndarray    # (Lp,)
    leaf_my1: jnp.ndarray    # (Lp,)
    leaf_bounds: jnp.ndarray  # (Lp, 4) actual x0,x1,y0,y1 (scaling spans)
    leaf_coeffs: jnp.ndarray  # (Lp, (deg+1)^2)
    # -- locate->gather extras (None when max_depth exceeds Morton range) -
    leaf_z: Optional[jnp.ndarray]  # (Lp,) int32 sorted z-interval starts
    xcuts: Optional[jnp.ndarray]   # (2^max_depth - 1,) exact split grid
    ycuts: Optional[jnp.ndarray]   # (2^max_depth - 1,)
    # -- exact refinement (merge-sort tree) ------------------------------
    ref_xs: Optional[jnp.ndarray]         # (n,)
    ref_ys_levels: Optional[jnp.ndarray]  # (L, n)
    # -- measure-carrying extension (DESIGN.md §12) ----------------------
    agg: str = "count2d"                  # 'count2d'|'sum2d'|'max2d'|'min2d'
    leaf_agg: Optional[jnp.ndarray] = None   # (Lp,) exact per-leaf measure
    ref_wcum: Optional[jnp.ndarray] = None   # (L, n) block prefix sums
    ref_wpmax: Optional[jnp.ndarray] = None  # (L, n) block prefix maxima

    @property
    def dtype(self):
        return self.leaf_coeffs.dtype

    def size_bytes(self) -> int:
        """Learned-structure size: topology + per-leaf fits (unpadded)."""
        total = (self.children.nbytes + self.bounds.nbytes +
                 self.qt_coeffs.nbytes)
        if self.leaf_agg is not None:
            total += self.n_leaves * self.leaf_agg.dtype.itemsize
        return int(total)


jax.tree_util.register_dataclass(
    IndexPlan2D,
    data_fields=["children", "leaf_of", "bounds", "leaf_nodes", "qt_coeffs",
                 "leaf_mx0", "leaf_mx1", "leaf_my0", "leaf_my1",
                 "leaf_bounds", "leaf_coeffs", "leaf_z", "xcuts", "ycuts",
                 "ref_xs", "ref_ys_levels", "leaf_agg", "ref_wcum",
                 "ref_wpmax"],
    meta_fields=["deg", "delta", "n", "n_leaves", "max_depth", "bh", "root",
                 "agg"],
)


def build_plan_2d(index: PolyFitIndex2D, dtype=jnp.float64,
                  bh: int = DEFAULT_BH, with_exact: bool = True) -> IndexPlan2D:
    """Lower a PolyFitIndex2D into the canonical device plan.

    The flat leaf table reproduces the quadtree descent's tie rule with pure
    interval membership: a coordinate exactly on an interior split line
    belongs to the higher-coordinate leaf (the descent tests ``>= mid``), so
    membership is [x0, x1) x [y0, y1) — except leaves touching the root's
    right/top edge, whose upper membership bound widens to the sentinel so
    the root's own boundary stays covered.
    """
    big = big_sentinel(dtype)
    x0r, x1r, y0r, y1r = (float(b) for b in index.root_bounds)
    lb = np.asarray(index.bounds)[np.asarray(index.leaf_nodes)]  # (L, 4) f64
    coeffs = np.asarray(index.coeffs)
    leaf_agg = (None if index.leaf_agg is None
                else np.asarray(index.leaf_agg))

    # locate->gather precomputation: exact dyadic split grids + Morton
    # z-interval starts, the whole leaf table reordered by z so the scan
    # path (order-independent) and the binary-search path share one table
    leaf_z = xcuts = ycuts = None
    depth = int(index.max_depth)
    if depth <= MAX_MORTON_DEPTH:
        xc = dyadic_cuts(x0r, x1r, depth)
        yc = dyadic_cuts(y0r, y1r, depth)
        if (np.all(np.diff(xc) > 0) if len(xc) else True) and (
                np.all(np.diff(yc) > 0) if len(yc) else True):
            z = leaf_morton_codes(lb, xc, yc, depth)
            order = np.argsort(z)
            lb = lb[order]
            coeffs = coeffs[order]
            if leaf_agg is not None:
                leaf_agg = leaf_agg[order]
            leaf_z = pad_to_multiple(jnp.asarray(z[order], jnp.int32), bh,
                                     INT_SENTINEL)
            # empty cut grids (depth 0) keep a sentinel entry so the kernel
            # always has a non-empty array to search (count stays 0)
            xcuts = jnp.asarray(xc if len(xc) else [big], dtype)
            ycuts = jnp.asarray(yc if len(yc) else [big], dtype)

    mx0 = lb[:, 0]
    mx1 = np.where(lb[:, 1] >= x1r, big, lb[:, 1])
    my0 = lb[:, 2]
    my1 = np.where(lb[:, 3] >= y1r, big, lb[:, 3])

    ref_xs = ref_ys = ref_wcum = ref_wpmax = None
    if with_exact and index.exact is not None:
        ref_xs = index.exact.xs
        ref_ys = index.exact.ys_levels
        ref_wcum = index.exact.wcum_levels
        ref_wpmax = index.exact.wpmax_levels

    to = lambda a: jnp.asarray(a, dtype)
    return IndexPlan2D(
        deg=index.deg, delta=float(index.delta), n=int(index.n),
        n_leaves=index.n_leaves, max_depth=index.max_depth, bh=int(bh),
        root=(x0r, x1r, y0r, y1r),
        children=index.children, leaf_of=index.leaf_of,
        bounds=to(index.bounds), leaf_nodes=index.leaf_nodes,
        qt_coeffs=to(index.coeffs),
        leaf_mx0=pad_to_multiple(to(mx0), bh, big),
        leaf_mx1=pad_to_multiple(to(mx1), bh, big),
        leaf_my0=pad_to_multiple(to(my0), bh, big),
        leaf_my1=pad_to_multiple(to(my1), bh, big),
        leaf_bounds=pad_to_multiple(to(lb), bh, 0.0),
        leaf_coeffs=pad_to_multiple(to(coeffs), bh, 0.0),
        leaf_z=leaf_z, xcuts=xcuts, ycuts=ycuts,
        ref_xs=ref_xs, ref_ys_levels=ref_ys,
        agg=index.agg,
        leaf_agg=(None if leaf_agg is None
                  else pad_to_multiple(to(leaf_agg), bh, 0.0)),
        ref_wcum=ref_wcum, ref_wpmax=ref_wpmax,
    )
