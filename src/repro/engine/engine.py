"""Backend-dispatched query engine: one fused execution path per
(aggregate, backend, batch-bucket).

``Engine`` executes SUM/COUNT/MAX/MIN (1 key) and COUNT (2 keys) against
``IndexPlan``/``IndexPlan2D`` through a pluggable backend:

* ``'xla'``         — searchsorted locate + gather + Horner, sparse-table
                      interior MAX (the reference semantics of
                      ``core.queries``);
* ``'pallas'``      — the locate->gather TPU kernels (DESIGN.md §10):
                      branch-free binary search resolves each endpoint in
                      O(log H), then exactly one coefficient row is
                      gathered and evaluated (interpret mode on CPU);
* ``'pallas_scan'`` — the original one-hot membership kernels, O(Q*H) per
                      batch — kept for A/B benchmarking (the H-sweep in
                      benchmarks/bench_kernels.py shows the crossover);
* ``'ref'``         — pure-jnp oracles mirroring the kernel contracts.

Every path is a single jitted function that computes the raw approximation,
applies the Lemma 5.2/5.4 (or 6.4) Q_rel acceptance test, and merges the
vectorized exact refinement with ``jnp.where`` — the refinement arrays live
inside the plan, so there is no host round trip and no per-query Python
dispatch.  Batches are padded to power-of-two buckets before entering the
jitted path: compilation count is bounded by the number of distinct
(aggregate, backend, bucket) triples, and plans with identical layouts share
compilations (plan metadata is static, arrays are traced).

Q_abs guarantees need no test: build the index with delta = eps_abs/2 (SUM,
Lemma 5.1), eps_abs (MAX, Lemma 5.3) or eps_abs/4 (2-D COUNT, Lemma 6.3)
and the raw answer already satisfies the bound.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

from ..core.exact import sparse_table_range_max
from ..core.index2d import mst_cf, mst_cf_sum, mst_dommax, quadtree_eval_cf
from ..core.poly import eval_segments, horner
from ..core.quantile import (boundary_array, certified_quantile_shifted,
                             rank_slack)
from ..core.queries import QueryResult, max_eval_segments
from ..kernels import ref as _ref
from ..kernels.leaf_eval2d import (corner_count2d_gather_pallas,
                                   corner_count2d_pallas,
                                   corner_eval2d_gather_pallas,
                                   corner_eval2d_pallas)
from ..kernels.poly_eval import DEFAULT_BQ
from ..kernels.quantile_invert import quantile_invert_pallas
from ..kernels.range_max import range_max_gather_pallas, range_max_pallas
from ..kernels.range_sum import range_sum_gather_pallas, range_sum_pallas
from .plan import IndexPlan, IndexPlan2D, big_sentinel, pad_to_multiple

__all__ = ["Engine", "BACKENDS", "QuantileResult", "raw_sum",
           "raw_extremum", "raw_count2d", "raw_eval2d", "truth_sum",
           "truth_extremum", "truth_count2d", "truth_sum2d",
           "truth_dommax2d", "check_pow2", "execute_sum",
           "execute_extremum", "execute_quantile", "execute_count2d",
           "execute_sum2d", "execute_extremum2d", "execute", "pad_fills"]


class QuantileResult(NamedTuple):
    """Certified quantile triple: ``lo <= answer <= hi`` everywhere, and
    [lo, hi] brackets the exact quantile key (DESIGN.md §16)."""
    answer: jnp.ndarray
    lo: jnp.ndarray
    hi: jnp.ndarray

BACKENDS = ("xla", "pallas", "pallas_scan", "ref")


def check_pow2(name: str, v: int) -> None:
    """Bucket/tile/capacity sizes must be powers of two (so smaller ones
    always divide larger ones)."""
    if v < 1 or v & (v - 1):
        raise ValueError(f"{name} must be a power of two, got {v}")


def _bucket_size(n: int, min_bucket: int) -> int:
    b = max(min_bucket, 1)
    while b < n:
        b <<= 1
    return b


def _pad_bucket(q: jnp.ndarray, size: int, fill) -> jnp.ndarray:
    p = size - q.shape[0]
    if p == 0:
        return q
    return jnp.concatenate([q, jnp.full((p,), fill, q.dtype)])


def pad_fills(plan: Union[IndexPlan, IndexPlan2D]):
    """Per-range-coordinate padding fills for bucketed batches — the same
    values the ``execute_*`` entry points pad with, exposed so external
    batchers (the serving engine's admission path) produce bit-identical
    padded batches."""
    if hasattr(plan, "levels"):   # LSM ladder: every level shares the fills
        plan = plan.levels[0].plan
    if isinstance(plan, IndexPlan2D):
        x0, _, y0, _ = plan.root
        if plan.agg in ("max2d", "min2d"):
            return (x0, y0)
        return (x0, x0, y0, y0)
    return (plan.domain_lo, plan.domain_lo)


def _cf_at(keys, cf, q):
    """Inclusive prefix CF at q: sum of measures with key <= q."""
    idx = jnp.searchsorted(keys, q, side="right")
    padded = jnp.concatenate([jnp.zeros((1,), cf.dtype), cf])
    return padded[idx]


# ---------------------------------------------------------------------------
# shared raw-approximation / static-truth primitives (traced inside jit by
# both the static executors below and the dynamic ones in dynamic.py)
# ---------------------------------------------------------------------------

def raw_sum(plan: IndexPlan, lqc, uqc, *, backend: str, interpret: bool,
            bq: int):
    """Backend-dispatched raw SUM/COUNT approximation (clamped queries)."""
    if backend == "pallas":
        return range_sum_gather_pallas(lqc, uqc, plan.seg_lo, plan.seg_hi,
                                       plan.coeffs, bq=bq,
                                       interpret=interpret)
    if backend == "pallas_scan":
        return range_sum_pallas(lqc, uqc, plan.seg_lo, plan.seg_next,
                                plan.seg_hi, plan.coeffs,
                                bq=bq, bh=plan.bh, interpret=interpret)
    if backend == "ref":
        return _ref.range_sum_ref(lqc, uqc, plan.seg_lo, plan.seg_next,
                                  plan.seg_hi, plan.coeffs)
    return (eval_segments(uqc, plan.seg_lo, plan.seg_hi, plan.coeffs)
            - eval_segments(lqc, plan.seg_lo, plan.seg_hi, plan.coeffs))


def raw_extremum(plan: IndexPlan, lqc, uqc, *, backend: str, interpret: bool,
                 bq: int):
    """Backend-dispatched raw MAX approximation, in MAX space (MIN plans run
    on negated measures end to end)."""
    if backend == "pallas":
        return range_max_gather_pallas(lqc, uqc, plan.seg_lo, plan.seg_hi,
                                       plan.coeffs, plan.st, bq=bq,
                                       interpret=interpret)
    if backend == "pallas_scan":
        return range_max_pallas(lqc, uqc, plan.seg_lo, plan.seg_next,
                                plan.seg_hi, plan.coeffs, plan.seg_agg,
                                bq=bq, bh=plan.bh, interpret=interpret)
    if backend == "ref":
        return _ref.range_max_ref(lqc, uqc, plan.seg_lo, plan.seg_next,
                                  plan.seg_hi, plan.coeffs, plan.seg_agg)
    return max_eval_segments(plan.seg_lo, plan.seg_hi, plan.coeffs,
                             plan.st, lqc, uqc)


def raw_count2d(plan: IndexPlan2D, lxc, uxc, lyc, uyc, *, backend: str,
                interpret: bool, bq: int):
    """Backend-dispatched raw 2-key COUNT approximation (clamped corners)."""
    if backend == "pallas" and plan.leaf_z is not None:
        return corner_count2d_gather_pallas(
            lxc, uxc, lyc, uyc, plan.xcuts, plan.ycuts, plan.leaf_z,
            plan.leaf_bounds, plan.leaf_coeffs, deg=plan.deg,
            depth=plan.max_depth, bq=bq, interpret=interpret)
    if backend in ("pallas", "pallas_scan"):
        # scan fallback: plans whose depth exceeds the Morton int32 range
        return corner_count2d_pallas(
            lxc, uxc, lyc, uyc, plan.leaf_mx0, plan.leaf_mx1, plan.leaf_my0,
            plan.leaf_my1, plan.leaf_bounds, plan.leaf_coeffs,
            deg=plan.deg, bq=bq, bh=plan.bh, interpret=interpret)
    if backend == "ref":
        return _ref.corner_count2d_ref(
            lxc, uxc, lyc, uyc, plan.leaf_mx0, plan.leaf_mx1, plan.leaf_my0,
            plan.leaf_my1, plan.leaf_bounds, plan.leaf_coeffs, plan.deg)
    ev = lambda u, v: quadtree_eval_cf(
        plan.children, plan.leaf_of, plan.bounds, plan.qt_coeffs,
        plan.leaf_nodes, plan.max_depth, plan.deg, u, v)
    return ev(uxc, uyc) - ev(lxc, uyc) - ev(uxc, lyc) + ev(lxc, lyc)


def raw_eval2d(plan: IndexPlan2D, uc, vc, *, backend: str, interpret: bool,
               bq: int):
    """Backend-dispatched single-corner evaluation P_{leaf(u,v)}(u, v) —
    the dominance MAX/MIN query path (clamped corners).  Dominance queries
    touch exactly one leaf, so there is no inclusion-exclusion step."""
    if backend == "pallas" and plan.leaf_z is not None:
        return corner_eval2d_gather_pallas(
            uc, vc, plan.xcuts, plan.ycuts, plan.leaf_z, plan.leaf_bounds,
            plan.leaf_coeffs, deg=plan.deg, depth=plan.max_depth, bq=bq,
            interpret=interpret)
    if backend in ("pallas", "pallas_scan"):
        # scan fallback: plans whose depth exceeds the Morton int32 range
        return corner_eval2d_pallas(
            uc, vc, plan.leaf_mx0, plan.leaf_mx1, plan.leaf_my0,
            plan.leaf_my1, plan.leaf_bounds, plan.leaf_coeffs,
            deg=plan.deg, bq=bq, bh=plan.bh, interpret=interpret)
    if backend == "ref":
        return _ref.leaf_eval2d_ref(
            uc, vc, plan.leaf_mx0, plan.leaf_mx1, plan.leaf_my0,
            plan.leaf_my1, plan.leaf_bounds, plan.leaf_coeffs, plan.deg)
    return quadtree_eval_cf(plan.children, plan.leaf_of, plan.bounds,
                            plan.qt_coeffs, plan.leaf_nodes, plan.max_depth,
                            plan.deg, uc, vc)


def truth_sum(plan: IndexPlan, lq, uq):
    """Exact static SUM/COUNT over (lq, uq] from the plan's refinement CF."""
    return _cf_at(plan.ref_keys, plan.ref_cf, uq) - _cf_at(
        plan.ref_keys, plan.ref_cf, lq)


def truth_extremum(plan: IndexPlan, lq, uq):
    """Exact static MAX over [lq, uq] (MAX space) from the refinement table."""
    i = jnp.searchsorted(plan.ref_keys, lq, side="left")
    j = jnp.searchsorted(plan.ref_keys, uq, side="right")
    return sparse_table_range_max(plan.ref_st, i, j)


def truth_count2d(plan: IndexPlan2D, lx, ux, ly, uy):
    """Exact static 2-key COUNT over (lx, ux] x (ly, uy] (merge-sort tree)."""
    cf = lambda u, v: mst_cf(plan.ref_xs, plan.ref_ys_levels, u, v)
    return (cf(ux, uy) - cf(lx, uy) - cf(ux, ly) + cf(lx, ly)).astype(
        plan.dtype)


def truth_sum2d(plan: IndexPlan2D, lx, ux, ly, uy):
    """Exact static 2-key SUM over (lx, ux] x (ly, uy] (weighted tree)."""
    cf = lambda u, v: mst_cf_sum(plan.ref_xs, plan.ref_ys_levels,
                                 plan.ref_wcum, u, v)
    return (cf(ux, uy) - cf(lx, uy) - cf(ux, ly) + cf(lx, ly)).astype(
        plan.dtype)


def truth_dommax2d(plan: IndexPlan2D, u, v):
    """Exact static dominance MAX over {x <= u, y <= v}, in MAX space
    (-inf when the dominated set is empty)."""
    return mst_dommax(plan.ref_xs, plan.ref_ys_levels, plan.ref_wpmax,
                      u, v).astype(plan.dtype)


# ---------------------------------------------------------------------------
# fused jitted executors (one compilation per static signature)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("backend", "eps_rel", "interpret", "bq"))
def _exec_sum(plan: IndexPlan, lq, uq, *, backend: str,
              eps_rel: Optional[float], interpret: bool, bq: int):
    dt = plan.dtype
    lqc = jnp.maximum(lq.astype(dt), plan.domain_lo)
    uqc = jnp.maximum(uq.astype(dt), plan.domain_lo)
    approx = raw_sum(plan, lqc, uqc, backend=backend, interpret=interpret,
                     bq=bq)
    if eps_rel is None:
        return approx, approx, jnp.zeros(approx.shape, bool)
    # Lemma 5.2 test: 2d / (A - 2d) <= eps_rel  (requires A > 2d)
    two_d = 2.0 * plan.delta
    ok = ((approx - two_d > 0) &
          (two_d / jnp.maximum(approx - two_d, 1e-300) <= eps_rel))
    truth = truth_sum(plan, lq, uq)
    return jnp.where(ok, approx, truth), approx, ~ok


@partial(jax.jit, static_argnames=("backend", "eps_rel", "interpret", "bq"))
def _exec_extremum(plan: IndexPlan, lq, uq, *, backend: str,
                   eps_rel: Optional[float], interpret: bool, bq: int):
    dt = plan.dtype
    lqc = jnp.maximum(lq.astype(dt), plan.domain_lo)
    uqc = jnp.maximum(uq.astype(dt), plan.domain_lo)
    approx = raw_extremum(plan, lqc, uqc, backend=backend,
                          interpret=interpret, bq=bq)
    neg = plan.agg == "min"
    if eps_rel is None:
        out = -approx if neg else approx
        return out, out, jnp.zeros(out.shape, bool)
    # Lemma 5.4 test: A >= delta * (1 + 1/eps_rel), in MAX space (MIN runs
    # on negated measures end to end, exactly like core.queries.query_max)
    ok = approx >= plan.delta * (1.0 + 1.0 / eps_rel)
    truth = truth_extremum(plan, lq, uq)
    ans = jnp.where(ok, approx, truth)
    if neg:
        ans, approx = -ans, -approx
    return ans, approx, ~ok


@partial(jax.jit, static_argnames=("backend", "eps_rel", "interpret", "bq"))
def _exec_rect2d(plan: IndexPlan2D, lx, ux, ly, uy, *, backend: str,
                 eps_rel: Optional[float], interpret: bool, bq: int):
    """Shared 4-corner rectangle executor for 2-key COUNT and SUM (the raw
    path is identical — only the exact-refinement truth differs, selected
    at trace time from the plan's static ``agg``)."""
    dt = plan.dtype
    x0, x1, y0, y1 = plan.root
    lxc, uxc = (jnp.clip(q.astype(dt), x0, x1) for q in (lx, ux))
    lyc, uyc = (jnp.clip(q.astype(dt), y0, y1) for q in (ly, uy))
    approx = raw_count2d(plan, lxc, uxc, lyc, uyc, backend=backend,
                         interpret=interpret, bq=bq)
    if eps_rel is None:
        return approx, approx, jnp.zeros(approx.shape, bool)
    # Lemma 6.4 test: A >= 4*delta*(1 + 1/eps_rel)
    ok = approx >= 4.0 * plan.delta * (1.0 + 1.0 / eps_rel)
    truth = (truth_sum2d(plan, lx, ux, ly, uy) if plan.agg == "sum2d"
             else truth_count2d(plan, lx, ux, ly, uy))
    return jnp.where(ok, approx, truth), approx, ~ok


@partial(jax.jit, static_argnames=("backend", "eps_rel", "interpret", "bq"))
def _exec_extremum2d(plan: IndexPlan2D, u, v, *, backend: str,
                     eps_rel: Optional[float], interpret: bool, bq: int):
    """Dominance MAX/MIN: one fitted-surface evaluation per corner, in MAX
    space throughout (min2d plans are built on negated measures)."""
    dt = plan.dtype
    x0, x1, y0, y1 = plan.root
    uc = jnp.clip(u.astype(dt), x0, x1)
    vc = jnp.clip(v.astype(dt), y0, y1)
    approx = raw_eval2d(plan, uc, vc, backend=backend, interpret=interpret,
                        bq=bq)
    neg = plan.agg == "min2d"
    if eps_rel is None:
        out = -approx if neg else approx
        return out, out, jnp.zeros(out.shape, bool)
    # Lemma 5.4 shape: A >= delta * (1 + 1/eps_rel), in MAX space
    ok = approx >= plan.delta * (1.0 + 1.0 / eps_rel)
    truth = truth_dommax2d(plan, u.astype(dt), v.astype(dt))
    ans = jnp.where(ok, approx, truth)
    if neg:
        ans, approx = -ans, -approx
    return ans, approx, ~ok


# ---------------------------------------------------------------------------
# the dispatch path: one module-level entry per aggregate family.
# Everything public (the Engine shims below, the PolyFit session facade in
# repro.api, the serving layer) routes through these four functions, so
# bucketing, validation and executor selection live exactly once.
# ---------------------------------------------------------------------------

def _prepare(*qs, min_bucket: int, bq: int):
    """Cast to a common device batch + bucket geometry."""
    check_pow2("bq", bq)                # the bucket math below relies on
    check_pow2("min_bucket", min_bucket)  # pow2 sizes (bq divides size)
    qs = [jnp.asarray(q) for q in qs]
    n = qs[0].shape[0]
    size = _bucket_size(n, min_bucket)
    return qs, n, size, min(bq, size)   # both powers of two -> bq | size


def _require_exact(cond: bool):
    if not cond:
        raise ValueError("Q_rel refinement requires a plan built with "
                         "with_exact=True")


def _check_backend(backend: str):
    if backend not in BACKENDS:
        raise ValueError(f"backend must be one of {BACKENDS}, got {backend}")


def execute_sum(plan: IndexPlan, lq, uq, *, backend: str = "xla",
                eps_rel: Optional[float] = None, interpret: bool = True,
                bq: int = DEFAULT_BQ, min_bucket: int = 64) -> QueryResult:
    """1-D SUM/COUNT over (lq, uq] through the fused jitted executor."""
    assert plan.agg in ("sum", "count"), plan.agg
    _check_backend(backend)
    if eps_rel is not None:
        _require_exact(plan.ref_cf is not None)
    (lq, uq), n, size, bq = _prepare(lq, uq, min_bucket=min_bucket, bq=bq)
    fill = plan.domain_lo.astype(lq.dtype)
    ans, approx, refined = _exec_sum(
        plan, _pad_bucket(lq, size, fill), _pad_bucket(uq, size, fill),
        backend=backend, eps_rel=eps_rel, interpret=interpret, bq=bq)
    return QueryResult(ans[:n], approx[:n], refined[:n])


@partial(jax.jit, static_argnames=("backend", "interpret", "bq"))
def _exec_quantile(plan: IndexPlan, q, *, backend: str, interpret: bool,
                   bq: int):
    dt = plan.dtype
    qc = jnp.clip(q.astype(dt), 0.0, 1.0)
    err = (plan.seg_err if plan.seg_err is not None
           else jnp.full_like(plan.seg_lo, plan.delta))
    if plan.agg == "count":
        M = jnp.asarray(float(plan.n), dt)
        slack = rank_slack("count", M)
    elif plan.ref_cf is not None:
        M = plan.ref_cf[-1]          # exact total mass
        slack = rank_slack("sum", M)
    else:
        # fitted total mass is off by at most the top segment's error:
        # widen the rank slack by delta to stay sound
        M = horner(plan.coeffs[plan.h - 1], jnp.asarray(1.0, dt))
        slack = rank_slack("sum", M) + plan.delta
    t = qc * M
    B = boundary_array(plan.coeffs)
    if plan.ref_keys is not None:
        keys = pad_to_multiple(plan.ref_keys, 128, big_sentinel(dt))
        nk = plan.n
    else:
        keys, nk = None, 0
    if backend in ("pallas", "pallas_scan"):
        return quantile_invert_pallas(
            t, t - slack, t + slack, B, plan.seg_lo, plan.seg_hi,
            plan.coeffs, err, keys, h=plan.h, n=nk,
            delta=float(plan.delta), bq=bq, interpret=interpret,
            scan=(backend == "pallas_scan"))
    return certified_quantile_shifted(
        t, t - slack, t + slack, seg_lo=plan.seg_lo, seg_hi=plan.seg_hi,
        coeffs=plan.coeffs, seg_err=err, h=plan.h,
        delta=float(plan.delta), B=B, ref_keys=keys, n=nk,
        scan=(backend == "ref"))


def execute_quantile(plan: IndexPlan, q, *, backend: str = "xla",
                     interpret: bool = True, bq: int = DEFAULT_BQ,
                     min_bucket: int = 64) -> QuantileResult:
    """Certified 1-D QUANTILE by CF inversion (DESIGN.md §16).

    ``q`` holds quantile fractions in [0, 1]; works on SUM/COUNT plans
    (COUNT inverts ranks, SUM inverts cumulative measure — the weighted
    quantile).  Q_abs-style certificates only: the returned [lo, hi]
    always brackets the exact quantile key, with no Q_rel refinement
    path (the certificate *is* the guarantee).
    """
    assert plan.agg in ("sum", "count"), plan.agg
    _check_backend(backend)
    if plan.deg < 1:
        raise ValueError("quantile inversion needs a plan with deg >= 1")
    if backend in ("pallas", "pallas_scan") and plan.ref_keys is None:
        backend = "xla"   # the kernel's key-grid snap needs ref_keys
    (q,), n, size, bq = _prepare(q, min_bucket=min_bucket, bq=bq)
    ans, lo, hi = _exec_quantile(plan, _pad_bucket(q, size, 0.5),
                                 backend=backend, interpret=interpret,
                                 bq=bq)
    return QuantileResult(ans[:n], lo[:n], hi[:n])


def execute_extremum(plan: IndexPlan, lq, uq, *, backend: str = "xla",
                     eps_rel: Optional[float] = None, interpret: bool = True,
                     bq: int = DEFAULT_BQ, min_bucket: int = 64) -> QueryResult:
    """1-D MAX/MIN over [lq, uq] (MIN plans run on negated measures)."""
    assert plan.agg in ("max", "min"), plan.agg
    _check_backend(backend)
    if eps_rel is not None:
        _require_exact(plan.ref_st is not None)
    if backend in ("pallas", "pallas_scan", "ref") and plan.deg > 3:
        # in-kernel closed-form extrema stop at deg 3 (the paper's
        # recommended MAX range); higher degrees take the XLA path
        backend = "xla"
    (lq, uq), n, size, bq = _prepare(lq, uq, min_bucket=min_bucket, bq=bq)
    fill = plan.domain_lo.astype(lq.dtype)
    ans, approx, refined = _exec_extremum(
        plan, _pad_bucket(lq, size, fill), _pad_bucket(uq, size, fill),
        backend=backend, eps_rel=eps_rel, interpret=interpret, bq=bq)
    return QueryResult(ans[:n], approx[:n], refined[:n])


def _execute_rect2d(plan: IndexPlan2D, lx, ux, ly, uy, *, backend, eps_rel,
                    interpret, bq, min_bucket) -> QueryResult:
    _check_backend(backend)
    if eps_rel is not None:
        _require_exact(plan.ref_xs is not None)
    (lx, ux, ly, uy), n, size, bq = _prepare(lx, ux, ly, uy,
                                             min_bucket=min_bucket, bq=bq)
    x0, _, y0, _ = plan.root
    args = (_pad_bucket(lx, size, x0), _pad_bucket(ux, size, x0),
            _pad_bucket(ly, size, y0), _pad_bucket(uy, size, y0))
    ans, approx, refined = _exec_rect2d(
        plan, *args, backend=backend, eps_rel=eps_rel, interpret=interpret,
        bq=bq)
    return QueryResult(ans[:n], approx[:n], refined[:n])


def execute_count2d(plan: IndexPlan2D, lx, ux, ly, uy, *,
                    backend: str = "xla", eps_rel: Optional[float] = None,
                    interpret: bool = True, bq: int = DEFAULT_BQ,
                    min_bucket: int = 64) -> QueryResult:
    """2-key COUNT over (lx, ux] x (ly, uy] via 4-corner inclusion-exclusion."""
    assert plan.agg == "count2d", plan.agg
    return _execute_rect2d(plan, lx, ux, ly, uy, backend=backend,
                           eps_rel=eps_rel, interpret=interpret, bq=bq,
                           min_bucket=min_bucket)


def execute_sum2d(plan: IndexPlan2D, lx, ux, ly, uy, *,
                  backend: str = "xla", eps_rel: Optional[float] = None,
                  interpret: bool = True, bq: int = DEFAULT_BQ,
                  min_bucket: int = 64) -> QueryResult:
    """2-key SUM over (lx, ux] x (ly, uy]: the same 4-corner path over a
    CF_sum-fitted plan, |A - R| <= 4*delta (DESIGN.md §12)."""
    assert plan.agg == "sum2d", plan.agg
    return _execute_rect2d(plan, lx, ux, ly, uy, backend=backend,
                           eps_rel=eps_rel, interpret=interpret, bq=bq,
                           min_bucket=min_bucket)


def execute_extremum2d(plan: IndexPlan2D, u, v, *, backend: str = "xla",
                       eps_rel: Optional[float] = None,
                       interpret: bool = True, bq: int = DEFAULT_BQ,
                       min_bucket: int = 64) -> QueryResult:
    """Dominance MAX/MIN at (u, v): the extremal measure over
    {x <= u, y <= v}, |A - R| <= delta (min2d plans run on negated
    measures end to end)."""
    assert plan.agg in ("max2d", "min2d"), plan.agg
    _check_backend(backend)
    if eps_rel is not None:
        _require_exact(plan.ref_wpmax is not None)
    (u, v), n, size, bq = _prepare(u, v, min_bucket=min_bucket, bq=bq)
    x0, _, y0, _ = plan.root
    ans, approx, refined = _exec_extremum2d(
        plan, _pad_bucket(u, size, x0), _pad_bucket(v, size, y0),
        backend=backend, eps_rel=eps_rel, interpret=interpret, bq=bq)
    return QueryResult(ans[:n], approx[:n], refined[:n])


def execute(plan: Union[IndexPlan, IndexPlan2D], ranges, *,
            backend: str = "xla", eps_rel: Optional[float] = None,
            interpret: bool = True, bq: int = DEFAULT_BQ,
            min_bucket: int = 64) -> QueryResult:
    """Dispatch on the plan: (lq, uq) for 1-D, (lx, ux, ly, uy) for 2-D
    rectangles, (u, v) for 2-D dominance MAX/MIN."""
    kw = dict(backend=backend, eps_rel=eps_rel, interpret=interpret, bq=bq,
              min_bucket=min_bucket)
    if hasattr(plan, "levels"):   # LsmPlan / LsmPlan2D level ladder
        from .lsm import execute_lsm
        return execute_lsm(plan, None, ranges, **kw)
    if isinstance(plan, IndexPlan2D):
        if plan.agg == "count2d":
            return execute_count2d(plan, *ranges, **kw)
        if plan.agg == "sum2d":
            return execute_sum2d(plan, *ranges, **kw)
        return execute_extremum2d(plan, *ranges, **kw)
    if plan.agg in ("sum", "count"):
        return execute_sum(plan, *ranges, **kw)
    return execute_extremum(plan, *ranges, **kw)


# ---------------------------------------------------------------------------
# the engine — a thin configuration shim over the dispatch path (kept for
# downstream callers; new code should go through repro.api.PolyFit)
# ---------------------------------------------------------------------------

class Engine:
    """Backend-dispatched range-aggregate query engine.

    One instance serves any number of plans; jit compiles (and caches) one
    executable per (aggregate, backend, batch-bucket, plan-layout).
    ``interpret`` controls Pallas interpret mode (True for CPU hosts).

    Every method is a shim binding this instance's (backend, interpret, bq,
    min_bucket) onto the module-level ``execute_*`` dispatch functions — the
    same path the ``repro.api`` session facade uses, so old and new callers
    hit bit-identical executors.
    """

    def __init__(self, backend: str = "xla", interpret: bool = True,
                 bq: int = DEFAULT_BQ, min_bucket: int = 64):
        _check_backend(backend)
        check_pow2("bq", bq)
        check_pow2("min_bucket", min_bucket)
        self.backend = backend
        self.interpret = interpret
        self.bq = bq
        self.min_bucket = min_bucket

    def _kw(self, eps_rel):
        return dict(backend=self.backend, eps_rel=eps_rel,
                    interpret=self.interpret, bq=self.bq,
                    min_bucket=self.min_bucket)

    def sum(self, plan: IndexPlan, lq, uq,
            eps_rel: Optional[float] = None) -> QueryResult:
        return execute_sum(plan, lq, uq, **self._kw(eps_rel))

    count = sum   # COUNT is SUM over unit measures

    def quantile(self, plan: IndexPlan, q) -> QuantileResult:
        kw = self._kw(None)
        kw.pop("eps_rel")   # quantile certificates are Q_abs-only
        return execute_quantile(plan, q, **kw)

    def extremum(self, plan: IndexPlan, lq, uq,
                 eps_rel: Optional[float] = None) -> QueryResult:
        return execute_extremum(plan, lq, uq, **self._kw(eps_rel))

    def count2d(self, plan: IndexPlan2D, lx, ux, ly, uy,
                eps_rel: Optional[float] = None) -> QueryResult:
        return execute_count2d(plan, lx, ux, ly, uy, **self._kw(eps_rel))

    def sum2d(self, plan: IndexPlan2D, lx, ux, ly, uy,
              eps_rel: Optional[float] = None) -> QueryResult:
        return execute_sum2d(plan, lx, ux, ly, uy, **self._kw(eps_rel))

    def extremum2d(self, plan: IndexPlan2D, u, v,
                   eps_rel: Optional[float] = None) -> QueryResult:
        return execute_extremum2d(plan, u, v, **self._kw(eps_rel))

    def query(self, plan: Union[IndexPlan, IndexPlan2D], *ranges,
              eps_rel: Optional[float] = None) -> QueryResult:
        return execute(plan, ranges, **self._kw(eps_rel))
