"""Epoch-ring windowed aggregates over the LSM level machinery.

Streaming rows land in an append-only delta buffer (the open epoch);
``advance()`` seals the buffer into an immutable fitted plan wrapped as a
tombstone-free ``LsmLevel`` and pushes it onto a bounded ring.  A window
query ``[t0, t1]`` then *is* an LSM execution over the selected epoch
levels — the existing ``execute_lsm`` fuses the per-epoch evaluations
exactly (every level's correction is exact; only fitted approximation
error composes), plus the open epoch's exact buffer correction when the
window reaches it.  Bounds compose via ``composed_bound`` over the
selected levels' deltas (DESIGN.md §16).

Epoch ids are dense integers starting at 0; the ring retains the last
``ring`` sealed epochs and queries below the oldest retained epoch raise
(the data is gone).  1-D SUM/COUNT only, append-only: a windowed stream
has no deletes — rows leave by epoch eviction.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import deque
from typing import List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from ..core.index import build_index_1d
from ..core.queries import QueryResult
from ..kernels.poly_eval import DEFAULT_BQ
from .dynamic import DeltaBuffer, _append_1d, _pad_batch
from .engine import check_pow2
from .lsm import LsmLevel, LsmPlan, composed_bound, execute_lsm
from .plan import big_sentinel, build_plan

__all__ = ["WindowEngine"]


class WindowEngine:
    """Ring of per-epoch immutable plans answering windowed SUM/COUNT.

    ``keys``/``measures`` (optional) seal immediately as epoch 0; the
    open epoch is always ``self.epoch``.  ``ingest`` appends to the open
    epoch, ``advance`` seals it, ``query(lq, uq, t0, t1)`` evaluates the
    range aggregate restricted to epochs t0..t1 inclusive.
    """

    def __init__(self, keys=None, measures=None, *, agg: str = "count",
                 delta: float = 64.0, deg: int = 2, ring: int = 8,
                 capacity: int = 1024, backend: str = "xla",
                 interpret: bool = True, bq: int = DEFAULT_BQ,
                 min_bucket: int = 64):
        if agg not in ("sum", "count"):
            raise ValueError("windowed aggregates support 1-D SUM/COUNT "
                             f"only, got {agg!r}")
        if ring < 1:
            raise ValueError("ring must retain at least one epoch")
        check_pow2("capacity", capacity)
        check_pow2("bq", bq)
        check_pow2("min_bucket", min_bucket)
        self.agg = agg
        self.delta = float(delta)
        self.deg = deg
        self.ring = ring
        self.capacity = capacity
        self.backend = backend
        self.interpret = interpret
        self.bq = bq
        self.min_bucket = min_bucket
        self._lock = threading.RLock()
        self._ring: deque = deque(maxlen=ring)   # (epoch_id, level-or-None)
        self._buf = DeltaBuffer.empty(capacity)
        self._pend: List[Tuple[np.ndarray, np.ndarray]] = []
        self._n_buf = 0
        self.epoch = 0
        if keys is not None and len(np.atleast_1d(keys)):
            self._ring.append((0, self._build_level(
                np.atleast_1d(np.asarray(keys, np.float64)), measures, 0)))
            self.epoch = 1

    # -- epoch lifecycle -------------------------------------------------

    def _build_level(self, k: np.ndarray, v, slot: int) -> LsmLevel:
        if self.agg == "count":
            v = np.ones_like(k)
        elif v is None:
            raise ValueError("measures required unless agg='count'")
        else:
            v = np.broadcast_to(np.asarray(v, np.float64), k.shape).copy()
        order = np.argsort(k, kind="stable")
        idx = build_index_1d(k[order], v[order], agg=self.agg,
                             delta=self.delta, deg=self.deg,
                             keep_exact=True)
        return LsmLevel(build_plan(idx), None, None, None, None, slot=slot)

    def ingest(self, keys, measures=None) -> None:
        """Append rows to the open epoch (exact until sealed)."""
        keys = np.atleast_1d(np.array(keys, np.float64))
        if self.agg == "count":
            vals = np.ones_like(keys)
        elif measures is None:
            raise ValueError("measures required unless agg='count'")
        else:
            vals = np.broadcast_to(
                np.asarray(measures, np.float64), keys.shape).copy()
        if not len(keys):
            return
        with self._lock:
            if self._n_buf + len(keys) > self.capacity:
                raise ValueError(
                    f"open epoch holds {self._n_buf} rows; {len(keys)} more "
                    f"exceeds capacity {self.capacity} — call advance()")
            buf = self._buf
            dt = buf.ins_keys.dtype
            pk = _pad_batch(keys, big_sentinel(dt), dt)
            pv = _pad_batch(vals, 0.0, dt)
            ik, iv, icf, _ = _append_1d(buf.ins_keys, buf.ins_vals, pk, pv,
                                        cap=buf.cap, with_st=False)
            self._buf = dataclasses.replace(buf, ins_keys=ik, ins_vals=iv,
                                            ins_cf=icf)
            self._pend.append((keys, vals))
            self._n_buf += len(keys)

    def advance(self) -> int:
        """Seal the open epoch into an immutable level; empty epochs seal
        as holes (no level).  Returns the new open epoch id."""
        with self._lock:
            eid = self.epoch
            if self._n_buf:
                k = np.concatenate([p[0] for p in self._pend])
                v = np.concatenate([p[1] for p in self._pend])
                lvl = self._build_level(k, v, eid)
            else:
                lvl = None
            self._ring.append((eid, lvl))
            self._buf = DeltaBuffer.empty(self.capacity)
            self._pend = []
            self._n_buf = 0
            self.epoch = eid + 1
            return self.epoch

    @property
    def oldest(self) -> int:
        """Oldest retained epoch id (sealed or the open epoch)."""
        return self._ring[0][0] if self._ring else self.epoch

    # -- queries ---------------------------------------------------------

    def _select(self, t0: int, t1: int):
        t0, t1 = int(t0), int(t1)
        if t1 < t0:
            raise ValueError(f"empty window [{t0}, {t1}]")
        if t0 < self.oldest:
            raise ValueError(f"epoch {t0} evicted (oldest retained is "
                             f"{self.oldest}, ring={self.ring})")
        levels = tuple(lvl for eid, lvl in self._ring
                       if t0 <= eid <= t1 and lvl is not None)
        buf = self._buf if (t0 <= self.epoch <= t1 and self._n_buf) else None
        return levels, buf

    def window_plan(self, t0: int, t1: int):
        """Atomic (LsmPlan-or-None, buf-or-None) snapshot of the window —
        the pair external executors (serving) evaluate against."""
        with self._lock:
            levels, buf = self._select(t0, t1)
        plan = LsmPlan(levels=levels, agg=self.agg) if levels else None
        return plan, buf

    def bound(self, t0: int, t1: int) -> float:
        """Certified absolute error of a [t0, t1] window answer: the
        sealed epochs' deltas compose (Lemma 5.1 per level); the open
        epoch's buffer correction is exact and adds nothing."""
        with self._lock:
            levels, _ = self._select(t0, t1)
        return composed_bound(self.agg, [l.plan.delta for l in levels]) \
            if levels else 0.0

    def query(self, lq, uq, t0: int, t1: int,
              eps_rel: Optional[float] = None) -> QueryResult:
        """SUM/COUNT over (lq, uq] restricted to epochs t0..t1."""
        plan, buf = self.window_plan(t0, t1)
        lq, uq = jnp.asarray(lq), jnp.asarray(uq)
        if plan is None:
            if buf is None:        # window covers no rows at all
                z = jnp.zeros(lq.shape, jnp.float64)
                return QueryResult(z, z, jnp.zeros(lq.shape, bool))
            # open epoch only: the exact prefix-sum correction is the answer
            dt = buf.ins_keys.dtype
            lqc, uqc = lq.astype(dt), uq.astype(dt)
            ans = (buf.ins_cf[jnp.searchsorted(buf.ins_keys, uqc, "right")]
                   - buf.ins_cf[jnp.searchsorted(buf.ins_keys, lqc,
                                                 "right")])
            return QueryResult(ans, ans, jnp.zeros(lq.shape, bool))
        return execute_lsm(plan, buf, (lq, uq), backend=self.backend,
                           eps_rel=eps_rel, interpret=self.interpret,
                           bq=self.bq, min_bucket=self.min_bucket)
