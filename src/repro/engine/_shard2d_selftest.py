"""2-D sharded-plan self-test: forces an 8-device host topology (scoped to
this module, like ``_shard_selftest``) and verifies that the z-range
shard_map executors are bit-identical to the single-device path.

    PYTHONPATH=src python -m repro.engine._shard2d_selftest

Checks, across count2d/sum2d/max2d/min2d:

* static answers (Q_abs and fused Q_rel refinement, including the refined
  mask) equal the unsharded XLA executor bit for bit at S in {2, 8}
  (S = 1 routes through the single-device executors by construction);
* rectangle corners centred inside the leaves that straddle the z-range
  cuts (the 2-D analogue of the 1-D boundary-straddling check);
* post-insert/delete dynamic state: a live ``DynamicEngine2D`` snapshot
  served through ``ShardedEngine2D`` with the replicated buffer yields
  bit-identical corrected answers, before and after a selective-refit
  merge.

Prints ``ALL_SHARD2D_OK`` on success (the marker tests/test_sharded.py
asserts on).
"""
from __future__ import annotations

import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=8")

import numpy as np  # noqa: E402
import jax  # noqa: E402

jax.config.update("jax_enable_x64", True)

SHARDS = (2, 8)


def _check(name, ref, got):
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(got),
                                  err_msg=name)
    print(f"[shard2d-selftest] {name}: OK")


def run() -> None:
    from repro.core import build_index_2d
    from repro.engine import (DynamicEngine2D, Engine, ShardedEngine2D,
                              build_plan_2d, shard_plan_2d)

    assert jax.device_count() >= 8, jax.device_count()
    rng = np.random.default_rng(11)
    n = 1500
    px, py = rng.uniform(0, 100, n), rng.uniform(0, 100, n)
    w = 50 + 10 * np.sin(px / 9) + 10 * np.cos(py / 13)
    nq = 64
    lx = rng.uniform(0, 80, nq)
    ux = lx + rng.uniform(5, 25, nq)
    ly = rng.uniform(0, 80, nq)
    uy = ly + rng.uniform(5, 25, nq)
    cu = px[rng.integers(0, n, nq)]
    cv = py[rng.integers(0, n, nq)]
    eng = Engine(backend="xla")

    for agg, delta in (("count2d", 25.0), ("sum2d", 400.0),
                       ("max2d", 5.0), ("min2d", 5.0)):
        meas = None if agg == "count2d" else w
        idx = build_index_2d(px, py, measures=meas, agg=agg, deg=2,
                             delta=delta, max_depth=6)
        plan = build_plan_2d(idx)
        rect = agg in ("count2d", "sum2d")
        ranges = (lx, ux, ly, uy) if rect else (cu, cv)
        ref = eng.query(plan, *ranges)
        refr = eng.query(plan, *ranges, eps_rel=0.05)
        for s in SHARDS:
            se = ShardedEngine2D(s)
            _check(f"{agg}.S{s}.qabs", ref.answer,
                   se.query(plan, *ranges).answer)
            got = se.query(plan, *ranges, eps_rel=0.05)
            _check(f"{agg}.S{s}.qrel", refr.answer, got.answer)
            _check(f"{agg}.S{s}.refined", refr.refined, got.refined)
        # corners inside the leaves straddling the z-range cuts
        sp = shard_plan_2d(plan, SHARDS[0])
        rows = np.searchsorted(np.asarray(plan.leaf_z)[: plan.n_leaves],
                               list(sp.zbounds[1:-1]))
        eb = np.asarray(plan.leaf_bounds)[rows]
        ex = 0.5 * (eb[:, 0] + eb[:, 1])
        ey = 0.5 * (eb[:, 2] + eb[:, 3])
        eranges = ((ex - 3.0, ex + 3.0, ey - 3.0, ey + 3.0) if rect
                   else (ex, ey))
        _check(f"{agg}.zedge", eng.query(plan, *eranges).answer,
               ShardedEngine2D(SHARDS[0]).query(plan, *eranges).answer)

    # dynamic state: the replicated delta buffer folds in exactly, before
    # and after a selective-refit merge
    for agg in ("sum2d", "max2d"):
        delta = 400.0 if agg == "sum2d" else 5.0
        idx = build_index_2d(px, py, measures=w, agg=agg, deg=2,
                             delta=delta, max_depth=6)
        dyn = DynamicEngine2D(idx, backend="xla", capacity=128,
                              auto_refit=False)
        dyn.insert(rng.uniform(5, 95, 24), rng.uniform(5, 95, 24),
                   rng.uniform(30, 70, 24))
        if agg == "sum2d":
            dyn.delete(px[40:48], py[40:48])
        ranges = (lx, ux, ly, uy) if agg == "sum2d" else (cu, cv)
        ref = dyn.query(*ranges, eps_rel=0.05)
        plan, buf = dyn.snapshot()
        for s in SHARDS:
            got = ShardedEngine2D(s).query(plan, *ranges, eps_rel=0.05,
                                           buf=buf)
            _check(f"dyn.{agg}.S{s}", ref.answer, got.answer)
        dyn.flush()
        assert dyn.last_refit_stats is not None
        assert not dyn.last_refit_stats["rebuild"]
        ref2 = dyn.query(*ranges)
        plan2, buf2 = dyn.snapshot()
        _check(f"dyn.{agg}.postmerge.S{SHARDS[0]}", ref2.answer,
               ShardedEngine2D(SHARDS[0]).query(plan2, *ranges,
                                                buf=buf2).answer)

    print("ALL_SHARD2D_OK")


if __name__ == "__main__":
    run()
