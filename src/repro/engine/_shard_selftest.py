"""Sharded-plan self-test: forces an 8-device host topology (scoped to this
module, like ``launch.dryrun``'s 512-device override) and verifies that the
shard_map executor is bit-identical to the single-device path.

    PYTHONPATH=src python -m repro.engine._shard_selftest

Checks, for S in {2, 4, 8} across sum/count/max/min:

* static answers (Q_abs and fused Q_rel refinement, including the refined
  mask) equal the unsharded XLA executor bit for bit;
* queries whose endpoints straddle (or sit exactly on) shard boundaries;
* post-insert/delete dynamic state: a live ``DynamicEngine`` buffer
  partitioned with ``shard_buffer`` yields bit-identical corrected answers;
* a mixed sum/max ``QueryBatch`` served through a sharded ``PolyFit``
  session matches the unsharded session.

Prints ``ALL_SHARD_OK`` on success (the marker tests/test_sharded.py
asserts on).
"""
from __future__ import annotations

import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=8")

import numpy as np  # noqa: E402
import jax  # noqa: E402

jax.config.update("jax_enable_x64", True)

SHARDS = (2, 4, 8)


def _check(name, ref, got):
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(got),
                                  err_msg=name)
    print(f"[shard-selftest] {name}: OK")


def run() -> None:
    from repro.api import ErrorBudget, PolyFit, QueryBatch, QuerySpec, TableSpec
    from repro.core import build_index_1d
    from repro.engine import (DynamicEngine, Engine, ShardedEngine,
                              build_plan, shard_plan)

    assert jax.device_count() >= 8, jax.device_count()
    rng = np.random.default_rng(7)
    n = 4000
    keys = np.sort(rng.uniform(0, 1000, n))
    meas = rng.uniform(0, 10, n)
    a = keys[rng.integers(0, n, 128)]
    b = keys[rng.integers(0, n, 128)]
    lq, uq = np.minimum(a, b), np.maximum(a, b)
    eng = Engine(backend="xla")

    for agg, m, deg in (("sum", meas, 2), ("count", None, 2),
                        ("max", meas * 100, 3), ("min", meas * 100, 3)):
        plan = build_plan(build_index_1d(keys, m, agg, deg=deg, delta=25.0))
        ref = eng.query(plan, lq, uq)
        refr = eng.query(plan, lq, uq, eps_rel=0.05)
        for s in SHARDS:
            se = ShardedEngine(s)
            sp = shard_plan(plan, s)
            _check(f"{agg}.S{s}.qabs", ref.answer,
                   se.query(plan, lq, uq).answer)
            got = se.query(plan, lq, uq, eps_rel=0.05)
            _check(f"{agg}.S{s}.qrel", refr.answer, got.answer)
            _check(f"{agg}.S{s}.refined", refr.refined, got.refined)
            edges = np.asarray([e for e in sp.bounds[1:-1]
                                if np.isfinite(e)], np.float64)
            if len(edges):
                sl, su = edges - 1e-9, edges + 13.0
                _check(f"{agg}.S{s}.straddle",
                       eng.query(plan, sl, su).answer,
                       se.query(plan, sl, su).answer)
                _check(f"{agg}.S{s}.on-edge",
                       eng.query(plan, edges, su).answer,
                       se.query(plan, edges, su).answer)

    # dynamic state: buffered inserts (and COUNT deletes) fold in exactly
    for agg, m in (("count", None), ("sum", meas), ("max", meas * 100)):
        dyn = DynamicEngine(
            build_index_1d(keys, m, agg, deg=2 if agg != "max" else 3,
                           delta=25.0),
            backend="xla", capacity=256, auto_refit=False)
        ins_k = rng.uniform(-50, 1100, 60)
        dyn.insert(ins_k, None if agg == "count" else rng.uniform(0, 900, 60))
        if agg != "max":
            dyn.delete(keys[10:20])
        ref = dyn.query(lq, uq, eps_rel=0.05)
        plan, buf = dyn.snapshot()
        for s in SHARDS:
            got = ShardedEngine(s).query(plan, lq, uq, eps_rel=0.05, buf=buf)
            _check(f"dyn.{agg}.S{s}", ref.answer, got.answer)

    # the facade end to end: sharded session == unsharded session
    budget = ErrorBudget(abs=50.0, rel=0.01)
    specs = lambda s: {"cnt": TableSpec("count", budget, shards=s),
                       "mx": TableSpec("max", budget, shards=s)}
    data = {"cnt": keys, "mx": (keys, meas * 100)}
    base = PolyFit.fit(data, specs(None))
    batch = QueryBatch.of(QuerySpec.range("cnt", lq[:64], uq[:64]),
                          QuerySpec.range("mx", lq, uq),
                          QuerySpec.range("cnt", lq[64:], uq[64:], rel=None))
    want = base.query(batch)
    for s in SHARDS:
        got = PolyFit.fit(data, specs(s)).query(batch)
        for i, (w, g) in enumerate(zip(want, got)):
            _check(f"session.S{s}.spec{i}", w.answer, g.answer)

    print("ALL_SHARD_OK")


if __name__ == "__main__":
    run()
