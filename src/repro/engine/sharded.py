"""Sharded plans: segment tables partitioned across devices (ROADMAP item).

``shard_plan`` splits an ``IndexPlan``'s segment table (and its exact
refinement arrays) into contiguous key ranges — shard ``s`` owns segments
``[off_s, off_{s+1})`` and therefore every key in ``[seg_lo[off_s],
seg_lo[off_{s+1}])`` — stacks the per-shard slices on a leading axis, and a
``shard_map`` executor answers query batches with each shard computing only
the part of the answer its key range owns:

* **SUM/COUNT** — the raw answer is ``F(uq) - F(lq)`` (Eq. 14); each
  endpoint is evaluated by exactly one owner shard (the clamped query is
  masked elsewhere), the two totals are combined with ``psum`` (one nonzero
  term each), and the final subtraction happens on the replicated totals —
  the identical operation sequence as the single-device executor, so
  answers are **bit-identical**, not merely close.  A naive
  "clamp-to-shard-range and sum partial sums" scheme would not be: segment
  fits are discontinuous at boundaries, so telescoping F over shard edges
  adds up to ``2*delta*(S-1)`` of spurious error.
* **MAX/MIN** — Eq. 17 decomposes exactly: the boundary-segment closed-form
  extrema are computed by the shards owning ``lq``/``uq`` (same arithmetic
  as ``core.queries.max_eval_segments``), interior segments reduce through
  per-shard sparse tables, and ``pmax`` combines — floating-point ``max``
  is associative, so this too is bit-identical to the XLA backend.
* **Exact refinement / delta buffers** — the refinement CF arrays and the
  ``DeltaBuffer`` logs are partitioned by the same key ranges.  Prefix-CF
  lookups use *global* prefix values stored at local positions (owner-masked
  psum again), masked buffer maxima ride ``pmax``, so Q_rel refinement and
  post-insert/delete dynamic answers stay bit-identical as well.

The mapped body runs the XLA primitive path (``eval_segments`` /
``poly_max_on_interval`` / ``sparse_table_range_max``) regardless of the
engine backend — exactly the arithmetic of ``backend='xla'`` (and of
``'ref'`` for SUM/COUNT, which shares ``eval_segments``).  Kernel backends
still apply *within* each unsharded plan; sharding is about datasets larger
than one device, where each shard's table again becomes a candidate for the
locate->gather kernels (a follow-up once multi-device Pallas lowering is
validated on hardware).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from ..core.exact import build_sparse_table, sparse_table_range_max
from ..core.index2d import mst_count_prefix, mst_weighted_prefix
from ..core.poly import eval_segments, locate, scale_unit
from ..core.queries import QueryResult, poly_max_on_interval
from ..kernels import ref as _ref
from ..kernels.leaf_eval2d import _bivariate_horner
from ..kernels.locate import INT_SENTINEL, bsearch_count, interleave2
from ..kernels.poly_eval import DEFAULT_BQ
from .dynamic import (DeltaBuffer, DeltaBuffer2D, _exec_dyn_count2d,
                      _exec_dyn_dommax2d, _exec_dyn_sum2d)
from .engine import (_bucket_size, _exec_extremum2d, _exec_rect2d,
                     _pad_bucket, check_pow2)
from .plan import IndexPlan, IndexPlan2D, big_sentinel

__all__ = ["ShardedPlan", "ShardedDelta", "ShardedEngine", "shard_plan",
           "shard_buffer", "make_shard_mesh", "ShardedPlan2D",
           "ShardedEngine2D", "shard_plan_2d", "ShardedLsmPlan",
           "ShardedLsmPlan2D", "shard_lsm_plan", "shard_lsm_plan_2d",
           "execute_lsm_sharded"]

_AXIS = "shards"


def make_shard_mesh(nshards: int) -> Mesh:
    """A 1-axis mesh over the first ``nshards`` local devices."""
    devs = jax.devices()
    if nshards > len(devs):
        raise ValueError(f"nshards={nshards} exceeds the {len(devs)} "
                         "available devices (force host devices with "
                         "XLA_FLAGS=--xla_force_host_platform_device_count=N)")
    return Mesh(np.array(devs[:nshards]), (_AXIS,))


@dataclasses.dataclass(frozen=True)
class ShardedPlan:
    """Per-shard slices of an ``IndexPlan``, stacked on a leading S axis.

    ``bounds`` (static metadata) are the S+1 owning-range edges
    ``(-inf, seg_lo[off_1], ..., +inf)``; ``rlo``/``rhi`` carry the same
    values as per-shard arrays for the mapped body's ownership masks.
    ``ref_cf`` holds *global* inclusive-prefix values at local positions
    (entry ``i`` of shard ``s`` is ``CF[a_s + i]`` of the unsharded array),
    so an owner shard's lookup returns exactly the unsharded value.
    """

    # -- static metadata ------------------------------------------------
    agg: str
    deg: int
    delta: float
    h: int                    # true global segment count
    n: int
    nshards: int
    domain_lo: float
    bounds: Tuple[float, ...]  # S+1 owning-range edges (host copy)
    # -- per-shard range/offset arrays (S,) ------------------------------
    rlo: jnp.ndarray
    rhi: jnp.ndarray
    off: jnp.ndarray          # int32 global index of first owned segment
    hloc: jnp.ndarray         # int32 owned segment count
    # -- stacked segment tables (S, Hs[, deg+1]) -------------------------
    seg_lo: jnp.ndarray
    seg_hi: jnp.ndarray
    coeffs: jnp.ndarray
    seg_agg: Optional[jnp.ndarray]   # max/min only
    st: Optional[jnp.ndarray]        # (S, L, Hs) local sparse tables
    # -- sharded exact-refinement arrays ---------------------------------
    ref_keys: Optional[jnp.ndarray]  # (S, R) sentinel-padded key slices
    ref_cf: Optional[jnp.ndarray]    # (S, R+1) global-prefix CF slices
    ref_st: Optional[jnp.ndarray]    # (S, L2, R) local measure tables

    @property
    def dtype(self):
        return self.coeffs.dtype


jax.tree_util.register_dataclass(
    ShardedPlan,
    data_fields=["rlo", "rhi", "off", "hloc", "seg_lo", "seg_hi", "coeffs",
                 "seg_agg", "st", "ref_keys", "ref_cf", "ref_st"],
    meta_fields=["agg", "deg", "delta", "h", "n", "nshards", "domain_lo",
                 "bounds"],
)


@dataclasses.dataclass(frozen=True)
class ShardedDelta:
    """Per-shard slices of a ``DeltaBuffer``, partitioned by the plan's
    owning key ranges.  ``ins_cf``/``del_cf`` hold *global* exclusive
    prefix sums at local positions (same trick as ``ShardedPlan.ref_cf``)."""

    ins_keys: jnp.ndarray   # (S, C) sentinel-padded
    ins_vals: jnp.ndarray   # (S, C)
    ins_cf: jnp.ndarray     # (S, C+1)
    del_keys: jnp.ndarray
    del_vals: jnp.ndarray
    del_cf: jnp.ndarray
    cap: int

    @property
    def dtype(self):
        return self.ins_vals.dtype


jax.tree_util.register_dataclass(
    ShardedDelta,
    data_fields=["ins_keys", "ins_vals", "ins_cf", "del_keys", "del_vals",
                 "del_cf"],
    meta_fields=["cap"],
)


# ---------------------------------------------------------------------------
# host-side partitioning
# ---------------------------------------------------------------------------

def _pad2(rows, length, fill):
    """Stack host rows padded to ``length`` along their first axis."""
    out = np.full((len(rows), length) + rows[0].shape[1:], fill,
                  rows[0].dtype)   # empty slices still carry the dtype
    for s, r in enumerate(rows):
        out[s, : len(r)] = r
    return jnp.asarray(out)


def shard_plan(plan: IndexPlan, nshards: int) -> ShardedPlan:
    """Partition a 1-D plan's segment table into ``nshards`` contiguous
    key ranges (balanced by segment count), shard-local sparse tables and
    refinement slices included.  Plans with fewer segments than shards
    leave the surplus shards empty (they own the degenerate range
    [+inf, +inf) and contribute the psum/pmax identity).  An
    ``LsmPlan`` ladder routes to ``shard_lsm_plan`` (every level sharded
    independently)."""
    if nshards < 1:
        raise ValueError(f"nshards must be >= 1, got {nshards}")
    if hasattr(plan, "levels"):
        return shard_lsm_plan(plan, nshards)
    h = plan.h
    dt = plan.dtype
    big = big_sentinel(dt)
    seg_lo = np.asarray(plan.seg_lo)[:h]
    seg_hi = np.asarray(plan.seg_hi)[:h]
    coeffs = np.asarray(plan.coeffs)[:h]
    seg_agg = np.asarray(plan.seg_agg)[:h]
    cuts = np.round(np.linspace(0, h, nshards + 1)).astype(np.int64)
    inner = np.where(cuts[1:-1] < h,
                     seg_lo[np.minimum(cuts[1:-1], h - 1)], np.inf)
    bounds = np.concatenate([[-np.inf], inner, [np.inf]])

    lo_rows = [seg_lo[a:b] for a, b in zip(cuts[:-1], cuts[1:])]
    hi_rows = [seg_hi[a:b] for a, b in zip(cuts[:-1], cuts[1:])]
    cf_rows = [coeffs[a:b] for a, b in zip(cuts[:-1], cuts[1:])]
    ag_rows = [seg_agg[a:b] for a, b in zip(cuts[:-1], cuts[1:])]
    hs = max(int(b - a) for a, b in zip(cuts[:-1], cuts[1:]))

    extremal = plan.agg in ("max", "min")
    st = None
    if extremal:
        st = jnp.asarray(np.stack([
            build_sparse_table(np.concatenate(
                [r, np.full(hs - len(r), -np.inf)])) for r in ag_rows]))

    ref_keys = ref_cf = ref_st = None
    if plan.ref_keys is not None:
        keys = np.asarray(plan.ref_keys)
        splits = np.searchsorted(keys, bounds[1:-1], side="left")
        edges = np.concatenate([[0], splits, [len(keys)]]).astype(np.int64)
        k_rows = [keys[a:b] for a, b in zip(edges[:-1], edges[1:])]
        r = max(len(kr) for kr in k_rows)
        ref_keys = _pad2(k_rows, r, big)
        if plan.ref_cf is not None:
            pcf = np.concatenate([[0.0], np.asarray(plan.ref_cf)])
            # local slice of the *global* padded prefix CF; tail repeats the
            # last value (owner lookups never index past their true length)
            rows = []
            for a, b in zip(edges[:-1], edges[1:]):
                sl = pcf[a: b + 1]
                rows.append(np.concatenate(
                    [sl, np.full(r + 1 - len(sl), sl[-1])]))
            ref_cf = jnp.asarray(np.stack(rows))
        if plan.ref_st is not None:
            meas = np.asarray(plan.ref_st)[0]   # level 0 = raw measures
            ref_st = jnp.asarray(np.stack([
                build_sparse_table(np.concatenate(
                    [meas[a:b], np.full(r - (b - a), -np.inf)]))
                for a, b in zip(edges[:-1], edges[1:])]))

    return ShardedPlan(
        agg=plan.agg, deg=plan.deg, delta=plan.delta, h=h, n=plan.n,
        nshards=nshards, domain_lo=float(seg_lo[0]),
        bounds=tuple(float(b) for b in bounds),
        rlo=jnp.asarray(bounds[:-1], dt), rhi=jnp.asarray(bounds[1:], dt),
        off=jnp.asarray(cuts[:-1], jnp.int32),
        hloc=jnp.asarray(np.diff(cuts), jnp.int32),
        seg_lo=_pad2(lo_rows, hs, big), seg_hi=_pad2(hi_rows, hs, big),
        coeffs=_pad2(cf_rows, hs, 0.0),
        seg_agg=_pad2(ag_rows, hs, -np.inf) if extremal else None,
        st=st, ref_keys=ref_keys, ref_cf=ref_cf, ref_st=ref_st,
    )


def shard_buffer(buf: DeltaBuffer, splan: ShardedPlan) -> ShardedDelta:
    """Partition a delta buffer by the plan's owning key ranges.

    Sentinel slots sort past every real key and land on the last shard with
    value 0 (they fail every membership/ownership test).  The CF slices keep
    global prefix values so owner lookups reproduce the unsharded arithmetic
    bit for bit.
    """
    cap = buf.cap
    inner = np.asarray(splan.bounds[1:-1])
    big = big_sentinel(splan.dtype)

    def split(keys, vals, cf):
        k = np.asarray(keys)
        v = np.asarray(vals)
        c = np.asarray(cf)
        edges = np.concatenate(
            [[0], np.searchsorted(k, inner, side="left"), [cap]]
        ).astype(np.int64)
        krs, vrs, crs = [], [], []
        for a, b in zip(edges[:-1], edges[1:]):
            krs.append(k[a:b])
            vrs.append(v[a:b])
            sl = c[a: b + 1]
            crs.append(np.concatenate(
                [sl, np.full(cap + 1 - len(sl), sl[-1])]))
        return (_pad2(krs, cap, big), _pad2(vrs, cap, 0.0),
                jnp.asarray(np.stack(crs)))

    ik, iv, icf = split(buf.ins_keys, buf.ins_vals, buf.ins_cf)
    dk, dv, dcf = split(buf.del_keys, buf.del_vals, buf.del_cf)
    return ShardedDelta(ik, iv, icf, dk, dv, dcf, cap)


# ---------------------------------------------------------------------------
# mapped-body helpers (each runs on one shard's local block; the leading
# length-1 mapped axis is stripped with [0])
# ---------------------------------------------------------------------------

def _own(q, rlo, rhi):
    return (q >= rlo) & (q < rhi)


def _psum_owned(val, own, zero=0.0):
    return jax.lax.psum(jnp.where(own, val, zero), _AXIS)


def _sum_endpoints(sp: ShardedPlan, lqc, uqc):
    """(F(lq), F(uq)) totals — each endpoint evaluated by its owner only."""
    rlo, rhi = sp.rlo[0], sp.rhi[0]
    args = (sp.seg_lo[0], sp.seg_hi[0], sp.coeffs[0])
    fl = _psum_owned(eval_segments(lqc, *args), _own(lqc, rlo, rhi))
    fu = _psum_owned(eval_segments(uqc, *args), _own(uqc, rlo, rhi))
    return fl, fu


def _extremum_raw(sp: ShardedPlan, lqc, uqc):
    """Eq. 17 decomposed: owner-computed boundary extrema + per-shard
    interior sparse-table maxima, combined with pmax (exact for max)."""
    rlo, rhi = sp.rlo[0], sp.rhi[0]
    seg_lo, seg_hi, coeffs = sp.seg_lo[0], sp.seg_hi[0], sp.coeffs[0]
    off, hloc = sp.off[0], sp.hloc[0]
    own_l = _own(lqc, rlo, rhi)
    own_u = _own(uqc, rlo, rhi)
    il_loc = locate(lqc, seg_lo)
    iu_loc = locate(uqc, seg_lo)
    il = _psum_owned(off + il_loc, own_l, 0)
    iu = _psum_owned(off + iu_loc, own_u, 0)
    same = il == iu
    ninf = -jnp.inf

    # left boundary segment: [lq, min(hi_l, uq)] — owner shard only
    lo_l, hi_l = seg_lo[il_loc], seg_hi[il_loc]
    ua_l = scale_unit(lqc, lo_l, hi_l)
    ub_l = scale_unit(jnp.minimum(hi_l, uqc), lo_l, hi_l)
    m_left = poly_max_on_interval(coeffs[il_loc], ua_l, ub_l)
    m_left = jnp.where(lqc <= hi_l, m_left, ninf)
    m_left = jnp.where(own_l, m_left, ninf)
    # right boundary segment: [max(lo_u, lq), uq] — owner shard only
    lo_u, hi_u = seg_lo[iu_loc], seg_hi[iu_loc]
    ua_u = scale_unit(jnp.maximum(lo_u, lqc), lo_u, hi_u)
    ub_u = scale_unit(uqc, lo_u, hi_u)
    m_right = jnp.where(same | ~own_u, ninf,
                        poly_max_on_interval(coeffs[iu_loc], ua_u, ub_u))
    # interior fully-covered segments owned by this shard
    a = jnp.clip(il + 1 - off, 0, hloc)
    b = jnp.clip(iu - off, 0, hloc)
    m_mid = sparse_table_range_max(sp.st[0], a, b)
    part = jnp.maximum(jnp.maximum(m_left, m_right), m_mid)
    return jax.lax.pmax(part, _AXIS)


def _truth_sum_tot(sp: ShardedPlan, lq, uq):
    """Exact static SUM over (lq, uq] from the sharded refinement CF."""
    rlo, rhi = sp.rlo[0], sp.rhi[0]
    keys, pcf = sp.ref_keys[0], sp.ref_cf[0]
    cl = _psum_owned(pcf[jnp.searchsorted(keys, lq, side="right")],
                     _own(lq, rlo, rhi))
    cu = _psum_owned(pcf[jnp.searchsorted(keys, uq, side="right")],
                     _own(uq, rlo, rhi))
    return cu - cl


def _truth_extremum_tot(sp: ShardedPlan, lq, uq):
    """Exact static MAX over [lq, uq] — per-shard slice maxima + pmax."""
    keys = sp.ref_keys[0]
    i = jnp.searchsorted(keys, lq, side="left")
    j = jnp.searchsorted(keys, uq, side="right")
    return jax.lax.pmax(sparse_table_range_max(sp.ref_st[0], i, j), _AXIS)


def _delta_sum_tot(keys, pcf, lq, uq, rlo, rhi):
    """Exact buffered SUM over (lq, uq] — owner-masked global-prefix diffs."""
    cl = _psum_owned(pcf[jnp.searchsorted(keys, lq, side="right")],
                     _own(lq, rlo, rhi))
    cu = _psum_owned(pcf[jnp.searchsorted(keys, uq, side="right")],
                     _own(uq, rlo, rhi))
    return cu - cl


def _delta_max_tot(keys, vals, lq, uq):
    """Exact buffered MAX over [lq, uq] — per-shard masked max + pmax."""
    member = (lq[:, None] <= keys[None, :]) & (keys[None, :] <= uq[:, None])
    part = jnp.max(jnp.where(member, vals[None, :], -jnp.inf), axis=1)
    return jax.lax.pmax(part, _AXIS)


# ---------------------------------------------------------------------------
# fused sharded executors (one compilation per mesh/bucket/layout signature)
# ---------------------------------------------------------------------------

def _specs(mesh, n_in):
    return dict(mesh=mesh, in_specs=(P(_AXIS),) * n_in + (P(), P()),
                out_specs=(P(), P(), P()))


@partial(jax.jit, static_argnames=("mesh", "eps_rel"))
def _exec_shard_sum(splan: ShardedPlan, lq, uq, *, mesh: Mesh,
                    eps_rel: Optional[float]):
    def body(sp, lq, uq):
        dt = sp.coeffs.dtype
        lqc = jnp.maximum(lq.astype(dt), sp.domain_lo)
        uqc = jnp.maximum(uq.astype(dt), sp.domain_lo)
        fl, fu = _sum_endpoints(sp, lqc, uqc)
        approx = fu - fl
        if eps_rel is None:
            return approx, approx, jnp.zeros(approx.shape, bool)
        two_d = 2.0 * sp.delta
        ok = ((approx - two_d > 0) &
              (two_d / jnp.maximum(approx - two_d, 1e-300) <= eps_rel))
        truth = _truth_sum_tot(sp, lq, uq)
        return jnp.where(ok, approx, truth), approx, ~ok

    return shard_map(body, **_specs(mesh, 1))(splan, lq, uq)


@partial(jax.jit, static_argnames=("mesh", "eps_rel"))
def _exec_shard_extremum(splan: ShardedPlan, lq, uq, *, mesh: Mesh,
                         eps_rel: Optional[float]):
    def body(sp, lq, uq):
        dt = sp.coeffs.dtype
        lqc = jnp.maximum(lq.astype(dt), sp.domain_lo)
        uqc = jnp.maximum(uq.astype(dt), sp.domain_lo)
        approx = _extremum_raw(sp, lqc, uqc)
        neg = sp.agg == "min"
        if eps_rel is None:
            out = -approx if neg else approx
            return out, out, jnp.zeros(out.shape, bool)
        ok = approx >= sp.delta * (1.0 + 1.0 / eps_rel)
        truth = _truth_extremum_tot(sp, lq, uq)
        ans = jnp.where(ok, approx, truth)
        if neg:
            ans, approx = -ans, -approx
        return ans, approx, ~ok

    return shard_map(body, **_specs(mesh, 1))(splan, lq, uq)


@partial(jax.jit, static_argnames=("mesh", "eps_rel"))
def _exec_shard_dyn_sum(splan: ShardedPlan, sbuf: ShardedDelta, lq, uq, *,
                        mesh: Mesh, eps_rel: Optional[float]):
    def body(sp, sb, lq, uq):
        dt = sp.coeffs.dtype
        rlo, rhi = sp.rlo[0], sp.rhi[0]
        lqr, uqr = lq.astype(dt), uq.astype(dt)
        lqc = jnp.maximum(lqr, sp.domain_lo)
        uqc = jnp.maximum(uqr, sp.domain_lo)
        fl, fu = _sum_endpoints(sp, lqc, uqc)
        static = fu - fl
        # exact correction over (lq, uq] — unclamped, as in _exec_dyn_sum
        corr = (_delta_sum_tot(sb.ins_keys[0], sb.ins_cf[0],
                               lqr, uqr, rlo, rhi)
                - _delta_sum_tot(sb.del_keys[0], sb.del_cf[0],
                                 lqr, uqr, rlo, rhi))
        approx = static + corr
        if eps_rel is None:
            return approx, approx, jnp.zeros(approx.shape, bool)
        two_d = 2.0 * sp.delta
        ok = ((approx - two_d > 0) &
              (two_d / jnp.maximum(approx - two_d, 1e-300) <= eps_rel))
        truth = _truth_sum_tot(sp, lqr, uqr) + corr
        return jnp.where(ok, approx, truth), approx, ~ok

    return shard_map(body, **_specs(mesh, 2))(splan, sbuf, lq, uq)


@partial(jax.jit, static_argnames=("mesh", "eps_rel"))
def _exec_shard_dyn_extremum(splan: ShardedPlan, sbuf: ShardedDelta, lq, uq,
                             *, mesh: Mesh, eps_rel: Optional[float]):
    def body(sp, sb, lq, uq):
        dt = sp.coeffs.dtype
        lqr, uqr = lq.astype(dt), uq.astype(dt)
        lqc = jnp.maximum(lqr, sp.domain_lo)
        uqc = jnp.maximum(uqr, sp.domain_lo)
        static = _extremum_raw(sp, lqc, uqc)
        ins = _delta_max_tot(sb.ins_keys[0], sb.ins_vals[0], lqr, uqr)
        approx = jnp.maximum(static, ins)
        neg = sp.agg == "min"
        if eps_rel is None:
            out = -approx if neg else approx
            return out, out, jnp.zeros(out.shape, bool)
        ok = approx >= sp.delta * (1.0 + 1.0 / eps_rel)
        truth = jnp.maximum(_truth_extremum_tot(sp, lqr, uqr), ins)
        ans = jnp.where(ok, approx, truth)
        if neg:
            ans, approx = -ans, -approx
        return ans, approx, ~ok

    return shard_map(body, **_specs(mesh, 2))(splan, sbuf, lq, uq)


# ---------------------------------------------------------------------------
# the sharded engine
# ---------------------------------------------------------------------------

class ShardedEngine:
    """Executes queries against device-partitioned 1-D plans.

    ``shard(plan)`` partitions (and caches) a plan; ``sum``/``extremum``
    accept either an ``IndexPlan`` (sharded on first use) or a prepared
    ``ShardedPlan``.  Passing ``buf=`` a ``DeltaBuffer`` (e.g. a
    ``DynamicEngine``'s live buffer) folds buffered updates in exactly,
    keeping dynamic answers bit-identical to the single-device path.
    """

    def __init__(self, nshards: int, *, mesh: Optional[Mesh] = None,
                 min_bucket: int = 64):
        check_pow2("nshards", nshards)
        check_pow2("min_bucket", min_bucket)
        self.nshards = nshards
        self.mesh = mesh if mesh is not None else make_shard_mesh(nshards)
        self.min_bucket = min_bucket
        self._plan_cache: dict = {}
        self._buf_cache: dict = {}

    # -- partition caches ------------------------------------------------

    def shard(self, plan: IndexPlan) -> ShardedPlan:
        if isinstance(plan, ShardedPlan):
            return plan
        if hasattr(plan, "levels") or isinstance(plan, ShardedLsmPlan):
            return _lsm_cache_shard(self, plan, shard_lsm_plan)
        hit = self._plan_cache.get(id(plan))
        if hit is None or hit[0] is not plan:
            self._plan_cache = {id(plan): (plan, shard_plan(plan,
                                                           self.nshards))}
            hit = self._plan_cache[id(plan)]
        return hit[1]

    def _shard_buf(self, splan: ShardedPlan,
                   buf: DeltaBuffer) -> ShardedDelta:
        # a partition is only valid for the owning ranges it was split
        # with, so the (single-entry) cache keys on buffer identity AND
        # the plan's bounds
        hit = self._buf_cache.get(id(buf))
        if hit is None or hit[0] is not buf or hit[1] != splan.bounds:
            self._buf_cache = {
                id(buf): (buf, splan.bounds, shard_buffer(buf, splan))}
            hit = self._buf_cache[id(buf)]
        return hit[2]

    # -- queries ---------------------------------------------------------

    def _run(self, plan, lq, uq, eps_rel, buf, exec_static, exec_dyn,
             need_ref):
        splan = self.shard(plan)
        if eps_rel is not None and getattr(splan, need_ref) is None:
            raise ValueError("Q_rel refinement requires a plan built with "
                             "with_exact=True")
        lq, uq = jnp.asarray(lq), jnp.asarray(uq)
        n = lq.shape[0]
        size = _bucket_size(n, self.min_bucket)
        fill = jnp.asarray(splan.domain_lo, lq.dtype)
        args = (_pad_bucket(lq, size, fill), _pad_bucket(uq, size, fill))
        if buf is None:
            ans, approx, refined = exec_static(
                splan, *args, mesh=self.mesh, eps_rel=eps_rel)
        else:
            sbuf = self._shard_buf(splan, buf)
            ans, approx, refined = exec_dyn(
                splan, sbuf, *args, mesh=self.mesh, eps_rel=eps_rel)
        return QueryResult(ans[:n], approx[:n], refined[:n])

    def sum(self, plan, lq, uq, eps_rel: Optional[float] = None,
            buf: Optional[DeltaBuffer] = None) -> QueryResult:
        assert (plan.agg in ("sum", "count")), plan.agg
        return self._run(plan, lq, uq, eps_rel, buf, _exec_shard_sum,
                         _exec_shard_dyn_sum, "ref_cf")

    count = sum

    def extremum(self, plan, lq, uq, eps_rel: Optional[float] = None,
                 buf: Optional[DeltaBuffer] = None) -> QueryResult:
        assert plan.agg in ("max", "min"), plan.agg
        return self._run(plan, lq, uq, eps_rel, buf, _exec_shard_extremum,
                         _exec_shard_dyn_extremum, "ref_st")

    def quantile(self, plan, qs, buf: Optional[DeltaBuffer] = None):
        """Certified quantiles over an *unsharded* ``IndexPlan``.

        CF inversion is O(Q log H) scalar work — a handful of binary
        searches and closed-form root extractions per query, with no
        per-segment reduction to distribute — so partitioning the segment
        table buys nothing and would only add collectives.  The method
        exists so sharded sessions keep one entry point: it routes to the
        single-device executors (replicated on every device by XLA as
        usual) and rejects plans that have already been partitioned.
        """
        if isinstance(plan, (ShardedPlan, ShardedLsmPlan)) \
                or hasattr(plan, "levels"):
            raise ValueError(
                "quantile inversion runs on the unsharded IndexPlan — "
                "pass the original plan, not a ShardedPlan/LsmPlan "
                "(inversion is O(Q log H) scalar work; there is no "
                "per-segment reduction to shard)")
        from .dynamic import _exec_dyn_quantile
        from .engine import QuantileResult, execute_quantile
        if buf is None:
            return execute_quantile(plan, qs, backend="xla",
                                    min_bucket=self.min_bucket)
        qs = jnp.asarray(qs)
        n = qs.shape[0]
        size = _bucket_size(n, self.min_bucket)
        qp = _pad_bucket(qs, size, jnp.asarray(0.5, qs.dtype))
        ans, lo, hi = _exec_dyn_quantile(plan, buf, qp, backend="xla",
                                         interpret=True,
                                         bq=min(DEFAULT_BQ, size))
        return QuantileResult(ans[:n], lo[:n], hi[:n])

    def query(self, plan, lq, uq, eps_rel: Optional[float] = None,
              buf: Optional[DeltaBuffer] = None) -> QueryResult:
        if hasattr(plan, "levels"):
            return self.query_lsm(plan, lq, uq, eps_rel=eps_rel, buf=buf)
        if plan.agg in ("sum", "count"):
            return self.sum(plan, lq, uq, eps_rel, buf)
        return self.extremum(plan, lq, uq, eps_rel, buf)

    def query_lsm(self, lsm, lq, uq, eps_rel: Optional[float] = None,
                  buf: Optional[DeltaBuffer] = None) -> QueryResult:
        slsm = _lsm_cache_shard(self, lsm, shard_lsm_plan)
        return execute_lsm_sharded(slsm, buf, (lq, uq), mesh=self.mesh,
                                   eps_rel=eps_rel,
                                   min_bucket=self.min_bucket)


# ---------------------------------------------------------------------------
# 2-D: the Morton-ordered leaf table partitioned by contiguous z-ranges
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardedPlan2D:
    """Per-shard z-range slices of an ``IndexPlan2D``'s Morton leaf table.

    Shard ``s`` owns the leaves whose z-interval starts fall in
    ``[zbounds[s], zbounds[s+1])`` — quadtree leaves are disjoint intervals
    in Z-order, so a (clamped) query corner's Morton code names exactly one
    owner shard.  The dyadic cut grids are replicated (they are
    O(2^depth) scalars and every shard needs them to code corners), as are
    the exact-refinement merge-sort-tree arrays and, in the dynamic
    executors, the (capacity-bounded) delta buffer: the refinement/buffer
    arithmetic runs identically on every shard with no collective, which
    keeps those answers trivially bit-identical; only the leaf-table
    evaluation is sharded and psum/pmax-combined.  Sharding the refinement
    arrays themselves stays on the ROADMAP (the BIT block structure does
    not split at arbitrary x cuts).
    """

    # -- static metadata ------------------------------------------------
    agg: str
    deg: int
    delta: float
    n: int
    n_leaves: int
    nshards: int
    max_depth: int
    root: Tuple[float, float, float, float]
    zbounds: Tuple[int, ...]     # S+1 owning z-range edges (host copy)
    # -- per-shard ownership + stacked leaf tables (S, ...) ---------------
    zlo: jnp.ndarray             # (S,) int32
    zhi: jnp.ndarray             # (S,) int32
    leaf_z: jnp.ndarray          # (S, Ls) int32 sentinel-padded
    leaf_bounds: jnp.ndarray     # (S, Ls, 4)
    leaf_coeffs: jnp.ndarray     # (S, Ls, (deg+1)^2)
    # -- replicated arrays ------------------------------------------------
    xcuts: jnp.ndarray           # (2^depth - 1,)
    ycuts: jnp.ndarray
    ref_xs: Optional[jnp.ndarray]
    ref_ys_levels: Optional[jnp.ndarray]
    ref_wcum: Optional[jnp.ndarray]
    ref_wpmax: Optional[jnp.ndarray]

    @property
    def dtype(self):
        return self.leaf_coeffs.dtype


jax.tree_util.register_dataclass(
    ShardedPlan2D,
    data_fields=["zlo", "zhi", "leaf_z", "leaf_bounds", "leaf_coeffs",
                 "xcuts", "ycuts", "ref_xs", "ref_ys_levels", "ref_wcum",
                 "ref_wpmax"],
    meta_fields=["agg", "deg", "delta", "n", "n_leaves", "nshards",
                 "max_depth", "root", "zbounds"],
)


def shard_plan_2d(plan: IndexPlan2D, nshards: int) -> ShardedPlan2D:
    """Partition a 2-D plan's Morton-ordered leaf table into ``nshards``
    contiguous z-ranges (balanced by leaf count).  Plans with fewer leaves
    than shards leave the surplus shards empty (they own the degenerate
    range [sentinel, sentinel) and contribute the psum/pmax identity).
    An ``LsmPlan2D`` ladder routes to ``shard_lsm_plan_2d``."""
    if nshards < 1:
        raise ValueError(f"nshards must be >= 1, got {nshards}")
    if hasattr(plan, "levels"):
        return shard_lsm_plan_2d(plan, nshards)
    if plan.leaf_z is None:
        raise ValueError(
            "2-D sharding requires the Morton leaf layout (max_depth <= "
            "MAX_MORTON_DEPTH and strictly increasing cut grids)")
    nl = plan.n_leaves
    leaf_z = np.asarray(plan.leaf_z)[:nl]
    bounds = np.asarray(plan.leaf_bounds)[:nl]
    coeffs = np.asarray(plan.leaf_coeffs)[:nl]
    cuts = np.round(np.linspace(0, nl, nshards + 1)).astype(np.int64)
    inner = np.where(cuts[1:-1] < nl,
                     leaf_z[np.minimum(cuts[1:-1], nl - 1)], INT_SENTINEL)
    zb = np.concatenate([[0], inner, [INT_SENTINEL]]).astype(np.int64)

    z_rows = [leaf_z[a:b] for a, b in zip(cuts[:-1], cuts[1:])]
    b_rows = [bounds[a:b] for a, b in zip(cuts[:-1], cuts[1:])]
    c_rows = [coeffs[a:b] for a, b in zip(cuts[:-1], cuts[1:])]
    ls = max(int(b - a) for a, b in zip(cuts[:-1], cuts[1:]))

    return ShardedPlan2D(
        agg=plan.agg, deg=plan.deg, delta=plan.delta, n=plan.n,
        n_leaves=nl, nshards=nshards, max_depth=plan.max_depth,
        root=plan.root, zbounds=tuple(int(z) for z in zb),
        zlo=jnp.asarray(zb[:-1], jnp.int32),
        zhi=jnp.asarray(zb[1:], jnp.int32),
        leaf_z=_pad2(z_rows, ls, INT_SENTINEL),
        leaf_bounds=_pad2(b_rows, ls, 0.0),
        leaf_coeffs=_pad2(c_rows, ls, 0.0),
        xcuts=plan.xcuts, ycuts=plan.ycuts,
        ref_xs=plan.ref_xs, ref_ys_levels=plan.ref_ys_levels,
        ref_wcum=plan.ref_wcum, ref_wpmax=plan.ref_wpmax,
    )


def _plan2d_inspec(sp: ShardedPlan2D) -> ShardedPlan2D:
    """The shard_map in_spec pytree for a ShardedPlan2D: leaf tables and
    ownership ranges partitioned on their leading S axis, cut grids and
    refinement arrays replicated."""
    kw = dict(zlo=P(_AXIS), zhi=P(_AXIS), leaf_z=P(_AXIS),
              leaf_bounds=P(_AXIS), leaf_coeffs=P(_AXIS),
              xcuts=P(), ycuts=P())
    for f in ("ref_xs", "ref_ys_levels", "ref_wcum", "ref_wpmax"):
        if getattr(sp, f) is not None:
            kw[f] = P()
    return dataclasses.replace(sp, **kw)


def _corner_eval2d_shard(sp: ShardedPlan2D, qx, qy):
    """Single-corner evaluation: the owner shard gathers the corner's leaf
    row, a psum replicates it, and the bivariate Horner runs on the
    replicated row.

    The z-locate (three binary searches, kernels/locate.py) and the gather
    are integer/selection ops — exact by construction — and the psum of
    one owner row plus zeros reproduces the owner's bits.  Deferring the
    *float* evaluation until after the collective keeps its compilation
    context independent of the mesh size and of each shard's local table
    length, so answers stay bit-identical across shard counts; fusing the
    Horner into the per-shard body instead lets XLA's FP-contraction
    choices vary with the surrounding program, costing a final ulp on
    some corners.
    """
    k = (sp.deg + 1) * (sp.deg + 1)
    ix = bsearch_count(sp.xcuts, qx, side="right")
    iy = bsearch_count(sp.ycuts, qy, side="right")
    z = interleave2(ix, iy, sp.max_depth)
    own = (z >= sp.zlo[0]) & (z < sp.zhi[0])
    row = jnp.maximum(bsearch_count(sp.leaf_z[0], z, side="right") - 1, 0)
    c = jnp.take(sp.leaf_coeffs[0], row, axis=0)
    b = jnp.take(sp.leaf_bounds[0], row, axis=0)
    cb = jnp.concatenate([c, b], axis=1)
    cb = jax.lax.psum(jnp.where(own[:, None], cb, 0.0), _AXIS)
    return _bivariate_horner(qx, qy, cb[:, :k], cb[:, k:], sp.deg)


def _rect2d_raw(sp: ShardedPlan2D, lxc, uxc, lyc, uyc):
    """4-corner inclusion-exclusion: each corner's leaf row gathered by
    its owner shard, psum-replicated, evaluated, combined with signs —
    the single-device op sequence, so bit-identical."""
    vals = [_corner_eval2d_shard(sp, qx, qy)
            for qx, qy in ((uxc, uyc), (lxc, uyc), (uxc, lyc), (lxc, lyc))]
    return vals[0] - vals[1] - vals[2] + vals[3]


def _truth_rect2d(sp: ShardedPlan2D, lx, ux, ly, uy):
    """Exact rectangle COUNT/SUM from the replicated refinement arrays
    (identical computation on every shard — no collective needed).

    The x-prefix rank comes from ``bsearch_count`` rather than
    ``jnp.searchsorted``: searchsorted's default scan lowering trips
    shard_map's replication checker on replicated operands, and the
    unrolled binary search returns the same exact integers.
    """
    if sp.agg == "sum2d":
        def cf(u, v):
            i = bsearch_count(sp.ref_xs, u, side="right")
            return mst_weighted_prefix(sp.ref_xs, sp.ref_ys_levels,
                                       sp.ref_wcum, i, v, mode="sum")
    else:
        def cf(u, v):
            i = bsearch_count(sp.ref_xs, u, side="right")
            return mst_count_prefix(sp.ref_xs, sp.ref_ys_levels, i, v)
    return (cf(ux, uy) - cf(lx, uy) - cf(ux, ly) + cf(lx, ly)).astype(
        sp.dtype)


def _truth_dommax2d(sp: ShardedPlan2D, u, v):
    """Exact dominance MAX from the replicated refinement arrays (same
    searchsorted-avoidance as ``_truth_rect2d``)."""
    i = bsearch_count(sp.ref_xs, u, side="right")
    return mst_weighted_prefix(sp.ref_xs, sp.ref_ys_levels, sp.ref_wpmax,
                               i, v, mode="max").astype(sp.dtype)


def _clamp2d(sp: ShardedPlan2D, qs):
    dt = sp.dtype
    x0, x1, y0, y1 = sp.root
    lx, ux, ly, uy = (q.astype(dt) for q in qs)
    return ((lx, ux, ly, uy),
            (jnp.clip(lx, x0, x1), jnp.clip(ux, x0, x1),
             jnp.clip(ly, y0, y1), jnp.clip(uy, y0, y1)))


@partial(jax.jit, static_argnames=("mesh", "eps_rel"))
def _exec_shard_rect2d(sp: ShardedPlan2D, lx, ux, ly, uy, *, mesh: Mesh,
                       eps_rel: Optional[float]):
    def body(sp, lx, ux, ly, uy):
        (lxr, uxr, lyr, uyr), clamped = _clamp2d(sp, (lx, ux, ly, uy))
        approx = _rect2d_raw(sp, *clamped)
        if eps_rel is None:
            return approx, approx, jnp.zeros(approx.shape, bool)
        ok = approx >= 4.0 * sp.delta * (1.0 + 1.0 / eps_rel)   # Lemma 6.4
        truth = _truth_rect2d(sp, lxr, uxr, lyr, uyr)
        return jnp.where(ok, approx, truth), approx, ~ok

    return shard_map(body, mesh=mesh,
                     in_specs=(_plan2d_inspec(sp),) + (P(),) * 4,
                     out_specs=(P(), P(), P()))(sp, lx, ux, ly, uy)


@partial(jax.jit, static_argnames=("mesh", "eps_rel"))
def _exec_shard_dyn_rect2d(sp: ShardedPlan2D, buf: DeltaBuffer2D,
                           lx, ux, ly, uy, *, mesh: Mesh,
                           eps_rel: Optional[float]):
    def body(sp, buf, lx, ux, ly, uy):
        (lxr, uxr, lyr, uyr), clamped = _clamp2d(sp, (lx, ux, ly, uy))
        static = _rect2d_raw(sp, *clamped)
        # replicated exact correction — the dense (xla-backend) arithmetic
        # of the single-device dynamic executor, unclamped
        if sp.agg == "sum2d":
            corr = (_ref.delta_sum2d_ref(lxr, uxr, lyr, uyr, buf.ins_x,
                                         buf.ins_y, buf.ins_w)
                    - _ref.delta_sum2d_ref(lxr, uxr, lyr, uyr, buf.del_x,
                                           buf.del_y, buf.del_w))
        else:
            corr = (_ref.delta_count2d_ref(lxr, uxr, lyr, uyr, buf.ins_x,
                                           buf.ins_y, dtype=sp.dtype)
                    - _ref.delta_count2d_ref(lxr, uxr, lyr, uyr, buf.del_x,
                                             buf.del_y, dtype=sp.dtype))
        approx = static + corr
        if eps_rel is None:
            return approx, approx, jnp.zeros(approx.shape, bool)
        ok = approx >= 4.0 * sp.delta * (1.0 + 1.0 / eps_rel)
        truth = _truth_rect2d(sp, lxr, uxr, lyr, uyr) + corr
        return jnp.where(ok, approx, truth), approx, ~ok

    return shard_map(body, mesh=mesh,
                     in_specs=(_plan2d_inspec(sp), P()) + (P(),) * 4,
                     out_specs=(P(), P(), P()))(sp, buf, lx, ux, ly, uy)


@partial(jax.jit, static_argnames=("mesh", "eps_rel"))
def _exec_shard_dommax2d(sp: ShardedPlan2D, u, v, *, mesh: Mesh,
                         eps_rel: Optional[float]):
    def body(sp, u, v):
        dt = sp.dtype
        x0, x1, y0, y1 = sp.root
        ur, vr = u.astype(dt), v.astype(dt)
        uc = jnp.clip(ur, x0, x1)
        vc = jnp.clip(vr, y0, y1)
        approx = _corner_eval2d_shard(sp, uc, vc)
        neg = sp.agg == "min2d"
        if eps_rel is None:
            out = -approx if neg else approx
            return out, out, jnp.zeros(out.shape, bool)
        ok = approx >= sp.delta * (1.0 + 1.0 / eps_rel)
        truth = _truth_dommax2d(sp, ur, vr)
        ans = jnp.where(ok, approx, truth)
        if neg:
            ans, approx = -ans, -approx
        return ans, approx, ~ok

    return shard_map(body, mesh=mesh,
                     in_specs=(_plan2d_inspec(sp), P(), P()),
                     out_specs=(P(), P(), P()))(sp, u, v)


@partial(jax.jit, static_argnames=("mesh", "eps_rel"))
def _exec_shard_dyn_dommax2d(sp: ShardedPlan2D, buf: DeltaBuffer2D, u, v,
                             *, mesh: Mesh, eps_rel: Optional[float]):
    def body(sp, buf, u, v):
        dt = sp.dtype
        x0, x1, y0, y1 = sp.root
        ur, vr = u.astype(dt), v.astype(dt)
        uc = jnp.clip(ur, x0, x1)
        vc = jnp.clip(vr, y0, y1)
        static = _corner_eval2d_shard(sp, uc, vc)
        ins = _ref.delta_dommax2d_ref(ur, vr, buf.ins_x, buf.ins_y,
                                      buf.ins_w)
        approx = jnp.maximum(static, ins)
        neg = sp.agg == "min2d"
        if eps_rel is None:
            out = -approx if neg else approx
            return out, out, jnp.zeros(out.shape, bool)
        ok = approx >= sp.delta * (1.0 + 1.0 / eps_rel)
        truth = jnp.maximum(_truth_dommax2d(sp, ur, vr), ins)
        ans = jnp.where(ok, approx, truth)
        if neg:
            ans, approx = -ans, -approx
        return ans, approx, ~ok

    return shard_map(body, mesh=mesh,
                     in_specs=(_plan2d_inspec(sp), P(), P(), P()),
                     out_specs=(P(), P(), P()))(sp, buf, u, v)


class ShardedEngine2D:
    """Executes 2-key queries against z-range-partitioned leaf tables.

    ``shard(plan)`` partitions (and caches) an ``IndexPlan2D``; at
    ``nshards >= 2`` the query methods accept either the raw plan or a
    prepared ``ShardedPlan2D``; ``nshards=1`` routes through the
    single-device executors (that is what keeps S=1 bit-identical to the
    engine), so it requires the unsharded plan.  Passing ``buf=`` a live ``DeltaBuffer2D``
    (e.g. a ``DynamicEngine2D`` snapshot's buffer) folds buffered updates
    in exactly — the buffer is replicated, so dynamic answers stay
    bit-identical to the single-device xla path.
    """

    def __init__(self, nshards: int, *, mesh: Optional[Mesh] = None,
                 min_bucket: int = 64):
        check_pow2("nshards", nshards)
        check_pow2("min_bucket", min_bucket)
        self.nshards = nshards
        self.mesh = mesh if mesh is not None else make_shard_mesh(nshards)
        self.min_bucket = min_bucket
        self._plan_cache: dict = {}

    def shard(self, plan) -> ShardedPlan2D:
        if isinstance(plan, ShardedPlan2D):
            return plan
        if hasattr(plan, "levels") or isinstance(plan, ShardedLsmPlan2D):
            return _lsm_cache_shard(self, plan, shard_lsm_plan_2d)
        hit = self._plan_cache.get(id(plan))
        if hit is None or hit[0] is not plan:
            self._plan_cache = {
                id(plan): (plan, shard_plan_2d(plan, self.nshards))}
            hit = self._plan_cache[id(plan)]
        return hit[1]

    def _prepare(self, qs, fills):
        qs = [jnp.asarray(q) for q in qs]
        n = qs[0].shape[0]
        size = _bucket_size(n, self.min_bucket)
        return [_pad_bucket(q, size, f) for q, f in zip(qs, fills)], n

    @staticmethod
    def _require_unsharded(plan) -> None:
        if not isinstance(plan, IndexPlan2D):
            raise ValueError(
                "nshards=1 runs the single-device executors (that is what "
                "keeps S=1 bit-identical) and needs the unsharded "
                "IndexPlan2D, not a pre-partitioned ShardedPlan2D")

    def _rect(self, plan, lx, ux, ly, uy, eps_rel, buf, want_agg):
        sp = self.shard(plan)
        assert sp.agg in want_agg, sp.agg
        if eps_rel is not None and sp.ref_xs is None:
            raise ValueError("Q_rel refinement requires a plan built with "
                             "with_exact=True")
        x0, _, y0, _ = sp.root
        args, n = self._prepare((lx, ux, ly, uy), (x0, x0, y0, y0))
        if self.nshards == 1:
            # S = 1 *is* the single-device path: run its executor directly
            # (inside shard_map, XLA elides the psum and fuses the body
            # differently, costing a final ulp of bit-identity)
            self._require_unsharded(plan)
            bq = min(64, args[0].shape[0])
            if buf is None:
                out = _exec_rect2d(plan, *args, backend="xla",
                                   eps_rel=eps_rel, interpret=True, bq=bq)
            else:
                dyn_exec = (_exec_dyn_sum2d if sp.agg == "sum2d"
                            else _exec_dyn_count2d)
                out = dyn_exec(plan, buf, *args, backend="xla",
                               eps_rel=eps_rel, interpret=True, bq=bq)
        elif buf is None:
            out = _exec_shard_rect2d(sp, *args, mesh=self.mesh,
                                     eps_rel=eps_rel)
        else:
            out = _exec_shard_dyn_rect2d(sp, buf, *args, mesh=self.mesh,
                                         eps_rel=eps_rel)
        return QueryResult(out[0][:n], out[1][:n], out[2][:n])

    def count2d(self, plan, lx, ux, ly, uy,
                eps_rel: Optional[float] = None,
                buf: Optional[DeltaBuffer2D] = None) -> QueryResult:
        return self._rect(plan, lx, ux, ly, uy, eps_rel, buf, ("count2d",))

    def sum2d(self, plan, lx, ux, ly, uy,
              eps_rel: Optional[float] = None,
              buf: Optional[DeltaBuffer2D] = None) -> QueryResult:
        return self._rect(plan, lx, ux, ly, uy, eps_rel, buf, ("sum2d",))

    def extremum2d(self, plan, u, v, eps_rel: Optional[float] = None,
                   buf: Optional[DeltaBuffer2D] = None) -> QueryResult:
        sp = self.shard(plan)
        assert sp.agg in ("max2d", "min2d"), sp.agg
        if eps_rel is not None and sp.ref_wpmax is None:
            raise ValueError("Q_rel refinement requires a plan built with "
                             "with_exact=True")
        x0, _, y0, _ = sp.root
        args, n = self._prepare((u, v), (x0, y0))
        if self.nshards == 1:
            self._require_unsharded(plan)
            bq = min(64, args[0].shape[0])
            if buf is None:
                out = _exec_extremum2d(plan, *args, backend="xla",
                                       eps_rel=eps_rel, interpret=True,
                                       bq=bq)
            else:
                out = _exec_dyn_dommax2d(plan, buf, *args, backend="xla",
                                         eps_rel=eps_rel, interpret=True,
                                         bq=bq)
        elif buf is None:
            out = _exec_shard_dommax2d(sp, *args, mesh=self.mesh,
                                       eps_rel=eps_rel)
        else:
            out = _exec_shard_dyn_dommax2d(sp, buf, *args, mesh=self.mesh,
                                           eps_rel=eps_rel)
        return QueryResult(out[0][:n], out[1][:n], out[2][:n])

    def query(self, plan, *ranges, eps_rel: Optional[float] = None,
              buf: Optional[DeltaBuffer2D] = None) -> QueryResult:
        if hasattr(plan, "levels"):
            return self.query_lsm(plan, *ranges, eps_rel=eps_rel, buf=buf)
        agg = plan.agg
        if agg == "count2d":
            return self.count2d(plan, *ranges, eps_rel=eps_rel, buf=buf)
        if agg == "sum2d":
            return self.sum2d(plan, *ranges, eps_rel=eps_rel, buf=buf)
        return self.extremum2d(plan, *ranges, eps_rel=eps_rel, buf=buf)

    def query_lsm(self, lsm, *ranges, eps_rel: Optional[float] = None,
                  buf: Optional[DeltaBuffer2D] = None) -> QueryResult:
        slsm = _lsm_cache_shard(self, lsm, shard_lsm_plan_2d)
        return execute_lsm_sharded(slsm, buf, ranges, mesh=self.mesh,
                                   eps_rel=eps_rel,
                                   min_bucket=self.min_bucket)


# ---------------------------------------------------------------------------
# LSM ladders: each immutable level's data plan sharded independently
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardedLsmPlan:
    """A 1-D level ladder with every level's fitted ``IndexPlan`` sharded.

    ``levels`` keeps the original (replicated) ``LsmLevel`` tuple: the
    exact side arrays — tombstone prefix sums, victim keys, live sparse
    tables, refinement keys — stay whole on every device, matching the
    documented 2-D sharding simplification (refinement arrays do not
    split at arbitrary cuts).  Only the per-level segment-table
    evaluation is distributed; the exact boundary corrections and the
    cross-level fusion run replicated, so fused answers reproduce the
    unsharded ``execute_lsm(backend='xla')`` bits."""

    agg: str
    nshards: int
    levels: tuple          # original LsmLevel tuple (replicated)
    slevels: tuple         # per-level ShardedPlan, same order

    @property
    def dtype(self):
        return self.levels[0].plan.dtype

    @property
    def deltas(self) -> Tuple[float, ...]:
        return tuple(lvl.plan.delta for lvl in self.levels)


@dataclasses.dataclass(frozen=True)
class ShardedLsmPlan2D:
    """2-D counterpart of ``ShardedLsmPlan`` (z-range-sharded leaf tables
    per level, replicated merge-sort-tree side arrays)."""

    agg: str
    nshards: int
    levels: tuple          # original LsmLevel2D tuple (replicated)
    slevels: tuple         # per-level ShardedPlan2D, same order

    @property
    def dtype(self):
        return self.levels[0].plan.dtype

    @property
    def deltas(self) -> Tuple[float, ...]:
        return tuple(lvl.plan.delta for lvl in self.levels)


def shard_lsm_plan(lsm, nshards: int) -> ShardedLsmPlan:
    """Shard every level of an ``LsmPlan`` (1-D) into ``nshards`` key
    ranges.  Levels are partitioned independently — a compaction that
    rebuilds one slot re-shards only that level's fresh plan."""
    return ShardedLsmPlan(
        agg=lsm.agg, nshards=nshards, levels=tuple(lsm.levels),
        slevels=tuple(shard_plan(l.plan, nshards) for l in lsm.levels))


def shard_lsm_plan_2d(lsm, nshards: int) -> ShardedLsmPlan2D:
    """Shard every level of an ``LsmPlan2D`` into ``nshards`` z-ranges."""
    return ShardedLsmPlan2D(
        agg=lsm.agg, nshards=nshards, levels=tuple(lsm.levels),
        slevels=tuple(shard_plan_2d(l.plan, nshards) for l in lsm.levels))


def _lsm_cache_shard(engine, lsm, shard_fn):
    """Single-entry per-engine ladder cache keyed on ladder identity."""
    if isinstance(lsm, (ShardedLsmPlan, ShardedLsmPlan2D)):
        return lsm
    cache = getattr(engine, "_lsm_cache", None)
    if cache is None or cache[0] is not lsm:
        engine._lsm_cache = (lsm, shard_fn(lsm, engine.nshards))
        cache = engine._lsm_cache
    return cache[1]


@partial(jax.jit, static_argnames=("mesh",))
def _exec_shard_eval2d(sp: ShardedPlan2D, qx, qy, *, mesh: Mesh):
    """Sharded single-corner CF evaluation (the owner-gather + deferred
    Horner of ``_corner_eval2d_shard``, exposed standalone so the LSM
    level cores can apply their own boundary corrections per corner)."""
    def body(sp, qx, qy):
        return (_corner_eval2d_shard(sp, qx, qy),)

    return shard_map(body, mesh=mesh,
                     in_specs=(_plan2d_inspec(sp), P(), P()),
                     out_specs=(P(),))(sp, qx, qy)[0]


def _lsm_level_sum_sharded(lvl, sp, qs, mesh):
    """Sharded twin of ``lsm._level_sum`` — the raw range sum runs on the
    owner shards; the m0 below-domain addend and the exact tombstone
    subtraction are replicated (same floats as the unsharded core)."""
    from .lsm import _tomb_sum_1d
    lq, uq = qs
    part = _exec_shard_sum(sp, lq, uq, mesh=mesh, eps_rel=None)[0]
    p = lvl.plan
    lo = p.seg_lo[0]
    part = part + jnp.where((lq < lo) & (uq >= lo), p.ref_cf[0],
                            jnp.zeros((), p.dtype))
    if lvl.tomb_keys is not None:
        part = part - _tomb_sum_1d(lvl, lq, uq)
    return (part,)


def _lsm_level_extremum_sharded(lvl, sp, qs, mesh):
    """Sharded twin of ``lsm._level_extremum``: the fitted staircase max
    reduces through per-shard sparse tables + pmax; the exact live
    maximum and the victim threat test read the replicated level arrays."""
    lq, uq = qs
    p = lvl.plan
    lo = p.seg_lo[0]
    hi = p.seg_hi[p.h - 1]
    lqc = jnp.clip(lq, lo, hi)
    uqc = jnp.clip(uq, lo, hi)
    out = _exec_shard_extremum(sp, lqc, uqc, mesh=mesh, eps_rel=None)[0]
    raw = -out if p.agg == "min" else out   # back to MAX space
    st = lvl.live_st if lvl.live_st is not None else p.ref_st
    i = jnp.searchsorted(p.ref_keys, lq, side="left")
    j = jnp.searchsorted(p.ref_keys, uq, side="right")
    exact = sparse_table_range_max(st, i, j)
    valid = (uq >= lo) & (lq <= hi) & (exact > -jnp.inf)
    part = jnp.where(valid, raw, -jnp.inf)
    if lvl.vic_keys is not None:
        vk = lvl.vic_keys[None, :]
        threat = jnp.any((lq[:, None] <= vk) & (vk <= uq[:, None]), axis=1)
    else:
        threat = jnp.zeros(lq.shape, bool)
    return part, exact, threat


def _lsm_level_rect_sharded(lvl, sp, qs, mesh):
    """Sharded twin of ``lsm._level_rect``: each clamped corner is one
    owner-gathered sharded evaluation; the below-root corner corrections
    reuse the *same* corner values (as the flat core reuses
    ``raw_eval2d``), and tombstones subtract replicated."""
    from .lsm import _tomb_rect_2d
    lx, ux, ly, uy = qs
    p = lvl.plan
    x0, x1, y0, y1 = p.root
    lxc, uxc = (jnp.clip(q, x0, x1) for q in (lx, ux))
    lyc, uyc = (jnp.clip(q, y0, y1) for q in (ly, uy))
    ev = lambda a, b: _exec_shard_eval2d(sp, a, b, mesh=mesh)
    v = (ev(uxc, uyc), ev(lxc, uyc), ev(uxc, lyc), ev(lxc, lyc))
    part = v[0] - v[1] - v[2] + v[3]
    zero = jnp.zeros((), p.dtype)
    for a, b, e, s in ((ux, uy, v[0], 1.0), (lx, uy, v[1], -1.0),
                       (ux, ly, v[2], -1.0), (lx, ly, v[3], 1.0)):
        part = part + jnp.where((a < x0) | (b < y0), -s * e, zero)
    if lvl.tomb_xs is not None:
        part = part - _tomb_rect_2d(lvl, lx, ux, ly, uy, p.dtype)
    return (part,)


def _lsm_level_dommax_sharded(lvl, sp, qs, mesh):
    """Sharded twin of ``lsm._level_dommax``."""
    from ..core.index2d import mst_dommax
    u, v = qs
    p = lvl.plan
    x0, x1, y0, y1 = p.root
    out = _exec_shard_dommax2d(sp, u, v, mesh=mesh, eps_rel=None)[0]
    raw = -out if p.agg == "min2d" else out   # back to MAX space
    wp = lvl.live_wpmax if lvl.live_wpmax is not None else p.ref_wpmax
    exact = mst_dommax(p.ref_xs, p.ref_ys_levels, wp, u, v).astype(p.dtype)
    valid = (u >= x0) & (v >= y0) & (exact > -jnp.inf)
    part = jnp.where(valid, raw, -jnp.inf)
    if lvl.vic_x is not None:
        threat = jnp.any((lvl.vic_x[None, :] <= u[:, None])
                         & (lvl.vic_y[None, :] <= v[:, None]), axis=1)
    else:
        threat = jnp.zeros(u.shape, bool)
    return part, exact, threat


_LSM_SHARD_CORES = {
    "sum": _lsm_level_sum_sharded, "count": _lsm_level_sum_sharded,
    "max": _lsm_level_extremum_sharded, "min": _lsm_level_extremum_sharded,
    "count2d": _lsm_level_rect_sharded, "sum2d": _lsm_level_rect_sharded,
    "max2d": _lsm_level_dommax_sharded, "min2d": _lsm_level_dommax_sharded,
}


def execute_lsm_sharded(slsm, buf, ranges, *, mesh: Mesh, eps_rel=None,
                        min_bucket: int = 64) -> QueryResult:
    """Fuse a query batch across a sharded level ladder (Q_abs only).

    Per-level raw evaluations run sharded; the exact corrections and the
    cross-level combiner (``lsm.combine_levels`` with ``backend='xla'``)
    run replicated, so answers are bit-identical to the unsharded
    ``execute_lsm(..., backend='xla', eps_rel=None)``.  Q_rel refinement
    would need the per-level refinement arrays partitioned (they are
    replicated here) — query the unsharded ladder for that."""
    if eps_rel is not None:
        raise ValueError(
            "sharded LSM execution is Q_abs-only (host-composed per-level "
            "fusion over replicated exact arrays); pass eps_rel=None or "
            "query the unsharded ladder")
    from .engine import pad_fills
    from .lsm import combine_levels, composed_bound
    check_pow2("min_bucket", min_bucket)
    agg = slsm.agg
    dt = slsm.dtype
    qs = [jnp.asarray(q).astype(dt) for q in ranges]
    n = qs[0].shape[0]
    size = _bucket_size(n, min_bucket)
    fills = pad_fills(slsm.levels[0].plan)
    qs = [_pad_bucket(q, size, jnp.asarray(f, dt))
          for q, f in zip(qs, fills)]
    core = _LSM_SHARD_CORES[agg]
    outs = [core(lvl, sp, qs, mesh)
            for lvl, sp in zip(slsm.levels, slsm.slevels)]
    bound = composed_bound(agg, slsm.deltas)
    ans, approx, refined = combine_levels(
        agg, outs, buf, qs, backend="xla", eps_rel=None, interpret=True,
        bq=min(64, size), bound=bound)
    return QueryResult(ans[:n], approx[:n], refined[:n])
