"""repro.engine — unified backend-dispatched query execution (DESIGN.md §7).

Lower a constructed index into a canonical device-resident ``IndexPlan``
once, then execute every query type through an ``Engine`` with
``backend='xla' | 'pallas' | 'pallas_scan' | 'ref'`` (``pallas`` is the
O(log H) locate->gather path, ``pallas_scan`` the one-hot membership scan
it replaced — kept for A/B benchmarking, DESIGN.md §10):

    from repro.core import build_index_1d
    from repro.engine import Engine, build_plan

    plan = build_plan(build_index_1d(keys, meas, "sum", delta=eps / 2))
    eng = Engine(backend="pallas")
    res = eng.query(plan, lq, uq, eps_rel=0.01)   # fused approx + refine

Serving, examples and benchmarks all route through this module; the Pallas
kernels and their jnp oracles are implementation details behind it.
"""
from .dynamic import (DeltaBuffer, DeltaBuffer2D, DynamicEngine,
                      DynamicEngine2D)
from .engine import BACKENDS, Engine
from .plan import (IndexPlan, IndexPlan2D, big_sentinel, build_plan,
                   build_plan_2d, pad_to_multiple)

__all__ = ["Engine", "BACKENDS", "IndexPlan", "IndexPlan2D", "build_plan",
           "build_plan_2d", "big_sentinel", "pad_to_multiple",
           "DynamicEngine", "DynamicEngine2D", "DeltaBuffer",
           "DeltaBuffer2D"]
