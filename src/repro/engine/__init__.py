"""repro.engine — unified backend-dispatched query execution (DESIGN.md §7).

Lower a constructed index into a canonical device-resident ``IndexPlan``
once, then execute every query type through the module-level ``execute_*``
dispatch path (or the ``Engine`` shim that binds a backend onto it) with
``backend='xla' | 'pallas' | 'pallas_scan' | 'ref'`` (``pallas`` is the
O(log H) locate->gather path, ``pallas_scan`` the one-hot membership scan
it replaced — kept for A/B benchmarking, DESIGN.md §10):

    from repro.core import build_index_1d
    from repro.engine import Engine, build_plan

    plan = build_plan(build_index_1d(keys, meas, "sum", delta=eps / 2))
    eng = Engine(backend="pallas")
    res = eng.query(plan, lq, uq, eps_rel=0.01)   # fused approx + refine

``shard_plan`` + ``ShardedEngine`` (engine/sharded.py) partition a 1-D
plan's segment tables across devices and answer through a ``shard_map``
executor with psum/pmax combination — bit-identical to the single-device
path; ``shard_plan_2d`` + ``ShardedEngine2D`` do the same for 2-D plans by
contiguous Morton z-ranges (DESIGN.md §12).  2-D plans carry measures:
``execute_sum2d`` answers rectangle SUM via the 4-corner decomposition and
``execute_extremum2d`` dominance MAX/MIN at a corner, with
``DynamicEngine2D`` buffering updates and merging through the selective
leaf refit.  ``engine.lsm`` stacks immutable plans into a geometric level
ladder (``LsmEngine``/``LsmEngine2D``) with worst-case update guarantees:
queries fuse O(log n) per-level evaluations exactly and merges become
bounded level-compactions (DESIGN.md §15).  This module is the execution
layer behind the declarative ``repro.api.PolyFit`` facade, which new code
should prefer; the Pallas kernels and their jnp oracles are implementation
details below it.
"""
from .dynamic import (DeltaBuffer, DeltaBuffer2D, DynamicEngine,
                      DynamicEngine2D, fused_executor,
                      fused_quantile_executor)
from .engine import (BACKENDS, Engine, QuantileResult, execute,
                     execute_count2d, execute_extremum, execute_extremum2d,
                     execute_quantile, execute_sum, execute_sum2d,
                     pad_fills)
from .lsm import (CompactionPolicy, LsmEngine, LsmEngine2D, LsmLevel,
                  LsmLevel2D, LsmPlan, LsmPlan2D, composed_bound,
                  execute_lsm, level_executor)
from .plan import (IndexPlan, IndexPlan2D, big_sentinel, build_plan,
                   build_plan_2d, pad_to_multiple)
from .window import WindowEngine
from .sharded import (ShardedDelta, ShardedEngine, ShardedEngine2D,
                      ShardedLsmPlan, ShardedLsmPlan2D, ShardedPlan,
                      ShardedPlan2D, execute_lsm_sharded, make_shard_mesh,
                      shard_buffer, shard_lsm_plan, shard_lsm_plan_2d,
                      shard_plan, shard_plan_2d)

__all__ = ["Engine", "BACKENDS", "IndexPlan", "IndexPlan2D", "build_plan",
           "build_plan_2d", "big_sentinel", "pad_to_multiple",
           "DynamicEngine", "DynamicEngine2D", "DeltaBuffer",
           "DeltaBuffer2D", "fused_executor", "fused_quantile_executor",
           "pad_fills", "execute", "execute_sum", "execute_extremum",
           "execute_quantile", "QuantileResult", "WindowEngine",
           "execute_count2d", "execute_sum2d", "execute_extremum2d",
           "LsmEngine", "LsmEngine2D", "LsmPlan", "LsmPlan2D", "LsmLevel",
           "LsmLevel2D", "CompactionPolicy", "composed_bound",
           "execute_lsm", "level_executor",
           "ShardedEngine", "ShardedEngine2D", "ShardedPlan",
           "ShardedPlan2D", "ShardedDelta", "shard_plan", "shard_plan_2d",
           "shard_buffer", "make_shard_mesh", "ShardedLsmPlan",
           "ShardedLsmPlan2D", "shard_lsm_plan", "shard_lsm_plan_2d",
           "execute_lsm_sharded"]
