"""Fault-tolerant checkpointing: atomic, content-verified, optionally async.

Layout:  <dir>/step_<n>/
             manifest.json   (tree structure, shapes, dtypes, crc32 per leaf)
             leaf_<i>.npy
A checkpoint is written to a temp directory and atomically renamed, so a
crash mid-save never corrupts the latest restorable state.  ``save_async``
snapshots to host (jax.device_get) synchronously — cheap — and writes on a
background thread so the train loop keeps stepping.  Restore verifies CRCs,
rebuilds the pytree, and (given a mesh + specs) device_puts each leaf with
its sharding — which is also the re-shard path after an elastic re-mesh.

At real multi-pod scale each process would write only its addressable
shards; the manifest format already records per-leaf shape/dtype so that
extension is a local change (documented, single-process here).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["CheckpointManager"]


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._pool = ThreadPoolExecutor(max_workers=1)
        self._pending: Optional[Future] = None
        self._lock = threading.Lock()

    # -- write ---------------------------------------------------------
    def save(self, step: int, tree: Any) -> str:
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        return self._write(step, host_tree)

    def save_async(self, step: int, tree: Any) -> Future:
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self.wait()
        self._pending = self._pool.submit(self._write, step, host_tree)
        return self._pending

    def wait(self):
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def _write(self, step: int, host_tree) -> str:
        leaves, treedef = jax.tree.flatten(host_tree)
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "treedef": str(treedef), "leaves": []}
        for i, leaf in enumerate(leaves):
            arr = np.asarray(leaf)
            path = os.path.join(tmp, f"leaf_{i}.npy")
            np.save(path, arr)
            manifest["leaves"].append({
                "shape": list(arr.shape), "dtype": str(arr.dtype),
                "crc32": zlib.crc32(arr.tobytes()) & 0xFFFFFFFF,
            })
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        with self._lock:
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)          # atomic publish
            self._gc()
        return final

    def _gc(self):
        steps = sorted(self.steps())
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- read ----------------------------------------------------------
    def steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, name, "manifest.json")):
                    out.append(int(name[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, template: Any, step: Optional[int] = None,
                mesh=None, specs=None) -> Any:
        """Rebuild the pytree of ``template``'s structure.  With mesh+specs
        each leaf is device_put with its NamedSharding (elastic re-shard)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        leaves_t, treedef = jax.tree.flatten(template)
        assert len(leaves_t) == len(manifest["leaves"]), "tree mismatch"
        out = []
        for i, meta in enumerate(manifest["leaves"]):
            arr = np.load(os.path.join(d, f"leaf_{i}.npy"))
            crc = zlib.crc32(arr.tobytes()) & 0xFFFFFFFF
            if crc != meta["crc32"]:
                raise IOError(f"checksum mismatch in leaf_{i} of step {step}")
            out.append(arr)
        tree = jax.tree.unflatten(treedef, out)
        if mesh is not None and specs is not None:
            from jax.sharding import NamedSharding
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
                tree, specs)
        return tree
