"""serve_step: one-token decode with a resident KV/SSM cache (the function
the decode_* / long_* dry-run cells lower), plus the prefill entry and the
aggregate-query request-step factory (the PolyFit serving hot path)."""
from __future__ import annotations

from typing import Callable, Optional

import jax

from ..models import decode_step, prefill

__all__ = ["make_serve_step", "make_prefill", "make_aggregate_step"]


def make_aggregate_step(engine, plan, eps_rel: Optional[float] = None) -> Callable:
    """One serving callable per request type (DESIGN.md §7).

    Binds (engine, plan, guarantee) once; each call pads the batch to its
    bucket and enters the engine's fused jitted path — approximation, Q_rel
    test and vectorized refinement in a single executable, with no per-query
    Python dispatch.  1-D plans take (lq, uq); 2-D plans (lx, ux, ly, uy).
    """
    def aggregate_step(*ranges):
        return engine.query(plan, *ranges, eps_rel=eps_rel)
    return aggregate_step


def make_serve_step(cfg) -> Callable:
    def serve_step(params, cache, token, pos):
        """token (B,) int32, pos scalar int32 -> (logits (B,V) f32, cache)."""
        return decode_step(params, cfg, cache, token, pos)
    return serve_step


def make_prefill(cfg, max_seq=None) -> Callable:
    def prefill_step(params, batch):
        return prefill(params, cfg, batch, max_seq=max_seq)
    return prefill_step
