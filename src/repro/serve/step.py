"""serve_step: one-token decode with a resident KV/SSM cache (the function
the decode_* / long_* dry-run cells lower), plus the prefill entry."""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from ..models import decode_step, prefill

__all__ = ["make_serve_step", "make_prefill"]


def make_serve_step(cfg) -> Callable:
    def serve_step(params, cache, token, pos):
        """token (B,) int32, pos scalar int32 -> (logits (B,V) f32, cache)."""
        return decode_step(params, cfg, cache, token, pos)
    return serve_step


def make_prefill(cfg, max_seq=None) -> Callable:
    def prefill_step(params, batch):
        return prefill(params, cfg, batch, max_seq=max_seq)
    return prefill_step
