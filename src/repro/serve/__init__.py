from .aggregates import AggregateService
from .engine import (DeadlineExceeded, EngineStats, Overloaded, QueueFull,
                     ServingEngine)
from .step import make_aggregate_step, make_prefill, make_serve_step

__all__ = ["make_serve_step", "make_prefill", "make_aggregate_step",
           "AggregateService", "ServingEngine", "QueueFull", "Overloaded",
           "DeadlineExceeded", "EngineStats"]
