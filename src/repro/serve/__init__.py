from .step import make_serve_step, make_prefill

__all__ = ["make_serve_step", "make_prefill"]
