from .aggregates import AggregateService
from .step import make_aggregate_step, make_prefill, make_serve_step

__all__ = ["make_serve_step", "make_prefill", "make_aggregate_step",
           "AggregateService"]
