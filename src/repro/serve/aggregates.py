"""Aggregate-query serving on the unified engine (DESIGN.md §7).

``AggregateService`` is the deployment-shaped wrapper around
``repro.engine``: it builds one PolyFit index per (dataset, aggregate),
lowers each to a canonical device-resident plan once, and serves batched
requests through per-request-type callables created by
``serve.step.make_aggregate_step``.  The backend ('xla' | 'pallas' |
'pallas_scan' | 'ref') is a constructor argument, so the same service code
runs the XLA reference path on CPU hosts and the Pallas locate->gather
kernels (or the one-hot scan variant, DESIGN.md §10) on TPU.
"""
from __future__ import annotations

import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core import build_index_1d, build_index_2d
from ..data import hki_series, osm_points, tweet_latitudes
from ..engine import (DynamicEngine, DynamicEngine2D, Engine, build_plan,
                      build_plan_2d)
from .step import make_aggregate_step

__all__ = ["AggregateService"]


class AggregateService:
    """Holds one plan per (dataset, aggregate); serves batched requests.

    Request kinds: 'count' (1-D COUNT over TWEET latitudes), 'max' (1-D MAX
    over the HKI series), 'count2d' (2-key COUNT over OSM points).

    ``dynamic=True`` wraps every plan in a delta-buffered
    ``DynamicEngine``/``DynamicEngine2D`` (engine/dynamic.py) and opens the
    ``insert``/``delete``/``flush`` endpoints: updates are absorbed without
    a rebuild, queries keep their certified bounds, and merges refit only
    affected segments on a background-installable plan swap.
    """

    def __init__(self, backend: str = "xla", eps_abs: float = 100.0,
                 eps_rel: Optional[float] = 0.01, n1: int = 150_000,
                 n2: int = 60_000, interpret: bool = True,
                 verbose: bool = True, dynamic: bool = False,
                 capacity: int = 1024):
        self.backend = backend
        self.eps_rel = eps_rel
        self.dynamic = dynamic
        say = print if verbose else (lambda *a, **k: None)
        say(f"[server] building indexes (backend={backend}, "
            f"dynamic={dynamic}) ...")
        t0 = time.time()
        lat = tweet_latitudes(n1)
        count_idx = build_index_1d(lat, None, "count", deg=2,
                                   delta=eps_abs / 2)
        ts, vals = hki_series(n1)
        max_idx = build_index_1d(ts, vals, "max", deg=3, delta=eps_abs)
        px, py = osm_points(n2)
        idx2d = build_index_2d(px, py, deg=3, delta=eps_abs / 4)

        self.engine = Engine(backend=backend, interpret=interpret)
        self.domains: Dict[str, Tuple[float, ...]] = {
            "count": (float(lat.min()), float(lat.max())),
            "max": (float(ts.min()), float(ts.max())),
            "count2d": (float(px.min()), float(px.max()),
                        float(py.min()), float(py.max())),
        }
        if dynamic:
            self._dyn = {
                "count": DynamicEngine(count_idx, backend=backend,
                                       interpret=interpret,
                                       capacity=capacity, background=True),
                "max": DynamicEngine(max_idx, backend=backend,
                                     interpret=interpret, capacity=capacity,
                                     background=True),
                "count2d": DynamicEngine2D(idx2d, backend=backend,
                                           interpret=interpret,
                                           capacity=capacity,
                                           background=True),
            }
            self.plans = {k: d.plan for k, d in self._dyn.items()}
            self._steps = {
                kind: (lambda d: lambda *r: d.query(*r, eps_rel=eps_rel))(dyn)
                for kind, dyn in self._dyn.items()}
        else:
            self._dyn = {}
            self.plans = {
                "count": build_plan(count_idx),
                "max": build_plan(max_idx),
                "count2d": build_plan_2d(idx2d),
            }
            # one engine-bound callable per request type — the only dispatch
            # a request pays is a dict lookup; everything below it is one
            # jitted executable per (aggregate, backend, batch-bucket)
            self._steps = {kind: make_aggregate_step(self.engine, plan,
                                                     eps_rel)
                           for kind, plan in self.plans.items()}
        say(f"[server] ready in {time.time() - t0:.1f}s — sizes: " +
            " ".join(f"{k}={p.size_bytes()}B" for k, p in self.plans.items()))

    def serve(self, kind: str, *ranges):
        """Answer one batched request; blocks until the device is done."""
        res = self._steps[kind](*ranges)
        jax.block_until_ready(res.answer)
        return res

    # -- update endpoints (dynamic mode) ---------------------------------

    def _dyn_engine(self, kind: str):
        if not self.dynamic:
            raise RuntimeError("updates require AggregateService("
                               "dynamic=True)")
        return self._dyn[kind]

    def insert(self, kind: str, *args) -> None:
        """Buffer new records: (keys[, measures]) for 1-D, (xs, ys) for
        'count2d'.  Subsequent queries fold them in exactly."""
        self._dyn_engine(kind).insert(*args)

    def delete(self, kind: str, *args) -> None:
        """Buffer delete tombstones for existing records."""
        self._dyn_engine(kind).delete(*args)

    def flush(self, kind: Optional[str] = None) -> None:
        """Merge buffered updates into fresh plans (all kinds by default)."""
        if not self.dynamic:
            raise RuntimeError("updates require AggregateService("
                               "dynamic=True)")
        kinds = [kind] if kind is not None else list(self._dyn)
        for k in kinds:
            self._dyn_engine(k).flush()
        for k in kinds:
            self.plans[k] = self._dyn[k].plan

    def warmup(self, batch_size: int = 1024) -> None:
        """Pre-compile the per-request-type executables for one bucket."""
        c0, c1 = self.domains["count"]
        l = jnp.full((batch_size,), c0)
        u = jnp.full((batch_size,), c1)
        self.serve("count", l, u)
        m0, m1 = self.domains["max"]
        self.serve("max", jnp.full((batch_size,), m0),
                   jnp.full((batch_size,), m1))
        x0, x1, y0, y1 = self.domains["count2d"]
        self.serve("count2d", jnp.full((batch_size,), x0),
                   jnp.full((batch_size,), x1),
                   jnp.full((batch_size,), y0),
                   jnp.full((batch_size,), y1))
