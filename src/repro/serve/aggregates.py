"""Aggregate-query serving on the declarative PolyFit session (DESIGN.md
§7, §11).

``AggregateService`` is the deployment-shaped wrapper around
``repro.api.PolyFit``: it declares one ``TableSpec`` per (dataset,
aggregate) with a shared ``ErrorBudget`` — the budget, not the service,
owns the Lemma 5.1/5.3/6.3 delta derivations — fits them into one session,
and serves batched requests by handing each one to ``session.query`` as a
``QuerySpec``.  The request endpoints (``serve``/``insert``/``delete``/
``flush``/``warmup``) are unchanged from the pre-session service; only the
machinery below them moved behind the facade.  The backend ('xla' |
'pallas' | 'pallas_scan' | 'ref') is a constructor argument, so the same
service code runs the XLA reference path on CPU hosts and the Pallas
locate->gather kernels (or the one-hot scan variant, DESIGN.md §10) on TPU.
"""
from __future__ import annotations

import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..api import ErrorBudget, PolyFit, QuerySpec, TableSpec
from ..data import hki_series, osm_points, tweet_latitudes

__all__ = ["AggregateService"]


class AggregateService:
    """Holds one fitted table per (dataset, aggregate); serves batched
    requests through the ``PolyFit`` session.

    Request kinds: 'count' (1-D COUNT over TWEET latitudes), 'max' (1-D MAX
    over the HKI series), 'count2d' (2-key COUNT over OSM points), 'sum2d'
    (2-key SUM over OSM points with synthetic per-node weights) and
    'max2d' (2-key dominance MAX over the same weighted points —
    DESIGN.md §12).

    ``dynamic=True`` fits every table with delta-buffered updates
    (engine/dynamic.py) and opens the ``insert``/``delete``/``flush``
    endpoints: updates are absorbed without a rebuild, queries keep their
    certified bounds, and merges refit only affected segments (1-D) or
    leaves (2-D selective refit) on a background-installable plan swap.
    ``shards=N`` serves every table from device-partitioned plans through
    the shard_map executors (engine/sharded.py; 1-D key ranges, 2-D Morton
    z-ranges; needs N local devices).
    """

    def __init__(self, backend: str = "xla", eps_abs: float = 100.0,
                 eps_rel: Optional[float] = 0.01, n1: int = 150_000,
                 n2: int = 60_000, interpret: bool = True,
                 verbose: bool = True, dynamic: bool = False,
                 capacity: int = 1024, shards: Optional[int] = None):
        self.backend = backend
        self.eps_rel = eps_rel
        self.dynamic = dynamic
        say = print if verbose else (lambda *a, **k: None)
        say(f"[server] building indexes (backend={backend}, "
            f"dynamic={dynamic}, shards={shards}) ...")
        t0 = time.time()
        lat = tweet_latitudes(n1)
        ts, vals = hki_series(n1)
        px, py = osm_points(n2)
        # synthetic per-node weights for the 2-D measure tables
        pw = 50.0 + 20.0 * np.sin(px / 7.0) + 15.0 * np.cos(py / 11.0)

        budget = ErrorBudget(abs=eps_abs, rel=eps_rel)
        # weighted sums run ~mean(w) larger than counts at the same shape,
        # so the SUM2D budget scales the COUNT one to matching *relative*
        # tightness (the absolute bound is still certified, just in
        # measure units); dominance MAX answers live on the measure
        # *spread*, so its budget is a fraction of that — reusing the
        # count-unit eps_abs would exceed the whole spread and certify a
        # trivial one-leaf fit
        wbudget = ErrorBudget(abs=eps_abs * float(pw.mean()), rel=eps_rel)
        mbudget = ErrorBudget(abs=0.1 * float(pw.max() - pw.min()),
                              rel=eps_rel)
        kw = dict(dynamic=dynamic, capacity=capacity, background=True)
        self.session = PolyFit.fit(
            {"count": lat, "max": (ts, vals), "count2d": (px, py),
             "sum2d": (px, py, pw), "max2d": (px, py, pw)},
            {"count": TableSpec("count", budget, deg=2, shards=shards, **kw),
             "max": TableSpec("max", budget, deg=3, shards=shards, **kw),
             "count2d": TableSpec("count2d", budget, deg=3, shards=shards,
                                  **kw),
             "sum2d": TableSpec("sum2d", wbudget, deg=3, shards=shards,
                                **kw),
             "max2d": TableSpec("max2d", mbudget, deg=3, shards=shards,
                                **kw)},
            backend=backend, interpret=interpret)

        dom2 = (float(px.min()), float(px.max()),
                float(py.min()), float(py.max()))
        self.domains: Dict[str, Tuple[float, ...]] = {
            "count": (float(lat.min()), float(lat.max())),
            "max": (float(ts.min()), float(ts.max())),
            "count2d": dom2, "sum2d": dom2, "max2d": dom2[1::2],
        }
        say(f"[server] ready in {time.time() - t0:.1f}s — sizes: " +
            " ".join(f"{k}={b}B" for k, b in self.session.size_bytes().items()))

    @property
    def plans(self):
        """Current device plans (fresh after dynamic merges)."""
        return {k: self.session.plan(k) for k in self.session.tables}

    def serve(self, kind: str, *ranges):
        """Answer one batched request; blocks until the device is done."""
        res = self.session.query(QuerySpec(kind, ranges))
        jax.block_until_ready(res.answer)
        return res

    # -- update endpoints (dynamic mode) ---------------------------------

    def _require_dynamic(self):
        if not self.dynamic:
            raise RuntimeError("updates require AggregateService("
                               "dynamic=True)")

    def insert(self, kind: str, *args) -> None:
        """Buffer new records: (keys[, measures]) for 1-D, (xs, ys) for
        'count2d', (xs, ys, measures) for 'sum2d'/'max2d'.  Subsequent
        queries fold them in exactly."""
        self._require_dynamic()
        self.session.insert(kind, *args)

    def delete(self, kind: str, *args) -> None:
        """Buffer delete tombstones for existing records."""
        self._require_dynamic()
        self.session.delete(kind, *args)

    def flush(self, kind: Optional[str] = None) -> None:
        """Merge buffered updates into fresh plans (all kinds by default)."""
        self._require_dynamic()
        self.session.flush(kind)

    def warmup(self, batch_size: int = 1024) -> None:
        """Pre-compile the per-request-type executables for one bucket."""
        c0, c1 = self.domains["count"]
        self.serve("count", jnp.full((batch_size,), c0),
                   jnp.full((batch_size,), c1))
        m0, m1 = self.domains["max"]
        self.serve("max", jnp.full((batch_size,), m0),
                   jnp.full((batch_size,), m1))
        x0, x1, y0, y1 = self.domains["count2d"]
        self.serve("count2d", jnp.full((batch_size,), x0),
                   jnp.full((batch_size,), x1),
                   jnp.full((batch_size,), y0),
                   jnp.full((batch_size,), y1))
        self.serve("sum2d", jnp.full((batch_size,), x0),
                   jnp.full((batch_size,), x1),
                   jnp.full((batch_size,), y0),
                   jnp.full((batch_size,), y1))
        self.serve("max2d", jnp.full((batch_size,), x1),
                   jnp.full((batch_size,), y1))
