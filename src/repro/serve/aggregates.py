"""Aggregate-query serving on the declarative PolyFit session (DESIGN.md
§7, §11, §13).

``AggregateService`` is the deployment-shaped wrapper around
``repro.api.PolyFit``: it declares one ``TableSpec`` per (dataset,
aggregate) with a shared ``ErrorBudget`` — the budget, not the service,
owns the Lemma 5.1/5.3/6.3 delta derivations — fits them into one
session, and serves requests through a ``ServingEngine``
(``serve/engine.py``): a bounded request queue with admission batching,
a per-(table, guarantee, bucket) AOT-compiled executable cache, and an
async staged update pipeline.  The request endpoints
(``serve``/``insert``/``delete``/``flush``/``warmup``) keep their
pre-engine signatures — ``serve`` still blocks on the answer and
``insert`` is still read-your-writes by default — plus ``submit`` for
callers that want the future.  The backend ('xla' | 'pallas' |
'pallas_scan' | 'ref') is a constructor argument, so the same service
code runs the XLA reference path on CPU hosts and the Pallas
locate->gather kernels (or the one-hot scan variant, DESIGN.md §10) on
TPU.
"""
from __future__ import annotations

import time
from typing import Dict, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from ..api import ErrorBudget, PolyFit, QuerySpec, TableSpec
from ..data import hki_series, osm_points, tweet_latitudes
from .engine import ServingEngine

__all__ = ["AggregateService"]


class AggregateService:
    """Holds one fitted table per (dataset, aggregate); serves batched
    requests through a continuous-batching ``ServingEngine`` over the
    ``PolyFit`` session.

    Request kinds: 1-D 'count' (TWEET latitudes), 'sum' / 'max' / 'min'
    (HKI series values over timestamps), and 2-key 'count2d' (OSM
    points), 'sum2d' / 'max2d' / 'min2d' (OSM points with synthetic
    per-node weights — DESIGN.md §12).

    ``dynamic=True`` fits every table with delta-buffered updates
    (engine/dynamic.py) and opens the ``insert``/``delete``/``flush``
    endpoints: updates stage on the host, drain in fused chunks off the
    query path, and merges refit only affected segments (1-D) or leaves
    (2-D selective refit) on a background-installable plan swap —
    readers never block on writers.  ``shards=N`` serves every table
    from device-partitioned plans through the shard_map executors
    (engine/sharded.py; 1-D key ranges, 2-D Morton z-ranges; needs N
    local devices).
    """

    KINDS_1D = ("count", "sum", "max", "min")
    KINDS_2D = ("count2d", "sum2d", "max2d", "min2d")

    def __init__(self, backend: str = "xla", eps_abs: float = 100.0,
                 eps_rel: Optional[float] = 0.01, n1: int = 150_000,
                 n2: int = 60_000, interpret: bool = True,
                 verbose: bool = True, dynamic: bool = False,
                 capacity: int = 1024, shards: Optional[int] = None,
                 max_queue: int = 1024, workers: int = 1,
                 admission: str = "block", start: bool = True,
                 guarantees: Optional[Dict[str, Tuple]] = None,
                 injector=None, retry=None, supervise: bool = True,
                 shed_watermark: Optional[float] = None,
                 default_deadline: Optional[float] = None):
        self.backend = backend
        self.eps_rel = eps_rel
        self.dynamic = dynamic
        say = print if verbose else (lambda *a, **k: None)
        say(f"[server] building indexes (backend={backend}, "
            f"dynamic={dynamic}, shards={shards}) ...")
        t0 = time.time()
        lat = tweet_latitudes(n1)
        ts, vals = hki_series(n1)
        px, py = osm_points(n2)
        # synthetic per-node weights for the 2-D measure tables
        pw = 50.0 + 20.0 * np.sin(px / 7.0) + 15.0 * np.cos(py / 11.0)

        budget = ErrorBudget(abs=eps_abs, rel=eps_rel)
        # weighted sums run ~mean(w) larger than counts at the same shape,
        # so the SUM/SUM2D budgets scale the COUNT one to matching
        # *relative* tightness (the absolute bound is still certified,
        # just in measure units); extremum answers live on the measure
        # *spread*, so their budgets are a fraction of that — reusing the
        # count-unit eps_abs would exceed the whole spread and certify a
        # trivial one-leaf fit
        sbudget = ErrorBudget(abs=eps_abs * float(np.abs(vals).mean()),
                              rel=eps_rel)
        vbudget = ErrorBudget(abs=0.1 * float(vals.max() - vals.min()),
                              rel=eps_rel)
        wbudget = ErrorBudget(abs=eps_abs * float(pw.mean()), rel=eps_rel)
        mbudget = ErrorBudget(abs=0.1 * float(pw.max() - pw.min()),
                              rel=eps_rel)
        kw = dict(dynamic=dynamic, capacity=capacity, background=True,
                  shards=shards)

        # per-kind serving guarantee classes: {kind: (deadline_s, priority)}
        # become the engine's admission-deadline / shed-ladder defaults
        def klass(kind):
            d, p = (guarantees or {}).get(kind, (None, 0))
            return dict(deadline=d, priority=p)
        self.session = PolyFit.fit(
            {"count": lat, "sum": (ts, vals), "max": (ts, vals),
             "min": (ts, vals), "count2d": (px, py),
             "sum2d": (px, py, pw), "max2d": (px, py, pw),
             "min2d": (px, py, pw)},
            {"count": TableSpec("count", budget, deg=2, **kw,
                                **klass("count")),
             "sum": TableSpec("sum", sbudget, deg=2, **kw, **klass("sum")),
             "max": TableSpec("max", vbudget, deg=3, **kw, **klass("max")),
             "min": TableSpec("min", vbudget, deg=3, **kw, **klass("min")),
             "count2d": TableSpec("count2d", budget, deg=3, **kw,
                                  **klass("count2d")),
             "sum2d": TableSpec("sum2d", wbudget, deg=3, **kw,
                                **klass("sum2d")),
             "max2d": TableSpec("max2d", mbudget, deg=3, **kw,
                                **klass("max2d")),
             "min2d": TableSpec("min2d", mbudget, deg=3, **kw,
                                **klass("min2d"))},
            backend=backend, interpret=interpret)

        dom1 = (float(ts.min()), float(ts.max()))
        dom2 = (float(px.min()), float(px.max()),
                float(py.min()), float(py.max()))
        self.domains: Dict[str, Tuple[float, ...]] = {
            "count": (float(lat.min()), float(lat.max())),
            "sum": dom1, "max": dom1, "min": dom1,
            "count2d": dom2, "sum2d": dom2,
            "max2d": dom2[1::2], "min2d": dom2[1::2],
        }
        self.engine = ServingEngine(self.session, max_queue=max_queue,
                                    workers=workers, admission=admission,
                                    start=start, injector=injector,
                                    retry=retry, supervise=supervise,
                                    shed_watermark=shed_watermark,
                                    default_deadline=default_deadline)
        say(f"[server] ready in {time.time() - t0:.1f}s — sizes: " +
            " ".join(f"{k}={b}B"
                     for k, b in self.session.size_bytes().items()))

    @property
    def plans(self):
        """Current device plans (fresh after dynamic merges)."""
        return {k: self.session.plan(k) for k in self.session.tables}

    @property
    def stats(self):
        """The serving engine's monotonic counters."""
        return self.engine.stats

    def serve(self, kind: str, *ranges):
        """Answer one batched request; blocks until the device is done.
        The request rides the engine queue, so concurrent callers
        coalesce into shared dispatches."""
        return self.engine.serve(kind, *ranges)

    def submit(self, kind: str, *ranges, deadline: Optional[float] = None,
               priority: Optional[int] = None):
        """Non-blocking variant: a future resolving to the QueryResult
        (carrying ``.staleness``).  ``deadline``/``priority`` override the
        kind's guarantee class for this request."""
        return self.engine.submit(QuerySpec(kind, ranges),
                                  deadline=deadline, priority=priority)

    def health(self) -> Dict:
        """The engine's liveness snapshot (thread states, stall list,
        crash counters, journal depth) — for operators and the chaos
        harness."""
        return self.engine.health()

    def shutdown(self, drain: bool = True) -> None:
        """Stop the serving engine (answers queued work when draining)."""
        self.engine.shutdown(drain=drain)

    # -- update endpoints (dynamic mode) ---------------------------------

    def _require_dynamic(self):
        if not self.dynamic:
            raise RuntimeError("updates require AggregateService("
                               "dynamic=True)")

    def insert(self, kind: str, *args, wait: bool = True) -> None:
        """Buffer new records: (keys[, measures]) for 1-D, (xs, ys) for
        'count2d', (xs, ys, measures) for the other 2-D kinds.
        ``wait=True`` (default) blocks until the records are
        query-visible; ``wait=False`` stages and returns immediately —
        the async pipeline folds them in off the query path."""
        self._require_dynamic()
        self.engine.insert(kind, *args, wait=wait)

    def delete(self, kind: str, *args, wait: bool = True) -> None:
        """Buffer delete tombstones for existing records."""
        self._require_dynamic()
        self.engine.delete(kind, *args, wait=wait)

    def flush(self, kind: Optional[str] = None) -> None:
        """Drain staged updates and merge them into fresh plans (all
        kinds by default)."""
        self._require_dynamic()
        self.engine.flush(kind)

    def warmup(self, batch_size: int = 1024) -> None:
        """Pre-compile the serving executables: the full power-of-two AOT
        bucket ladder up to ``batch_size`` for every kind, then one
        device execution per kind to warm allocator/runtime paths."""
        self.engine.warmup(max_bucket=batch_size)
        for kind in self.KINDS_1D:
            a, b = self.domains[kind]
            self.serve(kind, jnp.full((batch_size,), a),
                       jnp.full((batch_size,), b))
        x0, x1, y0, y1 = self.domains["count2d"]
        for kind in ("count2d", "sum2d"):
            self.serve(kind, jnp.full((batch_size,), x0),
                       jnp.full((batch_size,), x1),
                       jnp.full((batch_size,), y0),
                       jnp.full((batch_size,), y1))
        for kind in ("max2d", "min2d"):
            self.serve(kind, jnp.full((batch_size,), x1),
                       jnp.full((batch_size,), y1))
