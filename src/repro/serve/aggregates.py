"""Aggregate-query serving on the declarative PolyFit session (DESIGN.md
§7, §11).

``AggregateService`` is the deployment-shaped wrapper around
``repro.api.PolyFit``: it declares one ``TableSpec`` per (dataset,
aggregate) with a shared ``ErrorBudget`` — the budget, not the service,
owns the Lemma 5.1/5.3/6.3 delta derivations — fits them into one session,
and serves batched requests by handing each one to ``session.query`` as a
``QuerySpec``.  The request endpoints (``serve``/``insert``/``delete``/
``flush``/``warmup``) are unchanged from the pre-session service; only the
machinery below them moved behind the facade.  The backend ('xla' |
'pallas' | 'pallas_scan' | 'ref') is a constructor argument, so the same
service code runs the XLA reference path on CPU hosts and the Pallas
locate->gather kernels (or the one-hot scan variant, DESIGN.md §10) on TPU.
"""
from __future__ import annotations

import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..api import ErrorBudget, PolyFit, QuerySpec, TableSpec
from ..data import hki_series, osm_points, tweet_latitudes

__all__ = ["AggregateService"]


class AggregateService:
    """Holds one fitted table per (dataset, aggregate); serves batched
    requests through the ``PolyFit`` session.

    Request kinds: 'count' (1-D COUNT over TWEET latitudes), 'max' (1-D MAX
    over the HKI series), 'count2d' (2-key COUNT over OSM points).

    ``dynamic=True`` fits every table with delta-buffered updates
    (engine/dynamic.py) and opens the ``insert``/``delete``/``flush``
    endpoints: updates are absorbed without a rebuild, queries keep their
    certified bounds, and merges refit only affected segments on a
    background-installable plan swap.  ``shards=N`` serves the 1-D tables
    from device-partitioned plans through the shard_map executor
    (engine/sharded.py; needs N local devices).
    """

    def __init__(self, backend: str = "xla", eps_abs: float = 100.0,
                 eps_rel: Optional[float] = 0.01, n1: int = 150_000,
                 n2: int = 60_000, interpret: bool = True,
                 verbose: bool = True, dynamic: bool = False,
                 capacity: int = 1024, shards: Optional[int] = None):
        self.backend = backend
        self.eps_rel = eps_rel
        self.dynamic = dynamic
        say = print if verbose else (lambda *a, **k: None)
        say(f"[server] building indexes (backend={backend}, "
            f"dynamic={dynamic}, shards={shards}) ...")
        t0 = time.time()
        lat = tweet_latitudes(n1)
        ts, vals = hki_series(n1)
        px, py = osm_points(n2)

        budget = ErrorBudget(abs=eps_abs, rel=eps_rel)
        kw = dict(dynamic=dynamic, capacity=capacity, background=True)
        self.session = PolyFit.fit(
            {"count": lat, "max": (ts, vals), "count2d": (px, py)},
            {"count": TableSpec("count", budget, deg=2, shards=shards, **kw),
             "max": TableSpec("max", budget, deg=3, shards=shards, **kw),
             "count2d": TableSpec("count2d", budget, deg=3, **kw)},
            backend=backend, interpret=interpret)

        self.domains: Dict[str, Tuple[float, ...]] = {
            "count": (float(lat.min()), float(lat.max())),
            "max": (float(ts.min()), float(ts.max())),
            "count2d": (float(px.min()), float(px.max()),
                        float(py.min()), float(py.max())),
        }
        say(f"[server] ready in {time.time() - t0:.1f}s — sizes: " +
            " ".join(f"{k}={b}B" for k, b in self.session.size_bytes().items()))

    @property
    def plans(self):
        """Current device plans (fresh after dynamic merges)."""
        return {k: self.session.plan(k) for k in self.session.tables}

    def serve(self, kind: str, *ranges):
        """Answer one batched request; blocks until the device is done."""
        res = self.session.query(QuerySpec(kind, ranges))
        jax.block_until_ready(res.answer)
        return res

    # -- update endpoints (dynamic mode) ---------------------------------

    def _require_dynamic(self):
        if not self.dynamic:
            raise RuntimeError("updates require AggregateService("
                               "dynamic=True)")

    def insert(self, kind: str, *args) -> None:
        """Buffer new records: (keys[, measures]) for 1-D, (xs, ys) for
        'count2d'.  Subsequent queries fold them in exactly."""
        self._require_dynamic()
        self.session.insert(kind, *args)

    def delete(self, kind: str, *args) -> None:
        """Buffer delete tombstones for existing records."""
        self._require_dynamic()
        self.session.delete(kind, *args)

    def flush(self, kind: Optional[str] = None) -> None:
        """Merge buffered updates into fresh plans (all kinds by default)."""
        self._require_dynamic()
        self.session.flush(kind)

    def warmup(self, batch_size: int = 1024) -> None:
        """Pre-compile the per-request-type executables for one bucket."""
        c0, c1 = self.domains["count"]
        self.serve("count", jnp.full((batch_size,), c0),
                   jnp.full((batch_size,), c1))
        m0, m1 = self.domains["max"]
        self.serve("max", jnp.full((batch_size,), m0),
                   jnp.full((batch_size,), m1))
        x0, x1, y0, y1 = self.domains["count2d"]
        self.serve("count2d", jnp.full((batch_size,), x0),
                   jnp.full((batch_size,), x1),
                   jnp.full((batch_size,), y0),
                   jnp.full((batch_size,), y1))
