"""Continuous-batching serving engine over a ``PolyFit`` session
(DESIGN.md §13, fault model §14).

``ServingEngine`` turns the synchronous session facade into a traffic
engine with three moving parts:

* **Bounded request queue + admission batching.**  ``submit`` enqueues a
  read and returns a future; background worker threads drain the queue,
  coalesce whatever is waiting (up to ``max_batch`` queries) into groups
  keyed on (table, guarantee, deadline class), pad each group to its
  power-of-two bucket, and answer every caller's future from one device
  dispatch.  The executors are elementwise per query, so coalesced
  answers are bit-identical to serial execution of the same requests.
  Admission is ``'block'`` (default: ``submit`` waits for room) or
  ``'reject'`` (``QueueFull`` when the queue is at capacity).

* **AOT executable cache.**  Each (table, guarantee, bucket) is served by
  a ``jax.jit(fn).lower(plan, buf, *qs).compile()`` executable, so the
  steady state never re-traces: admission batching maps every batch shape
  onto the cached bucket ladder.  Compiled objects pin the plan's static
  metadata (``delta``/``h``/``n`` change on every merge), so entries are
  keyed by plan identity and recompiled on plan swap — the plan-swap
  protocol is simply "readers snapshot, the cache invalidates on
  mismatch".  ``warmup`` eagerly compiles the full bucket ladder per
  table instead of a single shape.  Two refinements on top of that
  protocol: (a) LSM tables (``TableSpec(lsm=True)``) are served through
  ``execute_lsm`` with one executable *per level*, keyed
  (table, guarantee, bucket, slot) — a compaction invalidates only the
  rebuilt slots' entries, surviving levels keep serving their compiled
  code; (b) the engine registers a ``session.on_plan_swap`` listener per
  dynamic table, so the merge/compaction thread AOT-lowers the incoming
  plan (or preview ladder) for every warmed bucket *before* the atomic
  install — post-swap dispatches promote the staged executable
  (``aot_promotions``) instead of paying a relower.

* **Async insert pipeline with a write-ahead journal.**  ``insert``/
  ``delete`` append to a host-side journal and return immediately
  (``wait=False``); a background updater thread drains the *un-applied
  suffix*, coalescing consecutive same-(table, op) runs into few engine
  calls — one fused jitted append per capacity-sized, item-aligned chunk
  — and marks each item applied only after its chunk lands.  A crashed
  updater therefore replays exactly the un-applied suffix on restart,
  preserving the whole-chunk-prefix visibility order readers rely on.
  Per-table submission order is preserved; ``wait=True`` blocks until
  the caller's records are query-visible.

Fault-tolerance hardening (``repro.dist.fault_tolerance``):

* **Deadlines.**  ``submit(spec, deadline=...)`` (or a per-table default
  from ``TableSpec.deadline``) rejects requests whose deadline expires
  while queued with ``DeadlineExceeded`` *before* wasting a dispatch;
  the deadline class joins the coalescing key, so a tight-deadline
  request is never padded into — or dispatched behind — a slack batch
  (groups dispatch earliest-deadline-first).

* **Supervised threads.**  Workers and the updater heartbeat into a
  ``HeartbeatMonitor``; a supervisor thread restarts crashed threads, a
  crash fails only the in-flight group's futures (never the whole
  queue), and crash/restart counts surface in ``EngineStats``.

* **Graceful degradation.**  ``shed_watermark`` arms a load-shedding
  ladder: the queue capacity beyond the watermark is reserved for
  higher-priority guarantee classes (class p may fill a
  ``w + (1-w)(1 - 2^-p)`` fraction), so the lowest class sheds first
  (``Overloaded``).  While the updater is down, reads keep serving from
  the last installed plan snapshot; each answered future carries
  ``.staleness`` — the acknowledged-but-unapplied record count for its
  table at dispatch time.  An optional ``RetryPolicy`` retries transient
  dispatch failures with backoff before failing the group.

* **Failure injection.**  An optional ``FailureInjector`` is consulted at
  three sites — ``serve.worker`` (thread crash with requests in flight),
  ``serve.dispatch`` (transient dispatch failure, retried), and
  ``serve.updater`` (updater crash between fused applies) — which is how
  the chaos harness (tests/chaos_serve.py, bench_serve --chaos) drives
  crash storms through the real code paths.

Sharded tables (``TableSpec(shards=N)``) fall back to the session's
shard_map executors, which carry their own cache; everything else goes
through the AOT path.
"""
from __future__ import annotations

import dataclasses
import math
import queue
import threading
import time
from collections import deque
from concurrent.futures import Future
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..api.session import Answer
from ..api.spec import DEFAULT_REL, QueryBatch, QuerySpec
from ..core.queries import QueryResult
from ..dist.fault_tolerance import HeartbeatMonitor
from ..engine import execute_lsm, level_executor, pad_fills
from ..engine.engine import _bucket_size, _pad_bucket

__all__ = ["ServingEngine", "QueueFull", "Overloaded", "DeadlineExceeded",
           "EngineStats"]


class QueueFull(RuntimeError):
    """``admission='reject'`` and the bounded request queue is at capacity."""


class Overloaded(QueueFull):
    """Shed by the degradation ladder: the queue is past the watermark and
    this request's priority class has no reserved headroom left."""


class DeadlineExceeded(TimeoutError):
    """The request's admission deadline expired while it was queued."""


@dataclasses.dataclass
class EngineStats:
    """Monotonic counters; read a consistent copy via ``engine.stats``."""

    submitted: int = 0        # read requests accepted into the queue
    rejected: int = 0         # read requests refused by admission='reject'
    shed: int = 0             # read requests shed by the priority ladder
    answered: int = 0         # read requests resolved by a dispatch
    deadline_expired: int = 0  # queued requests expired before dispatch
    dispatches: int = 0       # device dispatches serving reads
    coalesced: int = 0        # requests that shared a dispatch with others
    stale_reads: int = 0      # answers served with unapplied updates pending
    aot_compiles: int = 0     # executables lowered+compiled on dispatch
    aot_hits: int = 0         # dispatches served from the cache
    aot_invalidations: int = 0  # cache entries dropped on plan swap
    aot_precompiles: int = 0  # executables staged on the merge thread
    aot_promotions: int = 0   # staged executables promoted at dispatch
    staged_records: int = 0   # update records accepted into the journal
    drains: int = 0           # updater wake-ups that applied work
    fused_applies: int = 0    # engine insert/delete calls made by drains
    worker_crashes: int = 0   # worker threads that died mid-batch
    updater_crashes: int = 0  # updater threads that died mid-drain
    restarts: int = 0         # threads respawned by the supervisor
    journal_replayed: int = 0  # items a restarted updater found un-applied


class _ReadRequest:
    __slots__ = ("table", "kind", "rel", "ranges", "params", "n", "future",
                 "deadline", "dclass", "priority")

    def __init__(self, table: str, rel, ranges: Tuple, n: int,
                 deadline: Optional[float] = None,
                 dclass: Optional[int] = None, priority: int = 0,
                 kind: str = "count", params: Tuple = ()):
        self.table = table
        self.kind = kind            # resolved query kind (never None)
        self.rel = rel
        self.ranges = ranges
        self.params = params        # static kind params ((t0, t1) windows)
        self.n = n
        self.deadline = deadline    # absolute monotonic, or None
        self.dclass = dclass        # pow-2 bucket of the deadline duration
        self.priority = priority
        self.future: Future = Future()


class _WriteItem:
    __slots__ = ("table", "kind", "args", "n", "future", "seq")

    def __init__(self, table: Optional[str], kind: str, args: Tuple,
                 n: int):
        self.table = table
        self.kind = kind            # 'insert' | 'delete' | 'barrier'
        self.args = args
        self.n = n
        self.seq = -1               # assigned by the journal
        self.future: Future = Future()


class _UpdateJournal:
    """Write-ahead staging log with an applied watermark.

    ``append`` assigns a monotone sequence number; ``pending`` returns the
    un-applied suffix (items above the watermark, in order); the updater
    calls ``mark_applied`` only after an item's fused chunk has landed on
    the engine, so whatever the updater was holding when it crashed is
    exactly what ``pending`` hands its replacement.  All methods run under
    the engine's staging condition variable.
    """

    __slots__ = ("_items", "_next_seq", "_applied")

    def __init__(self):
        self._items: deque = deque()
        self._next_seq = 0
        self._applied = -1          # every seq <= this has been applied

    def append(self, item: _WriteItem) -> int:
        item.seq = self._next_seq
        self._next_seq += 1
        self._items.append(item)
        return item.seq

    def pending(self) -> List[_WriteItem]:
        return [it for it in self._items if it.seq > self._applied]

    def mark_applied(self, seq: int) -> None:
        self._applied = max(self._applied, seq)
        while self._items and self._items[0].seq <= self._applied:
            self._items.popleft()

    def depth(self, table: Optional[str] = None) -> int:
        return sum(it.n for it in self._items
                   if it.seq > self._applied
                   and (table is None or it.table == table))


class _ExecEntry:
    """One cached AOT executable plus its staged successor.

    ``plan_ref`` keys validity by identity (plan/level meta changes on
    every swap); ``sig`` guards the pytree *structure* of the non-plan
    operands (a delta buffer growing a victim mask, a level growing a
    tombstone array — an AOT executable pins those shapes).
    ``next_*`` hold the successor staged by the merge-thread
    pre-compilation listener; ``promote`` installs it at dispatch when
    the incoming plan matches, so a swap costs zero relowers."""

    __slots__ = ("plan_ref", "compiled", "sig", "buf_tmpl",
                 "next_ref", "next_compiled", "next_sig")

    def __init__(self, plan_ref, compiled, sig=None, buf_tmpl=None):
        self.plan_ref = plan_ref    # identity-keyed: meta changes per swap
        self.compiled = compiled
        self.sig = sig
        self.buf_tmpl = buf_tmpl    # ShapeDtypeStruct pytree for relowers
        self.next_ref = None
        self.next_compiled = None
        self.next_sig = None

    def matches(self, plan_ref, sig) -> bool:
        return self.plan_ref is plan_ref and self.sig == sig

    def stage(self, plan_ref, compiled, sig) -> None:
        self.next_ref = plan_ref
        self.next_compiled = compiled
        self.next_sig = sig

    def promote(self, plan_ref, sig) -> bool:
        if self.next_ref is plan_ref and self.next_sig == sig:
            self.plan_ref = self.next_ref
            self.compiled = self.next_compiled
            self.sig = self.next_sig
            self.next_ref = self.next_compiled = self.next_sig = None
            return True
        return False


def _tree_sig(x) -> Tuple:
    """Hashable (structure, shapes, dtypes) signature of a pytree."""
    leaves, treedef = jax.tree_util.tree_flatten(x)
    return treedef, tuple((l.shape, str(l.dtype)) for l in leaves)


def _tree_tmpl(x):
    """The pytree with every array leaf abstracted to ShapeDtypeStruct
    (``jax.jit(...).lower`` accepts these in place of concrete arrays)."""
    return jax.tree_util.tree_map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), x)


class ServingEngine:
    """Queue -> admission batcher -> AOT executable cache over one session.

    ``max_queue`` bounds the read queue (backpressure), ``max_batch`` caps
    the queries coalesced into one dispatch, ``workers`` is the number of
    drain threads (1 keeps dispatch order deterministic).  ``start=False``
    builds the engine without threads — ``submit`` still queues, nothing
    drains — which makes backpressure deterministic to test; call
    ``start()`` to begin serving.

    Fault-tolerance knobs: ``injector`` (a ``FailureInjector`` consulted
    at the serve.worker / serve.dispatch / serve.updater sites),
    ``retry`` (a ``RetryPolicy`` wrapped around dispatches — filter its
    ``retry_on`` to the transient exception classes), ``supervise``
    (restart crashed worker/updater threads; on by default),
    ``heartbeat_deadline`` (seconds without a beat before a thread counts
    as stalled), ``shed_watermark`` (queue fraction where the priority
    ladder starts shedding; ``None`` disables shedding), and
    ``default_deadline`` (admission deadline for requests whose table
    declares none).
    """

    def __init__(self, session, *, max_queue: int = 1024,
                 max_batch: int = 4096, workers: int = 1,
                 admission: str = "block", start: bool = True,
                 injector=None, retry=None, supervise: bool = True,
                 heartbeat_deadline: float = 5.0,
                 shed_watermark: Optional[float] = None,
                 default_deadline: Optional[float] = None):
        if admission not in ("block", "reject"):
            raise ValueError(f"admission must be 'block' or 'reject', "
                             f"got {admission!r}")
        if workers < 1:
            raise ValueError("workers must be >= 1")
        if shed_watermark is not None and not 0.0 < shed_watermark <= 1.0:
            raise ValueError("shed_watermark must be in (0, 1]")
        self.session = session
        self.max_batch = int(max_batch)
        self.admission = admission
        self.supervise = bool(supervise)
        self.shed_watermark = shed_watermark
        self.default_deadline = default_deadline
        self._injector = injector
        self._retry = retry
        self._crash_exc = injector.exc if injector is not None else ()
        self.monitor = HeartbeatMonitor(deadline=heartbeat_deadline)
        self._queue: "queue.Queue[_ReadRequest]" = queue.Queue(max_queue)
        self._cache: Dict[Tuple, _ExecEntry] = {}
        self._compile_lock = threading.Lock()
        self._journal = _UpdateJournal()
        self._staging_cv = threading.Condition()
        self._drain_lock = threading.Lock()
        self._stats = EngineStats()
        self._stats_lock = threading.Lock()
        self._update_errors: List[BaseException] = []
        self._stop = threading.Event()
        self._shut_down = False
        self._n_workers = int(workers)
        self._thread_lock = threading.Lock()
        self._workers: List[Optional[threading.Thread]] = []
        self._updater: Optional[threading.Thread] = None
        self._supervisor: Optional[threading.Thread] = None
        self._register_swap_listeners()
        if start:
            self.start()

    # -- lifecycle --------------------------------------------------------

    def _spawn_worker(self, i: int) -> threading.Thread:
        t = threading.Thread(target=self._worker_run, args=(i,),
                             daemon=True, name=f"polyfit-serve-{i}")
        t.start()
        return t

    def _spawn_updater(self, replaying: bool) -> threading.Thread:
        t = threading.Thread(target=self._updater_run, args=(replaying,),
                             daemon=True, name="polyfit-update")
        t.start()
        return t

    def start(self) -> None:
        """Spawn the worker + updater (+ supervisor) threads (idempotent)."""
        if self._shut_down:
            raise RuntimeError("engine was shut down")
        with self._thread_lock:
            if self._workers:
                return
            self._workers = [self._spawn_worker(i)
                             for i in range(self._n_workers)]
            self._updater = self._spawn_updater(replaying=False)
            if self.supervise:
                self._supervisor = threading.Thread(
                    target=self._supervisor_loop, daemon=True,
                    name="polyfit-supervise")
                self._supervisor.start()

    @property
    def _threads(self) -> List[threading.Thread]:
        with self._thread_lock:
            out = [t for t in self._workers if t is not None]
            if self._updater is not None:
                out.append(self._updater)
            if self._supervisor is not None:
                out.append(self._supervisor)
            return out

    @property
    def running(self) -> bool:
        return bool(self._threads) and not self._shut_down

    def shutdown(self, drain: bool = True, timeout: Optional[float] = None
                 ) -> None:
        """Stop the engine.  ``drain=True`` answers everything already
        queued (reads) and applies everything staged (writes) first;
        ``drain=False`` cancels queued reads and staged writes with a
        ``RuntimeError``.  Idempotent; a ``submit`` racing shutdown either
        gets served (drain) or resolves with the same error — never
        hangs."""
        if self._shut_down:
            return
        threads = self._threads
        if drain and threads:
            self._queue.join()
            # apply staged writes but never raise deferred errors out of a
            # cleanup path — they stay queued for explicit drain_updates()
            self._drain_updates(raise_errors=False)
        self._shut_down = True
        self._stop.set()
        with self._staging_cv:
            self._staging_cv.notify_all()
        if not drain:
            self._cancel_queued("serving engine shut down")
            self._cancel_staged("serving engine shut down")
        for t in threads:
            t.join(timeout)
        with self._thread_lock:
            self._workers = []
            self._updater = None
            self._supervisor = None
        # a submit may have slipped in between the drain/cancel above and
        # the _shut_down flag landing; nothing serves it now, so sweep —
        # submit() re-checks the flag after its put for the same reason
        self._cancel_queued("serving engine shut down")

    def _cancel_queued(self, msg: str) -> None:
        while True:
            try:
                req = self._queue.get_nowait()
            except queue.Empty:
                return
            if not req.future.done():
                req.future.set_exception(RuntimeError(msg))
            self._queue.task_done()

    def _cancel_staged(self, msg: str) -> None:
        with self._staging_cv:
            items = self._journal.pending()
            for it in items:
                self._journal.mark_applied(it.seq)
        for it in items:
            if not it.future.done():
                if it.kind == "barrier":
                    it.future.set_result(None)
                else:
                    it.future.set_exception(RuntimeError(msg))

    # -- supervision ------------------------------------------------------

    def _supervisor_loop(self) -> None:
        """Restart crashed worker/updater threads until shutdown."""
        while not self._stop.wait(0.02):
            with self._thread_lock:
                if self._stop.is_set() or not self._workers:
                    continue
                restarted = 0
                for i, t in enumerate(self._workers):
                    if t is not None and not t.is_alive():
                        self._workers[i] = self._spawn_worker(i)
                        restarted += 1
                if self._updater is not None and not self._updater.is_alive():
                    self._updater = self._spawn_updater(replaying=True)
                    restarted += 1
            if restarted:
                with self._stats_lock:
                    self._stats.restarts += restarted

    def health(self) -> Dict:
        """Liveness snapshot: thread states, stall list, crash counters,
        journal depth — the supervisor's view, for operators."""
        with self._thread_lock:
            workers_alive = sum(1 for t in self._workers
                                if t is not None and t.is_alive())
            updater_alive = (self._updater is not None
                             and self._updater.is_alive())
        st = self.stats
        out = {
            "running": self.running,
            "workers_alive": workers_alive,
            "updater_alive": updater_alive,
            "stalled": self.monitor.stalled(),
            "queue_depth": self.queue_depth,
            "staged_depth": self.staged_depth,
            "worker_crashes": st.worker_crashes,
            "updater_crashes": st.updater_crashes,
            "restarts": st.restarts,
        }
        if self._retry is not None:
            out["retry"] = {"retries": self._retry.retries,
                            "giveups": self._retry.giveups,
                            "slept": self._retry.slept}
        return out

    def _maybe_fail(self, site: str) -> None:
        if self._injector is not None:
            self._injector.maybe_fail(site)

    # -- reads ------------------------------------------------------------

    def _admission_class(self, table: str) -> Tuple[Optional[float], int]:
        deadline, priority = self.session.admission_class(table)
        if deadline is None:
            deadline = self.default_deadline
        return deadline, int(priority)

    def _shed(self, priority: int) -> bool:
        w = self.shed_watermark
        cap = self._queue.maxsize
        if w is None or cap <= 0:
            return False
        # the (1-w) tail of the queue is reserved in geometric slices for
        # higher priority classes: class p may fill w + (1-w)(1 - 2^-p)
        limit = cap * (w + (1.0 - w) * (1.0 - 2.0 ** (-max(priority, 0))))
        return self._queue.qsize() >= limit

    def submit(self, spec: QuerySpec, *, deadline: Optional[float] = None,
               priority: Optional[int] = None,
               timeout: Optional[float] = None) -> Future:
        """Enqueue one read; the future resolves to its structured
        ``Answer`` (value + certified bound + staleness; ``.staleness`` is
        also set on the future itself for pre-Answer consumers).

        ``deadline`` (seconds from now; default the table's class) bounds
        the *queue wait*: a request still queued when it expires resolves
        with ``DeadlineExceeded`` instead of dispatching.  ``priority``
        picks the shedding rung when the ladder is armed.
        ``admission='block'`` waits up to ``timeout`` for queue room (then
        raises ``QueueFull``); ``'reject'`` raises immediately when full.
        """
        if self._shut_down:
            raise RuntimeError("serving engine shut down")
        kind, rel, params = self.session.resolve_spec(spec)
        d_default, p_default = self._admission_class(spec.table)
        if deadline is None:
            deadline = d_default
        if priority is None:
            priority = p_default
        if self._shed(priority):
            with self._stats_lock:
                self._stats.shed += 1
            raise Overloaded(
                f"load shed: queue past watermark "
                f"{self.shed_watermark:.2f} for priority {priority}")
        dclass = (None if deadline is None
                  else max(math.ceil(math.log2(max(deadline, 1e-3))), -10))
        abs_deadline = (None if deadline is None
                        else time.monotonic() + deadline)
        req = _ReadRequest(spec.table, rel, spec.ranges, len(spec),
                           abs_deadline, dclass, priority, kind=kind,
                           params=params)
        try:
            if self.admission == "reject":
                self._queue.put_nowait(req)
            else:
                self._queue.put(req, timeout=timeout)
        except queue.Full:
            with self._stats_lock:
                self._stats.rejected += 1
            raise QueueFull(f"request queue at capacity "
                            f"({self._queue.maxsize})") from None
        with self._stats_lock:
            self._stats.submitted += 1
        if self._shut_down:
            # raced shutdown's final sweep: make sure this future resolves
            self._cancel_queued("serving engine shut down")
        return req.future

    def query(self, request: Union[QuerySpec, QueryBatch,
                                   Sequence[QuerySpec]],
              *, timeout: Optional[float] = None):
        """Blocking convenience mirroring ``session.query``: one spec
        returns its ``Answer``, a batch returns the aligned list."""
        if isinstance(request, QuerySpec):
            return self.submit(request).result(timeout)
        specs = list(request.specs if isinstance(request, QueryBatch)
                     else request)
        futures = [self.submit(s) for s in specs]
        return [f.result(timeout) for f in futures]

    def serve(self, table: str, *ranges, rel=DEFAULT_REL,
              timeout: Optional[float] = None):
        """Blocking single-request endpoint: ``serve('count', lq, uq)``."""
        res = self.submit(QuerySpec(table, ranges, rel)).result(timeout)
        jax.block_until_ready(res.answer)
        return res

    # -- worker: drain, coalesce, dispatch --------------------------------

    def _worker_run(self, wid: int) -> None:
        """Thread body: loop until stop; on crash, die quietly (the
        supervisor restarts; the crash already failed only the in-flight
        batch inside ``_worker_loop``)."""
        name = f"worker-{wid}"
        try:
            self._worker_loop(name)
        except BaseException:
            with self._stats_lock:
                self._stats.worker_crashes += 1
        finally:
            self.monitor.forget(name)

    def _worker_loop(self, name: str) -> None:
        q = self._queue
        while True:
            self.monitor.beat(name)
            try:
                req = q.get(timeout=0.05)
            except queue.Empty:
                if self._stop.is_set():
                    return
                continue
            batch = [req]
            try:
                # chaos site: a crash here has requests in flight — fail
                # exactly those futures, account the queue, then die
                self._maybe_fail("serve.worker")
                budget = self.max_batch - req.n
                while budget > 0:
                    # peek so the admission batch never overshoots
                    # max_batch — overshoot would hit a bucket above the
                    # warmed ladder
                    with q.mutex:
                        if not q.queue or q.queue[0].n > budget:
                            break
                    try:
                        nxt = q.get_nowait()
                    except queue.Empty:
                        break
                    batch.append(nxt)
                    budget -= nxt.n
                self._process_batch(batch)
            except BaseException as e:
                for r in batch:
                    if not r.future.done():
                        r.future.set_exception(e)
                raise
            finally:
                for _ in batch:
                    q.task_done()

    def _process_batch(self, batch: List[_ReadRequest]) -> None:
        # admission deadlines: expire pre-dispatch, never waste the device
        now = time.monotonic()
        live: List[_ReadRequest] = []
        expired = 0
        for r in batch:
            if r.deadline is not None and now > r.deadline:
                if not r.future.done():
                    r.future.set_exception(DeadlineExceeded(
                        f"deadline expired after "
                        f"{now - r.deadline:.3f}s in queue"))
                expired += 1
            else:
                live.append(r)
        if expired:
            with self._stats_lock:
                self._stats.deadline_expired += expired
        groups: Dict[Tuple, List[_ReadRequest]] = {}
        for r in live:
            # the deadline class keys the group: tight requests are never
            # padded into (or billed for) a slack batch's bucket; kind and
            # its static params key it too — a quantile never coalesces
            # into a range bucket, nor one window into another's epochs
            groups.setdefault((r.table, r.kind, r.rel, r.dclass, r.params),
                              []).append(r)
        # earliest-deadline-first across the batch's groups
        ordered = sorted(
            groups.items(),
            key=lambda kv: min((r.deadline for r in kv[1]
                                if r.deadline is not None),
                               default=float("inf")))
        for (table, kind, rel, _, params), grp in ordered:
            # count before resolving: a caller that saw its future
            # complete must also see it reflected in ``stats``
            with self._stats_lock:
                self._stats.dispatches += 1
                self._stats.answered += len(grp)
                if len(grp) > 1:
                    self._stats.coalesced += len(grp)
            try:
                if self._retry is not None:
                    self._retry.call(self._dispatch, table, kind, rel,
                                     params, grp)
                else:
                    self._dispatch(table, kind, rel, params, grp)
            except BaseException as e:   # surface on the callers
                for r in grp:
                    if not r.future.done():
                        r.future.set_exception(e)

    def _dispatch(self, table: str, kind: str, rel, params: Tuple,
                  grp: List[_ReadRequest]) -> None:
        self._maybe_fail("serve.dispatch")
        sess = self.session
        staleness = self.staleness(table)
        if staleness:
            with self._stats_lock:
                self._stats.stale_reads += len(grp)
        nq = sum(r.n for r in grp)
        size = _bucket_size(nq, sess.min_bucket)
        if kind == "window":
            # epoch-ring tables: the window snapshot *is* a small LSM plan
            # of immutable per-epoch levels — served by the same per-level
            # AOT machinery (sealed epochs never invalidate their entries)
            plan, buf = sess.window_snapshot(table, *params)
            bound = sess.window_bound(table, *params)
            if plan is None:
                res = sess.query(QuerySpec(table, self._concat_ranges(grp),
                                           rel, kind="window",
                                           params=params))
            else:
                res = execute_lsm(plan, buf, self._concat_ranges(grp),
                                  backend=sess.backend, eps_rel=rel,
                                  interpret=sess.interpret, bq=sess.bq,
                                  min_bucket=sess.min_bucket,
                                  level_runner=self._lsm_runner(
                                      table, rel, size, plan))
                res = Answer(res.answer, res.approx, res.refined,
                             bound=bound, staleness=staleness)
            jax.block_until_ready(res.answer)
            self._scatter(grp, res, staleness)
            return
        if sess.is_sharded(table):
            # shard_map executors keep their own cache; no AOT ladder here
            ranges = self._concat_ranges(grp)
            res = sess.query(QuerySpec(table, ranges, rel, kind=kind,
                                       params=params))
            jax.block_until_ready(res.answer)
            self._scatter(grp, res, staleness)
            return
        plan, buf = sess.snapshot(table)
        if kind == "quantile":
            compiled = self._executable(table, rel, size, plan, buf,
                                        kind="quantile")
            (qs,) = self._concat_ranges(grp)
            qp = _pad_bucket(jnp.asarray(qs, plan.dtype), size,
                             jnp.asarray(0.5, plan.dtype))
            ans, lo, hi = compiled(plan, buf, qp)
            jax.block_until_ready(ans)
            res = Answer(ans, ans, jnp.zeros(ans.shape, bool),
                         bound=(lo, hi), staleness=staleness)
            self._scatter(grp, res, staleness)
            return
        bound = sess.budget(table).bound(sess.spec(table).agg)
        if hasattr(plan, "levels"):
            # LSM ladder: one AOT executable *per level*, fused exactly by
            # execute_lsm's combiner — a compaction only invalidates the
            # rebuilt slots' entries
            res = execute_lsm(plan, buf, self._concat_ranges(grp),
                              backend=sess.backend, eps_rel=rel,
                              interpret=sess.interpret, bq=sess.bq,
                              min_bucket=sess.min_bucket,
                              level_runner=self._lsm_runner(
                                  table, rel, size, plan))
            jax.block_until_ready(res.answer)
            self._scatter(grp, Answer(res.answer, res.approx, res.refined,
                                      bound=bound, staleness=staleness),
                          staleness)
            return
        compiled = self._executable(table, rel, size, plan, buf)
        fills = pad_fills(plan)
        dt = plan.dtype
        qs = tuple(
            _pad_bucket(jnp.asarray(c, dt), size,
                        jnp.asarray(fills[j], dt))
            for j, c in enumerate(self._concat_ranges(grp)))
        ans, approx, refined = compiled(plan, buf, *qs)
        jax.block_until_ready(ans)   # futures resolve device-ready
        self._scatter(grp, Answer(ans, approx, refined, bound=bound,
                                  staleness=staleness), staleness)

    @staticmethod
    def _concat_ranges(grp: List[_ReadRequest]) -> Tuple:
        if len(grp) == 1:
            return tuple(grp[0].ranges)
        return tuple(
            jnp.concatenate([jnp.asarray(r.ranges[j]) for r in grp])
            for j in range(len(grp[0].ranges)))

    @staticmethod
    def _slice_answer(a, off: int, m: int) -> "Answer":
        bound = a.bound
        if isinstance(bound, tuple):     # quantile (lo, hi) certificates
            bound = tuple(b[off:off + m] for b in bound)
        return Answer(a.value[off:off + m], a.approx[off:off + m],
                      a.refined[off:off + m], bound=bound,
                      staleness=a.staleness)

    @staticmethod
    def _scatter(grp: List[_ReadRequest], res, staleness: int = 0) -> None:
        if not isinstance(res, Answer):  # degenerate paths (QueryResult)
            res = Answer(res.answer, res.approx, res.refined,
                         staleness=staleness)
        off = 0
        for r in grp:
            m = r.n
            # per-answer degradation signal: how many acknowledged update
            # records were not yet applied when this answer was computed
            r.future.staleness = staleness
            if not r.future.done():
                r.future.set_result(
                    ServingEngine._slice_answer(res, off, m))
            off += m

    # -- AOT executable cache ---------------------------------------------

    def _executable(self, table: str, rel, size: int, plan, buf,
                    kind: str = "range"):
        # quantile executables live under their own 4-tuple keys so the
        # range ladder and the inversion ladder never collide (LSM level
        # entries are 4-tuples too, distinguished by an int slot)
        key = ((table, rel, size) if kind == "range"
               else (table, rel, size, "quantile"))
        sig = _tree_sig(buf)
        entry = self._cache.get(key)
        if entry is not None and entry.matches(plan, sig):
            with self._stats_lock:
                self._stats.aot_hits += 1
            return entry.compiled
        with self._compile_lock:
            entry = self._cache.get(key)
            if entry is not None:
                if entry.matches(plan, sig):
                    with self._stats_lock:
                        self._stats.aot_hits += 1
                    return entry.compiled
                if entry.promote(plan, sig):
                    with self._stats_lock:
                        self._stats.aot_promotions += 1
                    return entry.compiled
                with self._stats_lock:
                    self._stats.aot_invalidations += 1
            sess = self.session
            fn = sess.serving_executor(table, rel, bq=min(sess.bq, size),
                                       kind=kind)
            k = sess.spec(table).n_ranges if kind == "range" else 1
            qs = [jax.ShapeDtypeStruct((size,), plan.dtype)] * k
            compiled = jax.jit(fn).lower(plan, buf, *qs).compile()
            self._cache[key] = _ExecEntry(plan, compiled, sig=sig,
                                          buf_tmpl=_tree_tmpl(buf))
            with self._stats_lock:
                self._stats.aot_compiles += 1
            return compiled

    # -- LSM tables: per-level executables ---------------------------------

    def _lsm_statics(self, rel, size: int, lsm) -> dict:
        """The statics ``execute_lsm`` resolves for this dispatch — the
        per-level executable must be lowered with exactly these so the
        cached call computes the same floats as the default jitted core."""
        sess = self.session
        backend = sess.backend
        if lsm.agg in ("max", "min") \
                and backend in ("pallas", "pallas_scan", "ref") \
                and any(l.plan.deg > 3 for l in lsm.levels):
            backend = "xla"   # mirrors execute_lsm's extremal downgrade
        return dict(backend=backend, interpret=sess.interpret,
                    bq=min(sess.bq, size), with_truth=rel is not None)

    @staticmethod
    def _lower_level(lvl, agg: str, statics: dict, size: int, k: int):
        fn = level_executor(agg, **statics)
        qs = [jax.ShapeDtypeStruct((size,), lvl.plan.dtype)] * k
        return jax.jit(fn).lower(lvl, *qs).compile()

    def _level_executable(self, table: str, rel, size: int, lvl, agg: str,
                          statics: dict, k: int):
        key = (table, rel, size, lvl.slot)
        sig = _tree_sig(lvl)
        entry = self._cache.get(key)
        if entry is not None and entry.matches(lvl.plan, sig):
            with self._stats_lock:
                self._stats.aot_hits += 1
            return entry.compiled
        with self._compile_lock:
            entry = self._cache.get(key)
            if entry is not None:
                if entry.matches(lvl.plan, sig):
                    with self._stats_lock:
                        self._stats.aot_hits += 1
                    return entry.compiled
                if entry.promote(lvl.plan, sig):
                    with self._stats_lock:
                        self._stats.aot_promotions += 1
                    return entry.compiled
                with self._stats_lock:
                    self._stats.aot_invalidations += 1
            compiled = self._lower_level(lvl, agg, statics, size, k)
            self._cache[key] = _ExecEntry(lvl.plan, compiled, sig=sig)
            with self._stats_lock:
                self._stats.aot_compiles += 1
            return compiled

    def _lsm_runner(self, table: str, rel, size: int, lsm):
        """A ``level_runner`` for ``execute_lsm`` that serves each level
        from the AOT cache (keyed by slot, validated by level identity)."""
        statics = self._lsm_statics(rel, size, lsm)
        k = self.session.spec(table).n_ranges
        agg = lsm.agg

        def runner(i, lvl, *qs):
            return self._level_executable(table, rel, size, lvl, agg,
                                          statics, k)(lvl, *qs)
        return runner

    # -- plan-swap pre-compilation (merge-thread listener) -----------------

    def _register_swap_listeners(self) -> None:
        """Hook ``session.on_plan_swap`` for every dynamic, unsharded
        table: the merge/compaction thread hands the incoming plan (or
        preview ladder) to ``_precompile`` *before* the atomic install,
        so post-swap dispatches promote staged executables instead of
        relowering."""
        sess = self.session
        hook = getattr(sess, "on_plan_swap", None)
        if hook is None:
            return
        for table in sess.tables:
            if sess.spec(table).dynamic and not sess.is_sharded(table):
                hook(table, self._precompile_listener(table))

    def _precompile_listener(self, table: str):
        def listener(incoming) -> None:
            if self._shut_down:
                return   # a dead engine's cache needs no staged successors
            try:
                self._precompile(table, incoming)
            except Exception:
                pass   # fall back to lazy recompile; never abort an install
        return listener

    def _precompile(self, table: str, incoming) -> None:
        sess = self.session
        with self._compile_lock:
            combos = sorted({(key[1], key[2]) for key in self._cache
                             if key[0] == table and len(key) == 3},
                            key=lambda c: (repr(c[0]), c[1]))
            lsm_combos = sorted({(key[1], key[2]) for key in self._cache
                                 if key[0] == table and len(key) == 4
                                 and key[3] != "quantile"},
                                key=lambda c: (repr(c[0]), c[1]))
            q_sizes = sorted({key[2] for key in self._cache
                              if key[0] == table and len(key) == 4
                              and key[3] == "quantile"})
        k = sess.spec(table).n_ranges
        if hasattr(incoming, "levels"):
            for rel, size in lsm_combos:
                statics = self._lsm_statics(rel, size, incoming)
                for lvl in incoming.levels:
                    key = (table, rel, size, lvl.slot)
                    sig = _tree_sig(lvl)
                    with self._compile_lock:
                        entry = self._cache.get(key)
                        if entry is not None and (
                                entry.matches(lvl.plan, sig)
                                or (entry.next_ref is lvl.plan
                                    and entry.next_sig == sig)):
                            continue   # surviving level: still valid
                    compiled = self._lower_level(lvl, incoming.agg,
                                                 statics, size, k)
                    with self._compile_lock:
                        entry = self._cache.get(key)
                        if entry is None:
                            entry = self._cache[key] = _ExecEntry(None, None)
                        entry.stage(lvl.plan, compiled, sig)
                    with self._stats_lock:
                        self._stats.aot_precompiles += 1
            return
        for rel, size in combos:
            key = (table, rel, size)
            with self._compile_lock:
                entry = self._cache.get(key)
                if entry is None or entry.buf_tmpl is None \
                        or entry.plan_ref is incoming \
                        or entry.next_ref is incoming:
                    continue
                tmpl = entry.buf_tmpl
            fn = sess.serving_executor(table, rel, bq=min(sess.bq, size))
            qs = [jax.ShapeDtypeStruct((size,), incoming.dtype)] * k
            compiled = jax.jit(fn).lower(incoming, tmpl, *qs).compile()
            with self._compile_lock:
                entry = self._cache.get(key)
                if entry is not None:
                    entry.stage(incoming, compiled, _tree_sig(tmpl))
            with self._stats_lock:
                self._stats.aot_precompiles += 1
        for size in q_sizes:
            key = (table, None, size, "quantile")
            with self._compile_lock:
                entry = self._cache.get(key)
                if entry is None or entry.buf_tmpl is None \
                        or entry.plan_ref is incoming \
                        or entry.next_ref is incoming:
                    continue
                tmpl = entry.buf_tmpl
            fn = sess.serving_executor(table, None, bq=min(sess.bq, size),
                                       kind="quantile")
            q = jax.ShapeDtypeStruct((size,), incoming.dtype)
            compiled = jax.jit(fn).lower(incoming, tmpl, q).compile()
            with self._compile_lock:
                entry = self._cache.get(key)
                if entry is not None:
                    entry.stage(incoming, compiled, _tree_sig(tmpl))
            with self._stats_lock:
                self._stats.aot_precompiles += 1

    def warmup(self, max_bucket: int = 1024,
               tables: Optional[Sequence[str]] = None,
               kinds: Sequence[str] = ("range",)) -> int:
        """Eagerly AOT-compile the full power-of-two bucket ladder
        (``min_bucket`` .. ``max_bucket``) for every (table, default
        guarantee); returns the number of executables compiled.  After
        this, any admitted batch up to ``max_bucket`` queries serves
        without tracing or compiling.  ``kinds`` picks the executor
        ladders: ``'range'`` (the aggregate family) and/or ``'quantile'``
        (CF inversion; skipped on tables that cannot answer quantiles).
        Windowed tables warm lazily — their per-epoch levels compile on
        first touch and sealed epochs never invalidate."""
        sess = self.session
        before = self.stats.aot_compiles
        for table in (tables if tables is not None else sess.tables):
            if sess.is_sharded(table) or sess.is_window(table):
                continue
            spec = sess.spec(table)
            rel = sess.resolve_rel(table)
            plan, buf = sess.snapshot(table)
            size = sess.min_bucket
            while size <= max_bucket:
                if hasattr(plan, "levels"):
                    if "range" in kinds:
                        statics = self._lsm_statics(rel, size, plan)
                        k = spec.n_ranges
                        for lvl in plan.levels:
                            self._level_executable(table, rel, size, lvl,
                                                   plan.agg, statics, k)
                else:
                    if "range" in kinds:
                        self._executable(table, rel, size, plan, buf)
                    if "quantile" in kinds \
                            and spec.agg in ("sum", "count") \
                            and not spec.lsm:
                        self._executable(table, None, size, plan, buf,
                                         kind="quantile")
                size *= 2
        return self.stats.aot_compiles - before

    # -- writes: journal + background drain -------------------------------

    def insert(self, table: str, *args, wait: bool = False) -> None:
        """Stage new records; ``wait=True`` blocks until they are
        query-visible (folded into the table's delta buffer)."""
        self._stage(table, "insert", args, wait)

    def delete(self, table: str, *args, wait: bool = True) -> None:
        """Stage delete tombstones.  Default ``wait=True`` so a bad key
        (``KeyError``: no live occurrence) surfaces to the caller;
        ``wait=False`` defers the error to the next ``flush``."""
        self._stage(table, "delete", args, wait)

    def _stage(self, table: str, kind: str, args: Tuple, wait: bool) -> None:
        if self._shut_down:
            raise RuntimeError("serving engine shut down")
        cols = self._norm_update(table, kind, args)
        item = _WriteItem(table, kind, cols, len(cols[0]))
        with self._staging_cv:
            self._journal.append(item)
            self._staging_cv.notify()
        with self._stats_lock:
            self._stats.staged_records += item.n
        if wait:
            if self._updater is None:   # no updater running: apply inline
                self._drain_once()
            item.future.result()

    def _norm_update(self, table: str, kind: str, args: Tuple) -> Tuple:
        """Host-normalize update args so same-(table, op) runs concat
        columnwise: every column rank-1 float64 of equal length."""
        spec = self.session.spec(table)
        if not spec.dynamic:
            raise RuntimeError(f"table {table!r} is static; fit it with "
                               "TableSpec(dynamic=True) to take updates")
        want = (1 if spec.agg in ("sum", "count", "max", "min")
                else 2) if kind == "delete" else (
            1 if spec.agg == "count" else
            2 if spec.agg in ("sum", "max", "min", "count2d") else 3)
        arrs = [np.atleast_1d(np.asarray(a, np.float64)) for a in args]
        if spec.agg == "count" and kind == "insert" and len(arrs) == 2:
            arrs = arrs[:1]          # engine forces unit measures anyway
        if len(arrs) != want:
            raise ValueError(f"{kind} on {table!r} ({spec.agg}) takes "
                             f"{want} array argument(s), got {len(args)}")
        base = arrs[0].shape
        return tuple(np.broadcast_to(a, base).astype(np.float64, copy=True)
                     for a in arrs)

    def drain_updates(self) -> None:
        """Block until every staged update is applied, then surface the
        oldest deferred write error (one per call, submission order).
        After shutdown this only surfaces deferred errors."""
        self._drain_updates(raise_errors=True)

    def _drain_updates(self, *, raise_errors: bool) -> None:
        if self._shut_down:
            if raise_errors:
                self._raise_update_error()
            return
        barrier = _WriteItem(None, "barrier", (), 0)
        with self._staging_cv:
            self._journal.append(barrier)
            self._staging_cv.notify()
        if self._updater is None or (not self._updater.is_alive()
                                     and self._supervisor is None):
            self._drain_once()
        barrier.future.result()
        if raise_errors:
            self._raise_update_error()

    def flush(self, table: Optional[str] = None) -> None:
        """Drain staging, then merge the tables' delta buffers into fresh
        plans (the AOT cache invalidates itself on the swap)."""
        self.drain_updates()
        self.session.flush(table)

    def _raise_update_error(self) -> None:
        if self._update_errors:
            raise self._update_errors.pop(0)

    def _updater_run(self, replaying: bool) -> None:
        if replaying:
            with self._staging_cv:
                n = len([it for it in self._journal.pending()
                         if it.kind != "barrier"])
            if n:
                with self._stats_lock:
                    self._stats.journal_replayed += n
        try:
            self._updater_loop()
        except BaseException:
            # un-applied suffix stays in the journal; the supervisor's
            # replacement updater replays exactly that
            with self._stats_lock:
                self._stats.updater_crashes += 1
        finally:
            self.monitor.forget("updater")

    def _updater_loop(self) -> None:
        while True:
            self.monitor.beat("updater")
            with self._staging_cv:
                while not self._journal.pending() and not self._stop.is_set():
                    self._staging_cv.wait(timeout=0.1)
            if not self._drain_once() and self._stop.is_set():
                return

    def _drain_once(self) -> bool:
        """Apply the journal's current un-applied suffix; True if any.

        Serialized by ``_drain_lock`` (an inline drain must not race a
        restarting updater into double-applying).  Items are applied in
        sequence order and marked applied chunk by chunk, so an injected
        crash between fused applies leaves exactly the un-applied suffix
        for replay.
        """
        with self._drain_lock:
            with self._staging_cv:
                items = self._journal.pending()
            if not items:
                return False
            # coalesce consecutive same-(table, op) runs; per-table order
            # is global order restricted to the table, so victim
            # resolution and read-your-writes see writes in submission
            # order
            runs: List[List[_WriteItem]] = []
            for it in items:
                if (runs and it.kind != "barrier"
                        and runs[-1][0].kind == it.kind
                        and runs[-1][0].table == it.table):
                    runs[-1].append(it)
                else:
                    runs.append([it])
            applies = 0
            for run in runs:
                head = run[0]
                if head.kind == "barrier":
                    with self._staging_cv:
                        self._journal.mark_applied(head.seq)
                    head.future.set_result(None)
                    continue
                try:
                    applies += self._apply_run(head.table, head.kind, run)
                except self._crash_exc:
                    # injected crash: leave the un-applied suffix in the
                    # journal and die through _updater_run
                    with self._stats_lock:
                        self._stats.drains += 1
                        self._stats.fused_applies += applies
                    raise
                except BaseException as e:
                    # permanent engine error: consume the run, defer the
                    # error (submission order) and fail its futures
                    self._update_errors.append(e)
                    with self._staging_cv:
                        for it in run:
                            self._journal.mark_applied(it.seq)
                    for it in run:
                        if not it.future.done():
                            it.future.set_exception(e)
                    continue
            with self._stats_lock:
                self._stats.drains += 1
                self._stats.fused_applies += applies
            return True

    def _apply_run(self, table: str, kind: str,
                   run: List[_WriteItem]) -> int:
        """Apply one same-(table, op) run in capacity-sized, item-aligned
        chunks; each item is marked applied (and its future resolved)
        only after the fused call covering it lands."""
        cap = self.session.spec(table).capacity
        op = self.session.insert if kind == "insert" else self.session.delete
        applies = 0
        pack: List[_WriteItem] = []
        pack_n = 0

        def flush_pack() -> int:
            nonlocal pack, pack_n
            if not pack:
                return 0
            # chaos site: a crash here is *between* fused applies — the
            # journal watermark sits exactly at the last applied item
            self._maybe_fail("serve.updater")
            cols = (pack[0].args if len(pack) == 1 else
                    tuple(np.concatenate([it.args[j] for it in pack])
                          for j in range(len(pack[0].args))))
            n = len(cols[0])
            calls = 0
            for lo in range(0, n, cap):
                op(table, *(c[lo:lo + cap] for c in cols))
                calls += 1
            with self._staging_cv:
                for it in pack:
                    self._journal.mark_applied(it.seq)
            for it in pack:
                if not it.future.done():
                    it.future.set_result(None)
            pack, pack_n = [], 0
            return calls

        for it in run:
            if pack and pack_n + it.n > cap:
                applies += flush_pack()
            pack.append(it)
            pack_n += it.n
        applies += flush_pack()
        return applies

    # -- introspection ----------------------------------------------------

    @property
    def stats(self) -> EngineStats:
        with self._stats_lock:
            return dataclasses.replace(self._stats)

    @property
    def queue_depth(self) -> int:
        return self._queue.qsize()

    @property
    def staged_depth(self) -> int:
        with self._staging_cv:
            return self._journal.depth()

    def staleness(self, table: str) -> int:
        """Acknowledged-but-unapplied update records for ``table`` —
        the per-answer degradation signal while the updater is down."""
        with self._staging_cv:
            return self._journal.depth(table)

    def cache_keys(self) -> Tuple[Tuple, ...]:
        return tuple(sorted(self._cache, key=repr))
