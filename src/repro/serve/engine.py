"""Continuous-batching serving engine over a ``PolyFit`` session
(DESIGN.md §13).

``ServingEngine`` turns the synchronous session facade into a traffic
engine with three moving parts:

* **Bounded request queue + admission batching.**  ``submit`` enqueues a
  read and returns a future; background worker threads drain the queue,
  coalesce whatever is waiting (up to ``max_batch`` queries) into groups
  keyed on (table, guarantee), pad each group to its power-of-two bucket,
  and answer every caller's future from one device dispatch.  The
  executors are elementwise per query, so coalesced answers are
  bit-identical to serial execution of the same requests.  Admission is
  ``'block'`` (default: ``submit`` waits for room) or ``'reject'``
  (``QueueFull`` when the queue is at capacity — load shedding).

* **AOT executable cache.**  Each (table, guarantee, bucket) is served by
  a ``jax.jit(fn).lower(plan, buf, *qs).compile()`` executable, so the
  steady state never re-traces: admission batching maps every batch shape
  onto the cached bucket ladder.  Compiled objects pin the plan's static
  metadata (``delta``/``h``/``n`` change on every merge), so entries are
  keyed by plan identity and recompiled on plan swap — the plan-swap
  protocol is simply "readers snapshot, the cache invalidates on
  mismatch".  ``warmup`` eagerly compiles the full bucket ladder per
  table instead of a single shape.

* **Async insert pipeline.**  ``insert``/``delete`` append to a host-side
  staging log and return immediately (``wait=False``); a background
  updater thread drains the log, coalescing consecutive same-(table, op)
  runs into few engine calls — one fused jitted append per
  capacity-sized chunk, not one dispatch per caller — and the dynamic
  engines' background merges install fresh plans atomically, so readers
  are never blocked by writers.  Per-table submission order is preserved
  (delete victim resolution and read-your-writes depend on it);
  ``wait=True`` blocks until the caller's records are query-visible.

Sharded tables (``TableSpec(shards=N)``) fall back to the session's
shard_map executors, which carry their own cache; everything else goes
through the AOT path.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from concurrent.futures import Future
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..api.spec import DEFAULT_REL, QueryBatch, QuerySpec
from ..core.queries import QueryResult
from ..engine import pad_fills
from ..engine.engine import _bucket_size, _pad_bucket

__all__ = ["ServingEngine", "QueueFull", "EngineStats"]


class QueueFull(RuntimeError):
    """``admission='reject'`` and the bounded request queue is at capacity."""


@dataclasses.dataclass
class EngineStats:
    """Monotonic counters; read a consistent copy via ``engine.stats``."""

    submitted: int = 0        # read requests accepted into the queue
    rejected: int = 0         # read requests shed by admission='reject'
    answered: int = 0         # read requests resolved (ok or error)
    dispatches: int = 0       # device dispatches serving reads
    coalesced: int = 0        # requests that shared a dispatch with others
    aot_compiles: int = 0     # executables lowered+compiled
    aot_hits: int = 0         # dispatches served from the cache
    aot_invalidations: int = 0  # cache entries dropped on plan swap
    staged_records: int = 0   # update records accepted into staging
    drains: int = 0           # updater wake-ups that applied work
    fused_applies: int = 0    # engine insert/delete calls made by drains


class _ReadRequest:
    __slots__ = ("table", "rel", "ranges", "n", "future")

    def __init__(self, table: str, rel, ranges: Tuple, n: int):
        self.table = table
        self.rel = rel
        self.ranges = ranges
        self.n = n
        self.future: Future = Future()


class _WriteItem:
    __slots__ = ("table", "kind", "args", "n", "future")

    def __init__(self, table: Optional[str], kind: str, args: Tuple,
                 n: int):
        self.table = table
        self.kind = kind            # 'insert' | 'delete' | 'barrier'
        self.args = args
        self.n = n
        self.future: Future = Future()


class _ExecEntry:
    __slots__ = ("plan_ref", "compiled")

    def __init__(self, plan_ref, compiled):
        self.plan_ref = plan_ref    # identity-keyed: meta changes per swap
        self.compiled = compiled


class ServingEngine:
    """Queue -> admission batcher -> AOT executable cache over one session.

    ``max_queue`` bounds the read queue (backpressure), ``max_batch`` caps
    the queries coalesced into one dispatch, ``workers`` is the number of
    drain threads (1 keeps dispatch order deterministic).  ``start=False``
    builds the engine without threads — ``submit`` still queues, nothing
    drains — which makes backpressure deterministic to test; call
    ``start()`` to begin serving.
    """

    def __init__(self, session, *, max_queue: int = 1024,
                 max_batch: int = 4096, workers: int = 1,
                 admission: str = "block", start: bool = True):
        if admission not in ("block", "reject"):
            raise ValueError(f"admission must be 'block' or 'reject', "
                             f"got {admission!r}")
        if workers < 1:
            raise ValueError("workers must be >= 1")
        self.session = session
        self.max_batch = int(max_batch)
        self.admission = admission
        self._queue: "queue.Queue[_ReadRequest]" = queue.Queue(max_queue)
        self._cache: Dict[Tuple, _ExecEntry] = {}
        self._compile_lock = threading.Lock()
        self._staging: List[_WriteItem] = []
        self._staging_cv = threading.Condition()
        self._stats = EngineStats()
        self._stats_lock = threading.Lock()
        self._update_error: Optional[BaseException] = None
        self._stop = threading.Event()
        self._shut_down = False
        self._n_workers = int(workers)
        self._threads: List[threading.Thread] = []
        if start:
            self.start()

    # -- lifecycle --------------------------------------------------------

    def start(self) -> None:
        """Spawn the worker + updater threads (idempotent)."""
        if self._shut_down:
            raise RuntimeError("engine was shut down")
        if self._threads:
            return
        for i in range(self._n_workers):
            t = threading.Thread(target=self._worker_loop, daemon=True,
                                 name=f"polyfit-serve-{i}")
            t.start()
            self._threads.append(t)
        t = threading.Thread(target=self._updater_loop, daemon=True,
                             name="polyfit-update")
        t.start()
        self._threads.append(t)

    @property
    def running(self) -> bool:
        return bool(self._threads) and not self._shut_down

    def shutdown(self, drain: bool = True, timeout: Optional[float] = None
                 ) -> None:
        """Stop the engine.  ``drain=True`` answers everything already
        queued (reads) and applies everything staged (writes) first;
        ``drain=False`` cancels queued reads with a ``RuntimeError`` and
        drops staged writes.  Idempotent."""
        if self._shut_down:
            return
        if drain and self._threads:
            self._queue.join()
            self.drain_updates()
        self._shut_down = True
        self._stop.set()
        with self._staging_cv:
            self._staging_cv.notify_all()
        if not drain:
            self._cancel_queued("serving engine shut down")
        for t in self._threads:
            t.join(timeout)
        self._threads = []
        if not drain:
            # workers may have exited between queue drains; sweep again
            self._cancel_queued("serving engine shut down")

    def _cancel_queued(self, msg: str) -> None:
        while True:
            try:
                req = self._queue.get_nowait()
            except queue.Empty:
                return
            if not req.future.done():
                req.future.set_exception(RuntimeError(msg))
            self._queue.task_done()

    # -- reads ------------------------------------------------------------

    def submit(self, spec: QuerySpec, *, timeout: Optional[float] = None
               ) -> Future:
        """Enqueue one read; the future resolves to its ``QueryResult``.

        ``admission='block'`` waits up to ``timeout`` for queue room (then
        raises ``QueueFull``); ``'reject'`` raises immediately when full.
        """
        if self._shut_down:
            raise RuntimeError("serving engine shut down")
        rel = self.session.resolve_rel(spec.table, spec.rel)
        req = _ReadRequest(spec.table, rel, spec.ranges, len(spec))
        try:
            if self.admission == "reject":
                self._queue.put_nowait(req)
            else:
                self._queue.put(req, timeout=timeout)
        except queue.Full:
            with self._stats_lock:
                self._stats.rejected += 1
            raise QueueFull(f"request queue at capacity "
                            f"({self._queue.maxsize})") from None
        with self._stats_lock:
            self._stats.submitted += 1
        return req.future

    def query(self, request: Union[QuerySpec, QueryBatch,
                                   Sequence[QuerySpec]],
              *, timeout: Optional[float] = None):
        """Blocking convenience mirroring ``session.query``: one spec
        returns its ``QueryResult``, a batch returns the aligned list."""
        if isinstance(request, QuerySpec):
            return self.submit(request).result(timeout)
        specs = list(request.specs if isinstance(request, QueryBatch)
                     else request)
        futures = [self.submit(s) for s in specs]
        return [f.result(timeout) for f in futures]

    def serve(self, table: str, *ranges, rel=DEFAULT_REL,
              timeout: Optional[float] = None) -> QueryResult:
        """Blocking single-request endpoint: ``serve('count', lq, uq)``."""
        res = self.submit(QuerySpec(table, ranges, rel)).result(timeout)
        jax.block_until_ready(res.answer)
        return res

    # -- worker: drain, coalesce, dispatch --------------------------------

    def _worker_loop(self) -> None:
        q = self._queue
        while True:
            try:
                req = q.get(timeout=0.05)
            except queue.Empty:
                if self._stop.is_set():
                    return
                continue
            batch = [req]
            budget = self.max_batch - req.n
            while budget > 0:
                # peek so the admission batch never overshoots max_batch —
                # overshoot would hit a bucket above the warmed ladder
                with q.mutex:
                    if not q.queue or q.queue[0].n > budget:
                        break
                try:
                    nxt = q.get_nowait()
                except queue.Empty:
                    break
                batch.append(nxt)
                budget -= nxt.n
            groups: Dict[Tuple, List[_ReadRequest]] = {}
            for r in batch:
                groups.setdefault((r.table, r.rel), []).append(r)
            for (table, rel), grp in groups.items():
                # count before resolving: a caller that saw its future
                # complete must also see it reflected in ``stats``
                with self._stats_lock:
                    self._stats.dispatches += 1
                    self._stats.answered += len(grp)
                    if len(grp) > 1:
                        self._stats.coalesced += len(grp)
                try:
                    self._dispatch(table, rel, grp)
                except BaseException as e:   # surface on the callers
                    for r in grp:
                        if not r.future.done():
                            r.future.set_exception(e)
            for _ in batch:
                q.task_done()

    def _dispatch(self, table: str, rel, grp: List[_ReadRequest]) -> None:
        sess = self.session
        if sess.is_sharded(table):
            # shard_map executors keep their own cache; no AOT ladder here
            ranges = self._concat_ranges(grp)
            res = sess.query(QuerySpec(table, ranges, rel))
            jax.block_until_ready(res.answer)
            self._scatter(grp, res)
            return
        plan, buf = sess.snapshot(table)
        nq = sum(r.n for r in grp)
        size = _bucket_size(nq, sess.min_bucket)
        compiled = self._executable(table, rel, size, plan, buf)
        fills = pad_fills(plan)
        dt = plan.dtype
        qs = tuple(
            _pad_bucket(jnp.asarray(c, dt), size,
                        jnp.asarray(fills[j], dt))
            for j, c in enumerate(self._concat_ranges(grp)))
        ans, approx, refined = compiled(plan, buf, *qs)
        jax.block_until_ready(ans)   # futures resolve device-ready
        self._scatter(grp, QueryResult(ans, approx, refined))

    @staticmethod
    def _concat_ranges(grp: List[_ReadRequest]) -> Tuple:
        if len(grp) == 1:
            return tuple(grp[0].ranges)
        return tuple(
            jnp.concatenate([jnp.asarray(r.ranges[j]) for r in grp])
            for j in range(len(grp[0].ranges)))

    @staticmethod
    def _scatter(grp: List[_ReadRequest], res: QueryResult) -> None:
        off = 0
        for r in grp:
            m = r.n
            r.future.set_result(QueryResult(res.answer[off:off + m],
                                            res.approx[off:off + m],
                                            res.refined[off:off + m]))
            off += m

    # -- AOT executable cache ---------------------------------------------

    def _executable(self, table: str, rel, size: int, plan, buf):
        key = (table, rel, size)
        entry = self._cache.get(key)
        if entry is not None and entry.plan_ref is plan:
            with self._stats_lock:
                self._stats.aot_hits += 1
            return entry.compiled
        with self._compile_lock:
            entry = self._cache.get(key)
            if entry is not None and entry.plan_ref is plan:
                with self._stats_lock:
                    self._stats.aot_hits += 1
                return entry.compiled
            if entry is not None:
                with self._stats_lock:
                    self._stats.aot_invalidations += 1
            sess = self.session
            fn = sess.serving_executor(table, rel, bq=min(sess.bq, size))
            k = sess.spec(table).n_ranges
            qs = [jax.ShapeDtypeStruct((size,), plan.dtype)] * k
            compiled = jax.jit(fn).lower(plan, buf, *qs).compile()
            self._cache[key] = _ExecEntry(plan, compiled)
            with self._stats_lock:
                self._stats.aot_compiles += 1
            return compiled

    def warmup(self, max_bucket: int = 1024,
               tables: Optional[Sequence[str]] = None) -> int:
        """Eagerly AOT-compile the full power-of-two bucket ladder
        (``min_bucket`` .. ``max_bucket``) for every (table, default
        guarantee); returns the number of executables compiled.  After
        this, any admitted batch up to ``max_bucket`` queries serves
        without tracing or compiling."""
        sess = self.session
        before = self.stats.aot_compiles
        for table in (tables if tables is not None else sess.tables):
            if sess.is_sharded(table):
                continue
            rel = sess.resolve_rel(table)
            plan, buf = sess.snapshot(table)
            size = sess.min_bucket
            while size <= max_bucket:
                self._executable(table, rel, size, plan, buf)
                size *= 2
        return self.stats.aot_compiles - before

    # -- writes: staging + background drain -------------------------------

    def insert(self, table: str, *args, wait: bool = False) -> None:
        """Stage new records; ``wait=True`` blocks until they are
        query-visible (folded into the table's delta buffer)."""
        self._stage(table, "insert", args, wait)

    def delete(self, table: str, *args, wait: bool = True) -> None:
        """Stage delete tombstones.  Default ``wait=True`` so a bad key
        (``KeyError``: no live occurrence) surfaces to the caller;
        ``wait=False`` defers the error to the next ``flush``."""
        self._stage(table, "delete", args, wait)

    def _stage(self, table: str, kind: str, args: Tuple, wait: bool) -> None:
        if self._shut_down:
            raise RuntimeError("serving engine shut down")
        cols = self._norm_update(table, kind, args)
        item = _WriteItem(table, kind, cols, len(cols[0]))
        with self._staging_cv:
            self._staging.append(item)
            self._staging_cv.notify()
        with self._stats_lock:
            self._stats.staged_records += item.n
        if wait:
            if not self._threads:   # no updater running: apply inline
                self._drain_once()
            item.future.result()

    def _norm_update(self, table: str, kind: str, args: Tuple) -> Tuple:
        """Host-normalize update args so same-(table, op) runs concat
        columnwise: every column rank-1 float64 of equal length."""
        spec = self.session.spec(table)
        if not spec.dynamic:
            raise RuntimeError(f"table {table!r} is static; fit it with "
                               "TableSpec(dynamic=True) to take updates")
        want = (1 if spec.agg in ("sum", "count", "max", "min")
                else 2) if kind == "delete" else (
            1 if spec.agg == "count" else
            2 if spec.agg in ("sum", "max", "min", "count2d") else 3)
        arrs = [np.atleast_1d(np.asarray(a, np.float64)) for a in args]
        if spec.agg == "count" and kind == "insert" and len(arrs) == 2:
            arrs = arrs[:1]          # engine forces unit measures anyway
        if len(arrs) != want:
            raise ValueError(f"{kind} on {table!r} ({spec.agg}) takes "
                             f"{want} array argument(s), got {len(args)}")
        base = arrs[0].shape
        return tuple(np.broadcast_to(a, base).astype(np.float64, copy=True)
                     for a in arrs)

    def drain_updates(self) -> None:
        """Block until every staged update is applied, then surface any
        deferred write error."""
        barrier = _WriteItem(None, "barrier", (), 0)
        with self._staging_cv:
            self._staging.append(barrier)
            self._staging_cv.notify()
        if not self._threads:
            self._drain_once()
        barrier.future.result()
        self._raise_update_error()

    def flush(self, table: Optional[str] = None) -> None:
        """Drain staging, then merge the tables' delta buffers into fresh
        plans (the AOT cache invalidates itself on the swap)."""
        self.drain_updates()
        self.session.flush(table)

    def _raise_update_error(self) -> None:
        if self._update_error is not None:
            err, self._update_error = self._update_error, None
            raise err

    def _updater_loop(self) -> None:
        while True:
            with self._staging_cv:
                while not self._staging and not self._stop.is_set():
                    self._staging_cv.wait(timeout=0.1)
            if not self._drain_once() and self._stop.is_set():
                return

    def _drain_once(self) -> bool:
        """Apply one swapped-out chunk of the staging log; True if any."""
        with self._staging_cv:
            items, self._staging = self._staging, []
        if not items:
            return False
        # coalesce consecutive same-(table, op) runs; per-table order is
        # global order restricted to the table, so victim resolution and
        # read-your-writes see writes in submission order
        runs: List[List[_WriteItem]] = []
        for it in items:
            if (runs and it.kind != "barrier"
                    and runs[-1][0].kind == it.kind
                    and runs[-1][0].table == it.table):
                runs[-1].append(it)
            else:
                runs.append([it])
        applies = 0
        for run in runs:
            head = run[0]
            if head.kind == "barrier":
                head.future.set_result(None)
                continue
            try:
                applies += self._apply_run(head.table, head.kind, run)
            except BaseException as e:
                self._update_error = e
                for it in run:
                    if not it.future.done():
                        it.future.set_exception(e)
                continue
            for it in run:
                it.future.set_result(None)
        with self._stats_lock:
            self._stats.drains += 1
            self._stats.fused_applies += applies
        return True

    def _apply_run(self, table: str, kind: str,
                   run: List[_WriteItem]) -> int:
        cols = (run[0].args if len(run) == 1 else
                tuple(np.concatenate([it.args[j] for it in run])
                      for j in range(len(run[0].args))))
        cap = self.session.spec(table).capacity
        op = self.session.insert if kind == "insert" else self.session.delete
        n = len(cols[0])
        applies = 0
        for lo in range(0, n, cap):
            op(table, *(c[lo:lo + cap] for c in cols))
            applies += 1
        return applies

    # -- introspection ----------------------------------------------------

    @property
    def stats(self) -> EngineStats:
        with self._stats_lock:
            return dataclasses.replace(self._stats)

    @property
    def queue_depth(self) -> int:
        return self._queue.qsize()

    @property
    def staged_depth(self) -> int:
        with self._staging_cv:
            return sum(it.n for it in self._staging)

    def cache_keys(self) -> Tuple[Tuple, ...]:
        return tuple(sorted(self._cache, key=repr))
