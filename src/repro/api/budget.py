"""ErrorBudget — the one place the paper's delta derivations live.

PolyFit guarantees are stated per aggregate family against the *index build
parameter* delta (the per-segment minimax fitting tolerance), while callers
think in terms of the answer-level bounds eps_abs / eps_rel:

* SUM/COUNT  — Lemma 5.1: |A - R| <= 2*delta, so build with delta = eps_abs/2;
* MAX/MIN    — Lemma 5.3: |A - R| <= delta,   so build with delta = eps_abs;
* 2-key COUNT — Lemma 6.3: |A - R| <= 4*delta, so build with delta = eps_abs/4.

Before this module those divisions were hand-inlined at every build site
(``serve/aggregates.py``, ``examples/*.py``), with nothing keeping the
service's convention in sync with the engine's acceptance tests (Lemma
5.2/5.4/6.4 read ``plan.delta`` directly).  ``ErrorBudget`` owns the
conversion in both directions and travels with a ``TableSpec`` through the
``repro.api.PolyFit`` facade, so a request-level guarantee is one declarative
object instead of scattered ``delta``/``eps_rel`` kwargs — the composable
error accounting arXiv:2503.05007 / arXiv:2506.20139 argue for.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

__all__ = ["ErrorBudget", "DELTA_FRACTION"]

# delta = DELTA_FRACTION[agg] * eps_abs  (Lemmas 5.1 / 5.3 / 6.3; the 2-D
# measure aggregates follow the same shapes — 4-corner SUM inherits the
# Lemma 6.3 factor, dominance MAX/MIN the Lemma 5.3 one, DESIGN.md §12)
DELTA_FRACTION = {"sum": 0.5, "count": 0.5, "max": 1.0, "min": 1.0,
                  "count2d": 0.25, "sum2d": 0.25, "max2d": 1.0,
                  "min2d": 1.0,
                  # quantile inversion widens the target rank by +-delta
                  # (plus data-dependent rank slack), so the rank-domain
                  # budget passes through 1:1 — DESIGN.md §16.  Not a
                  # TableSpec aggregate: quantiles read SUM/COUNT tables.
                  "quantile": 1.0}

# answer-level bound as a multiple of delta (the inverse direction: what a
# plan built with delta certifies — Lemmas 5.1 / 5.3 / 6.3 again)
BOUND_FACTOR = {"sum": 2.0, "count": 2.0, "max": 1.0, "min": 1.0,
                "count2d": 4.0, "sum2d": 4.0, "max2d": 1.0, "min2d": 1.0,
                "quantile": 1.0}


@dataclasses.dataclass(frozen=True)
class ErrorBudget:
    """Declarative per-table error budget: ``ErrorBudget(abs=100, rel=0.01)``.

    ``abs`` is the certified Q_abs bound the built index must satisfy on its
    raw answers (required — it fixes the build delta).  ``rel`` is the
    optional default Q_rel target: queries failing the Lemma 5.2/5.4/6.4
    acceptance test against it are refined exactly in-path.  ``rel=None``
    means Q_abs only (no refinement arrays consulted).
    """

    abs: float
    rel: Optional[float] = None

    def __post_init__(self):
        if not (self.abs > 0):
            raise ValueError(f"ErrorBudget.abs must be > 0, got {self.abs}")
        if self.rel is not None and not (self.rel > 0):
            raise ValueError(f"ErrorBudget.rel must be > 0 or None, "
                             f"got {self.rel}")

    @staticmethod
    def _check_agg(agg: str) -> None:
        if agg not in DELTA_FRACTION:
            raise ValueError(f"unknown aggregate {agg!r}; expected one of "
                             f"{sorted(DELTA_FRACTION)}")

    def delta(self, agg: str) -> float:
        """Index build tolerance for ``agg`` (Lemma 5.1 / 5.3 / 6.3)."""
        self._check_agg(agg)
        return DELTA_FRACTION[agg] * self.abs

    def bound(self, agg: str) -> float:
        """The certified |A - R| bound a plan built from this budget carries
        (equals ``abs`` by construction; exposed for assertions/tests)."""
        self._check_agg(agg)
        return BOUND_FACTOR[agg] * self.delta(agg)

    @classmethod
    def from_delta(cls, delta: float, agg: str,
                   rel: Optional[float] = None) -> "ErrorBudget":
        """Inverse constructor for callers holding a raw build delta."""
        cls._check_agg(agg)
        return cls(abs=delta / DELTA_FRACTION[agg], rel=rel)
