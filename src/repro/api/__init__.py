"""repro.api — the declarative PolyFit query API (DESIGN.md §11).

One import surface for everything a caller needs:

* ``ErrorBudget(abs=..., rel=...)`` — the composable error budget; the only
  place the Lemma 5.1/5.3/6.3 delta derivations live.
* ``TableSpec`` — fit-time description of a table (aggregate, budget,
  degree, dynamic buffering, sharding).
* ``QuerySpec`` / ``QueryBatch`` — declarative, kind-explicit request
  batches (registered pytrees): ``QuerySpec.range/rect/corner`` for the
  aggregate families, ``QuerySpec.quantile`` for certified CF inversion,
  ``QuerySpec.window`` for epoch-windowed aggregates.
* ``PolyFit`` — the session facade: ``PolyFit.fit(datasets, specs)`` builds
  the indexes, ``session.query(batch)`` answers mixed batches in request
  order through grouped fused executors as structured ``Answer``s
  (value + certified bound + staleness), ``session.insert/delete/flush``
  delegate to the delta-buffered dynamic engines and
  ``session.ingest/advance_epoch`` to windowed tables' epoch rings.

``repro.engine`` (Engine, DynamicEngine, plans, kernels) remains available
but is considered internal; new code should target this module.
"""
from .budget import ErrorBudget
from .session import Answer, PolyFit
from .spec import DEFAULT_REL, QueryBatch, QuerySpec, TableSpec

__all__ = ["Answer", "ErrorBudget", "PolyFit", "QueryBatch", "QuerySpec",
           "TableSpec", "DEFAULT_REL"]
