"""repro.api — the declarative PolyFit query API (DESIGN.md §11).

One import surface for everything a caller needs:

* ``ErrorBudget(abs=..., rel=...)`` — the composable error budget; the only
  place the Lemma 5.1/5.3/6.3 delta derivations live.
* ``TableSpec`` — fit-time description of a table (aggregate, budget,
  degree, dynamic buffering, sharding).
* ``QuerySpec`` / ``QueryBatch`` — declarative, mixed-aggregate request
  batches (registered pytrees).
* ``PolyFit`` — the session facade: ``PolyFit.fit(datasets, specs)`` builds
  the indexes, ``session.query(batch)`` answers mixed batches in request
  order through grouped fused executors, ``session.insert/delete/flush``
  delegate to the delta-buffered dynamic engines.

``repro.engine`` (Engine, DynamicEngine, plans, kernels) remains available
but is considered internal; new code should target this module.
"""
from .budget import ErrorBudget
from .session import PolyFit
from .spec import DEFAULT_REL, QueryBatch, QuerySpec, TableSpec

__all__ = ["ErrorBudget", "PolyFit", "QueryBatch", "QuerySpec", "TableSpec",
           "DEFAULT_REL"]
