"""Declarative request/fit descriptions for the ``PolyFit`` session facade.

``QuerySpec`` names a fitted table and carries the query ranges (scalars or
equal-length batches); ``QueryBatch`` is an ordered tuple of specs that may
mix aggregates and dimensions freely — the session groups them by
(plan, guarantee), dispatches each group through one fused executor, and
scatters answers back in request order.  Both are registered pytrees (range
arrays are data, the table name / guarantee are static metadata), so whole
batches can ride ``jax.tree`` utilities and jitted wrappers.

``TableSpec`` is the fit-time counterpart: aggregate family, ``ErrorBudget``
(the only source of build deltas — see ``budget.py``), degree, dynamic
buffering, and optional cross-device sharding.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .budget import DELTA_FRACTION, ErrorBudget

__all__ = ["QuerySpec", "QueryBatch", "TableSpec", "DEFAULT_REL", "KINDS",
           "KIND_OF_AGG"]

# sentinel: "use the table budget's rel" (None means "Q_abs only, no
# refinement", so a third state is needed for per-spec overrides)
DEFAULT_REL = ...

_NRANGES = {"sum": 2, "count": 2, "max": 2, "min": 2, "count2d": 4,
            "sum2d": 4, "max2d": 2, "min2d": 2}

# query kinds a spec can name explicitly; range-shaped kinds accept the
# same 2-or-4 ranges the legacy constructors did, 'quantile' takes the
# rank fractions alone, 'window' adds an inclusive [t0, t1] epoch interval
# as static params
KINDS = ("count", "sum", "max", "min", "quantile", "window")

# kind a legacy (kind=None) spec resolves to from its table's aggregate
KIND_OF_AGG = {"count": "count", "sum": "sum", "max": "max", "min": "min",
               "count2d": "count", "sum2d": "sum", "max2d": "max",
               "min2d": "min"}


def _norm_range(r):
    """Normalize one range coordinate to a rank-1 array.

    Device arrays (and tracers — both are ``jax.Array``) pass through
    untouched so the serving hot path never pays a device->host sync and
    specs stay constructible inside jit; everything else becomes a host
    float64 array."""
    if isinstance(r, jax.Array):
        return jnp.atleast_1d(r)
    return np.atleast_1d(np.asarray(r, np.float64))


@dataclasses.dataclass(frozen=True)
class QuerySpec:
    """One declarative request: ``QuerySpec("sales", (lo, hi))``.

    ``ranges`` is ``(lq, uq)`` for 1-D tables or ``(lx, ux, ly, uy)`` for
    2-key COUNT; entries may be python scalars or equal-length 1-D arrays
    (a whole sub-batch in one spec — the serving fast path).  ``rel``
    overrides the table's default Q_rel target for this spec only:
    ``DEFAULT_REL`` (the default) inherits the table budget, ``None`` forces
    Q_abs-only, a float is an explicit eps_rel.
    """

    table: str
    ranges: Tuple
    rel: object = DEFAULT_REL
    kind: Optional[str] = None
    params: Tuple = ()

    def __post_init__(self):
        if self.kind is not None and self.kind not in KINDS:
            raise ValueError(f"unknown query kind {self.kind!r}; expected "
                             f"one of {KINDS}")
        if self.kind == "quantile":
            if len(self.ranges) != 1:
                raise ValueError("quantile specs carry exactly the rank "
                                 f"fractions; got {len(self.ranges)} ranges")
        elif self.kind == "window":
            if len(self.ranges) != 2:
                raise ValueError("window specs carry (lq, uq); got "
                                 f"{len(self.ranges)} ranges")
            if len(self.params) != 2:
                raise ValueError("window specs need params=(t0, t1); got "
                                 f"{self.params!r}")
        elif len(self.ranges) not in (2, 4):
            raise ValueError("QuerySpec.ranges must have 2 entries (1-D) or "
                             f"4 (2-D); got {len(self.ranges)}")
        object.__setattr__(self, "ranges",
                           tuple(_norm_range(r) for r in self.ranges))
        object.__setattr__(self, "params",
                           tuple(int(p) for p in self.params))
        n = {r.shape[0] for r in self.ranges}
        if len(n) != 1:
            raise ValueError(f"QuerySpec.ranges lengths differ: {sorted(n)}")

    def __len__(self) -> int:
        return int(self.ranges[0].shape[0])

    @classmethod
    def range(cls, table: str, lq, uq, rel=DEFAULT_REL) -> "QuerySpec":
        """1-D range (SUM/COUNT over (lq, uq], MAX/MIN over [lq, uq])."""
        return cls(table, (lq, uq), rel)

    @classmethod
    def rect(cls, table: str, lx, ux, ly, uy, rel=DEFAULT_REL) -> "QuerySpec":
        """2-key COUNT/SUM over the rectangle (lx, ux] x (ly, uy]."""
        return cls(table, (lx, ux, ly, uy), rel)

    @classmethod
    def corner(cls, table: str, u, v, rel=DEFAULT_REL) -> "QuerySpec":
        """2-key dominance MAX/MIN over {x <= u, y <= v}."""
        return cls(table, (u, v), rel)

    @classmethod
    def quantile(cls, table: str, q, rel=None) -> "QuerySpec":
        """Certified q-quantile(s): the answer interval brackets the exact
        order statistic (SUM/COUNT tables only).  ``rel`` is accepted for
        symmetry but quantiles always answer with their certified key
        interval — there is no refinement path."""
        return cls(table, (q,), rel, kind="quantile")

    @classmethod
    def window(cls, table: str, lq, uq, t0, t1,
               rel=DEFAULT_REL) -> "QuerySpec":
        """Range aggregate restricted to epochs ``t0..t1`` inclusive of a
        windowed table (``TableSpec.window > 0``)."""
        return cls(table, (lq, uq), rel, kind="window",
                   params=(int(t0), int(t1)))


def _spec_flatten(s: QuerySpec):
    return tuple(s.ranges), (s.table, s.rel, len(s.ranges), s.kind, s.params)


def _spec_unflatten(meta, ranges):
    s = object.__new__(QuerySpec)
    object.__setattr__(s, "table", meta[0])
    object.__setattr__(s, "ranges", tuple(ranges))
    object.__setattr__(s, "rel", meta[1])
    object.__setattr__(s, "kind", meta[3])
    object.__setattr__(s, "params", meta[4])
    return s


jax.tree_util.register_pytree_node(QuerySpec, _spec_flatten, _spec_unflatten)


@dataclasses.dataclass(frozen=True)
class QueryBatch:
    """An ordered, possibly mixed-aggregate batch of ``QuerySpec``s."""

    specs: Tuple[QuerySpec, ...]

    def __post_init__(self):
        object.__setattr__(self, "specs", tuple(self.specs))

    @classmethod
    def of(cls, *specs: QuerySpec) -> "QueryBatch":
        return cls(specs)

    def __len__(self) -> int:
        return len(self.specs)

    def __iter__(self):
        return iter(self.specs)

    def __getitem__(self, i):
        return self.specs[i]

    @property
    def n_queries(self) -> int:
        return sum(len(s) for s in self.specs)


jax.tree_util.register_pytree_node(
    QueryBatch,
    lambda b: (b.specs, None),
    lambda _, specs: QueryBatch(tuple(specs)))


@dataclasses.dataclass(frozen=True)
class TableSpec:
    """Fit-time description of one table (dataset x aggregate).

    ``agg``: 'sum' | 'count' | 'max' | 'min' for one key, or 'count2d' |
    'sum2d' | 'max2d' | 'min2d' for two (2-D MAX/MIN are dominance-corner
    queries — DESIGN.md §12).
    ``budget``: the table's ``ErrorBudget`` — the *only* place the build
    delta comes from.  ``deg`` defaults to 2 for SUM/COUNT and 3 for
    MAX/MIN/2-D (the paper's recommendations).  ``dynamic`` wraps the plan
    in a delta-buffered engine (inserts/deletes without rebuild);
    ``lsm`` (requires ``dynamic``) tiers the table into a geometric
    ladder of immutable plans (``engine/lsm.py`` — worst-case bounded
    compactions instead of full refits, ``growth`` is the ladder's
    geometric factor); ``shards`` partitions the plan's tables across
    that many devices and serves it through the shard_map executors
    (``engine/sharded.py`` — 1-D key ranges, 2-D Morton z-ranges; LSM
    ladders shard per level and serve Q_abs only).

    ``deadline``/``priority`` declare the table's serving guarantee class
    (DESIGN.md §14): ``deadline`` is the default admission deadline in
    seconds for reads on this table (a request still queued when it
    expires fails with ``DeadlineExceeded`` instead of dispatching;
    ``None`` = no deadline), and ``priority`` picks the table's rung on
    the engine's load-shedding ladder (higher sheds later).  Both can be
    overridden per request at ``ServingEngine.submit``.
    """

    agg: str
    budget: ErrorBudget
    deg: Optional[int] = None
    dynamic: bool = False
    lsm: bool = False
    growth: int = 4
    capacity: int = 1024
    background: bool = True
    auto_refit: bool = True
    shards: Optional[int] = None
    deadline: Optional[float] = None
    priority: int = 0
    window: int = 0

    def __post_init__(self):
        if self.agg not in _NRANGES:
            raise ValueError(f"unknown aggregate {self.agg!r}; expected one "
                             f"of {sorted(_NRANGES)}")
        assert self.agg in DELTA_FRACTION
        if self.window:
            if self.window < 1:
                raise ValueError("window must be >= 1 retained epochs "
                                 "(or 0 for a non-windowed table)")
            if self.agg not in ("sum", "count"):
                raise ValueError("windowed tables support 1-D SUM/COUNT "
                                 f"only, got {self.agg!r}")
            if self.dynamic or self.lsm or self.shards:
                raise ValueError("window tables manage their own epoch "
                                 "ring; dynamic/lsm/shards do not apply")
        if self.lsm and not self.dynamic:
            raise ValueError("lsm=True tiers the *update* path into a level "
                             "ladder; it requires dynamic=True")
        if self.growth < 2:
            raise ValueError("growth must be >= 2")
        if self.deadline is not None and self.deadline <= 0:
            raise ValueError("deadline must be positive seconds (or None)")
        if self.priority < 0:
            raise ValueError("priority must be >= 0")

    @property
    def degree(self) -> int:
        return self.deg if self.deg is not None else (
            2 if self.agg in ("sum", "count") else 3)

    @property
    def n_ranges(self) -> int:
        return _NRANGES[self.agg]
