"""The ``PolyFit`` session facade: one declarative entry point for every
aggregate family, batch shape, dynamism level and device layout.

    from repro.api import ErrorBudget, PolyFit, QueryBatch, QuerySpec, TableSpec

    session = PolyFit.fit(
        {"lat": keys, "price": (ts, vals), "geo": (xs, ys)},
        {"lat":   TableSpec("count",   ErrorBudget(abs=100, rel=0.01)),
         "price": TableSpec("max",     ErrorBudget(abs=50.0)),
         "geo":   TableSpec("count2d", ErrorBudget(abs=200))})
    results = session.query(QueryBatch.of(
        QuerySpec.range("lat", -10.0, 30.0),
        QuerySpec.rect("geo", x0, x1, y0, y1),
        QuerySpec.range("price", t0, t1)))

``fit`` builds one index per named table with the delta its ``ErrorBudget``
derives (Lemma 5.1/5.3/6.3 — see ``budget.py``), lowers each to a canonical
device plan, and wires the execution stack the ``TableSpec`` asks for:
static plans dispatch straight through ``engine.execute_*``, ``dynamic``
tables get a delta-buffered ``DynamicEngine`` (inserts/deletes without
rebuild), ``lsm=True`` tables an ``LsmEngine`` geometric level ladder
(worst-case bounded compactions, never a full refit — DESIGN.md §15),
``shards=N`` partitions the plan across N devices behind the
``shard_map`` executor (``engine/sharded.py``; LSM ladders shard per
level, Q_abs only).  ``query`` groups a mixed
batch by (plan, guarantee), pads each group to its power-of-two bucket,
runs one fused jitted executor per group, and scatters the answers back in
request order — so callers never touch ``Engine``/``DynamicEngine``, which
are now internal machinery behind this facade.
"""
from __future__ import annotations

from typing import Dict, List, Mapping, Optional, Sequence, Tuple, Union

import jax.numpy as jnp
import numpy as np

from ..core import AGGS_2D, build_index_1d, build_index_2d
from ..core.queries import QueryResult
from ..engine import (DynamicEngine, DynamicEngine2D, LsmEngine,
                      LsmEngine2D, ShardedEngine, ShardedEngine2D,
                      build_plan, build_plan_2d, execute, fused_executor)
from ..kernels.poly_eval import DEFAULT_BQ
from .budget import ErrorBudget
from .spec import DEFAULT_REL, QueryBatch, QuerySpec, TableSpec

__all__ = ["PolyFit"]

Request = Union[QuerySpec, QueryBatch, Sequence[QuerySpec]]


class _Table:
    """One fitted table: the spec plus whichever execution stack it needs."""

    def __init__(self, name: str, spec: TableSpec, data, *, backend: str,
                 interpret: bool, bq: int, min_bucket: int):
        self.name = name
        self.spec = spec
        self.dyn = None
        self.sharded = None
        self._static_plan = None
        agg = spec.agg
        if agg in AGGS_2D:
            if agg == "count2d":
                xs, ys = (np.asarray(a, np.float64) for a in data)
                ws = None
            else:
                xs, ys, ws = (np.asarray(a, np.float64) for a in data)
            if spec.lsm:
                self.dyn = LsmEngine2D(
                    xs, ys, ws, agg=agg, deg=spec.degree,
                    delta=spec.budget.delta(agg), backend=backend,
                    interpret=interpret, capacity=spec.capacity,
                    growth=spec.growth, background=spec.background,
                    auto_refit=spec.auto_refit, bq=bq,
                    min_bucket=min_bucket)
            elif spec.dynamic:
                idx = build_index_2d(xs, ys, measures=ws, agg=agg,
                                     deg=spec.degree,
                                     delta=spec.budget.delta(agg))
                self.dyn = DynamicEngine2D(
                    idx, backend=backend, interpret=interpret,
                    capacity=spec.capacity, background=spec.background,
                    auto_refit=spec.auto_refit, bq=bq,
                    min_bucket=min_bucket)
            else:
                idx = build_index_2d(xs, ys, measures=ws, agg=agg,
                                     deg=spec.degree,
                                     delta=spec.budget.delta(agg))
                self._static_plan = build_plan_2d(idx)
            if spec.shards is not None:
                self.sharded = ShardedEngine2D(spec.shards,
                                               min_bucket=min_bucket)
                self.sharded.shard(self.plan)   # warm the partition cache
        else:
            keys, meas = data
            keys = np.asarray(keys, np.float64)
            meas = None if meas is None else np.asarray(meas, np.float64)
            if spec.lsm:
                self.dyn = LsmEngine(
                    keys, meas, agg=agg, deg=spec.degree,
                    delta=spec.budget.delta(agg), backend=backend,
                    interpret=interpret, capacity=spec.capacity,
                    growth=spec.growth, background=spec.background,
                    auto_refit=spec.auto_refit, bq=bq,
                    min_bucket=min_bucket)
            elif spec.dynamic:
                idx = build_index_1d(keys, meas, agg, deg=spec.degree,
                                     delta=spec.budget.delta(agg))
                self.dyn = DynamicEngine(
                    idx, backend=backend, interpret=interpret,
                    capacity=spec.capacity, background=spec.background,
                    auto_refit=spec.auto_refit, bq=bq,
                    min_bucket=min_bucket)
            else:
                idx = build_index_1d(keys, meas, agg, deg=spec.degree,
                                     delta=spec.budget.delta(agg))
                self._static_plan = build_plan(idx)
            if spec.shards is not None:
                self.sharded = ShardedEngine(spec.shards,
                                             min_bucket=min_bucket)
                self.sharded.shard(self.plan)   # warm the partition cache

    @property
    def plan(self):
        return self.dyn.plan if self.dyn is not None else self._static_plan

    def snapshot(self):
        """Immutable (plan, delta-buffer) pair; ``()`` buffer when static."""
        if self.dyn is not None:
            return self.dyn.snapshot()
        return self._static_plan, ()

    def resolve_rel(self, rel) -> Optional[float]:
        return self.spec.budget.rel if rel is DEFAULT_REL else rel


class PolyFit:
    """A fitted PolyFit session — construct with :meth:`fit`."""

    def __init__(self, tables: Dict[str, _Table], *, backend: str,
                 interpret: bool, bq: int, min_bucket: int):
        self._tables = tables
        self.backend = backend
        self.interpret = interpret
        self.bq = bq
        self.min_bucket = min_bucket

    # -- construction ----------------------------------------------------

    @classmethod
    def fit(cls, datasets: Mapping, specs: Mapping[str, TableSpec], *,
            backend: str = "xla", interpret: bool = True,
            bq: int = DEFAULT_BQ, min_bucket: int = 64) -> "PolyFit":
        """Build one index per named table and return the query session.

        ``datasets`` maps table name -> data: a bare key array (COUNT),
        ``(keys, measures)`` for SUM/MAX/MIN, ``(xs, ys)`` for 2-key COUNT.
        ``specs`` maps the same names to ``TableSpec``s; the spec's
        ``ErrorBudget`` is the only source of build deltas.
        """
        missing = set(datasets) ^ set(specs)
        if missing:
            raise ValueError(f"datasets and specs disagree on tables: "
                             f"{sorted(missing)}")
        tables = {}
        for name, spec in specs.items():
            data = datasets[name]
            if spec.agg == "count2d":
                if not (isinstance(data, tuple) and len(data) == 2):
                    raise ValueError(f"table {name!r}: count2d data must be "
                                     "(xs, ys)")
            elif spec.agg in ("sum2d", "max2d", "min2d"):
                if not (isinstance(data, tuple) and len(data) == 3):
                    raise ValueError(f"table {name!r}: {spec.agg} data must "
                                     "be (xs, ys, measures)")
            elif spec.agg == "count":
                if not isinstance(data, tuple):
                    data = (data, None)
                elif len(data) == 1:
                    data = (data[0], None)
            elif not (isinstance(data, tuple) and len(data) == 2):
                raise ValueError(f"table {name!r}: {spec.agg} data must be "
                                 "(keys, measures)")
            tables[name] = _Table(name, spec, data, backend=backend,
                                  interpret=interpret, bq=bq,
                                  min_bucket=min_bucket)
        return cls(tables, backend=backend, interpret=interpret, bq=bq,
                   min_bucket=min_bucket)

    # -- introspection ---------------------------------------------------

    @property
    def tables(self) -> Tuple[str, ...]:
        return tuple(self._tables)

    def spec(self, table: str) -> TableSpec:
        return self._table(table).spec

    def budget(self, table: str) -> ErrorBudget:
        return self._table(table).spec.budget

    def plan(self, table: str):
        """The table's current device plan (fresh after dynamic merges)."""
        return self._table(table).plan

    def size_bytes(self) -> Dict[str, int]:
        return {k: t.plan.size_bytes() for k, t in self._tables.items()}

    def _table(self, name: str) -> _Table:
        t = self._tables.get(name)
        if t is None:
            raise KeyError(f"unknown table {name!r}; fitted tables: "
                           f"{sorted(self._tables)}")
        return t

    # -- serving hooks (repro.serve.engine) -------------------------------

    def snapshot(self, table: str):
        """The table's current immutable (plan, delta-buffer) pair.

        Static tables return ``()`` for the buffer so callers can pass the
        pair straight into a :func:`~repro.engine.fused_executor` callable
        regardless of dynamism.  The pair never mutates — merges install a
        *new* plan object — so it is safe to hold across a dispatch.
        """
        return self._table(table).snapshot()

    def resolve_rel(self, table: str,
                    rel=DEFAULT_REL) -> Optional[float]:
        """Concrete eps_rel for ``table``: the budget's default unless a
        per-request override is given."""
        return self._table(table).resolve_rel(rel)

    def is_sharded(self, table: str) -> bool:
        return self._table(table).sharded is not None

    def is_lsm(self, table: str) -> bool:
        """True when the table is a tiered level ladder (``lsm=True``)."""
        return self._table(table).spec.lsm

    def on_plan_swap(self, table: str, fn) -> None:
        """Register ``fn(incoming_plan)`` to run on the merge/compaction
        thread immediately *before* a refit installs the new plan (or
        ladder).  The serving engine uses this to AOT-lower the incoming
        plan's warmed bucket sizes so post-swap dispatches never pay a
        relower; a listener exception aborts the install and surfaces as
        the table's refit error."""
        self._dyn(table).add_install_listener(fn)

    def admission_class(self, table: str) -> Tuple[Optional[float], int]:
        """The table's serving guarantee class ``(deadline, priority)``
        (``TableSpec.deadline``/``priority``) — the serving engine's
        per-request defaults for admission deadlines and load shedding."""
        spec = self._table(table).spec
        return spec.deadline, spec.priority

    def serving_executor(self, table: str, eps_rel: Optional[float], *,
                         bq: Optional[int] = None):
        """An un-jitted ``fn(plan, buf, *padded_ranges)`` for ``table``
        with this session's backend statics closed over — the unit the
        serving engine AOT-lowers per bucket size.  ``bq`` overrides the
        session block size (callers pass ``min(session.bq, bucket)`` to
        match the in-session executors bit for bit)."""
        t = self._table(table)
        return fused_executor(t.spec.agg, t.dyn is not None,
                              backend=self.backend, eps_rel=eps_rel,
                              interpret=self.interpret,
                              bq=self.bq if bq is None else bq,
                              deg=t.spec.degree)

    # -- queries ---------------------------------------------------------

    def query(self, request: Request):
        """Answer a request batch, preserving request order.

        A single ``QuerySpec`` returns its ``QueryResult``; a
        ``QueryBatch`` (or a sequence of specs) returns a list of
        ``QueryResult``s aligned with the specs.  Specs are grouped by
        (table, guarantee); each group enters one fused jitted executor.
        """
        if isinstance(request, QuerySpec):
            return self._exec_group(request.table,
                                    request.ranges,
                                    self._resolve(request))
        specs = list(request.specs if isinstance(request, QueryBatch)
                     else request)
        if not specs:
            return []
        groups: Dict[Tuple[str, Optional[float]], List[int]] = {}
        for i, spec in enumerate(specs):
            if not isinstance(spec, QuerySpec):
                raise TypeError(f"expected QuerySpec, got {type(spec)}")
            groups.setdefault((spec.table, self._resolve(spec)),
                              []).append(i)
        out: List[Optional[QueryResult]] = [None] * len(specs)
        for (table, rel), idxs in groups.items():
            # jnp.concatenate keeps device-resident sub-batches on device
            # (and is a cheap host concat for numpy ranges)
            ranges = tuple(
                jnp.concatenate([jnp.asarray(specs[i].ranges[j])
                                 for i in idxs])
                if len(idxs) > 1 else specs[idxs[0]].ranges[j]
                for j in range(len(specs[idxs[0]].ranges)))
            res = self._exec_group(table, ranges, rel)
            off = 0
            for i in idxs:
                m = len(specs[i])
                out[i] = QueryResult(res.answer[off:off + m],
                                     res.approx[off:off + m],
                                     res.refined[off:off + m])
                off += m
        return out

    def _resolve(self, spec: QuerySpec) -> Optional[float]:
        t = self._table(spec.table)
        if len(spec.ranges) != t.spec.n_ranges:
            raise ValueError(
                f"table {spec.table!r} ({t.spec.agg}) takes "
                f"{t.spec.n_ranges} range coordinates, spec has "
                f"{len(spec.ranges)}")
        return t.resolve_rel(spec.rel)

    def _exec_group(self, table: str, ranges, eps_rel) -> QueryResult:
        t = self._table(table)
        if t.sharded is not None:
            if t.dyn is not None:
                plan, buf = t.dyn.snapshot()
                return t.sharded.query(plan, *ranges, eps_rel=eps_rel,
                                       buf=buf)
            return t.sharded.query(t.plan, *ranges, eps_rel=eps_rel)
        if t.dyn is not None:
            return t.dyn.query(*ranges, eps_rel=eps_rel)
        return execute(t.plan, tuple(jnp.asarray(r) for r in ranges),
                       backend=self.backend, eps_rel=eps_rel,
                       interpret=self.interpret, bq=self.bq,
                       min_bucket=self.min_bucket)

    # -- updates (dynamic tables) ----------------------------------------

    def _dyn(self, table: str):
        t = self._table(table)
        if t.dyn is None:
            raise RuntimeError(f"table {table!r} is static; fit it with "
                               "TableSpec(dynamic=True) to take updates")
        return t.dyn

    def insert(self, table: str, *args) -> None:
        """Buffer new records: (keys[, measures]) for 1-D tables,
        (xs, ys) for 2-key COUNT.  Queries fold them in exactly."""
        self._dyn(table).insert(*args)

    def delete(self, table: str, *args) -> None:
        """Buffer delete tombstones for existing records."""
        self._dyn(table).delete(*args)

    def flush(self, table: Optional[str] = None) -> None:
        """Merge buffered updates into fresh plans (all tables default)."""
        names = [table] if table is not None else [
            k for k, t in self._tables.items() if t.dyn is not None]
        for name in names:
            self._dyn(name).flush()
