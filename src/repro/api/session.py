"""The ``PolyFit`` session facade: one declarative entry point for every
aggregate family, batch shape, dynamism level and device layout.

    from repro.api import ErrorBudget, PolyFit, QueryBatch, QuerySpec, TableSpec

    session = PolyFit.fit(
        {"lat": keys, "price": (ts, vals), "geo": (xs, ys)},
        {"lat":   TableSpec("count",   ErrorBudget(abs=100, rel=0.01)),
         "price": TableSpec("max",     ErrorBudget(abs=50.0)),
         "geo":   TableSpec("count2d", ErrorBudget(abs=200))})
    results = session.query(QueryBatch.of(
        QuerySpec.range("lat", -10.0, 30.0),
        QuerySpec.rect("geo", x0, x1, y0, y1),
        QuerySpec.range("price", t0, t1)))

``fit`` builds one index per named table with the delta its ``ErrorBudget``
derives (Lemma 5.1/5.3/6.3 — see ``budget.py``), lowers each to a canonical
device plan, and wires the execution stack the ``TableSpec`` asks for:
static plans dispatch straight through ``engine.execute_*``, ``dynamic``
tables get a delta-buffered ``DynamicEngine`` (inserts/deletes without
rebuild), ``lsm=True`` tables an ``LsmEngine`` geometric level ladder
(worst-case bounded compactions, never a full refit — DESIGN.md §15),
``shards=N`` partitions the plan across N devices behind the
``shard_map`` executor (``engine/sharded.py``; LSM ladders shard per
level, Q_abs only).  ``query`` groups a mixed
batch by (plan, guarantee), pads each group to its power-of-two bucket,
runs one fused jitted executor per group, and scatters the answers back in
request order — so callers never touch ``Engine``/``DynamicEngine``, which
are now internal machinery behind this facade.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..core import AGGS_2D, build_index_1d, build_index_2d
from ..core.queries import QueryResult
from ..engine import (DynamicEngine, DynamicEngine2D, LsmEngine,
                      LsmEngine2D, ShardedEngine, ShardedEngine2D,
                      WindowEngine, build_plan, build_plan_2d, execute,
                      execute_quantile, fused_executor,
                      fused_quantile_executor)
from ..kernels.poly_eval import DEFAULT_BQ
from .budget import ErrorBudget
from .spec import (DEFAULT_REL, KIND_OF_AGG, QueryBatch, QuerySpec,
                   TableSpec)

__all__ = ["PolyFit", "Answer"]

Request = Union[QuerySpec, QueryBatch, Sequence[QuerySpec]]


@dataclasses.dataclass(frozen=True)
class Answer:
    """One structured query answer, uniform across every query kind.

    ``value`` is the (possibly refined) answer batch; ``approx``/``refined``
    expose the raw index answers and the Q_rel refinement mask exactly as
    :class:`~repro.core.queries.QueryResult` did.  ``bound`` is the
    certified guarantee that travels with the answer: the scalar Q_abs
    bound for range aggregates (composed over selected epochs for window
    queries), or the ``(lo, hi)`` certified key interval for quantiles
    (``value`` is clipped inside it).  ``staleness`` counts how far the
    answer lags a fully-merged view — buffered-but-unmerged rows for
    dynamic/LSM tables, trailing epochs (current minus ``t1``) for window
    queries, 0 for static plans; buffered rows are still folded in
    *exactly*, so staleness is an operational signal, not extra error.

    ``.answer`` aliases ``value`` for drop-in compatibility with
    ``QueryResult`` consumers.
    """

    value: jnp.ndarray
    approx: jnp.ndarray
    refined: jnp.ndarray
    bound: object = None
    staleness: int = 0

    @property
    def answer(self):
        return self.value

    def __iter__(self):   # (value, approx, refined) unpacking compat
        return iter((self.value, self.approx, self.refined))


jax.tree_util.register_pytree_node(
    Answer,
    lambda a: ((a.value, a.approx, a.refined, a.bound), a.staleness),
    lambda staleness, kids: Answer(*kids, staleness=staleness))


class _Table:
    """One fitted table: the spec plus whichever execution stack it needs."""

    def __init__(self, name: str, spec: TableSpec, data, *, backend: str,
                 interpret: bool, bq: int, min_bucket: int):
        self.name = name
        self.spec = spec
        self.dyn = None
        self.win = None
        self.sharded = None
        self._static_plan = None
        agg = spec.agg
        if spec.window:
            keys, meas = data
            self.win = WindowEngine(
                keys, meas, agg=agg, delta=spec.budget.delta(agg),
                deg=spec.degree, ring=spec.window, capacity=spec.capacity,
                backend=backend, interpret=interpret, bq=bq,
                min_bucket=min_bucket)
        elif agg in AGGS_2D:
            if agg == "count2d":
                xs, ys = (np.asarray(a, np.float64) for a in data)
                ws = None
            else:
                xs, ys, ws = (np.asarray(a, np.float64) for a in data)
            if spec.lsm:
                self.dyn = LsmEngine2D(
                    xs, ys, ws, agg=agg, deg=spec.degree,
                    delta=spec.budget.delta(agg), backend=backend,
                    interpret=interpret, capacity=spec.capacity,
                    growth=spec.growth, background=spec.background,
                    auto_refit=spec.auto_refit, bq=bq,
                    min_bucket=min_bucket)
            elif spec.dynamic:
                idx = build_index_2d(xs, ys, measures=ws, agg=agg,
                                     deg=spec.degree,
                                     delta=spec.budget.delta(agg))
                self.dyn = DynamicEngine2D(
                    idx, backend=backend, interpret=interpret,
                    capacity=spec.capacity, background=spec.background,
                    auto_refit=spec.auto_refit, bq=bq,
                    min_bucket=min_bucket)
            else:
                idx = build_index_2d(xs, ys, measures=ws, agg=agg,
                                     deg=spec.degree,
                                     delta=spec.budget.delta(agg))
                self._static_plan = build_plan_2d(idx)
            if spec.shards is not None:
                self.sharded = ShardedEngine2D(spec.shards,
                                               min_bucket=min_bucket)
                self.sharded.shard(self.plan)   # warm the partition cache
        else:
            keys, meas = data
            keys = np.asarray(keys, np.float64)
            meas = None if meas is None else np.asarray(meas, np.float64)
            if spec.lsm:
                self.dyn = LsmEngine(
                    keys, meas, agg=agg, deg=spec.degree,
                    delta=spec.budget.delta(agg), backend=backend,
                    interpret=interpret, capacity=spec.capacity,
                    growth=spec.growth, background=spec.background,
                    auto_refit=spec.auto_refit, bq=bq,
                    min_bucket=min_bucket)
            elif spec.dynamic:
                idx = build_index_1d(keys, meas, agg, deg=spec.degree,
                                     delta=spec.budget.delta(agg))
                self.dyn = DynamicEngine(
                    idx, backend=backend, interpret=interpret,
                    capacity=spec.capacity, background=spec.background,
                    auto_refit=spec.auto_refit, bq=bq,
                    min_bucket=min_bucket)
            else:
                idx = build_index_1d(keys, meas, agg, deg=spec.degree,
                                     delta=spec.budget.delta(agg))
                self._static_plan = build_plan(idx)
            if spec.shards is not None:
                self.sharded = ShardedEngine(spec.shards,
                                             min_bucket=min_bucket)
                self.sharded.shard(self.plan)   # warm the partition cache

    @property
    def plan(self):
        if self.win is not None:
            raise RuntimeError(
                f"table {self.name!r} is windowed — there is no single "
                "plan; take window_plan(t0, t1) snapshots instead")
        return self.dyn.plan if self.dyn is not None else self._static_plan

    def snapshot(self):
        """Immutable (plan, delta-buffer) pair; ``()`` buffer when static."""
        if self.dyn is not None:
            return self.dyn.snapshot()
        return self._static_plan, ()

    def size_bytes(self) -> int:
        if self.win is not None:
            return sum(lvl.plan.size_bytes()
                       for _, lvl in self.win._ring if lvl is not None)
        return self.plan.size_bytes()

    def resolve_rel(self, rel) -> Optional[float]:
        return self.spec.budget.rel if rel is DEFAULT_REL else rel

    @property
    def kind(self) -> str:
        """The range-query kind this table's aggregate answers."""
        return KIND_OF_AGG[self.spec.agg]

    def staleness(self, kind: str, params: Tuple) -> int:
        if kind == "window":
            return max(0, self.win.epoch - params[1])
        if self.dyn is not None:
            return int(getattr(self.dyn, "n_pending",
                               getattr(self.dyn, "_n_pending", 0)))
        return 0


class PolyFit:
    """A fitted PolyFit session — construct with :meth:`fit`."""

    def __init__(self, tables: Dict[str, _Table], *, backend: str,
                 interpret: bool, bq: int, min_bucket: int):
        self._tables = tables
        self.backend = backend
        self.interpret = interpret
        self.bq = bq
        self.min_bucket = min_bucket

    # -- construction ----------------------------------------------------

    @classmethod
    def fit(cls, datasets: Mapping, specs: Mapping[str, TableSpec], *,
            backend: str = "xla", interpret: bool = True,
            bq: int = DEFAULT_BQ, min_bucket: int = 64) -> "PolyFit":
        """Build one index per named table and return the query session.

        ``datasets`` maps table name -> data: a bare key array (COUNT),
        ``(keys, measures)`` for SUM/MAX/MIN, ``(xs, ys)`` for 2-key COUNT.
        ``specs`` maps the same names to ``TableSpec``s; the spec's
        ``ErrorBudget`` is the only source of build deltas.
        """
        missing = set(datasets) ^ set(specs)
        if missing:
            raise ValueError(f"datasets and specs disagree on tables: "
                             f"{sorted(missing)}")
        tables = {}
        for name, spec in specs.items():
            data = datasets[name]
            if spec.agg == "count2d":
                if not (isinstance(data, tuple) and len(data) == 2):
                    raise ValueError(f"table {name!r}: count2d data must be "
                                     "(xs, ys)")
            elif spec.agg in ("sum2d", "max2d", "min2d"):
                if not (isinstance(data, tuple) and len(data) == 3):
                    raise ValueError(f"table {name!r}: {spec.agg} data must "
                                     "be (xs, ys, measures)")
            elif spec.agg == "count":
                if not isinstance(data, tuple):
                    data = (data, None)
                elif len(data) == 1:
                    data = (data[0], None)
            elif not (isinstance(data, tuple) and len(data) == 2):
                raise ValueError(f"table {name!r}: {spec.agg} data must be "
                                 "(keys, measures)")
            tables[name] = _Table(name, spec, data, backend=backend,
                                  interpret=interpret, bq=bq,
                                  min_bucket=min_bucket)
        return cls(tables, backend=backend, interpret=interpret, bq=bq,
                   min_bucket=min_bucket)

    # -- introspection ---------------------------------------------------

    @property
    def tables(self) -> Tuple[str, ...]:
        return tuple(self._tables)

    def spec(self, table: str) -> TableSpec:
        return self._table(table).spec

    def budget(self, table: str) -> ErrorBudget:
        return self._table(table).spec.budget

    def plan(self, table: str):
        """The table's current device plan (fresh after dynamic merges)."""
        return self._table(table).plan

    def size_bytes(self) -> Dict[str, int]:
        return {k: t.size_bytes() for k, t in self._tables.items()}

    def _table(self, name: str) -> _Table:
        t = self._tables.get(name)
        if t is None:
            raise KeyError(f"unknown table {name!r}; fitted tables: "
                           f"{sorted(self._tables)}")
        return t

    # -- serving hooks (repro.serve.engine) -------------------------------

    def snapshot(self, table: str):
        """The table's current immutable (plan, delta-buffer) pair.

        Static tables return ``()`` for the buffer so callers can pass the
        pair straight into a :func:`~repro.engine.fused_executor` callable
        regardless of dynamism.  The pair never mutates — merges install a
        *new* plan object — so it is safe to hold across a dispatch.
        """
        return self._table(table).snapshot()

    def resolve_rel(self, table: str,
                    rel=DEFAULT_REL) -> Optional[float]:
        """Concrete eps_rel for ``table``: the budget's default unless a
        per-request override is given."""
        return self._table(table).resolve_rel(rel)

    def is_sharded(self, table: str) -> bool:
        return self._table(table).sharded is not None

    def is_lsm(self, table: str) -> bool:
        """True when the table is a tiered level ladder (``lsm=True``)."""
        return self._table(table).spec.lsm

    def on_plan_swap(self, table: str, fn) -> None:
        """Register ``fn(incoming_plan)`` to run on the merge/compaction
        thread immediately *before* a refit installs the new plan (or
        ladder).  The serving engine uses this to AOT-lower the incoming
        plan's warmed bucket sizes so post-swap dispatches never pay a
        relower; a listener exception aborts the install and surfaces as
        the table's refit error."""
        self._dyn(table).add_install_listener(fn)

    def admission_class(self, table: str) -> Tuple[Optional[float], int]:
        """The table's serving guarantee class ``(deadline, priority)``
        (``TableSpec.deadline``/``priority``) — the serving engine's
        per-request defaults for admission deadlines and load shedding."""
        spec = self._table(table).spec
        return spec.deadline, spec.priority

    def serving_executor(self, table: str, eps_rel: Optional[float], *,
                         bq: Optional[int] = None, kind: str = "range"):
        """An un-jitted ``fn(plan, buf, *padded_ranges)`` for ``table``
        with this session's backend statics closed over — the unit the
        serving engine AOT-lowers per bucket size.  ``bq`` overrides the
        session block size (callers pass ``min(session.bq, bucket)`` to
        match the in-session executors bit for bit).  ``kind='quantile'``
        returns the CF-inversion executor ``fn(plan, buf, padded_qs)``
        instead of the range one."""
        t = self._table(table)
        if kind == "quantile":
            return fused_quantile_executor(t.dyn is not None,
                                           backend=self.backend,
                                           interpret=self.interpret,
                                           bq=self.bq if bq is None else bq,
                                           deg=t.spec.degree)
        return fused_executor(t.spec.agg, t.dyn is not None,
                              backend=self.backend, eps_rel=eps_rel,
                              interpret=self.interpret,
                              bq=self.bq if bq is None else bq,
                              deg=t.spec.degree)

    def resolve_spec(self, spec: QuerySpec):
        """Validated ``(kind, eps_rel, params)`` grouping coordinates for a
        spec — the serving engine's admission-time resolution (quantiles
        force ``eps_rel=None``; legacy kind-less specs resolve from the
        table's aggregate)."""
        return self._resolve(spec)

    def resolve_kind(self, table: str, kind: Optional[str]) -> str:
        """Concrete query kind for ``table``: an explicit spec kind wins,
        a legacy ``None`` resolves from the table's aggregate."""
        return self._table(table).kind if kind is None else kind

    def is_window(self, table: str) -> bool:
        """True when the table is an epoch-ring (``TableSpec.window``)."""
        return self._table(table).win is not None

    def window_bound(self, table: str, t0: int, t1: int) -> float:
        """Certified Q_abs bound of a [t0, t1] window answer."""
        return self._win(table).bound(t0, t1)

    def window_snapshot(self, table: str, t0: int, t1: int):
        """Atomic (LsmPlan-or-None, buf-or-None) snapshot of a window —
        what external executors (serving) evaluate against."""
        return self._win(table).window_plan(t0, t1)

    # -- queries ---------------------------------------------------------

    def query(self, request: Request):
        """Answer a request batch, preserving request order.

        A single ``QuerySpec`` returns its :class:`Answer`; a
        ``QueryBatch`` (or a sequence of specs) returns a list of
        ``Answer``s aligned with the specs.  Specs are grouped by
        (table, kind, guarantee, params); each group enters one fused
        jitted executor.  Legacy kind-less specs resolve their kind from
        the table's aggregate, so pre-redesign call sites group (and
        answer) exactly as before.
        """
        if isinstance(request, QuerySpec):
            kind, rel, params = self._resolve(request)
            res = self._exec_group(request.table, kind, request.ranges,
                                   rel, params)
            return self._wrap(request.table, kind, params, res)
        specs = list(request.specs if isinstance(request, QueryBatch)
                     else request)
        if not specs:
            return []
        groups: Dict[Tuple, List[int]] = {}
        resolved = []
        for i, spec in enumerate(specs):
            if not isinstance(spec, QuerySpec):
                raise TypeError(f"expected QuerySpec, got {type(spec)}")
            kind, rel, params = self._resolve(spec)
            resolved.append((kind, rel, params))
            groups.setdefault((spec.table, kind, rel, params),
                              []).append(i)
        out: List[Optional[Answer]] = [None] * len(specs)
        for (table, kind, rel, params), idxs in groups.items():
            # jnp.concatenate keeps device-resident sub-batches on device
            # (and is a cheap host concat for numpy ranges)
            ranges = tuple(
                jnp.concatenate([jnp.asarray(specs[i].ranges[j])
                                 for i in idxs])
                if len(idxs) > 1 else specs[idxs[0]].ranges[j]
                for j in range(len(specs[idxs[0]].ranges)))
            res = self._exec_group(table, kind, ranges, rel, params)
            off = 0
            for i in idxs:
                m = len(specs[i])
                part = type(res)(*(f[off:off + m] for f in res))
                out[i] = self._wrap(table, kind, params, part)
                off += m
        return out

    def _resolve(self, spec: QuerySpec):
        """Validate a spec against its table and return the concrete
        ``(kind, eps_rel, params)`` grouping coordinates."""
        t = self._table(spec.table)
        kind = spec.kind
        if kind is None:
            kind = t.kind        # legacy spec: the table names the query
        if kind == "quantile":
            if t.spec.agg not in ("sum", "count") or t.spec.window:
                raise ValueError(
                    f"table {spec.table!r} ({t.spec.agg}"
                    f"{', windowed' if t.spec.window else ''}) cannot "
                    "answer quantiles; they invert 1-D SUM/COUNT tables")
            if t.spec.lsm:
                raise ValueError(
                    f"table {spec.table!r} is LSM-tiered; quantile "
                    "inversion needs a single fitted CF (flush to a "
                    "dynamic or static table)")
            return kind, None, ()    # no refinement path: one group per q
        if kind == "window":
            if t.win is None:
                raise ValueError(
                    f"table {spec.table!r} is not windowed; fit it with "
                    "TableSpec(window=<ring>) to take window queries")
            return kind, t.resolve_rel(spec.rel), spec.params
        if t.win is not None:
            raise ValueError(
                f"table {spec.table!r} is windowed; use "
                "QuerySpec.window(..., t0, t1) to name the epoch range")
        if kind != t.kind:
            raise ValueError(
                f"table {spec.table!r} ({t.spec.agg}) answers "
                f"{t.kind!r} queries, spec asks for {kind!r}")
        if len(spec.ranges) != t.spec.n_ranges:
            raise ValueError(
                f"table {spec.table!r} ({t.spec.agg}) takes "
                f"{t.spec.n_ranges} range coordinates, spec has "
                f"{len(spec.ranges)}")
        return kind, t.resolve_rel(spec.rel), ()

    def _exec_group(self, table: str, kind: str, ranges, eps_rel, params):
        t = self._table(table)
        if kind == "quantile":
            (qs,) = ranges
            if t.sharded is not None:
                plan, buf = t.snapshot()
                return t.sharded.quantile(plan, qs, buf=buf or None)
            if t.dyn is not None:
                return t.dyn.quantile(qs)
            return execute_quantile(t.plan, jnp.asarray(qs),
                                    backend=self.backend,
                                    interpret=self.interpret, bq=self.bq,
                                    min_bucket=self.min_bucket)
        if kind == "window":
            return t.win.query(*ranges, *params, eps_rel=eps_rel)
        if t.sharded is not None:
            if t.dyn is not None:
                plan, buf = t.dyn.snapshot()
                return t.sharded.query(plan, *ranges, eps_rel=eps_rel,
                                       buf=buf)
            return t.sharded.query(t.plan, *ranges, eps_rel=eps_rel)
        if t.dyn is not None:
            return t.dyn.query(*ranges, eps_rel=eps_rel)
        return execute(t.plan, tuple(jnp.asarray(r) for r in ranges),
                       backend=self.backend, eps_rel=eps_rel,
                       interpret=self.interpret, bq=self.bq,
                       min_bucket=self.min_bucket)

    def _wrap(self, table: str, kind: str, params, res) -> Answer:
        t = self._table(table)
        stale = t.staleness(kind, params)
        if kind == "quantile":
            return Answer(res.answer, res.answer,
                          jnp.zeros(res.answer.shape, bool),
                          bound=(res.lo, res.hi), staleness=stale)
        bound = (t.win.bound(*params) if kind == "window"
                 else t.spec.budget.bound(t.spec.agg))
        return Answer(res.answer, res.approx, res.refined, bound=bound,
                      staleness=stale)

    # -- updates (dynamic tables) ----------------------------------------

    def _dyn(self, table: str):
        t = self._table(table)
        if t.dyn is None:
            raise RuntimeError(f"table {table!r} is static; fit it with "
                               "TableSpec(dynamic=True) to take updates")
        return t.dyn

    def insert(self, table: str, *args) -> None:
        """Buffer new records: (keys[, measures]) for 1-D tables,
        (xs, ys) for 2-key COUNT.  Queries fold them in exactly."""
        self._dyn(table).insert(*args)

    def delete(self, table: str, *args) -> None:
        """Buffer delete tombstones for existing records."""
        self._dyn(table).delete(*args)

    def flush(self, table: Optional[str] = None) -> None:
        """Merge buffered updates into fresh plans (all tables default)."""
        names = [table] if table is not None else [
            k for k, t in self._tables.items() if t.dyn is not None]
        for name in names:
            self._dyn(name).flush()

    # -- windowed tables --------------------------------------------------

    def _win(self, table: str) -> WindowEngine:
        t = self._table(table)
        if t.win is None:
            raise RuntimeError(f"table {table!r} is not windowed; fit it "
                               "with TableSpec(window=<ring>) to stream "
                               "epochs")
        return t.win

    def ingest(self, table: str, keys, measures=None) -> None:
        """Append rows to a windowed table's open epoch (exact until
        sealed by :meth:`advance_epoch`)."""
        self._win(table).ingest(keys, measures)

    def advance_epoch(self, table: str) -> int:
        """Seal the open epoch into an immutable fitted plan on the ring;
        returns the new open epoch id."""
        return self._win(table).advance()

    def epoch(self, table: str) -> int:
        """The windowed table's current open epoch id."""
        return self._win(table).epoch
