"""Gradient compression for cross-replica reduction: symmetric per-tensor
int8 quantization.

``quantize_int8`` maps a float tensor to (int8 codes, float scale) with
scale = max|x| / 127, so dequantization error is bounded by scale/2 per
element (round-to-nearest).  Symmetric (zero-point-free) quantization keeps
the all-reduce associative: summing codes then dequantizing equals
dequantizing then summing, up to the shared scale handling.  Both functions
are jit- and shard_map-safe (pure jnp, no host sync).
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

__all__ = ["quantize_int8", "dequantize_int8"]

_QMAX = 127.0


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8 quantization.

    Returns (q, scale): q int8 with |q| <= 127, scale a float scalar such
    that |dequantize(q, scale) - x| <= scale/2 elementwise.  All-zero
    tensors quantize to zeros with scale 0.
    """
    x = jnp.asarray(x)
    amax = jnp.max(jnp.abs(x))
    safe = jnp.where(amax > 0, amax, 1.0)
    scale = safe / _QMAX
    q = jnp.clip(jnp.round(x / scale), -_QMAX, _QMAX).astype(jnp.int8)
    return q, jnp.where(amax > 0, scale, 0.0).astype(x.dtype)


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray,
                    dtype=jnp.float32) -> jnp.ndarray:
    """Inverse of ``quantize_int8``: q * scale in the requested dtype."""
    return q.astype(dtype) * jnp.asarray(scale, dtype)
