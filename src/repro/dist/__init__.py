"""Distributed-training substrate utilities (gradient compression, ...).

Kept dependency-light: modules here are imported inside jitted train/serve
paths and must not pull the heavy core/engine stacks.
"""
from .compression import dequantize_int8, quantize_int8
from .fault_tolerance import (FailureInjector, HeartbeatMonitor, RetryPolicy,
                              SimulatedPodFailure, elastic_remesh)

__all__ = ["quantize_int8", "dequantize_int8", "FailureInjector",
           "HeartbeatMonitor", "RetryPolicy", "SimulatedPodFailure",
           "elastic_remesh"]
