"""PartitionSpec builders for the launch stack (train/dryrun contracts).

One rule, applied uniformly: shard exactly one dimension of each leaf —
the largest dimension divisible by the chosen mesh-axis group — and
replicate the rest.  Axis groups are tried widest first (every mesh axis
combined: full ZeRO-style FSDP over pod x data x model), narrowing to
``('data', 'model')``, ``'model'``, ``'data'``; a leaf with no divisible
dimension replicates.  Scan-stacked block leaves (any path through
``blocks`` / ``enc_blocks`` / ``dec_blocks``) never shard their leading
layer axis — it is the ``lax.scan`` carry axis, and sharding it would
force a per-layer re-gather inside the scan.

On the (1, 1) smoke mesh every group has size 1, so every spec degrades
to replication and the same launcher code runs on one CPU device, the
16x16 pod, or the 2x16x16 multi-pod mesh.

``state_specs`` mirrors the param specs onto the AdamW ``TrainState``
(m/v shard exactly like their parameters, the step count replicates);
``batch_specs``/``cache_specs`` shard the batch dimension over the
data-parallel axes; ``named`` maps a spec pytree to ``NamedSharding``s
for jit in/out_shardings; ``mesh_context`` papers over the moving
``set_mesh``/``use_mesh`` API (falling back to the ``Mesh`` context
manager itself on older jax).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import numpy as np

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

__all__ = ["param_specs", "state_specs", "batch_specs", "cache_specs",
           "named", "mesh_context"]

# leaves reached through these keys are scan-stacked with a leading layer
# axis that must stay replicated
_STACKED_KEYS = ("blocks", "enc_blocks", "dec_blocks")


def _axis_groups(mesh) -> Tuple[Tuple[str, ...], ...]:
    """Candidate shard-axis groups, widest first."""
    names = tuple(mesh.axis_names)
    groups = [names]
    for g in (("data", "model"), ("model",), ("data",)):
        if all(a in names for a in g) and g != names:
            groups.append(g)
    return tuple(groups)


def _group_size(mesh, group: Tuple[str, ...]) -> int:
    return int(np.prod([mesh.shape[a] for a in group], dtype=np.int64))


def _leaf_spec(shape: Tuple[int, ...], mesh, *, skip_leading: bool) -> P:
    """One sharded dim (largest divisible), widest axis group wins."""
    if len(shape) == 0:
        return P()
    entries: list = [None] * len(shape)
    start = 1 if skip_leading and len(shape) > 1 else 0
    dims = sorted(range(start, len(shape)), key=lambda d: -shape[d])
    for group in _axis_groups(mesh):
        size = _group_size(mesh, group)
        if size == 1:
            continue
        for d in dims:
            if shape[d] % size == 0:
                entries[d] = group if len(group) > 1 else group[0]
                return P(*entries)
    return P(*entries)


def _is_stacked(path) -> bool:
    for entry in path:
        key = getattr(entry, "key", getattr(entry, "name", None))
        if key in _STACKED_KEYS:
            return True
    return False


def param_specs(params: Any, mesh) -> Any:
    """A pytree of ``PartitionSpec`` matching ``params`` leaf for leaf.

    Works on concrete arrays and on ``jax.eval_shape`` trees alike (only
    ``.shape`` is read).
    """
    return jax.tree_util.tree_map_with_path(
        lambda path, x: _leaf_spec(tuple(x.shape), mesh,
                                   skip_leading=_is_stacked(path)),
        params)


def state_specs(params: Any, mesh) -> Any:
    """Specs for the AdamW ``TrainState`` over ``params``: m and v shard
    exactly like their parameters, the step count replicates."""
    from ..optim import TrainState
    pspecs = param_specs(params, mesh)
    return TrainState(pspecs, pspecs, pspecs, P())


def _dp_axes(mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _batch_dim_spec(mesh, batch: int) -> Any:
    """Dim-0 entry for a global-batch-leading array: the data axes when
    they divide the batch, else replicated."""
    dp = _dp_axes(mesh)
    if not dp or batch % _group_size(mesh, dp) != 0:
        return None
    return dp if len(dp) > 1 else dp[0]


def batch_specs(cfg, shape, mesh) -> Dict[str, P]:
    """Input-batch specs keyed like ``SyntheticTokens.batch``: the batch
    dimension shards over the data-parallel axes, everything else
    replicates (sequence stays whole — no context parallelism here)."""
    b = _batch_dim_spec(mesh, shape.global_batch)
    specs = {"tokens": P(b)}
    if cfg.frontend == "audio_stub":
        specs["frames"] = P(b)
    elif cfg.frontend == "vision_stub":
        specs["images"] = P(b)
    return specs


def cache_specs(cfg, shape, mesh) -> Any:
    """Decode-cache specs matching ``init_cache(cfg, B, S)`` structurally.

    Built from an ``eval_shape`` of the real cache tree so every family's
    layout (kv / ssm / hybrid / encdec) is covered by one rule: the first
    dimension whose extent equals the global batch shards over the data
    axes, everything else replicates.
    """
    from ..models import init_cache
    B, S = shape.global_batch, shape.seq_len
    abstract = jax.eval_shape(lambda: init_cache(cfg, B, S))
    b = _batch_dim_spec(mesh, B)

    def leaf(x) -> P:
        entries: list = [None] * len(x.shape)
        if b is not None:
            for d, extent in enumerate(x.shape):
                if extent == B:
                    entries[d] = b
                    break
        return P(*entries)

    return jax.tree.map(leaf, abstract)


def named(mesh, specs: Any) -> Any:
    """Map a ``PartitionSpec`` pytree to ``NamedSharding``s on ``mesh``
    (jit in/out_shardings take sharding pytrees, not spec pytrees)."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def mesh_context(mesh):
    """A context manager making ``mesh`` ambient, across jax versions:
    ``jax.sharding.set_mesh`` / ``use_mesh`` where they exist, else the
    ``Mesh`` object itself (the legacy context-manager protocol)."""
    for name in ("set_mesh", "use_mesh"):
        fn = getattr(jax.sharding, name, None)
        if fn is not None:
            return fn(mesh)
    return mesh
