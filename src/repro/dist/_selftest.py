"""Distribution self-test: forces an 8-device host topology (scoped to this
module) and verifies the cross-device building blocks end to end:

1. **shard_map PolyFit** — partitioned segment tables answered with
   psum/pmax combination are bit-identical to the single-device engine
   (the certified Q_abs/Q_rel guarantees therefore survive sharding);
2. **int8 ring all-reduce** — reduce-scatter + all-gather over ppermute
   with ``dist.compression`` int8 wire format; error within the analytic
   quantization bound, all replicas agree;
3. **pipeline parallelism** — an 8-stage ppermute pipeline streaming
   microbatches matches the sequential composition;
4. **checkpoint re-sharding** — a pytree saved from one mesh layout
   restores onto a different layout with identical values.

    PYTHONPATH=src python -m repro.dist._selftest

Prints ``ALL_DIST_OK`` on success (tests/test_distributed.py asserts on
this marker).
"""
from __future__ import annotations

import os
import tempfile

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=8")

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.experimental.shard_map import shard_map  # noqa: E402
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P  # noqa: E402

jax.config.update("jax_enable_x64", True)

WORLD = 8


def check_polyfit_shard_map() -> None:
    """Sharded PolyFit plans: psum (SUM/COUNT) / pmax (MAX) combination is
    bit-identical to the single-device engine, so Lemma 5.1-5.4 transfer."""
    from repro.core import build_index_1d
    from repro.engine import Engine, ShardedEngine, build_plan

    rng = np.random.default_rng(2)
    keys = np.sort(rng.uniform(0, 500, 3000))
    meas = rng.uniform(0, 10, 3000)
    a = keys[rng.integers(0, 3000, 96)]
    b = keys[rng.integers(0, 3000, 96)]
    lq, uq = np.minimum(a, b), np.maximum(a, b)
    for agg, m, deg in (("sum", meas, 2), ("max", meas * 100, 3)):
        plan = build_plan(build_index_1d(keys, m, agg, deg=deg, delta=20.0))
        ref = Engine(backend="xla").query(plan, lq, uq, eps_rel=0.05)
        got = ShardedEngine(WORLD).query(plan, lq, uq, eps_rel=0.05)
        np.testing.assert_array_equal(np.asarray(ref.answer),
                                      np.asarray(got.answer))
    print("[dist-selftest] shard_map PolyFit psum/pmax: OK")


def check_int8_ring_allreduce() -> None:
    """Ring all-reduce (reduce-scatter + all-gather over ppermute) with the
    int8 wire format from dist.compression."""
    from repro.dist.compression import dequantize_int8, quantize_int8

    mesh = Mesh(np.array(jax.devices()[:WORLD]), ("ring",))
    perm = [(i, (i + 1) % WORLD) for i in range(WORLD)]
    chunk = 128

    def body(x):
        x = x.reshape(WORLD, chunk)          # one chunk slot per device
        idx = jax.lax.axis_index("ring")
        acc = x
        # ring reduce-scatter: at step k device d forwards slot (d - k),
        # accumulating into slot (d - k - 1); after W-1 steps device d
        # owns the fully reduced slot (d + 1) mod W.  Each hop ships int8
        # codes + one scale (the compressed wire format).
        for k in range(WORLD - 1):
            send = jnp.take(acc, (idx - k) % WORLD, axis=0)
            q, s = quantize_int8(send)
            q = jax.lax.ppermute(q, "ring", perm)
            s = jax.lax.ppermute(s, "ring", perm)
            recv = dequantize_int8(q, s, x.dtype)
            acc = acc.at[(idx - k - 1) % WORLD].add(recv)
        owned = jnp.take(acc, (idx + 1) % WORLD, axis=0)
        # all-gather the owned slots; row i of the gather is device i's
        # slot (i + 1) mod W, so a static re-order recovers slot order —
        # every replica assembles from the *same* owned chunks
        gathered = jax.lax.all_gather(owned, "ring")
        return gathered[(np.arange(WORLD) - 1) % WORLD]

    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(0, 1, (WORLD, WORLD * chunk)), jnp.float32)
    got = jax.jit(shard_map(body, mesh=mesh, in_specs=P("ring"),
                            out_specs=P("ring"), check_rep=False))(x)
    got = np.asarray(got).reshape(WORLD, WORLD, chunk)  # per-device copies
    exact = np.asarray(x).reshape(WORLD, WORLD, chunk).sum(0)
    # each chunk crosses <= W-1 quantized hops, each adding <= scale/2
    # per element with scale <= max|partial| / 127
    tol = (WORLD - 1) * (np.abs(np.asarray(x)).max() * WORLD / 127.0)
    for d in range(WORLD):
        err = np.abs(got[d] - exact).max()
        assert err <= tol, (d, err, tol)
    # all replicas agree bitwise on the assembled result
    for d in range(1, WORLD):
        np.testing.assert_array_equal(got[0], got[d])
    print(f"[dist-selftest] int8 ring all-reduce: OK (tol {tol:.3f})")


def check_pipeline_parallelism() -> None:
    """8-stage ppermute pipeline streaming 16 microbatches == sequential."""
    mesh = Mesh(np.array(jax.devices()[:WORLD]), ("pp",))
    t_micro, width = 16, 32
    rng = np.random.default_rng(4)
    ws = jnp.asarray(rng.normal(0, 0.5, (WORLD, width)), jnp.float64)
    xs = jnp.asarray(rng.normal(0, 1, (t_micro, width)), jnp.float64)

    def stage(w, h):
        return jnp.tanh(h + w)

    def body(w, xs):
        w = w[0]
        shift = [(i, (i + 1) % WORLD) for i in range(WORLD)]
        idx = jax.lax.axis_index("pp")
        state = jnp.zeros((width,), xs.dtype)
        outs = jnp.zeros_like(xs)
        for t in range(t_micro + WORLD - 1):
            feed = xs[jnp.clip(t, 0, t_micro - 1)]
            inp = jnp.where(idx == 0, feed, state)
            h = stage(w, inp)
            state = jax.lax.ppermute(h, "pp", shift)
            done = t - (WORLD - 1)            # microbatch leaving the last
            outs = jnp.where(
                (jnp.arange(t_micro) == done)[:, None]
                & (idx == WORLD - 1), h[None, :], outs)
        return jax.lax.psum(outs, "pp")       # only the last stage wrote

    got = jax.jit(shard_map(body, mesh=mesh, in_specs=(P("pp"), P()),
                            out_specs=P(), check_rep=False))(ws, xs)
    ref = xs
    for s in range(WORLD):
        ref = jax.vmap(lambda h, w=ws[s]: stage(w, h))(ref)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-12, atol=1e-12)
    print("[dist-selftest] pipeline parallelism: OK")


def check_checkpoint_reshard() -> None:
    """Save sharded on ('data',), restore re-sharded on ('model',)."""
    from repro.checkpoint import CheckpointManager

    devs = np.array(jax.devices()[:WORLD])
    mesh_a = Mesh(devs.reshape(WORLD, 1), ("data", "model"))
    mesh_b = Mesh(devs.reshape(1, WORLD), ("data", "model"))
    rng = np.random.default_rng(5)
    tree = {"w": jnp.asarray(rng.normal(0, 1, (WORLD * 4, 16))),
            "b": jnp.asarray(rng.normal(0, 1, (16,)))}
    specs_a = {"w": P("data", None), "b": P()}
    specs_b = {"w": P(None, "model"), "b": P()}
    placed = jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh_a, s)),
        tree, specs_a)
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(1, placed)
        restored = mgr.restore(tree, mesh=mesh_b, specs=specs_b)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(restored[k]),
                                      np.asarray(tree[k]))
        assert restored[k].sharding.spec == specs_b[k]
    print("[dist-selftest] checkpoint re-sharding: OK")


def main() -> None:
    assert jax.device_count() >= WORLD, jax.device_count()
    check_polyfit_shard_map()
    check_int8_ring_allreduce()
    check_pipeline_parallelism()
    check_checkpoint_reshard()
    print("ALL_DIST_OK")


if __name__ == "__main__":
    main()
