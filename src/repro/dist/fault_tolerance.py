"""Fault-tolerance primitives shared by the train launcher and the serving
engine (DESIGN.md §14).

Four small, composable pieces:

* ``FailureInjector`` — deterministic failure injection.  Step-triggered
  (``check(step)`` raises at the configured steps — the train launcher's
  simulated pod loss) and site-triggered (``arm(site, nth=..., p=...)`` +
  ``maybe_fail(site)`` sprinkled at well-defined points inside the serving
  engine's worker/updater loops — the chaos harness's crash storms).  All
  triggers are seeded, so a chaos run replays bit-identically.

* ``HeartbeatMonitor`` — a per-participant beat ledger.  Workers call
  ``beat(name)`` once per loop iteration; ``beat`` returns a straggler
  warning when the participant's own inter-beat gap exceeded ``deadline``,
  and ``stalled()`` lists participants whose *latest* beat is older than
  the deadline (the supervisor's stall detector).

* ``RetryPolicy`` — bounded retry with exponential backoff and
  decorrelated jitter, filtered by exception class, capped by both an
  attempt count and a total-sleep budget.  The serving engine wraps
  transient dispatch failures in one; the policy is seeded so tests are
  deterministic.

* ``elastic_remesh`` — restore a parameter/optimizer pytree onto a freshly
  built mesh by re-device_put-ing every leaf with its ``PartitionSpec``
  (the same re-shard path ``CheckpointManager.restore(mesh=, specs=)``
  uses after a pod failure shrinks or rebuilds the mesh).
"""
from __future__ import annotations

import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

__all__ = ["SimulatedPodFailure", "FailureInjector", "HeartbeatMonitor",
           "RetryPolicy", "elastic_remesh"]


class SimulatedPodFailure(RuntimeError):
    """Raised by ``FailureInjector`` at a configured trigger point."""


class FailureInjector:
    """Deterministic step- and site-triggered failure injection.

    ``steps`` is the train-launcher contract: ``check(step)`` raises
    ``SimulatedPodFailure`` when ``step`` is in the set.  ``p`` adds a
    seeded per-``check`` failure probability on top.

    Sites are the serving-engine contract: ``arm(name, nth=50)`` fires on
    every 50th ``maybe_fail(name)`` call, ``arm(name, p=0.01)`` fires each
    call with probability 0.01 (seeded), ``times`` caps the total fires
    per site (``times=1`` is a one-shot crash).  Un-armed sites are
    no-ops, so production code can keep its injection points unconditionally.
    """

    def __init__(self, steps: Tuple[int, ...] = (), p: float = 0.0,
                 seed: int = 0, exc=SimulatedPodFailure):
        self.steps = frozenset(int(s) for s in steps)
        self.p = float(p)
        self.exc = exc
        self._rng = np.random.default_rng(seed)
        self._sites: Dict[str, dict] = {}
        self._lock = threading.Lock()

    # -- step-triggered (launch/train.py) ---------------------------------

    def check(self, step: int) -> None:
        """Raise at the configured steps (or with probability ``p``)."""
        if int(step) in self.steps:
            raise self.exc(f"injected pod failure at step {step}")
        if self.p > 0.0:
            with self._lock:
                hit = self._rng.random() < self.p
            if hit:
                raise self.exc(f"injected random pod failure at step {step}")

    # -- site-triggered (serve/engine.py thread loops) --------------------

    def arm(self, site: str, *, nth: Optional[int] = None, p: float = 0.0,
            times: Optional[int] = None) -> "FailureInjector":
        """Arm a named injection site; returns self for chaining."""
        if nth is None and p <= 0.0:
            raise ValueError("arm() needs nth=N and/or p>0")
        with self._lock:
            self._sites[site] = {"nth": nth, "p": float(p), "times": times,
                                 "calls": 0, "fires": 0}
        return self

    def disarm(self, site: str) -> None:
        with self._lock:
            self._sites.pop(site, None)

    def maybe_fail(self, site: str) -> None:
        """Raise ``exc`` when the armed trigger for ``site`` fires.

        No-op for un-armed sites.  Thread-safe; the call/fire counters are
        shared across threads so ``nth`` means "every nth call engine-wide".
        """
        with self._lock:
            cfg = self._sites.get(site)
            if cfg is None:
                return
            cfg["calls"] += 1
            if cfg["times"] is not None and cfg["fires"] >= cfg["times"]:
                return
            fire = ((cfg["nth"] is not None and cfg["calls"] % cfg["nth"] == 0)
                    or (cfg["p"] > 0.0 and self._rng.random() < cfg["p"]))
            if fire:
                cfg["fires"] += 1
                calls = cfg["calls"]
            else:
                return
        raise self.exc(f"injected failure at site {site!r} (call {calls})")

    def fires(self, site: str) -> int:
        with self._lock:
            cfg = self._sites.get(site)
            return cfg["fires"] if cfg else 0

    def calls(self, site: str) -> int:
        with self._lock:
            cfg = self._sites.get(site)
            return cfg["calls"] if cfg else 0


class HeartbeatMonitor:
    """Per-participant beat ledger with straggler/stall detection.

    ``beat(name)`` records a beat and returns a warning string when the
    participant's own gap since its previous beat exceeded ``deadline``
    (a straggler that *did* come back); ``stalled()`` lists participants
    whose latest beat is older than the deadline right now (threads that
    have not come back — the supervisor's crash/stall signal).
    """

    def __init__(self, deadline: float = 30.0,
                 clock: Callable[[], float] = time.monotonic):
        self.deadline = float(deadline)
        self._clock = clock
        self._last: Dict[str, float] = {}
        self._beats: Dict[str, int] = {}
        self._lock = threading.Lock()

    def beat(self, name: str = "main") -> Optional[str]:
        now = self._clock()
        with self._lock:
            prev = self._last.get(name)
            self._last[name] = now
            self._beats[name] = self._beats.get(name, 0) + 1
        if prev is not None and now - prev > self.deadline:
            return (f"straggler: {name!r} beat after {now - prev:.1f}s "
                    f"(deadline {self.deadline:.1f}s)")
        return None

    def forget(self, name: str) -> None:
        with self._lock:
            self._last.pop(name, None)

    def stalled(self, now: Optional[float] = None) -> List[Tuple[str, float]]:
        """Participants whose latest beat is older than the deadline:
        ``[(name, seconds_since_last_beat), ...]``."""
        now = self._clock() if now is None else now
        with self._lock:
            return [(n, now - t) for n, t in self._last.items()
                    if now - t > self.deadline]

    def beats(self, name: str) -> int:
        with self._lock:
            return self._beats.get(name, 0)

    @property
    def participants(self) -> Tuple[str, ...]:
        with self._lock:
            return tuple(sorted(self._last))


class RetryPolicy:
    """Exponential backoff with decorrelated jitter, class-filtered,
    attempt- and sleep-budget-capped.

    ``call(fn, *args, **kwargs)`` runs ``fn`` up to ``max_attempts`` times.
    Only exceptions matching ``retry_on`` are retried; anything else (and
    the final failure) propagates.  Sleeps follow AWS-style decorrelated
    jitter — ``sleep = min(cap, uniform(base, 3 * prev))`` — summed across
    the policy's lifetime and capped by ``budget`` seconds, after which
    retries stop engine-wide (a crash storm must not amplify itself into
    a sleep storm).
    """

    def __init__(self, max_attempts: int = 3, base: float = 0.01,
                 cap: float = 0.25, retry_on: Tuple[type, ...] = (Exception,),
                 budget: Optional[float] = None, seed: int = 0,
                 sleep: Callable[[float], None] = time.sleep):
        if max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")
        self.max_attempts = int(max_attempts)
        self.base = float(base)
        self.cap = float(cap)
        self.retry_on = tuple(retry_on)
        self.budget = None if budget is None else float(budget)
        self._sleep = sleep
        self._rng = np.random.default_rng(seed)
        self._lock = threading.Lock()
        self.retries = 0          # sleeps taken (monotonic, engine-wide)
        self.giveups = 0          # calls that exhausted attempts/budget
        self.slept = 0.0          # total backoff seconds consumed

    def _next_delay(self, prev: float) -> Optional[float]:
        """The next backoff, or None when the budget is exhausted."""
        with self._lock:
            if self.budget is not None and self.slept >= self.budget:
                return None
            d = float(min(self.cap,
                          self._rng.uniform(self.base, max(3 * prev,
                                                           self.base))))
            if self.budget is not None:
                d = min(d, self.budget - self.slept)
            self.slept += d
            self.retries += 1
            return d

    def call(self, fn: Callable, *args, **kwargs):
        prev = self.base
        for attempt in range(1, self.max_attempts + 1):
            try:
                return fn(*args, **kwargs)
            except self.retry_on:
                if attempt == self.max_attempts:
                    with self._lock:
                        self.giveups += 1
                    raise
                delay = self._next_delay(prev)
                if delay is None:          # budget exhausted: stop retrying
                    with self._lock:
                        self.giveups += 1
                    raise
                prev = delay
                self._sleep(delay)
        raise AssertionError("unreachable")  # pragma: no cover

    def __call__(self, fn: Callable) -> Callable:
        """Decorator form: ``@policy`` wraps ``fn`` in ``call``."""
        def wrapped(*args, **kwargs):
            return self.call(fn, *args, **kwargs)
        wrapped.__name__ = getattr(fn, "__name__", "wrapped")
        return wrapped


def elastic_remesh(state: Any, specs: Any, build_mesh: Callable[[], Any]):
    """Move ``state`` onto a freshly built mesh after a simulated pod loss.

    ``specs`` is a pytree of ``PartitionSpec`` matching ``state`` (the
    ``dist.sharding`` builders produce it).  Every leaf is pulled to host
    and re-``device_put`` with its ``NamedSharding`` on the new mesh — the
    same re-shard path ``CheckpointManager.restore(mesh=..., specs=...)``
    takes, so a restore-then-remesh and a remesh-of-restored-state agree.
    Returns ``(state_on_new_mesh, new_mesh)``.
    """
    import jax
    from jax.sharding import NamedSharding, PartitionSpec

    mesh = build_mesh()

    def put(x, s):
        return jax.device_put(np.asarray(jax.device_get(x)),
                              NamedSharding(mesh, s))

    state = jax.tree.map(put, state, specs,
                         is_leaf=lambda x: isinstance(x, PartitionSpec))
    return state, mesh
