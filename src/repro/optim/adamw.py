"""AdamW with global-norm clipping and cosine schedule (no external deps).

Optimizer states mirror the parameter pytree (and its sharding specs —
dist.sharding.state_specs), so FSDP shards m/v alongside the weights.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["TrainState", "adamw_init", "adamw_update", "clip_by_global_norm",
           "cosine_schedule"]


class TrainState(NamedTuple):
    params: Any
    m: Any
    v: Any
    count: jnp.ndarray


def adamw_init(params) -> TrainState:
    zeros = lambda p: jax.tree.map(jnp.zeros_like, p)
    return TrainState(params, zeros(params), zeros(params),
                      jnp.zeros((), jnp.int32))


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = 0.5 * base_lr * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)
    return lr


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gn


def adamw_update(state: TrainState, grads, lr, *, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.1, max_norm=1.0):
    grads, gnorm = clip_by_global_norm(grads, max_norm)
    count = state.count + 1
    cf = count.astype(jnp.float32)
    bc1 = 1 - b1 ** cf
    bc2 = 1 - b2 ** cf

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        pf = p.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * g * g
        mhat = m2 / bc1
        vhat = v2 / bc2
        step = lr * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * pf)
        return (pf - step).astype(p.dtype), m2, v2

    out = jax.tree.map(upd, state.params, grads, state.m, state.v)
    new_p = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return TrainState(new_p, new_m, new_v, count), gnorm
