from .adamw import (TrainState, adamw_init, adamw_update, clip_by_global_norm,
                    cosine_schedule)

__all__ = ["TrainState", "adamw_init", "adamw_update", "clip_by_global_norm",
           "cosine_schedule"]
