"""train_step: loss + grad + AdamW, with microbatched gradient accumulation.

Microbatching (``microbatches > 1``) reshapes the per-step batch to
(M, B/M, S) and accumulates grads with a lax.scan — bounding activation
memory (the big-vocab logits especially) by 1/M while XLA overlaps each
microbatch's FSDP all-gathers with the previous one's compute.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from ..models import loss_fn
from ..optim import TrainState, adamw_update, cosine_schedule

__all__ = ["make_train_step"]


def make_train_step(cfg, *, base_lr=3e-4, warmup=100, total_steps=10_000,
                    microbatches: int = 1, remat: bool = True) -> Callable:
    lr_fn = cosine_schedule(base_lr, warmup, total_steps)

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, cfg, batch, remat)
        return loss, metrics, grads

    def train_step(state: TrainState, batch):
        if microbatches == 1:
            loss, metrics, grads = grads_of(state.params, batch)
        else:
            def split(x):
                return x.reshape((microbatches, x.shape[0] // microbatches)
                                 + x.shape[1:])
            mb = jax.tree.map(split, batch)

            def acc(carry, micro):
                g_acc, l_acc = carry
                loss, _, grads = grads_of(state.params, micro)
                g_acc = jax.tree.map(jnp.add, g_acc, grads)
                return (g_acc, l_acc + loss), None

            g0 = jax.tree.map(jnp.zeros_like, state.params)
            (grads, loss), _ = jax.lax.scan(acc, (g0, jnp.zeros(())), mb)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss / microbatches
            metrics = {"nll": loss, "aux": jnp.zeros(())}
        new_state, gnorm = adamw_update(state, grads, lr_fn(state.count))
        metrics = dict(metrics, loss=loss, grad_norm=gnorm,
                       lr=lr_fn(state.count))
        return new_state, metrics

    return train_step
