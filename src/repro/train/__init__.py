from .step import make_train_step

__all__ = ["make_train_step"]
