"""Fault-tolerance tests for the serving engine (DESIGN.md §14): admission
deadlines + guarantee classes, supervised worker/updater restarts, dispatch
retry, crash-safe journal replay, the load-shedding ladder, staleness
surfacing, and the deferred-update error paths.

Crashes are driven through the engine's real injection sites
(``serve.worker`` / ``serve.dispatch`` / ``serve.updater``) by a
``FailureInjector`` — the same mechanism the chaos harness uses — so every
recovery path exercised here is the one production takes.
"""
from __future__ import annotations

import threading
import time

import numpy as np
import pytest

from repro.api import ErrorBudget, PolyFit, QuerySpec, TableSpec
from repro.dist.fault_tolerance import (FailureInjector, RetryPolicy,
                                        SimulatedPodFailure)
from repro.serve import (DeadlineExceeded, Overloaded, QueueFull,
                         ServingEngine)

N1 = 3000
N2 = 1500


@pytest.fixture(scope="module")
def session():
    rng = np.random.default_rng(0xFA17)
    keys = np.sort(rng.uniform(0.0, 100.0, N1))
    vals = rng.uniform(0.0, 10.0, N1)
    xs = rng.uniform(0.0, 50.0, N2)
    ys = rng.uniform(0.0, 50.0, N2)
    b = ErrorBudget(abs=50.0, rel=0.01)
    return PolyFit.fit(
        {"sum": (keys, vals), "fast": (keys, vals), "c2": (xs, ys)},
        {"sum": TableSpec("sum", b, dynamic=True, capacity=256,
                          auto_refit=False),
         "fast": TableSpec("sum", b, deadline=0.05, priority=2),
         "c2": TableSpec("count2d", b, dynamic=True, capacity=256,
                         auto_refit=False)},
        backend="ref")


SPEC = QuerySpec.range("sum", 0.0, 100.0)


def _wait(pred, timeout=10.0, what="condition"):
    t0 = time.monotonic()
    while not pred():
        if time.monotonic() - t0 > timeout:
            raise AssertionError(f"timed out waiting for {what}")
        time.sleep(0.005)


# -- guarantee classes ----------------------------------------------------

def test_admission_class_from_table_spec(session):
    assert session.admission_class("fast") == (0.05, 2)
    assert session.admission_class("sum") == (None, 0)
    b = ErrorBudget(abs=1.0)
    with pytest.raises(ValueError, match="deadline"):
        TableSpec("sum", b, deadline=-1.0)
    with pytest.raises(ValueError, match="priority"):
        TableSpec("sum", b, priority=-1)


def test_deadline_expires_in_queue(session):
    eng = ServingEngine(session, start=False)
    try:
        f_tight = eng.submit(SPEC, deadline=0.02)
        f_slack = eng.submit(SPEC)
        time.sleep(0.1)
        eng.start()
        with pytest.raises(DeadlineExceeded):
            f_tight.result(timeout=30)
        assert f_slack.result(timeout=30).answer.shape == (1,)
        assert eng.stats.deadline_expired == 1
        assert eng.stats.answered == 1
    finally:
        eng.shutdown()


def test_table_default_deadline_applies(session):
    spec = QuerySpec.range("fast", 0.0, 100.0)
    eng = ServingEngine(session, start=False)
    try:
        f_default = eng.submit(spec)                 # table class: 0.05s
        f_override = eng.submit(spec, deadline=30.0)
        time.sleep(0.15)
        eng.start()
        with pytest.raises(DeadlineExceeded):
            f_default.result(timeout=30)
        assert f_override.result(timeout=30).answer.shape == (1,)
        assert eng.stats.deadline_expired == 1
    finally:
        eng.shutdown()


def test_deadline_class_splits_coalescing(session):
    """A tight-deadline request is never padded into a slack batch: the
    deadline class joins the group key, so one admission batch with mixed
    classes produces one dispatch per class."""
    eng = ServingEngine(session, start=False)
    try:
        slack = [eng.submit(SPEC) for _ in range(3)]
        tight = eng.submit(SPEC, deadline=5.0)
        eng.start()
        want = session.query(SPEC).answer[0]
        for f in slack + [tight]:
            assert float(f.result(timeout=60).answer[0]) == float(want)
        st = eng.stats
        assert st.dispatches == 2          # one per deadline class
        assert st.answered == 4
        assert st.coalesced == 3           # only the slack trio shared
    finally:
        eng.shutdown()


# -- supervised crash recovery --------------------------------------------

def test_worker_crash_fails_batch_and_supervisor_restarts(session):
    inj = FailureInjector().arm("serve.worker", nth=1, times=1)
    eng = ServingEngine(session, injector=inj)
    try:
        f = eng.submit(SPEC)
        assert isinstance(f.exception(timeout=30), SimulatedPodFailure)
        _wait(lambda: eng.health()["workers_alive"] == 1,
              what="worker restart")
        # the replacement worker serves normally
        assert eng.query(SPEC, timeout=60).answer.shape == (1,)
        st = eng.stats
        assert st.worker_crashes == 1 and st.restarts >= 1
    finally:
        eng.shutdown()


def test_transient_dispatch_failure_is_retried(session):
    inj = FailureInjector().arm("serve.dispatch", nth=1, times=1)
    pol = RetryPolicy(max_attempts=3, base=0.001, cap=0.01,
                      retry_on=(SimulatedPodFailure,))
    eng = ServingEngine(session, injector=inj, retry=pol)
    try:
        res = eng.query(SPEC, timeout=60)
        assert res.answer.shape == (1,)
        assert pol.retries == 1 and pol.giveups == 0
        assert eng.health()["retry"]["retries"] == 1
        assert eng.stats.worker_crashes == 0   # absorbed below thread level
    finally:
        eng.shutdown()


def test_updater_crash_replays_exactly_unapplied_suffix(session):
    """Kill the updater between fused applies: the restarted updater must
    replay exactly the un-applied journal suffix — applied-prefix sums are
    neither lost nor double-applied."""
    inj = FailureInjector().arm("serve.updater", nth=2, times=1)
    eng = ServingEngine(session, injector=inj)
    try:
        before = float(eng.query(SPEC, timeout=60).answer[0])
        per_item = 200 * 100.0
        for _ in range(3):
            eng.insert("sum", np.random.default_rng(1).uniform(0, 100, 200),
                       np.full(200, 100.0), wait=False)
        eng.drain_updates()                   # rides through crash + replay
        after = float(eng.query(SPEC, timeout=60).answer[0])
        # 3 items x 20000; a lost suffix (-20000) or a double-applied
        # prefix (+20000) lands far outside the certified window
        assert after - before == pytest.approx(3 * per_item, abs=5000.0)
        st = eng.stats
        assert st.updater_crashes == 1
        assert st.restarts >= 1
        assert st.journal_replayed >= 1
        assert eng.staged_depth == 0 and eng.staleness("sum") == 0
        eng.drain_updates()                   # crash deferred no errors
    finally:
        eng.shutdown()


def test_staleness_surfaced_while_updater_down(session):
    inj = FailureInjector().arm("serve.updater", nth=1, times=1000)
    eng = ServingEngine(session, injector=inj, supervise=False)
    try:
        before = float(eng.query(SPEC, timeout=60).answer[0])
        eng.insert("sum", np.linspace(1.0, 9.0, 10), np.full(10, 3.0),
                   wait=False)
        _wait(lambda: not eng.health()["updater_alive"],
              what="updater crash")
        assert eng.staged_depth == 10 and eng.staleness("sum") == 10
        # reads degrade gracefully: last snapshot, staleness on the answer
        f = eng.submit(SPEC)
        res = f.result(timeout=60)
        assert float(res.answer[0]) == pytest.approx(before)
        assert f.staleness == 10
        st = eng.stats
        assert st.stale_reads >= 1 and st.updater_crashes == 1
        assert eng.health()["restarts"] == 0     # supervision disabled
        # recovery: disarm and drain inline (updater dead, no supervisor)
        inj.disarm("serve.updater")
        eng.drain_updates()
        assert eng.staleness("sum") == 0
        f2 = eng.submit(SPEC)
        assert float(f2.result(timeout=60).answer[0]) == pytest.approx(
            before + 30.0)
        assert f2.staleness == 0
    finally:
        eng.shutdown()


# -- graceful degradation -------------------------------------------------

def test_shed_ladder_reserves_headroom_by_priority(session):
    eng = ServingEngine(session, start=False, max_queue=8,
                        shed_watermark=0.5)
    try:
        for _ in range(4):                     # class 0 may fill w = 1/2
            eng.submit(SPEC, priority=0)
        with pytest.raises(Overloaded):
            eng.submit(SPEC, priority=0)
        for _ in range(2):                     # class 1: up to 3/4
            eng.submit(SPEC, priority=1)
        with pytest.raises(Overloaded):
            eng.submit(SPEC, priority=1)
        for _ in range(2):                     # class 3: up to 15/16
            eng.submit(SPEC, priority=3)
        with pytest.raises(Overloaded):
            eng.submit(SPEC, priority=3)
        st = eng.stats
        assert st.shed == 3 and st.submitted == 8 and st.rejected == 0
    finally:
        eng.shutdown(drain=False)


# -- deferred-update error paths ------------------------------------------

def test_deferred_errors_surface_in_submission_order(session):
    """Two tables fail in one drain: ``drain_updates`` surfaces one error
    per call, oldest first, across tables."""
    eng = ServingEngine(session)
    try:
        eng.delete("sum", 2e9, wait=False)              # no such key
        eng.delete("c2", 999.0, 999.0, wait=False)      # no such point
        with pytest.raises(KeyError, match="key") as e1:
            eng.drain_updates()
        with pytest.raises(KeyError, match="point") as e2:
            eng.drain_updates()
        assert "2" in str(e1.value) and "999" in str(e2.value)
        eng.drain_updates()                             # now clean
    finally:
        eng.shutdown()


def test_drain_after_shutdown_surfaces_leftover_errors(session):
    eng = ServingEngine(session)
    eng.delete("sum", 3e9, wait=False)
    eng.shutdown()            # cleanup path: applies, never raises
    with pytest.raises(KeyError):
        eng.drain_updates()
    eng.drain_updates()       # leftovers exhausted: clean no-op


def test_queue_full_under_concurrent_reject_submitters(session):
    eng = ServingEngine(session, start=False, max_queue=4,
                        admission="reject")
    outcomes = []
    lock = threading.Lock()

    def one():
        try:
            f = eng.submit(SPEC)
            with lock:
                outcomes.append(f)
        except QueueFull as e:
            with lock:
                outcomes.append(e)

    try:
        threads = [threading.Thread(target=one) for _ in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(30)
        ok = [o for o in outcomes if not isinstance(o, Exception)]
        full = [o for o in outcomes if isinstance(o, QueueFull)]
        assert len(ok) == 4 and len(full) == 4
        st = eng.stats
        assert st.submitted == 4 and st.rejected == 4
    finally:
        eng.shutdown(drain=False)


def test_shutdown_vs_submit_race_strands_no_future(session):
    """Futures submitted concurrently with shutdown either get served or
    resolve with the shutdown error — none hangs, none is silently lost."""
    eng = ServingEngine(session, workers=2)
    futures = []
    lock = threading.Lock()
    stop = threading.Event()

    def submitter():
        while not stop.is_set():
            try:
                f = eng.submit(SPEC, timeout=1.0)
            except (RuntimeError, QueueFull):
                return                        # engine gone: acceptable
            with lock:
                futures.append(f)

    threads = [threading.Thread(target=submitter) for _ in range(4)]
    for t in threads:
        t.start()
    time.sleep(0.2)
    eng.shutdown(drain=True)
    stop.set()
    for t in threads:
        t.join(60)
    assert futures
    served = errored = 0
    for f in futures:
        exc = f.exception(timeout=30)         # TimeoutError => stranded
        if exc is None:
            served += 1
        else:
            assert isinstance(exc, RuntimeError)
            errored += 1
    assert served >= 1
    assert served + errored == len(futures)
