"""benchmarks/check_regression.py gates every PR's bench-smoke job but had
no tests of its own: MATCH_META pairing, the multi-record max envelope, the
2x threshold, --require-prefix missing-family failures, and the exit-code
contract (0 ok / 1 regression / 2 config error)."""
import json

import pytest

from benchmarks.check_regression import MATCH_META, compare


def _record(meta, results):
    return {"meta": meta,
            "results": [{"name": n, "us_per_query": v, "derived": ""}
                        for n, v in results.items()]}


def _write(path, records):
    path.write_text(json.dumps(records))
    return str(path)


META = {"n": 1000, "nq": 64, "device": "cpu"}


@pytest.fixture()
def files(tmp_path):
    def make(baseline_records, candidate_records):
        return (_write(tmp_path / "base.json", baseline_records),
                _write(tmp_path / "cand.json", candidate_records))
    return make


def test_ok_within_threshold(files):
    base, cand = files([_record(META, {"a.x": 1.0, "a.y": 2.0})],
                       [_record(META, {"a.x": 1.5, "a.y": 2.5})])
    assert compare(base, cand, 2.0) == 0


def test_regression_beyond_threshold(files):
    base, cand = files([_record(META, {"a.x": 1.0})],
                       [_record(META, {"a.x": 2.5})])
    assert compare(base, cand, 2.0) == 1


def test_envelope_is_max_over_matching_records(files):
    """Two committed baseline samples widen the envelope: 2.5us regresses
    against a 1.0us sample but not against the 1.5us one (2.5/1.5 < 2x)."""
    base, cand = files([_record(META, {"a.x": 1.0}),
                        _record(META, {"a.x": 1.5})],
                       [_record(META, {"a.x": 2.5})])
    assert compare(base, cand, 2.0) == 0


def test_meta_mismatch_is_config_error(files):
    """A candidate whose meta shape matches no baseline must exit 2 (the
    gate cannot compare across shapes), for every MATCH_META key."""
    other = dict(META, n=2000)
    base, cand = files([_record(other, {"a.x": 1.0})],
                       [_record(META, {"a.x": 1.0})])
    assert compare(base, cand, 2.0) == 2


def test_meta_key_absent_on_both_sides_still_pairs(files):
    """Records missing a MATCH_META key on *both* sides pair (None == None)
    — old baselines keep gating candidates that never grew the key."""
    assert "dim" in MATCH_META   # the bench_updates 2-D mode key
    meta = {"n": 5, "device": "cpu"}
    base, cand = files([_record(meta, {"a.x": 1.0})],
                       [_record(meta, {"a.x": 1.2})])
    assert compare(base, cand, 2.0) == 0


def test_dim_key_separates_update_families(files):
    """A dim=2 candidate must not pair with dim-less 1-D baselines."""
    base, cand = files([_record(META, {"updates.insert.xla": 1.0})],
                       [_record(dict(META, dim=2),
                                {"updates2d.insert.xla": 1.0})])
    assert compare(base, cand, 2.0) == 2


def test_new_metric_without_baseline_is_ignored(files):
    base, cand = files([_record(META, {"a.x": 1.0})],
                       [_record(META, {"a.x": 1.0, "b.new": 99.0})])
    assert compare(base, cand, 2.0) == 0


def test_require_prefix_missing_family_fails(files):
    base, cand = files([_record(META, {"a.x": 1.0})],
                       [_record(META, {"a.x": 1.0})])
    assert compare(base, cand, 2.0, require_prefixes=("a.",)) == 0
    assert compare(base, cand, 2.0,
                   require_prefixes=("a.", "hsweep.sum2d.")) == 2


def test_no_shared_metrics_is_config_error(files):
    base, cand = files([_record(META, {"a.x": 1.0})],
                       [_record(META, {"b.y": 1.0})])
    assert compare(base, cand, 2.0) == 2


def test_latest_candidate_record_wins(files):
    """Only the candidate history's last record is gated (earlier appends
    are prior runs)."""
    base, cand = files([_record(META, {"a.x": 1.0})],
                       [_record(META, {"a.x": 9.0}),
                        _record(META, {"a.x": 1.1})])
    assert compare(base, cand, 2.0) == 0


def test_malformed_inputs_are_config_errors(tmp_path):
    """Unreadable/empty histories exit(2) straight from the loader — the
    same code the CLI surfaces for any non-comparable configuration."""
    good = _write(tmp_path / "g.json", [_record(META, {"a.x": 1.0})])
    empty = _write(tmp_path / "e.json", [])
    missing = str(tmp_path / "nope.json")
    with pytest.raises(SystemExit) as e:
        compare(empty, good, 2.0)
    assert e.value.code == 2
    with pytest.raises(SystemExit) as e:
        compare(good, missing, 2.0)
    assert e.value.code == 2
