"""Unit tests for repro.dist.fault_tolerance: injector triggers, heartbeat
ledger, retry policy, and the elastic re-mesh path (single device)."""
import numpy as np
import pytest

import jax
from jax.sharding import PartitionSpec as P

from repro.dist.fault_tolerance import (FailureInjector, HeartbeatMonitor,
                                        RetryPolicy, SimulatedPodFailure,
                                        elastic_remesh)


# -- FailureInjector ------------------------------------------------------

def test_step_trigger_fires_at_configured_steps():
    inj = FailureInjector((3, 7))
    for step in range(10):
        if step in (3, 7):
            with pytest.raises(SimulatedPodFailure, match=f"step {step}"):
                inj.check(step)
        else:
            inj.check(step)


def test_probability_trigger_is_seeded():
    def run(seed):
        inj = FailureInjector(p=0.3, seed=seed)
        hits = []
        for step in range(200):
            try:
                inj.check(step)
            except SimulatedPodFailure:
                hits.append(step)
        return hits
    a, b = run(7), run(7)
    assert a == b and 20 < len(a) < 120          # deterministic, ~30%
    assert run(8) != a                            # seed matters


def test_site_nth_trigger_and_times_cap():
    inj = FailureInjector().arm("w", nth=3, times=2)
    fired = []
    for i in range(12):
        try:
            inj.maybe_fail("w")
        except SimulatedPodFailure:
            fired.append(i)
    assert fired == [2, 5]                        # every 3rd, capped at 2
    assert inj.fires("w") == 2 and inj.calls("w") == 12
    inj.maybe_fail("unarmed-site")                # no-op
    inj.disarm("w")
    inj.maybe_fail("w")                           # disarmed: no-op


def test_site_probability_trigger_replays():
    def run():
        inj = FailureInjector(seed=42).arm("d", p=0.1)
        out = []
        for i in range(300):
            try:
                inj.maybe_fail("d")
            except SimulatedPodFailure:
                out.append(i)
        return out
    a, b = run(), run()
    assert a == b and 10 < len(a) < 70


def test_arm_requires_a_trigger():
    with pytest.raises(ValueError):
        FailureInjector().arm("w")


def test_custom_exception_class():
    class Boom(ConnectionError):
        pass
    inj = FailureInjector(exc=Boom).arm("s", nth=1)
    with pytest.raises(Boom):
        inj.maybe_fail("s")


# -- HeartbeatMonitor -----------------------------------------------------

def test_straggler_warning_on_own_gap():
    t = [0.0]
    mon = HeartbeatMonitor(deadline=1.0, clock=lambda: t[0])
    assert mon.beat("w") is None                  # first beat: no gap yet
    t[0] = 0.5
    assert mon.beat("w") is None
    t[0] = 2.0
    msg = mon.beat("w")
    assert msg is not None and "straggler" in msg and "w" in msg
    assert mon.beats("w") == 3


def test_stalled_lists_participants_past_deadline():
    t = [0.0]
    mon = HeartbeatMonitor(deadline=1.0, clock=lambda: t[0])
    mon.beat("a")
    mon.beat("b")
    t[0] = 0.9
    assert mon.stalled() == []
    mon.beat("b")
    t[0] = 1.5
    stalls = mon.stalled()
    assert [n for n, _ in stalls] == ["a"]
    assert stalls[0][1] == pytest.approx(1.5)
    mon.forget("a")
    assert mon.stalled() == [] and mon.participants == ("b",)


# -- RetryPolicy ----------------------------------------------------------

def test_retry_succeeds_after_transient_failures():
    sleeps = []
    pol = RetryPolicy(max_attempts=4, base=0.01, cap=0.05,
                      retry_on=(ConnectionError,), sleep=sleeps.append)
    calls = [0]

    def flaky():
        calls[0] += 1
        if calls[0] < 3:
            raise ConnectionError("transient")
        return "ok"

    assert pol.call(flaky) == "ok"
    assert calls[0] == 3 and pol.retries == 2 and pol.giveups == 0
    assert len(sleeps) == 2
    assert all(0.0 < s <= 0.05 for s in sleeps)
    assert pol.slept == pytest.approx(sum(sleeps))


def test_retry_filters_exception_classes():
    pol = RetryPolicy(max_attempts=5, retry_on=(ConnectionError,),
                      sleep=lambda _: None)
    calls = [0]

    def bug():
        calls[0] += 1
        raise KeyError("not transient")

    with pytest.raises(KeyError):
        pol.call(bug)
    assert calls[0] == 1 and pol.retries == 0     # no retry on a real bug


def test_retry_exhausts_attempts_then_raises():
    pol = RetryPolicy(max_attempts=3, retry_on=(ConnectionError,),
                      sleep=lambda _: None)
    calls = [0]

    def always():
        calls[0] += 1
        raise ConnectionError("down")

    with pytest.raises(ConnectionError):
        pol.call(always)
    assert calls[0] == 3 and pol.giveups == 1


def test_retry_budget_caps_total_sleep():
    pol = RetryPolicy(max_attempts=100, base=0.05, cap=10.0, budget=0.2,
                      retry_on=(ConnectionError,), sleep=lambda _: None)

    def always():
        raise ConnectionError("down")

    with pytest.raises(ConnectionError):
        pol.call(always)
    assert pol.slept <= 0.2 + 1e-9 and pol.giveups == 1


def test_retry_decorator_form():
    pol = RetryPolicy(max_attempts=2, retry_on=(ConnectionError,),
                      sleep=lambda _: None)
    state = [0]

    @pol
    def once():
        state[0] += 1
        if state[0] == 1:
            raise ConnectionError
        return state[0]

    assert once() == 2


# -- elastic_remesh -------------------------------------------------------

def test_elastic_remesh_preserves_values_on_new_mesh():
    state = {"w": np.arange(8.0).reshape(2, 4), "b": np.ones(4)}
    specs = {"w": P(), "b": P()}
    calls = [0]

    def build_mesh():
        calls[0] += 1
        return jax.make_mesh((1,), ("data",))

    out, mesh = elastic_remesh(state, specs, build_mesh)
    assert calls[0] == 1 and mesh.axis_names == ("data",)
    np.testing.assert_array_equal(np.asarray(out["w"]), state["w"])
    np.testing.assert_array_equal(np.asarray(out["b"]), state["b"])
    assert out["w"].sharding.mesh is mesh
