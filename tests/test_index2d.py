"""Two-key extension (§6): dominance counting, merge-sort tree, quadtree."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (MergeSortTree, build_index_2d, dominance_rank,
                        query_count_2d)
from repro.data import make_queries_2d, osm_points


def test_dominance_rank_brute(rng):
    n = 800
    px, py = rng.uniform(0, 10, n), rng.uniform(0, 10, n)
    got = dominance_rank(px, py)
    want = np.array([((px <= a) & (py <= b)).sum() for a, b in zip(px, py)])
    assert (got == want).all()


def test_merge_sort_tree_rect(rng):
    n = 2000
    px, py = rng.normal(0, 3, n), rng.normal(0, 3, n)
    t = MergeSortTree.build(px, py)
    x0 = rng.uniform(-5, 5, 100); x1 = x0 + rng.uniform(0, 4, 100)
    y0 = rng.uniform(-5, 5, 100); y1 = y0 + rng.uniform(0, 4, 100)
    got = np.asarray(t.query(jnp.asarray(x0), jnp.asarray(x1),
                             jnp.asarray(y0), jnp.asarray(y1)))
    want = np.array([((px >= a) & (px <= b) & (py >= c) & (py <= d)).sum()
                     for a, b, c, d in zip(x0, x1, y0, y1)])
    assert (got == want).all()


@pytest.mark.parametrize("deg", [2, 3])
def test_quadtree_count_guarantee(deg):
    """Lemma 6.3: delta = eps_abs/4 ==> |A - R| <= eps_abs (empirically, at
    rectangle corners drawn near data — the paper's workload)."""
    px, py = osm_points(20_000, seed=5)
    eps_abs = 200.0
    idx = build_index_2d(px, py, deg=deg, delta=eps_abs / 4)
    x0, x1, y0, y1 = make_queries_2d(px, py, 300, seed=9)
    res = query_count_2d(idx, x0, x1, y0, y1)
    t = idx.exact
    truth = np.asarray(
        t.cf(jnp.asarray(x1), jnp.asarray(y1)) - t.cf(jnp.asarray(x0), jnp.asarray(y1))
        - t.cf(jnp.asarray(x1), jnp.asarray(y0)) + t.cf(jnp.asarray(x0), jnp.asarray(y0)))
    err = np.abs(np.asarray(res.answer) - truth)
    assert err.max() <= eps_abs + 1e-6


def test_quadtree_rel_guarantee():
    px, py = osm_points(20_000, seed=6)
    idx = build_index_2d(px, py, deg=3, delta=25.0)
    x0, x1, y0, y1 = make_queries_2d(px, py, 300, seed=11, frac=0.2)
    eps_rel = 0.05
    res = query_count_2d(idx, x0, x1, y0, y1, eps_rel=eps_rel)
    t = idx.exact
    truth = np.asarray(
        t.cf(jnp.asarray(x1), jnp.asarray(y1)) - t.cf(jnp.asarray(x0), jnp.asarray(y1))
        - t.cf(jnp.asarray(x1), jnp.asarray(y0)) + t.cf(jnp.asarray(x0), jnp.asarray(y0)))
    pos = truth > 0
    rel = np.abs(np.asarray(res.answer)[pos] - truth[pos]) / truth[pos]
    assert rel.max() <= eps_rel + 1e-9


def test_quadtree_lookup_total():
    """Every point in the root bounding box lands in exactly one leaf."""
    px, py = osm_points(5_000, seed=7)
    idx = build_index_2d(px, py, deg=2, delta=100.0)
    rng = np.random.default_rng(0)
    qx = rng.uniform(px.min(), px.max(), 2000)
    qy = rng.uniform(py.min(), py.max(), 2000)
    leaf = np.asarray(idx.locate(jnp.asarray(qx), jnp.asarray(qy)))
    assert (leaf >= 0).all() and (leaf < idx.n_leaves).all()
    b = np.asarray(idx.bounds)[np.asarray(idx.leaf_nodes)[leaf]]
    assert ((qx >= b[:, 0]) & (qx <= b[:, 1]) & (qy >= b[:, 2]) & (qy <= b[:, 3])).all()
