"""Two-key extension (§6): dominance counting, merge-sort tree, quadtree."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (MergeSortTree, build_index_2d, dominance_rank,
                        query_count_2d)
from repro.data import make_queries_2d, osm_points


def test_dominance_rank_brute(rng):
    n = 800
    px, py = rng.uniform(0, 10, n), rng.uniform(0, 10, n)
    got = dominance_rank(px, py)
    want = np.array([((px <= a) & (py <= b)).sum() for a, b in zip(px, py)])
    assert (got == want).all()


def test_merge_sort_tree_rect(rng):
    n = 2000
    px, py = rng.normal(0, 3, n), rng.normal(0, 3, n)
    t = MergeSortTree.build(px, py)
    x0 = rng.uniform(-5, 5, 100); x1 = x0 + rng.uniform(0, 4, 100)
    y0 = rng.uniform(-5, 5, 100); y1 = y0 + rng.uniform(0, 4, 100)
    got = np.asarray(t.query(jnp.asarray(x0), jnp.asarray(x1),
                             jnp.asarray(y0), jnp.asarray(y1)))
    want = np.array([((px >= a) & (px <= b) & (py >= c) & (py <= d)).sum()
                     for a, b, c, d in zip(x0, x1, y0, y1)])
    assert (got == want).all()


@pytest.mark.parametrize("deg", [2, 3])
def test_quadtree_count_guarantee(deg):
    """Lemma 6.3: delta = eps_abs/4 ==> |A - R| <= eps_abs (empirically, at
    rectangle corners drawn near data — the paper's workload)."""
    px, py = osm_points(20_000, seed=5)
    eps_abs = 200.0
    idx = build_index_2d(px, py, deg=deg, delta=eps_abs / 4)
    x0, x1, y0, y1 = make_queries_2d(px, py, 300, seed=9)
    res = query_count_2d(idx, x0, x1, y0, y1)
    t = idx.exact
    truth = np.asarray(
        t.cf(jnp.asarray(x1), jnp.asarray(y1)) - t.cf(jnp.asarray(x0), jnp.asarray(y1))
        - t.cf(jnp.asarray(x1), jnp.asarray(y0)) + t.cf(jnp.asarray(x0), jnp.asarray(y0)))
    err = np.abs(np.asarray(res.answer) - truth)
    assert err.max() <= eps_abs + 1e-6


def test_quadtree_rel_guarantee():
    px, py = osm_points(20_000, seed=6)
    idx = build_index_2d(px, py, deg=3, delta=25.0)
    x0, x1, y0, y1 = make_queries_2d(px, py, 300, seed=11, frac=0.2)
    eps_rel = 0.05
    res = query_count_2d(idx, x0, x1, y0, y1, eps_rel=eps_rel)
    t = idx.exact
    truth = np.asarray(
        t.cf(jnp.asarray(x1), jnp.asarray(y1)) - t.cf(jnp.asarray(x0), jnp.asarray(y1))
        - t.cf(jnp.asarray(x1), jnp.asarray(y0)) + t.cf(jnp.asarray(x0), jnp.asarray(y0)))
    pos = truth > 0
    rel = np.abs(np.asarray(res.answer)[pos] - truth[pos]) / truth[pos]
    assert rel.max() <= eps_rel + 1e-9


def test_quadtree_lookup_total():
    """Every point in the root bounding box lands in exactly one leaf."""
    px, py = osm_points(5_000, seed=7)
    idx = build_index_2d(px, py, deg=2, delta=100.0)
    rng = np.random.default_rng(0)
    qx = rng.uniform(px.min(), px.max(), 2000)
    qy = rng.uniform(py.min(), py.max(), 2000)
    leaf = np.asarray(idx.locate(jnp.asarray(qx), jnp.asarray(qy)))
    assert (leaf >= 0).all() and (leaf < idx.n_leaves).all()
    b = np.asarray(idx.bounds)[np.asarray(idx.leaf_nodes)[leaf]]
    assert ((qx >= b[:, 0]) & (qx <= b[:, 1]) & (qy >= b[:, 2]) & (qy <= b[:, 3])).all()


# ---------------------------------------------------------------------------
# measure-carrying extension (DESIGN.md §12): weighted trees, SUM/MAX/MIN
# quadtrees, selective refit
# ---------------------------------------------------------------------------

from repro.core import (query_dommax_2d, query_sum_2d,  # noqa: E402
                        selective_refit_2d)


@pytest.fixture(scope="module")
def wdata():
    rng = np.random.default_rng(0x2D)
    n = 4000
    px, py = rng.uniform(0, 100, n), rng.uniform(0, 100, n)
    w = 50 + 10 * np.sin(px / 10) + 10 * np.cos(py / 15) + rng.uniform(0, 5, n)
    return px, py, w


def test_weighted_mst_exact(wdata):
    """cf_sum / dommax (device and host paths) against brute force."""
    px, py, w = wdata
    t = MergeSortTree.build(px, py, ws=w)
    rng = np.random.default_rng(1)
    qu, qv = rng.uniform(0, 100, 150), rng.uniform(0, 100, 150)
    dom = (px[None, :] <= qu[:, None]) & (py[None, :] <= qv[:, None])
    want_sum = (dom * w[None, :]).sum(axis=1)
    np.testing.assert_allclose(
        np.asarray(t.cf_sum(jnp.asarray(qu), jnp.asarray(qv))), want_sum,
        rtol=1e-12)
    np.testing.assert_allclose(t.cf_sum_np(qu, qv), want_sum, rtol=1e-12)
    want_max = np.where(dom.any(axis=1),
                        np.where(dom, w[None, :], -np.inf).max(axis=1),
                        -np.inf)
    np.testing.assert_array_equal(
        np.asarray(t.dommax(jnp.asarray(qu), jnp.asarray(qv))), want_max)
    np.testing.assert_array_equal(t.dommax_np(qu, qv), want_max)


def test_unweighted_mst_unchanged(wdata):
    """Weight-free build keeps the old layout (no weighted arrays)."""
    px, py, _ = wdata
    t = MergeSortTree.build(px, py)
    assert t.wcum_levels is None and t.wpmax_levels is None


def test_sum2d_certified_bound(wdata):
    """|A - R| <= 4*delta for rectangle SUM (the Lemma 6.3 shape over the
    weighted CF)."""
    px, py, w = wdata
    delta = 400.0
    idx = build_index_2d(px, py, measures=w, agg="sum2d", deg=2,
                         delta=delta, max_depth=8)
    rng = np.random.default_rng(2)
    lx = rng.uniform(0, 80, 120); ux = lx + rng.uniform(5, 20, 120)
    ly = rng.uniform(0, 80, 120); uy = ly + rng.uniform(5, 20, 120)
    res = query_sum_2d(idx, lx, ux, ly, uy)
    truth = np.array([
        w[(px > a) & (px <= b) & (py > c) & (py <= d)].sum()
        for a, b, c, d in zip(lx, ux, ly, uy)])
    assert np.abs(np.asarray(res.answer) - truth).max() \
        <= 4 * idx.certified_delta + 1e-6
    # Q_rel refinement keeps the relative bound
    resr = query_sum_2d(idx, lx, ux, ly, uy, eps_rel=0.05)
    pos = truth > 0
    rel = np.abs(np.asarray(resr.answer)[pos] - truth[pos]) / truth[pos]
    assert rel.max() <= 0.05 + 1e-9


@pytest.mark.parametrize("agg", ["max2d", "min2d"])
def test_dommax2d_certified_bound(wdata, agg):
    """|A - R| <= delta for dominance MAX/MIN at corners dominating data."""
    px, py, w = wdata
    idx = build_index_2d(px, py, measures=w, agg=agg, deg=2, delta=5.0,
                         max_depth=8)
    rng = np.random.default_rng(3)
    u = px[rng.integers(0, len(px), 120)] + 1e-9
    v = py[rng.integers(0, len(px), 120)] + 1e-9
    res = query_dommax_2d(idx, u, v)
    dom = (px[None, :] <= u[:, None]) & (py[None, :] <= v[:, None])
    red = np.max if agg == "max2d" else np.min
    truth = np.array([red(w[d]) for d in dom])
    assert np.abs(np.asarray(res.answer) - truth).max() \
        <= idx.certified_delta + 1e-6
    resr = query_dommax_2d(idx, u, v, eps_rel=0.05)
    rel = np.abs(np.asarray(resr.answer) - truth) / np.abs(truth)
    assert rel.max() <= 0.05 + 1e-9


def test_leaf_agg_partition(wdata):
    """Per-leaf exact aggregates cover the dataset exactly once."""
    px, py, w = wdata
    idx = build_index_2d(px, py, measures=w, agg="sum2d", deg=2,
                         delta=800.0, max_depth=7)
    assert np.isclose(float(np.asarray(idx.leaf_agg).sum()), w.sum())
    idxm = build_index_2d(px, py, measures=w, agg="max2d", deg=2,
                          delta=8.0, max_depth=7)
    la = np.asarray(idxm.leaf_agg)
    assert np.isclose(la[np.isfinite(la)].max(), w.max())


def test_selective_refit_touches_only_dirty_leaves(wdata):
    """The acceptance invariant: leaves outside every changed point's
    dominance boundary keep their coefficient rows bit for bit; wholly
    dominated leaves change only in the constant term (by the exact
    inserted measure); bounds stay certified."""
    px, py, w = wdata
    delta = 800.0
    idx = build_index_2d(px, py, measures=w, agg="sum2d", deg=2,
                         delta=delta, max_depth=7)
    # one inserted point, well inside the domain
    ins = (np.array([70.0]), np.array([65.0]), np.array([55.0]))
    npx = np.concatenate([px, ins[0]])
    npy = np.concatenate([py, ins[1]])
    npw = np.concatenate([w, ins[2]])
    new_idx, stats = selective_refit_2d(idx, npx, npy, npw,
                                        ins[0], ins[1], ins[2])
    assert not stats["rebuild"] and stats["split"] == 0
    assert stats["refit"] < stats["n_leaves"] // 4   # selectivity

    lb = np.asarray(idx.bounds)[np.asarray(idx.leaf_nodes)]
    old_c = np.asarray(idx.coeffs)
    new_lb = np.asarray(new_idx.bounds)[np.asarray(new_idx.leaf_nodes)]
    new_c = np.asarray(new_idx.coeffs)
    # no splits: leaves correspond 1:1 by bounds
    assert len(lb) == len(new_lb)
    x0, y0, wv = float(ins[0][0]), float(ins[1][0]), float(ins[2][0])
    n_same = n_shift = n_refit = 0
    for i, b in enumerate(lb):
        j = int(np.where((new_lb == b).all(axis=1))[0][0])
        untouched = b[1] < x0 or b[3] < y0
        dominated = b[0] >= x0 and b[2] >= y0
        if untouched:
            np.testing.assert_array_equal(old_c[i], new_c[j])
            n_same += 1
        elif dominated:
            assert new_c[j][0] == old_c[i][0] + wv   # exact constant bump
            np.testing.assert_array_equal(old_c[i][1:], new_c[j][1:])
            n_shift += 1
        else:
            n_refit += 1
    assert n_refit == stats["refit"] and n_shift == stats["shifted"]
    assert n_same > 0 and n_shift > 0 and n_refit > 0

    # certified bound holds over the merged dataset
    rng = np.random.default_rng(4)
    lx = rng.uniform(0, 80, 80); ux = lx + rng.uniform(5, 20, 80)
    ly = rng.uniform(0, 80, 80); uy = ly + rng.uniform(5, 20, 80)
    res = query_sum_2d(new_idx, lx, ux, ly, uy)
    truth = np.array([
        npw[(npx > a) & (npx <= b) & (npy > c) & (npy <= d)].sum()
        for a, b, c, d in zip(lx, ux, ly, uy)])
    assert np.abs(np.asarray(res.answer) - truth).max() \
        <= 4 * new_idx.certified_delta + 1e-6


def test_selective_refit_out_of_root_falls_back(wdata):
    """Points outside the frozen root rectangle force a full rebuild."""
    px, py, w = wdata
    idx = build_index_2d(px, py, measures=w, agg="sum2d", deg=2,
                         delta=800.0, max_depth=6)
    npx = np.concatenate([px, [150.0]])
    npy = np.concatenate([py, [50.0]])
    npw = np.concatenate([w, [10.0]])
    new_idx, stats = selective_refit_2d(
        idx, npx, npy, npw, np.array([150.0]), np.array([50.0]),
        np.array([10.0]))
    assert stats["rebuild"]
    assert float(new_idx.root_bounds[1]) >= 150.0


def test_selective_refit_splits_when_certificate_fails(wdata):
    """A dense insert burst inside one leaf deepens the tree in place."""
    px, py, w = wdata
    idx = build_index_2d(px, py, measures=w, agg="count2d", deg=2,
                         delta=40.0, max_depth=9)
    # 300 duplicated-ish points in a tiny box: the covering leaf's count CF
    # jumps too sharply for its old fit
    rng = np.random.default_rng(5)
    bx = rng.uniform(42.0, 42.5, 300)
    by = rng.uniform(42.0, 42.5, 300)
    bw = np.ones(300)
    npx = np.concatenate([px, bx])
    npy = np.concatenate([py, by])
    npw = np.concatenate([np.ones_like(px), bw])
    new_idx, stats = selective_refit_2d(idx, npx, npy, npw, bx, by, bw)
    assert not stats["rebuild"]
    assert stats["split"] >= 1
    assert new_idx.n_leaves > idx.n_leaves
