"""LSM level ladder (engine/lsm.py, DESIGN.md §15).

Invariants under test:

* the fused multi-level path is bit-identical across backends, and a
  one-level ladder is bit-identical to the flat executor in-domain
  (``lq >= seg_lo[0]``, extremal ranges covering >= 1 live key);
* fully-refined multi-level answers equal the numpy ground truth for
  COUNT/MAX (integer counts exact in f64; max is associative), and
  Q_abs answers stay within the composed certified bound across >= 3
  levels of interleaved inserts and deletes;
* an extremal delete is answered exactly with NO compaction (victim
  shadowing, never an eager merge);
* compactions install atomically under a concurrent reader thread;
* the ladder is a registered pytree that round-trips flatten/unflatten;
* sharded ladders (``shard_plan`` routing) match the unsharded driver
  bit-for-bit and reject Q_rel;
* the session facade builds LSM tables (``TableSpec(lsm=True)``) and the
  serving engine pays zero new compiles after a compaction swap.
"""
import threading

import numpy as np
import pytest
import jax

jax.config.update("jax_enable_x64", True)

from repro.engine import (CompactionPolicy, LsmEngine,  # noqa: E402
                          LsmEngine2D, ShardedEngine, ShardedEngine2D,
                          build_plan, composed_bound, execute, execute_lsm,
                          execute_sum)
from repro.core import build_index_1d  # noqa: E402

BACKENDS = ("xla", "pallas", "ref")
DELTA = 40.0


def _np(a):
    return np.asarray(a)


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(11)
    keys = np.sort(rng.uniform(0.0, 1000.0, 1200))
    vals = rng.uniform(0.5, 8.0, 1200)
    return keys, vals


def _ranges(rng, lo, hi, m=33):
    lq = rng.uniform(lo, hi, m)
    uq = rng.uniform(lo, hi, m)
    return np.minimum(lq, uq), np.maximum(lq, uq)


def _covering_ranges(rng, live, m=25):
    """[lq, uq] pairs that each contain at least one live key (extremal
    queries are only defined over non-empty ranges)."""
    live = np.sort(live)
    i = rng.integers(0, live.size - 1, m)
    j = rng.integers(i, live.size)
    return live[i], live[j]


def _grow_ladder(eng, rng, lo, hi, *, batches=6, batch=None):
    """Insert full-capacity batches (each forces room, hence compactions)
    until the ladder has >= 3 levels; returns the inserted columns."""
    batch = batch or eng.capacity
    ins_k, ins_v = [], []
    for _ in range(batches):
        k = rng.uniform(lo, hi, batch)
        v = rng.uniform(0.5, 8.0, batch)
        eng.insert(k, v)
        ins_k.append(k)
        ins_v.append(v)
        if eng.n_levels >= 3:
            break
    return np.concatenate(ins_k), np.concatenate(ins_v)


# -- bit-identity ---------------------------------------------------------

@pytest.mark.parametrize("agg", ["sum", "max"])
@pytest.mark.parametrize("backend", BACKENDS)
def test_single_level_matches_flat_executor(data, agg, backend):
    """A one-level ladder computes the flat plan's floats exactly —
    the combiner is the identity for K=1 (in-domain queries)."""
    keys, vals = data
    rng = np.random.default_rng(1)
    eng = LsmEngine(keys, vals, agg=agg, delta=DELTA, backend=backend)
    lsm, _ = eng.snapshot()
    assert len(lsm.levels) == 1
    flat = build_plan(build_index_1d(
        keys, vals if agg != "min" else -vals,
        agg if agg != "min" else "max", deg=eng.deg, delta=DELTA))
    if agg in ("sum", "count"):
        lq, uq = _ranges(rng, keys[0], keys[-1])
        ref = execute_sum(flat, lq, uq, backend=backend)
    else:
        lq, uq = _covering_ranges(rng, keys)
        ref = execute(flat, (lq, uq), backend=backend)
    got = execute_lsm(lsm, None, (lq, uq), backend=backend)
    np.testing.assert_array_equal(_np(got.answer), _np(ref.answer))


@pytest.mark.parametrize("agg", ["sum", "max"])
def test_multilevel_cross_backend_bit_identity(data, agg):
    keys, vals = data
    rng = np.random.default_rng(2)
    eng = LsmEngine(keys, vals, agg=agg, delta=DELTA, capacity=256,
                    growth=2, background=False)
    _grow_ladder(eng, rng, keys[0], keys[-1])
    eng.delete(keys[100:140])            # tombstones / victims on a level
    lsm, buf = eng.snapshot()
    assert len(lsm.levels) >= 3
    if agg == "sum":
        lq, uq = _ranges(rng, keys[0], keys[-1])
    else:
        # an extremal buffer is backend-specific (the pallas delta-max
        # kernel needs the buffer's sparse table, built only by pallas
        # engines) — cross-backend identity is a ladder property
        buf = None
        lq, uq = _covering_ranges(rng, np.delete(keys, np.s_[100:140]))
    base = execute_lsm(lsm, buf, (lq, uq), backend="xla")
    for backend in ("pallas", "ref"):
        got = execute_lsm(lsm, buf, (lq, uq), backend=backend)
        np.testing.assert_array_equal(_np(got.answer), _np(base.answer))
        np.testing.assert_array_equal(_np(got.approx), _np(base.approx))


# -- certified bounds + refined truth across >= 3 levels ------------------

def test_multilevel_count_refined_equals_truth(data):
    keys, _ = data
    rng = np.random.default_rng(3)
    eng = LsmEngine(keys, agg="count", delta=DELTA, capacity=256,
                    growth=2, background=False)
    ins_k, _ = _grow_ladder(eng, rng, keys[0], keys[-1])
    dead = np.concatenate([keys[50:80], ins_k[10:30]])
    eng.delete(dead)                     # level tombstones + buffered
    lsm, buf = eng.snapshot()
    assert len(lsm.levels) >= 3
    live = np.setdiff1d(np.concatenate([keys, ins_k]), dead)
    lq, uq = _ranges(rng, keys[0], keys[-1])
    # eps so tight everything refines: the answer IS the exact count
    res = eng.query(lq, uq, eps_rel=1e-12)
    truth = np.array([((live > a) & (live <= b)).sum()
                      for a, b in zip(lq, uq)], np.float64)
    np.testing.assert_array_equal(_np(res.answer), truth)
    assert bool(np.all(_np(res.refined)))
    # Q_abs path: within the composed bound B = sum_k 2*delta_k
    qabs = eng.query(lq, uq)
    bound = composed_bound("count", lsm.deltas)
    assert float(np.max(np.abs(_np(qabs.answer) - truth))) <= bound


def test_multilevel_max_certified(data):
    keys, vals = data
    rng = np.random.default_rng(4)
    eng = LsmEngine(keys, vals, agg="max", delta=DELTA, capacity=256,
                    growth=2, background=False)
    ins_k, ins_v = _grow_ladder(eng, rng, keys[0], keys[-1])
    eng.delete(keys[200:230])
    lsm, _ = eng.snapshot()
    assert len(lsm.levels) >= 3
    live_k = np.concatenate([np.delete(keys, np.s_[200:230]), ins_k])
    live_v = np.concatenate([np.delete(vals, np.s_[200:230]), ins_v])
    lq, uq = _covering_ranges(rng, live_k)
    truth = np.array([live_v[(live_k >= a) & (live_k <= b)].max()
                      for a, b in zip(lq, uq)])
    res = eng.query(lq, uq)              # Q_abs: |ans - truth| <= max delta
    bound = composed_bound("max", lsm.deltas)
    assert float(np.max(np.abs(_np(res.answer) - truth))) <= bound
    # tight eps forces refinement through the exact per-level live maxima
    ref = eng.query(lq, uq, eps_rel=1e-12)
    np.testing.assert_array_equal(_np(ref.answer), truth)


def test_multilevel_count2d_certified():
    rng = np.random.default_rng(5)
    xs = rng.uniform(0.0, 100.0, 900)
    ys = rng.uniform(0.0, 100.0, 900)
    eng = LsmEngine2D(xs, ys, agg="count2d", delta=30.0, capacity=256,
                      growth=2, background=False)
    all_x, all_y = [xs], [ys]
    for _ in range(4):
        nx = rng.uniform(0.0, 100.0, 256)
        ny = rng.uniform(0.0, 100.0, 256)
        eng.insert(nx, ny)
        all_x.append(nx)
        all_y.append(ny)
        if eng.n_levels >= 3:
            break
    eng.delete(xs[40:70], ys[40:70])
    lsm, _ = eng.snapshot()
    assert len(lsm.levels) >= 2
    X = np.concatenate(all_x)
    Y = np.concatenate(all_y)
    live = np.ones(X.size, bool)
    live[40:70] = False
    q = [_ranges(rng, 0.0, 100.0, m=17) for _ in range(2)]
    lx, ux = q[0]
    ly, uy = q[1]
    truth = np.array([(live & (X > a) & (X <= b) & (Y > c) & (Y <= d)).sum()
                      for a, b, c, d in zip(lx, ux, ly, uy)], np.float64)
    res = eng.query(lx, ux, ly, uy)
    bound = composed_bound("count2d", lsm.deltas)
    assert float(np.max(np.abs(_np(res.answer) - truth))) <= bound
    ref = eng.query(lx, ux, ly, uy, eps_rel=1e-12)
    np.testing.assert_array_equal(_np(ref.answer), truth)


# -- extremal deletes: victim shadow, never a merge -----------------------

def test_extremal_delete_answers_exactly_with_no_merge(data):
    keys, vals = data
    eng = LsmEngine(keys, vals, agg="max", delta=DELTA, background=False)
    top = int(np.argmax(vals))
    c0 = eng.compaction_count
    eng.delete(keys[top:top + 1])        # delete the global maximum
    assert eng.compaction_count == c0    # shadowed, not compacted
    res = eng.query(np.array([keys[0]]), np.array([keys[-1]]))
    rest = np.delete(vals, top)
    # the range covers the victim -> the threat path serves the exact
    # live maximum even on the Q_abs (no-refinement) path
    assert float(res.answer[0]) == float(rest.max())


def test_additive_delete_within_bounds_no_merge(data):
    keys, vals = data
    eng = LsmEngine(keys, vals, agg="sum", delta=DELTA, background=False)
    c0 = eng.compaction_count
    eng.delete(keys[500:560])
    assert eng.compaction_count == c0    # tombstoned, not compacted
    live = np.ones(keys.size, bool)
    live[500:560] = False
    lq, uq = _ranges(np.random.default_rng(6), keys[0], keys[-1])
    truth = np.array([vals[live & (keys > a) & (keys <= b)].sum()
                      for a, b in zip(lq, uq)])
    res = eng.query(lq, uq)
    lsm, _ = eng.snapshot()
    bound = composed_bound("sum", lsm.deltas)
    assert float(np.max(np.abs(_np(res.answer) - truth))) <= bound + 1e-9


# -- compaction atomicity under a concurrent reader -----------------------

def test_compaction_atomic_under_concurrent_reader(data):
    keys, _ = data
    cap, nbatch = 256, 5
    eng = LsmEngine(keys, agg="count", delta=DELTA, capacity=cap,
                    background=True)
    rng = np.random.default_rng(7)
    lq = np.array([keys[0]])             # (kmin, kmax]: all live but kmin
    uq = np.array([keys[-1]])
    valid = {float(keys.size - 1 + i * cap) for i in range(nbatch + 1)}
    bad, done = [], threading.Event()

    def reader():
        last = 0.0
        while not done.is_set():
            ans = float(eng.query(lq, uq, eps_rel=1e-12).answer[0])
            if ans not in valid or ans < last:
                bad.append(ans)
                return
            last = ans

    t = threading.Thread(target=reader)
    t.start()
    try:
        for _ in range(nbatch):
            eng.insert(rng.uniform(keys[0] + 1.0, keys[-1] - 1.0, cap))
    finally:
        done.set()
        t.join()
    eng.refit(wait=True)
    assert not bad, f"torn reads: {bad}"
    final = float(eng.query(lq, uq, eps_rel=1e-12).answer[0])
    assert final == keys.size - 1 + nbatch * cap
    assert eng.compaction_count >= 1


# -- pytree round-trip ----------------------------------------------------

def test_ladder_pytree_roundtrip(data):
    keys, vals = data
    rng = np.random.default_rng(8)
    eng = LsmEngine(keys, vals, agg="sum", delta=DELTA, capacity=256,
                    growth=2, background=False)
    _grow_ladder(eng, rng, keys[0], keys[-1], batches=3)
    eng.delete(keys[10:20])
    lsm, _ = eng.snapshot()
    leaves, treedef = jax.tree_util.tree_flatten(lsm)
    back = jax.tree_util.tree_unflatten(treedef, leaves)
    assert type(back) is type(lsm) and back.agg == lsm.agg
    assert [l.slot for l in back.levels] == [l.slot for l in lsm.levels]
    lq, uq = _ranges(rng, keys[0], keys[-1], m=9)
    np.testing.assert_array_equal(
        _np(execute_lsm(back, None, (lq, uq)).answer),
        _np(execute_lsm(lsm, None, (lq, uq)).answer))


# -- sharded ladders ------------------------------------------------------

def test_sharded_lsm_bit_identical(data):
    keys, vals = data
    rng = np.random.default_rng(9)
    eng = LsmEngine(keys, vals, agg="sum", delta=DELTA, capacity=256,
                    growth=2, background=False)
    _grow_ladder(eng, rng, keys[0], keys[-1], batches=3)
    eng.delete(keys[30:60])
    lsm, buf = eng.snapshot()
    lq, uq = _ranges(rng, keys[0], keys[-1], m=17)
    base = execute_lsm(lsm, buf, (lq, uq), backend="xla")
    for s in (1, 2, 4):
        if s > jax.device_count():
            continue
        sh = ShardedEngine(s)
        got = sh.query(lsm, lq, uq, buf=buf)
        np.testing.assert_array_equal(_np(got.answer), _np(base.answer))
        with pytest.raises(ValueError, match="Q_abs"):
            sh.query(lsm, lq, uq, eps_rel=0.05)


def test_sharded_lsm_2d_bit_identical():
    rng = np.random.default_rng(10)
    xs = rng.uniform(0.0, 100.0, 800)
    ys = rng.uniform(0.0, 100.0, 800)
    eng = LsmEngine2D(xs, ys, agg="count2d", delta=30.0, capacity=256,
                      growth=2, background=False)
    eng.insert(rng.uniform(0, 100, 256), rng.uniform(0, 100, 256))
    lsm, buf = eng.snapshot()
    lx, ux = _ranges(rng, 0.0, 100.0, m=9)
    ly, uy = _ranges(rng, 0.0, 100.0, m=9)
    base = execute_lsm(lsm, buf, (lx, ux, ly, uy), backend="xla")
    for s in (1, 2):
        if s > jax.device_count():
            continue
        sh = ShardedEngine2D(s)
        got = sh.query(lsm, lx, ux, ly, uy, buf=buf)
        np.testing.assert_array_equal(_np(got.answer), _np(base.answer))


# -- session facade + serving ---------------------------------------------

def test_session_lsm_table_and_serving_swap(data):
    from repro.api import PolyFit, QuerySpec, TableSpec
    from repro.api.budget import ErrorBudget
    from repro.serve import ServingEngine

    keys, vals = data
    pf = PolyFit.fit(
        {"t": (keys, vals)},
        {"t": TableSpec("sum", ErrorBudget(abs=100.0), dynamic=True,
                        lsm=True, capacity=256, background=False)})
    assert pf.is_lsm("t")
    swaps = []
    pf.on_plan_swap("t", lambda incoming: swaps.append(
        len(getattr(incoming, "levels", ()))))
    eng = ServingEngine(pf, workers=1)
    try:
        spec = QuerySpec.range("t", 100.0, 700.0)
        before = eng.query(spec, timeout=120)
        rng = np.random.default_rng(12)
        eng.insert("t", rng.uniform(keys[0], keys[-1], 256),
                   rng.uniform(0.5, 8.0, 256), wait=True)
        c0 = eng.stats.aot_compiles
        eng.flush("t")                   # forced compaction -> ladder swap
        assert swaps and swaps[-1] >= 1  # listener saw the preview ladder
        assert eng.stats.aot_precompiles > 0
        after = eng.query(spec, timeout=120)
        st = eng.stats
        assert st.aot_compiles == c0     # zero new compiles post-swap
        assert st.aot_promotions > 0
        sess = pf.query(spec)
        np.testing.assert_array_equal(_np(after.answer), _np(sess.answer))
        # the compaction folded the batch in: answers moved, bounds hold
        assert float(after.answer[0]) >= float(before.answer[0])
    finally:
        eng.shutdown()


def test_lsm_spec_requires_dynamic():
    from repro.api import TableSpec
    from repro.api.budget import ErrorBudget
    with pytest.raises(ValueError, match="dynamic"):
        TableSpec("sum", ErrorBudget(abs=1.0), lsm=True)


def test_policy_from_bench_has_costs():
    pol = CompactionPolicy.from_bench(dim=1)
    assert pol.merge_us_per_row > 0
    assert pol.should_compact(n_pending=512, capacity=512,
                              queries_since=0, rows_to_compact=512)


def test_shadow_fraction_folds_delete_only_workload(data):
    """Worst-case regression for the shadow-mass hole: a delete-only
    workload accumulates tombstones/victims on a level while the pending
    *insert* count stays zero, so neither the capacity watermark nor the
    cost model would ever fire.  The shadow-fraction trigger must fold the
    level anyway, and post-fold answers must be exact where refinement
    lands (COUNT integers)."""
    keys, _ = data
    pol = CompactionPolicy(query_overhead_us_per_row=0.0,
                           shadow_fraction=0.25)
    eng = LsmEngine(keys, agg="count", delta=DELTA, capacity=256,
                    background=False, policy=pol)
    # delete 40% of the rows in batches: well past shadow_fraction
    rng = np.random.default_rng(31)
    drop = rng.choice(len(keys), size=480, replace=False)
    for lo in range(0, len(drop), 120):
        eng.delete(keys[drop[lo:lo + 120]])
    assert eng.compaction_count >= 1
    assert not eng._shadow_slots()      # the fold consumed the shadow mass
    assert eng.n_pending == 0
    live = np.delete(keys, drop)
    lq, uq = _ranges(np.random.default_rng(37), 0.0, 1000.0)
    got = _np(eng.query(lq, uq, eps_rel=1e-9).answer)
    want = np.array([np.sum((live > a) & (live <= b))
                     for a, b in zip(lq, uq)], np.float64)
    np.testing.assert_array_equal(got, want)


def test_should_fold_thresholds():
    pol = CompactionPolicy(shadow_fraction=0.25)
    assert not pol.should_fold(shadow_rows=0, live_rows=100)
    assert not pol.should_fold(shadow_rows=24, live_rows=100)
    assert pol.should_fold(shadow_rows=25, live_rows=100)
    # fully-shadowed level (zero live rows) must fold, not divide by zero
    assert pol.should_fold(shadow_rows=10, live_rows=0)
