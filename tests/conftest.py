import numpy as np
import pytest

# NOTE: do NOT set XLA_FLAGS / device counts here — smoke tests and benches
# must see the single real CPU device (the 512-device override is scoped to
# launch/dryrun.py only, per the multi-pod dry-run contract).


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
