"""Chaos soak driver for the serving engine (nightly CI; not a pytest
module — run it directly):

    PYTHONPATH=src python tests/chaos_serve.py --duration 60

Drives a mixed read/write load against a ``ServingEngine`` while a
``FailureInjector`` crashes workers every ~40 admission batches, fails
1 in 200 dispatches transiently (retried in-engine), and kills the
updater every 7th fused apply — *recurring*, so the supervisor restarts
and the journal replays many times over the run.  Invariants held for
the whole soak:

* every read future resolves (answer or failure) — zero stranded;
* availability within one client retry stays >= 99%;
* the supervisor keeps worker/updater capacity at full strength;
* **exactly-once updates**: after the final drain, the whole-domain SUM
  equals the base sum plus everything inserted, within the certified
  bound — a lost journal suffix or a double-applied chunk (one 32-record
  chunk is worth ~6x the certified bound here) fails the run.

Exits non-zero (AssertionError) on any violation; prints a summary line
per ~5s plus a final report.
"""
from __future__ import annotations

import argparse
import sys
import threading
import time

import numpy as np


def run_soak(duration: float = 20.0, seed: int = 0x50AC,
             verbose: bool = True) -> dict:
    from repro.api import ErrorBudget, PolyFit, QuerySpec, TableSpec
    from repro.dist.fault_tolerance import (FailureInjector, RetryPolicy,
                                            SimulatedPodFailure)
    from repro.serve import ServingEngine

    say = print if verbose else (lambda *a, **k: None)
    rng = np.random.default_rng(seed)
    n = 20_000
    keys = np.sort(rng.uniform(0.0, 100.0, n))
    vals = rng.uniform(0.0, 10.0, n)
    base_sum = float(vals.sum())
    # rel=0.001 keeps the certified bound well under one insert chunk
    # (32 x 1000 = 32000), so the exactly-once check has teeth
    # capacity holds a short soak's full insert volume: applies stay
    # cheap (no synchronous merge per pack), so the updater drains — and
    # hits the serve.updater crash site — once per staged pack; longer
    # soaks overflow into merges, which is fine once crashes are rolling
    session = PolyFit.fit(
        {"sum": (keys, vals)},
        {"sum": TableSpec("sum", ErrorBudget(abs=50.0, rel=0.001),
                          dynamic=True, capacity=16384)},
        backend="ref")

    inj = (FailureInjector(seed=seed)
           .arm("serve.worker", nth=40)
           .arm("serve.dispatch", p=0.005)
           .arm("serve.updater", nth=5))
    pol = RetryPolicy(max_attempts=4, base=0.002, cap=0.02,
                      retry_on=(SimulatedPodFailure,))
    eng = ServingEngine(session, max_queue=512, workers=2, injector=inj,
                        retry=pol)
    eng.warmup(max_bucket=64)
    spec = QuerySpec.range("sum", 0.0, 100.0)

    counts = {"reads": 0, "ok": 0, "retried": 0, "failed": 0,
              "stranded": 0, "inserted": 0}
    lock = threading.Lock()
    stop = threading.Event()
    insert_total = [0.0]

    def writer():
        wrng = np.random.default_rng(seed + 1)
        while not stop.is_set():
            ks = wrng.uniform(0.0, 100.0, 32)
            try:
                eng.insert("sum", ks, np.full(32, 1000.0), wait=False)
            except RuntimeError:
                return
            with lock:
                insert_total[0] += 32 * 1000.0
                counts["inserted"] += 32
            stop.wait(0.03)

    def reader():
        while not stop.is_set():
            try:
                fut = eng.submit(spec, timeout=5.0)
            except RuntimeError:
                return
            with lock:
                counts["reads"] += 1
            try:
                fut.result(timeout=30.0)
            except SimulatedPodFailure:
                # client-side retry, as a deployment's client would
                with lock:
                    counts["retried"] += 1
                try:
                    eng.submit(spec, timeout=5.0).result(timeout=30.0)
                except SimulatedPodFailure:
                    with lock:
                        counts["failed"] += 1
                except TimeoutError:
                    with lock:
                        counts["stranded"] += 1
                else:
                    with lock:
                        counts["ok"] += 1
                continue
            except TimeoutError:
                with lock:
                    counts["stranded"] += 1
                continue
            with lock:
                counts["ok"] += 1
            time.sleep(0.002)

    threads = [threading.Thread(target=writer)] + [
        threading.Thread(target=reader) for _ in range(3)]
    t0 = time.monotonic()
    for t in threads:
        t.start()
    last = t0
    while time.monotonic() - t0 < duration:
        time.sleep(0.25)
        if time.monotonic() - last >= 5.0:
            last = time.monotonic()
            h = eng.health()
            with lock:
                snap = dict(counts)
            say(f"[soak t={last - t0:5.1f}s] reads={snap['reads']} "
                f"ok={snap['ok']} retried={snap['retried']} "
                f"failed={snap['failed']} "
                f"crashes={h['worker_crashes']}+{h['updater_crashes']} "
                f"restarts={h['restarts']} staged={h['staged_depth']}")
    stop.set()
    for t in threads:
        t.join(60)

    # final settle: disarm, replay whatever is left, verify exactly-once
    inj.disarm("serve.updater")
    inj.disarm("serve.worker")
    inj.disarm("serve.dispatch")
    eng.drain_updates()
    final = float(eng.query(spec, timeout=120.0).answer[0])
    expect = base_sum + insert_total[0]
    tol = 50.0 + 0.002 * abs(expect)
    st = eng.stats
    health = eng.health()
    eng.shutdown()

    avail = counts["ok"] / max(counts["reads"], 1)
    report = {**counts, "availability": avail,
              "worker_crashes": st.worker_crashes,
              "updater_crashes": st.updater_crashes,
              "restarts": st.restarts,
              "journal_replayed": st.journal_replayed,
              "sum_error": final - expect, "sum_tol": tol}
    say(f"[soak] done: {report}")
    assert counts["stranded"] == 0, report
    assert avail >= 0.99, report
    assert st.worker_crashes >= 1 and st.updater_crashes >= 1, report
    assert st.journal_replayed >= 1, report
    assert st.restarts >= st.worker_crashes + st.updater_crashes - 2, report
    assert health["workers_alive"] == 2, report
    assert abs(final - expect) <= tol, report
    return report


def main():
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--duration", type=float, default=20.0,
                   help="soak length in seconds (nightly uses 60+)")
    p.add_argument("--seed", type=int, default=0x50AC)
    args = p.parse_args()
    run_soak(duration=args.duration, seed=args.seed)


if __name__ == "__main__":
    sys.exit(main())
