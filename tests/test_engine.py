"""Engine integration: every backend must answer every query type within
the paper's certified error bounds, and the backends must agree with each
other (and with the core reference path) on identical query batches."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from repro.core import (ExactMax, ExactSum, build_index_1d,  # noqa: E402
                        build_index_2d, query_count_2d, query_max, query_sum)
from repro.engine import (BACKENDS, Engine, build_plan,  # noqa: E402
                          build_plan_2d)

N = 3000
NQ = 400
DELTA = 25.0


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(7)
    keys = np.sort(rng.uniform(0, 800, N))
    meas = rng.uniform(0, 10, N)
    return keys, meas


@pytest.fixture(scope="module")
def queries(data):
    keys, _ = data
    rng = np.random.default_rng(11)
    a = keys[rng.integers(0, N, NQ)]
    b = keys[rng.integers(0, N, NQ)]
    return np.minimum(a, b), np.maximum(a, b)


@pytest.fixture(scope="module")
def plans(data):
    keys, meas = data
    out = {}
    for agg, m, deg in (("sum", meas, 2), ("count", None, 2),
                        ("max", meas * 100, 3), ("min", meas * 100, 3)):
        idx = build_index_1d(keys, m, agg, deg=deg, delta=DELTA)
        out[agg] = (idx, build_plan(idx))
    return out


@pytest.fixture(scope="module")
def plan2d():
    rng = np.random.default_rng(13)
    px = rng.uniform(0, 120, 5000)
    py = rng.uniform(0, 120, 5000)
    idx = build_index_2d(px, py, deg=2, delta=DELTA, max_depth=6)
    qa = rng.uniform(0, 120, 256)
    qb = qa + rng.uniform(0.5, 40, 256)
    qc = rng.uniform(0, 120, 256)
    qd = qc + rng.uniform(0.5, 40, 256)
    return px, py, idx, build_plan_2d(idx), (qa, qb, qc, qd)


def _truth_1d(agg, keys, meas, lq, uq):
    if agg in ("sum", "count"):
        m = np.ones_like(keys) if agg == "count" else meas
        ex = ExactSum.build(keys, m)
        return np.asarray(ex.cf_at(jnp.asarray(uq)) - ex.cf_at(jnp.asarray(lq)))
    sgn = -1.0 if agg == "min" else 1.0
    ex = ExactMax.build(keys, sgn * meas)
    return sgn * np.asarray(ex.query(jnp.asarray(lq), jnp.asarray(uq)))


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("agg", ["sum", "count", "max", "min"])
def test_certified_bounds_1d(plans, data, queries, agg, backend):
    """Lemma 5.1/5.3: every backend's raw answer obeys the Q_abs bound."""
    keys, meas = data
    lq, uq = queries
    _, plan = plans[agg]
    res = Engine(backend=backend).query(plan, lq, uq)
    truth = _truth_1d(agg, keys, meas * 100 if agg in ("max", "min") else meas,
                      lq, uq)
    bound = 2 * DELTA if agg in ("sum", "count") else DELTA
    assert np.max(np.abs(np.asarray(res.answer) - truth)) <= bound + 1e-6


@pytest.mark.parametrize("agg", ["sum", "count", "max", "min"])
def test_cross_backend_equivalence_1d(plans, queries, agg):
    """All three backends produce identical f64 answers (and match core)."""
    idx, plan = plans[agg]
    lq, uq = queries
    outs = {b: np.asarray(Engine(backend=b).query(plan, lq, uq).answer)
            for b in BACKENDS}
    for b in ("pallas", "ref"):
        np.testing.assert_allclose(outs[b], outs["xla"], rtol=1e-9, atol=1e-9)
    qfn = query_sum if agg in ("sum", "count") else query_max
    core = np.asarray(qfn(idx, lq, uq).answer)
    np.testing.assert_allclose(outs["xla"], core, rtol=1e-9, atol=1e-9)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("agg", ["sum", "max"])
def test_fused_qrel_refinement(plans, data, queries, agg, backend):
    """Lemma 5.2/5.4 + in-path refinement: final answers satisfy eps_rel."""
    keys, meas = data
    lq, uq = queries
    _, plan = plans[agg]
    eps_rel = 0.05
    res = Engine(backend=backend).query(plan, lq, uq, eps_rel=eps_rel)
    truth = _truth_1d(agg, keys, meas * 100 if agg == "max" else meas, lq, uq)
    ans = np.asarray(res.answer)
    pos = np.abs(truth) > 0
    rel = np.abs(ans[pos] - truth[pos]) / np.abs(truth[pos])
    assert rel.max() <= eps_rel + 1e-9
    # the index must stay useful: refinement cannot fire on every query
    assert np.asarray(res.refined).mean() < 1.0


@pytest.mark.parametrize("backend", BACKENDS)
def test_certified_bounds_2d(plan2d, backend):
    """Lemma 6.3: 2-key COUNT within 4*delta on every backend."""
    px, py, idx, plan, (qa, qb, qc, qd) = plan2d
    res = Engine(backend=backend).query(plan, qa, qb, qc, qd)
    truth = np.asarray(idx.exact.cf(qb, qd) - idx.exact.cf(qa, qd)
                       - idx.exact.cf(qb, qc) + idx.exact.cf(qa, qc))
    assert np.max(np.abs(np.asarray(res.answer) - truth)) <= 4 * DELTA + 1e-6


def test_cross_backend_equivalence_2d(plan2d):
    px, py, idx, plan, (qa, qb, qc, qd) = plan2d
    outs = {b: np.asarray(Engine(backend=b).count2d(plan, qa, qb, qc, qd).answer)
            for b in BACKENDS}
    for b in ("pallas", "ref"):
        np.testing.assert_allclose(outs[b], outs["xla"], rtol=1e-9, atol=1e-9)
    core = np.asarray(query_count_2d(idx, qa, qb, qc, qd).answer)
    np.testing.assert_allclose(outs["xla"], core, rtol=1e-9, atol=1e-9)


def test_qrel_2d_fused(plan2d):
    px, py, idx, plan, (qa, qb, qc, qd) = plan2d
    eps_rel = 0.05
    res = Engine(backend="ref").count2d(plan, qa, qb, qc, qd, eps_rel=eps_rel)
    truth = np.asarray(idx.exact.cf(qb, qd) - idx.exact.cf(qa, qd)
                       - idx.exact.cf(qb, qc) + idx.exact.cf(qa, qc))
    ans = np.asarray(res.answer)
    pos = truth > 0
    rel = np.abs(ans[pos] - truth[pos]) / truth[pos]
    assert rel.max() <= eps_rel + 1e-9


@pytest.mark.parametrize("nq", [3, 64, 130, 700])
def test_batch_bucketing_consistency(plans, data, nq):
    """Padding to power-of-two buckets must not change any answer."""
    keys, meas = data
    rng = np.random.default_rng(nq)
    a = keys[rng.integers(0, N, nq)]
    b = keys[rng.integers(0, N, nq)]
    lq, uq = np.minimum(a, b), np.maximum(a, b)
    _, plan = plans["sum"]
    eng = Engine(backend="pallas")
    got = np.asarray(eng.sum(plan, lq, uq).answer)
    assert got.shape == (nq,)
    ref = np.asarray(Engine(backend="xla").sum(plan, lq, uq).answer)
    np.testing.assert_allclose(got, ref, rtol=1e-9, atol=1e-9)


def test_pallas_deg4_max_falls_back(data, queries):
    """deg-4 MAX has no in-kernel closed form; the engine must still answer
    within the certified bound on the pallas backend (XLA fallback)."""
    keys, meas = data
    idx = build_index_1d(keys, meas * 100, "max", deg=4, delta=DELTA)
    plan = build_plan(idx)
    lq, uq = queries
    res = Engine(backend="pallas").extremum(plan, lq, uq)
    truth = _truth_1d("max", keys, meas * 100, lq, uq)
    assert np.max(np.abs(np.asarray(res.answer) - truth)) <= DELTA + 1e-6


def test_refinement_requires_exact_arrays(data):
    keys, meas = data
    idx = build_index_1d(keys, meas, "sum", deg=2, delta=DELTA,
                         keep_exact=False)
    plan = build_plan(idx)
    with pytest.raises(ValueError, match="refinement"):
        Engine().sum(plan, keys[:4], keys[-4:], eps_rel=0.01)


def test_serve_step_routes_through_engine(plans, queries):
    from repro.serve.step import make_aggregate_step
    _, plan = plans["count"]
    lq, uq = queries
    step = make_aggregate_step(Engine(backend="ref"), plan, eps_rel=0.05)
    res = step(lq, uq)
    assert res.answer.shape == (NQ,)
    assert np.asarray(res.refined).mean() < 1.0


# ---------------------------------------------------------------------------
# 2-D measure aggregates (DESIGN.md §12): SUM over rectangles, dominance
# MAX/MIN — every backend agrees and stays within the certified bound
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def plans2d_measure():
    rng = np.random.default_rng(17)
    px = rng.uniform(0, 120, 4000)
    py = rng.uniform(0, 120, 4000)
    w = 50 + 10 * np.sin(px / 10) + 10 * np.cos(py / 15)
    out = {}
    for agg, delta in (("sum2d", 400.0), ("max2d", 4.0), ("min2d", 4.0)):
        idx = build_index_2d(px, py, measures=w, agg=agg, deg=2,
                             delta=delta, max_depth=7)
        out[agg] = (idx, build_plan_2d(idx))
    rect = (rng.uniform(0, 95, 256), None, rng.uniform(0, 95, 256), None)
    rect = (rect[0], rect[0] + rng.uniform(2, 25, 256),
            rect[2], rect[2] + rng.uniform(2, 25, 256))
    ci = rng.integers(0, 4000, 256)   # anchored at data points, so every
    corners = (px[ci], py[ci])        # corner dominates at least one record
    return px, py, w, out, rect, corners


@pytest.mark.parametrize("backend", BACKENDS)
def test_certified_bounds_sum2d(plans2d_measure, backend):
    px, py, w, plans, rect, _ = plans2d_measure
    idx, plan = plans["sum2d"]
    res = Engine(backend=backend).sum2d(plan, *rect)
    la, ua, lb, ub = rect
    truth = np.array([
        w[(px > a) & (px <= b) & (py > c) & (py <= d)].sum()
        for a, b, c, d in zip(la, ua, lb, ub)])
    assert np.abs(np.asarray(res.answer) - truth).max() \
        <= 4 * idx.certified_delta + 1e-6


@pytest.mark.parametrize("agg", ["max2d", "min2d"])
@pytest.mark.parametrize("backend", BACKENDS)
def test_certified_bounds_dommax2d(plans2d_measure, agg, backend):
    px, py, w, plans, _, corners = plans2d_measure
    idx, plan = plans[agg]
    u, v = corners
    res = Engine(backend=backend).extremum2d(plan, u, v)
    dom = (px[None, :] <= u[:, None]) & (py[None, :] <= v[:, None])
    red = np.max if agg == "max2d" else np.min
    truth = np.array([red(w[d]) for d in dom])
    assert np.abs(np.asarray(res.answer) - truth).max() \
        <= idx.certified_delta + 1e-6


@pytest.mark.parametrize("agg", ["sum2d", "max2d", "min2d"])
def test_cross_backend_equivalence_2d_measures(plans2d_measure, agg):
    """All four backends agree bitwise on the 2-D measure aggregates (the
    locate->gather, one-hot scan, jnp oracle and descent paths share one
    leaf rule and one Horner sequence)."""
    px, py, w, plans, rect, corners = plans2d_measure
    _, plan = plans[agg]
    ranges = rect if agg == "sum2d" else corners
    outs = {b: np.asarray(Engine(backend=b).query(plan, *ranges).answer)
            for b in BACKENDS}
    for b in BACKENDS[1:]:
        np.testing.assert_array_equal(outs[b], outs["xla"], err_msg=b)


@pytest.mark.parametrize("agg", ["sum2d", "max2d"])
def test_qrel_2d_measures_fused(plans2d_measure, agg):
    px, py, w, plans, rect, corners = plans2d_measure
    idx, plan = plans[agg]
    eps_rel = 0.05
    if agg == "sum2d":
        la, ua, lb, ub = rect
        res = Engine(backend="ref").sum2d(plan, *rect, eps_rel=eps_rel)
        truth = np.array([
            w[(px > a) & (px <= b) & (py > c) & (py <= d)].sum()
            for a, b, c, d in zip(la, ua, lb, ub)])
    else:
        u, v = corners
        res = Engine(backend="pallas").extremum2d(plan, u, v,
                                                  eps_rel=eps_rel)
        dom = (px[None, :] <= u[:, None]) & (py[None, :] <= v[:, None])
        truth = np.array([w[d].max() for d in dom])
    ans = np.asarray(res.answer)
    pos = np.abs(truth) > 0
    rel = np.abs(ans[pos] - truth[pos]) / np.abs(truth[pos])
    assert rel.max() <= eps_rel + 1e-9


def test_execute_dispatch_2d_aggs(plans2d_measure):
    """`execute` routes IndexPlan2D by its agg; mismatched executors
    refuse the plan."""
    from repro.engine import execute, execute_count2d, execute_sum2d
    _, _, _, plans, rect, corners = plans2d_measure
    _, plan_s = plans["sum2d"]
    _, plan_m = plans["max2d"]
    r1 = execute(plan_s, rect, backend="ref")
    r2 = execute_sum2d(plan_s, *rect, backend="ref")
    np.testing.assert_array_equal(np.asarray(r1.answer),
                                  np.asarray(r2.answer))
    r3 = execute(plan_m, corners, backend="ref")
    assert r3.answer.shape == corners[0].shape
    with pytest.raises(AssertionError):
        execute_count2d(plan_s, *rect)
