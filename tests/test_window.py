"""Epoch-ring windowed aggregates: window answers must be *bit-identical*
to a flat plan fitted over the concatenated epoch data (integer measures +
a tiny eps_rel force exact refinement on both paths, so the f64 sums are
exact integers), bounds compose over the selected epochs only, and
eviction below the ring raises.
"""
import numpy as np
import pytest
import jax

jax.config.update("jax_enable_x64", True)

from repro.core import build_index_1d                      # noqa: E402
from repro.engine import WindowEngine, build_plan, execute  # noqa: E402

DELTA = 16.0
EPS = 1e-9          # forces refinement -> exact integer answers


def _epochs(seed=13, n_epochs=5, rows=300):
    rng = np.random.default_rng(seed)
    return [np.round(rng.uniform(-100, 100, rows), 3)
            for _ in range(n_epochs)]


def _flat_answer(data, lq, uq):
    keys = np.sort(np.concatenate(data))
    idx = build_index_1d(keys, np.ones_like(keys), agg="count",
                         delta=DELTA, deg=2, keep_exact=True)
    res = execute(build_plan(idx), (jnp_arr(lq), jnp_arr(uq)),
                  backend="xla", eps_rel=EPS)
    return np.asarray(res.answer)


def jnp_arr(x):
    import jax.numpy as jnp
    return jnp.asarray(np.atleast_1d(np.asarray(x, np.float64)))


@pytest.fixture(scope="module")
def ring():
    eps = _epochs()
    w = WindowEngine(eps[0], agg="count", delta=DELTA, deg=2, ring=8,
                     capacity=1024)
    for e in eps[1:4]:
        w.ingest(e)
        w.advance()
    w.ingest(eps[4])          # epoch 4 stays open
    return w, eps


def test_window_bit_identical_to_flat_plan(ring):
    w, eps = ring
    rng = np.random.default_rng(17)
    lq = rng.uniform(-100, 80, 32)
    uq = lq + rng.uniform(1, 40, 32)
    for t0, t1 in [(0, 4), (0, 0), (1, 3), (2, 4), (4, 4), (3, 3)]:
        got = np.asarray(w.query(lq, uq, t0, t1, eps_rel=EPS).answer)
        want = _flat_answer(eps[t0:t1 + 1], lq, uq)
        np.testing.assert_array_equal(got, want), (t0, t1)


def test_open_epoch_only_is_exact(ring):
    w, eps = ring
    res = w.query(np.array([-100.0]), np.array([100.0]), 4, 4)
    assert float(res.answer[0]) == len(eps[4])
    assert w.bound(4, 4) == 0.0     # buffer correction is exact


def test_bound_composes_over_selected_epochs(ring):
    w, _ = ring
    b1 = w.bound(0, 0)
    b3 = w.bound(0, 2)
    assert b1 > 0.0 and b3 == pytest.approx(3 * b1)
    # answers honor the composed bound without refinement
    lq, uq = np.array([-60.0]), np.array([60.0])
    for t0, t1 in [(0, 2), (0, 4)]:
        got = float(w.query(lq, uq, t0, t1).answer[0])
        want = float(_flat_answer(w_eps_slice(w, t0, t1), lq, uq)[0])
        assert abs(got - want) <= w.bound(t0, t1) + 1e-9


def w_eps_slice(w, t0, t1):
    # reconstruct the rows the ring holds for [t0, t1]
    out = []
    for eid, lvl in w._ring:
        if t0 <= eid <= t1 and lvl is not None:
            out.append(np.asarray(lvl.plan.ref_keys))
    if t0 <= w.epoch <= t1 and w._n_buf:
        out.append(np.concatenate([p[0] for p in w._pend]))
    return out


def test_empty_and_evicted_windows():
    w = WindowEngine(ring=2, agg="count", delta=DELTA, capacity=64)
    w.ingest(np.array([1.0, 2.0]))
    w.advance()                     # seals epoch 0
    w.advance()                     # seals an empty epoch 1 (hole)
    w.ingest(np.array([3.0]))
    w.advance()                     # seals epoch 2; ring keeps {1, 2}
    assert w.oldest == 1
    with pytest.raises(ValueError, match="evicted"):
        w.query(np.array([0.0]), np.array([5.0]), 0, 2)
    with pytest.raises(ValueError, match="empty window"):
        w.query(np.array([0.0]), np.array([5.0]), 2, 1)
    # hole-only window: zero rows, zero bound
    res = w.query(np.array([0.0]), np.array([5.0]), 1, 1)
    assert float(res.answer[0]) == 0.0
    assert w.bound(1, 1) == 0.0
    # retained epoch answers exactly
    res = w.query(np.array([0.0]), np.array([5.0]), 2, 2)
    assert float(res.answer[0]) == 1.0


def test_sum_ring_matches_flat_plan():
    rng = np.random.default_rng(23)
    eps = [rng.uniform(0, 50, 200) for _ in range(3)]
    vals = [np.round(rng.uniform(1, 5, 200)) for _ in range(3)]
    w = WindowEngine(eps[0], vals[0], agg="sum", delta=DELTA, ring=4,
                     capacity=512)
    w.ingest(eps[1], vals[1])
    w.advance()
    w.ingest(eps[2], vals[2])
    lq = np.array([5.0, 20.0])
    uq = np.array([30.0, 45.0])
    got = np.asarray(w.query(lq, uq, 0, 2, eps_rel=EPS).answer)
    keys = np.concatenate(eps)
    meas = np.concatenate(vals)
    order = np.argsort(keys, kind="stable")
    idx = build_index_1d(keys[order], meas[order], agg="sum", delta=DELTA,
                         deg=2, keep_exact=True)
    want = np.asarray(execute(build_plan(idx), (jnp_arr(lq), jnp_arr(uq)),
                              backend="xla", eps_rel=EPS).answer)
    np.testing.assert_array_equal(got, want)


def test_capacity_overflow_names_advance():
    w = WindowEngine(ring=2, agg="count", delta=DELTA, capacity=64)
    w.ingest(np.zeros(60))
    with pytest.raises(ValueError, match="advance"):
        w.ingest(np.zeros(10))
