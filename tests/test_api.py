"""Declarative API: ErrorBudget delta derivations, QuerySpec/QueryBatch
semantics, the PolyFit session facade (mixed batches answered in request
order), and bit-identical equivalence between the legacy Engine surface and
the new dispatch path on every backend."""
import numpy as np
import pytest
import jax

jax.config.update("jax_enable_x64", True)

from repro.api import (DEFAULT_REL, ErrorBudget, PolyFit,  # noqa: E402
                       QueryBatch, QuerySpec, TableSpec)
from repro.core import build_index_1d, build_index_2d  # noqa: E402
from repro.engine import (BACKENDS, Engine, build_plan,  # noqa: E402
                          build_plan_2d)

N = 3000
DELTA = 25.0
EPS_ABS = 2 * DELTA          # so budget-derived sum/count deltas equal DELTA


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(7)
    keys = np.sort(rng.uniform(0, 800, N))
    meas = rng.uniform(0, 10, N)
    px = rng.uniform(0, 120, 4000)
    py = rng.uniform(0, 120, 4000)
    return keys, meas, px, py


@pytest.fixture(scope="module")
def queries(data):
    keys, _, px, py = data
    rng = np.random.default_rng(11)
    a = keys[rng.integers(0, N, 200)]
    b = keys[rng.integers(0, N, 200)]
    qa = rng.uniform(0, 100, 64)
    qc = rng.uniform(0, 100, 64)
    return (np.minimum(a, b), np.maximum(a, b),
            qa, qa + rng.uniform(1, 30, 64), qc, qc + rng.uniform(1, 30, 64))


def _session(data, backend="xla", rel=0.05, **tweaks):
    keys, meas, px, py = data
    budget = ErrorBudget(abs=2 * DELTA, rel=rel)
    bmax = ErrorBudget(abs=DELTA, rel=rel)
    b2d = ErrorBudget(abs=4 * DELTA, rel=rel)
    return PolyFit.fit(
        {"cnt": keys, "sm": (keys, meas), "mx": (keys, meas * 100),
         "mn": (keys, meas * 100), "geo": (px, py)},
        {"cnt": TableSpec("count", budget, **tweaks),
         "sm": TableSpec("sum", budget, **tweaks),
         "mx": TableSpec("max", bmax, **tweaks),
         "mn": TableSpec("min", bmax, **tweaks),
         "geo": TableSpec("count2d", b2d)},
        backend=backend)


# ---------------------------------------------------------------------------
# ErrorBudget: the Lemma 5.1/5.3/6.3 derivations live in exactly one place
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("agg,frac", [("sum", 0.5), ("count", 0.5),
                                      ("max", 1.0), ("min", 1.0),
                                      ("count2d", 0.25)])
def test_budget_delta_derivation(agg, frac):
    b = ErrorBudget(abs=100.0, rel=0.01)
    assert b.delta(agg) == pytest.approx(100.0 * frac)
    assert b.bound(agg) == pytest.approx(100.0)   # round-trips to eps_abs
    assert ErrorBudget.from_delta(b.delta(agg), agg).abs == pytest.approx(100.0)


def test_budget_validation():
    with pytest.raises(ValueError, match="abs"):
        ErrorBudget(abs=0.0)
    with pytest.raises(ValueError, match="rel"):
        ErrorBudget(abs=1.0, rel=-0.5)
    with pytest.raises(ValueError, match="aggregate"):
        ErrorBudget(abs=1.0).delta("median")


def test_spec_validation(data):
    session = _session(data)
    with pytest.raises(KeyError, match="unknown table"):
        session.query(QuerySpec.range("nope", 0.0, 1.0))
    with pytest.raises(ValueError, match="range coordinates"):
        session.query(QuerySpec.range("geo", 0.0, 1.0))
    with pytest.raises(ValueError, match="lengths differ"):
        QuerySpec("cnt", (np.zeros(3), np.zeros(4)))
    with pytest.raises(ValueError, match="1-D"):
        QuerySpec("cnt", (1.0, 2.0, 3.0))
    with pytest.raises(ValueError, match="unknown aggregate"):
        TableSpec("median2d", ErrorBudget(abs=1.0))
    # 2-D sharding landed (engine/sharded.py z-range partitioning): the
    # old "1-D only" rejection is gone
    assert TableSpec("count2d", ErrorBudget(abs=1.0), shards=2).shards == 2


# ---------------------------------------------------------------------------
# mixed batches: request-order scatter across aggregates and dimensions
# ---------------------------------------------------------------------------

def test_mixed_batch_request_order(data, queries):
    """A batch interleaving sum/max/count2d/count (twice, with a per-spec
    guarantee override) answers each spec exactly like a per-kind call."""
    lq, uq, qa, qb, qc, qd = queries
    session = _session(data)
    batch = QueryBatch.of(
        QuerySpec.range("sm", lq[:100], uq[:100]),
        QuerySpec.rect("geo", qa, qb, qc, qd),
        QuerySpec.range("mx", lq, uq),
        QuerySpec.range("cnt", lq[100:], uq[100:], rel=None),
        QuerySpec.range("sm", lq[100:], uq[100:]),
        QuerySpec.range("mn", lq, uq),
    )
    assert batch.n_queries == 100 + 64 + 200 + 100 + 100 + 200
    results = session.query(batch)
    assert len(results) == 6
    singles = [session.query(s) for s in batch]
    for got, want, spec in zip(results, singles, batch):
        assert got.answer.shape[0] == len(spec)
        np.testing.assert_array_equal(np.asarray(got.answer),
                                      np.asarray(want.answer))
        np.testing.assert_array_equal(np.asarray(got.refined),
                                      np.asarray(want.refined))


def test_scalar_specs_and_empty_batch(data):
    session = _session(data)
    res = session.query(QuerySpec.range("cnt", 100.0, 300.0))
    assert res.answer.shape == (1,)
    assert session.query(QueryBatch.of()) == []


def test_batch_pytree_roundtrip(data, queries):
    lq, uq, *_ = queries
    batch = QueryBatch.of(QuerySpec.range("cnt", lq, uq, rel=None),
                          QuerySpec.range("mx", lq, uq))
    leaves, treedef = jax.tree.flatten(batch)
    rebuilt = jax.tree.unflatten(treedef, leaves)
    assert rebuilt[0].table == "cnt" and rebuilt[0].rel is None
    assert rebuilt[1].rel is DEFAULT_REL
    np.testing.assert_array_equal(rebuilt[0].ranges[0], lq)


# ---------------------------------------------------------------------------
# old-vs-new equivalence: Engine shims and the session hit the same
# executors bit for bit, on every backend
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_engine_session_bit_identical(data, queries, backend):
    keys, meas, px, py = data
    lq, uq, qa, qb, qc, qd = queries
    session = _session(data, backend=backend)
    eng = Engine(backend=backend)
    cases_1d = {
        "cnt": build_plan(build_index_1d(keys, None, "count", deg=2,
                                         delta=DELTA)),
        "sm": build_plan(build_index_1d(keys, meas, "sum", deg=2,
                                        delta=DELTA)),
        "mx": build_plan(build_index_1d(keys, meas * 100, "max", deg=3,
                                        delta=DELTA)),
        "mn": build_plan(build_index_1d(keys, meas * 100, "min", deg=3,
                                        delta=DELTA)),
    }
    for eps_rel in (None, 0.05):
        for name, plan in cases_1d.items():
            old = eng.query(plan, lq, uq, eps_rel=eps_rel)
            new = session.query(QuerySpec.range(name, lq, uq, rel=eps_rel))
            np.testing.assert_array_equal(np.asarray(old.answer),
                                          np.asarray(new.answer))
            np.testing.assert_array_equal(np.asarray(old.approx),
                                          np.asarray(new.approx))
            np.testing.assert_array_equal(np.asarray(old.refined),
                                          np.asarray(new.refined))
        plan2 = build_plan_2d(build_index_2d(px, py, deg=3, delta=DELTA))
        old = eng.count2d(plan2, qa, qb, qc, qd, eps_rel=eps_rel)
        new = session.query(QuerySpec.rect("geo", qa, qb, qc, qd,
                                           rel=eps_rel))
        np.testing.assert_array_equal(np.asarray(old.answer),
                                      np.asarray(new.answer))


def test_engine_methods_are_shims(data, queries):
    """Engine.sum/extremum/count2d must route through the module-level
    dispatch functions (one code path for old and new callers)."""
    from repro.engine import execute_extremum, execute_sum
    keys, meas, *_ = data
    lq, uq, *_ = queries
    plan = build_plan(build_index_1d(keys, meas, "sum", deg=2, delta=DELTA))
    a = Engine(backend="ref").sum(plan, lq, uq)
    via = execute_sum(plan, lq, uq, backend="ref")
    np.testing.assert_array_equal(np.asarray(a.answer),
                                  np.asarray(via.answer))
    planm = build_plan(build_index_1d(keys, meas, "max", deg=3, delta=DELTA))
    b = Engine(backend="ref").extremum(planm, lq, uq)
    vib = execute_extremum(planm, lq, uq, backend="ref")
    np.testing.assert_array_equal(np.asarray(b.answer),
                                  np.asarray(vib.answer))


# ---------------------------------------------------------------------------
# guarantees + dynamic tables through the facade
# ---------------------------------------------------------------------------

def test_session_certified_bounds(data, queries):
    """Budget-declared Q_abs bounds hold end to end through the facade."""
    keys, meas, *_ = data
    lq, uq, *_ = queries
    session = _session(data, rel=None)
    truth = _exact_sum(keys, meas, lq, uq)
    got = np.asarray(session.query(QuerySpec.range("sm", lq, uq)).answer)
    assert np.max(np.abs(got - truth)) <= session.budget("sm").bound("sum") + 1e-6


def test_session_qrel_refinement(data, queries):
    keys, meas, *_ = data
    lq, uq, *_ = queries
    session = _session(data, rel=0.05)
    truth = _exact_sum(keys, meas, lq, uq)
    res = session.query(QuerySpec.range("sm", lq, uq))
    ans = np.asarray(res.answer)
    pos = np.abs(truth) > 0
    assert (np.abs(ans[pos] - truth[pos]) / np.abs(truth[pos])).max() <= 0.05 + 1e-9
    assert np.asarray(res.refined).mean() < 1.0


def test_dynamic_session_updates(data):
    keys, meas, *_ = data
    budget = ErrorBudget(abs=2 * DELTA)
    session = PolyFit.fit(
        {"cnt": keys}, {"cnt": TableSpec("count", budget, dynamic=True,
                                         capacity=128, background=False,
                                         auto_refit=False)})
    lq = np.full(8, keys[0] - 1.0)
    uq = np.full(8, keys[-1] + 1.0)
    base = float(np.asarray(session.query(
        QuerySpec.range("cnt", lq, uq)).answer)[0])
    session.insert("cnt", np.linspace(keys[0], keys[-1], 32))
    upd = float(np.asarray(session.query(
        QuerySpec.range("cnt", lq, uq)).answer)[0])
    assert abs(upd - (base + 32)) < 1e-6
    session.delete("cnt", keys[:4])
    del_upd = float(np.asarray(session.query(
        QuerySpec.range("cnt", lq, uq)).answer)[0])
    assert abs(del_upd - (upd - 4)) < 1e-6
    session.flush()
    post = float(np.asarray(session.query(
        QuerySpec.range("cnt", lq, uq)).answer)[0])
    assert abs(post - del_upd) <= 2 * DELTA + 1e-6
    with pytest.raises(RuntimeError, match="static"):
        _session(data).insert("cnt", [1.0])


def _exact_sum(keys, meas, lq, uq):
    cf = np.cumsum(meas)
    p = np.concatenate([[0.0], cf])
    return (p[np.searchsorted(keys, uq, side="right")]
            - p[np.searchsorted(keys, lq, side="right")])


# ---------------------------------------------------------------------------
# 2-D measure aggregates through the facade (DESIGN.md §12)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("agg,frac", [("sum2d", 0.25), ("max2d", 1.0),
                                      ("min2d", 1.0)])
def test_budget_delta_derivation_2d_measures(agg, frac):
    b = ErrorBudget(abs=100.0, rel=0.01)
    assert b.delta(agg) == pytest.approx(100.0 * frac)
    assert b.bound(agg) == pytest.approx(100.0)


def test_session_2d_measures_mixed_batch(data):
    """A batch mixing 1-D COUNT, 2-D SUM rectangles and dominance MAX/MIN
    corners: answers preserve request order, hold certified bounds, and
    updates flow through insert/delete/flush."""
    keys, meas, px, py = data
    w = 50 + 10 * np.sin(px / 10) + 10 * np.cos(py / 15)
    session = PolyFit.fit(
        {"cnt": keys, "spend": (px, py, w), "peak": (px, py, w),
         "low": (px, py, w)},
        {"cnt": TableSpec("count", ErrorBudget(abs=2 * DELTA)),
         "spend": TableSpec("sum2d", ErrorBudget(abs=1600.0), deg=2,
                            dynamic=True, background=False, capacity=64),
         "peak": TableSpec("max2d", ErrorBudget(abs=4.0), deg=2),
         "low": TableSpec("min2d", ErrorBudget(abs=4.0), deg=2)})
    assert session.spec("spend").degree == 2

    rng = np.random.default_rng(19)
    lx = rng.uniform(0, 95, 48)
    ux = lx + rng.uniform(2, 25, 48)
    ly = rng.uniform(0, 95, 48)
    uy = ly + rng.uniform(2, 25, 48)
    ci = rng.integers(0, len(px), 48)
    cu, cv = px[ci], py[ci]
    out = session.query(QueryBatch.of(
        QuerySpec.corner("peak", cu, cv),
        QuerySpec.rect("spend", lx, ux, ly, uy),
        QuerySpec.corner("low", cu, cv),
        QuerySpec.range("cnt", keys[10], keys[-10])))
    assert len(out) == 4

    dom = (px[None, :] <= cu[:, None]) & (py[None, :] <= cv[:, None])
    truth_max = np.array([w[d].max() for d in dom])
    truth_min = np.array([w[d].min() for d in dom])
    truth_sum = np.array([
        w[(px > a) & (px <= b) & (py > c) & (py <= d)].sum()
        for a, b, c, d in zip(lx, ux, ly, uy)])
    assert np.abs(np.asarray(out[0].answer) - truth_max).max() <= 4.0 + 1e-6
    assert np.abs(np.asarray(out[1].answer) - truth_sum).max() \
        <= 1600.0 + 1e-6
    assert np.abs(np.asarray(out[2].answer) - truth_min).max() <= 4.0 + 1e-6

    # dynamic updates on the sum2d table flow through the facade
    session.insert("spend", [50.0], [50.0], [25.0])
    rect1 = (np.array([40.0]), np.array([60.0]),
             np.array([40.0]), np.array([60.0]))
    before = float(np.asarray(
        session.query(QuerySpec.rect("spend", *rect1)).answer)[0])
    session.delete("spend", [50.0], [50.0])
    after = float(np.asarray(
        session.query(QuerySpec.rect("spend", *rect1)).answer)[0])
    assert before - after == pytest.approx(25.0)
    session.flush("spend")
    assert session._table("spend").dyn.refit_count >= 1


def test_session_2d_measure_data_validation(data):
    keys, meas, px, py = data
    with pytest.raises(ValueError, match="must be"):
        PolyFit.fit({"s": (px, py)},
                    {"s": TableSpec("sum2d", ErrorBudget(abs=100.0))})


# ---------------------------------------------------------------------------
# kind-explicit query surface: shim equivalence, Answer pytree, quantiles
# ---------------------------------------------------------------------------

def test_kind_shim_bit_identical_to_legacy(data, queries):
    """Legacy kind-less constructors and explicit-kind specs resolve to
    the same (table, kind, guarantee) group and answer bit-identically."""
    lq, uq, qa, qb, qc, qd = queries
    session = _session(data)
    pairs = [
        (QuerySpec.range("cnt", lq, uq),
         QuerySpec("cnt", (lq, uq), DEFAULT_REL, kind="count")),
        (QuerySpec.range("sm", lq, uq),
         QuerySpec("sm", (lq, uq), DEFAULT_REL, kind="sum")),
        (QuerySpec.range("mx", lq, uq),
         QuerySpec("mx", (lq, uq), DEFAULT_REL, kind="max")),
        (QuerySpec.rect("geo", qa, qb, qc, qd),
         QuerySpec("geo", (qa, qb, qc, qd), DEFAULT_REL, kind="count")),
    ]
    for legacy, explicit in pairs:
        a = session.query(legacy)
        b = session.query(explicit)
        np.testing.assert_array_equal(np.asarray(a.value),
                                      np.asarray(b.value))
        np.testing.assert_array_equal(np.asarray(a.approx),
                                      np.asarray(b.approx))
    with pytest.raises(ValueError, match="answers"):
        session.query(QuerySpec("cnt", (lq, uq), kind="max"))


def test_answer_structure_and_compat(data, queries):
    from repro.api import Answer
    lq, uq = queries[:2]
    session = _session(data)
    res = session.query(QuerySpec.range("cnt", lq, uq))
    assert isinstance(res, Answer)
    assert res.answer is res.value            # QueryResult-compat alias
    ans, approx, refined = res                # tuple-unpack compat
    assert ans is res.value and refined is res.refined
    assert res.bound == session.budget("cnt").bound("count")
    assert res.staleness == 0
    # registered pytree: round-trips with staleness as aux metadata
    leaves, td = jax.tree_util.tree_flatten(res)
    back = jax.tree_util.tree_unflatten(td, leaves)
    np.testing.assert_array_equal(np.asarray(back.value),
                                  np.asarray(res.value))
    assert back.staleness == res.staleness


def test_quantile_spec_and_budget_roundtrip(data):
    keys = data[0]
    session = _session(data)
    qs = np.array([0.05, 0.5, 0.95])
    res = session.query(QuerySpec.quantile("cnt", qs))
    lo, hi = res.bound
    truth = np.quantile(keys, qs)
    assert np.all(np.asarray(lo) <= truth + 1e-12)
    assert np.all(truth <= np.asarray(hi) + 1e-12)
    assert np.all(np.asarray(lo) <= np.asarray(res.value))
    assert np.all(np.asarray(res.value) <= np.asarray(hi))
    # the rank-domain budget passes through 1:1
    b = ErrorBudget(abs=7.0)
    assert b.delta("quantile") == pytest.approx(7.0)
    assert b.bound("quantile") == pytest.approx(7.0)
    # quantiles reject tables that have no monotone 1-D CF
    with pytest.raises(ValueError, match="quantile"):
        session.query(QuerySpec.quantile("mx", 0.5))
    with pytest.raises(ValueError, match="quantile"):
        TableSpec("quantile", ErrorBudget(abs=1.0))


def test_window_table_via_session(data):
    keys = data[0]
    session = PolyFit.fit(
        {"w": (keys, None), "cnt": keys},
        {"w": TableSpec("count", ErrorBudget(abs=2 * DELTA), window=4),
         "cnt": TableSpec("count", ErrorBudget(abs=2 * DELTA))})
    session.ingest("w", keys[:100] + 0.25)
    assert session.advance_epoch("w") == 2
    res = session.query(QuerySpec.window("w", 0.0, 800.0, 0, 2))
    exact = np.sum((keys > 0.0) & (keys <= 800.0)) \
        + np.sum((keys[:100] + 0.25 > 0.0) & (keys[:100] + 0.25 <= 800.0))
    assert abs(float(res.value[0]) - exact) <= res.bound + 1e-9
    assert res.staleness == 0                  # t1 is the open epoch
    stale = session.query(QuerySpec.window("w", 0.0, 800.0, 0, 0))
    assert stale.staleness == 2
    # windowed tables reject plain range reads and incompatible specs
    with pytest.raises(ValueError, match="windowed"):
        session.query(QuerySpec.range("w", 0.0, 1.0))
    with pytest.raises(ValueError, match="not windowed"):
        session.query(QuerySpec.window("cnt", 0.0, 1.0, 0, 0))


def test_window_spec_validation():
    with pytest.raises(ValueError, match="params"):
        QuerySpec("w", (0.0, 1.0), kind="window")
    with pytest.raises(ValueError, match="rank fractions"):
        QuerySpec("w", (0.0, 1.0), kind="quantile")
    with pytest.raises(ValueError, match="kind"):
        QuerySpec("w", (0.0, 1.0), kind="median")
    with pytest.raises(ValueError, match="window"):
        TableSpec("max", ErrorBudget(abs=1.0), window=4)
    with pytest.raises(ValueError, match="epoch ring"):
        TableSpec("count", ErrorBudget(abs=1.0), window=4, dynamic=True)
