"""Exact baselines (§3.2): prefix CF array and sparse-table range max."""
import numpy as np
import jax.numpy as jnp

from repro.core import ExactMax, ExactSum, build_sparse_table, sparse_table_range_max


def test_exact_sum_vs_brute(rng):
    n = 3000
    keys = rng.uniform(0, 100, n)
    meas = rng.uniform(0, 10, n)
    ex = ExactSum.build(keys, meas)
    lq = rng.uniform(0, 100, 200)
    uq = lq + rng.uniform(0, 50, 200)
    got = np.asarray(ex.cf_at(jnp.asarray(uq)) - ex.cf_at(jnp.asarray(lq)))
    want = np.array([meas[(keys > a) & (keys <= b)].sum() for a, b in zip(lq, uq)])
    assert np.allclose(got, want, atol=1e-6)


def test_exact_max_vs_brute(rng):
    n = 3000
    keys = rng.uniform(0, 100, n)
    meas = rng.uniform(0, 1000, n)
    ex = ExactMax.build(keys, meas)
    lq = rng.uniform(0, 100, 200)
    uq = lq + rng.uniform(0, 50, 200)
    got = np.asarray(ex.query(jnp.asarray(lq), jnp.asarray(uq)))
    for i, (a, b) in enumerate(zip(lq, uq)):
        sel = (keys >= a) & (keys <= b)
        want = meas[sel].max() if sel.any() else -np.inf
        assert got[i] == want


def test_sparse_table_all_ranges(rng):
    m = rng.uniform(-5, 5, 257)
    st = jnp.asarray(build_sparse_table(m))
    ii, jj = np.meshgrid(np.arange(258), np.arange(258), indexing="ij")
    ii, jj = ii.ravel(), jj.ravel()
    got = np.asarray(sparse_table_range_max(st, jnp.asarray(ii), jnp.asarray(jj)))
    for i, j, g in zip(ii[::97], jj[::97], got[::97]):
        want = m[i:j].max() if j > i and i < 257 else -np.inf
        if j > i and i < 257:
            assert g == want
        else:
            assert g == -np.inf
