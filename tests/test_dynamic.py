"""Dynamic engine: delta-buffered updates must preserve every certified
bound, agree across backends, and survive (selective, background) refits."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from repro.core import (ExactMax, ExactSum, MergeSortTree,  # noqa: E402
                        build_index_1d, build_index_2d)
from repro.engine import (BACKENDS, DynamicEngine,  # noqa: E402
                          DynamicEngine2D)

N = 2500
NQ = 256
DELTA = 25.0


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(42)
    keys = np.sort(rng.uniform(0, 600, N))
    meas = rng.uniform(0, 10, N)
    return keys, meas


@pytest.fixture(scope="module")
def updates(data):
    keys, _ = data
    rng = np.random.default_rng(43)
    ins_k = np.concatenate([rng.uniform(0, 600, 56),
                            [-5.0, 610.0]])   # includes out-of-domain keys
    ins_v = rng.uniform(0, 10, len(ins_k))
    del_k = np.unique(keys[rng.integers(0, N, 24)])
    return ins_k, ins_v, del_k


@pytest.fixture(scope="module")
def queries(data):
    keys, _ = data
    rng = np.random.default_rng(44)
    a = keys[rng.integers(0, N, NQ)]
    b = keys[rng.integers(0, N, NQ)]
    return np.minimum(a, b), np.maximum(a, b)


@pytest.fixture(scope="module")
def indexes(data):
    keys, meas = data
    out = {}
    for agg, m, deg in (("sum", meas, 2), ("count", None, 2),
                        ("max", meas * 100, 3), ("min", meas * 100, 3)):
        out[agg] = build_index_1d(keys, m, agg, deg=deg, delta=DELTA)
    return out


def _apply_updates(keys, meas, ins_k, ins_v, del_k):
    """Ground-truth multiset after the updates (first occurrence deleted)."""
    all_k = np.concatenate([keys, ins_k])
    all_v = np.concatenate([meas, ins_v])
    alive = np.ones(len(all_k), bool)
    for k in del_k:
        hit = np.where(alive & (all_k == k))[0]
        alive[hit[0]] = False
    return all_k[alive], all_v[alive]


def _truth_1d(agg, keys, meas, lq, uq):
    if agg in ("sum", "count"):
        m = np.ones_like(keys) if agg == "count" else meas
        ex = ExactSum.build(keys, m)
        return np.asarray(ex.cf_at(jnp.asarray(uq)) - ex.cf_at(jnp.asarray(lq)))
    sgn = -1.0 if agg == "min" else 1.0
    ex = ExactMax.build(keys, sgn * meas)
    return sgn * np.asarray(ex.query(jnp.asarray(lq), jnp.asarray(uq)))


def _measures_for(agg, meas):
    return None if agg == "count" else (
        meas * 100 if agg in ("max", "min") else meas)


def _dyn_with_updates(indexes, agg, backend, updates):
    ins_k, ins_v, del_k = updates
    dyn = DynamicEngine(indexes[agg], backend=backend, capacity=256,
                        auto_refit=False)
    if agg == "count":
        dyn.insert(ins_k)
    elif agg in ("max", "min"):
        dyn.insert(ins_k, ins_v * 100)
    else:
        dyn.insert(ins_k, ins_v)
    dyn.delete(del_k)
    return dyn


def _updated_truth(agg, data, updates, lq, uq):
    keys, meas = data
    ins_k, ins_v, del_k = updates
    scale = 100 if agg in ("max", "min") else 1
    uk, uv = _apply_updates(keys, meas * scale, ins_k, ins_v * scale, del_k)
    return _truth_1d(agg, uk, uv, lq, uq)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("agg", ["sum", "count", "max", "min"])
def test_certified_bounds_after_updates(indexes, data, updates, queries,
                                        agg, backend):
    """Lemma 5.1/5.3 must hold over the *updated* dataset while the updates
    sit in the delta buffer (the correction is exact)."""
    lq, uq = queries
    dyn = _dyn_with_updates(indexes, agg, backend, updates)
    truth = _updated_truth(agg, data, updates, lq, uq)
    res = dyn.query(lq, uq)
    bound = 2 * DELTA if agg in ("sum", "count") else DELTA
    assert np.max(np.abs(np.asarray(res.answer) - truth)) <= bound + 1e-6


@pytest.mark.parametrize("agg", ["sum", "count", "max", "min"])
def test_cross_backend_equivalence_post_update(indexes, updates, queries,
                                               agg):
    """All three backends produce identical post-update f64 answers."""
    lq, uq = queries
    outs = {}
    for b in BACKENDS:
        dyn = _dyn_with_updates(indexes, agg, b, updates)
        outs[b] = np.asarray(dyn.query(lq, uq).answer)
    for b in ("pallas", "ref"):
        np.testing.assert_allclose(outs[b], outs["xla"], rtol=1e-9,
                                   atol=1e-9)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("agg", ["sum", "max"])
def test_qrel_after_updates(indexes, data, updates, queries, agg, backend):
    """Fused Q_rel refinement keeps the relative bound after updates."""
    lq, uq = queries
    dyn = _dyn_with_updates(indexes, agg, backend, updates)
    truth = _updated_truth(agg, data, updates, lq, uq)
    eps_rel = 0.05
    ans = np.asarray(dyn.query(lq, uq, eps_rel=eps_rel).answer)
    pos = np.abs(truth) > 0
    rel = np.abs(ans[pos] - truth[pos]) / np.abs(truth[pos])
    assert rel.max() <= eps_rel + 1e-9


@pytest.mark.parametrize("agg", ["sum", "max"])
def test_flush_refits_and_preserves_bounds(indexes, data, updates, queries,
                                           agg):
    """A merge pass empties the buffer, re-certifies the touched segments,
    and post-refit answers stay within the certified bound (and close to
    the buffered answers)."""
    lq, uq = queries
    dyn = _dyn_with_updates(indexes, "sum" if agg == "sum" else agg,
                            "xla", updates)
    before = np.asarray(dyn.query(lq, uq).answer)
    assert dyn.n_pending > 0   # deletes ride the buffer for every agg now
    dyn.flush()
    assert dyn.n_pending == 0
    assert dyn.refit_count >= 1
    truth = _updated_truth(agg, data, updates, lq, uq)
    after = np.asarray(dyn.query(lq, uq).answer)
    bound = 2 * DELTA if agg == "sum" else DELTA
    assert np.max(np.abs(after - truth)) <= bound + 1e-6
    assert np.max(np.abs(before - truth)) <= bound + 1e-6
    # every refit segment is re-certified at delta
    assert float(np.max(np.asarray(dyn.index.seg_err))) <= DELTA + 1e-9


def test_selective_refit_leaves_far_segments_alone(data):
    """Only segments whose span contains changed keys are refit; clean SUM
    segments absorb upstream inserts as an exact constant-coefficient
    shift."""
    keys, meas = data
    idx = build_index_1d(keys, meas, "sum", deg=2, delta=DELTA)
    dyn = DynamicEngine(idx, capacity=256, auto_refit=False)
    # edits confined to keys < 50
    rng = np.random.default_rng(7)
    ins_k = rng.uniform(0, 50, 30)
    dyn.insert(ins_k, rng.uniform(0, 10, 30))
    net = float(np.sum(dyn._ins_log[0][1]))
    old_lo = np.asarray(idx.seg_lo)
    old_coeffs = np.asarray(idx.coeffs)
    dyn.flush()
    new_lo = np.asarray(dyn.index.seg_lo)
    new_coeffs = np.asarray(dyn.index.coeffs)
    far_old = np.where(old_lo > 100)[0]
    assert len(far_old) > 2
    for i in far_old:
        j = np.searchsorted(new_lo, old_lo[i])
        assert new_lo[j] == old_lo[i]
        # non-constant coefficients bit-identical; constant shifted by the
        # exact net inserted mass upstream
        np.testing.assert_array_equal(new_coeffs[j, 1:], old_coeffs[i, 1:])
        np.testing.assert_allclose(new_coeffs[j, 0] - old_coeffs[i, 0], net,
                                   rtol=1e-12)


def test_capacity_trigger_auto_refits(data):
    keys, meas = data
    idx = build_index_1d(keys, meas, "sum", deg=2, delta=DELTA)
    dyn = DynamicEngine(idx, capacity=64, auto_refit=True)
    rng = np.random.default_rng(8)
    for _ in range(3):
        dyn.insert(rng.uniform(0, 600, 40), rng.uniform(0, 10, 40))
    assert dyn.refit_count >= 1
    assert dyn.n_pending < 64


def test_drift_trigger_refits_hot_segment(data):
    """Accumulated |measure| drift past a segment's error headroom forces a
    merge before the buffer fills."""
    keys, meas = data
    idx = build_index_1d(keys, meas, "sum", deg=2, delta=DELTA)
    dyn = DynamicEngine(idx, capacity=1024, auto_refit=True)
    hot = float(np.asarray(idx.seg_lo)[3]) + 1e-9
    dyn.insert(np.full(8, hot), np.full(8, 50.0))
    assert dyn.refit_count >= 1
    assert dyn.n_pending == 0


def test_extremal_delete_shadows_victim_without_merge(data, queries):
    """A MAX delete never pays a merge on the write path: the victim is
    shadowed in the buffer (``vic_keys``/``live_st``), ranges covering it
    refine against the victim-masked exact sparse table, and the physical
    removal rides the next ordinary merge."""
    keys, meas = data
    idx = build_index_1d(keys, meas * 100, "max", deg=3, delta=DELTA)
    dyn = DynamicEngine(idx, backend="pallas", capacity=128,
                        auto_refit=False)
    dyn.delete(keys[[10, 500, 2000]])
    assert dyn.refit_count == 0 and dyn.n_pending == 3   # no eager merge
    _, buf = dyn.snapshot()
    assert buf.vic_keys is not None and buf.live_st is not None
    lq, uq = queries
    uk, uv = _apply_updates(keys, meas * 100, np.zeros(0), np.zeros(0),
                            keys[[10, 500, 2000]])
    truth = _truth_1d("max", uk, uv, lq, uq)
    res = dyn.query(lq, uq)
    assert np.max(np.abs(np.asarray(res.answer) - truth)) <= DELTA + 1e-6
    # threatened ranges (victim inside) answer exactly
    ref = np.asarray(res.refined)
    assert np.allclose(np.asarray(res.answer)[ref], truth[ref])
    # the next merge applies the shadows and clears the victim mask
    dyn.flush()
    assert dyn.n_pending == 0 and dyn.refit_count == 1
    _, buf = dyn.snapshot()
    assert buf.vic_keys is None
    res = dyn.query(lq, uq)
    assert np.max(np.abs(np.asarray(res.answer) - truth)) <= DELTA + 1e-6


def test_background_refit_never_blocks_queries(data, queries):
    keys, meas = data
    idx = build_index_1d(keys, meas, "sum", deg=2, delta=DELTA)
    dyn = DynamicEngine(idx, capacity=256, auto_refit=False,
                        background=True)
    rng = np.random.default_rng(9)
    ins_k = rng.uniform(0, 600, 50)
    ins_v = rng.uniform(0, 10, 50)
    dyn.insert(ins_k, ins_v)
    lq, uq = queries
    truth = _updated_truth("sum", data, (ins_k, ins_v, np.zeros(0)), lq, uq)
    dyn.refit(wait=False)   # merge runs on a worker thread
    # queries keep answering within bounds throughout the merge
    for _ in range(5):
        ans = np.asarray(dyn.query(lq, uq).answer)
        assert np.max(np.abs(ans - truth)) <= 2 * DELTA + 1e-6
    dyn.refit(wait=True)    # join + surface any merge error
    assert dyn.refit_count == 1 and dyn.n_pending == 0
    ans = np.asarray(dyn.query(lq, uq).answer)
    assert np.max(np.abs(ans - truth)) <= 2 * DELTA + 1e-6


def test_duplicate_deletes_in_one_batch_take_distinct_victims(data):
    """delete([k, k]) must tombstone *both* occurrences' measures, not the
    first one twice — the buffered SUM correction is exact."""
    keys, meas = data
    k = 300.0
    keys2 = np.sort(np.concatenate([keys, [k, k]]))
    order = np.argsort(np.concatenate([keys, [k, k]]), kind="stable")
    meas2 = np.concatenate([meas, [4.0, 9.0]])[order]
    idx = build_index_1d(keys2, meas2, "sum", deg=2, delta=DELTA)
    dyn = DynamicEngine(idx, capacity=64, auto_refit=False)
    dyn.delete([k, k])
    dels = dyn._del_log[0][1]
    assert sorted(dels.tolist()) == [4.0, 9.0]
    with pytest.raises(KeyError):
        dyn.delete([k])   # only two occurrences existed


def test_2d_duplicate_delete_of_single_point_raises(dyn2d_setup):
    px, py, idx, _, _, _ = dyn2d_setup
    dyn = DynamicEngine2D(idx, capacity=64, auto_refit=False)
    x, y = float(px[0]), float(py[0])
    with pytest.raises(KeyError):
        dyn.delete([x, x], [y, y])   # one live occurrence, two tombstones


def test_delete_missing_key_raises(data):
    keys, meas = data
    idx = build_index_1d(keys, meas, "sum", deg=2, delta=DELTA)
    dyn = DynamicEngine(idx, capacity=64, auto_refit=False)
    with pytest.raises(KeyError):
        dyn.delete([keys[0] + 0.123456789])


def test_oversize_batch_raises(data):
    keys, meas = data
    idx = build_index_1d(keys, meas, "sum", deg=2, delta=DELTA)
    dyn = DynamicEngine(idx, capacity=64, auto_refit=False)
    with pytest.raises(ValueError, match="capacity"):
        dyn.insert(np.linspace(0, 600, 100), np.ones(100))


@pytest.fixture(scope="module")
def dyn2d_setup():
    rng = np.random.default_rng(13)
    px = rng.uniform(0, 120, 4000)
    py = rng.uniform(0, 120, 4000)
    idx = build_index_2d(px, py, deg=2, delta=DELTA, max_depth=6)
    ins_x = rng.uniform(0, 120, 48)
    ins_y = rng.uniform(0, 120, 48)
    del_i = rng.integers(0, 4000, 16)
    qa = rng.uniform(0, 120, 128)
    qb = qa + rng.uniform(0.5, 40, 128)
    qc = rng.uniform(0, 120, 128)
    qd = qc + rng.uniform(0.5, 40, 128)
    keep = np.ones(4000, bool)
    keep[del_i] = False
    tree = MergeSortTree.build(np.concatenate([px[keep], ins_x]),
                               np.concatenate([py[keep], ins_y]))
    cf = lambda u, v: tree.cf(jnp.asarray(u), jnp.asarray(v))
    truth = np.asarray(cf(qb, qd) - cf(qa, qd) - cf(qb, qc) + cf(qa, qc))
    return px, py, idx, (ins_x, ins_y, px[del_i], py[del_i]), \
        (qa, qb, qc, qd), truth


@pytest.mark.parametrize("backend", BACKENDS)
def test_2d_bounds_after_updates(dyn2d_setup, backend):
    px, py, idx, (ix, iy, dx, dy), q, truth = dyn2d_setup
    dyn = DynamicEngine2D(idx, backend=backend, capacity=128,
                          auto_refit=False)
    dyn.insert(ix, iy)
    dyn.delete(dx, dy)
    res = dyn.count2d(*q)
    assert np.max(np.abs(np.asarray(res.answer) - truth)) <= 4 * DELTA + 1e-6


def test_2d_cross_backend_and_flush(dyn2d_setup):
    px, py, idx, (ix, iy, dx, dy), q, truth = dyn2d_setup
    outs = {}
    for b in BACKENDS:
        dyn = DynamicEngine2D(idx, backend=b, capacity=128,
                              auto_refit=False)
        dyn.insert(ix, iy)
        dyn.delete(dx, dy)
        outs[b] = np.asarray(dyn.count2d(*q).answer)
    for b in ("pallas", "ref"):
        np.testing.assert_allclose(outs[b], outs["xla"], rtol=1e-9,
                                   atol=1e-9)
    dyn.flush()
    assert dyn.refit_count == 1 and dyn.n_pending == 0
    res = np.asarray(dyn.count2d(*q).answer)
    assert np.max(np.abs(res - truth)) <= 4 * DELTA + 1e-6


def test_serve_dynamic_endpoints():
    from repro.serve.aggregates import AggregateService
    svc = AggregateService(backend="ref", n1=4000, n2=2500, eps_abs=50.0,
                          eps_rel=None, verbose=False, dynamic=True,
                          capacity=128)
    c0, c1 = svc.domains["count"]
    lq = np.full(16, c0)
    uq = np.full(16, c1)
    base = float(np.asarray(svc.serve("count", lq, uq).answer)[0])
    svc.insert("count", np.linspace(c0 + 1e-6, c1 - 1e-6, 32))
    upd = float(np.asarray(svc.serve("count", lq, uq).answer)[0])
    assert abs(upd - (base + 32)) < 1e-6
    svc.flush("count")
    post = float(np.asarray(svc.serve("count", lq, uq).answer)[0])
    assert abs(post - upd) <= 50.0 + 1e-6


# ---------------------------------------------------------------------------
# 2-D measure aggregates (DESIGN.md §12): buffered updates + selective refit
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def dyn2dw_setup():
    rng = np.random.default_rng(0x2DD)
    n = 3000
    px = rng.uniform(0, 100, n)
    py = rng.uniform(0, 100, n)
    w = 50 + 10 * np.sin(px / 10) + 10 * np.cos(py / 15)
    ins = (rng.uniform(5, 95, 40), rng.uniform(5, 95, 40),
           rng.uniform(30, 70, 40))
    del_i = rng.integers(0, n, 12)
    rect = (rng.uniform(0, 75, 96), None, rng.uniform(0, 75, 96), None)
    rect = (rect[0], rect[0] + rng.uniform(5, 25, 96),
            rect[2], rect[2] + rng.uniform(5, 25, 96))
    ci = rng.integers(0, n, 96)   # anchored at data points, so every
    corners = (px[ci], py[ci])    # corner dominates at least one record
    keep = np.ones(n, bool)
    keep[del_i] = False
    merged = (np.concatenate([px[keep], ins[0]]),
              np.concatenate([py[keep], ins[1]]),
              np.concatenate([w[keep], ins[2]]))
    return px, py, w, ins, del_i, rect, corners, merged


def _sum2d_truth(merged, rect):
    mx, my, mw = merged
    la, ua, lb, ub = rect
    return np.array([
        mw[(mx > a) & (mx <= b) & (my > c) & (my <= d)].sum()
        for a, b, c, d in zip(la, ua, lb, ub)])


@pytest.mark.parametrize("backend", BACKENDS)
def test_2d_sum_bounds_after_updates(dyn2dw_setup, backend):
    """4*delta holds over the updated dataset while the ops sit in the
    buffer (the weighted correction is exact)."""
    px, py, w, ins, del_i, rect, _, merged = dyn2dw_setup
    idx = build_index_2d(px, py, measures=w, agg="sum2d", deg=2,
                         delta=400.0, max_depth=7)
    dyn = DynamicEngine2D(idx, backend=backend, capacity=128,
                          auto_refit=False)
    dyn.insert(*ins)
    dyn.delete(px[del_i], py[del_i])
    res = dyn.sum2d(*rect)
    truth = _sum2d_truth(merged, rect)
    assert np.abs(np.asarray(res.answer) - truth).max() \
        <= 4 * idx.certified_delta + 1e-6


@pytest.mark.parametrize("agg", ["max2d", "min2d"])
def test_2d_extremum_bounds_after_inserts(dyn2dw_setup, agg):
    px, py, w, ins, _, _, corners, _ = dyn2dw_setup
    idx = build_index_2d(px, py, measures=w, agg=agg, deg=2, delta=4.0,
                         max_depth=7)
    dyn = DynamicEngine2D(idx, backend="xla", capacity=128,
                          auto_refit=False)
    dyn.insert(*ins)
    u, v = corners
    res = dyn.extremum2d(u, v)
    mx = np.concatenate([px, ins[0]])
    my = np.concatenate([py, ins[1]])
    mw = np.concatenate([w, ins[2]])
    dom = (mx[None, :] <= u[:, None]) & (my[None, :] <= v[:, None])
    red = np.max if agg == "max2d" else np.min
    truth = np.array([red(mw[d]) for d in dom])
    assert np.abs(np.asarray(res.answer) - truth).max() \
        <= idx.certified_delta + 1e-6


def test_2d_sum_cross_backend_and_flush(dyn2dw_setup):
    px, py, w, ins, del_i, rect, _, merged = dyn2dw_setup
    idx = build_index_2d(px, py, measures=w, agg="sum2d", deg=2,
                         delta=400.0, max_depth=7)
    outs = {}
    for b in BACKENDS:
        dyn = DynamicEngine2D(idx, backend=b, capacity=128,
                              auto_refit=False)
        dyn.insert(*ins)
        dyn.delete(px[del_i], py[del_i])
        outs[b] = np.asarray(dyn.sum2d(*rect).answer)
    for b in BACKENDS[1:]:
        np.testing.assert_allclose(outs[b], outs["xla"], rtol=1e-9,
                                   atol=1e-9)
    dyn.flush()
    assert dyn.refit_count == 1 and dyn.n_pending == 0
    stats = dyn.last_refit_stats
    assert stats is not None and not stats["rebuild"]
    assert 0 < stats["refit"] < stats["n_leaves"]
    truth = _sum2d_truth(merged, rect)
    res = np.asarray(dyn.sum2d(*rect).answer)
    assert np.abs(res - truth).max() <= 4 * dyn.index.certified_delta + 1e-6


def test_2d_selective_refit_leaves_far_leaves_alone(dyn2dw_setup):
    """Post-merge, leaves outside every changed point's dominance boundary
    keep identical coefficient rows; wholly dominated ones shift only in
    the constant term."""
    px, py, w, _, _, _, _, _ = dyn2dw_setup
    idx = build_index_2d(px, py, measures=w, agg="sum2d", deg=2,
                         delta=400.0, max_depth=7)
    dyn = DynamicEngine2D(idx, backend="xla", capacity=64,
                          auto_refit=False)
    x0, y0, wv = 70.0, 65.0, 55.0
    dyn.insert([x0], [y0], [wv])
    dyn.flush()
    stats = dyn.last_refit_stats
    assert not stats["rebuild"]
    assert stats["refit"] < stats["n_leaves"] // 4   # selectivity
    lb = np.asarray(idx.bounds)[np.asarray(idx.leaf_nodes)]
    old_c = np.asarray(idx.coeffs)
    new_idx = dyn.index
    new_lb = np.asarray(new_idx.bounds)[np.asarray(new_idx.leaf_nodes)]
    new_c = np.asarray(new_idx.coeffs)
    n_same = n_shift = 0
    for i, b in enumerate(lb):
        untouched = b[1] < x0 or b[3] < y0
        dominated = b[0] >= x0 and b[2] >= y0
        if not (untouched or dominated):
            continue   # ray-crossed: re-fitted (and possibly re-split)
        j = int(np.where((new_lb == b).all(axis=1))[0][0])
        if untouched:
            np.testing.assert_array_equal(old_c[i], new_c[j])
            n_same += 1
        else:                                       # constant bump only
            assert new_c[j][0] == old_c[i][0] + wv
            np.testing.assert_array_equal(old_c[i][1:], new_c[j][1:])
            n_shift += 1
    assert n_same > 0 and n_shift > 0


def test_2d_extremum_delete_shadows_victim_without_merge(dyn2dw_setup):
    """A dominance-MAX delete never merges on the write path: the victim
    point is shadowed (``vic_x``/``vic_y``/``live_wpmax``) and corners
    dominating it refine against the victim-masked merge-sort tree."""
    px, py, w, _, _, _, corners, _ = dyn2dw_setup
    idx = build_index_2d(px, py, measures=w, agg="max2d", deg=2,
                         delta=4.0, max_depth=7)
    dyn = DynamicEngine2D(idx, backend="xla", capacity=64,
                          auto_refit=False)
    victim = int(np.argmax(w))
    dyn.delete(px[victim], py[victim])
    assert dyn.refit_count == 0 and dyn.n_pending == 1   # no eager merge
    _, buf = dyn.snapshot()
    assert buf.vic_x is not None and buf.live_wpmax is not None
    u, v = corners
    res = dyn.extremum2d(u, v)
    keep = np.ones(len(px), bool)
    keep[victim] = False
    dom = ((px[keep][None, :] <= u[:, None])
           & (py[keep][None, :] <= v[:, None]))
    truth = np.array([w[keep][d].max() for d in dom])
    assert np.abs(np.asarray(res.answer) - truth).max() \
        <= dyn.index.certified_delta + 1e-6
    # corners dominating the victim refine to the exact live answer
    ref = np.asarray(res.refined)
    assert ref.any()
    assert np.allclose(np.asarray(res.answer)[ref], truth[ref])
    # the next merge applies the shadow and clears the mask
    dyn.flush()
    assert dyn.n_pending == 0 and dyn.refit_count == 1
    _, buf = dyn.snapshot()
    assert buf.vic_x is None
    res = dyn.extremum2d(u, v)
    assert np.abs(np.asarray(res.answer) - truth).max() \
        <= dyn.index.certified_delta + 1e-6


@pytest.mark.parametrize("agg,meas", [("max2d", 5.0), ("min2d", 150.0)])
def test_2d_below_floor_insert_refits_eagerly(dyn2dw_setup, agg, meas):
    """An insert below the frozen dominance floor (above the max, for MIN)
    cannot ride the buffer: the plan's clamp over-reports every query
    dominating only the new point.  The engine merges eagerly through the
    targeted refit path, the floor re-freezes at the merged minimum, and
    a query dominating only the new point certifies against its measure
    (the pre-fix behavior answered with the stale build-time floor)."""
    px, py, w, _, _, _, _, _ = dyn2dw_setup
    idx = build_index_2d(px, py, measures=w, agg=agg, deg=2, delta=4.0,
                         max_depth=7)
    old_floor = idx.extremal_floor
    dyn = DynamicEngine2D(idx, backend="xla", capacity=64,
                          auto_refit=False)
    x0 = y0 = 0.5    # below-left of (almost) all data
    dyn.insert([x0], [y0], [meas])
    assert dyn.n_pending == 0 and dyn.refit_count == 1   # eager merge
    stats = dyn.last_refit_stats
    assert not stats["rebuild"] and "floor_refit" in stats
    assert dyn.index.extremal_floor != old_floor         # re-frozen
    red = np.max if agg == "max2d" else np.min
    u = np.array([x0 + 1e-6, 90.0])
    v = np.array([y0 + 1e-6, 90.0])
    res = dyn.extremum2d(u, v)
    mx, my = np.append(px, x0), np.append(py, y0)
    mw = np.append(w, meas)
    dom = (mx[None, :] <= u[:, None]) & (my[None, :] <= v[:, None])
    truth = np.array([red(mw[d]) for d in dom])
    assert np.abs(np.asarray(res.answer) - truth).max() \
        <= dyn.index.certified_delta + 1e-6


def test_2d_weighted_delete_victims(dyn2dw_setup):
    """Duplicate (x, y) points with distinct measures: tombstones remove
    base occurrences first, with a cursor across the batch."""
    px, py, w, _, _, _, _, _ = dyn2dw_setup
    px2 = np.concatenate([px, [50.0, 50.0]])
    py2 = np.concatenate([py, [50.0, 50.0]])
    w2 = np.concatenate([w, [11.0, 13.0]])
    idx = build_index_2d(px2, py2, measures=w2, agg="sum2d", deg=2,
                         delta=400.0, max_depth=6)
    dyn = DynamicEngine2D(idx, backend="xla", capacity=64,
                          auto_refit=False)
    dyn.delete([50.0, 50.0], [50.0, 50.0])   # removes both occurrences
    with pytest.raises(KeyError, match="not present"):
        dyn.delete([50.0], [50.0])
    rect = (np.array([45.0]), np.array([55.0]),
            np.array([45.0]), np.array([55.0]))
    res = float(np.asarray(dyn.sum2d(*rect).answer)[0])
    m = (px > 45) & (px <= 55) & (py > 45) & (py <= 55)
    assert abs(res - w[m].sum()) <= 4 * idx.certified_delta + 1e-6


def test_2d_insert_measure_validation(dyn2dw_setup):
    px, py, w, _, _, _, _, _ = dyn2dw_setup
    idx = build_index_2d(px, py, measures=w, agg="sum2d", deg=2,
                         delta=800.0, max_depth=5)
    dyn = DynamicEngine2D(idx, backend="xla", capacity=64,
                          auto_refit=False)
    with pytest.raises(ValueError, match="measures required"):
        dyn.insert([1.0], [2.0])
    idxc = build_index_2d(px, py, deg=2, delta=50.0, max_depth=5)
    dync = DynamicEngine2D(idxc, backend="xla", capacity=64,
                           auto_refit=False)
    with pytest.raises(ValueError, match="only apply"):
        dync.insert([1.0], [2.0], [3.0])
