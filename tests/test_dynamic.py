"""Dynamic engine: delta-buffered updates must preserve every certified
bound, agree across backends, and survive (selective, background) refits."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from repro.core import (ExactMax, ExactSum, MergeSortTree,  # noqa: E402
                        build_index_1d, build_index_2d)
from repro.engine import (BACKENDS, DynamicEngine,  # noqa: E402
                          DynamicEngine2D)

N = 2500
NQ = 256
DELTA = 25.0


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(42)
    keys = np.sort(rng.uniform(0, 600, N))
    meas = rng.uniform(0, 10, N)
    return keys, meas


@pytest.fixture(scope="module")
def updates(data):
    keys, _ = data
    rng = np.random.default_rng(43)
    ins_k = np.concatenate([rng.uniform(0, 600, 56),
                            [-5.0, 610.0]])   # includes out-of-domain keys
    ins_v = rng.uniform(0, 10, len(ins_k))
    del_k = np.unique(keys[rng.integers(0, N, 24)])
    return ins_k, ins_v, del_k


@pytest.fixture(scope="module")
def queries(data):
    keys, _ = data
    rng = np.random.default_rng(44)
    a = keys[rng.integers(0, N, NQ)]
    b = keys[rng.integers(0, N, NQ)]
    return np.minimum(a, b), np.maximum(a, b)


@pytest.fixture(scope="module")
def indexes(data):
    keys, meas = data
    out = {}
    for agg, m, deg in (("sum", meas, 2), ("count", None, 2),
                        ("max", meas * 100, 3), ("min", meas * 100, 3)):
        out[agg] = build_index_1d(keys, m, agg, deg=deg, delta=DELTA)
    return out


def _apply_updates(keys, meas, ins_k, ins_v, del_k):
    """Ground-truth multiset after the updates (first occurrence deleted)."""
    all_k = np.concatenate([keys, ins_k])
    all_v = np.concatenate([meas, ins_v])
    alive = np.ones(len(all_k), bool)
    for k in del_k:
        hit = np.where(alive & (all_k == k))[0]
        alive[hit[0]] = False
    return all_k[alive], all_v[alive]


def _truth_1d(agg, keys, meas, lq, uq):
    if agg in ("sum", "count"):
        m = np.ones_like(keys) if agg == "count" else meas
        ex = ExactSum.build(keys, m)
        return np.asarray(ex.cf_at(jnp.asarray(uq)) - ex.cf_at(jnp.asarray(lq)))
    sgn = -1.0 if agg == "min" else 1.0
    ex = ExactMax.build(keys, sgn * meas)
    return sgn * np.asarray(ex.query(jnp.asarray(lq), jnp.asarray(uq)))


def _measures_for(agg, meas):
    return None if agg == "count" else (
        meas * 100 if agg in ("max", "min") else meas)


def _dyn_with_updates(indexes, agg, backend, updates):
    ins_k, ins_v, del_k = updates
    dyn = DynamicEngine(indexes[agg], backend=backend, capacity=256,
                        auto_refit=False)
    if agg == "count":
        dyn.insert(ins_k)
    elif agg in ("max", "min"):
        dyn.insert(ins_k, ins_v * 100)
    else:
        dyn.insert(ins_k, ins_v)
    dyn.delete(del_k)
    return dyn


def _updated_truth(agg, data, updates, lq, uq):
    keys, meas = data
    ins_k, ins_v, del_k = updates
    scale = 100 if agg in ("max", "min") else 1
    uk, uv = _apply_updates(keys, meas * scale, ins_k, ins_v * scale, del_k)
    return _truth_1d(agg, uk, uv, lq, uq)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("agg", ["sum", "count", "max", "min"])
def test_certified_bounds_after_updates(indexes, data, updates, queries,
                                        agg, backend):
    """Lemma 5.1/5.3 must hold over the *updated* dataset while the updates
    sit in the delta buffer (the correction is exact)."""
    lq, uq = queries
    dyn = _dyn_with_updates(indexes, agg, backend, updates)
    truth = _updated_truth(agg, data, updates, lq, uq)
    res = dyn.query(lq, uq)
    bound = 2 * DELTA if agg in ("sum", "count") else DELTA
    assert np.max(np.abs(np.asarray(res.answer) - truth)) <= bound + 1e-6


@pytest.mark.parametrize("agg", ["sum", "count", "max", "min"])
def test_cross_backend_equivalence_post_update(indexes, updates, queries,
                                               agg):
    """All three backends produce identical post-update f64 answers."""
    lq, uq = queries
    outs = {}
    for b in BACKENDS:
        dyn = _dyn_with_updates(indexes, agg, b, updates)
        outs[b] = np.asarray(dyn.query(lq, uq).answer)
    for b in ("pallas", "ref"):
        np.testing.assert_allclose(outs[b], outs["xla"], rtol=1e-9,
                                   atol=1e-9)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("agg", ["sum", "max"])
def test_qrel_after_updates(indexes, data, updates, queries, agg, backend):
    """Fused Q_rel refinement keeps the relative bound after updates."""
    lq, uq = queries
    dyn = _dyn_with_updates(indexes, agg, backend, updates)
    truth = _updated_truth(agg, data, updates, lq, uq)
    eps_rel = 0.05
    ans = np.asarray(dyn.query(lq, uq, eps_rel=eps_rel).answer)
    pos = np.abs(truth) > 0
    rel = np.abs(ans[pos] - truth[pos]) / np.abs(truth[pos])
    assert rel.max() <= eps_rel + 1e-9


@pytest.mark.parametrize("agg", ["sum", "max"])
def test_flush_refits_and_preserves_bounds(indexes, data, updates, queries,
                                           agg):
    """A merge pass empties the buffer, re-certifies the touched segments,
    and post-refit answers stay within the certified bound (and close to
    the buffered answers)."""
    lq, uq = queries
    dyn = _dyn_with_updates(indexes, "sum" if agg == "sum" else agg,
                            "xla", updates)
    before = np.asarray(dyn.query(lq, uq).answer)
    if agg == "sum":
        assert dyn.n_pending > 0   # max deletes merged eagerly already
    dyn.flush()
    assert dyn.n_pending == 0
    assert dyn.refit_count >= 1
    truth = _updated_truth(agg, data, updates, lq, uq)
    after = np.asarray(dyn.query(lq, uq).answer)
    bound = 2 * DELTA if agg == "sum" else DELTA
    assert np.max(np.abs(after - truth)) <= bound + 1e-6
    assert np.max(np.abs(before - truth)) <= bound + 1e-6
    # every refit segment is re-certified at delta
    assert float(np.max(np.asarray(dyn.index.seg_err))) <= DELTA + 1e-9


def test_selective_refit_leaves_far_segments_alone(data):
    """Only segments whose span contains changed keys are refit; clean SUM
    segments absorb upstream inserts as an exact constant-coefficient
    shift."""
    keys, meas = data
    idx = build_index_1d(keys, meas, "sum", deg=2, delta=DELTA)
    dyn = DynamicEngine(idx, capacity=256, auto_refit=False)
    # edits confined to keys < 50
    rng = np.random.default_rng(7)
    ins_k = rng.uniform(0, 50, 30)
    dyn.insert(ins_k, rng.uniform(0, 10, 30))
    net = float(np.sum(dyn._ins_log[0][1]))
    old_lo = np.asarray(idx.seg_lo)
    old_coeffs = np.asarray(idx.coeffs)
    dyn.flush()
    new_lo = np.asarray(dyn.index.seg_lo)
    new_coeffs = np.asarray(dyn.index.coeffs)
    far_old = np.where(old_lo > 100)[0]
    assert len(far_old) > 2
    for i in far_old:
        j = np.searchsorted(new_lo, old_lo[i])
        assert new_lo[j] == old_lo[i]
        # non-constant coefficients bit-identical; constant shifted by the
        # exact net inserted mass upstream
        np.testing.assert_array_equal(new_coeffs[j, 1:], old_coeffs[i, 1:])
        np.testing.assert_allclose(new_coeffs[j, 0] - old_coeffs[i, 0], net,
                                   rtol=1e-12)


def test_capacity_trigger_auto_refits(data):
    keys, meas = data
    idx = build_index_1d(keys, meas, "sum", deg=2, delta=DELTA)
    dyn = DynamicEngine(idx, capacity=64, auto_refit=True)
    rng = np.random.default_rng(8)
    for _ in range(3):
        dyn.insert(rng.uniform(0, 600, 40), rng.uniform(0, 10, 40))
    assert dyn.refit_count >= 1
    assert dyn.n_pending < 64


def test_drift_trigger_refits_hot_segment(data):
    """Accumulated |measure| drift past a segment's error headroom forces a
    merge before the buffer fills."""
    keys, meas = data
    idx = build_index_1d(keys, meas, "sum", deg=2, delta=DELTA)
    dyn = DynamicEngine(idx, capacity=1024, auto_refit=True)
    hot = float(np.asarray(idx.seg_lo)[3]) + 1e-9
    dyn.insert(np.full(8, hot), np.full(8, 50.0))
    assert dyn.refit_count >= 1
    assert dyn.n_pending == 0


def test_extremal_delete_merges_eagerly(data, queries):
    keys, meas = data
    idx = build_index_1d(keys, meas * 100, "max", deg=3, delta=DELTA)
    dyn = DynamicEngine(idx, backend="pallas", capacity=128,
                        auto_refit=False)
    dyn.delete(keys[[10, 500, 2000]])
    assert dyn.refit_count == 1 and dyn.n_pending == 0
    lq, uq = queries
    uk, uv = _apply_updates(keys, meas * 100, np.zeros(0), np.zeros(0),
                            keys[[10, 500, 2000]])
    truth = _truth_1d("max", uk, uv, lq, uq)
    res = dyn.query(lq, uq)
    assert np.max(np.abs(np.asarray(res.answer) - truth)) <= DELTA + 1e-6


def test_background_refit_never_blocks_queries(data, queries):
    keys, meas = data
    idx = build_index_1d(keys, meas, "sum", deg=2, delta=DELTA)
    dyn = DynamicEngine(idx, capacity=256, auto_refit=False,
                        background=True)
    rng = np.random.default_rng(9)
    ins_k = rng.uniform(0, 600, 50)
    ins_v = rng.uniform(0, 10, 50)
    dyn.insert(ins_k, ins_v)
    lq, uq = queries
    truth = _updated_truth("sum", data, (ins_k, ins_v, np.zeros(0)), lq, uq)
    dyn.refit(wait=False)   # merge runs on a worker thread
    # queries keep answering within bounds throughout the merge
    for _ in range(5):
        ans = np.asarray(dyn.query(lq, uq).answer)
        assert np.max(np.abs(ans - truth)) <= 2 * DELTA + 1e-6
    dyn.refit(wait=True)    # join + surface any merge error
    assert dyn.refit_count == 1 and dyn.n_pending == 0
    ans = np.asarray(dyn.query(lq, uq).answer)
    assert np.max(np.abs(ans - truth)) <= 2 * DELTA + 1e-6


def test_duplicate_deletes_in_one_batch_take_distinct_victims(data):
    """delete([k, k]) must tombstone *both* occurrences' measures, not the
    first one twice — the buffered SUM correction is exact."""
    keys, meas = data
    k = 300.0
    keys2 = np.sort(np.concatenate([keys, [k, k]]))
    order = np.argsort(np.concatenate([keys, [k, k]]), kind="stable")
    meas2 = np.concatenate([meas, [4.0, 9.0]])[order]
    idx = build_index_1d(keys2, meas2, "sum", deg=2, delta=DELTA)
    dyn = DynamicEngine(idx, capacity=64, auto_refit=False)
    dyn.delete([k, k])
    dels = dyn._del_log[0][1]
    assert sorted(dels.tolist()) == [4.0, 9.0]
    with pytest.raises(KeyError):
        dyn.delete([k])   # only two occurrences existed


def test_2d_duplicate_delete_of_single_point_raises(dyn2d_setup):
    px, py, idx, _, _, _ = dyn2d_setup
    dyn = DynamicEngine2D(idx, capacity=64, auto_refit=False)
    x, y = float(px[0]), float(py[0])
    with pytest.raises(KeyError):
        dyn.delete([x, x], [y, y])   # one live occurrence, two tombstones


def test_delete_missing_key_raises(data):
    keys, meas = data
    idx = build_index_1d(keys, meas, "sum", deg=2, delta=DELTA)
    dyn = DynamicEngine(idx, capacity=64, auto_refit=False)
    with pytest.raises(KeyError):
        dyn.delete([keys[0] + 0.123456789])


def test_oversize_batch_raises(data):
    keys, meas = data
    idx = build_index_1d(keys, meas, "sum", deg=2, delta=DELTA)
    dyn = DynamicEngine(idx, capacity=64, auto_refit=False)
    with pytest.raises(ValueError, match="capacity"):
        dyn.insert(np.linspace(0, 600, 100), np.ones(100))


@pytest.fixture(scope="module")
def dyn2d_setup():
    rng = np.random.default_rng(13)
    px = rng.uniform(0, 120, 4000)
    py = rng.uniform(0, 120, 4000)
    idx = build_index_2d(px, py, deg=2, delta=DELTA, max_depth=6)
    ins_x = rng.uniform(0, 120, 48)
    ins_y = rng.uniform(0, 120, 48)
    del_i = rng.integers(0, 4000, 16)
    qa = rng.uniform(0, 120, 128)
    qb = qa + rng.uniform(0.5, 40, 128)
    qc = rng.uniform(0, 120, 128)
    qd = qc + rng.uniform(0.5, 40, 128)
    keep = np.ones(4000, bool)
    keep[del_i] = False
    tree = MergeSortTree.build(np.concatenate([px[keep], ins_x]),
                               np.concatenate([py[keep], ins_y]))
    cf = lambda u, v: tree.cf(jnp.asarray(u), jnp.asarray(v))
    truth = np.asarray(cf(qb, qd) - cf(qa, qd) - cf(qb, qc) + cf(qa, qc))
    return px, py, idx, (ins_x, ins_y, px[del_i], py[del_i]), \
        (qa, qb, qc, qd), truth


@pytest.mark.parametrize("backend", BACKENDS)
def test_2d_bounds_after_updates(dyn2d_setup, backend):
    px, py, idx, (ix, iy, dx, dy), q, truth = dyn2d_setup
    dyn = DynamicEngine2D(idx, backend=backend, capacity=128,
                          auto_refit=False)
    dyn.insert(ix, iy)
    dyn.delete(dx, dy)
    res = dyn.count2d(*q)
    assert np.max(np.abs(np.asarray(res.answer) - truth)) <= 4 * DELTA + 1e-6


def test_2d_cross_backend_and_flush(dyn2d_setup):
    px, py, idx, (ix, iy, dx, dy), q, truth = dyn2d_setup
    outs = {}
    for b in BACKENDS:
        dyn = DynamicEngine2D(idx, backend=b, capacity=128,
                              auto_refit=False)
        dyn.insert(ix, iy)
        dyn.delete(dx, dy)
        outs[b] = np.asarray(dyn.count2d(*q).answer)
    for b in ("pallas", "ref"):
        np.testing.assert_allclose(outs[b], outs["xla"], rtol=1e-9,
                                   atol=1e-9)
    dyn.flush()
    assert dyn.refit_count == 1 and dyn.n_pending == 0
    res = np.asarray(dyn.count2d(*q).answer)
    assert np.max(np.abs(res - truth)) <= 4 * DELTA + 1e-6


def test_serve_dynamic_endpoints():
    from repro.serve.aggregates import AggregateService
    svc = AggregateService(backend="ref", n1=4000, n2=2500, eps_abs=50.0,
                          eps_rel=None, verbose=False, dynamic=True,
                          capacity=128)
    c0, c1 = svc.domains["count"]
    lq = np.full(16, c0)
    uq = np.full(16, c1)
    base = float(np.asarray(svc.serve("count", lq, uq).answer)[0])
    svc.insert("count", np.linspace(c0 + 1e-6, c1 - 1e-6, 32))
    upd = float(np.asarray(svc.serve("count", lq, uq).answer)[0])
    assert abs(upd - (base + 32)) < 1e-6
    svc.flush("count")
    post = float(np.asarray(svc.serve("count", lq, uq).answer)[0])
    assert abs(post - upd) <= 50.0 + 1e-6
