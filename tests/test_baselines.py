"""Learned-index competitors (RMI / FITing-tree / PGM, Appendix A)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (ExactSum, FitingTree, PGMIndex, RMIIndex,
                        build_index_1d, cone_segments)


def _data(n=20_000, seed=2):
    rng = np.random.default_rng(seed)
    keys = np.sort(rng.uniform(0, 100, n))
    meas = rng.uniform(0, 5, n)
    lq = keys[rng.integers(0, n, 300)]
    uq = np.maximum(lq, keys[rng.integers(0, n, 300)])
    ex = ExactSum.build(keys, meas)
    truth = np.asarray(ex.cf_at(jnp.asarray(uq)) - ex.cf_at(jnp.asarray(lq)))
    return keys, meas, lq, uq, truth


def test_cone_segments_certificate():
    keys, meas, *_ = _data()
    cf = np.cumsum(meas)
    delta = 20.0
    s, sl, it = cone_segments(keys, cf, delta)
    idx = np.clip(np.searchsorted(s, keys, side="right") - 1, 0, len(s) - 1)
    pred = it[idx] + sl[idx] * (keys - s[idx])
    assert np.max(np.abs(cf - pred)) <= delta + 1e-6


@pytest.mark.parametrize("cls", [FitingTree, PGMIndex])
def test_linear_baselines_guarantee(cls):
    keys, meas, lq, uq, truth = _data()
    delta = 20.0
    idx = cls.build(keys, meas, delta)
    res = idx.query(jnp.asarray(lq), jnp.asarray(uq))
    assert np.max(np.abs(np.asarray(res.answer) - truth)) <= 2 * delta + 1e-6
    res_rel = idx.query(jnp.asarray(lq), jnp.asarray(uq), eps_rel=0.01)
    pos = truth > 0
    rel = np.abs(np.asarray(res_rel.answer)[pos] - truth[pos]) / truth[pos]
    assert rel.max() <= 0.01 + 1e-9


def test_rmi_rel_guarantee():
    keys, meas, lq, uq, truth = _data()
    idx = RMIIndex.build(keys, meas, n_leaf=256)
    res = idx.query(jnp.asarray(lq), jnp.asarray(uq), eps_rel=0.01)
    pos = truth > 0
    rel = np.abs(np.asarray(res.answer)[pos] - truth[pos]) / truth[pos]
    assert rel.max() <= 0.01 + 1e-9


def test_polyfit_fewer_segments_than_linear():
    """The paper's Fig. 3 claim: polynomials need fewer segments than linear
    fits at equal delta."""
    rng = np.random.default_rng(8)
    n = 30_000
    keys = np.sort(rng.uniform(0, 100, n))
    meas = rng.uniform(0, 5, n)  # smooth CF -> polynomials win
    delta = 25.0
    pf = build_index_1d(keys, meas, "sum", deg=2, delta=delta)
    ft = FitingTree.build(keys, meas, delta)
    assert pf.h < ft.h
    assert pf.size_bytes() < ft.size_bytes()
