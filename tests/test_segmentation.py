"""Segmentation tests: GS optimality (Thm 4.3), Lemma 4.2, parallel build."""
import numpy as np
import pytest

from repro.core import (dp_segmentation, fit_minimax_lp, greedy_segmentation,
                        parallel_segmentation)


def _mk(n, seed=0):
    rng = np.random.default_rng(seed)
    keys = np.sort(rng.uniform(0, 100, n))
    F = np.cumsum(rng.uniform(0, 5, n))
    return keys, F


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("deg", [1, 2])
def test_gs_matches_dp_optimum(seed, deg):
    """Theorem 4.3: GS produces the optimal number of segments."""
    keys, F = _mk(120, seed)
    delta = 3.0
    gs = greedy_segmentation(keys, F, deg, delta)
    dp = dp_segmentation(keys, F, deg, delta)
    assert len(gs) == len(dp)
    assert all(m.err <= delta + 1e-9 for m in gs)


def test_gs_exponential_equals_literal():
    keys, F = _mk(200, 3)
    a = greedy_segmentation(keys, F, 2, 5.0, use_exponential_search=True)
    b = greedy_segmentation(keys, F, 2, 5.0, use_exponential_search=False)
    assert len(a) == len(b)
    assert np.allclose([m.lo for m in a], [m.lo for m in b])


def test_lemma_42_monotonicity():
    """E(I_l) <= E(I_u) whenever the key set of I_l is contained in I_u."""
    keys, F = _mk(80, 4)
    for deg in (1, 2, 3):
        errs = [fit_minimax_lp(keys[:j], F[:j], deg).err for j in range(deg + 2, 80, 7)]
        assert all(errs[i] <= errs[i + 1] + 1e-9 for i in range(len(errs) - 1))


def test_segments_tile_domain():
    keys, F = _mk(300, 5)
    segs = greedy_segmentation(keys, F, 2, 4.0)
    assert segs[0].lo == keys[0]
    assert segs[-1].hi == keys[-1]
    for a, b in zip(segs, segs[1:]):
        ia = np.searchsorted(keys, a.hi, side="right")
        assert keys[ia] == b.lo  # next segment starts at the next key


def test_parallel_covers_and_certifies():
    keys, F = _mk(500, 6)
    delta = 4.0
    segs = parallel_segmentation(keys, F, 2, delta, chunks=8)
    assert all(m.err <= delta + 1e-9 for m in segs)
    # coverage: every key falls inside some segment
    covered = np.zeros(len(keys), bool)
    for m in segs:
        covered |= (keys >= m.lo) & (keys <= m.hi)
    assert covered.all()
    # near-optimal: at most chunks-1 extra segments vs sequential GS
    gs = greedy_segmentation(keys, F, 2, delta)
    assert len(segs) <= len(gs) + 8
