"""Guarantee invariants for 1-D queries (Lemmas 5.1-5.4) — the paper's core
correctness claims.  The property cases run as vendored parametrized tests
(fixed seed grids) so the tier-1 suite collects without hypothesis; when
hypothesis is installed they additionally run as full property tests."""
import numpy as np
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import (ExactMax, ExactSum, build_index_1d, query_max,
                        query_sum)


def _queries(keys, n_q, seed):
    rng = np.random.default_rng(seed)
    a = keys[rng.integers(0, len(keys), n_q)]
    b = keys[rng.integers(0, len(keys), n_q)]
    return np.minimum(a, b), np.maximum(a, b)


def _profiles(n, seed):
    rng = np.random.default_rng(seed)
    keys = np.sort(rng.uniform(0, 500, n))
    return keys, {
        "uniform": rng.uniform(0, 10, n),
        "walk": np.abs(np.cumsum(rng.normal(0, 3, n))) + 1,
        "heavy": rng.pareto(1.5, n) + 0.1,
    }


@pytest.mark.parametrize("profile", ["uniform", "walk", "heavy"])
@pytest.mark.parametrize("deg", [1, 2, 3])
def test_sum_abs_guarantee(profile, deg):
    """Lemma 5.1: delta = eps_abs/2 ==> |A - R| <= eps_abs."""
    keys, profs = _profiles(4000, 11)
    meas = profs[profile]
    eps = 40.0
    idx = build_index_1d(keys, meas, "sum", deg=deg, delta=eps / 2)
    lq, uq = _queries(keys, 400, 13)
    res = query_sum(idx, lq, uq)
    ex = ExactSum.build(keys, meas)
    truth = np.asarray(ex.cf_at(jnp.asarray(uq)) - ex.cf_at(jnp.asarray(lq)))
    assert np.max(np.abs(np.asarray(res.answer) - truth)) <= eps + 1e-6


@pytest.mark.parametrize("deg", [2, 3])
def test_sum_rel_guarantee(deg):
    """Lemma 5.2 + refinement: final answers satisfy eps_rel."""
    keys, profs = _profiles(4000, 17)
    meas = profs["uniform"]
    idx = build_index_1d(keys, meas, "sum", deg=deg, delta=25.0)
    lq, uq = _queries(keys, 400, 19)
    eps_rel = 0.01
    res = query_sum(idx, lq, uq, eps_rel=eps_rel)
    ex = ExactSum.build(keys, meas)
    truth = np.asarray(ex.cf_at(jnp.asarray(uq)) - ex.cf_at(jnp.asarray(lq)))
    pos = truth > 0
    rel = np.abs(np.asarray(res.answer)[pos] - truth[pos]) / truth[pos]
    assert rel.max() <= eps_rel + 1e-9
    # refinement must not fire for every query (the index is useful)
    assert np.asarray(res.refined).mean() < 1.0


@pytest.mark.parametrize("agg", ["max", "min"])
@pytest.mark.parametrize("profile", ["uniform", "walk"])
@pytest.mark.parametrize("deg", [2, 3])
def test_extremal_abs_guarantee(agg, profile, deg):
    """Lemma 5.3: delta = eps_abs ==> |A - R| <= eps_abs (MAX & MIN)."""
    keys, profs = _profiles(3000, 23)
    meas = profs[profile] * 100
    eps = 60.0
    idx = build_index_1d(keys, meas, agg, deg=deg, delta=eps)
    lq, uq = _queries(keys, 300, 29)
    res = query_max(idx, lq, uq)
    if agg == "max":
        truth = np.asarray(ExactMax.build(keys, meas).query(jnp.asarray(lq), jnp.asarray(uq)))
    else:
        truth = -np.asarray(ExactMax.build(keys, -meas).query(jnp.asarray(lq), jnp.asarray(uq)))
    assert np.max(np.abs(np.asarray(res.answer) - truth)) <= eps + 1e-6


def test_max_rel_guarantee():
    """Lemma 5.4 + refinement path."""
    keys, profs = _profiles(3000, 31)
    meas = profs["walk"] * 50
    idx = build_index_1d(keys, meas, "max", deg=3, delta=30.0)
    lq, uq = _queries(keys, 300, 37)
    eps_rel = 0.05
    res = query_max(idx, lq, uq, eps_rel=eps_rel)
    truth = np.asarray(ExactMax.build(keys, meas).query(jnp.asarray(lq), jnp.asarray(uq)))
    rel = np.abs(np.asarray(res.answer) - truth) / np.abs(truth)
    assert rel.max() <= eps_rel + 1e-9


def test_count_query():
    keys, _ = _profiles(3000, 41)
    idx = build_index_1d(keys, None, "count", deg=2, delta=20.0)
    lq, uq = _queries(keys, 200, 43)
    res = query_sum(idx, lq, uq)
    truth = np.array([((keys > a) & (keys <= b)).sum() for a, b in zip(lq, uq)])
    assert np.max(np.abs(np.asarray(res.answer) - truth)) <= 40.0 + 1e-6


def _check_sum_guarantee(seed, deg, delta):
    """Property body: for arbitrary datasets/deltas the Q_abs bound holds."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(50, 600))
    keys = np.sort(rng.uniform(0, 100, n))
    keys = np.unique(keys)
    meas = rng.uniform(0, 20, len(keys))
    idx = build_index_1d(keys, meas, "sum", deg=deg, delta=delta,
                         keep_exact=True)
    lq, uq = _queries(keys, 64, seed + 1)
    res = query_sum(idx, lq, uq)
    ex = idx.exact_sum
    truth = np.asarray(ex.cf_at(jnp.asarray(uq)) - ex.cf_at(jnp.asarray(lq)))
    assert np.max(np.abs(np.asarray(res.answer) - truth)) <= 2 * delta + 1e-6


def _check_max_guarantee(seed, deg, delta):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(50, 400))
    keys = np.unique(np.sort(rng.uniform(0, 100, n)))
    meas = rng.uniform(0, 1000, len(keys))
    idx = build_index_1d(keys, meas, "max", deg=deg, delta=delta)
    lq, uq = _queries(keys, 64, seed + 2)
    res = query_max(idx, lq, uq)
    truth = np.asarray(ExactMax.build(keys, meas).query(jnp.asarray(lq), jnp.asarray(uq)))
    assert np.max(np.abs(np.asarray(res.answer) - truth)) <= delta + 1e-6


# vendored property grids: deterministic seed/shape sweeps that run without
# hypothesis (the container may lack it; the tier-1 suite must still cover
# the invariants)
@pytest.mark.parametrize("seed,deg,delta", [
    (0, 1, 5.0), (101, 1, 200.0), (2222, 2, 17.5), (303, 2, 60.0),
    (4044, 3, 5.0), (505, 3, 120.0), (6666, 2, 200.0), (77, 1, 33.3),
])
def test_vendored_sum_guarantee(seed, deg, delta):
    _check_sum_guarantee(seed, deg, delta)


@pytest.mark.parametrize("seed,deg,delta", [
    (1, 2, 10.0), (112, 2, 300.0), (223, 3, 45.0), (3334, 3, 150.0),
    (44, 2, 80.0), (5055, 3, 10.0),
])
def test_vendored_max_guarantee(seed, deg, delta):
    _check_max_guarantee(seed, deg, delta)


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 10_000), deg=st.integers(1, 3),
           delta=st.floats(5.0, 200.0))
    def test_property_sum_guarantee(seed, deg, delta):
        _check_sum_guarantee(seed, deg, delta)

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 10_000), deg=st.integers(2, 3),
           delta=st.floats(10.0, 300.0))
    def test_property_max_guarantee(seed, deg, delta):
        _check_max_guarantee(seed, deg, delta)
