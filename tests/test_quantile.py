"""Certified quantiles: the (lo, hi) interval must bracket the exact
order statistic on every tested combination — rank grid x distribution x
backend, static and post-insert/delete dynamic state — and the mid answer
must land inside its own certificate.  COUNT certificates are checked
against *every* numpy.quantile interpolation method (the rank slack
absorbs the method differences); SUM certificates against the weighted
convention x* = min{k : F(k) >= q * total}.
"""
import numpy as np
import pytest
import jax

jax.config.update("jax_enable_x64", True)

from repro.core import build_index_1d                      # noqa: E402
from repro.engine import (BACKENDS, build_plan,            # noqa: E402
                          execute_quantile, DynamicEngine)

QS = np.array([0.01, 0.25, 0.5, 0.75, 0.99])
METHODS = ("linear", "lower", "higher", "nearest", "midpoint")


def _dataset(name, n=2048, seed=5):
    rng = np.random.default_rng(seed)
    if name == "uniform":
        keys = rng.uniform(-50.0, 50.0, n)
    elif name == "skew":
        keys = rng.lognormal(mean=1.0, sigma=1.2, size=n)
    else:   # 'dups': heavy duplicate mass + a few unique outliers
        keys = np.concatenate([
            np.repeat(rng.uniform(0, 10, 8), n // 10),
            rng.uniform(-5, 15, n - 8 * (n // 10))])
    keys = np.sort(keys)
    vals = np.abs(rng.normal(2.0, 1.0, n)) + 0.1
    return keys, vals


def _plan(keys, vals, agg, delta=24.0, deg=2):
    idx = build_index_1d(keys, np.ones_like(keys) if agg == "count"
                         else vals, agg=agg, delta=delta, deg=deg,
                         keep_exact=True)
    return build_plan(idx)


def _check_count_brackets(keys, lo, hi):
    for m in METHODS:
        truth = np.quantile(keys, QS, method=m)
        assert np.all(np.asarray(lo) <= truth + 1e-12), (m, lo, truth)
        assert np.all(truth <= np.asarray(hi) + 1e-12), (m, truth, hi)


def _weighted_truth(keys, w, q):
    cf = np.cumsum(w)
    i = np.minimum(np.searchsorted(cf, q * cf[-1], side="left"),
                   len(keys) - 1)
    return keys[i]


@pytest.mark.parametrize("dist", ["uniform", "skew", "dups"])
def test_count_certificate_brackets_every_numpy_method(dist):
    keys, vals = _dataset(dist)
    res = execute_quantile(_plan(keys, vals, "count"), QS)
    _check_count_brackets(keys, res.lo, res.hi)
    assert np.all(np.asarray(res.lo) <= np.asarray(res.answer))
    assert np.all(np.asarray(res.answer) <= np.asarray(res.hi))


@pytest.mark.parametrize("dist", ["uniform", "skew", "dups"])
def test_sum_certificate_brackets_weighted_convention(dist):
    keys, vals = _dataset(dist)
    res = execute_quantile(_plan(keys, vals, "sum"), QS)
    truth = _weighted_truth(keys, vals, QS)
    assert np.all(np.asarray(res.lo) <= truth + 1e-12)
    assert np.all(truth <= np.asarray(res.hi) + 1e-12)


@pytest.mark.parametrize("backend", BACKENDS)
def test_backends_bracket_and_agree(backend):
    keys, vals = _dataset("uniform")
    plan = _plan(keys, vals, "count")
    res = execute_quantile(plan, QS, backend=backend)
    _check_count_brackets(keys, res.lo, res.hi)
    ref = execute_quantile(plan, QS, backend="xla")
    # the locate->Newton arithmetic is identical on every backend
    for a, b in zip(res, ref):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("deg", [1, 3, 5])
def test_higher_degree_certificates(deg):
    keys, vals = _dataset("skew")
    res = execute_quantile(_plan(keys, vals, "count", deg=deg), QS)
    _check_count_brackets(keys, res.lo, res.hi)


@pytest.mark.parametrize("agg", ["count", "sum"])
def test_dynamic_post_insert_delete(agg):
    keys, vals = _dataset("uniform", seed=9)
    idx = build_index_1d(keys, np.ones_like(keys) if agg == "count"
                         else vals, agg=agg, delta=24.0, deg=2,
                         keep_exact=True)
    eng = DynamicEngine(idx, capacity=512, auto_refit=False,
                        background=False)
    rng = np.random.default_rng(3)
    # inserts straddle the fitted domain on both sides (the certificate
    # must stay sound past the base plan's key range)
    ins_k = np.concatenate([rng.uniform(-90, -60, 40),
                            rng.uniform(-40, 40, 120),
                            rng.uniform(70, 120, 40)])
    ins_v = np.abs(rng.normal(2.0, 1.0, ins_k.shape[0])) + 0.1
    if agg == "count":
        eng.insert(ins_k)
    else:
        eng.insert(ins_k, ins_v)
    drop = rng.choice(len(keys), size=150, replace=False)
    eng.delete(keys[drop])

    res = eng.quantile(QS)
    live_mask = np.ones(len(keys), bool)
    live_mask[drop] = False
    lk = np.concatenate([keys[live_mask], ins_k])
    if agg == "count":
        for m in METHODS:
            truth = np.quantile(lk, QS, method=m)
            assert np.all(np.asarray(res.lo) <= truth + 1e-12), (m,)
            assert np.all(truth <= np.asarray(res.hi) + 1e-12), (m,)
    else:
        lv = np.concatenate([vals[live_mask], ins_v])
        order = np.argsort(lk, kind="stable")
        truth = _weighted_truth(lk[order], lv[order], QS)
        assert np.all(np.asarray(res.lo) <= truth + 1e-12)
        assert np.all(truth <= np.asarray(res.hi) + 1e-12)
    assert np.all(np.asarray(res.lo) <= np.asarray(res.answer))
    assert np.all(np.asarray(res.answer) <= np.asarray(res.hi))


def test_extreme_ranks_clip_to_domain():
    keys, vals = _dataset("uniform")
    res = execute_quantile(_plan(keys, vals, "count"),
                           np.array([0.0, 1.0]))
    assert np.asarray(res.lo)[0] <= keys[0] <= np.asarray(res.hi)[0]
    assert np.asarray(res.lo)[1] <= keys[-1] <= np.asarray(res.hi)[1]


def test_rejects_extremal_and_deg0_plans():
    keys, vals = _dataset("uniform", n=512)
    idx = build_index_1d(keys, vals, agg="max", delta=24.0, deg=3,
                         keep_exact=True)
    with pytest.raises(AssertionError):
        execute_quantile(build_plan(idx), QS)
