"""Distribution tests (8 forced host devices, run in subprocesses so the
main pytest process keeps its single real device)."""
import importlib.util
import os
import subprocess
import sys

import jax
import pytest

# The subprocesses force their own device meshes, but exercising them only
# makes sense on a multi-device container; single-device CI hosts skip
# (this replaces the old --ignore flags, so the CI invocation matches the
# ROADMAP tier-1 command).
pytestmark = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="distribution tests need a container with >= 8 devices")

# the train entrypoint still imports the seed's unshipped fault-tolerance
# module (ROADMAP open item); gate the two train tests on it so the rest of
# this file (the dist selftest) runs wherever 8 devices exist
needs_fault_tolerance = pytest.mark.skipif(
    importlib.util.find_spec("repro.dist.fault_tolerance") is None,
    reason="repro.dist.fault_tolerance not implemented yet (ROADMAP)")

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))


def test_distributed_selftest():
    """shard_map PolyFit (psum/pmax guarantees), int8 ring all-reduce,
    pipeline parallelism, checkpoint re-sharding — on an 8-device mesh."""
    r = subprocess.run([sys.executable, "-m", "repro.dist._selftest"],
                       env=ENV, cwd=ROOT, capture_output=True, text=True,
                       timeout=900)
    assert "ALL_DIST_OK" in r.stdout, r.stdout + r.stderr


@needs_fault_tolerance
def test_train_failure_recovery(tmp_path):
    """launch/train.py: injected pod failure -> checkpoint restore ->
    elastic re-mesh -> deterministic replay to completion."""
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "qwen3-1.7b",
         "--smoke", "--steps", "8", "--fail-at", "5",
         "--ckpt-dir", str(tmp_path / "ck")],
        env=ENV, cwd=ROOT, capture_output=True, text=True, timeout=900)
    out = r.stdout
    assert "[FAILURE]" in out, out + r.stderr
    assert "done at step 8" in out, out + r.stderr
    # deterministic data pipeline: replayed step 4 must match pre-failure
    lines = [l for l in out.splitlines() if "step 4 " in l]
    assert len(lines) == 2 and lines[0].split("loss=")[1] == lines[1].split("loss=")[1]


@needs_fault_tolerance
def test_train_restart_from_checkpoint(tmp_path):
    """A fresh process resumes from the latest checkpoint."""
    args = [sys.executable, "-m", "repro.launch.train", "--arch",
            "mamba2-130m", "--smoke", "--steps", "6", "--ckpt-every", "2",
            "--ckpt-dir", str(tmp_path / "ck")]
    r1 = subprocess.run(args, env=ENV, cwd=ROOT, capture_output=True,
                        text=True, timeout=900)
    assert "done at step 6" in r1.stdout, r1.stdout + r1.stderr
    r2 = subprocess.run(args[:8] + ["--steps", "8"] + args[10:],
                        env=ENV, cwd=ROOT, capture_output=True, text=True,
                        timeout=900)
    assert "restored checkpoint" in r2.stdout, r2.stdout + r2.stderr
