"""Sharded plans (engine/sharded.py): partitioned segment tables answered
through the shard_map executor must be bit-identical to the single-device
path — static, Q_rel-refined, boundary-straddling, and post-insert/delete
dynamic state, at S in {2, 4, 8}.

The in-process tests need >= 8 local devices (CI forces them with
XLA_FLAGS=--xla_force_host_platform_device_count=8); single-device hosts
still get coverage through the subprocess self-test, which forces its own
8-device host topology exactly like launch/dryrun.py does."""
import os
import subprocess
import sys

import numpy as np
import pytest
import jax

jax.config.update("jax_enable_x64", True)

from repro.core import build_index_1d  # noqa: E402
from repro.engine import (DynamicEngine, Engine, ShardedEngine,  # noqa: E402
                          build_plan, shard_buffer, shard_plan)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))

multidevice = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="sharding tests need >= 8 devices (run the tier-1 job with "
           "XLA_FLAGS=--xla_force_host_platform_device_count=8)")

N = 4000
DELTA = 25.0
SHARDS = (2, 4, 8)


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(3)
    keys = np.sort(rng.uniform(0, 1000, N))
    meas = rng.uniform(0, 10, N)
    a = keys[rng.integers(0, N, 160)]
    b = keys[rng.integers(0, N, 160)]
    return keys, meas, np.minimum(a, b), np.maximum(a, b)


@pytest.fixture(scope="module")
def plans(data):
    keys, meas, _, _ = data
    out = {}
    for agg, m, deg in (("sum", meas, 2), ("count", None, 2),
                        ("max", meas * 100, 3), ("min", meas * 100, 3)):
        out[agg] = build_plan(build_index_1d(keys, m, agg, deg=deg,
                                             delta=DELTA))
    return out


def test_shard_selftest_subprocess():
    """Full bit-identity sweep in a subprocess with 8 forced host devices
    (keeps the main pytest process on its single real device)."""
    r = subprocess.run([sys.executable, "-m", "repro.engine._shard_selftest"],
                       env=ENV, cwd=ROOT, capture_output=True, text=True,
                       timeout=900)
    assert "ALL_SHARD_OK" in r.stdout, r.stdout[-3000:] + r.stderr[-3000:]


@multidevice
@pytest.mark.parametrize("nshards", SHARDS)
@pytest.mark.parametrize("agg", ["sum", "count", "max", "min"])
def test_sharded_bit_identical(plans, data, agg, nshards):
    _, _, lq, uq = data
    plan = plans[agg]
    ref = Engine(backend="xla").query(plan, lq, uq)
    got = ShardedEngine(nshards).query(plan, lq, uq)
    np.testing.assert_array_equal(np.asarray(ref.answer),
                                  np.asarray(got.answer))


@multidevice
@pytest.mark.parametrize("nshards", SHARDS)
@pytest.mark.parametrize("agg", ["sum", "max"])
def test_sharded_qrel_bit_identical(plans, data, agg, nshards):
    """Fused Q_rel refinement (sharded refinement arrays) matches, answer
    and refined mask alike."""
    _, _, lq, uq = data
    plan = plans[agg]
    ref = Engine(backend="xla").query(plan, lq, uq, eps_rel=0.05)
    got = ShardedEngine(nshards).query(plan, lq, uq, eps_rel=0.05)
    np.testing.assert_array_equal(np.asarray(ref.answer),
                                  np.asarray(got.answer))
    np.testing.assert_array_equal(np.asarray(ref.refined),
                                  np.asarray(got.refined))


@multidevice
@pytest.mark.parametrize("agg", ["sum", "max"])
def test_sharded_boundary_straddle(plans, agg):
    """Queries with endpoints exactly on / just around shard boundaries."""
    plan = plans[agg]
    eng = Engine(backend="xla")
    for nshards in SHARDS:
        sp = shard_plan(plan, nshards)
        edges = np.asarray([e for e in sp.bounds[1:-1] if np.isfinite(e)])
        assert len(edges) == nshards - 1
        for lo, hi in ((edges, edges + 29.0), (edges - 1e-9, edges + 1e-9),
                       (np.full_like(edges, float(edges.min()) - 5.0),
                        np.full_like(edges, float(edges.max()) + 5.0))):
            ref = eng.query(plan, lo, hi)
            got = ShardedEngine(nshards).query(plan, lo, hi)
            np.testing.assert_array_equal(np.asarray(ref.answer),
                                          np.asarray(got.answer))


@multidevice
@pytest.mark.parametrize("nshards", SHARDS)
def test_sharded_dynamic_state(data, nshards):
    """Partitioned delta buffers: post-insert/delete answers bit-identical
    (COUNT exercises tombstones; MAX exercises the insert sparse path)."""
    keys, meas, lq, uq = data
    rng = np.random.default_rng(17)
    for agg, m in (("count", None), ("sum", meas), ("max", meas * 100)):
        dyn = DynamicEngine(
            build_index_1d(keys, m, agg, deg=3 if agg == "max" else 2,
                           delta=DELTA),
            backend="xla", capacity=256, auto_refit=False)
        dyn.insert(rng.uniform(-50, 1100, 48),
                   None if agg == "count" else rng.uniform(0, 500, 48))
        if agg != "max":
            dyn.delete(keys[30:40])
        ref = dyn.query(lq, uq, eps_rel=0.05)
        plan, buf = dyn.snapshot()
        got = ShardedEngine(nshards).query(plan, lq, uq, eps_rel=0.05,
                                           buf=buf)
        np.testing.assert_array_equal(np.asarray(ref.answer),
                                      np.asarray(got.answer))
        np.testing.assert_array_equal(np.asarray(ref.refined),
                                      np.asarray(got.refined))


@multidevice
def test_shard_buffer_partition(plans):
    """Every buffered op lands on exactly one shard, in its key range."""
    from repro.engine import DeltaBuffer, big_sentinel
    plan = plans["sum"]
    sp = shard_plan(plan, 4)
    buf = DeltaBuffer.empty(64)
    rng = np.random.default_rng(5)
    # emulate the DynamicEngine append path with a sorted host batch
    k = np.sort(rng.uniform(0, 1000, 32))
    v = rng.uniform(0, 5, 32)
    import jax.numpy as jnp
    big = big_sentinel(jnp.float64)
    keys = jnp.concatenate([jnp.asarray(k), jnp.full((32,), big)])
    vals = jnp.concatenate([jnp.asarray(v), jnp.zeros((32,))])
    cf = jnp.concatenate([jnp.zeros((1,)), jnp.cumsum(vals)])
    buf = DeltaBuffer(keys, vals, cf, buf.del_keys, buf.del_vals,
                      buf.del_cf, None, 64)
    sb = shard_buffer(buf, sp)
    ik = np.asarray(sb.ins_keys)
    total_real = sum(int((ik[s] < big / 2).sum()) for s in range(4))
    assert total_real == 32
    for s in range(4):
        real = ik[s][ik[s] < big / 2]
        assert np.all(real >= sp.bounds[s])
        assert np.all(real < sp.bounds[s + 1])


@multidevice
def test_sharded_plan_fewer_segments_than_shards():
    """Plans with h < S leave surplus shards empty but stay correct."""
    keys = np.sort(np.random.default_rng(0).uniform(0, 100, 500))
    plan = build_plan(build_index_1d(keys, None, "count", deg=2,
                                     delta=1000.0))
    assert plan.h < 8
    lq = np.asarray([0.0, 10.0, 50.0])
    uq = np.asarray([100.0, 60.0, 55.0])
    ref = Engine(backend="xla").query(plan, lq, uq)
    got = ShardedEngine(8).query(plan, lq, uq)
    np.testing.assert_array_equal(np.asarray(ref.answer),
                                  np.asarray(got.answer))
