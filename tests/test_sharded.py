"""Sharded plans (engine/sharded.py): partitioned segment tables answered
through the shard_map executor must be bit-identical to the single-device
path — static, Q_rel-refined, boundary-straddling, and post-insert/delete
dynamic state, at S in {2, 4, 8}.

The in-process tests need >= 8 local devices (CI forces them with
XLA_FLAGS=--xla_force_host_platform_device_count=8); single-device hosts
still get coverage through the subprocess self-test, which forces its own
8-device host topology exactly like launch/dryrun.py does."""
import os
import subprocess
import sys

import numpy as np
import pytest
import jax

jax.config.update("jax_enable_x64", True)

from repro.core import build_index_1d  # noqa: E402
from repro.engine import (DynamicEngine, Engine, ShardedEngine,  # noqa: E402
                          build_plan, shard_buffer, shard_plan)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))

multidevice = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="sharding tests need >= 8 devices (run the tier-1 job with "
           "XLA_FLAGS=--xla_force_host_platform_device_count=8)")

N = 4000
DELTA = 25.0
SHARDS = (2, 4, 8)


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(3)
    keys = np.sort(rng.uniform(0, 1000, N))
    meas = rng.uniform(0, 10, N)
    a = keys[rng.integers(0, N, 160)]
    b = keys[rng.integers(0, N, 160)]
    return keys, meas, np.minimum(a, b), np.maximum(a, b)


@pytest.fixture(scope="module")
def plans(data):
    keys, meas, _, _ = data
    out = {}
    for agg, m, deg in (("sum", meas, 2), ("count", None, 2),
                        ("max", meas * 100, 3), ("min", meas * 100, 3)):
        out[agg] = build_plan(build_index_1d(keys, m, agg, deg=deg,
                                             delta=DELTA))
    return out


def test_shard_selftest_subprocess():
    """Full bit-identity sweep in a subprocess with 8 forced host devices
    (keeps the main pytest process on its single real device)."""
    r = subprocess.run([sys.executable, "-m", "repro.engine._shard_selftest"],
                       env=ENV, cwd=ROOT, capture_output=True, text=True,
                       timeout=900)
    assert "ALL_SHARD_OK" in r.stdout, r.stdout[-3000:] + r.stderr[-3000:]


@multidevice
@pytest.mark.parametrize("nshards", SHARDS)
@pytest.mark.parametrize("agg", ["sum", "count", "max", "min"])
def test_sharded_bit_identical(plans, data, agg, nshards):
    _, _, lq, uq = data
    plan = plans[agg]
    ref = Engine(backend="xla").query(plan, lq, uq)
    got = ShardedEngine(nshards).query(plan, lq, uq)
    np.testing.assert_array_equal(np.asarray(ref.answer),
                                  np.asarray(got.answer))


@multidevice
@pytest.mark.parametrize("nshards", SHARDS)
@pytest.mark.parametrize("agg", ["sum", "max"])
def test_sharded_qrel_bit_identical(plans, data, agg, nshards):
    """Fused Q_rel refinement (sharded refinement arrays) matches, answer
    and refined mask alike."""
    _, _, lq, uq = data
    plan = plans[agg]
    ref = Engine(backend="xla").query(plan, lq, uq, eps_rel=0.05)
    got = ShardedEngine(nshards).query(plan, lq, uq, eps_rel=0.05)
    np.testing.assert_array_equal(np.asarray(ref.answer),
                                  np.asarray(got.answer))
    np.testing.assert_array_equal(np.asarray(ref.refined),
                                  np.asarray(got.refined))


@multidevice
@pytest.mark.parametrize("agg", ["sum", "max"])
def test_sharded_boundary_straddle(plans, agg):
    """Queries with endpoints exactly on / just around shard boundaries."""
    plan = plans[agg]
    eng = Engine(backend="xla")
    for nshards in SHARDS:
        sp = shard_plan(plan, nshards)
        edges = np.asarray([e for e in sp.bounds[1:-1] if np.isfinite(e)])
        assert len(edges) == nshards - 1
        for lo, hi in ((edges, edges + 29.0), (edges - 1e-9, edges + 1e-9),
                       (np.full_like(edges, float(edges.min()) - 5.0),
                        np.full_like(edges, float(edges.max()) + 5.0))):
            ref = eng.query(plan, lo, hi)
            got = ShardedEngine(nshards).query(plan, lo, hi)
            np.testing.assert_array_equal(np.asarray(ref.answer),
                                          np.asarray(got.answer))


@multidevice
@pytest.mark.parametrize("nshards", SHARDS)
def test_sharded_dynamic_state(data, nshards):
    """Partitioned delta buffers: post-insert/delete answers bit-identical
    (COUNT exercises tombstones; MAX exercises the insert sparse path)."""
    keys, meas, lq, uq = data
    rng = np.random.default_rng(17)
    for agg, m in (("count", None), ("sum", meas), ("max", meas * 100)):
        dyn = DynamicEngine(
            build_index_1d(keys, m, agg, deg=3 if agg == "max" else 2,
                           delta=DELTA),
            backend="xla", capacity=256, auto_refit=False)
        dyn.insert(rng.uniform(-50, 1100, 48),
                   None if agg == "count" else rng.uniform(0, 500, 48))
        if agg != "max":
            dyn.delete(keys[30:40])
        ref = dyn.query(lq, uq, eps_rel=0.05)
        plan, buf = dyn.snapshot()
        got = ShardedEngine(nshards).query(plan, lq, uq, eps_rel=0.05,
                                           buf=buf)
        np.testing.assert_array_equal(np.asarray(ref.answer),
                                      np.asarray(got.answer))
        np.testing.assert_array_equal(np.asarray(ref.refined),
                                      np.asarray(got.refined))


@multidevice
def test_shard_buffer_partition(plans):
    """Every buffered op lands on exactly one shard, in its key range."""
    from repro.engine import DeltaBuffer, big_sentinel
    plan = plans["sum"]
    sp = shard_plan(plan, 4)
    buf = DeltaBuffer.empty(64)
    rng = np.random.default_rng(5)
    # emulate the DynamicEngine append path with a sorted host batch
    k = np.sort(rng.uniform(0, 1000, 32))
    v = rng.uniform(0, 5, 32)
    import jax.numpy as jnp
    big = big_sentinel(jnp.float64)
    keys = jnp.concatenate([jnp.asarray(k), jnp.full((32,), big)])
    vals = jnp.concatenate([jnp.asarray(v), jnp.zeros((32,))])
    cf = jnp.concatenate([jnp.zeros((1,)), jnp.cumsum(vals)])
    buf = DeltaBuffer(keys, vals, cf, buf.del_keys, buf.del_vals,
                      buf.del_cf, None, 64)
    sb = shard_buffer(buf, sp)
    ik = np.asarray(sb.ins_keys)
    total_real = sum(int((ik[s] < big / 2).sum()) for s in range(4))
    assert total_real == 32
    for s in range(4):
        real = ik[s][ik[s] < big / 2]
        assert np.all(real >= sp.bounds[s])
        assert np.all(real < sp.bounds[s + 1])


@multidevice
def test_sharded_plan_fewer_segments_than_shards():
    """Plans with h < S leave surplus shards empty but stay correct."""
    keys = np.sort(np.random.default_rng(0).uniform(0, 100, 500))
    plan = build_plan(build_index_1d(keys, None, "count", deg=2,
                                     delta=1000.0))
    assert plan.h < 8
    lq = np.asarray([0.0, 10.0, 50.0])
    uq = np.asarray([100.0, 60.0, 55.0])
    ref = Engine(backend="xla").query(plan, lq, uq)
    got = ShardedEngine(8).query(plan, lq, uq)
    np.testing.assert_array_equal(np.asarray(ref.answer),
                                  np.asarray(got.answer))


# ---------------------------------------------------------------------------
# 2-D: the Morton leaf table partitioned by contiguous z-ranges
# ---------------------------------------------------------------------------

def test_shard2d_selftest_subprocess():
    """Full 2-D z-range bit-identity sweep in a subprocess with 8 forced
    host devices (single-device hosts get coverage this way)."""
    r = subprocess.run(
        [sys.executable, "-m", "repro.engine._shard2d_selftest"],
        env=ENV, cwd=ROOT, capture_output=True, text=True, timeout=900)
    assert "ALL_SHARD2D_OK" in r.stdout, r.stdout[-3000:] + r.stderr[-3000:]


@pytest.fixture(scope="module")
def data2d():
    from repro.core import build_index_2d
    from repro.engine import build_plan_2d
    rng = np.random.default_rng(0x2D5)
    n = 2000
    px, py = rng.uniform(0, 100, n), rng.uniform(0, 100, n)
    w = 50 + 10 * np.sin(px / 9) + 10 * np.cos(py / 13)
    plans = {}
    for agg, delta in (("count2d", 25.0), ("sum2d", 400.0),
                       ("max2d", 5.0), ("min2d", 5.0)):
        meas = None if agg == "count2d" else w
        idx = build_index_2d(px, py, measures=meas, agg=agg, deg=2,
                             delta=delta, max_depth=6)
        plans[agg] = build_plan_2d(idx)
    nq = 96
    lx = rng.uniform(0, 75, nq)
    ux = lx + rng.uniform(5, 25, nq)
    ly = rng.uniform(0, 75, nq)
    uy = ly + rng.uniform(5, 25, nq)
    ci = rng.integers(0, n, nq)
    return px, py, w, plans, (lx, ux, ly, uy), (px[ci], py[ci])


@multidevice
@pytest.mark.parametrize("nshards", (1,) + SHARDS)
@pytest.mark.parametrize("agg", ["count2d", "sum2d", "max2d", "min2d"])
def test_sharded2d_bit_identical(data2d, agg, nshards):
    """z-range sharded answers == single-device engine, bit for bit, at
    S in {1, 2, 4, 8} (Q_abs and fused Q_rel, refined mask included)."""
    from repro.engine import Engine, ShardedEngine2D
    _, _, _, plans, rect, corners = data2d
    plan = plans[agg]
    ranges = rect if agg in ("count2d", "sum2d") else corners
    ref = Engine(backend="xla").query(plan, *ranges)
    refr = Engine(backend="xla").query(plan, *ranges, eps_rel=0.05)
    se = ShardedEngine2D(nshards)
    got = se.query(plan, *ranges)
    np.testing.assert_array_equal(np.asarray(ref.answer),
                                  np.asarray(got.answer))
    gr = se.query(plan, *ranges, eps_rel=0.05)
    np.testing.assert_array_equal(np.asarray(refr.answer),
                                  np.asarray(gr.answer))
    np.testing.assert_array_equal(np.asarray(refr.refined),
                                  np.asarray(gr.refined))


@multidevice
@pytest.mark.parametrize("agg", ["count2d", "sum2d", "max2d"])
def test_sharded2d_dynamic_state(data2d, agg):
    """Live DynamicEngine2D snapshots (replicated buffers) fold buffered
    updates in exactly through the sharded executors."""
    from repro.core import build_index_2d
    from repro.engine import DynamicEngine2D, ShardedEngine2D
    px, py, w, _, rect, corners = data2d
    rng = np.random.default_rng(23)
    meas = None if agg == "count2d" else w
    delta = {"count2d": 25.0, "sum2d": 400.0, "max2d": 5.0}[agg]
    idx = build_index_2d(px, py, measures=meas, agg=agg, deg=2,
                         delta=delta, max_depth=6)
    dyn = DynamicEngine2D(idx, backend="xla", capacity=128,
                          auto_refit=False)
    ins = (rng.uniform(5, 95, 24), rng.uniform(5, 95, 24))
    if agg == "count2d":
        dyn.insert(*ins)
        dyn.delete(px[30:38], py[30:38])
    else:
        dyn.insert(*ins, rng.uniform(30, 70, 24))
        if agg == "sum2d":
            dyn.delete(px[30:38], py[30:38])
    ranges = rect if agg != "max2d" else corners
    ref = dyn.query(*ranges, eps_rel=0.05)
    plan, buf = dyn.snapshot()
    for s in SHARDS:
        got = ShardedEngine2D(s).query(plan, *ranges, eps_rel=0.05,
                                       buf=buf)
        np.testing.assert_array_equal(np.asarray(ref.answer),
                                      np.asarray(got.answer))


@multidevice
def test_shard_plan_2d_partition(data2d):
    """Every leaf lands on exactly one shard; z-ranges tile [0, sentinel)."""
    from repro.engine import shard_plan_2d
    from repro.kernels.locate import INT_SENTINEL
    _, _, _, plans, _, _ = data2d
    plan = plans["sum2d"]
    sp = shard_plan_2d(plan, 4)
    assert sp.zbounds[0] == 0 and sp.zbounds[-1] == INT_SENTINEL
    assert list(sp.zbounds) == sorted(sp.zbounds)
    z = np.asarray(plan.leaf_z)[: plan.n_leaves]
    total = 0
    for s in range(4):
        local = np.asarray(sp.leaf_z[s])
        real = local[local < INT_SENTINEL]
        total += len(real)
        assert np.all(real >= sp.zbounds[s])
        assert np.all(real < sp.zbounds[s + 1])
    assert total == len(z)


def test_shard_plan_2d_requires_morton_layout():
    from repro.core import build_index_2d
    from repro.engine import build_plan_2d, shard_plan_2d
    rng = np.random.default_rng(0)
    px, py = rng.uniform(0, 50, 800), rng.uniform(0, 50, 800)
    plan = build_plan_2d(build_index_2d(px, py, deg=2, delta=1000.0,
                                        max_depth=16))
    assert plan.leaf_z is None   # beyond the int32 Morton range
    with pytest.raises(ValueError, match="Morton"):
        shard_plan_2d(plan, 2)


def test_sharded2d_s1_requires_unsharded_plan():
    """nshards=1 is the single-device path by construction; a
    pre-partitioned ShardedPlan2D would silently take the shard_map body
    (and its last-ulp fusion variance), so it is refused."""
    from repro.core import build_index_2d
    from repro.engine import ShardedEngine2D, build_plan_2d, shard_plan_2d
    rng = np.random.default_rng(1)
    px, py = rng.uniform(0, 50, 600), rng.uniform(0, 50, 600)
    plan = build_plan_2d(build_index_2d(px, py, deg=2, delta=200.0,
                                        max_depth=4))
    sp = shard_plan_2d(plan, 1)
    se = ShardedEngine2D(1)
    q = (np.array([5.0]), np.array([25.0]), np.array([5.0]),
         np.array([25.0]))
    with pytest.raises(ValueError, match="unsharded"):
        se.count2d(sp, *q)
    assert se.count2d(plan, *q).answer.shape == (1,)
