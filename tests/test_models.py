"""Per-arch smoke tests (reduced configs) + algorithmic equivalence checks
(chunked SSD == recurrence; prefill+decode == full forward)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import ARCHS
from repro.models import (decode_step, forward_train, init_model, loss_fn,
                          prefill)
from repro.models import ssm as ssm_mod


def _batch(cfg, rng, B=2, S=32):
    batch = {"tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(rng, (B, 64, cfg.frontend_dim))
        batch["tokens"] = jax.random.randint(rng, (B, cfg.dec_seq), 0, cfg.vocab)
    if cfg.family == "vlm":
        batch["images"] = jax.random.normal(rng, (B, cfg.n_img_tokens,
                                                  cfg.frontend_dim))
    return batch


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_arch_smoke_forward_and_grad(name):
    """Deliverable (f): reduced config, one forward + train grad step on CPU,
    output shapes + no NaNs."""
    cfg = ARCHS[name].smoke()
    rng = jax.random.PRNGKey(0)
    params = init_model(rng, cfg)
    batch = _batch(cfg, rng)
    lg, _ = forward_train(params, cfg, batch, remat=False)
    B = batch["tokens"].shape[0]
    S = batch["tokens"].shape[1]
    assert lg.shape == (B, S, cfg.vocab)
    assert bool(jnp.isfinite(lg).all())
    loss, metrics = jax.value_and_grad(
        lambda p: loss_fn(p, cfg, batch, remat=True)[0])(params)
    assert bool(jnp.isfinite(loss))
    leaves = jax.tree.leaves(metrics)
    assert all(bool(jnp.isfinite(l).all()) for l in leaves)


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_arch_smoke_decode(name):
    cfg = ARCHS[name].smoke()
    rng = jax.random.PRNGKey(1)
    params = init_model(rng, cfg)
    batch = _batch(cfg, rng)
    npos = batch["tokens"].shape[1] + (cfg.n_img_tokens if cfg.family == "vlm" else 0)
    cache, last = prefill(params, cfg, batch, max_seq=npos + 4)
    tok = jnp.argmax(last, -1).astype(jnp.int32)
    lg, cache = decode_step(params, cfg, cache, tok, jnp.asarray(npos, jnp.int32))
    assert lg.shape == (batch["tokens"].shape[0], cfg.vocab)
    assert bool(jnp.isfinite(lg).all())


def test_ssd_chunked_matches_recurrence():
    """The chunked SSD scan must equal the naive per-step recurrence."""
    rng = np.random.default_rng(0)
    B, S, H, P, N, chunk = 2, 64, 3, 8, 16, 16
    x = jnp.asarray(rng.normal(0, 1, (B, S, H, P)).astype(np.float32))
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (B, S, H)).astype(np.float32))
    A = -jnp.asarray(rng.uniform(0.5, 2.0, (H,)).astype(np.float32))
    Bm = jnp.asarray(rng.normal(0, 1, (B, S, N)).astype(np.float32))
    Cm = jnp.asarray(rng.normal(0, 1, (B, S, N)).astype(np.float32))
    y_chunked, s_final = ssm_mod.ssd_chunked(x, dt, A, Bm, Cm, chunk,
                                             return_state=True)
    # naive recurrence
    s = np.zeros((B, H, P, N), np.float64)
    ys = np.zeros((B, S, H, P), np.float64)
    xn, dtn, An = np.asarray(x, np.float64), np.asarray(dt, np.float64), np.asarray(A, np.float64)
    Bn, Cn = np.asarray(Bm, np.float64), np.asarray(Cm, np.float64)
    for t in range(S):
        dA = np.exp(dtn[:, t] * An[None, :])                       # (B,H)
        upd = np.einsum("bhp,bn->bhpn", xn[:, t] * dtn[:, t][..., None], Bn[:, t])
        s = s * dA[..., None, None] + upd
        ys[:, t] = np.einsum("bhpn,bn->bhp", s, Cn[:, t])
    np.testing.assert_allclose(np.asarray(y_chunked), ys, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_final), s, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("name", ["qwen3-1.7b", "gemma3-4b", "mamba2-130m",
                                  "zamba2-2.7b"])
def test_prefill_decode_consistency(name):
    """Logits from prefill+decode_step must match the full forward pass at
    the same positions (the serving path is algebraically the training
    path)."""
    cfg = ARCHS[name].smoke()
    rng = jax.random.PRNGKey(3)
    params = init_model(rng, cfg)
    B, S = 2, 33
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab)
    # full forward over all S tokens
    lg_full, _ = forward_train(params, cfg, {"tokens": tokens}, remat=False)
    # prefill on first S-1, then decode token S-1
    cache, last = prefill(params, cfg, {"tokens": tokens[:, :-1]}, max_seq=S)
    np.testing.assert_allclose(np.asarray(last),
                               np.asarray(lg_full[:, S - 2]),
                               rtol=2e-2, atol=2e-2)
    lg_step, _ = decode_step(params, cfg, cache, tokens[:, -1],
                             jnp.asarray(S - 1, jnp.int32))
    np.testing.assert_allclose(np.asarray(lg_step),
                               np.asarray(lg_full[:, S - 1]),
                               rtol=2e-2, atol=2e-2)
